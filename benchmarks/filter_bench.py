"""Filter data-plane microbenchmark (ISSUE 1 satellite; extended in PR 3).

Reports lookup / insert / insert-residue / delete keys-per-second through
``FilterOps`` for each backend, plus the keystore comparison that motivated
the OCF rework: the seed kept a Python ``dict`` and looped ``for k in
keys.tolist()`` per insert and a list-comprehension membership check per
delete; the vectorized ``VectorKeystore`` replaces both with numpy batch
ops.  The insert-residue row times a *contended* insert (preloaded table
pushed to ~0.9 load) so the eviction machinery is actually on the clock —
on the pallas backend that is the in-kernel bounded eviction rounds, on jnp
the lax.scan chain sweep.  Results land in ``BENCH_filter.json`` so later
PRs have a perf trajectory.

Run directly (``PYTHONPATH=src python benchmarks/filter_bench.py``) or via
``benchmarks/run.py``.
"""
from __future__ import annotations

import functools
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.adaptive import AdaptiveConfig, AdaptiveFilter
from repro.core import hashing
from repro.core import filter as jf
from repro.core.filter_ops import FilterOps
from repro.core.keystore import VectorKeystore
from repro.core.ocf import OCF, OcfConfig
from repro.core.scheduling import wave_count
from repro.kernels import ops as kops
from repro.kernels.stash import make_stash, stash_occupancy
from repro.streaming import GenerationConfig, GenerationalFilter

# Anchored to the repo root so run.py writes the same trajectory file no
# matter which directory it is invoked from.
JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_filter.json")
KEYSTORE_BATCH = 1 << 17          # ≥100k keys (acceptance criterion)


def _pair(rng, n):
    keys = rng.randint(0, 2 ** 63, size=n, dtype=np.int64).astype(np.uint64)
    hi, lo = hashing.key_to_u32_pair_np(keys)
    return keys, jnp.asarray(hi), jnp.asarray(lo)


def _time(f, *a, reps=5, trials=3, **kw):
    # Warm the jit/kernel cache AND drain the warm-up's async dispatch
    # before starting the clock — without the block_until_ready the first
    # timed rep used to absorb whatever compile/dispatch tail was still in
    # flight, folding compile time into keys/s on first-call rows.  The
    # timed region repeats ``trials`` times and the BEST mean wins: on a
    # shared CPU container the sub-millisecond rows otherwise swing ±30%
    # with scheduler noise, which is larger than real cross-backend deltas.
    jax.block_until_ready(f(*a, **kw))
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        for _ in range(reps):
            r = f(*a, **kw)
        jax.block_until_ready(r)
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def _interleaved_times(fns: dict, *, reps=5, trials=5) -> dict:
    """Min-of-trials per entry, with the trials INTERLEAVED across entries.

    Measuring all of backend A then all of backend B lets a noise burst
    land entirely on one backend and decide the comparison; cycling
    A, B, A, B ... exposes both arms to the same machine weather, and the
    per-entry min discards the bursts.  This is what makes cross-backend
    rows on a shared CPU container reproducible.  An entry may be
    ``(callable, reps)`` to override the rep count — the sub-millisecond
    lookup rows need many reps per timed segment or the clock granularity
    itself becomes the noise.
    """
    def split(v):
        return v if isinstance(v, tuple) else (v, reps)

    for v in fns.values():
        jax.block_until_ready(split(v)[0]())   # warm before any clock
    best = {k: float("inf") for k in fns}
    for _ in range(trials):
        for k, v in fns.items():
            f, r_n = split(v)
            t0 = time.perf_counter()
            for _ in range(r_n):
                r = f()
            jax.block_until_ready(r)
            best[k] = min(best[k], (time.perf_counter() - t0) / r_n)
    return best


def _legacy_keystore_add(store: dict, keys: np.ndarray) -> None:
    """The seed's per-key Python loop (core/ocf.py at PR 0), verbatim."""
    for k in keys.tolist():
        store[k] = store.get(k, 0) + 1


def _legacy_keystore_delete_check(store: dict, keys: np.ndarray) -> np.ndarray:
    """The seed's list-comprehension membership check, verbatim."""
    return np.array([store.get(int(k), 0) > 0 for k in keys])


def backend_rows(rng, *, backends=("jnp", "pallas"), n_buckets=1 << 14,
                 n=1 << 15):
    """(name, us_per_call, keys_per_s) rows per backend x op.

    Each op's backend arms are timed interleaved (A, B, A, B, ...) so
    machine noise can't decide the cross-backend comparison."""
    rows, results = [], {}
    _keys, hi, lo = _pair(rng, n)
    fns = {}
    for backend in backends:
        fops = FilterOps(fp_bits=16, backend=backend)
        base = jf.make_state(n_buckets, 4)
        loaded, _ = fops.insert(base, hi, lo)   # ~50% load
        fns[("lookup", backend)] = (functools.partial(
            fops.lookup, loaded, hi, lo), 8)
        fns[("insert", backend)] = (functools.partial(
            fops.insert, base, hi, lo), 3)
        fns[("delete", backend)] = (functools.partial(
            fops.delete, loaded, hi, lo), 2)
    best = _interleaved_times(fns, reps=5, trials=12)
    for (op, backend), t in best.items():
        rows.append((f"filter_{op}_{backend}", t / n * 1e6, int(n / t)))
        results[f"{op}_{backend}_keys_per_s"] = int(n / t)
    return rows, results


def residue_rows(rng, *, backends=("jnp", "pallas"), n_buckets=2048,
                 preload=6000, n=1 << 11):
    """Contended-insert rows: preloaded to ~0.73, the timed batch lands at
    ~0.98 load, so a large residue falls through to the eviction machinery
    (in-kernel rounds on pallas, the lax.scan sweep on jnp).  The pallas
    arm runs the conflict-aware scheduling pre-pass (the control planes'
    default); the batch's conflict-group count is recorded alongside."""
    rows, results = [], {}
    pre, phi, plo = _pair(rng, preload)
    _keys, hi, lo = _pair(rng, n)
    fns = {}
    for backend in backends:
        fops = FilterOps(fp_bits=16, backend=backend, schedule=True)
        loaded, ok = fops.insert(jf.make_state(n_buckets, 4), phi, plo)
        fns[backend] = functools.partial(fops.insert, loaded, hi, lo)
    best = _interleaved_times(fns, reps=3, trials=5)
    for backend, t in best.items():
        rows.append((f"filter_insert_residue_{backend}", t / n * 1e6,
                     int(n / t)))
        results[f"insert_residue_{backend}_keys_per_s"] = int(n / t)
    # Scheduler introspection: how many conflict-free waves the contended
    # batch splits into (1 == already conflict-free), i.e. the intra-batch
    # serialization the wave pre-pass unwinds.
    i1 = hashing.index_hash_dyn(hi, lo, n_buckets)
    results["schedule_waves_residue"] = int(
        wave_count(i1, jnp.ones((n,), bool)))
    return rows, results


def stash_rows(rng, *, backends=("jnp", "pallas"), n_buckets=2048,
               preload=6000, n=1 << 11, stash_slots=256):
    """Stash-path rows (ISSUE 4): the same contended workload as
    ``residue_rows`` but through ``insert_spill`` — overflow parks in the
    stash instead of rolling back — plus the measured stash hit rate of a
    lookup over everything that landed."""
    rows, results = [], {}
    pre, phi, plo = _pair(rng, preload)
    _keys, hi, lo = _pair(rng, n)
    spills = {}
    for backend in backends:
        fops = FilterOps(fp_bits=16, backend=backend, schedule=True)
        loaded, _ = fops.insert(jf.make_state(n_buckets, 4), phi, plo)
        spills[backend] = (fops, functools.partial(
            fops.insert_spill, loaded, make_stash(stash_slots), hi, lo))
    best = _interleaved_times({b: f for b, (_o, f) in spills.items()},
                              reps=3, trials=5)
    for backend, t in best.items():
        rows.append((f"filter_insert_spill_{backend}", t / n * 1e6,
                     int(n / t)))
        results[f"insert_spill_{backend}_keys_per_s"] = int(n / t)
        fops, spill = spills[backend]
        st, stash, ok = spill()
        spilled = int(stash_occupancy(stash))
        hits = np.asarray(fops.lookup_with_stash(st, stash, hi, lo))
        table_only = np.asarray(fops.lookup(st, hi, lo))
        stash_hits = int((hits & ~table_only).sum())
        results[f"stash_spilled_{backend}"] = spilled
        results[f"stash_hit_rate_{backend}"] = (
            stash_hits / max(1, int(hits.sum())))
        rows.append((f"stash_hit_rate_{backend}", 0.0,
                     results[f"stash_hit_rate_{backend}"]))
    return rows, results


def generational_rows(rng, *, backends=("jnp", "pallas"), k=4,
                      capacity=1 << 14, n=1 << 15):
    """Generational-lookup rows (ISSUE 4): keys/s for a probe that fans
    out over K live TTL generations (+ stashes) in one fused device call —
    the streaming subsystem's serving hot path."""
    rows, results = [], {}
    keys = rng.randint(0, 2 ** 63, size=n, dtype=np.int64).astype(np.uint64)
    fns = {}
    for backend in backends:
        gf = GenerationalFilter(GenerationConfig(
            generations=k, capacity=capacity, backend=backend), now=0.0)
        per_gen = n // k
        for g in range(k):
            gf.insert(keys[g * per_gen:(g + 1) * per_gen], now=0.0)
            if g < k - 1:
                gf.rotate(now=0.0)
        assert gf.live_generations == k
        fns[backend] = functools.partial(gf.lookup, keys, now=0.0)
    best = _interleaved_times(fns, reps=5, trials=12)
    for backend, t in best.items():
        rows.append((f"generational_lookup_{backend}", t / n * 1e6,
                     int(n / t)))
        results[f"generational_lookup_{backend}_keys_per_s"] = int(n / t)
        results[f"generational_lookup_{backend}_generations"] = k
        # Per-live-generation normalized throughput (generation-probes/s):
        # a probe over K generations does K tables' worth of work per key,
        # so keys/s alone halves whenever K doubles — this row is invariant
        # to K-rotation changes and is the one to trend across PRs.
        results[f"generational_lookup_{backend}_gen_probes_per_s"] = int(
            n * k / t)
    return rows, results


def adaptive_rows(rng, *, n_buckets=4096, n_members=12_000, n_neg=1 << 15,
                  fp_bits=12, rounds=3):
    """False-positive-rate rows: static vs adaptive under two query mixes.

    ``fp_bits=12`` (not the default 16) so the baseline FPR is large enough
    to measure deterministically at this query count (~2e-3 -> ~60 false
    positives over 2^15 negatives with the fixed bench seed).

      * **uniform** — fresh random non-members, each queried once.  The
        feedback loop never sees a key twice, so static and adaptive track
        the same partial-key collision rate; this row pins down that
        adaptivity costs nothing on non-repeating traffic.
      * **adversarial** — ONE non-member population replayed every round
        (the degradation-of-service pattern: a static filter's false
        positives are deterministic, so an attacker replays them to force
        slow-path work forever).  Between rounds the adaptive filter gets
        the confirmed false positives reported back; the recorded row is
        the FINAL round's rate.  ``scripts/bench_gate.py`` enforces the
        acceptance ratio (adaptive <= static/10 after feedback) and the
        absolute ceilings on all four rows, same-run.

    Also asserts the zero-false-negative contract (every placed member
    still answers True after all adaptation) and records the adaptive
    lookup's throughput row for the perf trajectory.
    """
    rows, results = [], {}
    members = np.unique(rng.randint(0, 2 ** 63, size=n_members,
                                    dtype=np.int64).astype(np.uint64))
    neg = np.unique(rng.randint(0, 2 ** 63, size=2 * n_neg,
                                dtype=np.int64).astype(np.uint64))
    neg = neg[~np.isin(neg, members)]
    uniform, adversarial = neg[:n_neg], neg[n_neg:2 * n_neg]
    mhi, mlo = hashing.key_to_u32_pair_np(members)
    mhi, mlo = jnp.asarray(mhi), jnp.asarray(mlo)

    fops = FilterOps(fp_bits=fp_bits, backend="auto")
    static, ok_s = fops.insert(jf.make_state(n_buckets, 4), mhi, mlo)
    af = AdaptiveFilter(AdaptiveConfig(n_buckets=n_buckets, bucket_size=4,
                                       fp_bits=fp_bits, backend="auto"))
    ok_a = af.insert(members)

    def static_fpr(keys):
        hi, lo = hashing.key_to_u32_pair_np(keys)
        hits = np.asarray(fops.lookup(static, jnp.asarray(hi),
                                      jnp.asarray(lo)))
        return float(hits.mean())

    results["fp_rate_static_uniform"] = static_fpr(uniform)
    results["fp_rate_adaptive_uniform"] = float(af.lookup(uniform).mean())
    results["fp_rate_static_adversarial"] = static_fpr(adversarial)
    for _ in range(rounds):
        hits = af.lookup(adversarial)
        af.report_false_positives(adversarial[hits])
    results["fp_rate_adaptive_adversarial"] = float(
        af.lookup(adversarial).mean())
    results["fp_rate_fp_bits"] = fp_bits
    results["fp_rate_feedback_rounds"] = rounds

    # Zero-false-negative contract — adaptation may never lose a member.
    ok_s, ok_a = np.asarray(ok_s), np.asarray(ok_a)
    s_hi, s_lo = hashing.key_to_u32_pair_np(members[ok_s])
    assert np.asarray(fops.lookup(static, jnp.asarray(s_hi),
                                  jnp.asarray(s_lo))).all()
    assert af.lookup(members[ok_a]).all(), \
        "adaptive filter lost a member after feedback"

    qhi, qlo = hashing.key_to_u32_pair_np(adversarial)
    qhi, qlo = jnp.asarray(qhi), jnp.asarray(qlo)
    t = _time(functools.partial(af.ops.lookup_adaptive, af.state, qhi, qlo,
                                stash=af.stash), reps=8, trials=5)
    n = adversarial.size
    rows.append(("adaptive_lookup", t / n * 1e6, int(n / t)))
    results["adaptive_lookup_keys_per_s"] = int(n / t)
    for k in ("fp_rate_static_uniform", "fp_rate_adaptive_uniform",
              "fp_rate_static_adversarial", "fp_rate_adaptive_adversarial"):
        rows.append((k, 0.0, results[k]))
    return rows, results


def autotune_rows(*, n_buckets=1 << 14, residue_buckets=2048, n=1 << 15):
    """Record the BLOCK sizes the autotuner picks for the bench shapes —
    the knob `kernels/ops.py::autotune_block` now derives from the VMEM
    footprint model instead of the old fixed 1024."""
    main_bytes = n_buckets * 4 * 4
    residue_bytes = residue_buckets * 4 * 4
    results = {
        "autotune_block_probe": kops.autotune_block(
            "probe", table_bytes=main_bytes),
        "autotune_block_insert": kops.autotune_block(
            "insert", table_bytes=main_bytes, evict_rounds=32, n_keys=n),
        "autotune_block_insert_residue": kops.autotune_block(
            "insert", table_bytes=residue_bytes, evict_rounds=32,
            stash_slots=256, n_keys=1 << 11),
        "autotune_block_delete": kops.autotune_block(
            "delete", table_bytes=main_bytes, n_keys=n),
    }
    rows = [(k, 0.0, v) for k, v in results.items()]
    return rows, results


def telemetry_rows(rng, *, n_buckets=1 << 14, n=1 << 15,
                   wave_slots=512, n_waves=48):
    """Telemetry-overhead rows (observability PR), two levels:

    * **raw twin rows** — each ``FilterOps`` op timed against its ``*_tm``
      twin (arms interleaved), recording what the device counter planes
      cost at the jit boundary.  Informational: on the CPU emulation arm
      the per-lane depth attribution is real extra work against a ~13
      ns/key probe, so the lookup delta here is an emulation artifact a
      fused TPU kernel absorbs — these rows track the trajectory, they
      are not the gate.
    * **wave rows** — the serving surface the PR actually instruments: a
      fixed mixed insert/lookup/delete stream replayed through
      ``FilterOpBatcher`` with telemetry off vs on (on = twin jits +
      counter transfer + metrics registry fold, exactly what ``slo.py
      --telemetry`` pays).  ``telemetry_overhead_pct`` is this arm's
      slowdown; ``scripts/bench_gate.py`` fails verify when it exceeds
      its ceiling (default 5%) — the twin-jit design promises
      observability is cheap enough to leave on in serving, and this row
      is where that promise is measured, not asserted.
    """
    from repro.serving.scheduler import FilterOpBatcher
    rows, results = [], {}
    _keys, hi, lo = _pair(rng, n)
    fops = FilterOps(fp_bits=16, backend="pallas")
    base = jf.make_state(n_buckets, 4)
    loaded, _ = fops.insert(base, hi, lo)   # ~50% load
    fns = {
        ("lookup", "off"): (functools.partial(fops.lookup, loaded, hi, lo),
                            8),
        ("lookup", "on"): (functools.partial(fops.lookup_tm, loaded, hi, lo),
                           8),
        ("insert", "off"): (functools.partial(fops.insert, base, hi, lo), 3),
        ("insert", "on"): (functools.partial(fops.insert_tm, base, hi, lo),
                           3),
        ("delete", "off"): (functools.partial(fops.delete, loaded, hi, lo),
                            2),
        ("delete", "on"): (functools.partial(fops.delete_tm, loaded, hi, lo),
                           2),
    }
    best = _interleaved_times(fns, reps=5, trials=12)
    for op in ("lookup", "insert", "delete"):
        t_off, t_on = best[(op, "off")], best[(op, "on")]
        for arm, t in (("off", t_off), ("on", t_on)):
            rows.append((f"telemetry_{op}_{arm}", t / n * 1e6, int(n / t)))
            results[f"telemetry_{op}_{arm}_keys_per_s"] = int(n / t)
        results[f"telemetry_{op}_overhead_pct"] = round(
            (t_on / t_off - 1.0) * 100.0, 2)

    # Serving wave path: one deterministic mixed stream, fresh batcher per
    # run (waves mutate state), arms alternated so both see the same
    # machine weather; min-of-trials per arm.
    kinds = ("insert", "lookup", "delete")
    stream = [(kinds[i % 3],
               rng.randint(1, 2 ** 62, size=wave_slots,
                           dtype=np.int64).astype(np.uint64))
              for i in range(n_waves)]
    total_ops = n_waves * wave_slots

    def run_arm(telemetry: bool) -> float:
        ops = FilterOps(fp_bits=16, backend="pallas")
        batcher = FilterOpBatcher(
            ops, jf.make_state(4096, 4), stash=make_stash(64),
            wave_slots=wave_slots, double_buffer=True, telemetry=telemetry)
        t0 = time.perf_counter()
        for kind, keys in stream:
            batcher.submit(kind, keys)
        batcher.flush()
        return time.perf_counter() - t0

    run_arm(False), run_arm(True)          # compile both arms off-clock
    wave_best = {False: float("inf"), True: float("inf")}
    for _ in range(5):
        for arm in (False, True):
            wave_best[arm] = min(wave_best[arm], run_arm(arm))
    for arm, label in ((False, "off"), (True, "on")):
        t = wave_best[arm]
        rows.append((f"telemetry_wave_{label}", t / total_ops * 1e6,
                     int(total_ops / t)))
        results[f"telemetry_wave_{label}_keys_per_s"] = int(total_ops / t)
    results["telemetry_overhead_pct"] = round(
        (wave_best[True] / wave_best[False] - 1.0) * 100.0, 2)
    rows.append(("telemetry_overhead_pct", 0.0,
                 results["telemetry_overhead_pct"]))
    return rows, results


def keystore_rows(rng, *, n=KEYSTORE_BATCH):
    """Vectorized keystore vs the seed per-key dict loop on one big batch."""
    keys = rng.randint(0, 2 ** 63, size=n, dtype=np.int64).astype(np.uint64)

    t0 = time.perf_counter()
    legacy: dict[int, int] = {}
    _legacy_keystore_add(legacy, keys)
    _legacy_keystore_delete_check(legacy, keys)
    t_legacy = time.perf_counter() - t0

    t0 = time.perf_counter()
    ks = VectorKeystore()
    ks.add(keys)
    ks.remove(keys)
    t_vec = time.perf_counter() - t0

    rows = [
        ("keystore_legacy_dict_loop", t_legacy / n * 1e6, int(n / t_legacy)),
        ("keystore_vectorized", t_vec / n * 1e6, int(n / t_vec)),
    ]
    results = {
        "keystore_batch": int(n),
        "keystore_legacy_dict_loop_s": t_legacy,
        "keystore_vectorized_s": t_vec,
        "keystore_speedup": t_legacy / t_vec,
    }
    return rows, results


def ocf_insert_rows(rng, *, n=KEYSTORE_BATCH):
    """End-to-end OCF.insert on a ≥100k-key burst (vectorized keystore)."""
    keys = rng.randint(0, 2 ** 63, size=n, dtype=np.int64).astype(np.uint64)
    ocf = OCF(OcfConfig(capacity=2 * n, backend="auto"))
    ocf.insert(keys[:1024])   # warm the jit cache at this buffer size
    t0 = time.perf_counter()
    ocf.insert(keys[1024:])
    t = time.perf_counter() - t0
    kps = int((n - 1024) / t)
    rows = [("ocf_insert_burst", t / (n - 1024) * 1e6, kps)]
    return rows, {"ocf_insert_burst_keys": int(n),
                  "ocf_insert_burst_keys_per_s": kps}


def distributed_rows():
    """Routed vs host-loop sharded writes (PR 6) — run in a subprocess.

    ``distributed_bench.py`` forces a 4-device host platform, which must
    happen before jax initializes; this process already holds a 1-device
    jax, so the benchmark runs out-of-process and hands back its JSON
    (last stdout line).  The routed/hostloop pairing is the PR-6
    acceptance comparison: same per-shard kernels, different dispatch
    architecture — ``scripts/bench_gate.py`` enforces routed >= hostloop
    on the insert row in addition to the usual regression threshold.
    """
    script = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "distributed_bench.py")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    out = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, timeout=1200, env=env)
    if out.returncode != 0:
        raise RuntimeError(
            f"distributed_bench failed:\n{out.stderr[-3000:]}")
    results = json.loads(out.stdout.strip().splitlines()[-1])
    rows = [(k, results.get(k.replace("_keys_per_s", "_us_per_key"), 0.0), v)
            for k, v in results.items() if k.endswith("_keys_per_s")]
    return rows, results


def elastic_rows():
    """Elastic resharding + recovery rows (ISSUE 10) — subprocess.

    ``elastic_bench.py`` forces a 4-device host platform (same constraint
    as ``distributed_bench.py``: must precede jax init) and measures the
    full cutover protocol — live 2->4 split with a parked concurrent
    stream, 4->2 merge, shard-loss recovery from a durable snapshot.
    ``scripts/bench_gate.py`` enforces the recovery rows structurally:
    zero false negatives in every phase, migration failures == 0, the
    deferred backlog drained to exactly 0, and time-to-recover present
    and positive.
    """
    script = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "elastic_bench.py")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    out = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, timeout=1200, env=env)
    if out.returncode != 0:
        raise RuntimeError(f"elastic_bench failed:\n{out.stderr[-3000:]}")
    results = json.loads(out.stdout.strip().splitlines()[-1])
    rows = [(k, 0.0, v) for k, v in sorted(results.items())
            if k.endswith("_keys_per_s") or k.endswith("_s")]
    return rows, results


def slo_rows(*, seed=0):
    """Latency-SLO scenario x percentile matrix (ISSUE 8).

    Replays the deterministic workload scenarios closed-loop through the
    serving submit path (``repro.serving.slo``) and records op-weighted
    p50/p99/p99.9 + keys/s per scenario, the sync-path burst arm the
    double-buffer comparison gates on, and the admission arm's shed/defer
    counters.  ``scripts/bench_gate.py`` fails verify when a committed
    ``slo_*_p99_us`` row regresses or the async burst tail falls behind
    the sync one in the same run.
    """
    from repro.serving.slo import bench_scenarios
    results = bench_scenarios(seed=seed)
    rows = [(k, 0.0, v) for k, v in sorted(results.items())
            if k.endswith("_us") or k.endswith("_keys_per_s")]
    return rows, results


def run(json_path: str | None = JSON_PATH):
    rng = np.random.RandomState(0)
    rows, results = [], {"backend_default": jax.default_backend()}
    for fn in (backend_rows, residue_rows, stash_rows, generational_rows,
               adaptive_rows, telemetry_rows, keystore_rows, ocf_insert_rows):
        r, res = fn(rng)
        rows += r
        results.update(res)
    for fn in (autotune_rows, distributed_rows, elastic_rows, slo_rows):
        r, res = fn()
        rows += r
        results.update(res)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
