"""Filter data-plane microbenchmarks: JAX bulk ops + Pallas-vs-ref probes.

These are the TPU-adaptation numbers (DESIGN.md §2): vectorized bulk
lookup/insert throughput and the optimistic parallel-insert coverage.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import filter as jf
from repro.core import hashing
from repro.kernels import ops


def _pair(rng, n):
    keys = rng.randint(0, 2 ** 63, size=n, dtype=np.int64).astype(np.uint64)
    hi, lo = hashing.key_to_u32_pair_np(keys)
    return jnp.asarray(hi), jnp.asarray(lo)


def _time(f, *a, reps=5, **kw):
    f(*a, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        r = f(*a, **kw)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps


def run():
    rows = []
    rng = np.random.RandomState(0)
    n_buckets, n = 1 << 15, 1 << 16
    hi, lo = _pair(rng, n)
    st = jf.make_state(n_buckets, 4)
    st, ok = jf.bulk_insert_hybrid(st, hi, lo, fp_bits=16)

    t = _time(jf.bulk_lookup, st, hi, lo, fp_bits=16)
    rows.append(("bulk_lookup_jax", t / n * 1e6, int(n / t)))

    t = _time(ops.filter_lookup, st.table, hi, lo, fp_bits=16,
              use_pallas="always")
    rows.append(("bulk_lookup_pallas_interp", t / n * 1e6, int(n / t)))

    t = _time(ops.hash_keys, hi, lo, fp_bits=16, n_buckets=n_buckets)
    rows.append(("fingerprint_kernel", t / n * 1e6, int(n / t)))

    # insert strategies at 50% load into fresh tables
    def seq_insert():
        s, _ = jf.bulk_insert(jf.make_state(n_buckets, 4), hi, lo, fp_bits=16)
        return s.table

    def par_insert():
        s, placed = jf.parallel_insert_once(jf.make_state(n_buckets, 4), hi,
                                            lo, fp_bits=16)
        return placed

    t = _time(seq_insert, reps=2)
    rows.append(("bulk_insert_scan", t / n * 1e6, int(n / t)))
    t = _time(par_insert, reps=3)
    placed = jf.parallel_insert_once(jf.make_state(n_buckets, 4), hi, lo,
                                     fp_bits=16)[1]
    cov = float(jnp.mean(placed))
    rows.append(("parallel_insert_once", t / n * 1e6, round(cov, 4)))
    return rows
