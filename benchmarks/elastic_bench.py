"""Elastic resharding + recovery benchmark (ISSUE 10) — 4-device CPU mesh.

Measures the zero-downtime control plane end to end and emits the
``elastic_*`` recovery rows ``scripts/bench_gate.py`` enforces:

  * a live 2->4 **split** through the full cutover protocol (pump held,
    concurrent stream parked, migrate, retarget, drain) — migration
    throughput (keys/s over the migration window), rounds, time-to-recover
    (hold -> backlog drained), residual backlog, and false negatives on the
    previously-acknowledged keys;
  * the inverse 4->2 **merge** (the contended direction: two shards'
    entries interleave into one, exercising eviction chains + stash spill
    on the receive path);
  * a **shard-loss recovery**: checkpoint, kill one shard, degraded-window
    lookups (must be FN-free), restore from the snapshot — time-to-recover
    for the restore.

Run standalone (prints one JSON line, the filter_bench subprocess contract)
or through ``filter_bench.elastic_rows``.  Migration jits are warmed with a
throwaway split/merge at the same geometry so the timed runs measure the
steady-state control plane, not trace time.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.checkpoint import ckpt  # noqa: E402
from repro.core import distributed as dist  # noqa: E402
from repro.core import hashing  # noqa: E402
from repro.distributed import elastic, fault  # noqa: E402
from repro.obs import MetricsRegistry, RecoveryMetrics  # noqa: E402
from repro.serving.scheduler import DeferredWritePump  # noqa: E402

NB, BS, FP, SS = 512, 4, 16, 128
CF = 4.0
N_KEYS = 3072
N_CONCURRENT = 256


def _keys(rng, n):
    raw = rng.randint(0, 2**63, size=n, dtype=np.int64).astype(np.uint64)
    return hashing.key_to_u32_pair_np(raw)


def run() -> dict:
    rng = np.random.RandomState(0)
    m2 = elastic.filter_mesh(2)
    m4 = elastic.filter_mesh(4)
    hi, lo = _keys(rng, N_KEYS)

    def fresh_pump(metrics=None, recovery=None):
        pump = DeferredWritePump(
            m2, "data", dist.make_sharded_state(2, NB, BS, stash_slots=SS),
            fp_bits=FP, backend="jnp", donate=False, metrics=metrics,
            route="pair", capacity_factor=CF)
        pump.submit(hi, lo)
        pump.run_until_drained()
        assert pump.pending == 0 and pump.stats.failed == 0
        return pump

    # -- warmup: compile the migration round jits at this geometry --
    warm = fresh_pump()
    warm_state, _ = elastic.split_state(m4, "data", warm.state)
    elastic.merge_state(m4, "data", warm_state)

    # -- timed split through the full cutover protocol --
    reg = MetricsRegistry()
    rec = RecoveryMetrics(metrics=reg)
    pump = fresh_pump(metrics=reg, recovery=rec)
    ctrl = elastic.ElasticController(pump, axis="data", recovery=rec)
    chi, clo = _keys(rng, N_CONCURRENT)
    pump.hold()
    pump.submit(chi, clo)             # concurrent stream: parks mid-cutover
    t0 = time.perf_counter()
    rep_split = ctrl.split(m4)
    split_ttr = time.perf_counter() - t0
    backlog_after = pump.pending

    ahi = np.concatenate([hi, chi])
    alo = np.concatenate([lo, clo])
    hits, _ = dist.distributed_lookup(
        m4, "data", pump.state, jnp.asarray(ahi), jnp.asarray(alo),
        fp_bits=FP, backend="jnp", route="pair", capacity_factor=CF)
    split_fns = int((~np.asarray(hits)).sum())

    # -- timed merge (plain state path: the migration engine itself) --
    rep_merge = ctrl.merge(m2)
    hits2, _ = dist.distributed_lookup(
        m2, "data", pump.state, jnp.asarray(ahi), jnp.asarray(alo),
        fp_bits=FP, backend="jnp", route="pair", capacity_factor=CF)
    merge_fns = int((~np.asarray(hits2)).sum())

    # -- shard-loss recovery from a durable snapshot --
    with tempfile.TemporaryDirectory() as d:
        ckpt.save_sharded(d, 1, pump.state)
        inj = fault.FaultInjector(recovery=rec)
        dead = inj.kill(pump.state, 0)
        dh, _, deg = fault.degraded_lookup(
            m2, "data", dead, jnp.asarray(ahi), jnp.asarray(alo),
            fp_bits=FP, injector=inj, backend="jnp", capacity_factor=CF,
            route="pair", recovery=rec)
        degraded_fns = int((~np.asarray(dh)).sum())
        t0 = time.perf_counter()
        healed = fault.recover_shard(dead, 0, ckpt_dir=d, injector=inj,
                                     recovery=rec)
        recover_s = time.perf_counter() - t0
    rh, _ = dist.distributed_lookup(
        m2, "data",
        healed._replace(tables=jnp.asarray(healed.tables),
                        stashes=jnp.asarray(healed.stashes)),
        jnp.asarray(ahi), jnp.asarray(alo), fp_bits=FP, backend="jnp",
        route="pair", capacity_factor=CF)
    recover_fns = int((~np.asarray(rh)).sum())

    return {
        "elastic_split_keys_per_s": round(
            rep_split.keys_moved / max(rep_split.seconds, 1e-9), 1),
        "elastic_merge_keys_per_s": round(
            rep_merge.keys_moved / max(rep_merge.seconds, 1e-9), 1),
        "elastic_split_seconds": round(rep_split.seconds, 4),
        "elastic_merge_seconds": round(rep_merge.seconds, 4),
        "elastic_split_rounds": rep_split.rounds,
        "elastic_merge_rounds": rep_merge.rounds,
        "elastic_split_keys_moved": rep_split.keys_moved,
        "elastic_merge_keys_moved": rep_merge.keys_moved,
        "elastic_migration_failed": rep_split.failed + rep_merge.failed,
        "elastic_time_to_recover_s": round(split_ttr, 4),
        "elastic_shard_restore_s": round(recover_s, 4),
        "elastic_deferred_backlog_after": int(backlog_after),
        "elastic_split_false_negatives": split_fns,
        "elastic_merge_false_negatives": merge_fns,
        "elastic_degraded_false_negatives": degraded_fns,
        "elastic_degraded_answers": int(np.asarray(deg).sum()),
        "elastic_recover_false_negatives": recover_fns,
    }


if __name__ == "__main__":
    print(json.dumps(run()))
