"""Benchmarks reproducing the paper's Table I, Fig. 2 and Fig. 3.

Scaled to CI time (100k keys instead of 1M by default; pass --full for the
paper's sizes).  Outputs CSV rows ``name,us_per_call,derived`` (derived
carries the table's own quantity — occupancy, false positives, bytes, …).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import OCF, OcfConfig, PyCuckooFilter
from repro.core.metrics import measure_false_positives


def _keys(rng, n):
    return rng.randint(0, 2 ** 63, size=n, dtype=np.int64).astype(np.uint64)


def table1_occupancy_and_fp(n_keys: int = 100_000, batch: int = 4096):
    """Paper Table I: occupancy + avg false positives, EOF vs PRE.

    The paper inserts 1M keys and reports EOF occupancy 0.74 vs PRE 0.47
    (PRE pre-allocates ~2x) and avg FPs 49 (EOF) vs 32 (PRE) per 1M-key
    probe set.  We reproduce the *relationships*: EOF denser than PRE,
    PRE slightly fewer FPs, both well under 0.1% FP rate.
    """
    rows = []
    rng = np.random.RandomState(0)
    keys = _keys(rng, n_keys)
    probes = _keys(rng, n_keys)
    for mode in ("EOF", "PRE"):
        ocf = OCF(OcfConfig(capacity=2 * batch, mode=mode))
        t0 = time.perf_counter()
        for i in range(0, n_keys, batch):
            ocf.insert(keys[i:i + batch])
        dt = time.perf_counter() - t0
        fps = measure_false_positives(ocf, probes)
        rows.append((f"table1_{mode.lower()}_occupancy",
                     dt / max(1, n_keys) * 1e6, round(ocf.occupancy, 4)))
        rows.append((f"table1_{mode.lower()}_false_positives",
                     dt / max(1, n_keys) * 1e6, fps))
        rows.append((f"table1_{mode.lower()}_capacity",
                     dt / max(1, n_keys) * 1e6, ocf.capacity))
    return rows


def fig2_throughput(rounds: int = 40, burst: int = 2048):
    """Paper Fig. 2: sustained insert bursts — EOF, PRE and the unmanaged
    cuckoo filter.  The unmanaged filter saturates within the first trials
    (insert failures); EOF and PRE keep absorbing the burst.
    """
    rows = []
    rng = np.random.RandomState(1)
    for mode in ("EOF", "PRE"):
        ocf = OCF(OcfConfig(capacity=2 * burst, mode=mode))
        inserted = 0
        t0 = time.perf_counter()
        for r in range(rounds):
            ocf.insert(_keys(rng, burst))
            inserted += burst
        dt = time.perf_counter() - t0
        rows.append((f"fig2_{mode.lower()}_throughput_keys_per_s",
                     dt / inserted * 1e6, int(inserted / dt)))
        rows.append((f"fig2_{mode.lower()}_final_capacity",
                     dt / inserted * 1e6, ocf.capacity))
    # unmanaged traditional cuckoo filter: fixed capacity
    f = PyCuckooFilter(n_buckets=burst // 2, bucket_size=4, fp_bits=16)
    fail_round = None
    t0 = time.perf_counter()
    for r in range(rounds):
        ok = f.bulk_insert(_keys(rng, burst))
        if not ok.all():
            fail_round = r
            break
    dt = time.perf_counter() - t0
    rows.append(("fig2_unmanaged_saturates_at_round",
                 dt / max(1, (fail_round or rounds) * burst) * 1e6,
                 fail_round if fail_round is not None else -1))
    return rows


def fig3_size_trendlines(rounds: int = 30, burst: int = 2048):
    """Paper Fig. 3: capacity trendlines — PRE grows ~2x beyond need while
    EOF tracks the optimal size.  Derived value: final PRE/EOF capacity
    ratio (>1 reproduces the paper's memory story) and mean occupancy.
    """
    rng = np.random.RandomState(2)
    caps = {}
    occs = {}
    for mode in ("EOF", "PRE"):
        ocf = OCF(OcfConfig(capacity=2 * burst, mode=mode))
        t0 = time.perf_counter()
        for r in range(rounds):
            ocf.insert(_keys(rng, burst))
            # mixed churn in later rounds (deletes shrink)
            if r > rounds // 2:
                ocf.delete(_keys(rng, burst // 4))  # mostly blind -> blocked
        caps[mode] = ocf.capacity_history
        occs[mode] = ocf.occupancy
        dt = time.perf_counter() - t0
    ratio = caps["PRE"][-1] / caps["EOF"][-1]
    return [
        ("fig3_pre_over_eof_capacity_ratio", 0.0, round(ratio, 3)),
        ("fig3_eof_final_occupancy", 0.0, round(occs["EOF"], 4)),
        ("fig3_pre_final_occupancy", 0.0, round(occs["PRE"], 4)),
        ("fig3_eof_resizes", 0.0, len(caps["EOF"]) - 1),
        ("fig3_pre_resizes", 0.0, len(caps["PRE"]) - 1),
    ]


def run(full: bool = False):
    rows = []
    n = 1_000_000 if full else 100_000
    rows += table1_occupancy_and_fp(n_keys=n)
    rows += fig2_throughput(rounds=100 if full else 40)
    rows += fig3_size_trendlines(rounds=60 if full else 30)
    return rows
