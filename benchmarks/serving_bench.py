"""Serving-path benchmark: OCF prefix-index ops at request rates + the
distributed membership service microbenchmark."""
from __future__ import annotations

import time

import numpy as np

from repro.core import OCF, OcfConfig
from repro.serving.kvcache import PrefixCacheIndex


def run():
    rows = []
    rng = np.random.RandomState(0)

    # prefix-index ops at serving rates
    idx = PrefixCacheIndex(block=64)
    prompts = [rng.randint(0, 32000, 2048).astype(np.int32)
               for _ in range(64)]
    t0 = time.perf_counter()
    for p in prompts:
        idx.admit(p)
    t_admit = (time.perf_counter() - t0) / len(prompts)
    t0 = time.perf_counter()
    for p in prompts:
        idx.match_prefix(p)
    t_match = (time.perf_counter() - t0) / len(prompts)
    rows.append(("prefix_admit_per_request", t_admit * 1e6, idx.ocf.capacity))
    rows.append(("prefix_match_per_request", t_match * 1e6,
                 round(idx.hit_rate, 3)))

    # bursty lookup stream against one OCF node (the paper's workload)
    ocf = OCF(OcfConfig(capacity=1 << 14, mode="EOF"))
    keys = rng.randint(0, 2 ** 63, size=1 << 15,
                       dtype=np.int64).astype(np.uint64)
    ocf.insert(keys)
    q = rng.permutation(np.concatenate([keys, keys]))[: 1 << 15]
    t0 = time.perf_counter()
    hits = ocf.lookup(q)
    dt = time.perf_counter() - t0
    rows.append(("ocf_lookup_stream", dt / q.size * 1e6, int(hits.sum())))
    return rows
