"""Serving-path benchmark: OCF prefix-index ops at request rates, plus the
latency-SLO scenario suite (ISSUE 8).

Two entry points:

  * ``run()`` — the legacy request-rate rows (prefix index + OCF lookup
    stream), consumed by ``benchmarks/run.py``.  The SLO scenario matrix
    itself is emitted into ``BENCH_filter.json`` by
    ``benchmarks/filter_bench.py`` (one canonical trajectory file, one
    gate).
  * the CLI — interactive scenario replay:

        PYTHONPATH=src python benchmarks/serving_bench.py \
            --scenario burst_train --seed 0 [--sync]

    prints the scenario's p50/p99/p99.9 (overall and per op kind),
    keys/s, and the admission/shed counters.  ``--scenario all`` runs the
    full matrix exactly as the bench writes it.

Determinism: every stream derives from ONE ``np.random.Generator`` seeded
by ``--seed`` (``repro.serving.workloads.scenario_stream``); two runs at
one seed replay byte-identical key streams (tier-1-tested in
``tests/test_slo.py``).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import OCF, OcfConfig
from repro.serving.kvcache import PrefixCacheIndex
from repro.serving.slo import (BENCH_SCENARIOS, bench_scenarios,
                               run_scenario, run_scenario_telemetry)
from repro.serving.workloads import SCENARIOS, scenario_stream


def make_streams(seed: int, *, wave_slots: int = 512,
                 scenarios=tuple(SCENARIOS)) -> dict:
    """scenario -> materialized OpBatch stream, all from one seed.

    The seed-reproducibility audit point: everything the SLO bench
    replays flows through here (or ``run_scenario``, which builds the
    identical stream), so asserting two calls of this are byte-equal
    pins the whole suite's determinism.
    """
    return {name: scenario_stream(name, seed, wave_slots=wave_slots)
            for name in scenarios}


def run(seed: int = 0):
    """Legacy request-rate rows (run.py section ``prefix_* / ocf_*``)."""
    rows = []
    rng = np.random.default_rng(seed)

    # prefix-index ops at serving rates
    idx = PrefixCacheIndex(block=64)
    prompts = [rng.integers(0, 32000, 2048).astype(np.int32)
               for _ in range(64)]
    t0 = time.perf_counter()
    for p in prompts:
        idx.admit(p)
    t_admit = (time.perf_counter() - t0) / len(prompts)
    t0 = time.perf_counter()
    for p in prompts:
        idx.match_prefix(p)
    t_match = (time.perf_counter() - t0) / len(prompts)
    rows.append(("prefix_admit_per_request", t_admit * 1e6, idx.ocf.capacity))
    rows.append(("prefix_match_per_request", t_match * 1e6,
                 round(idx.hit_rate, 3)))

    # bursty lookup stream against one OCF node (the paper's workload)
    ocf = OCF(OcfConfig(capacity=1 << 14, mode="EOF"))
    keys = rng.integers(0, 2 ** 63, size=1 << 15, dtype=np.uint64)
    ocf.insert(keys)
    q = rng.permutation(np.concatenate([keys, keys]))[: 1 << 15]
    t0 = time.perf_counter()
    hits = ocf.lookup(q)
    dt = time.perf_counter() - t0
    rows.append(("ocf_lookup_stream", dt / q.size * 1e6, int(hits.sum())))
    return rows


def _print_report(rep, *, arm: str) -> None:
    p = rep.percentiles_us
    print(f"{rep.scenario} [{arm}]: {rep.ops} ops in {rep.wall_s:.3f}s "
          f"({rep.keys_per_s:,.0f} keys/s)")
    print(f"  p50 {p['p50']:>10.1f} us   p99 {p['p99']:>10.1f} us   "
          f"p99.9 {p['p999']:>10.1f} us")
    for kind, kp in sorted(rep.per_kind.items()):
        print(f"  {kind:>7}: p50 {kp['p50']:>10.1f}  p99 {kp['p99']:>10.1f}"
              f"  p99.9 {kp['p999']:>10.1f}")
    if rep.deferred_waves or rep.shed_ops:
        print(f"  admission: deferred_waves={rep.deferred_waves} "
              f"held_ticks={rep.held_ticks} shed_ops={rep.shed_ops}")
    for k, v in rep.extras.items():
        print(f"  {k}: {v}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", default=None,
                    choices=sorted(SCENARIOS) + ["all"],
                    help="replay one SLO scenario (or 'all' for the "
                         "BENCH_filter.json matrix)")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for the single np.random.Generator every "
                         "stream derives from (byte-reproducible replays)")
    ap.add_argument("--sync", action="store_true",
                    help="force the synchronous submit path")
    ap.add_argument("--double-buffer", action="store_true",
                    help="force the double-buffered submit path (default: "
                         "auto — async only where the host can overlap)")
    ap.add_argument("--telemetry", action="store_true",
                    help="replay with device counter planes + trace spans "
                         "on; writes slo_<scenario>_metrics.jsonl and a "
                         "perfetto-loadable slo_<scenario>_trace.json into "
                         "--telemetry-dir")
    ap.add_argument("--telemetry-dir", default=".",
                    help="directory for --telemetry artifacts")
    args = ap.parse_args()

    if args.scenario == "all":
        if args.telemetry:
            for name in BENCH_SCENARIOS:
                rep, paths = run_scenario_telemetry(
                    name, args.telemetry_dir, seed=args.seed)
                _print_report(rep, arm="telemetry")
                print(f"  metrics: {paths['metrics']}")
                print(f"  trace:   {paths['trace']}")
            return
        for k, v in bench_scenarios(args.seed).items():
            print(f"{k},{v}")
        return
    if args.scenario:
        db = "auto"
        if args.sync:
            db = False
        elif args.double_buffer:
            db = True
        if args.telemetry:
            rep, paths = run_scenario_telemetry(
                args.scenario, args.telemetry_dir, seed=args.seed,
                double_buffer=db)
            _print_report(rep, arm="telemetry")
            print(f"  metrics: {paths['metrics']}")
            print(f"  trace:   {paths['trace']}")
            return
        rep = run_scenario(args.scenario, seed=args.seed, double_buffer=db)
        arm = {False: "sync", True: "double-buffered"}.get(db, "auto")
        _print_report(rep, arm=arm)
        return
    print("name,us_per_call,derived")
    for name, us, derived in run(seed=args.seed):
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
