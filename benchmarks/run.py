"""Benchmark harness — one section per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV (deliverable d).  Sections:
  table1_*   — paper Table I (occupancy + false positives, EOF vs PRE)
  fig2_*     — paper Fig. 2 (burst-insert throughput, incl. unmanaged filter)
  fig3_*     — paper Fig. 3 (capacity trendlines, PRE/EOF ratio)
  bulk_*     — TPU-adapted filter data-plane microbenches
  filter_*   — FilterOps per-backend lookup/insert/delete + keystore compare
               (also writes BENCH_filter.json — the perf trajectory file)
  prefix_* / ocf_* — serving-path OCF integration
  roofline_* — per (arch x shape x mesh) dry-run roofline summary (if
               artifacts/dryrun has been populated by launch/dryrun.py)
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (1M keys)")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()

    from benchmarks import bulk_ops, filter_bench, paper_tables, serving_bench

    rows = []
    rows += paper_tables.run(full=args.full)
    rows += bulk_ops.run()
    rows += filter_bench.run()
    rows += serving_bench.run()
    if not args.skip_roofline:
        from benchmarks import roofline_report
        rows += roofline_report.rows()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == '__main__':
    main()
