"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from artifacts/dryrun.

Also exposes ``rows()`` for benchmarks.run (CSV deliverable d: one derived
metric per dry-run cell).
"""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(art_dir: str = ART):
    recs = []
    for fn in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def rows(art_dir: str = ART):
    out = []
    for r in load(art_dir):
        if r.get("knobs", {}).get("tagged"):
            continue
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        out.append((name, r["step_time_est"] * 1e6,
                    f"bn={r['bottleneck']};mfu={r['mfu']:.3f}"))
    return out


def markdown_table(recs, mesh: str = "single") -> str:
    hdr = ("| arch | shape | Tc (s) | Tm (s) | Tx (s) | bottleneck | "
           "MODEL_FLOPs | useful | est-MFU |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in recs:
        if r["mesh"] != mesh or r.get("tag"):
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.4f} | "
            f"{r['t_memory']:.4f} | {r['t_collective']:.4f} | "
            f"{r['bottleneck']} | {r['model_flops']:.3e} | "
            f"{r['useful_ratio']:.3f} | {r['mfu']:.3f} |")
    return "\n".join(lines)


def memory_table(recs) -> str:
    hdr = ("| arch | shape | mesh | args GB/dev | temps GB/dev | "
           "collectives | compile s |\n|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in recs:
        ms = r.get("memory_stats") or {}
        arg = (ms.get("argument_bytes") or 0) / 2 ** 30
        tmp = (ms.get("temp_bytes") or 0) / 2 ** 30
        nc = (r.get("collectives") or {}).get("count", 0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {arg:.2f} | "
            f"{tmp:.2f} | {nc} | {r.get('t_compile_s', 0)} |")
    return "\n".join(lines)


if __name__ == "__main__":
    recs = load()
    print("## single-pod roofline\n")
    print(markdown_table(recs, "single"))
    print("\n## multi-pod roofline\n")
    print(markdown_table(recs, "multi"))
    print("\n## memory / collectives\n")
    print(memory_table(recs))
