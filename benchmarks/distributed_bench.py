"""Distributed write-path benchmark: routed vs host-loop sharded writes.

Standalone on purpose: the forced host device count must be exported before
jax initializes, so ``filter_bench.distributed_rows`` runs this file in a
subprocess and merges the JSON printed on the last stdout line.

Two arms per op, timed interleaved from the same preloaded ~0.8-load base
state (both run the identical per-shard kernel, so the delta is pure
dispatch architecture):

* ``distributed_insert_pallas`` / ``distributed_delete_pallas`` — the PR-6
  routed path: capacity-bounded all_to_all to the owner shard, conflict-
  aware scheduled insert / fused delete inside ``shard_map``, per-shard
  stashes.  Zero host round-trips, zero whole-stack copies in the loop.

* ``distributed_insert_hostloop`` / ``distributed_delete_hostloop`` — the
  pre-PR-6 idiom this PR retires: partition keys by owner on the host,
  loop over shards running the single-shard op, swap each mutated table
  back with ``local_shard_*_host`` (a stacked-buffer copy per shard per
  batch).

The timed batch lands on a ~0.9-load table, so the eviction machinery and
stash spill are on the clock — the contended regime the paper's burst
story cares about.
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import json  # noqa: E402
import sys  # noqa: E402

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from filter_bench import _interleaved_times  # noqa: E402
from repro.core import distributed as dist  # noqa: E402
from repro.core import hashing  # noqa: E402
from repro.core.filter_ops import FilterOps  # noqa: E402

N_SHARDS = 4
N_BUCKETS = 1024                     # per shard -> 16384 slots total
PRELOAD = 12800                      # ~0.78 load before the timed batch
BATCH = 2048                         # timed batch -> ~0.9 load
EVICT_ROUNDS = 64
STASH_SLOTS = 256
FP = 16


def _pair(rng, n):
    keys = rng.randint(0, 2 ** 63, size=n, dtype=np.int64).astype(np.uint64)
    hi, lo = hashing.key_to_u32_pair_np(keys)
    return hi, lo


def main():
    mesh = jax.make_mesh((N_SHARDS,), ("data",))
    rng = np.random.RandomState(42)
    phi, plo = _pair(rng, PRELOAD)
    bhi, blo = _pair(rng, BATCH)
    owner = np.asarray(hashing.owner_shard_np(bhi, blo, N_SHARDS))
    jhi, jlo = jnp.asarray(bhi), jnp.asarray(blo)

    base = dist.make_sharded_state(N_SHARDS, N_BUCKETS, 4,
                                   stash_slots=STASH_SLOTS)
    base, ok, _, _ = dist.distributed_insert(
        mesh, "data", base, jnp.asarray(phi), jnp.asarray(plo), fp_bits=FP,
        backend="pallas", evict_rounds=EVICT_ROUNDS)
    jax.block_until_ready(base.tables)
    preload_load = float(dist.sharded_occupancy(base))

    fops = FilterOps(fp_bits=FP, backend="pallas",
                     evict_rounds=EVICT_ROUNDS, schedule=True)
    per_shard = [(jnp.asarray(bhi[owner == s]), jnp.asarray(blo[owner == s]))
                 for s in range(N_SHARDS)]

    def routed_insert():
        st, ok, _, _ = dist.distributed_insert(
            mesh, "data", base, jhi, jlo, fp_bits=FP, backend="pallas",
            evict_rounds=EVICT_ROUNDS)
        return st.tables

    def hostloop_insert():
        # pre-PR-6: host partition + per-shard op + whole-stack swap
        st = base
        for s in range(N_SHARDS):
            shi, slo = per_shard[s]
            tbl, stash, ok = fops.insert_table(st.tables[s], shi, slo,
                                               stash=st.stashes[s])
            st = dist.local_shard_insert_host(st, s, tbl)
            st = st._replace(stashes=st.stashes.at[s].set(stash))
        return st.tables

    def routed_delete():
        st, ok, _, _ = dist.distributed_delete(
            mesh, "data", loaded, jhi, jlo, fp_bits=FP, backend="pallas")
        return st.tables

    def hostloop_delete():
        st = loaded
        for s in range(N_SHARDS):
            shi, slo = per_shard[s]
            st, ok = dist.local_shard_delete_host(st, s, shi, slo,
                                                  fp_bits=FP,
                                                  backend="pallas")
        return st.tables

    # the delete arms run against the post-batch ~0.9-load state
    loaded, lok, _, _ = dist.distributed_insert(
        mesh, "data", base, jhi, jlo, fp_bits=FP, backend="pallas",
        evict_rounds=EVICT_ROUNDS)
    jax.block_until_ready(loaded.tables)
    final_load = float(dist.sharded_occupancy(loaded))

    best = _interleaved_times({
        "insert_pallas": routed_insert,
        "insert_hostloop": hostloop_insert,
        "delete_pallas": routed_delete,
        "delete_hostloop": hostloop_delete,
    }, reps=2, trials=5)

    results = {"distributed_n_shards": N_SHARDS,
               "distributed_batch": BATCH,
               "distributed_preload_load": round(preload_load, 4),
               "distributed_batch_load": round(final_load, 4),
               "distributed_batch_ok": int(np.asarray(lok).sum()),
               "distributed_stash_spilled": int(
                   np.asarray(loaded.stashes[:, 0, :] != 0).sum())}
    for name, t in best.items():
        results[f"distributed_{name}_keys_per_s"] = int(BATCH / t)
        results[f"distributed_{name}_us_per_key"] = round(t / BATCH * 1e6, 3)
    print(json.dumps(results))


if __name__ == "__main__":
    main()
