"""AdamW (decoupled weight decay) as pure pytree functions."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(z, params),
                          v=jax.tree.map(z, params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state: AdamWState, params):
        # global-norm clip
        if self.grad_clip:
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                              for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
        else:
            gn = jnp.zeros(())
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step=step, m=m, v=v), gn


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(1, warmup)
        t = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = peak_lr * (floor_frac + (1 - floor_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup, warm, cos)
    return lr
