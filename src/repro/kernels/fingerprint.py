"""Pallas TPU kernel: batched fingerprint + bucket-index hashing.

Pure VPU bit-mixing over uint32 lanes (no 64-bit ints on TPU — DESIGN.md §2).
Keys are tiled over a 1-D grid; each program mixes a ``(BLOCK,)`` tile held in
VMEM and emits three tiles: fingerprint, home bucket i1, alternate bucket i2.

The hash family itself lives in ``repro.core.hashing`` — the kernel body
calls the exact same jnp functions the host data plane uses, so there is ONE
spec of the hash math in the repo and the kernels can never drift from the
numpy oracle (``hashing.*_np``) that ``pyfilter`` validates against.

This is the front half of every filter operation; fused into the probe
kernel for lookups and the optimistic-insert kernel for placements.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import hashing

DEFAULT_BLOCK = 1024


def _fingerprint_kernel(hi_ref, lo_ref, fp_ref, i1_ref, i2_ref, *,
                        fp_bits: int, n_buckets: int):
    hi = hi_ref[...]
    lo = lo_ref[...]
    # One hash spec: these are the same jnp mixers core.filter uses.
    fp = hashing.fingerprint(hi, lo, fp_bits)
    i1 = hashing.index_hash(hi, lo, n_buckets)
    i2 = hashing.alt_index(i1, fp, n_buckets)
    fp_ref[...] = fp
    i1_ref[...] = i1
    i2_ref[...] = i2


@functools.partial(jax.jit,
                   static_argnames=("fp_bits", "n_buckets", "block",
                                    "interpret", "emulate"))
def fingerprint_hash(hi: jax.Array, lo: jax.Array, *, fp_bits: int,
                     n_buckets: int, block: int = DEFAULT_BLOCK,
                     interpret: bool = True, emulate: bool = False):
    """Returns (fp, i1, i2), each uint32[N].  N must be a block multiple
    (callers pad; ops.py handles that).  ``emulate`` runs the same hash
    spec as one compiled XLA pass (the off-TPU fast path; the mixers are
    pure per-lane bit math, so no grid carry is involved)."""
    n = hi.shape[0]
    block = min(block, n)
    assert n % block == 0, f"{n=} not a multiple of {block=}"
    if emulate:
        hi = hi.astype(jnp.uint32)
        lo = lo.astype(jnp.uint32)
        fp = hashing.fingerprint(hi, lo, fp_bits)
        i1 = hashing.index_hash(hi, lo, n_buckets)
        i2 = hashing.alt_index(i1, fp, n_buckets)
        return fp, i1, i2
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out = pl.pallas_call(
        functools.partial(_fingerprint_kernel, fp_bits=fp_bits,
                          n_buckets=n_buckets),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.uint32)] * 3,
        interpret=interpret,
    )(hi.astype(jnp.uint32), lo.astype(jnp.uint32))
    return tuple(out)


# --------------------------------------- selector-parameterized family ------


def _family_body(hi, lo, *, fp_bits: int, n_buckets: int):
    """All four selector fingerprints + the (selector-independent) bucket
    pair.  Geometry comes from the selector-0 member — the adaptive-filter
    invariant that lets a repair rewrite a slot without moving the entry."""
    fps = [hashing.fingerprint_sel(hi, lo, s, fp_bits)
           for s in range(hashing.SEL_VARIANTS)]
    i1 = hashing.index_hash(hi, lo, n_buckets)
    i2 = hashing.alt_index(i1, fps[0], n_buckets)
    return fps, i1, i2


def _family_kernel(hi_ref, lo_ref, f0_ref, f1_ref, f2_ref, f3_ref, i1_ref,
                   i2_ref, *, fp_bits: int, n_buckets: int):
    fps, i1, i2 = _family_body(hi_ref[...], lo_ref[...], fp_bits=fp_bits,
                               n_buckets=n_buckets)
    for ref, fp in zip((f0_ref, f1_ref, f2_ref, f3_ref), fps):
        ref[...] = fp
    i1_ref[...] = i1
    i2_ref[...] = i2


@functools.partial(jax.jit,
                   static_argnames=("fp_bits", "n_buckets", "block",
                                    "interpret", "emulate"))
def fingerprint_hash_family(hi: jax.Array, lo: jax.Array, *, fp_bits: int,
                            n_buckets: int, block: int = DEFAULT_BLOCK,
                            interpret: bool = True, emulate: bool = False):
    """Selector-aware front half: ((fp0, fp1, fp2, fp3), i1, i2).

    fp0 is bit-identical to ``fingerprint_hash``'s fp (selector 0 == the
    static fingerprint), and i1/i2 are the same bucket pair — so the static
    and adaptive data planes agree on where every key lives.
    """
    n = hi.shape[0]
    block = min(block, n)
    assert n % block == 0, f"{n=} not a multiple of {block=}"
    if emulate:
        return _family_body(hi.astype(jnp.uint32), lo.astype(jnp.uint32),
                            fp_bits=fp_bits, n_buckets=n_buckets)
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out = pl.pallas_call(
        functools.partial(_family_kernel, fp_bits=fp_bits,
                          n_buckets=n_buckets),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec] * 6,
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.uint32)] * 6,
        interpret=interpret,
    )(hi.astype(jnp.uint32), lo.astype(jnp.uint32))
    return tuple(out[:4]), out[4], out[5]
