"""Pallas TPU kernel: batched fingerprint + bucket-index hashing.

Pure VPU bit-mixing over uint32 lanes (no 64-bit ints on TPU — DESIGN.md §2).
Keys are tiled over a 1-D grid; each program mixes a ``(BLOCK,)`` tile held in
VMEM and emits three tiles: fingerprint, home bucket i1, alternate bucket i2.

This is the front half of every filter operation; fused into the probe
kernel for lookups, standalone for the insert path (the eviction chain runs
in lax on the host-of-record, which only needs the hashes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_M3_C1 = 0x85EBCA6B
_M3_C2 = 0xC2B2AE35
_SM_C1 = 0x9E3779B9
_SM_C2 = 0x7FEB352D
_SM_C3 = 0x846CA68B

DEFAULT_BLOCK = 1024


def _mm3(x):
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_M3_C1)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(_M3_C2)
    return x ^ (x >> 16)


def _sm32(x):
    x = x + jnp.uint32(_SM_C1)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_SM_C2)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(_SM_C3)
    return x ^ (x >> 16)


def _fingerprint_kernel(hi_ref, lo_ref, fp_ref, i1_ref, i2_ref, *,
                        fp_bits: int, n_buckets: int):
    hi = hi_ref[...]
    lo = lo_ref[...]
    # fingerprint in [1, 2^f - 1]
    h = _mm3(lo ^ _mm3(hi ^ jnp.uint32(0xDEADBEEF)))
    fp = h & jnp.uint32((1 << fp_bits) - 1)
    fp = jnp.where(fp == 0, jnp.uint32(1), fp)
    # home bucket
    i1 = (_sm32(lo) ^ _mm3(hi + jnp.uint32(0x51ED270B))) % jnp.uint32(n_buckets)
    # alternate bucket: additive-complement involution (any n_buckets)
    hfp = _sm32(fp) % jnp.uint32(n_buckets)
    i2 = (hfp + jnp.uint32(n_buckets) - i1) % jnp.uint32(n_buckets)
    fp_ref[...] = fp
    i1_ref[...] = i1
    i2_ref[...] = i2


@functools.partial(jax.jit,
                   static_argnames=("fp_bits", "n_buckets", "block",
                                    "interpret"))
def fingerprint_hash(hi: jax.Array, lo: jax.Array, *, fp_bits: int,
                     n_buckets: int, block: int = DEFAULT_BLOCK,
                     interpret: bool = True):
    """Returns (fp, i1, i2), each uint32[N].  N must be a block multiple
    (callers pad; ops.py handles that)."""
    n = hi.shape[0]
    block = min(block, n)
    assert n % block == 0, f"{n=} not a multiple of {block=}"
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out = pl.pallas_call(
        functools.partial(_fingerprint_kernel, fp_bits=fp_bits,
                          n_buckets=n_buckets),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.uint32)] * 3,
        interpret=interpret,
    )(hi.astype(jnp.uint32), lo.astype(jnp.uint32))
    return tuple(out)
