"""Per-slot hash-selector plane — shared math for the adaptive filter kernels.

The adaptivity mechanism (Kopelowitz–McCauley–Porat, "Support Optimality and
Adaptive Cuckoo Filters") gives every occupied slot a 2-bit **selector**
choosing which member of a 4-hash fingerprint family the slot stores:

    stored[b, s] == fingerprint_sel(resident_key, sel[b, s])

A confirmed false positive on query q at slot (b, s) is repaired by bumping
``sel[b, s]`` and rewriting the slot under the resident's *next* fingerprint
— the entry never moves, its candidate bucket pair never changes (bucket
geometry is always derived from the selector-0 fingerprint), but the
(q, slot) collision is gone for every future query with probability
1 - 2^-fp_bits.

Layout: the selector plane is a **packed companion uint32 plane** beside the
table — ``uint32[buffer_buckets, 1]``, slot s of a bucket occupying bits
[2s, 2s+2).  That is 2 bits of state per slot (0.5 byte/bucket at
bucket_size 4) and supports bucket_size up to 16.  Kernels unpack to a
transient ``uint32[buckets, bucket_size]`` view at entry and repack at exit
(``sel_pack(sel_unpack(x)) == x``, so the pallas / interpret / XLA-emulation
paths stay bit-for-bit).

The repair step itself needs the resident key (you cannot rehash a
fingerprint), so the adaptive table carries **mirror key planes**
``khi/klo: uint32[buffer_buckets, bucket_size]`` — the "remote
representation" of the adaptive-cuckoo-filter literature, kept beside the
fingerprints so eviction chains can re-derive selector-0 geometry when they
move a victim (movement resets the victim's selector; rollback restores the
original plane contents verbatim).

Everything here is pure jnp on purpose: the same functions run inside the
Pallas kernels, on the jnp dispatch arm, and as the test reference — one
definition, zero parity surface (the ``kernels/stash.py`` discipline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashing

SEL_MASK = 3          # 2 selector bits per slot
MAX_BUCKET_SIZE = 16  # 16 slots * 2 bits fill the packed uint32


def make_sel_plane(buffer_buckets: int) -> jax.Array:
    """Fresh all-zero packed selector plane: uint32[buffer_buckets, 1]."""
    return jnp.zeros((buffer_buckets, 1), dtype=jnp.uint32)


def make_key_planes(buffer_buckets: int, bucket_size: int
                    ) -> tuple[jax.Array, jax.Array]:
    """Fresh mirror key planes (hi, lo): uint32[buffer_buckets, bucket_size]."""
    assert bucket_size <= MAX_BUCKET_SIZE, "packed selector plane holds <= 16"
    shape = (buffer_buckets, bucket_size)
    return jnp.zeros(shape, jnp.uint32), jnp.zeros(shape, jnp.uint32)


def sel_unpack(packed: jax.Array, bucket_size: int) -> jax.Array:
    """uint32[..., 1] packed rows -> uint32[..., bucket_size] selectors.

    2-D broadcasted iota (not 1-D arange) so the same spelling lowers on
    TPU Mosaic, in interpret mode, and under the XLA grid emulation.
    """
    shifts = jax.lax.broadcasted_iota(
        jnp.uint32, (1, bucket_size), 1) * jnp.uint32(2)
    return (packed >> shifts) & jnp.uint32(SEL_MASK)


def sel_pack(sel_tbl: jax.Array) -> jax.Array:
    """uint32[..., bucket_size] selectors -> packed uint32[..., 1] rows.

    Disjoint bit ranges, so a sum is an OR; exact inverse of sel_unpack.
    """
    bucket_size = sel_tbl.shape[-1]
    shifts = jax.lax.broadcasted_iota(
        jnp.uint32, (1, bucket_size), 1) * jnp.uint32(2)
    return jnp.sum((sel_tbl & jnp.uint32(SEL_MASK)) << shifts,
                   axis=-1, keepdims=True, dtype=jnp.uint32)


def fp_family(hi: jax.Array, lo: jax.Array, fp_bits: int
              ) -> tuple[jax.Array, ...]:
    """All four family fingerprints of a key batch: 4 x uint32[N].

    fam[0] is the static fingerprint (selector-0 == ``hashing.fingerprint``),
    which also fixes the bucket geometry and the stash identity.
    """
    return tuple(hashing.fingerprint_sel(hi, lo, s, fp_bits)
                 for s in range(hashing.SEL_VARIANTS))


def select_fp(fam, sels: jax.Array) -> jax.Array:
    """Per-slot expected fingerprint under slot selectors.

    fam: 4 x uint32[N] (``fp_family``); sels: uint32[N, bucket_size] ->
    uint32[N, bucket_size].  A VPU select-chain, not a gather, so callers
    hash each key once per family member amortized over both candidate
    buckets — kernel-safe on every backend.
    """
    exp = jnp.where(sels == 1, fam[1][:, None], fam[0][:, None])
    exp = jnp.where(sels == 2, fam[2][:, None], exp)
    exp = jnp.where(sels == 3, fam[3][:, None], exp)
    return exp


def _adapt_one_bucket(table, sel_tbl, khi, klo, bucket, hi, lo, enable, *,
                      fp_bits: int):
    """Repair every colliding slot of one bucket for one reported query.

    Returns the updated planes and whether any slot (a) adapted or (b) held
    the query key itself (a true positive — never adapted).
    """
    b = bucket.astype(jnp.int32)
    row, sels = table[b], sel_tbl[b]
    rhi, rlo = khi[b], klo[b]
    exp = hashing.fingerprint_sel(hi, lo, sels, fp_bits)
    same = (rhi == hi) & (rlo == lo) & (row != 0)
    collide = (row != 0) & (row == exp) & ~same & enable
    nsel = (sels + jnp.uint32(1)) & jnp.uint32(SEL_MASK)
    nfp = hashing.fingerprint_sel(rhi, rlo, nsel, fp_bits)
    table = table.at[b].set(jnp.where(collide, nfp, row))
    sel_tbl = sel_tbl.at[b].set(jnp.where(collide, nsel, sels))
    return table, sel_tbl, jnp.any(collide), jnp.any(same & enable)


def report_adapt(table: jax.Array, sels: jax.Array, khi: jax.Array,
                 klo: jax.Array, hi: jax.Array, lo: jax.Array,
                 valid: jax.Array, *, fp_bits: int, n_buckets
                 ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Apply a batch of confirmed-false-positive reports sequentially.

    -> (table, packed sels, adapted bool[N], resident bool[N]).  A lane
    adapts every slot in its candidate pair whose stored fingerprint matches
    the query under that slot's selector and whose mirror key differs;
    ``resident[i]`` flags reports whose key actually occupies a slot (a true
    positive — callers should not have reported it, and it is never
    "repaired" into a false negative).  Reports are rare control-plane
    events, so a lax.scan (exact sequential semantics, matching the python
    oracle loop) costs nothing on the hot path.
    """
    def step(carry, lane):
        table, sel_tbl = carry
        hi_l, lo_l, ok = lane
        fp0 = hashing.fingerprint(hi_l, lo_l, fp_bits)
        i1 = hashing.index_hash_dyn(hi_l, lo_l, n_buckets)
        i2 = hashing.alt_index_dyn(i1, fp0, n_buckets)
        table, sel_tbl, a1, r1 = _adapt_one_bucket(
            table, sel_tbl, khi, klo, i1, hi_l, lo_l, ok, fp_bits=fp_bits)
        # i2 == i1 happens (the involution has fixed points); guard the
        # second pass so a fixed-point lane cannot double-bump a selector.
        table, sel_tbl, a2, r2 = _adapt_one_bucket(
            table, sel_tbl, khi, klo, i2, hi_l, lo_l, ok & (i2 != i1),
            fp_bits=fp_bits)
        return (table, sel_tbl), (a1 | a2, r1 | r2)

    bucket_size = table.shape[-1]
    sel_tbl = sel_unpack(sels, bucket_size)
    (table, sel_tbl), (adapted, resident) = jax.lax.scan(
        step, (table, sel_tbl), (hi, lo, valid))
    return table, sel_pack(sel_tbl), adapted, resident
