"""Sort-free intra-block conflict ranking shared by the mutating kernels.

Every Pallas filter kernel that writes the table (insert placement rounds,
eviction kicks, delete clears) must serialize lanes of one block that target
the same bucket.  The host data plane does this with a stable argsort
(``core.filter.parallel_insert_once``); on the VPU a [BLOCK, BLOCK]
broadcast-compare computes the identical quantity without a device sort:

    rank(i) = #active lanes j < i targeting the same bucket (and, for
              deletes, carrying the same fingerprint)

One definition here keeps the three call sites (``insert._place_round``,
``insert._evict_rounds`` phase B, ``delete._clear_round``) in lockstep with
each other and with ``ops.kernel_vmem_bytes``' estimate of the compare
working set.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rank_among_earlier(target: jax.Array, active: jax.Array,
                       fp: jax.Array | None = None) -> jax.Array:
    """Per-lane conflict rank among earlier active lanes -> int32[N].

    ``fp`` refines the grouping to (bucket, fingerprint) pairs — the delete
    kernel's duplicate-key discipline.  Matches the host path's stable-sort
    rank bit for bit.
    """
    n = target.shape[0]
    li = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)   # lane i (rows)
    lj = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)   # lane j (cols)
    same = (target[:, None] == target[None, :]) & active[None, :] & (lj < li)
    if fp is not None:
        same &= fp[:, None] == fp[None, :]
    return jnp.sum(same, axis=1).astype(jnp.int32)
