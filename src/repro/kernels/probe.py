"""Pallas TPU kernel: fused hash + bucket-probe bulk lookup (the serving
hot path of the OCF).

Layout strategy (TPU adaptation of the paper's pointer-chasing lookup):
  * the bucket table ``uint32[buffer_buckets, bucket_size]`` is block-resident
    in VMEM — the BlockSpec index_map pins the whole table for every program
    (capacity ≤ ~2M slots ⇒ ≤ 8 MB, inside the ~16 MB VMEM budget; larger
    filters shard first — see core.distributed);
  * the ACTIVE bucket count rides along as a ``(1, 1)`` SMEM scalar, so the
    kernel probes the same dynamic-capacity state the OCF control plane
    resizes — one compiled kernel per buffer size, never per active size;
  * keys are tiled ``(BLOCK,)`` over a 1-D grid, hashing is fused so a key is
    read once from HBM and never revisited;
  * both candidate buckets are gathered from VMEM and compared per lane —
    2·bucket_size uint32 compares per key on the VPU, no MXU involvement;
  * with an overflow stash attached (``kernels/stash.py``), the same pass
    also broadcast-compares each lane against the stash — a stashed
    fingerprint (spilled by the insert kernel when an eviction chain
    exhausted its budget) answers True exactly like a resident one, so the
    stash is invisible to every lookup consumer.

The hash math is imported from ``repro.core.hashing`` — one spec shared by
the host data plane, the numpy oracle, and every kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hashing
from repro.kernels.selector import fp_family, select_fp, sel_unpack
from repro.kernels.stash import stash_match
from repro.kernels.telemetry import probe_depth_counts

DEFAULT_BLOCK = 1024


def _probe_body(table_ref, stash, hi, lo, n_buckets, *, fp_bits: int,
                array_table: bool = False, want_stats: bool = False):
    fp = hashing.fingerprint(hi, lo, fp_bits)
    i1 = hashing.index_hash_dyn(hi, lo, n_buckets)
    i2 = hashing.alt_index_dyn(i1, fp, n_buckets)
    if array_table:
        # XLA-emulation arm (table is a plain array): gather with the
        # native uint32 indices (an int32 cast would add a negative-wrap
        # select) and promise bounds — i1/i2 are mod-n_buckets <= buffer
        # rows by construction, so the clamp path XLA emits for a plain
        # table[i1] is dead weight (together ~10% of the lookup).  An
        # explicit flag, not isinstance: interpret-mode ref tracers pass
        # isinstance(x, jax.Array) but reject .at[].get kwargs.
        b1 = table_ref.at[i1].get(mode="promise_in_bounds")
        b2 = table_ref.at[i2].get(mode="promise_in_bounds")
    else:
        # Pallas ref gather: Mosaic wants int32 indices.
        b1 = table_ref[i1.astype(jnp.int32), :]
        b2 = table_ref[i2.astype(jnp.int32), :]
    h1 = jnp.any(b1 == fp[:, None], axis=-1)
    h2 = jnp.any(b2 == fp[:, None], axis=-1)
    hit = h1 | h2
    hs = None
    if stash is not None:
        hs = stash_match(stash, fp, i1, i2)
        hit = hit | hs
    if want_stats:
        # Per-bucket hit components for the probe-depth telemetry plane.
        if hs is None:
            hs = jnp.zeros_like(hit)
        return hit, (h1, h2, hs)
    return hit


def _probe_kernel(n_ref, table_ref, hi_ref, lo_ref, hit_ref, *, fp_bits: int):
    hit_ref[...] = _probe_body(table_ref, None, hi_ref[...], lo_ref[...],
                               n_ref[0, 0], fp_bits=fp_bits)


def _probe_stash_kernel(n_ref, table_ref, stash_ref, hi_ref, lo_ref, hit_ref,
                        *, fp_bits: int):
    hit_ref[...] = _probe_body(table_ref, stash_ref[...], hi_ref[...],
                               lo_ref[...], n_ref[0, 0], fp_bits=fp_bits)


@functools.partial(jax.jit, static_argnames=("fp_bits", "block", "interpret",
                                             "emulate"))
def probe(table: jax.Array, hi: jax.Array, lo: jax.Array, *, fp_bits: int,
          n_buckets=None, stash=None, block: int = DEFAULT_BLOCK,
          interpret: bool = True, emulate: bool = False) -> jax.Array:
    """Bulk membership test -> bool[N].  N must be a block multiple.

    ``n_buckets``: ACTIVE bucket count (int or traced scalar); defaults to
    the full table, i.e. buffer == active.  May be less than
    ``table.shape[0]`` when the table is the OCF's preallocated pow2 buffer.
    ``stash``: optional overflow stash (``kernels.stash``) checked in the
    same fused pass.  ``emulate``: run the identical kernel body as one
    compiled XLA pass instead of ``pallas_call`` — the off-TPU fast path
    (probes don't mutate, so no grid carry is needed: the whole batch is
    one fused body evaluation; bit-for-bit the kernel's answers).
    """
    n = hi.shape[0]
    block = min(block, n)
    assert n % block == 0, f"{n=} not a multiple of {block=}"
    buffer_buckets, bucket_size = table.shape
    if n_buckets is None:
        n_buckets = buffer_buckets
    if emulate:
        return _probe_body(table, stash, hi.astype(jnp.uint32),
                           lo.astype(jnp.uint32), n_buckets, fp_bits=fp_bits,
                           array_table=True)
    n_arr = jnp.asarray(n_buckets, jnp.int32).reshape(1, 1)
    grid = (n // block,)
    smem_spec = pl.BlockSpec((1, 1), lambda i: (0, 0),
                             memory_space=pltpu.SMEM)
    key_spec = pl.BlockSpec((block,), lambda i: (i,))
    table_spec = pl.BlockSpec((buffer_buckets, bucket_size), lambda i: (0, 0))
    out_spec = pl.BlockSpec((block,), lambda i: (i,))
    out_shape = jax.ShapeDtypeStruct((n,), jnp.bool_)
    if stash is None:
        return pl.pallas_call(
            functools.partial(_probe_kernel, fp_bits=fp_bits),
            grid=grid,
            in_specs=[smem_spec, table_spec, key_spec, key_spec],
            out_specs=out_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(n_arr, table, hi.astype(jnp.uint32), lo.astype(jnp.uint32))
    stash_spec = pl.BlockSpec(stash.shape, lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_probe_stash_kernel, fp_bits=fp_bits),
        grid=grid,
        in_specs=[smem_spec, table_spec, stash_spec, key_spec, key_spec],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(n_arr, table, stash, hi.astype(jnp.uint32), lo.astype(jnp.uint32))


@functools.partial(jax.jit, static_argnames=("fp_bits",))
def probe_emulated(table: jax.Array, hi: jax.Array, lo: jax.Array,
                   n_buckets, stash, *, fp_bits: int) -> jax.Array:
    """The emulated probe body behind a minimal positional-arg jit.

    Same function as ``probe(..., emulate=True)``; exists because the hot
    serving lookup is dispatch-bound enough on CPU that the keyword-arg
    jit entry with five statics costs a measurable slice of the call
    (``ops.probe_dispatch`` uses this one).
    """
    return _probe_body(table, stash, hi, lo, n_buckets, fp_bits=fp_bits,
                       array_table=True)


@functools.partial(jax.jit, static_argnames=("fp_bits",))
def probe_emulated_tm(table: jax.Array, hi: jax.Array, lo: jax.Array,
                      n_buckets, stash, *, fp_bits: int):
    """Telemetry twin of ``probe_emulated`` -> (hit, probe_depth uint32[4]).

    ``probe_depth`` counts lanes by shallowest hit location — first bucket,
    second bucket, stash, miss (``kernels.telemetry.probe_depth_counts``).
    Its own jit: the telemetry-off lookup keeps its cache and dispatch.
    """
    hit, (h1, h2, hs) = _probe_body(table, stash, hi, lo, n_buckets,
                                    fp_bits=fp_bits, array_table=True,
                                    want_stats=True)
    valid = jnp.ones_like(hit)
    return hit, probe_depth_counts(h1, h2, hs, valid)


# --------------------------------------------- selector-aware probe ---------


def _probe_adaptive_body(table_ref, sel_ref, stash, hi, lo, n_buckets, *,
                         fp_bits: int, array_table: bool = False,
                         want_stats: bool = False):
    """Adaptive lookup: compare each slot against the fingerprint the slot's
    selector chose (``kernels/selector.py``).

    Bucket geometry (i1, i2) always comes from the selector-0 fingerprint —
    adaptation rewrites what a slot *stores*, never where the entry *lives*
    — so the candidate pair of a key is stable across repairs.  The stash
    holds selector-0 fingerprints (spills reset adaptation), so the stash
    compare is unchanged.  With an all-zero selector plane this body is
    bit-for-bit ``_probe_body``.
    """
    fam = fp_family(hi, lo, fp_bits)
    fp0 = fam[0]
    i1 = hashing.index_hash_dyn(hi, lo, n_buckets)
    i2 = hashing.alt_index_dyn(i1, fp0, n_buckets)
    bucket_size = table_ref.shape[-1]
    if array_table:
        b1 = table_ref.at[i1].get(mode="promise_in_bounds")
        b2 = table_ref.at[i2].get(mode="promise_in_bounds")
        s1 = sel_ref.at[i1].get(mode="promise_in_bounds")
        s2 = sel_ref.at[i2].get(mode="promise_in_bounds")
    else:
        b1 = table_ref[i1.astype(jnp.int32), :]
        b2 = table_ref[i2.astype(jnp.int32), :]
        s1 = sel_ref[i1.astype(jnp.int32), :]
        s2 = sel_ref[i2.astype(jnp.int32), :]
    e1 = select_fp(fam, sel_unpack(s1, bucket_size))
    e2 = select_fp(fam, sel_unpack(s2, bucket_size))
    h1 = jnp.any(b1 == e1, axis=-1)
    h2 = jnp.any(b2 == e2, axis=-1)
    hit = h1 | h2
    hs = None
    if stash is not None:
        hs = stash_match(stash, fp0, i1, i2)
        hit = hit | hs
    if want_stats:
        if hs is None:
            hs = jnp.zeros_like(hit)
        return hit, (h1, h2, hs)
    return hit


def _probe_adaptive_kernel(n_ref, table_ref, sel_ref, hi_ref, lo_ref, hit_ref,
                           *, fp_bits: int):
    hit_ref[...] = _probe_adaptive_body(table_ref, sel_ref, None, hi_ref[...],
                                        lo_ref[...], n_ref[0, 0],
                                        fp_bits=fp_bits)


def _probe_adaptive_stash_kernel(n_ref, table_ref, sel_ref, stash_ref, hi_ref,
                                 lo_ref, hit_ref, *, fp_bits: int):
    hit_ref[...] = _probe_adaptive_body(table_ref, sel_ref, stash_ref[...],
                                        hi_ref[...], lo_ref[...], n_ref[0, 0],
                                        fp_bits=fp_bits)


@functools.partial(jax.jit, static_argnames=("fp_bits", "block", "interpret",
                                             "emulate"))
def probe_adaptive(table: jax.Array, sels: jax.Array, hi: jax.Array,
                   lo: jax.Array, *, fp_bits: int, n_buckets=None, stash=None,
                   block: int = DEFAULT_BLOCK, interpret: bool = True,
                   emulate: bool = False) -> jax.Array:
    """Selector-aware bulk membership test -> bool[N].

    Same contract as ``probe`` plus ``sels``: the packed per-slot selector
    plane ``uint32[buffer_buckets, 1]`` riding block-resident beside the
    table (2 bits/slot; +1/16th of a table of VMEM at bucket_size 4).
    """
    n = hi.shape[0]
    block = min(block, n)
    assert n % block == 0, f"{n=} not a multiple of {block=}"
    buffer_buckets, bucket_size = table.shape
    if n_buckets is None:
        n_buckets = buffer_buckets
    if emulate:
        return _probe_adaptive_body(table, sels, stash, hi.astype(jnp.uint32),
                                    lo.astype(jnp.uint32), n_buckets,
                                    fp_bits=fp_bits, array_table=True)
    n_arr = jnp.asarray(n_buckets, jnp.int32).reshape(1, 1)
    grid = (n // block,)
    smem_spec = pl.BlockSpec((1, 1), lambda i: (0, 0),
                             memory_space=pltpu.SMEM)
    key_spec = pl.BlockSpec((block,), lambda i: (i,))
    table_spec = pl.BlockSpec((buffer_buckets, bucket_size), lambda i: (0, 0))
    sel_spec = pl.BlockSpec((buffer_buckets, 1), lambda i: (0, 0))
    out_spec = pl.BlockSpec((block,), lambda i: (i,))
    out_shape = jax.ShapeDtypeStruct((n,), jnp.bool_)
    if stash is None:
        return pl.pallas_call(
            functools.partial(_probe_adaptive_kernel, fp_bits=fp_bits),
            grid=grid,
            in_specs=[smem_spec, table_spec, sel_spec, key_spec, key_spec],
            out_specs=out_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(n_arr, table, sels, hi.astype(jnp.uint32), lo.astype(jnp.uint32))
    stash_spec = pl.BlockSpec(stash.shape, lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_probe_adaptive_stash_kernel, fp_bits=fp_bits),
        grid=grid,
        in_specs=[smem_spec, table_spec, sel_spec, stash_spec, key_spec,
                  key_spec],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(n_arr, table, sels, stash, hi.astype(jnp.uint32),
      lo.astype(jnp.uint32))


@functools.partial(jax.jit, static_argnames=("fp_bits",))
def probe_adaptive_emulated(table: jax.Array, sels: jax.Array, hi: jax.Array,
                            lo: jax.Array, n_buckets, stash, *,
                            fp_bits: int) -> jax.Array:
    """Positional-arg fast path for the emulated adaptive probe (the
    adaptive serving lookup's analogue of ``probe_emulated``)."""
    return _probe_adaptive_body(table, sels, stash, hi, lo, n_buckets,
                                fp_bits=fp_bits, array_table=True)


@functools.partial(jax.jit, static_argnames=("fp_bits",))
def probe_adaptive_emulated_tm(table: jax.Array, sels: jax.Array,
                               hi: jax.Array, lo: jax.Array, n_buckets,
                               stash, *, fp_bits: int):
    """Telemetry twin of ``probe_adaptive_emulated`` -> (hit, depth[4])."""
    hit, (h1, h2, hs) = _probe_adaptive_body(
        table, sels, stash, hi, lo, n_buckets, fp_bits=fp_bits,
        array_table=True, want_stats=True)
    valid = jnp.ones_like(hit)
    return hit, probe_depth_counts(h1, h2, hs, valid)


# ----------------------------------------------- multi-generation probe ----


def _probe_multi_kernel(n_ref, table_ref, hi_ref, lo_ref, hit_ref, *,
                        fp_bits: int):
    """Grid (blocks, K): OR one generation's hits into the block's output.

    The output block is revisited across the K axis (its index_map ignores
    k); TPU grids execute sequentially, so initializing at k == 0 and
    accumulating afterwards is the standard revisit-accumulate pattern.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        hit_ref[...] = jnp.zeros(hit_ref.shape, jnp.bool_)

    hit_ref[...] |= _probe_body(table_ref[0], None, hi_ref[...], lo_ref[...],
                                n_ref[0, 0], fp_bits=fp_bits)


def _probe_multi_stash_kernel(n_ref, table_ref, stash_ref, hi_ref, lo_ref,
                              hit_ref, *, fp_bits: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        hit_ref[...] = jnp.zeros(hit_ref.shape, jnp.bool_)

    hit_ref[...] |= _probe_body(table_ref[0], stash_ref[0], hi_ref[...],
                                lo_ref[...], n_ref[0, 0], fp_bits=fp_bits)


def _emulated_probe_multi(tables, stashes, hi, lo, n_buckets, *,
                          fp_bits: int):
    """Fused fan-out, XLA-compiled: hash ONCE, gather/compare per generation.

    This is where the fused probe beats the per-generation loop even off
    TPU: the loop hashes every key 2·K times (once in each generation's
    table probe, once in each stash match); here fp/i1/i2 are computed a
    single time and only the table gathers and stash compares fan out.
    """
    fp = hashing.fingerprint(hi, lo, fp_bits)
    i1 = hashing.index_hash_dyn(hi, lo, n_buckets)
    i2 = hashing.alt_index_dyn(i1, fp, n_buckets)

    def one_table(table):
        b1 = table.at[i1].get(mode="promise_in_bounds")
        b2 = table.at[i2].get(mode="promise_in_bounds")
        return (jnp.any(b1 == fp[:, None], axis=-1)
                | jnp.any(b2 == fp[:, None], axis=-1))

    hit = jnp.any(jax.vmap(one_table)(tables), axis=0)
    if stashes is not None:
        hit = hit | jnp.any(
            jax.vmap(lambda s: stash_match(s, fp, i1, i2))(stashes), axis=0)
    return hit


@functools.partial(jax.jit, static_argnames=("fp_bits", "block", "interpret",
                                             "emulate"))
def probe_multi(tables: jax.Array, hi: jax.Array, lo: jax.Array, *,
                fp_bits: int, n_buckets=None, stashes=None,
                block: int = DEFAULT_BLOCK, interpret: bool = True,
                emulate: bool = False) -> jax.Array:
    """Fused multi-generation membership -> bool[N]: one kernel whose grid
    spans every live generation of the preallocated pool.

    ``tables``: uint32[K, buffer_buckets, bucket_size] — the K live
    generations' tables stacked (same shape by construction: they all come
    from the generation ring's one buffer pool).  ``stashes``: optional
    uint32[K, 2, S] stack of their overflow stashes, checked in the same
    pass.  ``n_buckets`` is the generations' shared ACTIVE bucket count.
    Replaces the per-generation probe loop (K kernel launches, 2·K hash
    evaluations per key) with one launch and one hash evaluation.
    """
    n = hi.shape[0]
    block = min(block, n)
    assert n % block == 0, f"{n=} not a multiple of {block=}"
    k, buffer_buckets, bucket_size = tables.shape
    if n_buckets is None:
        n_buckets = buffer_buckets
    if emulate:
        return _emulated_probe_multi(tables, stashes, hi.astype(jnp.uint32),
                                     lo.astype(jnp.uint32), n_buckets,
                                     fp_bits=fp_bits)
    n_arr = jnp.asarray(n_buckets, jnp.int32).reshape(1, 1)
    grid = (n // block, k)
    smem_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                             memory_space=pltpu.SMEM)
    key_spec = pl.BlockSpec((block,), lambda i, j: (i,))
    table_spec = pl.BlockSpec((1, buffer_buckets, bucket_size),
                              lambda i, j: (j, 0, 0))
    out_spec = pl.BlockSpec((block,), lambda i, j: (i,))
    out_shape = jax.ShapeDtypeStruct((n,), jnp.bool_)
    if stashes is None:
        return pl.pallas_call(
            functools.partial(_probe_multi_kernel, fp_bits=fp_bits),
            grid=grid,
            in_specs=[smem_spec, table_spec, key_spec, key_spec],
            out_specs=out_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(n_arr, tables, hi.astype(jnp.uint32), lo.astype(jnp.uint32))
    stash_spec = pl.BlockSpec((1,) + stashes.shape[1:], lambda i, j: (j, 0, 0))
    return pl.pallas_call(
        functools.partial(_probe_multi_stash_kernel, fp_bits=fp_bits),
        grid=grid,
        in_specs=[smem_spec, table_spec, stash_spec, key_spec, key_spec],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(n_arr, tables, stashes, hi.astype(jnp.uint32), lo.astype(jnp.uint32))
