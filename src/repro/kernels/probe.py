"""Pallas TPU kernel: fused hash + bucket-probe bulk lookup (the serving
hot path of the OCF).

Layout strategy (TPU adaptation of the paper's pointer-chasing lookup):
  * the bucket table ``uint32[n_buckets, bucket_size]`` is block-resident in
    VMEM — the BlockSpec index_map pins the whole table for every program
    (capacity ≤ ~2M slots ⇒ ≤ 8 MB, inside the ~16 MB VMEM budget; larger
    filters shard first — see core.distributed);
  * keys are tiled ``(BLOCK,)`` over a 1-D grid, hashing is fused so a key is
    read once from HBM and never revisited;
  * both candidate buckets are gathered from VMEM and compared per lane —
    2·bucket_size uint32 compares per key on the VPU, no MXU involvement.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fingerprint import _mm3, _sm32

DEFAULT_BLOCK = 1024


def _probe_kernel(table_ref, hi_ref, lo_ref, hit_ref, *, fp_bits: int):
    n_buckets = table_ref.shape[0]
    hi = hi_ref[...]
    lo = lo_ref[...]
    h = _mm3(lo ^ _mm3(hi ^ jnp.uint32(0xDEADBEEF)))
    fp = h & jnp.uint32((1 << fp_bits) - 1)
    fp = jnp.where(fp == 0, jnp.uint32(1), fp)
    i1 = (_sm32(lo) ^ _mm3(hi + jnp.uint32(0x51ED270B))) % jnp.uint32(n_buckets)
    hfp = _sm32(fp) % jnp.uint32(n_buckets)
    i2 = (hfp + jnp.uint32(n_buckets) - i1) % jnp.uint32(n_buckets)
    b1 = table_ref[i1.astype(jnp.int32), :]   # [BLOCK, bucket_size] VMEM gather
    b2 = table_ref[i2.astype(jnp.int32), :]
    hit = jnp.any(b1 == fp[:, None], axis=-1) | jnp.any(b2 == fp[:, None], axis=-1)
    hit_ref[...] = hit


@functools.partial(jax.jit, static_argnames=("fp_bits", "block", "interpret"))
def probe(table: jax.Array, hi: jax.Array, lo: jax.Array, *, fp_bits: int,
          block: int = DEFAULT_BLOCK, interpret: bool = True) -> jax.Array:
    """Bulk membership test -> bool[N].  N must be a block multiple."""
    n = hi.shape[0]
    block = min(block, n)
    assert n % block == 0, f"{n=} not a multiple of {block=}"
    n_buckets, bucket_size = table.shape
    grid = (n // block,)
    key_spec = pl.BlockSpec((block,), lambda i: (i,))
    table_spec = pl.BlockSpec((n_buckets, bucket_size), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_probe_kernel, fp_bits=fp_bits),
        grid=grid,
        in_specs=[table_spec, key_spec, key_spec],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.bool_),
        interpret=interpret,
    )(table, hi.astype(jnp.uint32), lo.astype(jnp.uint32))
