"""Pallas TPU kernel: fused hash + bucket-probe bulk lookup (the serving
hot path of the OCF).

Layout strategy (TPU adaptation of the paper's pointer-chasing lookup):
  * the bucket table ``uint32[buffer_buckets, bucket_size]`` is block-resident
    in VMEM — the BlockSpec index_map pins the whole table for every program
    (capacity ≤ ~2M slots ⇒ ≤ 8 MB, inside the ~16 MB VMEM budget; larger
    filters shard first — see core.distributed);
  * the ACTIVE bucket count rides along as a ``(1, 1)`` SMEM scalar, so the
    kernel probes the same dynamic-capacity state the OCF control plane
    resizes — one compiled kernel per buffer size, never per active size;
  * keys are tiled ``(BLOCK,)`` over a 1-D grid, hashing is fused so a key is
    read once from HBM and never revisited;
  * both candidate buckets are gathered from VMEM and compared per lane —
    2·bucket_size uint32 compares per key on the VPU, no MXU involvement.

The hash math is imported from ``repro.core.hashing`` — one spec shared by
the host data plane, the numpy oracle, and every kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hashing

DEFAULT_BLOCK = 1024


def _probe_kernel(n_ref, table_ref, hi_ref, lo_ref, hit_ref, *, fp_bits: int):
    n_buckets = n_ref[0, 0]
    hi = hi_ref[...]
    lo = lo_ref[...]
    fp = hashing.fingerprint(hi, lo, fp_bits)
    i1 = hashing.index_hash_dyn(hi, lo, n_buckets)
    i2 = hashing.alt_index_dyn(i1, fp, n_buckets)
    b1 = table_ref[i1.astype(jnp.int32), :]   # [BLOCK, bucket_size] VMEM gather
    b2 = table_ref[i2.astype(jnp.int32), :]
    hit = jnp.any(b1 == fp[:, None], axis=-1) | jnp.any(b2 == fp[:, None], axis=-1)
    hit_ref[...] = hit


@functools.partial(jax.jit, static_argnames=("fp_bits", "block", "interpret"))
def probe(table: jax.Array, hi: jax.Array, lo: jax.Array, *, fp_bits: int,
          n_buckets=None, block: int = DEFAULT_BLOCK,
          interpret: bool = True) -> jax.Array:
    """Bulk membership test -> bool[N].  N must be a block multiple.

    ``n_buckets``: ACTIVE bucket count (int or traced scalar); defaults to
    the full table, i.e. buffer == active.  May be less than
    ``table.shape[0]`` when the table is the OCF's preallocated pow2 buffer.
    """
    n = hi.shape[0]
    block = min(block, n)
    assert n % block == 0, f"{n=} not a multiple of {block=}"
    buffer_buckets, bucket_size = table.shape
    if n_buckets is None:
        n_buckets = buffer_buckets
    n_arr = jnp.asarray(n_buckets, jnp.int32).reshape(1, 1)
    grid = (n // block,)
    smem_spec = pl.BlockSpec((1, 1), lambda i: (0, 0),
                             memory_space=pltpu.SMEM)
    key_spec = pl.BlockSpec((block,), lambda i: (i,))
    table_spec = pl.BlockSpec((buffer_buckets, bucket_size), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_probe_kernel, fp_bits=fp_bits),
        grid=grid,
        in_specs=[smem_spec, table_spec, key_spec, key_spec],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.bool_),
        interpret=interpret,
    )(n_arr, table, hi.astype(jnp.uint32), lo.astype(jnp.uint32))
