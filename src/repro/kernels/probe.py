"""Pallas TPU kernel: fused hash + bucket-probe bulk lookup (the serving
hot path of the OCF).

Layout strategy (TPU adaptation of the paper's pointer-chasing lookup):
  * the bucket table ``uint32[buffer_buckets, bucket_size]`` is block-resident
    in VMEM — the BlockSpec index_map pins the whole table for every program
    (capacity ≤ ~2M slots ⇒ ≤ 8 MB, inside the ~16 MB VMEM budget; larger
    filters shard first — see core.distributed);
  * the ACTIVE bucket count rides along as a ``(1, 1)`` SMEM scalar, so the
    kernel probes the same dynamic-capacity state the OCF control plane
    resizes — one compiled kernel per buffer size, never per active size;
  * keys are tiled ``(BLOCK,)`` over a 1-D grid, hashing is fused so a key is
    read once from HBM and never revisited;
  * both candidate buckets are gathered from VMEM and compared per lane —
    2·bucket_size uint32 compares per key on the VPU, no MXU involvement;
  * with an overflow stash attached (``kernels/stash.py``), the same pass
    also broadcast-compares each lane against the stash — a stashed
    fingerprint (spilled by the insert kernel when an eviction chain
    exhausted its budget) answers True exactly like a resident one, so the
    stash is invisible to every lookup consumer.

The hash math is imported from ``repro.core.hashing`` — one spec shared by
the host data plane, the numpy oracle, and every kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hashing
from repro.kernels.stash import stash_match

DEFAULT_BLOCK = 1024


def _probe_body(table_ref, stash, hi, lo, n_buckets, *, fp_bits: int):
    fp = hashing.fingerprint(hi, lo, fp_bits)
    i1 = hashing.index_hash_dyn(hi, lo, n_buckets)
    i2 = hashing.alt_index_dyn(i1, fp, n_buckets)
    b1 = table_ref[i1.astype(jnp.int32), :]   # [BLOCK, bucket_size] VMEM gather
    b2 = table_ref[i2.astype(jnp.int32), :]
    hit = jnp.any(b1 == fp[:, None], axis=-1) | jnp.any(b2 == fp[:, None], axis=-1)
    if stash is not None:
        hit = hit | stash_match(stash, fp, i1, i2)
    return hit


def _probe_kernel(n_ref, table_ref, hi_ref, lo_ref, hit_ref, *, fp_bits: int):
    hit_ref[...] = _probe_body(table_ref, None, hi_ref[...], lo_ref[...],
                               n_ref[0, 0], fp_bits=fp_bits)


def _probe_stash_kernel(n_ref, table_ref, stash_ref, hi_ref, lo_ref, hit_ref,
                        *, fp_bits: int):
    hit_ref[...] = _probe_body(table_ref, stash_ref[...], hi_ref[...],
                               lo_ref[...], n_ref[0, 0], fp_bits=fp_bits)


@functools.partial(jax.jit, static_argnames=("fp_bits", "block", "interpret"))
def probe(table: jax.Array, hi: jax.Array, lo: jax.Array, *, fp_bits: int,
          n_buckets=None, stash=None, block: int = DEFAULT_BLOCK,
          interpret: bool = True) -> jax.Array:
    """Bulk membership test -> bool[N].  N must be a block multiple.

    ``n_buckets``: ACTIVE bucket count (int or traced scalar); defaults to
    the full table, i.e. buffer == active.  May be less than
    ``table.shape[0]`` when the table is the OCF's preallocated pow2 buffer.
    ``stash``: optional overflow stash (``kernels.stash``) checked in the
    same fused pass.
    """
    n = hi.shape[0]
    block = min(block, n)
    assert n % block == 0, f"{n=} not a multiple of {block=}"
    buffer_buckets, bucket_size = table.shape
    if n_buckets is None:
        n_buckets = buffer_buckets
    n_arr = jnp.asarray(n_buckets, jnp.int32).reshape(1, 1)
    grid = (n // block,)
    smem_spec = pl.BlockSpec((1, 1), lambda i: (0, 0),
                             memory_space=pltpu.SMEM)
    key_spec = pl.BlockSpec((block,), lambda i: (i,))
    table_spec = pl.BlockSpec((buffer_buckets, bucket_size), lambda i: (0, 0))
    out_spec = pl.BlockSpec((block,), lambda i: (i,))
    out_shape = jax.ShapeDtypeStruct((n,), jnp.bool_)
    if stash is None:
        return pl.pallas_call(
            functools.partial(_probe_kernel, fp_bits=fp_bits),
            grid=grid,
            in_specs=[smem_spec, table_spec, key_spec, key_spec],
            out_specs=out_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(n_arr, table, hi.astype(jnp.uint32), lo.astype(jnp.uint32))
    stash_spec = pl.BlockSpec(stash.shape, lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_probe_stash_kernel, fp_bits=fp_bits),
        grid=grid,
        in_specs=[smem_spec, table_spec, stash_spec, key_spec, key_spec],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(n_arr, table, stash, hi.astype(jnp.uint32), lo.astype(jnp.uint32))
