"""Jit'd public wrappers around the Pallas kernels.

Backend dispatch: Pallas-TPU lowers only on TPU; on the CPU host (this
container, tests) kernels run in ``interpret=True`` mode and large-shape
callers fall back to the pure-jnp oracle (``ref.py``), which is what the
dry-run compiles.  ``use_pallas='auto'|'always'|'never'`` controls this.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fingerprint import fingerprint_hash
from repro.kernels.flash_attention import flash_attention
from repro.kernels.probe import probe


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, mult: int):
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return x, n


def hash_keys(hi: jax.Array, lo: jax.Array, *, fp_bits: int, n_buckets: int,
              use_pallas: str = "auto"):
    """(fp, i1, i2) via the fingerprint kernel (padded to the block size)."""
    if use_pallas == "never":
        return ref.fingerprint_ref(hi, lo, fp_bits=fp_bits, n_buckets=n_buckets)
    block = 1024 if hi.shape[0] >= 1024 else hi.shape[0]
    hi_p, n = _pad_to(hi, block)
    lo_p, _ = _pad_to(lo, block)
    fp, i1, i2 = fingerprint_hash(hi_p, lo_p, fp_bits=fp_bits,
                                  n_buckets=n_buckets, block=block,
                                  interpret=not _on_tpu())
    return fp[:n], i1[:n], i2[:n]


def filter_lookup(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                  fp_bits: int, use_pallas: str = "auto") -> jax.Array:
    """Bulk membership via the fused probe kernel."""
    vmem_bytes = table.size * 4
    if use_pallas == "never" or (use_pallas == "auto" and
                                 (not _on_tpu() and hi.shape[0] > 65536)
                                 or vmem_bytes > 12 * 2**20):
        return ref.probe_ref(table, hi, lo, fp_bits=fp_bits)
    block = 1024 if hi.shape[0] >= 1024 else hi.shape[0]
    hi_p, n = _pad_to(hi, block)
    lo_p, _ = _pad_to(lo, block)
    hit = probe(table, hi_p, lo_p, fp_bits=fp_bits, block=block,
                interpret=not _on_tpu())
    return hit[:n]


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              logit_softcap: float | None = None, scale: float | None = None,
              qpos_start=None, valid_len=None, key_positions=None,
              use_pallas: str = "auto") -> jax.Array:
    """Attention dispatcher.

    TPU: Pallas flash kernel.  XLA path (CPU host / dry-run): window layers
    use the O(S·W) chunked local path; everything else goes through
    blockwise attention (never materializes SxS) — see ref.py docstrings.
    """
    if use_pallas == "always" or (use_pallas == "auto" and _on_tpu()):
        if valid_len is None and qpos_start is None and key_positions is None:
            return flash_attention(q, k, v, causal=causal, window=window,
                                   logit_softcap=logit_softcap, scale=scale,
                                   interpret=not _on_tpu())
    sq, skv = q.shape[2], k.shape[2]
    if (window is not None and causal and valid_len is None
            and key_positions is None and sq == skv
            and sq % window == 0 and sq > window):
        return ref.local_attention(q, k, v, window=window,
                                   logit_softcap=logit_softcap, scale=scale)
    return ref.blockwise_attention(q, k, v, causal=causal, window=window,
                                   logit_softcap=logit_softcap, scale=scale,
                                   qpos_start=qpos_start, valid_len=valid_len,
                                   key_positions=key_positions)


__all__ = ["hash_keys", "filter_lookup", "attention", "fingerprint_hash",
           "probe", "flash_attention"]
