"""Jit'd public wrappers around the Pallas kernels.

Backend dispatch: Pallas-TPU lowers only on TPU.  Off TPU each kernel runs
its **XLA grid emulation** — the identical kernel body compiled as a
``lax.scan`` over the grid (``emulate=True`` on every kernel entry point) —
so the "pallas" backend is a throughput configuration on CPU hosts too; the
Pallas interpreter (``interpret=True`` without ``emulate``) remains
available for kernel-fidelity debugging and is parity-tested bit-for-bit
against the emulation.  Large-shape ``auto`` callers still fall back to the
pure-jnp oracle (``ref.py``), which is what the dry-run compiles.
``use_pallas='auto'|'always'|'never'`` controls the arms.

Per-op BLOCK sizes come from ``autotune_block`` — the same VMEM footprint
model ``kernel_vmem_bytes`` gives the 'auto' dispatch, inverted: pick the
block that balances the [BLOCK, BLOCK] rank working set (cost grows with
the block) against the per-block whole-table work and launch overhead
(amortized by the block), subject to the op fitting the VMEM budget.

The single dispatch predicate lives in ``_use_kernel`` — the seed had an
operator-precedence bug (``A or (B and C) or D`` instead of
``A or (B and (C or D))``) that silently demoted ``use_pallas='always'`` to
the ref path whenever the VMEM estimate was large; 'always' now ALWAYS takes
the kernel (regression-tested in tests/test_filter_ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.delete import delete_bulk, delete_bulk_adaptive
from repro.kernels.fingerprint import fingerprint_hash, fingerprint_hash_family
from repro.kernels.flash_attention import flash_attention
from repro.kernels.insert import (DEFAULT_EVICT_ROUNDS, insert_bulk,
                                  insert_bulk_adaptive, insert_bulk_adaptive_tm,
                                  insert_bulk_tm, insert_once)
from repro.kernels.probe import (probe, probe_adaptive,
                                 probe_adaptive_emulated,
                                 probe_adaptive_emulated_tm, probe_emulated,
                                 probe_emulated_tm, probe_multi)
from repro.kernels.selector import (make_key_planes, make_sel_plane,
                                    report_adapt)
from repro.kernels.stash import (DEFAULT_STASH_SLOTS, make_stash,
                                 stash_delete_ref, stash_occupancy,
                                 stash_probe_ref, stash_spill_ref)
from repro.kernels.telemetry import FilterTelemetry, empty_telemetry

# VMEM residency budget for the filter kernels.  The probe/insert/delete
# BlockSpecs pin the full table per program, and the mutating kernels carry
# extra VMEM-resident working state (see ``kernel_vmem_bytes``); larger
# filters shard first (core.distributed).
VMEM_TABLE_BUDGET = 12 * 2**20


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# Budgeted bytes/element for the [block, block] broadcast-compare rank
# (kernels/rank.py).  Bounds: ~1 B/elem if Mosaic streams the iota/compare/
# reduce tiles (the common lowering), ~9 B/elem if the two int32 iotas and
# the bool mask fully materialize.  4 is the engineering estimate pending
# the real-TPU pass (ROADMAP "TPU-hardware validation"); biasing high only
# costs an early fallback to the jnp path, biasing low risks VMEM OOM.
RANK_BYTES_PER_ELEM = 4


def kernel_vmem_bytes(op: str, *, table_bytes: int, block: int,
                      evict_rounds: int = 0, stash_slots: int = 0) -> int:
    """Estimated peak VMEM footprint of one filter-kernel program.

    Used by 'auto' dispatch so budgeting reflects what each kernel actually
    pins, not just the table:
      * probe  — the table plus two gathered bucket rows per lane;
      * delete — the table plus the [block, block] broadcast-compare rank
        working set (``RANK_BYTES_PER_ELEM``);
      * insert — the table twice over (the dirty bitmap rides at table
        shape), the rank working set, and the 3 per-lane eviction-history
        arrays of width ``evict_rounds``.
    ``stash_slots`` adds the overflow stash's footprint: the aliased
    uint32[2, S] block plus the [block, S] broadcast-compare mask the match
    (probe) / spill (insert) step materializes.
    """
    rank_bytes = RANK_BYTES_PER_ELEM * block * block
    stash_bytes = 8 * stash_slots + block * stash_slots if stash_slots else 0
    if op == "probe":
        return table_bytes + 16 * block + stash_bytes
    if op == "delete":
        return table_bytes + rank_bytes + 16 * block
    if op == "insert":
        return (2 * table_bytes + rank_bytes
                + 3 * 4 * block * max(evict_rounds, 1) + 16 * block
                + stash_bytes)
    raise ValueError(f"unknown filter kernel op {op!r}")


# Pow2 block-size candidates for the autotuner.  128 is the TPU lane width
# (smaller tiles waste the VPU); 8192 keeps the padded-batch overhead and
# the key tiles bounded.
_BLOCK_CANDIDATES = (128, 256, 512, 1024, 2048, 4096, 8192)


@functools.lru_cache(maxsize=256)
def autotune_block(op: str, *, table_bytes: int, evict_rounds: int = 0,
                   stash_slots: int = 0, n_keys: int | None = None) -> int:
    """Per-op kernel BLOCK from the ``kernel_vmem_bytes`` footprint model.

    The fixed ``DEFAULT_BLOCK = 1024`` the kernels shipped with is the
    wrong point for most shapes, in both directions:

      * **probe** has no [BLOCK, BLOCK] rank term — its footprint is table
        + O(BLOCK) — so the biggest block that fits the budget wins (fewer
        grid launches, better key-tile amortization);
      * **insert/delete** pay the rank compare, whose *total* work grows
        linearly with the block (N lanes × BLOCK compares each), so the
        smallest candidate wins — measured on the bench shapes, insert at
        block 128 is ~5x block 1024.  One exception: a batch that fits
        entirely inside a single budget-fitting block takes that block —
        one launch, and a single-block insert reproduces the host
        optimistic round table-for-table (the PR-1 parity contract).

    Candidates are pow2 and must keep the op's ``kernel_vmem_bytes`` inside
    ``VMEM_TABLE_BUDGET`` — the same model 'auto' dispatch budgets with, so
    autotuned blocks can never pick a footprint dispatch would reject.
    """
    fits = [b for b in _BLOCK_CANDIDATES
            if kernel_vmem_bytes(op, table_bytes=table_bytes, block=b,
                                 evict_rounds=evict_rounds,
                                 stash_slots=stash_slots)
            <= VMEM_TABLE_BUDGET]
    if not fits:
        return _BLOCK_CANDIDATES[0]
    if op == "probe":
        return fits[-1]
    if n_keys is not None:
        whole = [b for b in fits if b >= n_keys]
        if whole:
            return whole[0]
    return fits[0]


def _emulate() -> bool:
    """Off TPU, run kernels as their compiled XLA grid emulation (bit-for-
    bit the pallas_call; ~100x the interpreter's throughput)."""
    return not _on_tpu()


def _use_kernel(use_pallas: str, *, vmem_bytes: int, n_keys: int) -> bool:
    """True when the Pallas kernel should run (vs the pure-jnp ref path).

    'always' -> kernel, unconditionally (interpret mode off-TPU).
    'never'  -> ref path, unconditionally.
    'auto'   -> kernel iff the op's estimated VMEM footprint (see
                ``kernel_vmem_bytes``) fits the budget AND, off-TPU, the
                batch is small enough for interpret mode to be sensible.
    """
    if use_pallas == "never":
        return False
    if use_pallas == "always":
        return True
    if vmem_bytes > VMEM_TABLE_BUDGET:
        return False
    if not _on_tpu() and n_keys > 65536:
        return False
    return True


def _pad_to(x: jax.Array, mult: int):
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return x, n


def _unpad(x: jax.Array, n: int):
    # Skip the slice when the batch needed no padding: an eager x[:n] is a
    # dispatched device op, and on the hot lookup path it is pure overhead.
    return x if x.shape[0] == n else x[:n]


def hash_keys(hi: jax.Array, lo: jax.Array, *, fp_bits: int, n_buckets: int,
              use_pallas: str = "auto"):
    """(fp, i1, i2) via the fingerprint kernel (padded to the block size)."""
    if hi.shape[0] == 0 or not _use_kernel(use_pallas, vmem_bytes=0,
                                           n_keys=hi.shape[0]):
        return ref.fingerprint_ref(hi, lo, fp_bits=fp_bits, n_buckets=n_buckets)
    block = min(autotune_block("probe", table_bytes=0), hi.shape[0])
    hi_p, n = _pad_to(hi, block)
    lo_p, _ = _pad_to(lo, block)
    fp, i1, i2 = fingerprint_hash(hi_p, lo_p, fp_bits=fp_bits,
                                  n_buckets=n_buckets, block=block,
                                  interpret=not _on_tpu(),
                                  emulate=_emulate())
    return _unpad(fp, n), _unpad(i1, n), _unpad(i2, n)


def filter_lookup(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                  fp_bits: int, n_buckets=None, stash=None,
                  use_pallas: str = "auto") -> jax.Array:
    """Bulk membership via the fused probe kernel.

    ``n_buckets``: ACTIVE bucket count when ``table`` is a pow2 buffer
    larger than the live filter (the OCF state); defaults to the full table.
    ``stash``: optional overflow stash — checked inside the same kernel pass
    (or by the jnp ``stash_probe_ref`` on the non-kernel arm), so stashed
    fingerprints answer True exactly like resident ones.
    """
    if hi.shape[0] == 0:
        return jnp.zeros((0,), jnp.bool_)
    stash_slots = 0 if stash is None else stash.shape[1]
    block = min(autotune_block("probe", table_bytes=table.size * 4,
                               stash_slots=stash_slots), hi.shape[0])
    if not _use_kernel(use_pallas,
                       vmem_bytes=kernel_vmem_bytes(
                           "probe", table_bytes=table.size * 4, block=block,
                           stash_slots=stash_slots),
                       n_keys=hi.shape[0]):
        hit = ref.probe_ref(table, hi, lo, fp_bits=fp_bits,
                            n_buckets=n_buckets)
        if stash is not None:
            nb = table.shape[0] if n_buckets is None else n_buckets
            hit = hit | stash_probe_ref(stash, hi, lo, fp_bits=fp_bits,
                                        n_buckets=nb)
        return hit
    hi_p, n = _pad_to(hi, block)
    lo_p, _ = _pad_to(lo, block)
    hit = probe(table, hi_p, lo_p, fp_bits=fp_bits, n_buckets=n_buckets,
                stash=stash, block=block, interpret=not _on_tpu(),
                emulate=_emulate())
    return _unpad(hit, n)


def filter_lookup_multi(tables: jax.Array, hi: jax.Array, lo: jax.Array, *,
                        fp_bits: int, n_buckets=None, stashes=None,
                        use_pallas: str = "auto") -> jax.Array:
    """Bulk membership across K stacked generations -> bool[N].

    ``tables``: uint32[K, buffer_buckets, bucket_size]; ``stashes``:
    optional uint32[K, 2, S]; ``n_buckets`` is the generations' shared
    ACTIVE bucket count.  Kernel arm: ONE fused ``probe_multi`` launch
    whose grid spans all K generations (keys hashed once).  Ref arm: the
    per-generation probe/stash loop — the same answers, 2·K hash passes.
    """
    if hi.shape[0] == 0:
        return jnp.zeros((0,), jnp.bool_)
    k = tables.shape[0]
    per_table_bytes = (tables.size // max(k, 1)) * 4
    stash_slots = 0 if stashes is None else stashes.shape[2]
    block = min(autotune_block("probe", table_bytes=per_table_bytes,
                               stash_slots=stash_slots), hi.shape[0])
    if not _use_kernel(use_pallas,
                       vmem_bytes=kernel_vmem_bytes(
                           "probe", table_bytes=per_table_bytes, block=block,
                           stash_slots=stash_slots),
                       n_keys=hi.shape[0]):
        nb = tables.shape[1] if n_buckets is None else n_buckets
        hit = jnp.zeros(hi.shape, jnp.bool_)
        for g in range(k):
            hit = hit | ref.probe_ref(tables[g], hi, lo, fp_bits=fp_bits,
                                      n_buckets=nb)
            if stashes is not None:
                hit = hit | stash_probe_ref(stashes[g], hi, lo,
                                            fp_bits=fp_bits, n_buckets=nb)
        return hit
    hi_p, n = _pad_to(hi, block)
    lo_p, _ = _pad_to(lo, block)
    hit = probe_multi(tables, hi_p, lo_p, fp_bits=fp_bits,
                      n_buckets=n_buckets, stashes=stashes, block=block,
                      interpret=not _on_tpu(), emulate=_emulate())
    return _unpad(hit, n)


@functools.lru_cache(maxsize=256)
def _probe_plan(fp_bits: int, table_shape: tuple, stash_slots: int):
    """Pinned (block, emulate) for a table shape — the per-call python of
    re-deriving them is measurable on the serving lookup path."""
    table_bytes = table_shape[0] * table_shape[1] * 4
    block = autotune_block("probe", table_bytes=table_bytes,
                           stash_slots=stash_slots)
    return block, _emulate()


def probe_dispatch(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                   fp_bits: int, n_buckets=None, stash=None) -> jax.Array:
    """``filter_lookup`` with the kernel arm pinned (use_pallas='always'),
    skipping the per-call block/VMEM re-derivation — the one-jit-dispatch
    fast path ``FilterOps.lookup`` takes on the pallas backend."""
    if hi.shape[0] == 0:
        return jnp.zeros((0,), jnp.bool_)
    stash_slots = 0 if stash is None else stash.shape[1]
    block, emul = _probe_plan(fp_bits, table.shape, stash_slots)
    if emul:
        # No padding needed: the emulated body is gridless.
        if n_buckets is None:
            n_buckets = table.shape[0]
        return probe_emulated(table, hi, lo, n_buckets, stash,
                              fp_bits=fp_bits)
    b = min(block, hi.shape[0])
    hi_p, n = _pad_to(hi, b)
    lo_p, _ = _pad_to(lo, b)
    # not emul => on TPU (emulation is exactly the off-TPU arm), so the
    # pallas_call compiles natively.
    hit = probe(table, hi_p, lo_p, fp_bits=fp_bits, n_buckets=n_buckets,
                stash=stash, block=b, interpret=False)
    return _unpad(hit, n)


def probe_dispatch_tm(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                      fp_bits: int, n_buckets=None, stash=None):
    """Telemetry twin of ``probe_dispatch`` -> (hit, FilterTelemetry).

    Runs the gridless emulated probe body (bit-for-bit the kernel's
    answers — the PR-5 parity contract) plus the probe-depth counter
    plane.  Separate jit under the hood (``probe_emulated_tm``), so the
    telemetry-off lookup's dispatch is untouched.
    """
    if hi.shape[0] == 0:
        return jnp.zeros((0,), jnp.bool_), empty_telemetry()
    if n_buckets is None:
        n_buckets = table.shape[0]
    hit, depth = probe_emulated_tm(table, hi, lo, n_buckets, stash,
                                   fp_bits=fp_bits)
    return hit, empty_telemetry()._replace(probe_depth=depth)


def multi_prober(tables: jax.Array, *, fp_bits: int, n_buckets=None,
                 stashes=None, use_pallas: str = "auto"):
    """Resolve ``filter_lookup_multi``'s dispatch ONCE for a fixed
    generation stack -> callable ``(hi, lo) -> bool[N]``.

    The streaming ring probes the same K tables for every chunk of a
    batch; re-deriving the block size, VMEM budget, and dispatch arm per
    chunk is measurable overhead on the serving hot path (~15% of a
    4096-key chunk).  The closure pins them, leaving one jitted
    ``probe_multi`` dispatch (plus padding when the tail chunk needs it)
    per call.
    """
    k = tables.shape[0]
    per_table_bytes = (tables.size // max(k, 1)) * 4
    stash_slots = 0 if stashes is None else stashes.shape[2]
    block = autotune_block("probe", table_bytes=per_table_bytes,
                           stash_slots=stash_slots)
    kernel = _use_kernel(use_pallas,
                         vmem_bytes=kernel_vmem_bytes(
                             "probe", table_bytes=per_table_bytes,
                             block=block, stash_slots=stash_slots),
                         n_keys=block)
    if not kernel:
        def ref_probe(hi, lo):
            return filter_lookup_multi(tables, hi, lo, fp_bits=fp_bits,
                                       n_buckets=n_buckets, stashes=stashes,
                                       use_pallas="never")
        return ref_probe
    interp = not _on_tpu()
    emul = _emulate()

    def kernel_probe(hi, lo):
        if hi.shape[0] == 0:
            return jnp.zeros((0,), jnp.bool_)
        b = min(block, hi.shape[0])
        hi_p, n = _pad_to(hi, b)
        lo_p, _ = _pad_to(lo, b)
        hit = probe_multi(tables, hi_p, lo_p, fp_bits=fp_bits,
                          n_buckets=n_buckets, stashes=stashes, block=b,
                          interpret=interp, emulate=emul)
        return _unpad(hit, n)

    return kernel_probe


def filter_insert(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                  fp_bits: int, n_buckets=None, valid=None,
                  evict_rounds: int = 0, stash=None, max_disp: int = 500,
                  use_pallas: str = "auto", schedule: bool = False,
                  donate: bool = False):
    """Fused bulk insert -> (new_table, placed bool[N]), or
    (new_table, new_stash, placed) when an overflow ``stash`` is attached.

    With ``evict_rounds=0`` this is the PR-1 optimistic single round — the
    fast path for ~95% of a batch, with the caller sweeping the residue.
    With ``evict_rounds>0`` the contended residue is resolved by bounded
    device-side eviction rounds inside the same kernel pass, so the WHOLE
    insert stays on-device (``core.filter_ops.FilterOps.insert``); lanes
    whose chain exceeds the budget spill to the stash when one is attached,
    and only roll back losslessly and report False once the stash is full
    (or when no stash is attached).

    The non-kernel fallback keeps exact scan semantics: optimistic jnp round
    plus the ``lax.scan`` eviction path over the residue (its sequential
    chains bounded by ``max_disp``, the jnp backend's knob); its spill parks
    the *key's own* fingerprint (the scan rolls exhausted chains back),
    while the kernel parks the chain's final carried victim — the two arms
    agree on which lanes succeed and on membership, not on which
    fingerprint of an exhausted chain physically sits in the stash.
    """
    if hi.shape[0] == 0:
        empty_ok = jnp.zeros((0,), jnp.bool_)
        return (table, empty_ok) if stash is None else (table, stash,
                                                        empty_ok)
    if valid is None:
        valid = jnp.ones(hi.shape, bool)
    stash_slots = 0 if stash is None else stash.shape[1]
    block = min(autotune_block("insert", table_bytes=table.size * 4,
                               evict_rounds=evict_rounds,
                               stash_slots=stash_slots,
                               n_keys=hi.shape[0]), hi.shape[0])
    if not _use_kernel(use_pallas,
                       vmem_bytes=kernel_vmem_bytes(
                           "insert", table_bytes=table.size * 4, block=block,
                           evict_rounds=evict_rounds,
                           stash_slots=stash_slots),
                       n_keys=hi.shape[0]):
        table, placed = ref.insert_once_ref(table, hi, lo, fp_bits=fp_bits,
                                            n_buckets=n_buckets, valid=valid)
        if evict_rounds > 0:
            table, ok2 = ref.insert_residue_ref(table, hi, lo,
                                                fp_bits=fp_bits,
                                                n_buckets=n_buckets,
                                                valid=valid & ~placed,
                                                max_disp=max_disp)
            placed = placed | ok2
        if stash is None:
            return table, placed
        nb = table.shape[0] if n_buckets is None else n_buckets
        stash, spilled = stash_spill_ref(stash, hi, lo, valid & ~placed,
                                         fp_bits=fp_bits, n_buckets=nb)
        return table, stash, placed | spilled
    hi_p, n = _pad_to(hi, block)
    lo_p, _ = _pad_to(lo, block)
    valid_p, _ = _pad_to(valid, block)   # pads False: never touches the table
    if stash is None:
        new_table, ok = insert_bulk(table, hi_p, lo_p, fp_bits=fp_bits,
                                    n_buckets=n_buckets, valid=valid_p,
                                    evict_rounds=evict_rounds,
                                    block=block, interpret=not _on_tpu(),
                                    emulate=_emulate(), schedule=schedule,
                                    donate=donate)
        return new_table, _unpad(ok, n)
    new_table, new_stash, ok = insert_bulk(
        table, hi_p, lo_p, fp_bits=fp_bits, n_buckets=n_buckets,
        valid=valid_p, evict_rounds=evict_rounds, stash=stash, block=block,
        interpret=not _on_tpu(), emulate=_emulate(), schedule=schedule,
        donate=donate)
    return new_table, new_stash, _unpad(ok, n)


def filter_delete(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                  fp_bits: int, n_buckets=None, valid=None, stash=None,
                  use_pallas: str = "auto", donate: bool = False):
    """Fused bulk delete -> (new_table, deleted bool[N]), or
    (new_table, new_stash, deleted) when an overflow ``stash`` is attached.

    Device-side first-match-slot clearing via ``kernels.delete``; the
    non-kernel path falls back to the sequential ``lax.scan`` oracle
    (``ref.delete_ref``).  With a stash, lanes that miss the table clear
    their stash entry in a composed jnp pass (``stash_delete`` — the stash
    is tiny, so it never needs the kernel), which is what makes spilled
    keys deletable: table copies go first, exactly like the sequential
    table-then-stash order, because the kernel's rank discipline credits
    earlier duplicate lanes with the resident copies.  Callers must
    pre-verify membership (the OCF keystore does) — blind deletes corrupt
    foreign fingerprints on every cuckoo-filter implementation, kernels
    included.
    """
    if hi.shape[0] == 0:
        empty_ok = jnp.zeros((0,), jnp.bool_)
        return (table, empty_ok) if stash is None else (table, stash,
                                                        empty_ok)
    if valid is None:
        valid = jnp.ones(hi.shape, bool)
    block = min(autotune_block("delete", table_bytes=table.size * 4,
                               n_keys=hi.shape[0]), hi.shape[0])
    if not _use_kernel(use_pallas,
                       vmem_bytes=kernel_vmem_bytes(
                           "delete", table_bytes=table.size * 4, block=block),
                       n_keys=hi.shape[0]):
        new_table, ok = ref.delete_ref(table, hi, lo, fp_bits=fp_bits,
                                       n_buckets=n_buckets, valid=valid)
    else:
        hi_p, n = _pad_to(hi, block)
        lo_p, _ = _pad_to(lo, block)
        valid_p, _ = _pad_to(valid, block)   # pads False: never touches table
        new_table, ok = delete_bulk(table, hi_p, lo_p, fp_bits=fp_bits,
                                    n_buckets=n_buckets, valid=valid_p,
                                    block=block, interpret=not _on_tpu(),
                                    emulate=_emulate(), donate=donate)
        ok = _unpad(ok, n)
    if stash is None:
        return new_table, ok
    nb = table.shape[0] if n_buckets is None else n_buckets
    stash, cleared = stash_delete_ref(stash, hi, lo, valid & ~ok,
                                      fp_bits=fp_bits, n_buckets=nb)
    return new_table, stash, ok | cleared


def filter_insert_tm(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                     fp_bits: int, n_buckets=None, valid=None,
                     evict_rounds: int = 0, stash=None,
                     schedule: bool = False, donate: bool = False):
    """Telemetry twin of ``filter_insert`` (kernel arm pinned) -> the same
    results plus a ``FilterTelemetry`` with the kick-depth histogram,
    spill/rollback counts, and stash fill high-water.

    Padding lanes ride ``valid=False`` and are excluded from every counter
    (the histogram masks on ``valid``), so the counters describe exactly
    the caller's batch.
    """
    if hi.shape[0] == 0:
        empty_ok = jnp.zeros((0,), jnp.bool_)
        tm = empty_telemetry()
        return ((table, empty_ok, tm) if stash is None
                else (table, stash, empty_ok, tm))
    if valid is None:
        valid = jnp.ones(hi.shape, bool)
    stash_slots = 0 if stash is None else stash.shape[1]
    block = min(autotune_block("insert", table_bytes=table.size * 4,
                               evict_rounds=evict_rounds,
                               stash_slots=stash_slots,
                               n_keys=hi.shape[0]), hi.shape[0])
    hi_p, n = _pad_to(hi, block)
    lo_p, _ = _pad_to(lo, block)
    valid_p, _ = _pad_to(valid, block)   # pads False: never touches the table
    if stash is None:
        new_table, ok, tm = insert_bulk_tm(
            table, hi_p, lo_p, fp_bits=fp_bits, n_buckets=n_buckets,
            valid=valid_p, evict_rounds=evict_rounds, block=block,
            schedule=schedule, donate=donate)
        return new_table, _unpad(ok, n), tm
    new_table, new_stash, ok, tm = insert_bulk_tm(
        table, hi_p, lo_p, fp_bits=fp_bits, n_buckets=n_buckets,
        valid=valid_p, evict_rounds=evict_rounds, stash=stash, block=block,
        schedule=schedule, donate=donate)
    return new_table, new_stash, _unpad(ok, n), tm


def filter_delete_tm(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                     fp_bits: int, n_buckets=None, valid=None, stash=None,
                     donate: bool = False):
    """Telemetry twin of ``filter_delete`` -> the same results plus a
    ``FilterTelemetry`` counting table- vs stash-resolved deletes.

    The delete kernels already return everything the counters need, so
    this twin is pure ops-level assembly — same kernel calls, two extra
    reductions.
    """
    if hi.shape[0] == 0:
        empty_ok = jnp.zeros((0,), jnp.bool_)
        tm = empty_telemetry()
        return ((table, empty_ok, tm) if stash is None
                else (table, stash, empty_ok, tm))
    if valid is None:
        valid = jnp.ones(hi.shape, bool)
    block = min(autotune_block("delete", table_bytes=table.size * 4,
                               n_keys=hi.shape[0]), hi.shape[0])
    hi_p, n = _pad_to(hi, block)
    lo_p, _ = _pad_to(lo, block)
    valid_p, _ = _pad_to(valid, block)   # pads False: never touches table
    new_table, ok = delete_bulk(table, hi_p, lo_p, fp_bits=fp_bits,
                                n_buckets=n_buckets, valid=valid_p,
                                block=block, interpret=not _on_tpu(),
                                emulate=_emulate(), donate=donate)
    ok = _unpad(ok, n)
    if stash is None:
        return new_table, ok, _delete_tm_plane(ok)
    nb = table.shape[0] if n_buckets is None else n_buckets
    stash, cleared = stash_delete_ref(stash, hi, lo, valid & ~ok,
                                      fp_bits=fp_bits, n_buckets=nb)
    return (new_table, stash, ok | cleared,
            _delete_tm_plane_stash(ok, cleared, stash))


@jax.jit
def _delete_tm_plane(ok):
    """Counter plane of a stashless delete in ONE fused dispatch — the
    loose ``jnp.sum``/``astype`` calls this replaces each paid a separate
    CPU dispatch, together several times the delete kernel's own cost."""
    return empty_telemetry()._replace(
        table_deletes=jnp.sum(ok).astype(jnp.uint32))


@jax.jit
def _delete_tm_plane_stash(ok, cleared, stash):
    return empty_telemetry()._replace(
        table_deletes=jnp.sum(ok).astype(jnp.uint32),
        stash_deletes=jnp.sum(cleared).astype(jnp.uint32),
        stash_fill_hw=stash_occupancy(stash).astype(jnp.uint32))


# ------------------------------------------------- adaptive dispatch -------
#
# The adaptive filter's state is FOUR planes (fingerprint table + packed
# selector column + two mirror-key planes), all pinned block-resident by the
# selector-aware kernels.  Dispatch reuses the static footprint model with
# the plane-scaled table bytes; there is no separate jnp oracle arm — the
# XLA grid emulation (the same kernel body as one compiled scan) IS the
# fallback, so a batch the VMEM model rejects still runs as compiled XLA
# over HBM instead of dropping to interpret mode.


def _adaptive_plane_bytes(table: jax.Array) -> int:
    """VMEM bytes of the four adaptive planes (fp + khi + klo at table
    shape, plus the packed selector column)."""
    return 3 * table.size * 4 + table.shape[0] * 4


def adaptive_lookup(table: jax.Array, sels: jax.Array, hi: jax.Array,
                    lo: jax.Array, *, fp_bits: int, n_buckets=None,
                    stash=None, use_pallas: str = "auto") -> jax.Array:
    """Selector-aware bulk membership -> bool[N].

    A slot hits when its stored fingerprint equals the query's family
    fingerprint **under that slot's selector**; stash entries always hold
    selector-0 fingerprints and are matched in the same pass.
    """
    if hi.shape[0] == 0:
        return jnp.zeros((0,), jnp.bool_)
    table_bytes = _adaptive_plane_bytes(table)
    stash_slots = 0 if stash is None else stash.shape[1]
    block = min(autotune_block("probe", table_bytes=table_bytes,
                               stash_slots=stash_slots), hi.shape[0])
    kernel = _use_kernel(use_pallas,
                         vmem_bytes=kernel_vmem_bytes(
                             "probe", table_bytes=table_bytes, block=block,
                             stash_slots=stash_slots),
                         n_keys=hi.shape[0])
    if not kernel or _emulate():
        if n_buckets is None:
            n_buckets = table.shape[0]
        return probe_adaptive_emulated(table, sels, hi.astype(jnp.uint32),
                                       lo.astype(jnp.uint32), n_buckets,
                                       stash, fp_bits=fp_bits)
    hi_p, n = _pad_to(hi, block)
    lo_p, _ = _pad_to(lo, block)
    hit = probe_adaptive(table, sels, hi_p, lo_p, fp_bits=fp_bits,
                         n_buckets=n_buckets, stash=stash, block=block,
                         interpret=False)
    return _unpad(hit, n)


def adaptive_insert(table: jax.Array, sels: jax.Array, khi_t: jax.Array,
                    klo_t: jax.Array, hi: jax.Array, lo: jax.Array, *,
                    fp_bits: int, n_buckets=None, valid=None,
                    evict_rounds: int = 0, stash=None,
                    use_pallas: str = "auto", schedule: bool = False,
                    donate: bool = False):
    """Fused bulk insert over the adaptive planes
    -> (table, sels, khi, klo, placed) or (..., stash, placed).

    Same contract as ``filter_insert``; placements and kicks write
    selector-0 entries with the key mirrored into khi/klo, so eviction
    chains re-derive victim geometry exactly and rollback restores all
    four planes verbatim.
    """
    if hi.shape[0] == 0:
        empty_ok = jnp.zeros((0,), jnp.bool_)
        return ((table, sels, khi_t, klo_t, empty_ok) if stash is None
                else (table, sels, khi_t, klo_t, stash, empty_ok))
    if valid is None:
        valid = jnp.ones(hi.shape, bool)
    table_bytes = _adaptive_plane_bytes(table)
    stash_slots = 0 if stash is None else stash.shape[1]
    # The adaptive chain history carries 6 per-lane arrays (slot coords plus
    # the kicked slot's original fp/sel/key), vs the static kernel's 3 —
    # doubling evict_rounds in the footprint model accounts for them.
    block = min(autotune_block("insert", table_bytes=table_bytes,
                               evict_rounds=2 * evict_rounds,
                               stash_slots=stash_slots,
                               n_keys=hi.shape[0]), hi.shape[0])
    kernel = _use_kernel(use_pallas,
                         vmem_bytes=kernel_vmem_bytes(
                             "insert", table_bytes=table_bytes, block=block,
                             evict_rounds=2 * evict_rounds,
                             stash_slots=stash_slots),
                         n_keys=hi.shape[0])
    emul = (not kernel) or _emulate()
    hi_p, n = _pad_to(hi, block)
    lo_p, _ = _pad_to(lo, block)
    valid_p, _ = _pad_to(valid, block)   # pads False: never touches planes
    out = insert_bulk_adaptive(table, sels, khi_t, klo_t, hi_p, lo_p,
                               fp_bits=fp_bits, n_buckets=n_buckets,
                               valid=valid_p, evict_rounds=evict_rounds,
                               stash=stash, block=block,
                               interpret=not _on_tpu(), emulate=emul,
                               schedule=schedule, donate=donate)
    return (*out[:-1], _unpad(out[-1], n))


def adaptive_delete(table: jax.Array, sels: jax.Array, khi_t: jax.Array,
                    klo_t: jax.Array, hi: jax.Array, lo: jax.Array, *,
                    fp_bits: int, n_buckets=None, valid=None, stash=None,
                    use_pallas: str = "auto", donate: bool = False):
    """Fused bulk delete over the adaptive planes
    -> (table, sels, khi, klo, deleted) or (..., stash, deleted).

    Slots are matched under THEIR selector (adapted residents stay
    deletable); clearing zeroes all four planes.  Stash entries hold
    selector-0 fingerprints, so lanes that miss the table compose the same
    jnp ``stash_delete_ref`` pass as the static path.
    """
    if hi.shape[0] == 0:
        empty_ok = jnp.zeros((0,), jnp.bool_)
        return ((table, sels, khi_t, klo_t, empty_ok) if stash is None
                else (table, sels, khi_t, klo_t, stash, empty_ok))
    if valid is None:
        valid = jnp.ones(hi.shape, bool)
    table_bytes = _adaptive_plane_bytes(table)
    block = min(autotune_block("delete", table_bytes=table_bytes,
                               n_keys=hi.shape[0]), hi.shape[0])
    kernel = _use_kernel(use_pallas,
                         vmem_bytes=kernel_vmem_bytes(
                             "delete", table_bytes=table_bytes, block=block),
                         n_keys=hi.shape[0])
    emul = (not kernel) or _emulate()
    hi_p, n = _pad_to(hi, block)
    lo_p, _ = _pad_to(lo, block)
    valid_p, _ = _pad_to(valid, block)   # pads False: never touches planes
    table, sels, khi_t, klo_t, ok = delete_bulk_adaptive(
        table, sels, khi_t, klo_t, hi_p, lo_p, fp_bits=fp_bits,
        n_buckets=n_buckets, valid=valid_p, block=block,
        interpret=not _on_tpu(), emulate=emul, donate=donate)
    ok = _unpad(ok, n)
    if stash is None:
        return table, sels, khi_t, klo_t, ok
    nb = table.shape[0] if n_buckets is None else n_buckets
    stash, cleared = stash_delete_ref(stash, hi, lo, valid & ~ok,
                                      fp_bits=fp_bits, n_buckets=nb)
    return table, sels, khi_t, klo_t, stash, ok | cleared


@functools.partial(jax.jit, static_argnames=("fp_bits",))
def adaptive_report(table: jax.Array, sels: jax.Array, khi_t: jax.Array,
                    klo_t: jax.Array, hi: jax.Array, lo: jax.Array, *,
                    fp_bits: int, n_buckets, valid=None):
    """Jitted confirmed-false-positive feedback pass
    -> (table, sels, adapted bool[N], resident bool[N]).

    Reports are rare control-plane events; the sequential ``report_adapt``
    scan (exact python-oracle semantics) needs no kernel arm.
    """
    if valid is None:
        valid = jnp.ones(hi.shape, bool)
    return report_adapt(table, sels, khi_t, klo_t, hi.astype(jnp.uint32),
                        lo.astype(jnp.uint32), valid, fp_bits=fp_bits,
                        n_buckets=n_buckets)


def adaptive_lookup_tm(table: jax.Array, sels: jax.Array, hi: jax.Array,
                       lo: jax.Array, *, fp_bits: int, n_buckets=None,
                       stash=None):
    """Telemetry twin of ``adaptive_lookup`` -> (hit, FilterTelemetry)."""
    if hi.shape[0] == 0:
        return jnp.zeros((0,), jnp.bool_), empty_telemetry()
    if n_buckets is None:
        n_buckets = table.shape[0]
    hit, depth = probe_adaptive_emulated_tm(
        table, sels, hi.astype(jnp.uint32), lo.astype(jnp.uint32), n_buckets,
        stash, fp_bits=fp_bits)
    return hit, empty_telemetry()._replace(probe_depth=depth)


def adaptive_insert_tm(table: jax.Array, sels: jax.Array, khi_t: jax.Array,
                       klo_t: jax.Array, hi: jax.Array, lo: jax.Array, *,
                       fp_bits: int, n_buckets=None, valid=None,
                       evict_rounds: int = 0, stash=None,
                       schedule: bool = False, donate: bool = False):
    """Telemetry twin of ``adaptive_insert`` -> same results + telemetry."""
    if hi.shape[0] == 0:
        empty_ok = jnp.zeros((0,), jnp.bool_)
        tm = empty_telemetry()
        return ((table, sels, khi_t, klo_t, empty_ok, tm) if stash is None
                else (table, sels, khi_t, klo_t, stash, empty_ok, tm))
    if valid is None:
        valid = jnp.ones(hi.shape, bool)
    table_bytes = _adaptive_plane_bytes(table)
    stash_slots = 0 if stash is None else stash.shape[1]
    block = min(autotune_block("insert", table_bytes=table_bytes,
                               evict_rounds=2 * evict_rounds,
                               stash_slots=stash_slots,
                               n_keys=hi.shape[0]), hi.shape[0])
    hi_p, n = _pad_to(hi, block)
    lo_p, _ = _pad_to(lo, block)
    valid_p, _ = _pad_to(valid, block)   # pads False: never touches planes
    out = insert_bulk_adaptive_tm(table, sels, khi_t, klo_t, hi_p, lo_p,
                                  fp_bits=fp_bits, n_buckets=n_buckets,
                                  valid=valid_p, evict_rounds=evict_rounds,
                                  stash=stash, block=block,
                                  schedule=schedule, donate=donate)
    tm = out[-1]
    return (*out[:-2], _unpad(out[-2], n), tm)


def adaptive_delete_tm(table: jax.Array, sels: jax.Array, khi_t: jax.Array,
                       klo_t: jax.Array, hi: jax.Array, lo: jax.Array, *,
                       fp_bits: int, n_buckets=None, valid=None, stash=None,
                       donate: bool = False):
    """Telemetry twin of ``adaptive_delete`` -> same results + telemetry."""
    if hi.shape[0] == 0:
        empty_ok = jnp.zeros((0,), jnp.bool_)
        tm = empty_telemetry()
        return ((table, sels, khi_t, klo_t, empty_ok, tm) if stash is None
                else (table, sels, khi_t, klo_t, stash, empty_ok, tm))
    if valid is None:
        valid = jnp.ones(hi.shape, bool)
    table_bytes = _adaptive_plane_bytes(table)
    block = min(autotune_block("delete", table_bytes=table_bytes,
                               n_keys=hi.shape[0]), hi.shape[0])
    hi_p, n = _pad_to(hi, block)
    lo_p, _ = _pad_to(lo, block)
    valid_p, _ = _pad_to(valid, block)   # pads False: never touches planes
    table, sels, khi_t, klo_t, ok = delete_bulk_adaptive(
        table, sels, khi_t, klo_t, hi_p, lo_p, fp_bits=fp_bits,
        n_buckets=n_buckets, valid=valid_p, block=block,
        interpret=not _on_tpu(), emulate=True, donate=donate)
    ok = _unpad(ok, n)
    tm = empty_telemetry()._replace(
        table_deletes=jnp.sum(ok).astype(jnp.uint32))
    if stash is None:
        return table, sels, khi_t, klo_t, ok, tm
    nb = table.shape[0] if n_buckets is None else n_buckets
    stash, cleared = stash_delete_ref(stash, hi, lo, valid & ~ok,
                                      fp_bits=fp_bits, n_buckets=nb)
    tm = tm._replace(stash_deletes=jnp.sum(cleared).astype(jnp.uint32),
                     stash_fill_hw=stash_occupancy(stash).astype(jnp.uint32))
    return table, sels, khi_t, klo_t, stash, ok | cleared, tm


def adaptive_report_tm(table: jax.Array, sels: jax.Array, khi_t: jax.Array,
                       klo_t: jax.Array, hi: jax.Array, lo: jax.Array, *,
                       fp_bits: int, n_buckets, valid=None):
    """Telemetry twin of ``adaptive_report`` — ``selector_bumps`` counts
    the slots whose selector actually advanced this pass."""
    table, sels, adapted, resident = adaptive_report(
        table, sels, khi_t, klo_t, hi, lo, fp_bits=fp_bits,
        n_buckets=n_buckets, valid=valid)
    tm = empty_telemetry()._replace(
        selector_bumps=jnp.sum(adapted).astype(jnp.uint32))
    return table, sels, adapted, resident, tm


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              logit_softcap: float | None = None, scale: float | None = None,
              qpos_start=None, valid_len=None, key_positions=None,
              use_pallas: str = "auto") -> jax.Array:
    """Attention dispatcher.

    TPU: Pallas flash kernel.  XLA path (CPU host / dry-run): window layers
    use the O(S·W) chunked local path; everything else goes through
    blockwise attention (never materializes SxS) — see ref.py docstrings.
    """
    if use_pallas == "always" or (use_pallas == "auto" and _on_tpu()):
        if valid_len is None and qpos_start is None and key_positions is None:
            return flash_attention(q, k, v, causal=causal, window=window,
                                   logit_softcap=logit_softcap, scale=scale,
                                   interpret=not _on_tpu())
    sq, skv = q.shape[2], k.shape[2]
    if (window is not None and causal and valid_len is None
            and key_positions is None and sq == skv
            and sq % window == 0 and sq > window):
        return ref.local_attention(q, k, v, window=window,
                                   logit_softcap=logit_softcap, scale=scale)
    return ref.blockwise_attention(q, k, v, causal=causal, window=window,
                                   logit_softcap=logit_softcap, scale=scale,
                                   qpos_start=qpos_start, valid_len=valid_len,
                                   key_positions=key_positions)


__all__ = ["hash_keys", "filter_lookup", "filter_lookup_multi",
           "filter_insert", "filter_delete", "attention", "fingerprint_hash",
           "fingerprint_hash_family", "probe", "probe_multi", "insert_once",
           "insert_bulk", "delete_bulk", "flash_attention",
           "kernel_vmem_bytes", "autotune_block", "VMEM_TABLE_BUDGET",
           "DEFAULT_EVICT_ROUNDS", "DEFAULT_STASH_SLOTS", "make_stash",
           "stash_occupancy", "adaptive_lookup", "adaptive_insert",
           "adaptive_delete", "adaptive_report", "make_sel_plane",
           "make_key_planes", "FilterTelemetry", "empty_telemetry",
           "probe_dispatch_tm", "filter_insert_tm", "filter_delete_tm",
           "adaptive_lookup_tm", "adaptive_insert_tm", "adaptive_delete_tm",
           "adaptive_report_tm"]
