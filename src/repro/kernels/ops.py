"""Jit'd public wrappers around the Pallas kernels.

Backend dispatch: Pallas-TPU lowers only on TPU; on the CPU host (this
container, tests) kernels run in ``interpret=True`` mode and large-shape
callers fall back to the pure-jnp oracle (``ref.py``), which is what the
dry-run compiles.  ``use_pallas='auto'|'always'|'never'`` controls this.

The single dispatch predicate lives in ``_use_kernel`` — the seed had an
operator-precedence bug (``A or (B and C) or D`` instead of
``A or (B and (C or D))``) that silently demoted ``use_pallas='always'`` to
the ref path whenever the VMEM estimate was large; 'always' now ALWAYS takes
the kernel (regression-tested in tests/test_filter_ops.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.delete import delete_bulk
from repro.kernels.fingerprint import fingerprint_hash
from repro.kernels.flash_attention import flash_attention
from repro.kernels.insert import DEFAULT_EVICT_ROUNDS, insert_bulk, insert_once
from repro.kernels.probe import probe
from repro.kernels.stash import (DEFAULT_STASH_SLOTS, make_stash,
                                 stash_occupancy, stash_probe_ref,
                                 stash_spill_ref)

# VMEM residency budget for the filter kernels.  The probe/insert/delete
# BlockSpecs pin the full table per program, and the mutating kernels carry
# extra VMEM-resident working state (see ``kernel_vmem_bytes``); larger
# filters shard first (core.distributed).
VMEM_TABLE_BUDGET = 12 * 2**20


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# Budgeted bytes/element for the [block, block] broadcast-compare rank
# (kernels/rank.py).  Bounds: ~1 B/elem if Mosaic streams the iota/compare/
# reduce tiles (the common lowering), ~9 B/elem if the two int32 iotas and
# the bool mask fully materialize.  4 is the engineering estimate pending
# the real-TPU pass (ROADMAP "TPU-hardware validation"); biasing high only
# costs an early fallback to the jnp path, biasing low risks VMEM OOM.
RANK_BYTES_PER_ELEM = 4


def kernel_vmem_bytes(op: str, *, table_bytes: int, block: int,
                      evict_rounds: int = 0, stash_slots: int = 0) -> int:
    """Estimated peak VMEM footprint of one filter-kernel program.

    Used by 'auto' dispatch so budgeting reflects what each kernel actually
    pins, not just the table:
      * probe  — the table plus two gathered bucket rows per lane;
      * delete — the table plus the [block, block] broadcast-compare rank
        working set (``RANK_BYTES_PER_ELEM``);
      * insert — the table twice over (the dirty bitmap rides at table
        shape), the rank working set, and the 3 per-lane eviction-history
        arrays of width ``evict_rounds``.
    ``stash_slots`` adds the overflow stash's footprint: the aliased
    uint32[2, S] block plus the [block, S] broadcast-compare mask the match
    (probe) / spill (insert) step materializes.
    """
    rank_bytes = RANK_BYTES_PER_ELEM * block * block
    stash_bytes = 8 * stash_slots + block * stash_slots if stash_slots else 0
    if op == "probe":
        return table_bytes + 16 * block + stash_bytes
    if op == "delete":
        return table_bytes + rank_bytes + 16 * block
    if op == "insert":
        return (2 * table_bytes + rank_bytes
                + 3 * 4 * block * max(evict_rounds, 1) + 16 * block
                + stash_bytes)
    raise ValueError(f"unknown filter kernel op {op!r}")


def _use_kernel(use_pallas: str, *, vmem_bytes: int, n_keys: int) -> bool:
    """True when the Pallas kernel should run (vs the pure-jnp ref path).

    'always' -> kernel, unconditionally (interpret mode off-TPU).
    'never'  -> ref path, unconditionally.
    'auto'   -> kernel iff the op's estimated VMEM footprint (see
                ``kernel_vmem_bytes``) fits the budget AND, off-TPU, the
                batch is small enough for interpret mode to be sensible.
    """
    if use_pallas == "never":
        return False
    if use_pallas == "always":
        return True
    if vmem_bytes > VMEM_TABLE_BUDGET:
        return False
    if not _on_tpu() and n_keys > 65536:
        return False
    return True


def _pad_to(x: jax.Array, mult: int):
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return x, n


def hash_keys(hi: jax.Array, lo: jax.Array, *, fp_bits: int, n_buckets: int,
              use_pallas: str = "auto"):
    """(fp, i1, i2) via the fingerprint kernel (padded to the block size)."""
    if hi.shape[0] == 0 or not _use_kernel(use_pallas, vmem_bytes=0,
                                           n_keys=hi.shape[0]):
        return ref.fingerprint_ref(hi, lo, fp_bits=fp_bits, n_buckets=n_buckets)
    block = 1024 if hi.shape[0] >= 1024 else hi.shape[0]
    hi_p, n = _pad_to(hi, block)
    lo_p, _ = _pad_to(lo, block)
    fp, i1, i2 = fingerprint_hash(hi_p, lo_p, fp_bits=fp_bits,
                                  n_buckets=n_buckets, block=block,
                                  interpret=not _on_tpu())
    return fp[:n], i1[:n], i2[:n]


def filter_lookup(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                  fp_bits: int, n_buckets=None, stash=None,
                  use_pallas: str = "auto") -> jax.Array:
    """Bulk membership via the fused probe kernel.

    ``n_buckets``: ACTIVE bucket count when ``table`` is a pow2 buffer
    larger than the live filter (the OCF state); defaults to the full table.
    ``stash``: optional overflow stash — checked inside the same kernel pass
    (or by the jnp ``stash_probe_ref`` on the non-kernel arm), so stashed
    fingerprints answer True exactly like resident ones.
    """
    if hi.shape[0] == 0:
        return jnp.zeros((0,), jnp.bool_)
    block = 1024 if hi.shape[0] >= 1024 else hi.shape[0]
    stash_slots = 0 if stash is None else stash.shape[1]
    if not _use_kernel(use_pallas,
                       vmem_bytes=kernel_vmem_bytes(
                           "probe", table_bytes=table.size * 4, block=block,
                           stash_slots=stash_slots),
                       n_keys=hi.shape[0]):
        hit = ref.probe_ref(table, hi, lo, fp_bits=fp_bits,
                            n_buckets=n_buckets)
        if stash is not None:
            nb = table.shape[0] if n_buckets is None else n_buckets
            hit = hit | stash_probe_ref(stash, hi, lo, fp_bits=fp_bits,
                                        n_buckets=nb)
        return hit
    hi_p, n = _pad_to(hi, block)
    lo_p, _ = _pad_to(lo, block)
    hit = probe(table, hi_p, lo_p, fp_bits=fp_bits, n_buckets=n_buckets,
                stash=stash, block=block, interpret=not _on_tpu())
    return hit[:n]


def filter_insert(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                  fp_bits: int, n_buckets=None, valid=None,
                  evict_rounds: int = 0, stash=None, max_disp: int = 500,
                  use_pallas: str = "auto"):
    """Fused bulk insert -> (new_table, placed bool[N]), or
    (new_table, new_stash, placed) when an overflow ``stash`` is attached.

    With ``evict_rounds=0`` this is the PR-1 optimistic single round — the
    fast path for ~95% of a batch, with the caller sweeping the residue.
    With ``evict_rounds>0`` the contended residue is resolved by bounded
    device-side eviction rounds inside the same kernel pass, so the WHOLE
    insert stays on-device (``core.filter_ops.FilterOps.insert``); lanes
    whose chain exceeds the budget spill to the stash when one is attached,
    and only roll back losslessly and report False once the stash is full
    (or when no stash is attached).

    The non-kernel fallback keeps exact scan semantics: optimistic jnp round
    plus the ``lax.scan`` eviction path over the residue (its sequential
    chains bounded by ``max_disp``, the jnp backend's knob); its spill parks
    the *key's own* fingerprint (the scan rolls exhausted chains back),
    while the kernel parks the chain's final carried victim — the two arms
    agree on which lanes succeed and on membership, not on which
    fingerprint of an exhausted chain physically sits in the stash.
    """
    if hi.shape[0] == 0:
        empty_ok = jnp.zeros((0,), jnp.bool_)
        return (table, empty_ok) if stash is None else (table, stash,
                                                        empty_ok)
    if valid is None:
        valid = jnp.ones(hi.shape, bool)
    block = 1024 if hi.shape[0] >= 1024 else hi.shape[0]
    stash_slots = 0 if stash is None else stash.shape[1]
    if not _use_kernel(use_pallas,
                       vmem_bytes=kernel_vmem_bytes(
                           "insert", table_bytes=table.size * 4, block=block,
                           evict_rounds=evict_rounds,
                           stash_slots=stash_slots),
                       n_keys=hi.shape[0]):
        table, placed = ref.insert_once_ref(table, hi, lo, fp_bits=fp_bits,
                                            n_buckets=n_buckets, valid=valid)
        if evict_rounds > 0:
            table, ok2 = ref.insert_residue_ref(table, hi, lo,
                                                fp_bits=fp_bits,
                                                n_buckets=n_buckets,
                                                valid=valid & ~placed,
                                                max_disp=max_disp)
            placed = placed | ok2
        if stash is None:
            return table, placed
        nb = table.shape[0] if n_buckets is None else n_buckets
        stash, spilled = stash_spill_ref(stash, hi, lo, valid & ~placed,
                                         fp_bits=fp_bits, n_buckets=nb)
        return table, stash, placed | spilled
    hi_p, n = _pad_to(hi, block)
    lo_p, _ = _pad_to(lo, block)
    valid_p, _ = _pad_to(valid, block)   # pads False: never touches the table
    if stash is None:
        new_table, ok = insert_bulk(table, hi_p, lo_p, fp_bits=fp_bits,
                                    n_buckets=n_buckets, valid=valid_p,
                                    evict_rounds=evict_rounds,
                                    block=block, interpret=not _on_tpu())
        return new_table, ok[:n]
    new_table, new_stash, ok = insert_bulk(
        table, hi_p, lo_p, fp_bits=fp_bits, n_buckets=n_buckets,
        valid=valid_p, evict_rounds=evict_rounds, stash=stash, block=block,
        interpret=not _on_tpu())
    return new_table, new_stash, ok[:n]


def filter_delete(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                  fp_bits: int, n_buckets=None, valid=None,
                  use_pallas: str = "auto") -> tuple[jax.Array, jax.Array]:
    """Fused bulk delete -> (new_table, deleted bool[N]).

    Device-side first-match-slot clearing via ``kernels.delete``; the
    non-kernel path falls back to the sequential ``lax.scan`` oracle
    (``ref.delete_ref``).  Callers must pre-verify membership (the OCF
    keystore does) — blind deletes corrupt foreign fingerprints on every
    cuckoo-filter implementation, kernels included.
    """
    if hi.shape[0] == 0:
        return table, jnp.zeros((0,), jnp.bool_)
    if valid is None:
        valid = jnp.ones(hi.shape, bool)
    block = 1024 if hi.shape[0] >= 1024 else hi.shape[0]
    if not _use_kernel(use_pallas,
                       vmem_bytes=kernel_vmem_bytes(
                           "delete", table_bytes=table.size * 4, block=block),
                       n_keys=hi.shape[0]):
        return ref.delete_ref(table, hi, lo, fp_bits=fp_bits,
                              n_buckets=n_buckets, valid=valid)
    hi_p, n = _pad_to(hi, block)
    lo_p, _ = _pad_to(lo, block)
    valid_p, _ = _pad_to(valid, block)   # pads False: never touches the table
    new_table, ok = delete_bulk(table, hi_p, lo_p, fp_bits=fp_bits,
                                n_buckets=n_buckets, valid=valid_p,
                                block=block, interpret=not _on_tpu())
    return new_table, ok[:n]


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              logit_softcap: float | None = None, scale: float | None = None,
              qpos_start=None, valid_len=None, key_positions=None,
              use_pallas: str = "auto") -> jax.Array:
    """Attention dispatcher.

    TPU: Pallas flash kernel.  XLA path (CPU host / dry-run): window layers
    use the O(S·W) chunked local path; everything else goes through
    blockwise attention (never materializes SxS) — see ref.py docstrings.
    """
    if use_pallas == "always" or (use_pallas == "auto" and _on_tpu()):
        if valid_len is None and qpos_start is None and key_positions is None:
            return flash_attention(q, k, v, causal=causal, window=window,
                                   logit_softcap=logit_softcap, scale=scale,
                                   interpret=not _on_tpu())
    sq, skv = q.shape[2], k.shape[2]
    if (window is not None and causal and valid_len is None
            and key_positions is None and sq == skv
            and sq % window == 0 and sq > window):
        return ref.local_attention(q, k, v, window=window,
                                   logit_softcap=logit_softcap, scale=scale)
    return ref.blockwise_attention(q, k, v, causal=causal, window=window,
                                   logit_softcap=logit_softcap, scale=scale,
                                   qpos_start=qpos_start, valid_len=valid_len,
                                   key_positions=key_positions)


__all__ = ["hash_keys", "filter_lookup", "filter_insert", "filter_delete",
           "attention", "fingerprint_hash", "probe", "insert_once",
           "insert_bulk", "delete_bulk", "flash_attention",
           "kernel_vmem_bytes", "VMEM_TABLE_BUDGET",
           "DEFAULT_EVICT_ROUNDS", "DEFAULT_STASH_SLOTS", "make_stash",
           "stash_occupancy"]
