"""Pallas TPU kernel: fused hash + first-match-slot bulk delete.

The device-side analogue of ``core.filter.bulk_delete`` — deletes are what
distinguish a cuckoo filter from a Bloom filter, and until PR 3 they were
the last ``FilterOps`` op stuck on the sequential ``lax.scan`` path.  One
kernel pass hashes each key, probes the home bucket and (for lanes that
missed there) the alternate bucket, and clears exactly one matching slot
per successful lane.

Schedule — same layout strategy as ``probe.py`` / ``insert.py``:
  * the table (the OCF's pow2 buffer) is block-resident in VMEM and aliased
    input→output, so grid steps accumulate clears — TPU grids execute
    sequentially, which makes block b's deletes visible to block b+1;
  * the ACTIVE bucket count is a ``(1, 1)`` SMEM scalar;
  * keys are tiled ``(BLOCK,)``; duplicate keys inside a block are resolved
    with the broadcast-compare rank used by the insert kernel, refined to
    (bucket, fingerprint) pairs: lane i's rank counts earlier lanes
    clearing the same fingerprint from the same bucket, and lane i claims
    the rank-th matching slot.  That reproduces the sequential scan exactly
    for duplicate keys — the k-th duplicate clears the k-th copy, and
    duplicates beyond the resident multiplicity report False.

Parity caveat: the kernel runs all home-bucket attempts before all
alternate-bucket attempts, while the scan interleaves them per key.  For
verified deletes (every requested key resident — what the OCF keystore
guarantees) and for duplicate keys the outcomes are identical; the one
divergence is *unverified* deletes where two DISTINCT keys collide on the
same 16-bit fingerprint with conjugate buckets and fewer resident copies
than requests — there the two orders can credit a different lane.  Blind
deletes corrupt any cuckoo filter anyway, so the control plane never issues
them.

Hash math is imported from ``repro.core.hashing`` — one spec for kernels,
host data plane, and the numpy oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hashing
from repro.kernels.rank import rank_among_earlier
from repro.kernels.selector import fp_family, select_fp, sel_pack, sel_unpack

DEFAULT_BLOCK = 1024


def _clear_round(table, target, active, fp):
    """One clear attempt for every active lane in ``target`` buckets.

    Returns (table, cleared).  Rank = #earlier active lanes clearing the
    same fingerprint from the same bucket; a lane succeeds when its rank is
    below the bucket's match count and zeroes the rank-th matching slot, so
    duplicate lanes of one bucket never race for a slot.
    """
    buf, _bucket_size = table.shape
    rank = rank_among_earlier(target, active, fp=fp)
    tgt_c = jnp.clip(target, 0, buf - 1)
    row = table[tgt_c]                                    # [n, bucket_size]
    match = row == fp[:, None]
    hits = active & (rank < jnp.sum(match, axis=1).astype(jnp.int32))
    match_pos = jnp.cumsum(match.astype(jnp.int32), axis=1) - 1
    is_dest = match & (match_pos == rank[:, None])
    slot = jnp.argmax(is_dest, axis=1)
    upd_i = jnp.where(hits, target, buf)                  # OOB -> dropped
    table = table.at[upd_i, slot].set(jnp.uint32(0), mode="drop")
    return table, hits


def _delete_body(table, hi, lo, valid, n_buckets, *, fp_bits: int):
    """Hash + home/alternate clear rounds on loaded values -> (table, ok)."""
    fp = hashing.fingerprint(hi, lo, fp_bits)
    i1 = hashing.index_hash_dyn(hi, lo, n_buckets).astype(jnp.int32)
    i2 = hashing.alt_index_dyn(i1, fp, n_buckets).astype(jnp.int32)
    table, ok1 = _clear_round(table, i1, valid, fp)
    table, ok2 = _clear_round(table, i2, valid & ~ok1, fp)
    return table, ok1 | ok2


def _delete_kernel(n_ref, table_in_ref, hi_ref, lo_ref, valid_ref, table_ref,
                   ok_ref, *, fp_bits: int):
    del table_in_ref  # aliased to table_ref (the output) — read/write there
    table, ok = _delete_body(table_ref[...], hi_ref[...], lo_ref[...],
                             valid_ref[...], n_ref[0, 0], fp_bits=fp_bits)
    table_ref[...] = table
    ok_ref[...] = ok


def _delete_bulk_impl(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                      fp_bits: int, n_buckets=None, valid=None,
                      block: int = DEFAULT_BLOCK, interpret: bool = True,
                      emulate: bool = False) -> tuple[jax.Array, jax.Array]:
    n = hi.shape[0]
    block = min(block, n)
    assert n % block == 0, f"{n=} not a multiple of {block=}"
    buffer_buckets, bucket_size = table.shape
    if n_buckets is None:
        n_buckets = buffer_buckets
    if valid is None:
        valid = jnp.ones((n,), bool)
    hi = hi.astype(jnp.uint32)
    lo = lo.astype(jnp.uint32)
    if emulate:
        # The kernel's sequential grid as a compiled lax.scan (table carried
        # between blocks) — bit-for-bit the pallas_call, without the
        # interpreter (see kernels/insert.py::_emulated_insert).
        g = n // block
        if g == 1:
            return _delete_body(table, hi, lo, valid, n_buckets,
                                fp_bits=fp_bits)

        def step(tbl, x):
            return _delete_body(tbl, *x, n_buckets, fp_bits=fp_bits)

        table, ok = jax.lax.scan(step, table,
                                 (hi.reshape(g, block), lo.reshape(g, block),
                                  valid.reshape(g, block)))
        return table, ok.reshape(-1)
    n_arr = jnp.asarray(n_buckets, jnp.int32).reshape(1, 1)
    grid = (n // block,)
    smem_spec = pl.BlockSpec((1, 1), lambda i: (0, 0),
                             memory_space=pltpu.SMEM)
    key_spec = pl.BlockSpec((block,), lambda i: (i,))
    table_spec = pl.BlockSpec((buffer_buckets, bucket_size), lambda i: (0, 0))
    new_table, ok = pl.pallas_call(
        functools.partial(_delete_kernel, fp_bits=fp_bits),
        grid=grid,
        in_specs=[smem_spec, table_spec, key_spec, key_spec, key_spec],
        out_specs=[table_spec, pl.BlockSpec((block,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct(table.shape, table.dtype),
                   jax.ShapeDtypeStruct((n,), jnp.bool_)],
        input_output_aliases={1: 0},   # table updates in place across steps
        interpret=interpret,
    )(n_arr, table, hi, lo, valid)
    return new_table, ok


# ------------------------------------------- selector-aware (adaptive) -----


def _clear_round_adaptive(planes, target, active, fam, fp0):
    """Adaptive clear round: a slot matches when it stores the lane's
    fingerprint under the SLOT's selector; clearing zeroes all four planes.

    Duplicate rank stays keyed on (bucket, selector-0 fingerprint) — lanes
    deleting the same key share fp0 whatever the resident slots' selectors
    are, so the k-th duplicate still clears the k-th matching copy.
    """
    table, sel_tbl, khi_t, klo_t = planes
    buf, _bucket_size = table.shape
    rank = rank_among_earlier(target, active, fp=fp0)
    tgt_c = jnp.clip(target, 0, buf - 1)
    row = table[tgt_c]                                    # [n, bucket_size]
    match = row == select_fp(fam, sel_tbl[tgt_c])
    hits = active & (rank < jnp.sum(match, axis=1).astype(jnp.int32))
    match_pos = jnp.cumsum(match.astype(jnp.int32), axis=1) - 1
    is_dest = match & (match_pos == rank[:, None])
    slot = jnp.argmax(is_dest, axis=1)
    upd_i = jnp.where(hits, target, buf)                  # OOB -> dropped
    table = table.at[upd_i, slot].set(jnp.uint32(0), mode="drop")
    sel_tbl = sel_tbl.at[upd_i, slot].set(jnp.uint32(0), mode="drop")
    khi_t = khi_t.at[upd_i, slot].set(jnp.uint32(0), mode="drop")
    klo_t = klo_t.at[upd_i, slot].set(jnp.uint32(0), mode="drop")
    return (table, sel_tbl, khi_t, klo_t), hits


def _delete_adaptive_body(table, sels, khi_t, klo_t, hi, lo, valid, n_buckets,
                          *, fp_bits: int):
    """Hash family + home/alternate adaptive clear rounds.

    With an all-zero selector plane this is bit-for-bit ``_delete_body`` on
    the fingerprint plane (selector-0 expected fps == static fps).
    """
    bucket_size = table.shape[-1]
    sel_tbl = sel_unpack(sels, bucket_size)
    fam = fp_family(hi, lo, fp_bits)
    fp0 = fam[0]
    i1 = hashing.index_hash_dyn(hi, lo, n_buckets).astype(jnp.int32)
    i2 = hashing.alt_index_dyn(i1, fp0, n_buckets).astype(jnp.int32)
    planes = (table, sel_tbl, khi_t, klo_t)
    planes, ok1 = _clear_round_adaptive(planes, i1, valid, fam, fp0)
    planes, ok2 = _clear_round_adaptive(planes, i2, valid & ~ok1, fam, fp0)
    table, sel_tbl, khi_t, klo_t = planes
    return table, sel_pack(sel_tbl), khi_t, klo_t, ok1 | ok2


def _delete_adaptive_kernel(n_ref, table_in, sels_in, khi_in, klo_in, hi_ref,
                            lo_ref, valid_ref, table_ref, sels_ref, khi_ref,
                            klo_ref, ok_ref, *, fp_bits: int):
    del table_in, sels_in, khi_in, klo_in      # aliased to the outputs
    table, sels, khi_t, klo_t, ok = _delete_adaptive_body(
        table_ref[...], sels_ref[...], khi_ref[...], klo_ref[...],
        hi_ref[...], lo_ref[...], valid_ref[...], n_ref[0, 0],
        fp_bits=fp_bits)
    table_ref[...] = table
    sels_ref[...] = sels
    khi_ref[...] = khi_t
    klo_ref[...] = klo_t
    ok_ref[...] = ok


def _delete_adaptive_impl(table, sels, khi_t, klo_t, hi, lo, *, fp_bits: int,
                          n_buckets=None, valid=None,
                          block: int = DEFAULT_BLOCK, interpret: bool = True,
                          emulate: bool = False):
    n = hi.shape[0]
    block = min(block, n)
    assert n % block == 0, f"{n=} not a multiple of {block=}"
    buffer_buckets, bucket_size = table.shape
    if n_buckets is None:
        n_buckets = buffer_buckets
    if valid is None:
        valid = jnp.ones((n,), bool)
    hi = hi.astype(jnp.uint32)
    lo = lo.astype(jnp.uint32)
    if emulate:
        g = n // block
        if g == 1:
            return _delete_adaptive_body(table, sels, khi_t, klo_t, hi, lo,
                                         valid, n_buckets, fp_bits=fp_bits)

        def step(carry, x):
            t, s, kh, kl = carry
            t, s, kh, kl, ok = _delete_adaptive_body(t, s, kh, kl, *x,
                                                     n_buckets,
                                                     fp_bits=fp_bits)
            return (t, s, kh, kl), ok

        (table, sels, khi_t, klo_t), ok = jax.lax.scan(
            step, (table, sels, khi_t, klo_t),
            (hi.reshape(g, block), lo.reshape(g, block),
             valid.reshape(g, block)))
        return table, sels, khi_t, klo_t, ok.reshape(-1)
    n_arr = jnp.asarray(n_buckets, jnp.int32).reshape(1, 1)
    grid = (n // block,)
    smem_spec = pl.BlockSpec((1, 1), lambda i: (0, 0),
                             memory_space=pltpu.SMEM)
    key_spec = pl.BlockSpec((block,), lambda i: (i,))
    table_spec = pl.BlockSpec((buffer_buckets, bucket_size), lambda i: (0, 0))
    sel_spec = pl.BlockSpec((buffer_buckets, 1), lambda i: (0, 0))
    out = pl.pallas_call(
        functools.partial(_delete_adaptive_kernel, fp_bits=fp_bits),
        grid=grid,
        in_specs=[smem_spec, table_spec, sel_spec, table_spec, table_spec,
                  key_spec, key_spec, key_spec],
        out_specs=[table_spec, sel_spec, table_spec, table_spec,
                   pl.BlockSpec((block,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct(table.shape, jnp.uint32),
                   jax.ShapeDtypeStruct((buffer_buckets, 1), jnp.uint32),
                   jax.ShapeDtypeStruct(table.shape, jnp.uint32),
                   jax.ShapeDtypeStruct(table.shape, jnp.uint32),
                   jax.ShapeDtypeStruct((n,), jnp.bool_)],
        input_output_aliases={1: 0, 2: 1, 3: 2, 4: 3},
        interpret=interpret,
    )(n_arr, table, sels, khi_t, klo_t, hi, lo, valid)
    return out


_DELETE_STATICS = ("fp_bits", "block", "interpret", "emulate")
_delete_bulk_jit = jax.jit(_delete_bulk_impl, static_argnames=_DELETE_STATICS)
_delete_bulk_donated = jax.jit(_delete_bulk_impl,
                               static_argnames=_DELETE_STATICS,
                               donate_argnames=("table",))


def delete_bulk(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                fp_bits: int, n_buckets=None, valid=None,
                block: int = DEFAULT_BLOCK, interpret: bool = True,
                emulate: bool = False, donate: bool = False
                ) -> tuple[jax.Array, jax.Array]:
    """Fused bulk delete -> (new_table, deleted bool[N]).

    N must be a block multiple (ops.py pads).  ``n_buckets`` is the ACTIVE
    bucket count (may be < ``table.shape[0]`` for the OCF's pow2 buffer).
    Lanes with ``valid=False`` never touch the table.  Callers are expected
    to have verified membership against the keystore (the OCF control plane
    does) — like every cuckoo delete, clearing a fingerprint that was never
    inserted corrupts another key's slot.

    ``emulate`` runs the identical grid as a compiled XLA scan (the off-TPU
    fast path); ``donate`` hands the table buffer to the call so the
    cleared table is written in place (callers must own the buffer — the
    OCF control plane does).  Deletes are never wave-scheduled: duplicate
    keys must clear the k-th resident copy in lane order.
    """
    fn = _delete_bulk_donated if donate else _delete_bulk_jit
    return fn(table, hi, lo, fp_bits=fp_bits, n_buckets=n_buckets,
              valid=valid, block=block, interpret=interpret, emulate=emulate)


_delete_adaptive_jit = jax.jit(_delete_adaptive_impl,
                               static_argnames=_DELETE_STATICS)
_delete_adaptive_donated = jax.jit(
    _delete_adaptive_impl, static_argnames=_DELETE_STATICS,
    donate_argnames=("table", "sels", "khi_t", "klo_t"))


def delete_bulk_adaptive(table, sels, khi_t, klo_t, hi, lo, *, fp_bits: int,
                         n_buckets=None, valid=None,
                         block: int = DEFAULT_BLOCK, interpret: bool = True,
                         emulate: bool = False, donate: bool = False):
    """Selector-aware bulk delete -> (table, sels, khi, klo, deleted).

    Same contract as ``delete_bulk``; a slot matches under ITS selector
    (so an adapted resident is still deletable by its key), and clearing
    zeroes the selector and mirror-key planes along with the fingerprint.
    Overflow-stash entries hold selector-0 fingerprints — callers compose
    ``kernels.stash.stash_delete_ref`` for lanes that miss the table,
    exactly like the static path.
    """
    fn = _delete_adaptive_donated if donate else _delete_adaptive_jit
    return fn(table, sels, khi_t, klo_t, hi, lo, fp_bits=fp_bits,
              n_buckets=n_buckets, valid=valid, block=block,
              interpret=interpret, emulate=emulate)
