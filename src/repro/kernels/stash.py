"""Device-resident overflow stash — shared math for the filter kernels.

The stash is the burst-tolerance escape hatch for the insert hot path: when a
lane's bounded eviction chain exhausts its round budget, the insert kernel
spills the lane's *carried* fingerprint into a small fixed-size stash instead
of rolling the whole chain back and failing (the Kirsch–Mitzenmacher–Wieder
constant-size-stash result for cuckoo hashing, and the same overflow-absorbing
role the adaptive-cuckoo-filter literature gives its cellar).  The probe
kernel checks the stash in the same fused pass, so a stashed key is
indistinguishable from a resident one to every consumer.

Layout: ``uint32[2, STASH_SLOTS]`` —

  * row 0: fingerprints (0 == EMPTY; real fingerprints are never 0, the hash
    remaps them to 1);
  * row 1: the bucket the entry was bound for when it was stashed.

Because the alternate index is an involution (``alt(alt(b, fp), fp) == b``),
whichever bucket of the pair a chain happened to hold at exhaustion
identifies the pair: a probe matches a stash entry when the fingerprints
agree AND the stored bucket is either of the probe's two candidate buckets.
That makes the stash insensitive to *which* victim of a chain got spilled.
Deletes clear stash entries through the same identity (``stash_delete``), so
a spilled key is deletable exactly like a resident one — required by the
distributed write path, where a shard's verified deletes must reach keys
that parked in its stash during a burst.

Everything here is pure jnp on purpose: the same three functions run inside
the Pallas kernels (``kernels/insert.py`` / ``kernels/probe.py``), on the
jnp dispatch arm (``kernels/ops.py``), and as the test reference — one
definition, zero parity surface.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core.scheduling import pair_rank

# Default stash capacity.  The stash absorbs chain-budget overflows, whose
# count at a fixed load is O(batch residue), not O(table) — 128 slots rides
# out the 0.9-load eviction storms the tests throw while costing 1 KB of
# VMEM.  Streaming callers size it per generation (streaming/stash.py).
DEFAULT_STASH_SLOTS = 128


def make_stash(slots: int = DEFAULT_STASH_SLOTS) -> jax.Array:
    """Fresh empty stash: uint32[2, slots] of zeros."""
    assert slots > 0, "a stash needs at least one slot"
    return jnp.zeros((2, slots), dtype=jnp.uint32)


def stash_occupancy(stash: jax.Array) -> jax.Array:
    """Live entry count -> int32[] (device scalar)."""
    return jnp.sum(stash[0] != 0, dtype=jnp.int32)


def stash_match(stash: jax.Array, fp: jax.Array, i1: jax.Array,
                i2: jax.Array) -> jax.Array:
    """Membership of (fp, {i1, i2}) batches against the stash -> bool[N].

    One ``[N, STASH_SLOTS]`` broadcast-compare on the VPU — the stash-side
    counterpart of the probe kernel's bucket compare.  Empty slots hold
    fp == 0, which no real fingerprint equals, so they never match.
    """
    s_fp = stash[0][None, :]
    s_bkt = stash[1][None, :]
    i1 = i1.astype(jnp.uint32)[:, None]
    i2 = i2.astype(jnp.uint32)[:, None]
    hit = (s_fp == fp[:, None]) & ((s_bkt == i1) | (s_bkt == i2))
    return jnp.any(hit, axis=1)


def stash_spill(stash: jax.Array, carried: jax.Array, bucket: jax.Array,
                want: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Spill ``want`` lanes' (carried fp, bucket) into free stash slots.

    Lanes are ranked in lane order (earlier lane wins — the same discipline
    as the placement rounds) and lane i takes the rank-th empty slot; lanes
    whose rank exceeds the free-slot count miss and must fall back to the
    caller's failure path (rollback, in the insert kernel).  Returns
    (new_stash, spilled bool[N]).
    """
    s_fp, s_bkt = stash[0], stash[1]
    slots = s_fp.shape[0]
    empty = s_fp == 0
    n_free = jnp.sum(empty, dtype=jnp.int32)
    rank = jnp.cumsum(want.astype(jnp.int32)) - 1
    fits = want & (rank < n_free)
    empty_pos = jnp.cumsum(empty.astype(jnp.int32)) - 1
    is_dest = empty[None, :] & (empty_pos[None, :] == rank[:, None])
    slot = jnp.argmax(is_dest, axis=1)
    upd = jnp.where(fits, slot, slots)                    # OOB -> dropped
    s_fp = s_fp.at[upd].set(carried.astype(jnp.uint32), mode="drop")
    s_bkt = s_bkt.at[upd].set(bucket.astype(jnp.uint32), mode="drop")
    return jnp.concatenate([s_fp[None, :], s_bkt[None, :]], axis=0), fits


def stash_delete(stash: jax.Array, fp: jax.Array, i1: jax.Array,
                 i2: jax.Array, want: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """Clear ``want`` lanes' matching stash entries -> (new_stash, cleared).

    The stash-side counterpart of the delete kernel's first-match-slot
    clear: lane i matches slots whose fingerprint equals ``fp[i]`` and whose
    stored bucket is either candidate (the involution identity, same as
    ``stash_match``), is ranked among earlier want-lanes carrying the same
    (home bucket, fingerprint) pair — the delete kernel's duplicate
    discipline, computed sort-based (``pair_rank``) since this pass runs
    outside the kernels — and clears the rank-th matching slot.  Lanes whose
    rank exceeds the match count report False.  Cleared slots zero both rows
    so they are indistinguishable from never-used ones (spills refill them
    first, in slot order).

    Without this, a key that spilled to the stash could never be deleted:
    its fingerprint would answer lookups forever — a permanent false
    positive the verified-delete contract does not allow.
    """
    s_fp, s_bkt = stash[0], stash[1]
    slots = s_fp.shape[0]
    i1u = i1.astype(jnp.uint32)[:, None]
    i2u = i2.astype(jnp.uint32)[:, None]
    match = (s_fp[None, :] == fp[:, None]) & (
        (s_bkt[None, :] == i1u) | (s_bkt[None, :] == i2u))      # [N, S]
    rank = pair_rank(i1.astype(jnp.int32), fp.astype(jnp.int32), want)
    cleared = want & (rank < jnp.sum(match, axis=1).astype(jnp.int32))
    match_pos = jnp.cumsum(match.astype(jnp.int32), axis=1) - 1
    is_dest = match & (match_pos == rank[:, None])
    slot = jnp.argmax(is_dest, axis=1)
    upd = jnp.where(cleared, slot, slots)                 # OOB -> dropped
    s_fp = s_fp.at[upd].set(jnp.uint32(0), mode="drop")
    s_bkt = s_bkt.at[upd].set(jnp.uint32(0), mode="drop")
    return jnp.concatenate([s_fp[None, :], s_bkt[None, :]], axis=0), cleared


def stash_delete_ref(stash: jax.Array, hi: jax.Array, lo: jax.Array,
                     want: jax.Array, *, fp_bits: int, n_buckets
                     ) -> tuple[jax.Array, jax.Array]:
    """Hash a key batch and clear its stash entries (the whole-key arm
    ``ops.filter_delete`` composes after the table pass)."""
    fp = hashing.fingerprint(hi, lo, fp_bits)
    i1 = hashing.index_hash_dyn(hi, lo, n_buckets)
    i2 = hashing.alt_index_dyn(i1, fp, n_buckets)
    return stash_delete(stash, fp, i1, i2, want)


def stash_probe_ref(stash: jax.Array, hi: jax.Array, lo: jax.Array, *,
                    fp_bits: int, n_buckets) -> jax.Array:
    """Hash a key batch and match it against the stash (jnp reference arm)."""
    fp = hashing.fingerprint(hi, lo, fp_bits)
    i1 = hashing.index_hash_dyn(hi, lo, n_buckets)
    i2 = hashing.alt_index_dyn(i1, fp, n_buckets)
    return stash_match(stash, fp, i1, i2)


def stash_spill_ref(stash: jax.Array, hi: jax.Array, lo: jax.Array,
                    want: jax.Array, *, fp_bits: int, n_buckets
                    ) -> tuple[jax.Array, jax.Array]:
    """Spill whole keys (fp bound for the alternate bucket) — jnp arm.

    The scan fallback rolls an exhausted chain back, so the key itself (not
    a mid-chain victim) is what overflows; it is stashed against its
    alternate bucket, which is where the sequential chain starts.  The two
    dispatch arms therefore agree on *which lanes succeed* and on
    membership, though not necessarily on which fingerprint of a contended
    chain physically sits in the stash (same caveat as the multi-lane
    eviction schedule itself).
    """
    fp = hashing.fingerprint(hi, lo, fp_bits)
    i1 = hashing.index_hash_dyn(hi, lo, n_buckets)
    i2 = hashing.alt_index_dyn(i1, fp, n_buckets)
    return stash_spill(stash, fp, i2, want)
