"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references the kernel tests ``assert_allclose``
against (shape/dtype sweeps in tests/test_kernels_*.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import filter as jfilter
from repro.core import hashing


# ----------------------------------------------------------- fingerprint ---


def fingerprint_ref(hi: jax.Array, lo: jax.Array, *, fp_bits: int,
                    n_buckets: int):
    """(fp, i1, i2) for a batch of keys — mirrors core.hashing exactly."""
    fp = hashing.fingerprint(hi, lo, fp_bits)
    i1 = hashing.index_hash(hi, lo, n_buckets)
    i2 = hashing.alt_index(i1, fp, n_buckets)
    return fp, i1, i2


# ------------------------------------------------------------------ probe --


def probe_ref(table: jax.Array, hi: jax.Array, lo: jax.Array, *, fp_bits: int,
              n_buckets=None) -> jax.Array:
    """Bulk membership: bool[N].

    ``n_buckets``: ACTIVE bucket count (int or traced scalar); defaults to
    the full table (buffer == active)."""
    if n_buckets is None:
        n_buckets = table.shape[0]
    fp = hashing.fingerprint(hi, lo, fp_bits)
    i1 = hashing.index_hash_dyn(hi, lo, n_buckets)
    i2 = hashing.alt_index_dyn(i1, fp, n_buckets)
    hit1 = jnp.any(table[i1] == fp[:, None], axis=-1)
    hit2 = jnp.any(table[i2] == fp[:, None], axis=-1)
    return hit1 | hit2


# ------------------------------------------------------------------ insert --


def insert_once_ref(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                    fp_bits: int, n_buckets=None, valid=None
                    ) -> tuple[jax.Array, jax.Array]:
    """Optimistic single-round insert on a raw table -> (table, placed).

    Delegates to ``core.filter.parallel_insert_once`` so the oracle and the
    host fast path are literally the same code."""
    if n_buckets is None:
        n_buckets = table.shape[0]
    state = jfilter.FilterState(table, jnp.zeros((), jnp.int32),
                                jnp.asarray(n_buckets, jnp.int32))
    state, placed = jfilter.parallel_insert_once(state, hi, lo,
                                                 fp_bits=fp_bits, valid=valid)
    return state.table, placed


def insert_residue_ref(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                       fp_bits: int, n_buckets=None, valid=None,
                       max_disp: int = 500) -> tuple[jax.Array, jax.Array]:
    """Sequential eviction-chain sweep on a raw table -> (table, placed).

    The scan counterpart of the kernel's bounded eviction rounds — used by
    ``ops.filter_insert`` when ``evict_rounds>0`` resolves to the non-kernel
    path, so both dispatch arms finish the whole insert themselves."""
    if n_buckets is None:
        n_buckets = table.shape[0]
    state = jfilter.FilterState(table, jnp.zeros((), jnp.int32),
                                jnp.asarray(n_buckets, jnp.int32))
    state, ok = jfilter.bulk_insert(state, hi, lo, fp_bits=fp_bits,
                                    max_disp=max_disp, valid=valid)
    return state.table, ok


# ------------------------------------------------------------------ delete --


def delete_ref(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
               fp_bits: int, n_buckets=None, valid=None
               ) -> tuple[jax.Array, jax.Array]:
    """Sequential-semantics bulk delete on a raw table -> (table, deleted).

    Delegates to ``core.filter.bulk_delete`` so the oracle and the host
    fallback are literally the same code (mirrors ``insert_once_ref``)."""
    if n_buckets is None:
        n_buckets = table.shape[0]
    state = jfilter.FilterState(table, jnp.zeros((), jnp.int32),
                                jnp.asarray(n_buckets, jnp.int32))
    state, ok = jfilter.bulk_delete(state, hi, lo, fp_bits=fp_bits,
                                    valid=valid)
    return state.table, ok


# -------------------------------------------------------- flash attention --


def blockwise_attention(q, k, v, *, causal=True, window=None,
                        logit_softcap=None, scale=None, qpos_start=None,
                        valid_len=None, key_positions=None,
                        q_chunk: int = 512):
    """Memory-bounded attention: scan over q chunks, never materialize SxS.

    The XLA analogue of the flash kernel's schedule (the Pallas kernel is the
    TPU fast path; this is what the dry-run compiles).  Peak intermediate is
    [B, H, q_chunk, Skv] instead of [B, H, Sq, Skv] — the difference between
    prefill_32k fitting in HBM (67 MB/chunk/head) and needing 17 GB/device.

    q: [B,Hq,Sq,Dk]; k: [B,Hkv,Skv,Dk]; v: [B,Hkv,Skv,Dv].  GQA via
    q-head h -> kv-head h // group.  ``qpos_start``: traced offset of q
    position 0 (decode); default right-aligned (Skv - Sq).  ``valid_len``:
    number of valid cache entries (traced) — keys beyond it are masked.
    """
    b, hq, sq, dk = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = hq // hkv
    scale = scale if scale is not None else dk ** -0.5
    if qpos_start is None:
        qpos_start = skv - sq
    slot = jnp.arange(skv)
    kpos = slot if key_positions is None else key_positions  # absolute pos
    kvalid = kpos >= 0
    if valid_len is not None:
        kvalid &= slot < valid_len

    qg = q.reshape(b, hkv, group, sq, dk).astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def one_chunk(qc, qpos):
        # qc: [b,hkv,group,C,dk]; qpos: [C]
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kf)
        if logit_softcap is not None:
            logits = logit_softcap * jnp.tanh(logits / logit_softcap)
        mask = kvalid[None, :]
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window is not None:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)

    c = min(q_chunk, sq)
    if sq % c != 0 or sq == c:
        out = one_chunk(qg, qpos_start + jnp.arange(sq))
    else:
        nc = sq // c
        qcs = qg.reshape(b, hkv, group, nc, c, dk).transpose(3, 0, 1, 2, 4, 5)
        qpos = qpos_start + jnp.arange(sq).reshape(nc, c)
        outs = jax.lax.map(lambda t: one_chunk(*t), (qcs, qpos))
        out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, group, sq, dv)
    return out.reshape(b, hq, sq, dv).astype(q.dtype)


def local_attention(q, k, v, *, window: int, logit_softcap=None, scale=None):
    """Sliding-window attention in O(S·W): chunk into window-sized tiles,
    each q tile attends (self, previous) tiles only.

    Requires Sq == Skv and Sq % window == 0 (callers fall back otherwise).
    This is the XLA counterpart of the flash kernel's block-skip: compiled
    FLOPs/bytes drop from O(S²) to O(S·2W) — the honest roofline for
    gemma2/gemma3/recurrentgemma local layers.
    """
    b, hq, s, dk = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    group = hq // hkv
    w = window
    nc = s // w
    scale = scale if scale is not None else dk ** -0.5
    qg = (q.reshape(b, hkv, group, nc, w, dk).astype(jnp.float32) * scale)
    kf = k.reshape(b, hkv, nc, w, dk).astype(jnp.float32)
    vf = v.reshape(b, hkv, nc, w, dv).astype(jnp.float32)
    # previous tile (zeros before the first)
    kprev = jnp.concatenate([jnp.zeros_like(kf[:, :, :1]), kf[:, :, :-1]], 2)
    vprev = jnp.concatenate([jnp.zeros_like(vf[:, :, :1]), vf[:, :, :-1]], 2)
    k2 = jnp.concatenate([kprev, kf], axis=3)        # [b,hkv,nc,2w,dk]
    v2 = jnp.concatenate([vprev, vf], axis=3)
    logits = jnp.einsum("bhgcqd,bhckd->bhgcqk", qg, k2)  # [b,hkv,g,nc,w,2w]
    if logit_softcap is not None:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    qpos = jnp.arange(w)[:, None]
    kpos = jnp.arange(2 * w)[None, :] - w
    first = jnp.arange(nc) == 0                       # [nc]
    base = (kpos <= qpos) & (kpos > qpos - w)         # causal ∩ window
    inbounds = kpos >= 0
    mask = base & (inbounds | ~first[:, None, None])  # [nc,w,2w]
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgcqk,bhckd->bhgcqd", probs, v2)
    return out.reshape(b, hq, s, dv).astype(q.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  logit_softcap: float | None = None,
                  scale: float | None = None) -> jax.Array:
    """Reference multi-head attention with GQA, sliding window and softcap.

    q: [B, Hq, Sq, D]; k, v: [B, Hkv, Skv, D]; Hq % Hkv == 0.
    ``window``: sliding-window size w — query i attends keys in (i-w, i].
    Query positions are right-aligned to key positions (decode friendly:
    q position = Skv - Sq + arange(Sq)).
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kf = jnp.repeat(kf, group, axis=1)
    vf = jnp.repeat(vf, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    if logit_softcap is not None:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    qpos = skv - sq + jnp.arange(sq)
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)
