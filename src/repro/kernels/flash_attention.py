"""Pallas TPU kernel: flash attention (online softmax) for the LM stack.

Covers every attention variant the assigned architectures need:
GQA (q-head → kv-head mapping in the index_map, no materialized repeat),
causal masking, sliding-window (gemma2/gemma3/recurrentgemma local layers)
and logit soft-capping (gemma2).

Grid: (batch·q_heads, Sq/BQ, Skv/BK) — the kv dimension is the innermost,
sequentially-iterated axis; running max/denominator/accumulator live in VMEM
scratch across kv steps (the canonical TPU flash schedule: the MXU consumes
[BQ, D]×[D, BK] tiles while the VPU maintains the online softmax).
Fully-masked kv blocks are skipped via the grid bounds (causal/window
block-level early-out), which is where the memory-term win over naive
attention comes from.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int | None,
                  logit_softcap: float | None, bq: int, bk: int,
                  sq: int, skv: int):
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)
    n_kv = pl.num_programs(2)

    @pl.when(kv_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Positions: queries right-aligned to keys (decode-friendly).
    qpos = skv - sq + q_idx * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window

    block_live = jnp.any(mask)

    @pl.when(block_live)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # [bq, d]
        k = k_ref[0].astype(jnp.float32)                  # [bk, d]
        v = v_ref[0].astype(jnp.float32)                  # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [bq, bk]
        if logit_softcap is not None:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                               # [bq]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(kv_idx == n_kv - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, ...] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
                         ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "logit_softcap", "scale", "bq", "bk",
                     "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    logit_softcap: float | None = None,
                    scale: float | None = None, bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: [B, Hq, Sq, D]; k, v: [B, Hkv, Skv, D] -> [B, Hq, Sq, D].

    GQA is handled by the kv index_map (q head h reads kv head h // group);
    no repeat is materialized in HBM.
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    group = hq // hkv
    bq_ = min(bq, sq)
    bk_ = min(bk, skv)
    assert sq % bq_ == 0 and skv % bk_ == 0
    scale_ = scale if scale is not None else d ** -0.5

    qr = q.reshape(b * hq, sq, d)
    kr = k.reshape(b * hkv, skv, d)
    vr = v.reshape(b * hkv, skv, d)

    def kv_map(h, i, j):
        # flat q index h = batch * hq + qhead  ->  batch * hkv + qhead//group
        return ((h // hq) * hkv + (h % hq) // group, j, 0)

    grid = (b * hq, sq // bq_, skv // bk_)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale_, causal=causal,
                          window=window, logit_softcap=logit_softcap,
                          bq=bq_, bk=bk_, sq=sq, skv=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk_, d), kv_map),
            pl.BlockSpec((1, bk_, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, d), jnp.float32),   # acc
            pltpu.VMEM((bq_,), jnp.float32),     # running max m
            pltpu.VMEM((bq_,), jnp.float32),     # running denom l
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, d)
