"""Pallas TPU kernel: fused hash + optimistic single-round bulk insert.

The device-side analogue of ``core.filter.parallel_insert_once`` — one
fully-vectorized placement round (home bucket, then alternate bucket) with
**no eviction chains**: the ~95% uncontended mass of a batch lands in one
kernel pass; the contended residue falls back to the lax.scan eviction path
(see ``core.filter_ops.FilterOps.insert``).

Schedule:
  * the table (the OCF's pow2 buffer) is block-resident in VMEM and aliased
    input→output, so grid steps accumulate placements — TPU grids execute
    sequentially, which makes block b's inserts visible to block b+1;
  * the ACTIVE bucket count is a ``(1, 1)`` SMEM scalar (dynamic-capacity
    filter: resizes change no shapes);
  * keys are tiled ``(BLOCK,)``; intra-block conflicts are resolved with a
    sort-free rank (a [BLOCK, BLOCK] broadcast-compare on the VPU — no
    device sort needed, unlike the host path's stable argsort; both compute
    the identical "number of earlier lanes targeting my bucket" rank, so a
    single-block batch reproduces ``parallel_insert_once`` table-for-table);
  * each fitting lane writes one empty slot of its bucket: rank-th empty
    slot, so distinct lanes of a bucket never collide.

Hash math is imported from ``repro.core.hashing`` — one spec for kernels,
host data plane, and the numpy oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hashing

DEFAULT_BLOCK = 1024


def _place_round(table, target, active, fp):
    """One placement attempt for every active lane into ``target`` buckets.

    Returns (table, placed).  Same math as the host optimistic round, with
    the stable-argsort rank replaced by a broadcast-compare count (identical
    result: rank = #earlier active lanes targeting the same bucket).
    """
    buf, _bucket_size = table.shape
    n = target.shape[0]
    li = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)   # lane i (rows)
    lj = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)   # lane j (cols)
    same = (target[:, None] == target[None, :]) & active[None, :] & (lj < li)
    rank = jnp.sum(same, axis=1).astype(jnp.int32)
    tgt_c = jnp.clip(target, 0, buf - 1)
    free = jnp.sum(table == 0, axis=1).astype(jnp.int32)  # empties per bucket
    fits = active & (rank < free[tgt_c])
    row = table[tgt_c]                                    # [n, bucket_size]
    empty_pos = jnp.cumsum((row == 0).astype(jnp.int32), axis=1) - 1
    is_dest = (row == 0) & (empty_pos == rank[:, None])
    slot = jnp.argmax(is_dest, axis=1)
    upd_i = jnp.where(fits, target, buf)                  # OOB -> dropped
    table = table.at[upd_i, slot].set(fp, mode="drop")
    return table, fits


def _insert_kernel(n_ref, table_in_ref, hi_ref, lo_ref, valid_ref, table_ref,
                   ok_ref, *, fp_bits: int):
    del table_in_ref  # aliased to table_ref (the output) — read/write there
    n_buckets = n_ref[0, 0]
    table = table_ref[...]
    hi = hi_ref[...]
    lo = lo_ref[...]
    valid = valid_ref[...]
    fp = hashing.fingerprint(hi, lo, fp_bits)
    i1 = hashing.index_hash_dyn(hi, lo, n_buckets).astype(jnp.int32)
    i2 = hashing.alt_index_dyn(i1, fp, n_buckets).astype(jnp.int32)
    table, ok1 = _place_round(table, i1, valid, fp)
    table, ok2 = _place_round(table, i2, valid & ~ok1, fp)
    table_ref[...] = table
    ok_ref[...] = ok1 | ok2


@functools.partial(jax.jit, static_argnames=("fp_bits", "block", "interpret"))
def insert_once(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                fp_bits: int, n_buckets=None, valid=None,
                block: int = DEFAULT_BLOCK, interpret: bool = True
                ) -> tuple[jax.Array, jax.Array]:
    """One optimistic insert round -> (new_table, placed bool[N]).

    N must be a block multiple (ops.py pads).  ``n_buckets`` is the ACTIVE
    bucket count (may be < ``table.shape[0]`` for the OCF's pow2 buffer).
    Lanes with ``valid=False`` never touch the table.
    """
    n = hi.shape[0]
    block = min(block, n)
    assert n % block == 0, f"{n=} not a multiple of {block=}"
    buffer_buckets, bucket_size = table.shape
    if n_buckets is None:
        n_buckets = buffer_buckets
    if valid is None:
        valid = jnp.ones((n,), bool)
    n_arr = jnp.asarray(n_buckets, jnp.int32).reshape(1, 1)
    grid = (n // block,)
    smem_spec = pl.BlockSpec((1, 1), lambda i: (0, 0),
                             memory_space=pltpu.SMEM)
    key_spec = pl.BlockSpec((block,), lambda i: (i,))
    table_spec = pl.BlockSpec((buffer_buckets, bucket_size), lambda i: (0, 0))
    new_table, ok = pl.pallas_call(
        functools.partial(_insert_kernel, fp_bits=fp_bits),
        grid=grid,
        in_specs=[smem_spec, table_spec, key_spec, key_spec, key_spec],
        out_specs=[table_spec, pl.BlockSpec((block,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct(table.shape, table.dtype),
                   jax.ShapeDtypeStruct((n,), jnp.bool_)],
        input_output_aliases={1: 0},   # table updates in place across steps
        interpret=interpret,
    )(n_arr, table, hi.astype(jnp.uint32), lo.astype(jnp.uint32), valid)
    return new_table, ok
