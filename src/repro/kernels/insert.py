"""Pallas TPU kernel: fused hash + bulk insert with bounded eviction rounds.

The device-side analogue of ``core.filter.bulk_insert_hybrid`` — and since
PR 3 the *whole* insert, not just the optimistic prefix.  One kernel pass
does:

  1. two fully-vectorized optimistic placement rounds (home bucket, then
     alternate bucket) — the ~95% uncontended mass of a batch lands here;
  2. up to ``evict_rounds`` **device-side eviction rounds** for the residue:
     each round re-attempts the carried fingerprint against empty slots of
     its current bucket, then performs one displacement per contended bucket
     (kick a victim, take its slot, chase the victim to its alternate
     bucket) — the bounded-multi-round optimistic schedule Cuckoo-GPU-style
     accelerator filters use instead of pointer-chasing chains;
  3. an optional **overflow-stash spill** (``kernels/stash.py``): lanes whose
     chain exhausts the budget park their carried fingerprint in a small
     device-resident stash instead of failing — every committed kick stays,
     the final victim lands in the stash, and the lane reports success.  The
     probe kernel checks the stash in the same fused pass, so the spill is
     invisible to lookups.  This is what cuts the worst-case insert latency
     at high load: the rollback + grow + rebuild cliff becomes an O(1) park;
  4. per-lane rollback for chains that did not finish inside the budget AND
     found no stash slot (or when no stash is attached), so a failed insert
     NEVER orphans a resident fingerprint (the same transactional guarantee
     as ``pyfilter.PyCuckooFilter.insert``).

Schedule:
  * the table (the OCF's pow2 buffer) is block-resident in VMEM and aliased
    input→output, so grid steps accumulate placements — TPU grids execute
    sequentially, which makes block b's inserts visible to block b+1;
  * the ACTIVE bucket count is a ``(1, 1)`` SMEM scalar (dynamic-capacity
    filter: resizes change no shapes);
  * keys are tiled ``(BLOCK,)``; intra-block conflicts are resolved with a
    sort-free rank (a [BLOCK, BLOCK] broadcast-compare on the VPU — no
    device sort needed, unlike the host path's stable argsort; both compute
    the identical "number of earlier lanes targeting my bucket" rank, so a
    single-block batch reproduces ``parallel_insert_once`` table-for-table);
  * each fitting lane writes one empty slot of its bucket: rank-th empty
    slot, so distinct lanes of a bucket never collide;
  * the eviction loop is a ``lax.while_loop`` that exits as soon as every
    lane has landed — an uncontended batch pays zero eviction rounds.

Eviction-round invariants (why rollback is conflict-free):
  * one kick per bucket per round (rank-0 lane wins; later lanes retry next
    round), so two lanes never kick the same slot in the same round;
  * a kicked slot is marked **dirty** and never kicked again this
    invocation, so across rounds every table slot is written by at most one
    lane — rollback scatters of failed lanes touch only slots they own;
  * a lane's preferred kick slot rotates ``steps % bucket_size`` exactly
    like the sequential chain (``pyfilter`` / ``core.filter._insert_one``),
    so a single-lane residue walks the identical chain and produces the
    identical table while its chain stays within the round budget.

Hash math is imported from ``repro.core.hashing`` — one spec for kernels,
host data plane, and the numpy oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hashing
from repro.core.scheduling import dispatch_order
from repro.kernels.rank import rank_among_earlier
from repro.kernels.selector import sel_pack, sel_unpack
from repro.kernels.stash import stash_occupancy, stash_spill
from repro.kernels.telemetry import (empty_telemetry, kick_histogram,
                                     merge as tm_merge)

DEFAULT_BLOCK = 1024
# Bounded eviction budget.  The loop is a while_loop that exits as soon as
# every lane lands, so an easy batch pays zero rounds regardless of the
# bound; 32 rounds fully drains random batches at the OCF's o_max=0.85
# operating load.  Harder regimes need more budget (the 0.9-load parity
# test passes evict_rounds=64); lanes that exhaust it roll back and report
# False, which the OCF answers with a grow+rebuild.
DEFAULT_EVICT_ROUNDS = 32


def _place_round(table, target, active, fp):
    """One placement attempt for every active lane into ``target`` buckets.

    Returns (table, placed).  Same math as the host optimistic round, with
    the stable-argsort rank replaced by the broadcast-compare count
    (``kernels.rank`` — identical result).
    """
    buf, _bucket_size = table.shape
    rank = rank_among_earlier(target, active)
    tgt_c = jnp.clip(target, 0, buf - 1)
    free = jnp.sum(table == 0, axis=1).astype(jnp.int32)  # empties per bucket
    fits = active & (rank < free[tgt_c])
    row = table[tgt_c]                                    # [n, bucket_size]
    empty_pos = jnp.cumsum((row == 0).astype(jnp.int32), axis=1) - 1
    is_dest = (row == 0) & (empty_pos == rank[:, None])
    slot = jnp.argmax(is_dest, axis=1)
    upd_i = jnp.where(fits, target, buf)                  # OOB -> dropped
    table = table.at[upd_i, slot].set(fp, mode="drop")
    return table, fits


def _evict_rounds(table, fp, start_bucket, residue, n_buckets, rounds: int,
                  stash=None, want_stats: bool = False):
    """Bounded device-side eviction rounds for the contended residue.

    Each residual lane carries a fingerprint (initially its own; after a
    kick, the victim's) and a current bucket.  Per round:

      phase A — try to place the carried fp into an empty slot of the
                current bucket (rank-resolved, same as the optimistic round);
      phase B — lanes still carrying kick: the rank-0 lane per bucket swaps
                its carried fp into the first non-dirty slot (rotating from
                ``steps % bucket_size``), takes the victim, and chases it to
                the victim's alternate bucket.

    Lanes still carrying after ``rounds`` first try to **spill** their
    carried fingerprint into the overflow stash (when one is attached): the
    chain's kicks all stay committed, only the final victim parks in the
    stash, and the lane completes.  The carried lane's current bucket is
    always one of the carried fingerprint's two candidate buckets (chains
    move via the alternate-index involution), which is exactly the identity
    ``stash_match`` probes against.  Lanes that miss the stash too (or when
    ``stash is None``) roll their kicks back in reverse — restoring every
    victim to its original slot — and report failure.
    Returns (table, completed bool[N]) or (table, stash, completed).
    """
    buf, bucket_size = table.shape
    n = fp.shape[0]
    slot_iota = jax.lax.broadcasted_iota(jnp.int32, (n, bucket_size), 1)

    def round_body(carry):
        (r, table, dirty, carried, bucket, active, steps, hb, hs, hw) = carry
        # phase A: carried fp into an empty slot of the current bucket.
        table, placed = _place_round(table, bucket, active, carried)
        active = active & ~placed

        # A completed lane will never roll back, so its kicked slots no
        # longer need rollback protection — release them for later kicks
        # (without this, long chains starve on fully-dirty hot buckets).
        def release(t, dirty):
            has = placed & (t < steps)
            upd_i = jnp.where(has, hb[:, t], buf)
            return dirty.at[upd_i, hs[:, t]].set(False, mode="drop")

        dirty = jax.lax.cond(
            jnp.any(placed & (steps > 0)),
            lambda d: jax.lax.fori_loop(0, r + 1, release, d),
            lambda d: d, dirty)
        # phase B: one kick per bucket — earliest active lane wins the round.
        first = active & (rank_among_earlier(bucket, active) == 0)
        b_c = jnp.clip(bucket, 0, buf - 1)
        # First non-dirty slot, rotating from the sequential chain's
        # preferred slot (steps % bucket_size) — dirty slots hold another
        # lane's kick and are off-limits (rollback exclusivity).
        pos = (slot_iota + (steps % bucket_size)[:, None]) % bucket_size
        cand_free = ~jnp.take_along_axis(dirty[b_c], pos, axis=1)
        kick = first & jnp.any(cand_free, axis=1)
        k = jnp.argmax(cand_free, axis=1)
        slot = jnp.take_along_axis(pos, k[:, None], axis=1)[:, 0]
        victim = table[b_c, slot]
        upd_i = jnp.where(kick, bucket, buf)              # OOB -> dropped
        table = table.at[upd_i, slot].set(carried, mode="drop")
        dirty = dirty.at[upd_i, slot].set(True, mode="drop")
        # Per-lane chain history (bucket, slot, written value) at column
        # ``steps`` — what rollback needs to unwind a failed chain.
        onehot = (jax.lax.broadcasted_iota(jnp.int32, (n, rounds), 1)
                  == steps[:, None]) & kick[:, None]
        hb = jnp.where(onehot, bucket[:, None], hb)
        hs = jnp.where(onehot, slot[:, None], hs)
        hw = jnp.where(onehot, carried[:, None], hw)
        nxt = hashing.alt_index_dyn(b_c, victim, n_buckets).astype(jnp.int32)
        carried = jnp.where(kick, victim, carried)
        bucket = jnp.where(kick, nxt, bucket)
        steps = steps + kick.astype(jnp.int32)
        return (r + 1, table, dirty, carried, bucket, active, steps, hb, hs,
                hw)

    def round_cond(carry):
        r, _t, _d, _c, _b, active, *_ = carry
        return (r < rounds) & jnp.any(active)

    init = (jnp.int32(0), table, jnp.zeros(table.shape, jnp.bool_),
            fp, start_bucket, residue, jnp.zeros((n,), jnp.int32),
            jnp.zeros((n, rounds), jnp.int32),
            jnp.zeros((n, rounds), jnp.int32),
            jnp.zeros((n, rounds), jnp.uint32))
    (_r, table, _dirty, carried, bucket, active, steps, hb, hs,
     hw) = jax.lax.while_loop(round_cond, round_body, init)

    # Spill: exhausted lanes park their carried fp in the stash (chain kicks
    # stay committed — only the final victim moves off-table), completing
    # without rollback.  Lane order decides who wins the last free slots.
    if stash is not None:
        stash, spilled = stash_spill(stash, carried, bucket, active)
        active = active & ~spilled
    elif want_stats:
        spilled = jnp.zeros_like(active)

    # Rollback: lanes still carrying restore their kicks newest-first; the
    # dirty discipline above makes every restored slot exclusively theirs.
    failed = active

    def rb_body(k, carry):
        table, cur = carry
        t = steps - 1 - k
        do = failed & (t >= 0)
        t_c = jnp.clip(t, 0, rounds - 1)[:, None]
        b = jnp.take_along_axis(hb, t_c, axis=1)[:, 0]
        s = jnp.take_along_axis(hs, t_c, axis=1)[:, 0]
        w = jnp.take_along_axis(hw, t_c, axis=1)[:, 0]
        upd_i = jnp.where(do, b, buf)
        table = table.at[upd_i, s].set(cur, mode="drop")
        cur = jnp.where(do, w, cur)
        return table, cur

    table, _cur = jax.lax.cond(
        jnp.any(failed),
        lambda tc: jax.lax.fori_loop(0, rounds, rb_body, tc),
        lambda tc: tc, (table, carried))
    # Telemetry-twin extras: per-lane chain length + spill/rollback masks
    # (the raw material the dispatch layer folds into FilterTelemetry).
    stats = (steps, spilled, failed) if want_stats else None
    if stash is not None:
        if want_stats:
            return table, stash, residue & ~failed, stats
        return table, stash, residue & ~failed
    if want_stats:
        return table, residue & ~failed, stats
    return table, residue & ~failed


def _insert_body(table, stash, hi, lo, valid, n_buckets, *, fp_bits: int,
                 evict_rounds: int, want_stats: bool = False):
    """Optimistic rounds + eviction rounds (+ stash spill) on loaded values.

    ``want_stats`` (trace-time bool) additionally returns a
    ``FilterTelemetry`` for the block: kick-depth histogram over every
    valid lane (optimistic placements count as depth 0), spill / rollback
    lane counts, and the stash occupancy high-water after this block.  The
    default-False trace is byte-identical to a build without the flag.
    """
    n = hi.shape[0]
    fp = hashing.fingerprint(hi, lo, fp_bits)
    i1 = hashing.index_hash_dyn(hi, lo, n_buckets).astype(jnp.int32)
    i2 = hashing.alt_index_dyn(i1, fp, n_buckets).astype(jnp.int32)
    table, ok1 = _place_round(table, i1, valid, fp)
    table, ok2 = _place_round(table, i2, valid & ~ok1, fp)
    ok = ok1 | ok2
    steps = jnp.zeros((n,), jnp.int32)
    spilled = jnp.zeros((n,), jnp.bool_)
    failed = jnp.zeros((n,), jnp.bool_)
    if evict_rounds > 0:
        # Chains start at the alternate bucket, matching the sequential path.
        if stash is None:
            if want_stats:
                table, completed, (steps, spilled, failed) = _evict_rounds(
                    table, fp, i2, valid & ~ok, n_buckets, evict_rounds,
                    want_stats=True)
            else:
                table, completed = _evict_rounds(table, fp, i2, valid & ~ok,
                                                 n_buckets, evict_rounds)
        elif want_stats:
            table, stash, completed, (steps, spilled, failed) = _evict_rounds(
                table, fp, i2, valid & ~ok, n_buckets, evict_rounds,
                stash=stash, want_stats=True)
        else:
            table, stash, completed = _evict_rounds(
                table, fp, i2, valid & ~ok, n_buckets, evict_rounds,
                stash=stash)
        ok = ok | completed
    elif stash is not None:
        # No eviction budget at all: the optimistic residue spills straight
        # to the stash (bound for its alternate bucket, where a chain would
        # have started).
        stash, spilled0 = stash_spill(stash, fp, i2, valid & ~ok)
        ok = ok | spilled0
        spilled = spilled0
    if not want_stats:
        return table, stash, ok
    tm = empty_telemetry()._replace(
        kick_hist=kick_histogram(steps, valid),
        stash_spills=jnp.sum(spilled).astype(jnp.uint32),
        rollback_lanes=jnp.sum(failed).astype(jnp.uint32),
        stash_fill_hw=(stash_occupancy(stash).astype(jnp.uint32)
                       if stash is not None else jnp.zeros((), jnp.uint32)))
    return table, stash, ok, tm


def _insert_kernel(n_ref, table_in_ref, hi_ref, lo_ref, valid_ref, table_ref,
                   ok_ref, *, fp_bits: int, evict_rounds: int):
    del table_in_ref  # aliased to table_ref (the output) — read/write there
    table, _stash, ok = _insert_body(
        table_ref[...], None, hi_ref[...], lo_ref[...], valid_ref[...],
        n_ref[0, 0], fp_bits=fp_bits, evict_rounds=evict_rounds)
    table_ref[...] = table
    ok_ref[...] = ok


def _insert_stash_kernel(n_ref, table_in_ref, stash_in_ref, hi_ref, lo_ref,
                         valid_ref, table_ref, stash_ref, ok_ref, *,
                         fp_bits: int, evict_rounds: int):
    del table_in_ref, stash_in_ref  # aliased to the outputs — read/write there
    table, stash, ok = _insert_body(
        table_ref[...], stash_ref[...], hi_ref[...], lo_ref[...],
        valid_ref[...], n_ref[0, 0], fp_bits=fp_bits,
        evict_rounds=evict_rounds)
    table_ref[...] = table
    stash_ref[...] = stash
    ok_ref[...] = ok


def _emulated_insert(table, stash, hi, lo, valid, n_buckets, *,
                     fp_bits: int, evict_rounds: int, block: int,
                     want_stats: bool = False):
    """The kernel schedule compiled by XLA instead of the Pallas interpreter.

    Bit-for-bit the grid semantics of the ``pallas_call`` below: blocks run
    sequentially with the table (and stash) carried between them, exactly
    like the aliased in→out BlockSpecs on a sequential TPU grid — here as a
    ``lax.scan`` whose carry is the table.  Same ``_insert_body``, same
    results; this is what the off-TPU dispatch runs so the "pallas" backend
    is a *throughput* configuration on CPU hosts too, not just a
    correctness one (the interpreter re-dispatches every primitive per
    grid step, which is ~100x slower than the compiled scan).

    ``want_stats`` rides the per-block ``FilterTelemetry`` in the scan
    carry (fixed shape) and merges it across blocks — returns an extra tm.
    """
    g = hi.shape[0] // block
    if want_stats:
        if g == 1:
            return _insert_body(table, stash, hi, lo, valid, n_buckets,
                                fp_bits=fp_bits, evict_rounds=evict_rounds,
                                want_stats=True)
        xs = (hi.reshape(g, block), lo.reshape(g, block),
              valid.reshape(g, block))

        def step(carry, x):
            tbl, st, tm = carry
            tbl, st, ok, tm_b = _insert_body(
                tbl, st, *x, n_buckets, fp_bits=fp_bits,
                evict_rounds=evict_rounds, want_stats=True)
            return (tbl, st, tm_merge(tm, tm_b)), ok

        (table, stash, tm), ok = jax.lax.scan(
            step, (table, stash, empty_telemetry()), xs)
        return table, stash, ok.reshape(-1), tm
    if g == 1:
        table, stash, ok = _insert_body(table, stash, hi, lo, valid,
                                        n_buckets, fp_bits=fp_bits,
                                        evict_rounds=evict_rounds)
        return table, stash, ok
    xs = (hi.reshape(g, block), lo.reshape(g, block),
          valid.reshape(g, block))

    if stash is None:
        def step(tbl, x):
            tbl, _stash, ok = _insert_body(tbl, None, *x, n_buckets,
                                           fp_bits=fp_bits,
                                           evict_rounds=evict_rounds)
            return tbl, ok

        table, ok = jax.lax.scan(step, table, xs)
        return table, None, ok.reshape(-1)

    def step(carry, x):
        tbl, st = carry
        tbl, st, ok = _insert_body(tbl, st, *x, n_buckets, fp_bits=fp_bits,
                                   evict_rounds=evict_rounds)
        return (tbl, st), ok

    (table, stash), ok = jax.lax.scan(step, (table, stash), xs)
    return table, stash, ok.reshape(-1)


def _insert_bulk_impl(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                      fp_bits: int, n_buckets=None, valid=None,
                      evict_rounds: int = DEFAULT_EVICT_ROUNDS, stash=None,
                      block: int = DEFAULT_BLOCK, interpret: bool = True,
                      emulate: bool = False, schedule: bool = False,
                      telemetry: bool = False):
    n = hi.shape[0]
    block = min(block, n)
    assert n % block == 0, f"{n=} not a multiple of {block=}"
    buffer_buckets, bucket_size = table.shape
    if n_buckets is None:
        n_buckets = buffer_buckets
    if valid is None:
        valid = jnp.ones((n,), bool)
    hi = hi.astype(jnp.uint32)
    lo = lo.astype(jnp.uint32)
    # A single-block batch gains nothing from the pre-pass: the stable
    # permutation preserves same-bucket lane order, so with every lane in
    # one block the ranks and kick order are provably identical — skip the
    # two argsorts (n and block are trace-time python ints).
    schedule = schedule and n > block
    if schedule:
        # Conflict-aware pre-pass: dispatch wave-major (at most one lane
        # per home bucket per wave) so blocks meet fewer rank races and
        # eviction rounds; results scatter back through the inverse
        # permutation.  See core/scheduling.py for why this cannot change
        # any lane's placement rank.
        perm, inv = dispatch_order(hi, lo, valid, n_buckets=n_buckets)
        hi, lo, valid = hi[perm], lo[perm], valid[perm]
    if telemetry:
        # Telemetry twin: always the XLA-emulation arm (same bits as the
        # kernel by the PR-5 parity contract; on TPU this trades the
        # pallas_call for a compiled scan — a perf configuration, never a
        # correctness one).  The per-lane stats are permutation-invariant
        # sums/histograms, so the schedule pre-pass needs no inverse
        # scatter on the telemetry, only on ``ok``.
        new_table, new_stash, ok, tm = _emulated_insert(
            table, stash, hi, lo, valid, n_buckets, fp_bits=fp_bits,
            evict_rounds=evict_rounds, block=block, want_stats=True)
        if schedule:
            ok = ok[inv]
        if stash is None:
            return new_table, ok, tm
        return new_table, new_stash, ok, tm
    if emulate:
        new_table, new_stash, ok = _emulated_insert(
            table, stash, hi, lo, valid, n_buckets, fp_bits=fp_bits,
            evict_rounds=evict_rounds, block=block)
        if schedule:
            ok = ok[inv]
        if stash is None:
            return new_table, ok
        return new_table, new_stash, ok
    n_arr = jnp.asarray(n_buckets, jnp.int32).reshape(1, 1)
    grid = (n // block,)
    smem_spec = pl.BlockSpec((1, 1), lambda i: (0, 0),
                             memory_space=pltpu.SMEM)
    key_spec = pl.BlockSpec((block,), lambda i: (i,))
    table_spec = pl.BlockSpec((buffer_buckets, bucket_size), lambda i: (0, 0))
    ok_spec = pl.BlockSpec((block,), lambda i: (i,))
    if stash is None:
        new_table, ok = pl.pallas_call(
            functools.partial(_insert_kernel, fp_bits=fp_bits,
                              evict_rounds=evict_rounds),
            grid=grid,
            in_specs=[smem_spec, table_spec, key_spec, key_spec, key_spec],
            out_specs=[table_spec, ok_spec],
            out_shape=[jax.ShapeDtypeStruct(table.shape, table.dtype),
                       jax.ShapeDtypeStruct((n,), jnp.bool_)],
            input_output_aliases={1: 0},  # table updates in place across steps
            interpret=interpret,
        )(n_arr, table, hi, lo, valid)
        return new_table, ok[inv] if schedule else ok
    stash_spec = pl.BlockSpec(stash.shape, lambda i: (0, 0))
    new_table, new_stash, ok = pl.pallas_call(
        functools.partial(_insert_stash_kernel, fp_bits=fp_bits,
                          evict_rounds=evict_rounds),
        grid=grid,
        in_specs=[smem_spec, table_spec, stash_spec, key_spec, key_spec,
                  key_spec],
        out_specs=[table_spec, stash_spec, ok_spec],
        out_shape=[jax.ShapeDtypeStruct(table.shape, table.dtype),
                   jax.ShapeDtypeStruct(stash.shape, stash.dtype),
                   jax.ShapeDtypeStruct((n,), jnp.bool_)],
        # table and stash update in place across grid steps
        input_output_aliases={1: 0, 2: 1},
        interpret=interpret,
    )(n_arr, table, stash, hi, lo, valid)
    return new_table, new_stash, ok[inv] if schedule else ok


_INSERT_STATICS = ("fp_bits", "evict_rounds", "block", "interpret",
                   "emulate", "schedule")
_insert_bulk_jit = jax.jit(_insert_bulk_impl, static_argnames=_INSERT_STATICS)
# Donating twin: the caller hands over the table (and stash) buffers, so
# XLA writes the output state into them instead of copying the pow2 buffer
# every batch.  Opt-in via ``donate=True`` — only for callers that own the
# buffers and never touch the pre-insert arrays again (the OCF and the
# generation ring do; ad-hoc callers that re-insert into one base state,
# like the benchmarks, must not).
_insert_bulk_donated = jax.jit(_insert_bulk_impl,
                               static_argnames=_INSERT_STATICS,
                               donate_argnames=("table", "stash"))
# Telemetry twins: separate jit objects, so the telemetry-off entry above
# keeps its exact cache keys and dispatch path — enabling counters never
# recompiles or re-routes the hot path.
_INSERT_TM_STATICS = _INSERT_STATICS + ("telemetry",)
_insert_bulk_tm_jit = jax.jit(_insert_bulk_impl,
                              static_argnames=_INSERT_TM_STATICS)
_insert_bulk_tm_donated = jax.jit(_insert_bulk_impl,
                                  static_argnames=_INSERT_TM_STATICS,
                                  donate_argnames=("table", "stash"))


def insert_bulk(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                fp_bits: int, n_buckets=None, valid=None,
                evict_rounds: int = DEFAULT_EVICT_ROUNDS, stash=None,
                block: int = DEFAULT_BLOCK, interpret: bool = True,
                emulate: bool = False, schedule: bool = False,
                donate: bool = False):
    """Full bulk insert (optimistic rounds + bounded eviction rounds)
    -> (new_table, placed bool[N]), or (new_table, new_stash, placed) when
    an overflow ``stash`` (``kernels.stash.make_stash``) is attached.

    N must be a block multiple (ops.py pads).  ``n_buckets`` is the ACTIVE
    bucket count (may be < ``table.shape[0]`` for the OCF's pow2 buffer).
    Lanes with ``valid=False`` never touch the table.  ``evict_rounds=0``
    degenerates to the PR-1 optimistic-only kernel (``insert_once``).
    Without a stash, lanes whose chain exceeds the round budget roll back
    and report False — the control plane treats that exactly like a full
    filter (grow+rebuild).  With a stash, those lanes spill their carried
    fingerprint into it (aliased in→out like the table, so grid blocks
    accumulate) and only roll back once the stash is full too.

    Pipeline knobs (all default off, all bit-preserving):
      * ``emulate``  — run the identical kernel schedule as a compiled XLA
        ``lax.scan`` over the grid instead of ``pallas_call`` (the off-TPU
        fast path; ops.py sets it automatically);
      * ``schedule`` — the conflict-aware wave pre-pass
        (``core/scheduling.py``): sort lanes wave-major by home bucket and
        scatter ``placed`` back, cutting intra-batch rank races and
        eviction rounds for contended batches;
      * ``donate``   — donate the table/stash buffers to the call (zero-copy
        update; the caller's input arrays are consumed).
    """
    fn = _insert_bulk_donated if donate else _insert_bulk_jit
    return fn(table, hi, lo, fp_bits=fp_bits, n_buckets=n_buckets,
              valid=valid, evict_rounds=evict_rounds, stash=stash,
              block=block, interpret=interpret, emulate=emulate,
              schedule=schedule)


def insert_bulk_tm(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                   fp_bits: int, n_buckets=None, valid=None,
                   evict_rounds: int = DEFAULT_EVICT_ROUNDS, stash=None,
                   block: int = DEFAULT_BLOCK, schedule: bool = False,
                   donate: bool = False):
    """Telemetry twin of ``insert_bulk`` -> the same results plus a
    ``FilterTelemetry`` (kick-depth histogram, spill / rollback counts,
    stash fill high-water).

    Same placement bits as ``insert_bulk`` — the twin runs the XLA
    emulation arm of the kernel schedule (bit-for-bit by the PR-5 parity
    contract), so answers never depend on whether counters are on.
    Compiled as its own jit: calling this never touches the telemetry-off
    entry's cache or dispatch.
    """
    fn = _insert_bulk_tm_donated if donate else _insert_bulk_tm_jit
    return fn(table, hi, lo, fp_bits=fp_bits, n_buckets=n_buckets,
              valid=valid, evict_rounds=evict_rounds, stash=stash,
              block=block, interpret=False, emulate=True, schedule=schedule,
              telemetry=True)


# ------------------------------------------- selector-aware (adaptive) -----
#
# The adaptive insert is the static schedule — same optimistic rounds, same
# rank discipline, same dirty-slot eviction loop, same stash spill — acting
# on FOUR planes instead of one: fingerprints, the packed selector plane,
# and the mirror key planes (see kernels/selector.py).  Two invariants make
# adaptation compose with eviction chains:
#
#   * every slot the insert path writes is a selector-0 entry (placements
#     and kicks reset sel — movement loses a slot's adaptation, which is the
#     standard ACF trade: correctness is preserved, the repaired collision
#     may reappear and be repaired again);
#   * a kicked victim's NEXT bucket is derived from its mirror key's
#     selector-0 fingerprint, not from the stored (possibly adapted)
#     fingerprint — otherwise kicking an adapted slot would teleport the
#     entry off its candidate pair and manufacture a false negative.
#
# With an all-zero selector plane the fingerprint-table trajectory is
# bit-for-bit ``_insert_body``'s (stored values are all selector-0, and the
# alt-index of a non-adapted victim equals the static kernel's).


def _place_round_adaptive(planes, target, active, fp, khi, klo):
    """Adaptive placement round: write (fp, sel=0, key) to the rank-th empty
    slot.  ``planes`` = (table, sel_tbl, khi_t, klo_t), sel_tbl unpacked."""
    table, sel_tbl, khi_t, klo_t = planes
    buf, _bucket_size = table.shape
    rank = rank_among_earlier(target, active)
    tgt_c = jnp.clip(target, 0, buf - 1)
    free = jnp.sum(table == 0, axis=1).astype(jnp.int32)
    fits = active & (rank < free[tgt_c])
    row = table[tgt_c]
    empty_pos = jnp.cumsum((row == 0).astype(jnp.int32), axis=1) - 1
    is_dest = (row == 0) & (empty_pos == rank[:, None])
    slot = jnp.argmax(is_dest, axis=1)
    upd_i = jnp.where(fits, target, buf)                  # OOB -> dropped
    table = table.at[upd_i, slot].set(fp, mode="drop")
    sel_tbl = sel_tbl.at[upd_i, slot].set(jnp.uint32(0), mode="drop")
    khi_t = khi_t.at[upd_i, slot].set(khi, mode="drop")
    klo_t = klo_t.at[upd_i, slot].set(klo, mode="drop")
    return (table, sel_tbl, khi_t, klo_t), fits


def _evict_rounds_adaptive(planes, hi, lo, start_bucket, residue, n_buckets,
                           rounds: int, *, fp_bits: int, stash=None,
                           want_stats: bool = False):
    """Bounded eviction rounds over the four adaptive planes.

    Lanes carry the KEY (hi, lo) — the carried fingerprint is always its
    selector-0 member, recomputed per round, and spills park that
    selector-0 fingerprint (the identity ``stash_match`` probes).  The
    chain history records each kicked slot's ORIGINAL four-plane contents;
    since the dirty discipline gives a failed lane exclusive ownership of
    its kicked slots, restoring originals is exactly the static kernel's
    newest-first unwind (which reconstructs the same values chain-step by
    chain-step), including an adapted victim's original selector.
    """
    table, sel_tbl, khi_t, klo_t = planes
    buf, bucket_size = table.shape
    n = hi.shape[0]
    slot_iota = jax.lax.broadcasted_iota(jnp.int32, (n, bucket_size), 1)

    def round_body(carry):
        (r, planes, dirty, chi, clo, bucket, active, steps, hist) = carry
        cfp = hashing.fingerprint(chi, clo, fp_bits)
        planes, placed = _place_round_adaptive(planes, bucket, active, cfp,
                                               chi, clo)
        active = active & ~placed
        table, sel_tbl, khi_t, klo_t = planes
        hb, hs, hfp, hsel, hhi, hlo = hist

        def release(t, dirty):
            has = placed & (t < steps)
            upd_i = jnp.where(has, hb[:, t], buf)
            return dirty.at[upd_i, hs[:, t]].set(False, mode="drop")

        dirty = jax.lax.cond(
            jnp.any(placed & (steps > 0)),
            lambda d: jax.lax.fori_loop(0, r + 1, release, d),
            lambda d: d, dirty)
        first = active & (rank_among_earlier(bucket, active) == 0)
        b_c = jnp.clip(bucket, 0, buf - 1)
        pos = (slot_iota + (steps % bucket_size)[:, None]) % bucket_size
        cand_free = ~jnp.take_along_axis(dirty[b_c], pos, axis=1)
        kick = first & jnp.any(cand_free, axis=1)
        k = jnp.argmax(cand_free, axis=1)
        slot = jnp.take_along_axis(pos, k[:, None], axis=1)[:, 0]
        # Victim's original contents, all four planes (rollback restores
        # these verbatim; the mirror key re-derives its chase geometry).
        vfp = table[b_c, slot]
        vsel = sel_tbl[b_c, slot]
        vhi = khi_t[b_c, slot]
        vlo = klo_t[b_c, slot]
        upd_i = jnp.where(kick, bucket, buf)              # OOB -> dropped
        table = table.at[upd_i, slot].set(cfp, mode="drop")
        sel_tbl = sel_tbl.at[upd_i, slot].set(jnp.uint32(0), mode="drop")
        khi_t = khi_t.at[upd_i, slot].set(chi, mode="drop")
        klo_t = klo_t.at[upd_i, slot].set(clo, mode="drop")
        dirty = dirty.at[upd_i, slot].set(True, mode="drop")
        onehot = (jax.lax.broadcasted_iota(jnp.int32, (n, rounds), 1)
                  == steps[:, None]) & kick[:, None]
        hb = jnp.where(onehot, bucket[:, None], hb)
        hs = jnp.where(onehot, slot[:, None], hs)
        hfp = jnp.where(onehot, vfp[:, None], hfp)
        hsel = jnp.where(onehot, vsel[:, None], hsel)
        hhi = jnp.where(onehot, vhi[:, None], hhi)
        hlo = jnp.where(onehot, vlo[:, None], hlo)
        # Chase the victim to ITS alternate bucket — selector-0 geometry
        # from the mirror key (the stored fp may be an adapted member).
        vfp0 = hashing.fingerprint(vhi, vlo, fp_bits)
        nxt = hashing.alt_index_dyn(b_c, vfp0, n_buckets).astype(jnp.int32)
        chi = jnp.where(kick, vhi, chi)
        clo = jnp.where(kick, vlo, clo)
        bucket = jnp.where(kick, nxt, bucket)
        steps = steps + kick.astype(jnp.int32)
        return (r + 1, (table, sel_tbl, khi_t, klo_t), dirty, chi, clo,
                bucket, active, steps, (hb, hs, hfp, hsel, hhi, hlo))

    def round_cond(carry):
        r, _p, _d, _chi, _clo, _b, active, *_ = carry
        return (r < rounds) & jnp.any(active)

    hist0 = (jnp.zeros((n, rounds), jnp.int32),
             jnp.zeros((n, rounds), jnp.int32),
             jnp.zeros((n, rounds), jnp.uint32),
             jnp.zeros((n, rounds), jnp.uint32),
             jnp.zeros((n, rounds), jnp.uint32),
             jnp.zeros((n, rounds), jnp.uint32))
    init = (jnp.int32(0), planes, jnp.zeros(table.shape, jnp.bool_),
            hi, lo, start_bucket, residue, jnp.zeros((n,), jnp.int32), hist0)
    (_r, planes, _dirty, chi, clo, bucket, active, steps,
     hist) = jax.lax.while_loop(round_cond, round_body, init)
    table, sel_tbl, khi_t, klo_t = planes
    hb, hs, hfp, hsel, hhi, hlo = hist

    if stash is not None:
        cfp = hashing.fingerprint(chi, clo, fp_bits)
        stash, spilled = stash_spill(stash, cfp, bucket, active)
        active = active & ~spilled
    elif want_stats:
        spilled = jnp.zeros_like(active)

    failed = active

    def rb_body(k, planes):
        table, sel_tbl, khi_t, klo_t = planes
        t = steps - 1 - k
        do = failed & (t >= 0)
        t_c = jnp.clip(t, 0, rounds - 1)[:, None]
        b = jnp.take_along_axis(hb, t_c, axis=1)[:, 0]
        s = jnp.take_along_axis(hs, t_c, axis=1)[:, 0]
        upd_i = jnp.where(do, b, buf)
        table = table.at[upd_i, s].set(
            jnp.take_along_axis(hfp, t_c, axis=1)[:, 0], mode="drop")
        sel_tbl = sel_tbl.at[upd_i, s].set(
            jnp.take_along_axis(hsel, t_c, axis=1)[:, 0], mode="drop")
        khi_t = khi_t.at[upd_i, s].set(
            jnp.take_along_axis(hhi, t_c, axis=1)[:, 0], mode="drop")
        klo_t = klo_t.at[upd_i, s].set(
            jnp.take_along_axis(hlo, t_c, axis=1)[:, 0], mode="drop")
        return table, sel_tbl, khi_t, klo_t

    planes = jax.lax.cond(
        jnp.any(failed),
        lambda p: jax.lax.fori_loop(0, rounds, rb_body, p),
        lambda p: p, (table, sel_tbl, khi_t, klo_t))
    stats = (steps, spilled, failed) if want_stats else None
    if stash is not None:
        if want_stats:
            return planes, stash, residue & ~failed, stats
        return planes, stash, residue & ~failed
    if want_stats:
        return planes, residue & ~failed, stats
    return planes, residue & ~failed


def _insert_adaptive_body(table, sels, khi_t, klo_t, stash, hi, lo, valid,
                          n_buckets, *, fp_bits: int, evict_rounds: int,
                          want_stats: bool = False):
    """Optimistic + eviction rounds over the four adaptive planes.

    ``sels`` is the PACKED plane; pack∘unpack is the identity, so per-block
    repacking keeps the pallas grid and the emulation scan bit-for-bit.
    ``want_stats`` mirrors the static body's telemetry extras.
    """
    n = hi.shape[0]
    bucket_size = table.shape[-1]
    sel_tbl = sel_unpack(sels, bucket_size)
    fp = hashing.fingerprint(hi, lo, fp_bits)
    i1 = hashing.index_hash_dyn(hi, lo, n_buckets).astype(jnp.int32)
    i2 = hashing.alt_index_dyn(i1, fp, n_buckets).astype(jnp.int32)
    planes = (table, sel_tbl, khi_t, klo_t)
    planes, ok1 = _place_round_adaptive(planes, i1, valid, fp, hi, lo)
    planes, ok2 = _place_round_adaptive(planes, i2, valid & ~ok1, fp, hi, lo)
    ok = ok1 | ok2
    steps = jnp.zeros((n,), jnp.int32)
    spilled = jnp.zeros((n,), jnp.bool_)
    failed = jnp.zeros((n,), jnp.bool_)
    if evict_rounds > 0:
        if stash is None:
            if want_stats:
                planes, completed, (steps, spilled, failed) = (
                    _evict_rounds_adaptive(
                        planes, hi, lo, i2, valid & ~ok, n_buckets,
                        evict_rounds, fp_bits=fp_bits, want_stats=True))
            else:
                planes, completed = _evict_rounds_adaptive(
                    planes, hi, lo, i2, valid & ~ok, n_buckets, evict_rounds,
                    fp_bits=fp_bits)
        elif want_stats:
            planes, stash, completed, (steps, spilled, failed) = (
                _evict_rounds_adaptive(
                    planes, hi, lo, i2, valid & ~ok, n_buckets, evict_rounds,
                    fp_bits=fp_bits, stash=stash, want_stats=True))
        else:
            planes, stash, completed = _evict_rounds_adaptive(
                planes, hi, lo, i2, valid & ~ok, n_buckets, evict_rounds,
                fp_bits=fp_bits, stash=stash)
        ok = ok | completed
    elif stash is not None:
        stash, spilled0 = stash_spill(stash, fp, i2, valid & ~ok)
        ok = ok | spilled0
        spilled = spilled0
    table, sel_tbl, khi_t, klo_t = planes
    if not want_stats:
        return table, sel_pack(sel_tbl), khi_t, klo_t, stash, ok
    tm = empty_telemetry()._replace(
        kick_hist=kick_histogram(steps, valid),
        stash_spills=jnp.sum(spilled).astype(jnp.uint32),
        rollback_lanes=jnp.sum(failed).astype(jnp.uint32),
        stash_fill_hw=(stash_occupancy(stash).astype(jnp.uint32)
                       if stash is not None else jnp.zeros((), jnp.uint32)))
    return table, sel_pack(sel_tbl), khi_t, klo_t, stash, ok, tm


def _insert_adaptive_kernel(n_ref, table_in, sels_in, khi_in, klo_in, hi_ref,
                            lo_ref, valid_ref, table_ref, sels_ref, khi_ref,
                            klo_ref, ok_ref, *, fp_bits: int,
                            evict_rounds: int):
    del table_in, sels_in, khi_in, klo_in      # aliased to the outputs
    table, sels, khi_t, klo_t, _stash, ok = _insert_adaptive_body(
        table_ref[...], sels_ref[...], khi_ref[...], klo_ref[...], None,
        hi_ref[...], lo_ref[...], valid_ref[...], n_ref[0, 0],
        fp_bits=fp_bits, evict_rounds=evict_rounds)
    table_ref[...] = table
    sels_ref[...] = sels
    khi_ref[...] = khi_t
    klo_ref[...] = klo_t
    ok_ref[...] = ok


def _insert_adaptive_stash_kernel(n_ref, table_in, sels_in, khi_in, klo_in,
                                  stash_in, hi_ref, lo_ref, valid_ref,
                                  table_ref, sels_ref, khi_ref, klo_ref,
                                  stash_ref, ok_ref, *, fp_bits: int,
                                  evict_rounds: int):
    del table_in, sels_in, khi_in, klo_in, stash_in    # aliased to outputs
    table, sels, khi_t, klo_t, stash, ok = _insert_adaptive_body(
        table_ref[...], sels_ref[...], khi_ref[...], klo_ref[...],
        stash_ref[...], hi_ref[...], lo_ref[...], valid_ref[...], n_ref[0, 0],
        fp_bits=fp_bits, evict_rounds=evict_rounds)
    table_ref[...] = table
    sels_ref[...] = sels
    khi_ref[...] = khi_t
    klo_ref[...] = klo_t
    stash_ref[...] = stash
    ok_ref[...] = ok


def _emulated_insert_adaptive(table, sels, khi_t, klo_t, stash, hi, lo, valid,
                              n_buckets, *, fp_bits: int, evict_rounds: int,
                              block: int, want_stats: bool = False):
    """The adaptive kernel schedule as a compiled XLA scan (the off-TPU
    path) — same ``_insert_adaptive_body`` per block, planes carried."""
    g = hi.shape[0] // block
    if want_stats:
        if g == 1:
            return _insert_adaptive_body(
                table, sels, khi_t, klo_t, stash, hi, lo, valid, n_buckets,
                fp_bits=fp_bits, evict_rounds=evict_rounds, want_stats=True)
        xs = (hi.reshape(g, block), lo.reshape(g, block),
              valid.reshape(g, block))

        def step(carry, x):
            t, s, kh, kl, st, tm = carry
            t, s, kh, kl, st, ok, tm_b = _insert_adaptive_body(
                t, s, kh, kl, st, *x, n_buckets, fp_bits=fp_bits,
                evict_rounds=evict_rounds, want_stats=True)
            return (t, s, kh, kl, st, tm_merge(tm, tm_b)), ok

        (table, sels, khi_t, klo_t, stash, tm), ok = jax.lax.scan(
            step, (table, sels, khi_t, klo_t, stash, empty_telemetry()), xs)
        return table, sels, khi_t, klo_t, stash, ok.reshape(-1), tm
    if g == 1:
        return _insert_adaptive_body(table, sels, khi_t, klo_t, stash, hi,
                                     lo, valid, n_buckets, fp_bits=fp_bits,
                                     evict_rounds=evict_rounds)
    xs = (hi.reshape(g, block), lo.reshape(g, block), valid.reshape(g, block))

    if stash is None:
        def step(carry, x):
            t, s, kh, kl = carry
            t, s, kh, kl, _stash, ok = _insert_adaptive_body(
                t, s, kh, kl, None, *x, n_buckets, fp_bits=fp_bits,
                evict_rounds=evict_rounds)
            return (t, s, kh, kl), ok

        (table, sels, khi_t, klo_t), ok = jax.lax.scan(
            step, (table, sels, khi_t, klo_t), xs)
        return table, sels, khi_t, klo_t, None, ok.reshape(-1)

    def step(carry, x):
        t, s, kh, kl, st = carry
        t, s, kh, kl, st, ok = _insert_adaptive_body(
            t, s, kh, kl, st, *x, n_buckets, fp_bits=fp_bits,
            evict_rounds=evict_rounds)
        return (t, s, kh, kl, st), ok

    (table, sels, khi_t, klo_t, stash), ok = jax.lax.scan(
        step, (table, sels, khi_t, klo_t, stash), xs)
    return table, sels, khi_t, klo_t, stash, ok.reshape(-1)


def _insert_adaptive_impl(table, sels, khi_t, klo_t, hi, lo, *, fp_bits: int,
                          n_buckets=None, valid=None,
                          evict_rounds: int = DEFAULT_EVICT_ROUNDS,
                          stash=None, block: int = DEFAULT_BLOCK,
                          interpret: bool = True, emulate: bool = False,
                          schedule: bool = False, telemetry: bool = False):
    n = hi.shape[0]
    block = min(block, n)
    assert n % block == 0, f"{n=} not a multiple of {block=}"
    buffer_buckets, bucket_size = table.shape
    if n_buckets is None:
        n_buckets = buffer_buckets
    if valid is None:
        valid = jnp.ones((n,), bool)
    hi = hi.astype(jnp.uint32)
    lo = lo.astype(jnp.uint32)
    schedule = schedule and n > block
    if schedule:
        perm, inv = dispatch_order(hi, lo, valid, n_buckets=n_buckets)
        hi, lo, valid = hi[perm], lo[perm], valid[perm]
    if telemetry:
        # Telemetry twin — emulation arm, same bits (see _insert_bulk_impl).
        table, sels, khi_t, klo_t, stash, ok, tm = _emulated_insert_adaptive(
            table, sels, khi_t, klo_t, stash, hi, lo, valid, n_buckets,
            fp_bits=fp_bits, evict_rounds=evict_rounds, block=block,
            want_stats=True)
        if schedule:
            ok = ok[inv]
        if stash is None:
            return table, sels, khi_t, klo_t, ok, tm
        return table, sels, khi_t, klo_t, stash, ok, tm
    if emulate:
        table, sels, khi_t, klo_t, stash, ok = _emulated_insert_adaptive(
            table, sels, khi_t, klo_t, stash, hi, lo, valid, n_buckets,
            fp_bits=fp_bits, evict_rounds=evict_rounds, block=block)
        if schedule:
            ok = ok[inv]
        if stash is None:
            return table, sels, khi_t, klo_t, ok
        return table, sels, khi_t, klo_t, stash, ok
    n_arr = jnp.asarray(n_buckets, jnp.int32).reshape(1, 1)
    grid = (n // block,)
    smem_spec = pl.BlockSpec((1, 1), lambda i: (0, 0),
                             memory_space=pltpu.SMEM)
    key_spec = pl.BlockSpec((block,), lambda i: (i,))
    table_spec = pl.BlockSpec((buffer_buckets, bucket_size), lambda i: (0, 0))
    sel_spec = pl.BlockSpec((buffer_buckets, 1), lambda i: (0, 0))
    ok_spec = pl.BlockSpec((block,), lambda i: (i,))
    plane_shapes = [jax.ShapeDtypeStruct(table.shape, jnp.uint32),
                    jax.ShapeDtypeStruct((buffer_buckets, 1), jnp.uint32),
                    jax.ShapeDtypeStruct(table.shape, jnp.uint32),
                    jax.ShapeDtypeStruct(table.shape, jnp.uint32)]
    if stash is None:
        out = pl.pallas_call(
            functools.partial(_insert_adaptive_kernel, fp_bits=fp_bits,
                              evict_rounds=evict_rounds),
            grid=grid,
            in_specs=[smem_spec, table_spec, sel_spec, table_spec, table_spec,
                      key_spec, key_spec, key_spec],
            out_specs=[table_spec, sel_spec, table_spec, table_spec, ok_spec],
            out_shape=plane_shapes + [jax.ShapeDtypeStruct((n,), jnp.bool_)],
            # all four planes update in place across grid steps
            input_output_aliases={1: 0, 2: 1, 3: 2, 4: 3},
            interpret=interpret,
        )(n_arr, table, sels, khi_t, klo_t, hi, lo, valid)
        table, sels, khi_t, klo_t, ok = out
        return table, sels, khi_t, klo_t, ok[inv] if schedule else ok
    stash_spec = pl.BlockSpec(stash.shape, lambda i: (0, 0))
    out = pl.pallas_call(
        functools.partial(_insert_adaptive_stash_kernel, fp_bits=fp_bits,
                          evict_rounds=evict_rounds),
        grid=grid,
        in_specs=[smem_spec, table_spec, sel_spec, table_spec, table_spec,
                  stash_spec, key_spec, key_spec, key_spec],
        out_specs=[table_spec, sel_spec, table_spec, table_spec, stash_spec,
                   ok_spec],
        out_shape=plane_shapes + [
            jax.ShapeDtypeStruct(stash.shape, stash.dtype),
            jax.ShapeDtypeStruct((n,), jnp.bool_)],
        input_output_aliases={1: 0, 2: 1, 3: 2, 4: 3, 5: 4},
        interpret=interpret,
    )(n_arr, table, sels, khi_t, klo_t, stash, hi, lo, valid)
    table, sels, khi_t, klo_t, stash, ok = out
    return table, sels, khi_t, klo_t, stash, ok[inv] if schedule else ok


_insert_adaptive_jit = jax.jit(_insert_adaptive_impl,
                               static_argnames=_INSERT_STATICS)
_insert_adaptive_donated = jax.jit(
    _insert_adaptive_impl, static_argnames=_INSERT_STATICS,
    donate_argnames=("table", "sels", "khi_t", "klo_t", "stash"))
_insert_adaptive_tm_jit = jax.jit(_insert_adaptive_impl,
                                  static_argnames=_INSERT_TM_STATICS)
_insert_adaptive_tm_donated = jax.jit(
    _insert_adaptive_impl, static_argnames=_INSERT_TM_STATICS,
    donate_argnames=("table", "sels", "khi_t", "klo_t", "stash"))


def insert_bulk_adaptive(table, sels, khi_t, klo_t, hi, lo, *, fp_bits: int,
                         n_buckets=None, valid=None,
                         evict_rounds: int = DEFAULT_EVICT_ROUNDS, stash=None,
                         block: int = DEFAULT_BLOCK, interpret: bool = True,
                         emulate: bool = False, schedule: bool = False,
                         donate: bool = False):
    """Selector-aware bulk insert over the four adaptive planes
    -> (table, sels, khi, klo, placed) or (..., stash, placed).

    Same contract and knobs as ``insert_bulk``; new entries land as
    selector-0 slots with their key mirrored, kicks reset the victim's
    selector (re-deriving its chase geometry from the mirror key), and
    rollback restores all four planes verbatim.
    """
    fn = _insert_adaptive_donated if donate else _insert_adaptive_jit
    return fn(table, sels, khi_t, klo_t, hi, lo, fp_bits=fp_bits,
              n_buckets=n_buckets, valid=valid, evict_rounds=evict_rounds,
              stash=stash, block=block, interpret=interpret, emulate=emulate,
              schedule=schedule)


def insert_bulk_adaptive_tm(table, sels, khi_t, klo_t, hi, lo, *,
                            fp_bits: int, n_buckets=None, valid=None,
                            evict_rounds: int = DEFAULT_EVICT_ROUNDS,
                            stash=None, block: int = DEFAULT_BLOCK,
                            schedule: bool = False, donate: bool = False):
    """Telemetry twin of ``insert_bulk_adaptive`` — same results plus a
    ``FilterTelemetry``; own jit, emulation arm (see ``insert_bulk_tm``)."""
    fn = _insert_adaptive_tm_donated if donate else _insert_adaptive_tm_jit
    return fn(table, sels, khi_t, klo_t, hi, lo, fp_bits=fp_bits,
              n_buckets=n_buckets, valid=valid, evict_rounds=evict_rounds,
              stash=stash, block=block, interpret=False, emulate=True,
              schedule=schedule, telemetry=True)


def insert_once(table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                fp_bits: int, n_buckets=None, valid=None,
                block: int = DEFAULT_BLOCK, interpret: bool = True,
                emulate: bool = False) -> tuple[jax.Array, jax.Array]:
    """One optimistic insert round (no eviction) -> (new_table, placed).

    The PR-1 entry point, kept for callers that sweep the residue
    themselves; ``insert_bulk`` with eviction rounds is the full fast path.
    """
    return insert_bulk(table, hi, lo, fp_bits=fp_bits, n_buckets=n_buckets,
                       valid=valid, evict_rounds=0, block=block,
                       interpret=interpret, emulate=emulate)
