"""Device-resident telemetry planes for the filter kernels.

The hot path never pays for observability: every kernel entry point keeps
its existing signature and jit, and a *twin* jit (selected by a static
``telemetry`` flag at the dispatch layer) returns a ``FilterTelemetry``
alongside the normal results.  The telemetry twin is a separate compiled
trace, so the telemetry-off path is dispatch-identical to a build without
this module.

All fields are fixed-shape ``uint32`` vectors/scalars so a wave's counters
ride back to the host in the same transfer as its results and merge across
waves with one elementwise op.  ``merge`` is elementwise addition except
for ``stash_fill_hw`` (a high-water mark, merged with ``max``) — that
makes merge associative and commutative, which the property tests pin.

Kick-depth histogram bins are powers of two over the eviction-chain
length: ``0, 1, 2, 3-4, 5-8, 9-16, 17-32, 33+``.  A lane that placed
without kicking lands in bin 0; the open top bin absorbs any
``evict_rounds`` configuration.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

KICK_BINS = 8
PROBE_DEPTHS = 4  # b1-hit, b2-hit, stash-hit, miss

# Inclusive upper edge of each histogram bin except the open-topped last.
KICK_EDGES = (0, 1, 2, 4, 8, 16, 32)
_BIN_EDGES = jnp.asarray(KICK_EDGES, dtype=jnp.uint32)


class FilterTelemetry(NamedTuple):
    """Per-wave device counters; all uint32, fixed shape."""

    kick_hist: jnp.ndarray      # (KICK_BINS,) eviction-chain depth histogram
    probe_depth: jnp.ndarray    # (PROBE_DEPTHS,) lookup hit-depth counts
    stash_spills: jnp.ndarray   # () lanes spilled to the stash
    stash_fill_hw: jnp.ndarray  # () stash occupancy high-water (merge=max)
    rollback_lanes: jnp.ndarray  # () lanes rolled back after a failed chain
    selector_bumps: jnp.ndarray  # () adaptive selector rewrites applied
    overflow_lanes: jnp.ndarray  # () routed-write lanes bounced to the host
    table_deletes: jnp.ndarray  # () deletes resolved in the bucket table
    stash_deletes: jnp.ndarray  # () deletes resolved in the stash


_EMPTY: Optional[FilterTelemetry] = None


def empty_telemetry() -> FilterTelemetry:
    """All-zero counter plane, cached once built outside a trace: jax
    arrays are immutable and none of the tm paths donate telemetry
    buffers, so every host-side dispatch can share one instance — 9 fresh
    device_puts per call otherwise dominate the host side of the cheap
    twins (measured ~0.5 ms on the CPU lookup).  Inside a jit trace
    ``jnp.zeros`` yields tracers, which must never be cached — those
    calls build (and discard) a fresh instance."""
    global _EMPTY
    if _EMPTY is not None:
        return _EMPTY
    u = jnp.uint32
    tm = FilterTelemetry(
        kick_hist=jnp.zeros((KICK_BINS,), u),
        probe_depth=jnp.zeros((PROBE_DEPTHS,), u),
        stash_spills=jnp.zeros((), u),
        stash_fill_hw=jnp.zeros((), u),
        rollback_lanes=jnp.zeros((), u),
        selector_bumps=jnp.zeros((), u),
        overflow_lanes=jnp.zeros((), u),
        table_deletes=jnp.zeros((), u),
        stash_deletes=jnp.zeros((), u),
    )
    if not isinstance(tm.kick_hist, jax.core.Tracer):
        _EMPTY = tm
    return tm


def merge(a: FilterTelemetry, b: FilterTelemetry) -> FilterTelemetry:
    """Fold two waves' counters: add everywhere, max for the high-water."""
    return FilterTelemetry(
        kick_hist=a.kick_hist + b.kick_hist,
        probe_depth=a.probe_depth + b.probe_depth,
        stash_spills=a.stash_spills + b.stash_spills,
        stash_fill_hw=jnp.maximum(a.stash_fill_hw, b.stash_fill_hw),
        rollback_lanes=a.rollback_lanes + b.rollback_lanes,
        selector_bumps=a.selector_bumps + b.selector_bumps,
        overflow_lanes=a.overflow_lanes + b.overflow_lanes,
        table_deletes=a.table_deletes + b.table_deletes,
        stash_deletes=a.stash_deletes + b.stash_deletes,
    )


def kick_histogram(steps: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Histogram eviction-chain lengths into the fixed pow2 bins.

    ``steps`` is the per-lane kick count from the eviction loop carry,
    ``mask`` selects lanes that actually attempted placement.  Fixed
    output shape (KICK_BINS,), so it composes inside jit.  Bin index via
    broadcast-compare against the bin edges (counting edges <= steps) —
    the same ranks-not-sorts idiom the kernels use, no sort, no segment
    ops.
    """
    steps = steps.astype(jnp.uint32)
    idx = jnp.sum(steps[:, None] > _BIN_EDGES[None, :], axis=1)
    onehot = (idx[:, None] == jnp.arange(KICK_BINS)[None, :])
    return jnp.sum(onehot & mask[:, None], axis=0).astype(jnp.uint32)


def probe_depth_counts(h1: jnp.ndarray, h2: jnp.ndarray,
                       hs: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Count lookup lanes by the depth at which they hit.

    ``h1``/``h2``/``hs`` are per-lane bools for a match in the first
    bucket, second bucket, and stash; a lane counts at its *shallowest*
    hit (the order the fused probe short-circuits on TPU is irrelevant —
    this is an accounting convention, not a claim about execution).
    """
    d1 = h1 & valid
    d2 = h2 & ~h1 & valid
    ds = hs & ~h1 & ~h2 & valid
    miss = ~(h1 | h2 | hs) & valid
    return jnp.stack([jnp.sum(d1), jnp.sum(d2), jnp.sum(ds),
                      jnp.sum(miss)]).astype(jnp.uint32)


__all__ = [
    "KICK_BINS", "KICK_EDGES", "PROBE_DEPTHS", "FilterTelemetry",
    "empty_telemetry", "merge", "kick_histogram", "probe_depth_counts",
]
