"""Pallas TPU kernels (validated in interpret mode on CPU; see ops.py)."""
from repro.kernels import ops, ref
from repro.kernels.fingerprint import fingerprint_hash
from repro.kernels.flash_attention import flash_attention
from repro.kernels.insert import insert_once
from repro.kernels.probe import probe
from repro.kernels.stash import make_stash, stash_occupancy
