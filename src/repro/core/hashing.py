"""Hash primitives for the Optimized Cuckoo Filter.

TPU-native design note (see DESIGN.md §2): TPUs have no 64-bit integer lanes,
so all hashing is expressed as 32-bit mixes (murmur3 finalizer and a
splitmix-derived 32-bit mixer).  Every function has two spellings with
identical bit-level semantics:

  * ``*_np``  — numpy/uint32 (host oracle, used by ``pyfilter.py``),
  * jnp       — jitted JAX (used by ``filter.py`` and the Pallas kernels).

Keys are arbitrary uint32/uint64-representable integers; 64-bit keys are fed
in as (hi, lo) uint32 pairs so the same code runs on TPU.

Partial-key cuckoo hashing (Fan et al. 2014) needs, per key:
  fp  = fingerprint(key)      in [1, 2^f - 1]   (0 is the EMPTY sentinel)
  i1  = index_hash(key)       mod n_buckets
  i2  = (H(fp) - i1) mod n    -- additive-complement involution; unlike the
                                 XOR trick it works for ANY bucket count,
                                 which EOF's fractional resizing requires.
  alt(alt(i)) == i            for both i1 and i2 by construction.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

_M3_C1 = np.uint32(0x85EBCA6B)
_M3_C2 = np.uint32(0xC2B2AE35)
_SM_C1 = np.uint32(0x9E3779B9)  # golden-ratio increment (splitmix)
_SM_C2 = np.uint32(0x7FEB352D)
_SM_C3 = np.uint32(0x846CA68B)

# Selector-parameterized fingerprint family (Adaptive Cuckoo Filters).
# Selector s XORs a tweak into the pre-mix seed, so the four family members
# are independent full-avalanche hashes of the same key.  Tweak 0 is the
# identity: ``fingerprint_sel(.., sel=0)`` is bit-identical to
# ``fingerprint`` — a table whose selector plane is all-zero behaves exactly
# like the static filter.
SEL_VARIANTS = 4          # 2 selector bits per slot
_SEL_TWEAKS = (0x00000000, 0x7F4A7C15, 0x94D049BB, 0xBF58476D)

# ---------------------------------------------------------------- numpy ----


def murmur3_mix_np(x: np.ndarray) -> np.ndarray:
    """murmur3 32-bit finalizer — a full-avalanche bijection on uint32."""
    x = np.asarray(x, dtype=np.uint32)
    with np.errstate(over="ignore"):
        x = x ^ (x >> np.uint32(16))
        x = (x * _M3_C1).astype(np.uint32)
        x = x ^ (x >> np.uint32(13))
        x = (x * _M3_C2).astype(np.uint32)
        x = x ^ (x >> np.uint32(16))
    return x


def splitmix32_np(x: np.ndarray) -> np.ndarray:
    """splitmix-style 32-bit mixer (independent avalanche function)."""
    x = np.asarray(x, dtype=np.uint32)
    with np.errstate(over="ignore"):
        x = (x + _SM_C1).astype(np.uint32)
        x = x ^ (x >> np.uint32(16))
        x = (x * _SM_C2).astype(np.uint32)
        x = x ^ (x >> np.uint32(15))
        x = (x * _SM_C3).astype(np.uint32)
        x = x ^ (x >> np.uint32(16))
    return x


def key_to_u32_pair_np(keys) -> tuple[np.ndarray, np.ndarray]:
    """Split arbitrary integer keys into (hi, lo) uint32 halves."""
    k = np.asarray(keys, dtype=np.uint64)
    lo = (k & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (k >> np.uint64(32)).astype(np.uint32)
    return hi, lo


def fingerprint_np(hi: np.ndarray, lo: np.ndarray, fp_bits: int) -> np.ndarray:
    """Fingerprint in [1, 2^fp_bits - 1] (0 reserved as EMPTY)."""
    h = murmur3_mix_np(lo ^ murmur3_mix_np(hi ^ np.uint32(0xDEADBEEF)))
    mask = np.uint32((1 << fp_bits) - 1)
    fp = (h & mask).astype(np.uint32)
    # Remap 0 -> 1: costs a sliver of entropy, keeps the sentinel free.
    return np.where(fp == 0, np.uint32(1), fp)


def sel_tweak_np(sel) -> np.ndarray:
    """Per-selector seed tweak (numpy).  Accepts scalars or arrays in [0, 3].

    Spelled as a where-chain (not a gather) so the jnp twin lowers to pure
    VPU selects inside Pallas kernels; both spellings are bit-identical.
    """
    sel = np.asarray(sel, dtype=np.uint32) & np.uint32(3)
    t = np.where(sel == 1, np.uint32(_SEL_TWEAKS[1]), np.uint32(0))
    t = np.where(sel == 2, np.uint32(_SEL_TWEAKS[2]), t)
    t = np.where(sel == 3, np.uint32(_SEL_TWEAKS[3]), t)
    return t.astype(np.uint32)


def fingerprint_sel_np(hi: np.ndarray, lo: np.ndarray, sel,
                       fp_bits: int) -> np.ndarray:
    """Selector-indexed fingerprint in [1, 2^fp_bits - 1]; sel=0 == static.

    ``sel`` broadcasts against ``hi``/``lo`` (e.g. per-slot selectors of
    shape [N, bucket_size] against keys of shape [N, 1]).
    """
    seed = np.uint32(0xDEADBEEF) ^ sel_tweak_np(sel)
    h = murmur3_mix_np(lo ^ murmur3_mix_np(hi ^ seed))
    mask = np.uint32((1 << fp_bits) - 1)
    fp = (h & mask).astype(np.uint32)
    return np.where(fp == 0, np.uint32(1), fp)


def index_hash_np(hi: np.ndarray, lo: np.ndarray, n_buckets: int) -> np.ndarray:
    h = splitmix32_np(lo) ^ murmur3_mix_np(hi + np.uint32(0x51ED270B))
    return (h % np.uint32(n_buckets)).astype(np.uint32)


def alt_index_np(i: np.ndarray, fp: np.ndarray, n_buckets: int) -> np.ndarray:
    """Additive-complement alternate bucket: alt(i) = (H(fp) - i) mod n."""
    hfp = splitmix32_np(fp).astype(np.uint64) % np.uint64(n_buckets)
    i = np.asarray(i, dtype=np.uint64) % np.uint64(n_buckets)
    return ((hfp + np.uint64(n_buckets) - i) % np.uint64(n_buckets)).astype(np.uint32)


def owner_shard_np(hi: np.ndarray, lo: np.ndarray, n_shards: int) -> np.ndarray:
    """Which filter shard owns a key in the distributed OCF."""
    h = murmur3_mix_np(splitmix32_np(lo) + hi)
    return (h % np.uint32(n_shards)).astype(np.uint32)


# Pair routing (elastic resharding).  A stored slot is only (bucket, fp) —
# the key is gone — so a shard-owner function that must be re-evaluable
# during a live split/merge can depend ONLY on invariants of the slot.  The
# candidate pair {i, alt(i, fp)} is such an invariant (the additive
# complement is an involution), and min(i, alt(i, fp)) + fp identifies it,
# computable both at insert time (from the key's i1) and at migration time
# (from whichever bucket the entry happens to reside in).  Any pair-owner
# hash factors through exactly this: i + alt(i, fp) == H(fp) mod n_buckets,
# so there is no more slot-derivable entropy to be had.
_PAIR_C = np.uint32(0x27220A95)


def owner_shard_pair_np(bucket: np.ndarray, fp: np.ndarray, n_buckets: int,
                        n_shards: int) -> np.ndarray:
    """Owner shard of a stored (bucket, fingerprint) pair — key-free.

    Because the hash is independent of ``n_shards`` (only the final mod
    changes), power-of-two shard counts nest: ``owner(2n) mod n ==
    owner(n)``, so a split moves every entry of shard ``s`` to ``s`` or
    ``s + n`` and a merge folds ``s + n`` back onto ``s``.
    """
    b = np.asarray(bucket, dtype=np.uint32) % np.uint32(n_buckets)
    alt = alt_index_np(b, np.asarray(fp, np.uint32), n_buckets)
    lo_b = np.minimum(b, alt)
    with np.errstate(over="ignore"):
        h = murmur3_mix_np(splitmix32_np(lo_b)
                           ^ murmur3_mix_np((np.asarray(fp, np.uint32)
                                             + _PAIR_C).astype(np.uint32)))
    return (h % np.uint32(n_shards)).astype(np.uint32)


def owner_shard_key_pair_np(hi: np.ndarray, lo: np.ndarray, n_buckets: int,
                            fp_bits: int, n_shards: int) -> np.ndarray:
    """Pair-routing owner computed from a live key (the insert-time side)."""
    fp = fingerprint_np(hi, lo, fp_bits)
    i1 = index_hash_np(hi, lo, n_buckets)
    return owner_shard_pair_np(i1, fp, n_buckets, n_shards)


# ------------------------------------------------------------------ jax ----


def murmur3_mix(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_M3_C1)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(_M3_C2)
    x = x ^ (x >> 16)
    return x


def splitmix32(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.uint32)
    x = x + jnp.uint32(_SM_C1)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_SM_C2)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(_SM_C3)
    x = x ^ (x >> 16)
    return x


def fingerprint(hi: jax.Array, lo: jax.Array, fp_bits: int) -> jax.Array:
    h = murmur3_mix(lo ^ murmur3_mix(hi ^ jnp.uint32(0xDEADBEEF)))
    fp = h & jnp.uint32((1 << fp_bits) - 1)
    return jnp.where(fp == 0, jnp.uint32(1), fp)


def sel_tweak(sel) -> jax.Array:
    """jnp twin of ``sel_tweak_np`` (VPU select chain, kernel-safe)."""
    sel = jnp.asarray(sel).astype(jnp.uint32) & jnp.uint32(3)
    t = jnp.where(sel == 1, jnp.uint32(_SEL_TWEAKS[1]), jnp.uint32(0))
    t = jnp.where(sel == 2, jnp.uint32(_SEL_TWEAKS[2]), t)
    t = jnp.where(sel == 3, jnp.uint32(_SEL_TWEAKS[3]), t)
    return t


def fingerprint_sel(hi: jax.Array, lo: jax.Array, sel,
                    fp_bits: int) -> jax.Array:
    """jnp twin of ``fingerprint_sel_np``; sel broadcasts against hi/lo."""
    seed = jnp.uint32(0xDEADBEEF) ^ sel_tweak(sel)
    h = murmur3_mix(lo ^ murmur3_mix(hi ^ seed))
    fp = h & jnp.uint32((1 << fp_bits) - 1)
    return jnp.where(fp == 0, jnp.uint32(1), fp)


def index_hash(hi: jax.Array, lo: jax.Array, n_buckets: int) -> jax.Array:
    h = splitmix32(lo) ^ murmur3_mix(hi + jnp.uint32(0x51ED270B))
    return h % jnp.uint32(n_buckets)


def alt_index(i: jax.Array, fp: jax.Array, n_buckets: int) -> jax.Array:
    """(H(fp) - i) mod n without 64-bit ints (TPU-safe).

    Both H(fp)%n and i%n are < n <= 2^31, so (a - b + n) stays in uint32.
    """
    hfp = splitmix32(fp) % jnp.uint32(n_buckets)
    i = i.astype(jnp.uint32) % jnp.uint32(n_buckets)
    return (hfp + jnp.uint32(n_buckets) - i) % jnp.uint32(n_buckets)


def index_hash_dyn(hi: jax.Array, lo: jax.Array, n_buckets) -> jax.Array:
    """index_hash with a *traced* bucket count (dynamic-capacity filter)."""
    h = splitmix32(lo) ^ murmur3_mix(hi + jnp.uint32(0x51ED270B))
    return h % jnp.asarray(n_buckets, jnp.uint32)


def alt_index_dyn(i: jax.Array, fp: jax.Array, n_buckets) -> jax.Array:
    """alt_index with a traced bucket count."""
    n = jnp.asarray(n_buckets, jnp.uint32)
    hfp = splitmix32(fp) % n
    i = i.astype(jnp.uint32) % n
    return (hfp + n - i) % n


def owner_shard(hi: jax.Array, lo: jax.Array, n_shards: int) -> jax.Array:
    h = murmur3_mix(splitmix32(lo) + hi)
    return h % jnp.uint32(n_shards)


def owner_shard_pair(bucket: jax.Array, fp: jax.Array, n_buckets: int,
                     n_shards: int) -> jax.Array:
    """jnp twin of ``owner_shard_pair_np`` (bit-identical)."""
    b = bucket.astype(jnp.uint32) % jnp.uint32(n_buckets)
    alt = alt_index(b, fp.astype(jnp.uint32), n_buckets)
    lo_b = jnp.minimum(b, alt)
    h = murmur3_mix(splitmix32(lo_b)
                    ^ murmur3_mix(fp.astype(jnp.uint32) + jnp.uint32(_PAIR_C)))
    return h % jnp.uint32(n_shards)


def owner_shard_key_pair(hi: jax.Array, lo: jax.Array, n_buckets: int,
                         fp_bits: int, n_shards: int) -> jax.Array:
    """jnp twin of ``owner_shard_key_pair_np``."""
    fp = fingerprint(hi, lo, fp_bits)
    i1 = index_hash(hi, lo, n_buckets)
    return owner_shard_pair(i1, fp, n_buckets, n_shards)


def key_to_u32_pair(keys: jax.Array) -> tuple[jax.Array, jax.Array]:
    """JAX version.  Accepts uint32 (hi=0) or uint64-packed-in-2xuint32 input.

    On CPU hosts we allow uint64 input (x64 may be off, so we accept int64 /
    uint64 via two uint32 views); inside TPU programs callers pass pairs.
    """
    if keys.dtype in (jnp.uint32, jnp.int32):
        lo = keys.astype(jnp.uint32)
        hi = jnp.zeros_like(lo)
        return hi, lo
    k = keys.astype(jnp.uint64)
    lo = (k & jnp.uint64(0xFFFFFFFF)).astype(jnp.uint32)
    hi = (k >> jnp.uint64(32)).astype(jnp.uint32)
    return hi, lo
