"""`FilterOps` — the single backend-dispatched filter data plane.

Every consumer of the cuckoo-filter data plane goes through this layer: the
OCF control plane (``core.ocf``), the serving prefix-cache index
(``serving.kvcache``), and the sharded lookup path (``core.distributed``).
One ``backend`` flag flips the whole stack:

  * ``"jnp"``    — the pure-jnp jitted bulk ops (``core.filter``): XLA
                   gather/scatter lookups, optimistic parallel insert round
                   with a mask-driven lax.scan eviction fallback.
  * ``"pallas"`` — the fused TPU kernels (``kernels.probe`` for lookups,
                   ``kernels.insert`` for inserts, ``kernels.delete`` for
                   deletes): hash and probe fused so each key is read from
                   HBM once, table VMEM-resident, active capacity as an SMEM
                   scalar.  Since PR 3 the WHOLE insert stays on-device —
                   the contended residue is resolved by bounded eviction
                   rounds inside the insert kernel (``evict_rounds``), and
                   deletes run through the fused first-match-slot kernel;
                   nothing on this backend touches the lax.scan path.
  * ``"auto"``   — pallas on TPU when the table fits the kernel VMEM budget,
                   jnp otherwise (CPU hosts interpret Pallas, which is only
                   worth it for validation, not throughput).

All ops speak (hi, lo) uint32 key pairs and the dynamic-capacity
``FilterState`` (active ``n_buckets`` inside a preallocated pow2 buffer), so
a single FilterOps instance serves every resize the OCF schedule produces
with a warm jit cache.  Both backends implement the *same* hash spec
(``core.hashing`` — the kernels import it directly) and are parity-tested
bit-for-bit against each other and the ``pyfilter`` oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.core import filter as jfilter
from repro.kernels import ops as kops
from repro.kernels import ref as kref

Backend = Literal["jnp", "pallas", "auto"]


def evict_rounds_for_load(load: float) -> int:
    """Eviction-round budget for a target operating load, pow2-rounded.

    Cuckoo insert chains lengthen roughly like 1/(1 - load) as the table
    fills; budgeting ``4 / (1 - load)`` rounds and rounding up to a power
    of two gives the empirically validated points — 32 rounds drains random
    batches at the OCF's default ``o_max = 0.85``, the 0.9-load parity
    tests need 64, and 0.95 maps to 128.  Pow2 rounding keeps the jit cache
    small (the budget is a static kernel parameter).  Clamped to [8, 256]:
    below that chains barely exist, above it the per-lane rollback history
    VMEM cost outgrows what a stash + rotate/grow handles better.
    """
    load = min(max(load, 0.0), 0.97)
    need = 4.0 / (1.0 - load)
    r = 8
    while r < need and r < 256:
        r <<= 1
    return r


@dataclasses.dataclass(frozen=True)
class FilterOps:
    """Backend-dispatched lookup / insert / delete / rebuild entry points.

    ``max_disp`` bounds the sequential eviction chain of the jnp backend;
    ``evict_rounds`` bounds the device-side eviction rounds of the pallas
    insert kernel (its while_loop exits early, so the bound only costs VMEM
    for the per-lane rollback history) and defaults to the budget derived
    from the 0.85 operating load (``evict_rounds_for_load``).  Both exhaust
    the same way: the overflowing key reports False with the table rolled
    back, and the OCF control plane grows + rebuilds from the keystore.

    The ``*_with_stash`` / ``insert_spill`` entry points add the overflow
    stash (``kernels/stash.py``): exhausted chains park in a fixed-size
    device-resident side table instead of failing, and lookups check it in
    the same fused pass — the streaming subsystem's burst escape hatch
    (``repro.streaming``).
    """

    fp_bits: int = 16
    max_disp: int = 500
    backend: Backend = "auto"
    # None -> derived from the OCF's default o_max=0.85 operating load
    # (= 32 rounds); pass evict_rounds_for_load(o_max) for other loads, the
    # way OcfConfig.make_filter_ops does.
    evict_rounds: Optional[int] = None
    # Conflict-aware wave scheduling of insert batches (core/scheduling.py):
    # dispatch lanes wave-major by home bucket so blocks meet fewer rank
    # races / eviction rounds.  Off by default — the pre-pass permutes the
    # table layout relative to an unscheduled run, which callers comparing
    # tables bit-for-bit across backends must not enable.  The control
    # planes (OcfConfig / GenerationConfig) turn it on.
    schedule: bool = False
    # Buffer donation: mutating ops consume the caller's table (and stash)
    # buffers so XLA updates them in place instead of copying the pow2
    # buffer every batch.  ONLY for callers that own their buffers and
    # never reuse a pre-op array (the control planes qualify; a benchmark
    # re-inserting into one base state does not).
    donate: bool = False

    def __post_init__(self):
        assert self.backend in ("jnp", "pallas", "auto"), (
            f"unknown filter backend {self.backend!r} "
            "(expected 'jnp' | 'pallas' | 'auto')")
        if self.evict_rounds is None:
            object.__setattr__(self, "evict_rounds",
                               evict_rounds_for_load(0.85))

    # -------------------------------------------------------- dispatch --

    def resolve(self, table: jax.Array, *, stash_slots: int = 0) -> str:
        """Concrete backend for this table ('auto' -> hardware decision)."""
        return self.resolve_bytes(table.size * 4, stash_slots=stash_slots)

    def resolve_bytes(self, table_bytes: int, *, stash_slots: int = 0) -> str:
        """Concrete backend for a table of this size ('auto' -> hardware
        decision).

        Budgets against the insert kernel's footprint — the most demanding
        of the three (aliased table + dirty bitmap + eviction history, plus
        the stash match/spill working set when the caller attaches one) —
        so one FilterOps never splits a workload across backends
        mid-stream.  The stash-aware entry points pass ``stash_slots``;
        an explicit 'pallas'/'jnp' backend skips the budget (caller's
        call, same as ``use_pallas='always'``).
        """
        if self.backend != "auto":
            return self.backend
        # Budget with the block the kernel would actually run at (the
        # autotuner only returns budget-fitting candidates), not a fixed
        # 1024 — otherwise 'auto' rejects mid-size tables whose [B, B]
        # rank term the autotuned block was chosen to shrink.
        block = kops.autotune_block("insert", table_bytes=table_bytes,
                                    evict_rounds=self.evict_rounds,
                                    stash_slots=stash_slots)
        if kops._on_tpu() and kops.kernel_vmem_bytes(
                "insert", table_bytes=table_bytes, block=block,
                evict_rounds=self.evict_rounds,
                stash_slots=stash_slots) <= kops.VMEM_TABLE_BUDGET:
            return "pallas"
        return "jnp"

    # ------------------------------------------------------------- ops --

    def lookup(self, state: jfilter.FilterState, hi: jax.Array,
               lo: jax.Array) -> jax.Array:
        """Membership for a batch -> bool[N]."""
        if self.resolve(state.table) == "pallas":
            return kops.probe_dispatch(state.table, hi, lo,
                                       fp_bits=self.fp_bits,
                                       n_buckets=state.n_buckets)
        return jfilter.bulk_lookup(state, hi, lo, fp_bits=self.fp_bits)

    def insert(self, state: jfilter.FilterState, hi: jax.Array,
               lo: jax.Array, valid: Optional[jax.Array] = None
               ) -> tuple[jfilter.FilterState, jax.Array]:
        """Bulk insert -> (state, ok[N]).

        pallas: ONE fused kernel pass — optimistic rounds plus bounded
        device-side eviction rounds for the contended residue; no lax.scan
        fallback, no host sync.  jnp: the hybrid optimistic-round +
        eviction-chain-scan path.  Either way a key that exhausts its
        budget reports False with the table rolled back (never corrupted).
        """
        if self.resolve(state.table) == "pallas":
            table, ok = kops.filter_insert(
                state.table, hi, lo, fp_bits=self.fp_bits,
                n_buckets=state.n_buckets, valid=valid,
                evict_rounds=self.evict_rounds, use_pallas="always",
                schedule=self.schedule, donate=self.donate)
            return jfilter.FilterState(
                table, state.count + jnp.sum(ok, dtype=jnp.int32),
                state.n_buckets), ok
        # Donation is a kernel-pipeline feature: wrapping the already-jitted
        # hybrid in a donating outer jit measured ~10% SLOWER on CPU (the
        # rewrap costs more than the one table copy it saves), so the jnp
        # arm stays undonated.
        return jfilter.bulk_insert_hybrid(state, hi, lo, fp_bits=self.fp_bits,
                                          max_disp=self.max_disp, valid=valid)

    # ------------------------------------------------- stash-aware ops --

    def lookup_with_stash(self, state: jfilter.FilterState,
                          stash: jax.Array, hi: jax.Array,
                          lo: jax.Array) -> jax.Array:
        """Membership against table AND overflow stash -> bool[N].

        pallas: the probe kernel checks the stash in the same fused pass.
        jnp: table probe OR'd with the jnp stash match — identical answers.
        """
        if self.resolve(state.table,
                        stash_slots=stash.shape[1]) == "pallas":
            return kops.probe_dispatch(state.table, hi, lo,
                                       fp_bits=self.fp_bits,
                                       n_buckets=state.n_buckets,
                                       stash=stash)
        return kops.filter_lookup(state.table, hi, lo, fp_bits=self.fp_bits,
                                  n_buckets=state.n_buckets, stash=stash,
                                  use_pallas="never")

    def insert_spill(self, state: jfilter.FilterState, stash: jax.Array,
                     hi: jax.Array, lo: jax.Array,
                     valid: Optional[jax.Array] = None
                     ) -> tuple[jfilter.FilterState, jax.Array, jax.Array]:
        """Bulk insert that spills overflow to the stash
        -> (state, stash, ok[N]).

        ``ok`` goes False only when table eviction budget AND stash are both
        exhausted — the streaming layer answers that with a generation
        rotation instead of the OCF's grow+rebuild.  ``state.count`` tracks
        table-resident fingerprints only; stashed entries are counted by
        ``kops.stash_occupancy`` so occupancy math stays honest.
        """
        spilled_before = kops.stash_occupancy(stash)
        up = ("always" if self.resolve(state.table,
                                       stash_slots=stash.shape[1])
              == "pallas" else "never")
        table, new_stash, ok = kops.filter_insert(
            state.table, hi, lo, fp_bits=self.fp_bits,
            n_buckets=state.n_buckets, valid=valid,
            evict_rounds=self.evict_rounds, stash=stash,
            max_disp=self.max_disp, use_pallas=up,
            schedule=self.schedule, donate=self.donate)
        newly_stashed = kops.stash_occupancy(new_stash) - spilled_before
        count = state.count + jnp.sum(ok, dtype=jnp.int32) - newly_stashed
        return jfilter.FilterState(table, count, state.n_buckets), \
            new_stash, ok

    def delete(self, state: jfilter.FilterState, hi: jax.Array,
               lo: jax.Array, valid: Optional[jax.Array] = None
               ) -> tuple[jfilter.FilterState, jax.Array]:
        """Verified bulk delete -> (state, ok[N]).

        pallas: the fused first-match-slot kernel (``kernels.delete``).
        jnp: the sequential lax.scan path.  Both rank duplicate keys so the
        k-th duplicate clears the k-th resident copy; callers pre-verify
        membership against the keystore (the OCF control plane does)."""
        if self.resolve(state.table) == "pallas":
            table, ok = kops.filter_delete(
                state.table, hi, lo, fp_bits=self.fp_bits,
                n_buckets=state.n_buckets, valid=valid, use_pallas="always",
                donate=self.donate)
            return jfilter.FilterState(
                table, state.count - jnp.sum(ok, dtype=jnp.int32),
                state.n_buckets), ok
        return jfilter.bulk_delete(state, hi, lo, fp_bits=self.fp_bits,
                                   valid=valid)

    def rebuild(self, hi: jax.Array, lo: jax.Array, n_buckets: int,
                bucket_size: int, *, buffer_buckets: Optional[int] = None,
                valid: Optional[jax.Array] = None
                ) -> tuple[jfilter.FilterState, jax.Array]:
        """Re-insert a keystore batch into a fresh table (resize path)."""
        state = jfilter.make_state(n_buckets, bucket_size,
                                   buffer_buckets=buffer_buckets)
        return self.insert(state, hi, lo, valid=valid)

    def fanout_prober(self, tables: jax.Array, stashes: jax.Array, *,
                      n_buckets):
        """Dispatch-resolved fan-out closure -> callable (hi, lo) -> bool[N].

        Membership across K stacked generations: ``tables`` is
        uint32[K, buffer_buckets, bucket_size] (the generation ring's pool
        buffers stacked), ``stashes`` uint32[K, 2, S], ``n_buckets`` the
        generations' shared active count.  pallas: ONE fused
        ``probe_multi`` launch whose grid spans every generation (keys
        hashed once); jnp: the per-generation probe/stash loop with
        identical answers.  Block size, VMEM budget, and dispatch arm are
        pinned once — the generation ring caches the closure across a
        batch's chunks (per-chunk re-derivation costs ~15% of a chunk on
        the serving hot path).
        """
        per_bytes = (tables.size // tables.shape[0]) * 4
        up = ("always" if self.resolve_bytes(
            per_bytes, stash_slots=stashes.shape[2]) == "pallas" else "never")
        return kops.multi_prober(tables, fp_bits=self.fp_bits,
                                 n_buckets=n_buckets, stashes=stashes,
                                 use_pallas=up)

    # ---------------------------------------------------- adaptive ops --
    #
    # Selector-aware entry points over the four-plane adaptive state
    # (``adaptive.state.AdaptiveState`` — duck-typed here to keep core free
    # of an adaptive import: anything with table/sels/khi/klo/count/
    # n_buckets fields and NamedTuple ``_replace`` works).  The planes ride
    # together through the fused kernels; there is no separate jnp oracle —
    # the XLA grid emulation of the same kernel body is the non-pallas arm,
    # so both backends are bit-for-bit by construction.

    def _adaptive_up(self, state, *, stash_slots: int = 0) -> str:
        bytes_ = 3 * state.table.size * 4 + state.table.shape[0] * 4
        return ("always" if self.resolve_bytes(
            bytes_, stash_slots=stash_slots) == "pallas" else "never")

    def lookup_adaptive(self, state, hi: jax.Array, lo: jax.Array,
                        stash: Optional[jax.Array] = None) -> jax.Array:
        """Selector-aware membership -> bool[N].

        A slot answers under ITS selector, so a repaired slot no longer
        hits the reported query; stash entries are selector-0 and are
        checked in the same pass when attached.
        """
        slots = 0 if stash is None else stash.shape[1]
        return kops.adaptive_lookup(
            state.table, state.sels, hi, lo, fp_bits=self.fp_bits,
            n_buckets=state.n_buckets, stash=stash,
            use_pallas=self._adaptive_up(state, stash_slots=slots))

    def insert_adaptive(self, state, hi: jax.Array, lo: jax.Array,
                        valid: Optional[jax.Array] = None,
                        stash: Optional[jax.Array] = None):
        """Bulk insert over the adaptive planes -> (state, ok[N]) or
        (state, stash, ok[N]).

        New entries land as selector-0 slots with the key mirrored into
        khi/klo; kicks reset the victim's selector (its adaptation is the
        price of movement — the standard adaptive-cuckoo trade) and
        rollback restores all four planes verbatim.
        """
        slots = 0 if stash is None else stash.shape[1]
        if stash is not None:
            spilled_before = kops.stash_occupancy(stash)
        out = kops.adaptive_insert(
            state.table, state.sels, state.khi, state.klo, hi, lo,
            fp_bits=self.fp_bits, n_buckets=state.n_buckets, valid=valid,
            evict_rounds=self.evict_rounds, stash=stash,
            use_pallas=self._adaptive_up(state, stash_slots=slots),
            schedule=self.schedule, donate=self.donate)
        ok = out[-1]
        count = state.count + jnp.sum(ok, dtype=jnp.int32)
        if stash is None:
            table, sels, khi, klo = out[:4]
            return state._replace(table=table, sels=sels, khi=khi, klo=klo,
                                  count=count), ok
        table, sels, khi, klo, new_stash = out[:5]
        count = count - (kops.stash_occupancy(new_stash) - spilled_before)
        return state._replace(table=table, sels=sels, khi=khi, klo=klo,
                              count=count), new_stash, ok

    def delete_adaptive(self, state, hi: jax.Array, lo: jax.Array,
                        valid: Optional[jax.Array] = None,
                        stash: Optional[jax.Array] = None):
        """Verified bulk delete -> (state, ok[N]) or (state, stash, ok[N]).

        Slots match under THEIR selector, so adapted residents stay
        deletable by key; clearing zeroes all four planes.  With a stash,
        lanes that miss the table clear their selector-0 stash entry in the
        composed jnp pass, same order as the static path.
        """
        out = kops.adaptive_delete(
            state.table, state.sels, state.khi, state.klo, hi, lo,
            fp_bits=self.fp_bits, n_buckets=state.n_buckets, valid=valid,
            stash=stash, use_pallas=self._adaptive_up(state),
            donate=self.donate)
        ok = out[-1]
        if stash is None:
            table, sels, khi, klo = out[:4]
            count = state.count - jnp.sum(ok, dtype=jnp.int32)
            return state._replace(table=table, sels=sels, khi=khi, klo=klo,
                                  count=count), ok
        table, sels, khi, klo, new_stash = out[:5]
        stash_cleared = (kops.stash_occupancy(stash)
                         - kops.stash_occupancy(new_stash))
        count = state.count - jnp.sum(ok, dtype=jnp.int32) + stash_cleared
        return state._replace(table=table, sels=sels, khi=khi, klo=klo,
                              count=count), new_stash, ok

    def report_false_positive(self, state, hi: jax.Array, lo: jax.Array,
                              valid: Optional[jax.Array] = None):
        """Feed confirmed false positives back -> (state, adapted[N],
        resident[N]).

        Every slot in a reported key's candidate pair whose stored
        fingerprint collides under that slot's selector is bumped to its
        next family member and rewritten from the mirrored resident key —
        the entry never moves, so no false negative can be introduced.
        ``resident`` flags reports that were actually true positives (never
        repaired); ``adapted`` lanes stop colliding with probability
        1 - 2^-fp_bits per future query.  Stash-resident collisions cannot
        adapt (the stash has no selector) — repeat offenders are the
        reputation tier's job (``adaptive.reputation``).
        """
        table, sels, adapted, resident = kops.adaptive_report(
            state.table, state.sels, state.khi, state.klo, hi, lo,
            fp_bits=self.fp_bits, n_buckets=state.n_buckets, valid=valid)
        return state._replace(table=table, sels=sels), adapted, resident

    # --------------------------------------------------- raw-table ops --
    #
    # Stateless entry points over a bare uint32[n_buckets, bucket_size]
    # table (plus optional stash): what ``core.distributed`` runs *inside*
    # shard_map, where there is no FilterState — the shard's table slice IS
    # the state.  Same backend dispatch as the stateful ops; donation is
    # deliberately NOT threaded here (always ``donate=False`` on the inner
    # kernels) because inside a shard_map body the arrays are tracers — the
    # zero-copy update belongs to the *enclosing* jit, which
    # ``distributed_insert``/``distributed_delete`` donate whole.

    def probe_table(self, table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                    n_buckets=None, stash=None) -> jax.Array:
        """Membership probe on a raw table (distributed shards / replicas).

        Same dispatch as ``lookup`` but stateless — ``core.distributed``
        probes stacked per-shard tables inside shard_map with this.  With a
        ``stash`` the shard's overflow entries answer in the same pass
        (fused on the kernel arm), so routed lookups see spilled keys.
        """
        slots = 0 if stash is None else stash.shape[1]
        if self.resolve(table, stash_slots=slots) == "pallas":
            return kops.filter_lookup(table, hi, lo, fp_bits=self.fp_bits,
                                      n_buckets=n_buckets, stash=stash,
                                      use_pallas="always")
        if stash is None:
            return kref.probe_ref(table, hi, lo, fp_bits=self.fp_bits,
                                  n_buckets=n_buckets)
        return kops.filter_lookup(table, hi, lo, fp_bits=self.fp_bits,
                                  n_buckets=n_buckets, stash=stash,
                                  use_pallas="never")

    def insert_table(self, table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                     n_buckets=None, valid: Optional[jax.Array] = None,
                     stash=None):
        """Raw-table bulk insert -> (table, ok[N]) or (table, stash, ok[N]).

        The shard-local write the routed distributed insert runs on-device:
        optimistic rounds + bounded eviction chains + stash spill, scheduled
        when ``self.schedule`` — identical machinery to ``insert`` /
        ``insert_spill`` minus the FilterState bookkeeping (shards count
        occupancy from the table itself).
        """
        slots = 0 if stash is None else stash.shape[1]
        up = ("always" if self.resolve(table, stash_slots=slots) == "pallas"
              else "never")
        return kops.filter_insert(table, hi, lo, fp_bits=self.fp_bits,
                                  n_buckets=n_buckets, valid=valid,
                                  evict_rounds=self.evict_rounds,
                                  stash=stash, max_disp=self.max_disp,
                                  use_pallas=up, schedule=self.schedule)

    def delete_table(self, table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                     n_buckets=None, valid: Optional[jax.Array] = None,
                     stash=None):
        """Raw-table verified delete -> (table, ok[N]) or
        (table, stash, ok[N]).

        Fused first-match-slot clear; with a ``stash``, lanes that miss the
        table clear their spilled entry (table copies first — the
        sequential order), so a burst-parked key is deletable like any
        other.
        """
        up = "always" if self.resolve(table) == "pallas" else "never"
        return kops.filter_delete(table, hi, lo, fp_bits=self.fp_bits,
                                  n_buckets=n_buckets, valid=valid,
                                  stash=stash, use_pallas=up)

    # --------------------------------------------------- telemetry twins --
    #
    # Each ``*_tm`` method is the corresponding op plus a device-computed
    # ``kernels.telemetry.FilterTelemetry`` (kick-depth histogram, probe
    # hit-depth, spill / rollback / delete counters, stash high-water).
    # The twins pin the KERNEL arm (the XLA emulation of the kernel
    # schedule — bit-for-bit the pallas_call by the PR-5 parity contract)
    # and compile as separate jits, so:
    #   * answers never depend on whether counters are on, and
    #   * the telemetry-off methods above keep their exact dispatch —
    #     nothing here runs unless a caller asks for telemetry.
    # They are NOT in the hot path's method bodies on purpose: the
    # dispatch-spy tier-1 test pins the off path to the pre-telemetry
    # device-call sequence.

    def lookup_tm(self, state: jfilter.FilterState, hi: jax.Array,
                  lo: jax.Array):
        """``lookup`` + telemetry -> (hit[N], FilterTelemetry)."""
        return kops.probe_dispatch_tm(state.table, hi, lo,
                                      fp_bits=self.fp_bits,
                                      n_buckets=state.n_buckets)

    def lookup_with_stash_tm(self, state: jfilter.FilterState,
                             stash: jax.Array, hi: jax.Array, lo: jax.Array):
        """``lookup_with_stash`` + telemetry -> (hit[N], FilterTelemetry)."""
        return kops.probe_dispatch_tm(state.table, hi, lo,
                                      fp_bits=self.fp_bits,
                                      n_buckets=state.n_buckets, stash=stash)

    def insert_tm(self, state: jfilter.FilterState, hi: jax.Array,
                  lo: jax.Array, valid: Optional[jax.Array] = None):
        """``insert`` + telemetry -> (state, ok[N], FilterTelemetry)."""
        table, ok, tm = kops.filter_insert_tm(
            state.table, hi, lo, fp_bits=self.fp_bits,
            n_buckets=state.n_buckets, valid=valid,
            evict_rounds=self.evict_rounds, schedule=self.schedule,
            donate=self.donate)
        return jfilter.FilterState(
            table, state.count + jnp.sum(ok, dtype=jnp.int32),
            state.n_buckets), ok, tm

    def insert_spill_tm(self, state: jfilter.FilterState, stash: jax.Array,
                        hi: jax.Array, lo: jax.Array,
                        valid: Optional[jax.Array] = None):
        """``insert_spill`` + telemetry -> (state, stash, ok[N], tm)."""
        spilled_before = kops.stash_occupancy(stash)
        table, new_stash, ok, tm = kops.filter_insert_tm(
            state.table, hi, lo, fp_bits=self.fp_bits,
            n_buckets=state.n_buckets, valid=valid,
            evict_rounds=self.evict_rounds, stash=stash,
            schedule=self.schedule, donate=self.donate)
        newly_stashed = kops.stash_occupancy(new_stash) - spilled_before
        count = state.count + jnp.sum(ok, dtype=jnp.int32) - newly_stashed
        return jfilter.FilterState(table, count, state.n_buckets), \
            new_stash, ok, tm

    def delete_tm(self, state: jfilter.FilterState, hi: jax.Array,
                  lo: jax.Array, valid: Optional[jax.Array] = None):
        """``delete`` + telemetry -> (state, ok[N], FilterTelemetry)."""
        table, ok, tm = kops.filter_delete_tm(
            state.table, hi, lo, fp_bits=self.fp_bits,
            n_buckets=state.n_buckets, valid=valid, donate=self.donate)
        return jfilter.FilterState(
            table, state.count - jnp.sum(ok, dtype=jnp.int32),
            state.n_buckets), ok, tm

    def lookup_adaptive_tm(self, state, hi: jax.Array, lo: jax.Array,
                           stash: Optional[jax.Array] = None):
        """``lookup_adaptive`` + telemetry -> (hit[N], FilterTelemetry)."""
        return kops.adaptive_lookup_tm(
            state.table, state.sels, hi, lo, fp_bits=self.fp_bits,
            n_buckets=state.n_buckets, stash=stash)

    def insert_adaptive_tm(self, state, hi: jax.Array, lo: jax.Array,
                           valid: Optional[jax.Array] = None,
                           stash: Optional[jax.Array] = None):
        """``insert_adaptive`` + telemetry -> (..., ok[N], tm)."""
        if stash is not None:
            spilled_before = kops.stash_occupancy(stash)
        out = kops.adaptive_insert_tm(
            state.table, state.sels, state.khi, state.klo, hi, lo,
            fp_bits=self.fp_bits, n_buckets=state.n_buckets, valid=valid,
            evict_rounds=self.evict_rounds, stash=stash,
            schedule=self.schedule, donate=self.donate)
        tm = out[-1]
        ok = out[-2]
        count = state.count + jnp.sum(ok, dtype=jnp.int32)
        if stash is None:
            table, sels, khi, klo = out[:4]
            return state._replace(table=table, sels=sels, khi=khi, klo=klo,
                                  count=count), ok, tm
        table, sels, khi, klo, new_stash = out[:5]
        count = count - (kops.stash_occupancy(new_stash) - spilled_before)
        return state._replace(table=table, sels=sels, khi=khi, klo=klo,
                              count=count), new_stash, ok, tm

    def delete_adaptive_tm(self, state, hi: jax.Array, lo: jax.Array,
                           valid: Optional[jax.Array] = None,
                           stash: Optional[jax.Array] = None):
        """``delete_adaptive`` + telemetry -> (..., ok[N], tm)."""
        out = kops.adaptive_delete_tm(
            state.table, state.sels, state.khi, state.klo, hi, lo,
            fp_bits=self.fp_bits, n_buckets=state.n_buckets, valid=valid,
            stash=stash, donate=self.donate)
        tm = out[-1]
        ok = out[-2]
        if stash is None:
            table, sels, khi, klo = out[:4]
            count = state.count - jnp.sum(ok, dtype=jnp.int32)
            return state._replace(table=table, sels=sels, khi=khi, klo=klo,
                                  count=count), ok, tm
        table, sels, khi, klo, new_stash = out[:5]
        stash_cleared = (kops.stash_occupancy(stash)
                         - kops.stash_occupancy(new_stash))
        count = state.count - jnp.sum(ok, dtype=jnp.int32) + stash_cleared
        return state._replace(table=table, sels=sels, khi=khi, klo=klo,
                              count=count), new_stash, ok, tm

    def report_false_positive_tm(self, state, hi: jax.Array, lo: jax.Array,
                                 valid: Optional[jax.Array] = None):
        """``report_false_positive`` + telemetry (``selector_bumps``)."""
        table, sels, adapted, resident, tm = kops.adaptive_report_tm(
            state.table, state.sels, state.khi, state.klo, hi, lo,
            fp_bits=self.fp_bits, n_buckets=state.n_buckets, valid=valid)
        return state._replace(table=table, sels=sels), adapted, resident, tm
