"""`FilterOps` — the single backend-dispatched filter data plane.

Every consumer of the cuckoo-filter data plane goes through this layer: the
OCF control plane (``core.ocf``), the serving prefix-cache index
(``serving.kvcache``), and the sharded lookup path (``core.distributed``).
One ``backend`` flag flips the whole stack:

  * ``"jnp"``    — the pure-jnp jitted bulk ops (``core.filter``): XLA
                   gather/scatter lookups, optimistic parallel insert round
                   with a mask-driven lax.scan eviction fallback.
  * ``"pallas"`` — the fused TPU kernels (``kernels.probe`` for lookups,
                   ``kernels.insert`` for the optimistic insert round): hash
                   and probe fused so each key is read from HBM once, table
                   VMEM-resident, active capacity as an SMEM scalar.  The
                   eviction-chain fallback and deletes still run on the
                   lax.scan path — device-side eviction chains are an open
                   kernel gap (ROADMAP "Open items").
  * ``"auto"``   — pallas on TPU when the table fits the kernel VMEM budget,
                   jnp otherwise (CPU hosts interpret Pallas, which is only
                   worth it for validation, not throughput).

All ops speak (hi, lo) uint32 key pairs and the dynamic-capacity
``FilterState`` (active ``n_buckets`` inside a preallocated pow2 buffer), so
a single FilterOps instance serves every resize the OCF schedule produces
with a warm jit cache.  Both backends implement the *same* hash spec
(``core.hashing`` — the kernels import it directly) and are parity-tested
bit-for-bit against each other and the ``pyfilter`` oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.core import filter as jfilter
from repro.kernels import ops as kops
from repro.kernels import ref as kref

Backend = Literal["jnp", "pallas", "auto"]


@dataclasses.dataclass(frozen=True)
class FilterOps:
    """Backend-dispatched lookup / insert / delete / rebuild entry points."""

    fp_bits: int = 16
    max_disp: int = 500
    backend: Backend = "auto"

    def __post_init__(self):
        assert self.backend in ("jnp", "pallas", "auto"), (
            f"unknown filter backend {self.backend!r} "
            "(expected 'jnp' | 'pallas' | 'auto')")

    # -------------------------------------------------------- dispatch --

    def resolve(self, table: jax.Array) -> str:
        """Concrete backend for this table ('auto' -> hardware decision)."""
        if self.backend != "auto":
            return self.backend
        if kops._on_tpu() and table.size * 4 <= kops.VMEM_TABLE_BUDGET:
            return "pallas"
        return "jnp"

    # ------------------------------------------------------------- ops --

    def lookup(self, state: jfilter.FilterState, hi: jax.Array,
               lo: jax.Array) -> jax.Array:
        """Membership for a batch -> bool[N]."""
        if self.resolve(state.table) == "pallas":
            return kops.filter_lookup(state.table, hi, lo,
                                      fp_bits=self.fp_bits,
                                      n_buckets=state.n_buckets,
                                      use_pallas="always")
        return jfilter.bulk_lookup(state, hi, lo, fp_bits=self.fp_bits)

    def insert(self, state: jfilter.FilterState, hi: jax.Array,
               lo: jax.Array, valid: Optional[jax.Array] = None
               ) -> tuple[jfilter.FilterState, jax.Array]:
        """Hybrid insert -> (state, ok[N]).

        Optimistic single round on the chosen backend, then the residue mask
        drives the eviction-chain scan on device — no host sync in between.
        """
        if self.resolve(state.table) == "pallas":
            if valid is None:
                valid = jnp.ones(hi.shape, bool)
            table, placed = kops.filter_insert(
                state.table, hi, lo, fp_bits=self.fp_bits,
                n_buckets=state.n_buckets, valid=valid, use_pallas="always")
            mid = jfilter.FilterState(
                table, state.count + jnp.sum(placed, dtype=jnp.int32),
                state.n_buckets)
            state2, ok2 = jfilter.bulk_insert(
                mid, hi, lo, fp_bits=self.fp_bits, max_disp=self.max_disp,
                valid=valid & ~placed)
            return state2, placed | ok2
        return jfilter.bulk_insert_hybrid(state, hi, lo, fp_bits=self.fp_bits,
                                          max_disp=self.max_disp, valid=valid)

    def delete(self, state: jfilter.FilterState, hi: jax.Array,
               lo: jax.Array, valid: Optional[jax.Array] = None
               ) -> tuple[jfilter.FilterState, jax.Array]:
        """Verified bulk delete -> (state, ok[N]).

        Always the lax.scan path — a fused delete kernel is an open item
        (deletes are rare on the serving path relative to probes)."""
        return jfilter.bulk_delete(state, hi, lo, fp_bits=self.fp_bits,
                                   valid=valid)

    def rebuild(self, hi: jax.Array, lo: jax.Array, n_buckets: int,
                bucket_size: int, *, buffer_buckets: Optional[int] = None,
                valid: Optional[jax.Array] = None
                ) -> tuple[jfilter.FilterState, jax.Array]:
        """Re-insert a keystore batch into a fresh table (resize path)."""
        state = jfilter.make_state(n_buckets, bucket_size,
                                   buffer_buckets=buffer_buckets)
        return self.insert(state, hi, lo, valid=valid)

    # ------------------------------------------------- raw-table probes --

    def probe_table(self, table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                    n_buckets=None) -> jax.Array:
        """Membership probe on a raw table (distributed shards / replicas).

        Same dispatch as ``lookup`` but stateless — ``core.distributed``
        probes stacked per-shard tables inside shard_map with this.
        """
        if self.resolve(table) == "pallas":
            return kops.filter_lookup(table, hi, lo, fp_bits=self.fp_bits,
                                      n_buckets=n_buckets,
                                      use_pallas="always")
        return kref.probe_ref(table, hi, lo, fp_bits=self.fp_bits,
                              n_buckets=n_buckets)
