"""`FilterOps` — the single backend-dispatched filter data plane.

Every consumer of the cuckoo-filter data plane goes through this layer: the
OCF control plane (``core.ocf``), the serving prefix-cache index
(``serving.kvcache``), and the sharded lookup path (``core.distributed``).
One ``backend`` flag flips the whole stack:

  * ``"jnp"``    — the pure-jnp jitted bulk ops (``core.filter``): XLA
                   gather/scatter lookups, optimistic parallel insert round
                   with a mask-driven lax.scan eviction fallback.
  * ``"pallas"`` — the fused TPU kernels (``kernels.probe`` for lookups,
                   ``kernels.insert`` for inserts, ``kernels.delete`` for
                   deletes): hash and probe fused so each key is read from
                   HBM once, table VMEM-resident, active capacity as an SMEM
                   scalar.  Since PR 3 the WHOLE insert stays on-device —
                   the contended residue is resolved by bounded eviction
                   rounds inside the insert kernel (``evict_rounds``), and
                   deletes run through the fused first-match-slot kernel;
                   nothing on this backend touches the lax.scan path.
  * ``"auto"``   — pallas on TPU when the table fits the kernel VMEM budget,
                   jnp otherwise (CPU hosts interpret Pallas, which is only
                   worth it for validation, not throughput).

All ops speak (hi, lo) uint32 key pairs and the dynamic-capacity
``FilterState`` (active ``n_buckets`` inside a preallocated pow2 buffer), so
a single FilterOps instance serves every resize the OCF schedule produces
with a warm jit cache.  Both backends implement the *same* hash spec
(``core.hashing`` — the kernels import it directly) and are parity-tested
bit-for-bit against each other and the ``pyfilter`` oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.core import filter as jfilter
from repro.kernels import ops as kops
from repro.kernels import ref as kref

Backend = Literal["jnp", "pallas", "auto"]


@dataclasses.dataclass(frozen=True)
class FilterOps:
    """Backend-dispatched lookup / insert / delete / rebuild entry points.

    ``max_disp`` bounds the sequential eviction chain of the jnp backend;
    ``evict_rounds`` bounds the device-side eviction rounds of the pallas
    insert kernel (its while_loop exits early, so the bound only costs VMEM
    for the per-lane rollback history).  Both exhaust the same way: the
    overflowing key reports False with the table rolled back, and the OCF
    control plane grows + rebuilds from the keystore.
    """

    fp_bits: int = 16
    max_disp: int = 500
    backend: Backend = "auto"
    # Literal (not kops.DEFAULT_EVICT_ROUNDS): entry points that import the
    # kernel package first would hit it partially initialized here.
    evict_rounds: int = 32

    def __post_init__(self):
        assert self.backend in ("jnp", "pallas", "auto"), (
            f"unknown filter backend {self.backend!r} "
            "(expected 'jnp' | 'pallas' | 'auto')")

    # -------------------------------------------------------- dispatch --

    def resolve(self, table: jax.Array) -> str:
        """Concrete backend for this table ('auto' -> hardware decision).

        Budgets against the insert kernel's footprint — the most demanding
        of the three (aliased table + dirty bitmap + eviction history) — so
        one FilterOps never splits a workload across backends mid-stream.
        """
        if self.backend != "auto":
            return self.backend
        if kops._on_tpu() and kops.kernel_vmem_bytes(
                "insert", table_bytes=table.size * 4, block=1024,
                evict_rounds=self.evict_rounds) <= kops.VMEM_TABLE_BUDGET:
            return "pallas"
        return "jnp"

    # ------------------------------------------------------------- ops --

    def lookup(self, state: jfilter.FilterState, hi: jax.Array,
               lo: jax.Array) -> jax.Array:
        """Membership for a batch -> bool[N]."""
        if self.resolve(state.table) == "pallas":
            return kops.filter_lookup(state.table, hi, lo,
                                      fp_bits=self.fp_bits,
                                      n_buckets=state.n_buckets,
                                      use_pallas="always")
        return jfilter.bulk_lookup(state, hi, lo, fp_bits=self.fp_bits)

    def insert(self, state: jfilter.FilterState, hi: jax.Array,
               lo: jax.Array, valid: Optional[jax.Array] = None
               ) -> tuple[jfilter.FilterState, jax.Array]:
        """Bulk insert -> (state, ok[N]).

        pallas: ONE fused kernel pass — optimistic rounds plus bounded
        device-side eviction rounds for the contended residue; no lax.scan
        fallback, no host sync.  jnp: the hybrid optimistic-round +
        eviction-chain-scan path.  Either way a key that exhausts its
        budget reports False with the table rolled back (never corrupted).
        """
        if self.resolve(state.table) == "pallas":
            table, ok = kops.filter_insert(
                state.table, hi, lo, fp_bits=self.fp_bits,
                n_buckets=state.n_buckets, valid=valid,
                evict_rounds=self.evict_rounds, use_pallas="always")
            return jfilter.FilterState(
                table, state.count + jnp.sum(ok, dtype=jnp.int32),
                state.n_buckets), ok
        return jfilter.bulk_insert_hybrid(state, hi, lo, fp_bits=self.fp_bits,
                                          max_disp=self.max_disp, valid=valid)

    def delete(self, state: jfilter.FilterState, hi: jax.Array,
               lo: jax.Array, valid: Optional[jax.Array] = None
               ) -> tuple[jfilter.FilterState, jax.Array]:
        """Verified bulk delete -> (state, ok[N]).

        pallas: the fused first-match-slot kernel (``kernels.delete``).
        jnp: the sequential lax.scan path.  Both rank duplicate keys so the
        k-th duplicate clears the k-th resident copy; callers pre-verify
        membership against the keystore (the OCF control plane does)."""
        if self.resolve(state.table) == "pallas":
            table, ok = kops.filter_delete(
                state.table, hi, lo, fp_bits=self.fp_bits,
                n_buckets=state.n_buckets, valid=valid, use_pallas="always")
            return jfilter.FilterState(
                table, state.count - jnp.sum(ok, dtype=jnp.int32),
                state.n_buckets), ok
        return jfilter.bulk_delete(state, hi, lo, fp_bits=self.fp_bits,
                                   valid=valid)

    def rebuild(self, hi: jax.Array, lo: jax.Array, n_buckets: int,
                bucket_size: int, *, buffer_buckets: Optional[int] = None,
                valid: Optional[jax.Array] = None
                ) -> tuple[jfilter.FilterState, jax.Array]:
        """Re-insert a keystore batch into a fresh table (resize path)."""
        state = jfilter.make_state(n_buckets, bucket_size,
                                   buffer_buckets=buffer_buckets)
        return self.insert(state, hi, lo, valid=valid)

    # ------------------------------------------------- raw-table probes --

    def probe_table(self, table: jax.Array, hi: jax.Array, lo: jax.Array, *,
                    n_buckets=None) -> jax.Array:
        """Membership probe on a raw table (distributed shards / replicas).

        Same dispatch as ``lookup`` but stateless — ``core.distributed``
        probes stacked per-shard tables inside shard_map with this.
        """
        if self.resolve(table) == "pallas":
            return kops.filter_lookup(table, hi, lo, fp_bits=self.fp_bits,
                                      n_buckets=n_buckets,
                                      use_pallas="always")
        return kref.probe_ref(table, hi, lo, fp_bits=self.fp_bits,
                              n_buckets=n_buckets)
