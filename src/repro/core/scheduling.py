"""Conflict-aware batch scheduling — the data plane's dispatch pre-pass.

Cuckoo-GPU-style batch filters get their throughput from *batch-level*
scheduling: group the operations of a batch by target bucket before any of
them touches the table, so the table pass itself meets as few intra-batch
conflicts as possible.  This module is that pre-pass, shared by the insert
kernels (`kernels/insert.py` applies it inside the jitted wrapper when
``schedule=True``) and the host control planes (lookup dedup).

Two pieces:

* **Wave construction** (device-side, jittable).  Every lane's home bucket
  is ranked within its equal-bucket group: the k-th lane targeting a bucket
  lands in *wave k*.  Dispatching the batch in (wave, bucket) order means
  each wave is **conflict-free** — at most one lane per bucket — so the
  kernel's placement rounds stop burning rank races and the bounded
  eviction loop stops burning rounds on lanes that lost a one-kick-per-
  bucket lottery.  In-batch repeats of one key (same bucket, same
  fingerprint) are what this deduplicates on the insert path: they are
  pulled apart into consecutive waves instead of colliding in one block.

  The sort is **stable per bucket**: lanes sharing a bucket keep their
  original relative order (their waves ascend with their batch positions),
  so the rank each lane sees inside `_place_round` — "how many earlier
  lanes target my bucket" — is unchanged by the permutation.  Scheduling
  therefore reorders *work*, never *outcomes-by-rank*; the `ok` mask is
  scattered back through the inverse permutation and single-lane residue
  chains stay bit-for-bit identical to the sequential oracle
  (`streaming/oracle.py::PyStashFilter` — tested in
  tests/test_scheduling.py).

* **Lookup dedup** (host-side).  Probes are idempotent, so a batch with
  in-batch repeats only needs one device lane per distinct key;
  ``dedupe_keys`` is the numpy pre-pass the OCF lookup path uses to
  collapse repeats before chunking, with the answers broadcast back
  through the inverse index.  Streams with no repeats pay one ``np.unique``
  sort and lose nothing; dedup-heavy streams (the streaming subsystem's
  whole workload) probe a fraction of their lanes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing

# Invalid (padding) lanes park on a bucket id no real table reaches, so they
# sort behind every real lane and never split a wave.
_PARKED = jnp.int32(1 << 30)


def conflict_waves(bucket: jax.Array, valid: jax.Array) -> jax.Array:
    """Occurrence rank of each lane within its equal-bucket group -> int32[N].

    wave[i] = #earlier valid lanes targeting the same bucket as lane i —
    the wave index the lane dispatches in.  Invalid lanes get wave N (past
    every real wave).  Sort-based (two stable argsorts), no [N, N]
    broadcast-compare: the pre-pass must stay cheap for batches far larger
    than a kernel block.

    This same quantity is the distributed routing rank: with ``bucket`` =
    owner shard, lane i claims slot ``wave[i]`` of its owner's
    capacity-bounded all_to_all row (``core/distributed.py``), and
    ``wave >= cap`` IS the routing-overflow condition — one definition for
    kernel scheduling and shard dispatch.
    """
    n = bucket.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    b = jnp.where(valid, bucket.astype(jnp.int32), _PARKED)
    order = jnp.argsort(b, stable=True)
    sb = b[order]
    new_run = jnp.concatenate([jnp.ones((1,), bool), sb[1:] != sb[:-1]])
    run_start = jax.lax.associative_scan(jnp.maximum,
                                         jnp.where(new_run, idx, 0))
    wave_sorted = idx - run_start
    wave = jnp.zeros((n,), jnp.int32).at[order].set(wave_sorted)
    return jnp.where(valid, wave, jnp.int32(n))


def dispatch_order(hi: jax.Array, lo: jax.Array, valid: jax.Array, *,
                   n_buckets) -> tuple[jax.Array, jax.Array]:
    """Conflict-free-wave dispatch permutation -> (perm, inv), int32[N] each.

    ``perm`` reorders a batch wave-major (wave 0's lanes first, each wave
    holding at most one lane per home bucket; invalid lanes last); ``inv``
    scatters per-lane results back to the caller's order
    (``out[inv] == out_of_original_lane``).  Both sorts are stable, so
    same-bucket lanes keep their original relative order — the property
    that makes scheduling invisible to rank-based placement (see module
    docstring).
    """
    n = hi.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    i1 = hashing.index_hash_dyn(hi, lo, n_buckets).astype(jnp.int32)
    b = jnp.where(valid, i1, _PARKED)
    wave = conflict_waves(i1, valid)
    ord_b = jnp.argsort(b, stable=True)           # bucket-minor ...
    ord_w = jnp.argsort(wave[ord_b], stable=True)  # ... then wave-major
    perm = ord_b[ord_w]
    inv = jnp.zeros((n,), jnp.int32).at[perm].set(idx)
    return perm, inv


def pair_rank(a: jax.Array, b: jax.Array, valid: jax.Array) -> jax.Array:
    """Occurrence rank within equal-``(a, b)`` groups -> int32[N].

    The two-key generalization of ``conflict_waves``: ``rank[i]`` counts
    earlier valid lanes carrying the same (a, b) pair as lane i, in original
    lane order.  Sort-based (two stable argsorts — b-minor then a-major
    brings equal pairs into contiguous runs while ties keep batch order), so
    there is no [N, N] broadcast-compare and it stays cheap for routed
    shard batches far larger than a kernel block.  Used by the stash delete
    pass (duplicate delete lanes grouped by (home bucket, fingerprint) —
    the delete kernel's own discipline) and by any caller that needs the
    distributed routing rank refined past a single key.  Invalid lanes get
    rank N (past every real rank).
    """
    n = a.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    av = jnp.where(valid, a.astype(jnp.int32), _PARKED)
    bv = jnp.where(valid, b.astype(jnp.int32), _PARKED)
    ord1 = jnp.argsort(bv, stable=True)                 # minor key ...
    order = ord1[jnp.argsort(av[ord1], stable=True)]    # ... then major
    sa, sb = av[order], bv[order]
    new_run = jnp.concatenate(
        [jnp.ones((1,), bool), (sa[1:] != sa[:-1]) | (sb[1:] != sb[:-1])])
    run_start = jax.lax.associative_scan(jnp.maximum,
                                         jnp.where(new_run, idx, 0))
    rank = jnp.zeros((n,), jnp.int32).at[order].set(idx - run_start)
    return jnp.where(valid, rank, jnp.int32(n))


@jax.jit
def wave_count(i1: jax.Array, valid: jax.Array) -> jax.Array:
    """Number of conflict-free waves a batch schedules into -> int32[].

    1 == the batch was already conflict-free; K == some bucket is targeted
    by K lanes.  Bench introspection (`BENCH_filter.json` records it for
    the contended-residue workload) and a direct measure of how much
    serialization the scheduler is unwinding.
    """
    w = conflict_waves(i1, valid)
    return jnp.max(jnp.where(valid, w + 1, 0), initial=0)


def dedupe_keys(keys: np.ndarray) -> tuple[np.ndarray, "np.ndarray | None"]:
    """Host-side lookup dedup -> (probe_keys, inverse-or-None).

    With in-batch repeats: ``probe_keys`` is the unique set and
    ``probe_keys[inverse] == keys`` — probe the unique set, answer the
    original batch with ``hits_unique[inverse]``.  With no repeats the
    original ``keys`` come back with ``inverse=None``, so every caller is
    the same two lines (probe; gather-if-inverse).  Probes are idempotent
    so this is semantics-free; it exists because dedup-window streams send
    the same hot keys many times per batch and each device lane costs the
    same whether or not its key repeats.
    """
    keys = np.asarray(keys)
    uniq, inverse = np.unique(keys, return_inverse=True)
    if uniq.size == keys.size:
        return keys, None
    return uniq, inverse
