"""OCF core: the paper's contribution as a composable JAX module."""
from repro.core.filter import (FilterState, bulk_delete, bulk_insert,
                               bulk_insert_hybrid, bulk_lookup, make_state,
                               parallel_insert_once, rebuild)
from repro.core.filter_ops import FilterOps
from repro.core.keystore import VectorKeystore
from repro.core.ocf import OCF, OcfConfig, OcfStats
from repro.core.policy import EofPolicy, PrePolicy, ResizeDecision
from repro.core.pyfilter import PyCuckooFilter

__all__ = [
    "OCF", "OcfConfig", "OcfStats", "EofPolicy", "PrePolicy", "ResizeDecision",
    "PyCuckooFilter", "FilterState", "FilterOps", "VectorKeystore",
    "make_state", "bulk_lookup", "bulk_insert", "bulk_delete",
    "bulk_insert_hybrid", "parallel_insert_once", "rebuild",
]
