"""JAX (TPU-native) bulk cuckoo-filter data plane.

The table lives in a **preallocated pow2 buffer** (a device memory pool);
the *active* bucket count ``n_buckets`` is a traced int32 scalar, so OCF
resizes — the paper's whole point — change no array shapes and trigger **no
recompilation**.  Only buffer growth (rare, pow2) compiles a new executable.
All index math is mod-``n_buckets`` (additive-complement alternate bucket —
works for any active size, which EOF's fractional schedule requires).

Semantics match ``pyfilter.PyCuckooFilter`` exactly (same hash family,
deterministic eviction, transactional rollback) when buffer == active size —
the tests assert table-for-table equality.

Insert strategies:
  * ``bulk_insert``          — lax.scan over keys, eviction chains in a
                               lax.while_loop. Exact sequential semantics.
  * ``parallel_insert_once`` — beyond-paper TPU optimization: one
                               fully-vectorized optimistic round (intra-batch
                               ranking, no chains).
  * ``bulk_insert_hybrid``   — parallel round for the ~95% easy mass, scan
                               fallback for the contended residue.
All bulk ops take an optional ``valid`` mask so callers can batch in fixed
chunks (padding never touches the table, so chunked calls hit one compile).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import hashing


class FilterState(NamedTuple):
    table: jax.Array      # uint32[buffer_buckets, bucket_size]; 0 == EMPTY
    count: jax.Array      # int32[] live fingerprints
    n_buckets: jax.Array  # int32[] ACTIVE bucket count (<= buffer_buckets)


def make_state(n_buckets: int, bucket_size: int = 4,
               buffer_buckets: Optional[int] = None) -> FilterState:
    buf = buffer_buckets or n_buckets
    assert buf >= n_buckets
    return FilterState(
        table=jnp.zeros((buf, bucket_size), dtype=jnp.uint32),
        count=jnp.zeros((), dtype=jnp.int32),
        n_buckets=jnp.asarray(n_buckets, jnp.int32))


def _fp_i1_i2(hi, lo, n_buckets, fp_bits: int):
    n = jnp.asarray(n_buckets, jnp.uint32)
    fp = hashing.fingerprint(hi, lo, fp_bits)
    i1 = hashing.index_hash_dyn(hi, lo, n)
    i2 = hashing.alt_index_dyn(i1, fp, n)
    return fp, i1, i2


# ---------------------------------------------------------------- lookup ---


@functools.partial(jax.jit, static_argnames=("fp_bits",))
def bulk_lookup(state: FilterState, hi: jax.Array, lo: jax.Array, *,
                fp_bits: int) -> jax.Array:
    """Membership for a batch of keys -> bool[N]."""
    fp, i1, i2 = _fp_i1_i2(hi, lo, state.n_buckets, fp_bits)
    b1 = state.table[i1]  # [N, bucket_size]
    b2 = state.table[i2]
    return jnp.any(b1 == fp[:, None], axis=-1) | jnp.any(
        b2 == fp[:, None], axis=-1)


# ---------------------------------------------------------------- insert ---


def _insert_one(table, fp, i1, i2, n_buckets, *, max_disp: int,
                bucket_size: int):
    """Insert one fingerprint; mirrors PyCuckooFilter.insert exactly."""
    n = jnp.asarray(n_buckets, jnp.uint32)

    def place(table, i, f):
        row = table[i]
        slot = jnp.argmax(row == 0)
        has = jnp.any(row == 0)
        new_row = jnp.where((jnp.arange(bucket_size) == slot) & has, f, row)
        return table.at[i].set(new_row), has

    table1, ok1 = place(table, i1, fp)

    def try_i2(_):
        return place(table, i2, fp)

    table2, ok2 = jax.lax.cond(ok1, lambda _: (table1, jnp.bool_(True)),
                               try_i2, operand=None)

    def evict(_):
        hist = jnp.zeros((max_disp,), dtype=jnp.uint32)

        def cond(c):
            _t, _i, _cur, step, _h, done = c
            return (~done) & (step < max_disp)

        def body(c):
            t, i, cur, step, h, _done = c
            j = (step % bucket_size).astype(jnp.int32)
            old = t[i, j]
            t = t.at[i, j].set(cur)
            h = h.at[step].set(i)
            cur = old
            i = hashing.alt_index_dyn(i, cur, n)
            row = t[i]
            has = jnp.any(row == 0)
            slot = jnp.argmax(row == 0)
            new_row = jnp.where((jnp.arange(bucket_size) == slot) & has, cur,
                                row)
            t = t.at[i].set(new_row)
            return (t, i, cur, step + 1, h, has)

        t, i, cur, step, h, done = jax.lax.while_loop(
            cond, body, (table, i2, fp, jnp.int32(0), hist, jnp.bool_(False)))

        def rollback(args):
            t, cur, h, step = args

            def rb(k, tc):
                t, cur = tc
                idx = step - 1 - k
                bi = h[idx]
                bj = (idx % bucket_size).astype(jnp.int32)
                old = t[bi, bj]
                t = t.at[bi, bj].set(cur)
                return (t, old)

            t, _ = jax.lax.fori_loop(0, step, rb, (t, cur))
            return t

        t = jax.lax.cond(done, lambda a: a[0], rollback, (t, cur, h, step))
        return t, done

    return jax.lax.cond(ok2, lambda _: (table2, jnp.bool_(True)), evict,
                        operand=None)


@functools.partial(jax.jit, static_argnames=("fp_bits", "max_disp"))
def bulk_insert(state: FilterState, hi: jax.Array, lo: jax.Array, *,
                fp_bits: int, max_disp: int = 500,
                valid: Optional[jax.Array] = None
                ) -> tuple[FilterState, jax.Array]:
    """Sequential-semantics bulk insert via lax.scan. Returns (state, ok[N])."""
    bucket_size = state.table.shape[1]
    fp, i1, i2 = _fp_i1_i2(hi, lo, state.n_buckets, fp_bits)
    if valid is None:
        valid = jnp.ones(hi.shape, bool)

    def step(table, x):
        f, a, b, v = x

        def do(_):
            return _insert_one(table, f, a, b, state.n_buckets,
                               max_disp=max_disp, bucket_size=bucket_size)

        return jax.lax.cond(v, do, lambda _: (table, jnp.bool_(False)),
                            operand=None)

    table, ok = jax.lax.scan(step, state.table, (fp, i1, i2, valid))
    count = state.count + jnp.sum(ok, dtype=jnp.int32)
    return FilterState(table, count, state.n_buckets), ok


@functools.partial(jax.jit, static_argnames=("fp_bits",))
def parallel_insert_once(state: FilterState, hi, lo, *, fp_bits: int,
                         valid: Optional[jax.Array] = None
                         ) -> tuple[FilterState, jax.Array]:
    """One optimistic vectorized insert round (no eviction chains)."""
    table = state.table
    buf, bucket_size = table.shape
    fp, i1, i2 = _fp_i1_i2(hi, lo, state.n_buckets, fp_bits)
    n = fp.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)

    def round_(table, target, active, fp):
        tgt = jnp.where(active, target, buf)  # park inactive past the buffer
        order = jnp.argsort(tgt, stable=True)
        sorted_tgt = tgt[order]
        idx = jnp.arange(n)
        run_start = jnp.where(
            jnp.concatenate([jnp.array([True]),
                             sorted_tgt[1:] != sorted_tgt[:-1]]), idx, 0)
        run_start = jax.lax.associative_scan(jnp.maximum, run_start)
        rank_sorted = idx - run_start
        rank = jnp.zeros((n,), jnp.int32).at[order].set(
            rank_sorted.astype(jnp.int32))
        free = jnp.sum(table == 0, axis=1).astype(jnp.int32)
        fits = active & (rank < free[target.clip(0, buf - 1)])
        row = table[target.clip(0, buf - 1)]
        empty_pos = jnp.cumsum((row == 0).astype(jnp.int32), axis=1) - 1
        is_dest = (row == 0) & (empty_pos == rank[:, None])
        slot = jnp.argmax(is_dest, axis=1)
        upd_i = jnp.where(fits, target, buf)  # OOB -> dropped
        table = table.at[upd_i, slot].set(fp, mode="drop")
        return table, fits

    table, ok1 = round_(table, i1.astype(jnp.int32), valid, fp)
    table, ok2 = round_(table, i2.astype(jnp.int32), valid & ~ok1, fp)
    placed = ok1 | ok2
    count = state.count + jnp.sum(placed, dtype=jnp.int32)
    return FilterState(table, count, state.n_buckets), placed


@functools.partial(jax.jit, static_argnames=("fp_bits", "max_disp"))
def bulk_insert_hybrid(state: FilterState, hi, lo, *, fp_bits: int,
                       max_disp: int = 500, valid=None
                       ) -> tuple[FilterState, jax.Array]:
    """Parallel optimistic round + mask-driven sequential fallback.

    Fully jitted end-to-end: the residue mask drives the scan fallback on
    device (lanes already placed are skipped per-step), so there is **no
    host sync** between the rounds — the seed version pulled
    ``bool(jnp.any(residue))`` back to the host for every batch, which
    serialized the insert pipeline on device->host latency.

    Membership semantics are order-independent, so only the table layout may
    differ from pure-sequential — membership answers do not."""
    if valid is None:
        valid = jnp.ones(hi.shape, bool)
    state, placed = parallel_insert_once(state, hi, lo, fp_bits=fp_bits,
                                         valid=valid)
    residue = valid & ~placed
    state2, ok2 = bulk_insert(state, hi, lo, fp_bits=fp_bits,
                              max_disp=max_disp, valid=residue)
    return state2, placed | ok2


# ---------------------------------------------------------------- delete ---


@functools.partial(jax.jit, static_argnames=("fp_bits",))
def bulk_delete(state: FilterState, hi: jax.Array, lo: jax.Array, *,
                fp_bits: int, valid: Optional[jax.Array] = None
                ) -> tuple[FilterState, jax.Array]:
    """Sequential-semantics bulk delete (scan). Returns (state, ok[N])."""
    bucket_size = state.table.shape[1]
    fp, i1, i2 = _fp_i1_i2(hi, lo, state.n_buckets, fp_bits)
    if valid is None:
        valid = jnp.ones(hi.shape, bool)

    def step(table, x):
        f, a, b, v = x

        def clear(table, i):
            row = table[i]
            hit = row == f
            has = jnp.any(hit)
            slot = jnp.argmax(hit)
            new_row = jnp.where((jnp.arange(bucket_size) == slot) & has,
                                jnp.uint32(0), row)
            return table.at[i].set(new_row), has

        def do(_):
            t1, ok1 = clear(table, a)

            def try2(_):
                return clear(table, b)

            return jax.lax.cond(ok1, lambda _: (t1, jnp.bool_(True)), try2,
                                operand=None)

        return jax.lax.cond(v, do, lambda _: (table, jnp.bool_(False)),
                            operand=None)

    table, ok = jax.lax.scan(step, state.table, (fp, i1, i2, valid))
    count = state.count - jnp.sum(ok, dtype=jnp.int32)
    return FilterState(table, count, state.n_buckets), ok


# ------------------------------------------------------------- rebuild -----


def rebuild(keys_hi, keys_lo, n_buckets: int, bucket_size: int, *,
            fp_bits: int, max_disp: int = 500,
            buffer_buckets: Optional[int] = None, valid=None
            ) -> tuple[FilterState, jax.Array]:
    """Re-insert a keystore into a fresh table of the new active capacity."""
    state = make_state(n_buckets, bucket_size, buffer_buckets)
    return bulk_insert_hybrid(state, keys_hi, keys_lo, fp_bits=fp_bits,
                              max_disp=max_disp, valid=valid)
