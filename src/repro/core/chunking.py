"""Host-side batching helpers shared by the filter control planes.

One definition of the fixed-chunk device-batch contract (chunk size, pad
value, (hi, lo) split, validity mask) for every host controller that feeds
the FilterOps data plane — the OCF (``core/ocf.py``) and the streaming
generation ring (``streaming/generations.py``).  Fixed-size chunks with
validity masks are what keep the jit/kernel cache at one compile per buffer
size; two drifting copies of this contract would silently desynchronize
the paths.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import hashing

CHUNK = 4096


def pow2_at_least(n: int) -> int:
    """Smallest power of two >= n (buffer-pool sizing)."""
    p = 1
    while p < n:
        p <<= 1
    return p


def key_chunks(keys: np.ndarray, chunk: int = CHUNK):
    """Yield (hi, lo, valid, n_real) fixed-size device batches.

    The tail chunk is zero-padded with ``valid=False`` lanes, which never
    touch a table, so callers compile exactly one executable per chunk
    shape regardless of batch size.
    """
    for i in range(0, keys.size, chunk):
        part = keys[i:i + chunk]
        n = part.size
        if n < chunk:
            part = np.pad(part, (0, chunk - n))
        hi, lo = hashing.key_to_u32_pair_np(part)
        valid = np.zeros(chunk, bool)
        valid[:n] = True
        yield jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(valid), n
