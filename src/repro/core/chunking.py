"""Host-side batching helpers shared by the filter control planes.

One definition of the fixed-chunk device-batch contract (chunk size, pad
value, (hi, lo) split, validity mask) for every host controller that feeds
the FilterOps data plane — the OCF (``core/ocf.py``) and the streaming
generation ring (``streaming/generations.py``).  Fixed-size chunks with
validity masks are what keep the jit/kernel cache at one compile per buffer
size; two drifting copies of this contract would silently desynchronize
the paths.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import hashing

CHUNK = 4096


def pow2_at_least(n: int) -> int:
    """Smallest power of two >= n (buffer-pool sizing)."""
    p = 1
    while p < n:
        p <<= 1
    return p


def collect_chunk_results(parts, ns, dtype=bool) -> np.ndarray:
    """Stack per-chunk device results and pull them back in ONE transfer.

    ``parts`` are the fixed-``CHUNK``-shaped device arrays a batched op
    queued (one per ``key_chunks`` batch), ``ns`` the real lane counts.
    Stacking on device and materializing once is the transfer discipline
    every control plane here follows — per-chunk ``np.asarray`` round-trips
    serialize the whole batch on device->host latency (the seed's OCF did
    exactly that on its insert path).
    """
    if not parts:
        return np.zeros((0,), dtype)
    stacked = np.asarray(jnp.stack(parts))
    out = np.empty((sum(ns),), stacked.dtype)
    off = 0
    for i, n in enumerate(ns):
        out[off:off + n] = stacked[i, :n]
        off += n
    return out


def key_chunks(keys: np.ndarray, chunk: int = CHUNK, *,
               with_valid: bool = True):
    """Yield (hi, lo, valid, n_real) fixed-size device batches.

    The tail chunk is zero-padded with ``valid=False`` lanes, which never
    touch a table, so callers compile exactly one executable per chunk
    shape regardless of batch size.  Lookup paths pass
    ``with_valid=False`` (yielding ``valid=None``): probes ignore the mask
    — padding lanes just probe the zero key and get sliced off — so
    building and transferring a bool[CHUNK] per chunk is pure overhead on
    the read hot path.
    """
    for i in range(0, keys.size, chunk):
        part = keys[i:i + chunk]
        n = part.size
        if n < chunk:
            part = np.pad(part, (0, chunk - n))
        hi, lo = hashing.key_to_u32_pair_np(part)
        if with_valid:
            valid = np.zeros(chunk, bool)
            valid[:n] = True
            yield jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(valid), n
        else:
            yield jnp.asarray(hi), jnp.asarray(lo), None, n
