"""Filter quality metrics (paper Table I quantities)."""
from __future__ import annotations

import numpy as np

from repro.core.ocf import OCF


def theoretical_fp_rate(bucket_size: int, fp_bits: int, occupancy: float) -> float:
    """ε ≈ 1 - (1 - 1/2^f)^(2b·O)  ≈ 2b·O / 2^f  for standard cuckoo filters."""
    return 1.0 - (1.0 - 2.0 ** (-fp_bits)) ** (2 * bucket_size * occupancy)


def measure_false_positives(ocf: OCF, probe_keys: np.ndarray) -> int:
    """Count positive answers for keys known to be absent from the keystore.

    Ground truth comes from one vectorized keystore pass
    (``contains_keys_exact``), not a per-key Python loop — at the probe
    sizes the FP-rate experiments run, the scalar form dominated the whole
    measurement.
    """
    probe_keys = np.asarray(probe_keys, dtype=np.uint64)
    absent = ~ocf.contains_keys_exact(probe_keys)
    hits = ocf.lookup(probe_keys)
    return int(np.sum(hits & absent))


def measure_false_negatives(ocf: OCF, inserted_keys: np.ndarray) -> int:
    """Must be 0 for any correct filter — the paper saw FNs at load > 0.9."""
    inserted_keys = np.asarray(inserted_keys, dtype=np.uint64)
    present = ocf.contains_keys_exact(inserted_keys)
    hits = ocf.lookup(inserted_keys)
    return int(np.sum(~hits & present))
