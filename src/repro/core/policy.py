"""OCF resize policies — the paper's §II contribution.

Capacity ``c`` is measured in item slots (= n_buckets × bucket_size), and
"time" is logical (number of marked operations), which is the only clock a
deterministic filter sees (DESIGN.md §1 interpretation notes).

* ``PrePolicy``  (PRE, primitive): static thresholds.  ``O > O_max`` → double;
  ``O < O_min`` → ``c ← c − c/10``.  Bounded by user's ``[c_min, c_max]``.
* ``EofPolicy``  (EOF, congestion-aware): k-markers arm a monitoring window;
  on threshold crossing the rate ratio ``M = (c′·t′)/(c·t)`` updates the
  growth factor ``α ← α(1−g) + g·M`` (estimation gain ``g = 1/16`` default);
  grow ``c ← c + c·α``, shrink ``c ← c − c·(1−α)``.

Both policies apply the safety clamp ``c ≥ items/O_safe`` so a shrink can
never push occupancy past the safe load (the paper's observed false-negative
regime at load > 0.9); clamp events are counted for monitoring.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

O_SAFE = 0.95  # never allow a resize that would leave occupancy above this


@dataclasses.dataclass
class ResizeDecision:
    new_capacity: int
    reason: str          # "grow" | "shrink"
    alpha: float = 0.0   # EOF growth factor at decision time
    clamped: bool = False


@dataclasses.dataclass
class PrePolicy:
    """PRE mode: static-threshold resizing."""

    o_max: float = 0.85
    o_min: float = 0.25
    c_min: int = 1024
    c_max: int = 1 << 30

    unsafe_shrinks_prevented: int = 0

    def observe(self, *, items: int, capacity: int, ops: int = 1
                ) -> Optional[ResizeDecision]:
        occ = items / capacity
        if occ > self.o_max:
            target, reason = capacity * 2, "grow"
        elif occ < self.o_min and capacity > self.c_min:
            target, reason = capacity - capacity // 10, "shrink"
        else:
            return None
        new_c, clamped = _clamp(target, items, self.c_min, self.c_max)
        if reason == "shrink" and clamped:
            self.unsafe_shrinks_prevented += 1
        if new_c == capacity:
            return None
        return ResizeDecision(new_c, reason, clamped=clamped)


@dataclasses.dataclass
class EofPolicy:
    """EOF mode: congestion-aware resizing (paper Alg. 1)."""

    o_max: float = 0.85
    o_min: float = 0.25
    k_min: float = 0.35      # markers arm monitoring before thresholds hit
    k_max: float = 0.75
    gain: float = 1.0 / 16.0  # estimation gain g
    c_min: int = 1024
    c_max: int = 1 << 30

    alpha: float = dataclasses.field(default=None)  # type: ignore[assignment]
    monitoring: bool = False
    t_cur: int = 0            # marked ops in the current window
    c_window: int = 0         # capacity when the window was armed
    t_prev: int = 0           # previous window's length
    c_prev: int = 0           # previous window's capacity
    unsafe_shrinks_prevented: int = 0

    def __post_init__(self):
        if self.alpha is None:
            self.alpha = self.gain  # conservative seed; EWMA converges

    def observe(self, *, items: int, capacity: int, ops: int = 1
                ) -> Optional[ResizeDecision]:
        occ = items / capacity
        inside_markers = self.k_min <= occ <= self.k_max
        if not self.monitoring:
            if not inside_markers:
                # Arm the monitoring window; start marking operations.
                self.monitoring = True
                self.t_cur = 0
                self.c_window = capacity
            return None

        self.t_cur += ops
        if inside_markers:
            # Load receded between the markers: disarm without resizing.
            self.monitoring = False
            return None
        if self.o_min <= occ <= self.o_max:
            return None  # marked, still between hard thresholds

        # Hard threshold crossed: compute the rate ratio and resize.
        if self.t_prev > 0:
            m = (self.c_prev * self.t_prev) / max(1, self.c_window * self.t_cur)
        else:
            m = 1.0  # first resize: no history, neutral ratio
        self.alpha = self.alpha * (1.0 - self.gain) + self.gain * m
        a = min(max(self.alpha, 0.0), 1.0)
        if occ < self.o_max:   # paper Alg.1 line 5: shrink branch
            target, reason = int(capacity - capacity * (1.0 - a)), "shrink"
        else:
            target, reason = int(capacity + capacity * a), "grow"
        self.c_prev, self.t_prev = self.c_window, max(1, self.t_cur)
        self.monitoring = False
        new_c, clamped = _clamp(target, items, self.c_min, self.c_max)
        if reason == "shrink" and clamped:
            self.unsafe_shrinks_prevented += 1
        if new_c == capacity:
            return None
        return ResizeDecision(new_c, reason, alpha=a, clamped=clamped)


def _clamp(target: int, items: int, c_min: int, c_max: int) -> tuple[int, bool]:
    safe_floor = int(items / O_SAFE) + 1
    new_c = max(target, safe_floor, c_min)
    new_c = min(new_c, c_max)
    return new_c, new_c != target
