"""Vectorized backing keystore for the OCF (the paper's memtable analogue).

A sorted-array multiset of uint64 keys with **batch** add/remove — the seed
kept a Python ``dict[int, int]`` and looped ``for k in keys.tolist()`` per
insert, which made the keystore the host-side bottleneck of the whole insert
path (~10x slower than the device filter work at 100k-key batches; see
benchmarks/filter_bench.py).  All operations here are O(B log B + U) numpy
vector ops for a batch of B keys over U resident uniques.

Semantics match the dict exactly, including per-occurrence delete
verification: deleting a key that appears m times in the store and d times
in one batch succeeds for the first min(m, d) occurrences *in batch order*.
"""
from __future__ import annotations

import numpy as np


class VectorKeystore:
    """Sorted parallel arrays: ``keys`` (uint64, unique) and ``counts``."""

    def __init__(self):
        self._keys = np.empty(0, np.uint64)
        self._counts = np.empty(0, np.int64)
        self._total = 0

    # ------------------------------------------------------------ views --

    @property
    def total(self) -> int:
        """Live key count, multiplicities included (== len of the OCF)."""
        return self._total

    @property
    def unique(self) -> int:
        return self._keys.size

    def multiplicity(self, key: int) -> int:
        if not self._keys.size:
            return 0
        pos = int(np.searchsorted(self._keys, np.uint64(key)))
        if pos < self._keys.size and self._keys[pos] == np.uint64(key):
            return int(self._counts[pos])
        return 0

    def contains(self, key: int) -> bool:
        return self.multiplicity(key) > 0

    def contains_batch(self, keys) -> np.ndarray:
        """Residency mask bool[B] for a query batch — one searchsorted over
        the sorted uniques instead of B scalar probes (the metrics module's
        ground-truth pass was the last per-key Python loop in the repo)."""
        keys = np.asarray(keys, dtype=np.uint64)
        if keys.size == 0 or not self._keys.size:
            return np.zeros(keys.size, bool)
        _, hit = self._locate(keys)
        return hit

    def materialize(self) -> np.ndarray:
        """All keys with multiplicity, as uint64[total] (rebuild input)."""
        return np.repeat(self._keys, self._counts)

    def clear(self) -> None:
        self._keys = np.empty(0, np.uint64)
        self._counts = np.empty(0, np.int64)
        self._total = 0

    # ------------------------------------------------------------- edit --

    def _locate(self, uk: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(pos, hit): insertion index per unique key, and residency mask."""
        pos = np.searchsorted(self._keys, uk)
        hit = np.zeros(uk.size, bool)
        if self._keys.size:
            inb = pos < self._keys.size
            hit[inb] = self._keys[pos[inb]] == uk[inb]
        return pos, hit

    def add(self, keys) -> None:
        keys = np.asarray(keys, dtype=np.uint64)
        if keys.size == 0:
            return
        uk, cnt = np.unique(keys, return_counts=True)
        pos, hit = self._locate(uk)
        self._counts[pos[hit]] += cnt[hit]       # pos unique per uk: no races
        if (~hit).any():
            self._keys = np.insert(self._keys, pos[~hit], uk[~hit])
            self._counts = np.insert(self._counts, pos[~hit], cnt[~hit])
        self._total += int(keys.size)

    def remove(self, keys) -> np.ndarray:
        """Remove a batch; returns present bool[B] (per-occurrence verified).

        Occurrence k of a key (in batch order) is present iff k < resident
        multiplicity — identical to looping a dict decrement per key.
        """
        keys = np.asarray(keys, dtype=np.uint64)
        if keys.size == 0:
            return np.zeros(0, bool)
        uk, inv, cnt = np.unique(keys, return_inverse=True, return_counts=True)
        pos, hit = self._locate(uk)
        avail = np.zeros(uk.size, np.int64)
        avail[hit] = self._counts[pos[hit]]
        # occurrence rank in batch order: stable sort groups equal keys while
        # preserving arrival order, so rank = index within the equal-run
        order = np.argsort(keys, kind="stable")
        sk = keys[order]
        idx = np.arange(keys.size)
        new_run = np.ones(keys.size, bool)
        new_run[1:] = sk[1:] != sk[:-1]
        run_start = np.maximum.accumulate(np.where(new_run, idx, 0))
        rank = np.empty(keys.size, np.int64)
        rank[order] = idx - run_start
        present = rank < avail[inv]
        removed = np.minimum(cnt, avail)
        if removed.any():
            self._counts[pos[hit]] -= removed[hit]
            keep = self._counts > 0
            if not keep.all():
                self._keys = self._keys[keep]
                self._counts = self._counts[keep]
            self._total -= int(removed.sum())
        return present
