"""Distributed OCF — the paper's distributed-database story on a JAX mesh.

Filter shards live along a mesh axis (one shard per `data`-axis slice, the
same placement a Cassandra node ring would have).  Lookups AND writes are
routed with the MoE dispatch shape:

    owner = H(key) mod n_shards
    one capacity-bounded all_to_all sends each key to its owner shard,
    the owner runs the local data-plane op (probe / scheduled insert /
    fused delete) on its table slice,
    a second all_to_all returns the answers.

The routing rank is ``core.scheduling.conflict_waves`` with the owner shard
as the "bucket": lane i claims slot ``wave[i]`` of its owner's row in the
send buffer, and ``wave >= cap`` IS the routing-overflow condition — the
same definition the insert kernels use for conflict-free wave dispatch.

Burst tolerance shows up here exactly as in the paper: the per-shard routing
capacity is a buffer; ``overflow`` counts keys that exceeded it and feeds
the EOF congestion signal, the same way switch-queue marking drives the
resize controller.  Lookup answers overflowed keys conservatively ("maybe
present"); writes return them as a **deferred batch** (never attempted —
resubmit next step), so routing pressure degrades latency, never
correctness.

Writes are the PR-6 tentpole: ``distributed_insert`` / ``distributed_delete``
run the PR-5 conflict-aware scheduled insert — bounded eviction chains,
spill to a per-shard device-resident stash, fused verified delete —
entirely inside ``shard_map``.  Per-shard stashes ride in
``ShardedFilterState`` next to the tables, and the enclosing jit donates
both stacks, so the hot loop never copies a table and never bounces one
through the host (the pre-PR-6 ``local_shard_*_host`` swap functions remain
only as control-plane compat shims for rebuilds).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import filter as jfilter
from repro.core import hashing
from repro.core.filter_ops import FilterOps
from repro.core.scheduling import conflict_waves
from repro.kernels.stash import DEFAULT_STASH_SLOTS

try:                                  # jax >= 0.6 exports it at top level
    _shard_map = jax.shard_map
except AttributeError:                # 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map


def _shard_map_for(backend: str, fn, *, mesh, in_specs, out_specs):
    """shard_map wrapper that disables the replication check for kernels.

    shard_map's replication checker has no rule for ``pallas_call`` (the
    reason the Pallas shard probe used to be impossible — ROADMAP item);
    with fully explicit out_specs the check is advisory here, so it is
    dropped exactly when the FilterOps dispatch may lower a kernel.  The
    kwarg was renamed ``check_rep`` -> ``check_vma`` across jax versions.
    """
    if backend == "jnp":
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)
    return _shard_map_unchecked(fn, mesh=mesh, in_specs=in_specs,
                                out_specs=out_specs)


def _shard_map_unchecked(fn, *, mesh, in_specs, out_specs):
    """shard_map with the replication check off on every backend.

    The routed *writes* need this even on the jnp arm: their eviction scan
    lowers to ``lax.while``, which the checker has no rule for either.
    Out_specs are fully explicit, so the check is advisory here too.
    """
    try:
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    except TypeError:                 # newer jax: check_vma
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)


class ShardedFilterState(NamedTuple):
    """Per-shard filter data plane, stacked along the shard axis.

    ``tables``: uint32[n_shards, buffer_buckets, bucket_size].
    ``stashes``: uint32[n_shards, 2, stash_slots] overflow stashes (one per
    shard, mutated on-device by the routed writes), or None for read-only /
    pre-PR-6 states — every entry point treats a stash-less state as
    "no spill, chain exhaustion fails the lane".
    ``n_buckets``: the shards' ACTIVE bucket count as a static python int
    (every shard resizes in lockstep — the controller owns rotation), or
    None meaning "the full buffer" (tables.shape[1]).  Static on purpose:
    it is a kernel grid parameter inside shard_map, and the pow2 buffer
    discipline (core/filter.py) makes recompiles rare.
    """
    tables: jax.Array
    stashes: Optional[jax.Array] = None
    n_buckets: Optional[int] = None


def make_sharded_state(n_shards: int, n_buckets: int, bucket_size: int = 4,
                       *, stash_slots: int = DEFAULT_STASH_SLOTS,
                       buffer_buckets: Optional[int] = None
                       ) -> ShardedFilterState:
    """Fresh sharded state: zero tables + per-shard overflow stashes.

    ``buffer_buckets`` preallocates the pow2 pool the single-node path uses
    (``core/filter.py``); the active count ``n_buckets`` rides in the state
    so every consumer mods by the same modulus.  ``stash_slots=0`` opts out
    of stashes (pre-PR-6 behavior: exhausted chains roll back and fail).
    """
    buf = buffer_buckets or n_buckets
    assert buf >= n_buckets
    return ShardedFilterState(
        tables=jnp.zeros((n_shards, buf, bucket_size), dtype=jnp.uint32),
        stashes=(jnp.zeros((n_shards, 2, stash_slots), dtype=jnp.uint32)
                 if stash_slots else None),
        n_buckets=n_buckets)


def sharded_occupancy(state: ShardedFilterState) -> jax.Array:
    """Aggregate load factor (live slots / capacity) -> float32[].

    Counts table residents and stash entries against table capacity — the
    quantity the bench gate's load assertion and the resize controller's
    o_max threshold both read.
    """
    live = jnp.sum(state.tables != 0)
    if state.stashes is not None:
        live = live + jnp.sum(state.stashes[:, 0, :] != 0)
    return live.astype(jnp.float32) / jnp.float32(state.tables.size)


def _route(hi, lo, n_shards: int, cap: int, valid=None, *,
           route: str = "key", n_buckets: Optional[int] = None,
           fp_bits: Optional[int] = None):
    """Owner routing for one source shard's lane batch.

    Returns (dst int32[N] — owner or n_shards for overflow, rank int32[N]
    — the claimed slot in the owner's row, fits bool[N]).  ``rank`` is
    ``conflict_waves`` with the owner shard as the bucket, computed in
    original lane order — so answers scatter straight back by (dst, rank)
    with no argsort/inverse permutation.  Invalid lanes (``valid=False`` —
    resubmission padding) claim no capacity slot and never fit.

    ``route`` picks the owner function: ``"key"`` hashes the raw key
    (legacy, cheapest); ``"pair"`` hashes the key's candidate-pair
    invariant (min bucket + fingerprint), the routing elastic resharding
    requires — a stored slot's owner stays re-derivable after the key is
    gone (``distributed/elastic.py``).
    """
    if route == "pair":
        owner = hashing.owner_shard_key_pair(
            hi, lo, n_buckets, fp_bits, n_shards).astype(jnp.int32)
    else:
        owner = hashing.owner_shard(hi, lo, n_shards).astype(jnp.int32)
    if valid is None:
        valid = jnp.ones(owner.shape, bool)
    rank = conflict_waves(owner, valid)
    fits = (rank < cap) & valid
    dst = jnp.where(fits, owner, n_shards)
    return dst, rank, fits


def _scatter_routed(dst, rank, fits, n_shards: int, cap: int, hi, lo):
    """Lane batch -> capacity-bounded send buffers ([n_shards, cap] each)."""
    buf_hi = jnp.zeros((n_shards, cap), jnp.uint32).at[dst, rank].set(
        hi, mode="drop")
    buf_lo = jnp.zeros((n_shards, cap), jnp.uint32).at[dst, rank].set(
        lo, mode="drop")
    valid = jnp.zeros((n_shards, cap), jnp.bool_).at[dst, rank].set(
        fits, mode="drop")
    return buf_hi, buf_lo, valid


def _local_probe(table, hi, lo, fp_bits: int, backend: str = "auto"):
    """Per-shard membership probe, routed through the FilterOps data plane
    (same backend dispatch as the single-node OCF hot path)."""
    return FilterOps(fp_bits=fp_bits, backend=backend).probe_table(
        table, hi, lo)


def distributed_lookup(mesh: Mesh, axis: str, state: ShardedFilterState,
                       hi: jax.Array, lo: jax.Array, *, fp_bits: int,
                       capacity_factor: float = 2.0, backend: str = "auto",
                       route: str = "key"):
    """Batched membership across filter shards.

    ``hi``/``lo``: uint32[n_shards * per_shard] keys, sharded over ``axis``.
    Returns (hits bool[N], overflow int32[n_shards] per-shard overflow
    count).  Overflowed keys answer True ("maybe") — conservative for
    dedup/caching, and the overflow count is the congestion signal for the
    EOF policy.  States carrying per-shard stashes answer spilled keys in
    the same fused probe pass.

    ``backend`` selects the local-probe data plane ("jnp" | "pallas" |
    "auto") inside ``shard_map`` — the same FilterOps dispatch as the
    single-node hot path.  "auto" resolves per-host: the fused probe kernel
    on TPU meshes whose shard tables fit the VMEM budget, jnp elsewhere
    (CPU hosts trace the jnp path unless "pallas" is forced, which runs the
    kernel in interpret mode — how the parity tests pin it).

    ``route`` must match the routing the state was written with ("key" |
    "pair" — see ``_route``); probing a pair-routed elastic state with key
    routing sends keys to the wrong shard and silently false-negatives.
    """
    n_shards = mesh.shape[axis]
    per_shard = hi.shape[0] // n_shards
    cap = int(per_shard * capacity_factor / n_shards + 1)  # slots per (src,dst)
    has_stash = state.stashes is not None
    nb = state.n_buckets
    route_nb = nb if nb is not None else state.tables.shape[1]
    fops = FilterOps(fp_bits=fp_bits, backend=backend)

    def shard_fn(tables, stashes, hi, lo):
        # tables: [1, buf, b] local shard; hi/lo: [per_shard]
        table = tables[0]
        stash = stashes[0] if has_stash else None
        dst, rank, fits = _route(hi, lo, n_shards, cap, route=route,
                                 n_buckets=route_nb, fp_bits=fp_bits)
        overflow = jnp.sum(~fits, dtype=jnp.int32)
        buf_hi, buf_lo, valid = _scatter_routed(dst, rank, fits, n_shards,
                                                cap, hi, lo)
        # Exchange: after all_to_all, row s holds what shard s sent me.
        r_hi = jax.lax.all_to_all(buf_hi, axis, 0, 0, tiled=False)
        r_lo = jax.lax.all_to_all(buf_lo, axis, 0, 0, tiled=False)
        r_valid = jax.lax.all_to_all(valid, axis, 0, 0, tiled=False)
        hit = fops.probe_table(table, r_hi.reshape(-1), r_lo.reshape(-1),
                               n_buckets=nb, stash=stash
                               ).reshape(n_shards, cap)
        hit = jnp.where(r_valid, hit, False)
        # Route answers back; overflowed lanes answer "maybe present".
        back = jax.lax.all_to_all(hit, axis, 0, 0, tiled=False)
        ans = jnp.where(fits, back[dst.clip(0, n_shards - 1), rank], True)
        return ans, overflow[None]

    if has_stash:
        fn = _shard_map_for(
            backend, shard_fn, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P(axis)),
            out_specs=(P(axis), P(axis)))
        return fn(state.tables, state.stashes, hi, lo)
    fn = _shard_map_for(
        backend, lambda t, h, l: shard_fn(t, None, h, l), mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis)))
    return fn(state.tables, hi, lo)


# ------------------------------------------------------- routed writes --
#
# One cached builder serves insert and delete: the dispatch shape (route ->
# all_to_all -> local FilterOps op -> all_to_all back) is identical; only
# the shard-local op differs.  The jit wrapping the shard_map donates the
# table/stash stacks, so XLA aliases them in->out and a write step performs
# ZERO whole-table copies and ZERO host round-trips — the acceptance bar
# the host-swap compat shims (below) could never meet.


@functools.lru_cache(maxsize=None)
def _routed_write_fn(mesh: Mesh, axis: str, op: str, n_shards: int,
                     cap: int, fp_bits: int, backend: str,
                     evict_rounds: Optional[int], max_disp: int,
                     schedule: bool, donate: bool,
                     n_buckets: Optional[int], has_stash: bool,
                     route: str, route_nb: int):
    """Build (and cache) the jitted routed-write executable.

    Cache key == every static that shapes the traced program; jax.jit
    handles retracing across batch shapes within one entry.  Donation is
    threaded HERE, at the outermost jit — inside the shard_map body the
    arrays are tracers, so inner kernel calls stay donate=False and the
    in-place update happens at this boundary (see FilterOps raw-table ops).
    """
    fops = FilterOps(fp_bits=fp_bits, backend=backend,
                     evict_rounds=evict_rounds, max_disp=max_disp,
                     schedule=schedule)

    def shard_fn(tables, stashes, hi, lo, lane_valid):
        table = tables[0]
        stash = stashes[0] if has_stash else None
        dst, rank, fits = _route(hi, lo, n_shards, cap, lane_valid,
                                 route=route, n_buckets=route_nb,
                                 fp_bits=fp_bits)
        overflow = jnp.sum(~fits & lane_valid, dtype=jnp.int32)
        buf_hi, buf_lo, valid = _scatter_routed(dst, rank, fits, n_shards,
                                                cap, hi, lo)
        r_hi = jax.lax.all_to_all(buf_hi, axis, 0, 0, tiled=False)
        r_lo = jax.lax.all_to_all(buf_lo, axis, 0, 0, tiled=False)
        r_valid = jax.lax.all_to_all(valid, axis, 0, 0, tiled=False)
        flat_hi, flat_lo = r_hi.reshape(-1), r_lo.reshape(-1)
        flat_valid = r_valid.reshape(-1)
        if op == "insert":
            out = fops.insert_table(table, flat_hi, flat_lo, n_buckets=n_buckets,
                                    valid=flat_valid, stash=stash)
        else:
            out = fops.delete_table(table, flat_hi, flat_lo, n_buckets=n_buckets,
                                    valid=flat_valid, stash=stash)
        if has_stash:
            new_table, new_stash, ok_flat = out
        else:
            new_table, ok_flat = out
            new_stash = stashes[0]          # dummy passthrough
        ok = ok_flat.reshape(n_shards, cap) & r_valid
        back = jax.lax.all_to_all(ok, axis, 0, 0, tiled=False)
        ok_lane = fits & back[dst.clip(0, n_shards - 1), rank]
        deferred = ~fits & lane_valid       # never attempted: resubmit
        return (new_table[None], new_stash[None], ok_lane, deferred,
                overflow[None])

    mapped = _shard_map_unchecked(
        shard_fn, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(axis),) * 5)
    return jax.jit(mapped, donate_argnums=(0, 1) if donate else ())


def _distributed_write(op: str, mesh: Mesh, axis: str,
                       state: ShardedFilterState, hi, lo, *, fp_bits: int,
                       capacity_factor: float, backend: str,
                       evict_rounds: Optional[int], max_disp: int,
                       schedule: bool, donate: bool, valid=None,
                       route: str = "key"):
    n_shards = mesh.shape[axis]
    per_shard = hi.shape[0] // n_shards
    cap = int(per_shard * capacity_factor / n_shards + 1)
    has_stash = state.stashes is not None
    route_nb = (state.n_buckets if state.n_buckets is not None
                else state.tables.shape[1])
    fn = _routed_write_fn(mesh, axis, op, n_shards, cap, fp_bits, backend,
                          evict_rounds, max_disp, schedule, donate,
                          state.n_buckets, has_stash, route, route_nb)
    stashes = (state.stashes if has_stash else
               jnp.zeros((n_shards, 2, 1), jnp.uint32))  # dummy, threaded
    if valid is None:
        valid = jnp.ones(hi.shape, bool)
    tables, stashes, ok, deferred, overflow = fn(state.tables, stashes,
                                                 hi, lo, valid)
    new_state = state._replace(tables=tables,
                               stashes=stashes if has_stash else None)
    return new_state, ok, deferred, overflow


def distributed_insert(mesh: Mesh, axis: str, state: ShardedFilterState,
                       hi: jax.Array, lo: jax.Array, *, fp_bits: int,
                       capacity_factor: float = 2.0, backend: str = "auto",
                       evict_rounds: Optional[int] = None,
                       max_disp: int = 500, schedule: bool = True,
                       donate: bool = False, valid=None,
                       route: str = "key"):
    """Routed bulk insert across filter shards, entirely on-device.

    ``hi``/``lo``: uint32[n_shards * per_shard] keys, sharded over ``axis``.
    Each key rides the capacity-bounded all_to_all to its owner shard,
    which runs the conflict-aware scheduled insert (optimistic rounds +
    bounded eviction chains + spill to the shard's stash) on its table
    slice inside ``shard_map`` — no host round-trip, no table copy when
    ``donate=True`` (the enclosing jit aliases the table/stash stacks
    in->out; only callers that never reuse the pre-op state qualify,
    exactly the single-node donation contract).

    Returns ``(new_state, ok bool[N], deferred bool[N],
    overflow int32[n_shards])``:

      * ``ok`` — key resident (table or stash) on its owner shard;
      * ``deferred`` — routing overflow: the lane exceeded its owner's
        all_to_all capacity and was NEVER attempted.  Resubmit these
        (``hi[deferred]``) next step; the count is the burst signal the
        EOF/admission policy consumes, exactly like the lookup overflow.
      * ``overflow`` — per-source-shard deferred counts (the device-side
        aggregate of ``deferred``).

    ``ok=False`` with ``deferred=False`` means the shard genuinely failed
    the insert (chain budget exhausted AND stash full) — the rotate/grow
    signal, identical to single-node ``FilterOps.insert``.

    ``evict_rounds`` bounds the kernel arm's eviction rounds (None -> the
    0.85-load default); ``max_disp`` bounds the jnp arm's sequential
    chains — the same two knobs, same semantics, as ``FilterOps``.

    ``valid`` masks lanes out entirely (never routed, never attempted,
    never deferred) — what lets a resubmission pump pad a deferred batch
    to the sharded shape without inserting sentinel keys
    (``serving.scheduler.DeferredWritePump``).

    ``route`` selects the owner function ("key" hashes the full key,
    "pair" hashes the candidate bucket pair + fingerprint — elastic
    states that must re-derive ownership from resident slots).  A state
    must be written and probed under ONE routing mode for its lifetime.
    """
    return _distributed_write("insert", mesh, axis, state, hi, lo,
                              fp_bits=fp_bits,
                              capacity_factor=capacity_factor,
                              backend=backend, evict_rounds=evict_rounds,
                              max_disp=max_disp, schedule=schedule,
                              donate=donate, valid=valid, route=route)


def distributed_delete(mesh: Mesh, axis: str, state: ShardedFilterState,
                       hi: jax.Array, lo: jax.Array, *, fp_bits: int,
                       capacity_factor: float = 2.0, backend: str = "auto",
                       donate: bool = False, valid=None,
                       route: str = "key"):
    """Routed verified delete across filter shards, entirely on-device.

    The write-side mirror of ``distributed_lookup``: each key deletes on
    its owner shard through the fused first-match-slot kernel; lanes that
    miss the table clear the shard's stash entry in the same composed pass
    (table copies first), so keys that parked in a stash during a burst
    are deletable like residents.  Same return contract as
    ``distributed_insert`` — ``ok`` is the per-key verified-delete result,
    ``deferred`` the never-attempted routing overflow to resubmit.

    Callers must pre-verify membership (the OCF keystore does): blind
    deletes corrupt foreign fingerprints on every cuckoo filter, sharded
    or not.
    """
    return _distributed_write("delete", mesh, axis, state, hi, lo,
                              fp_bits=fp_bits,
                              capacity_factor=capacity_factor,
                              backend=backend, evict_rounds=None,
                              max_disp=500, schedule=False, donate=donate,
                              valid=valid, route=route)


# ------------------------------------------------- compat shims (host) --
#
# Pre-PR-6 the write path bounced every mutated table through the host:
# gather shard -> single-node op -> scatter back with a whole-stack copy.
# The routed writes above retire that pattern from the hot loop; these
# shims remain for the *control plane* only (rebuild/rotation swaps a
# freshly built table in at generation boundaries, where a copy per
# rotation is irrelevant) and for tests that need to seed one shard.


def local_shard_insert_host(state: ShardedFilterState, shard: int, table
                            ) -> ShardedFilterState:
    """Host-side table swap after a per-shard rebuild (control plane only —
    the hot loop uses ``distributed_insert``)."""
    return state._replace(tables=state.tables.at[shard].set(table))


def local_shard_delete_host(state: ShardedFilterState, shard: int,
                            hi: jax.Array, lo: jax.Array, *, fp_bits: int,
                            backend: str = "auto", n_buckets=None
                            ) -> tuple[ShardedFilterState, jax.Array]:
    """Verified delete on one shard via a host round-trip (compat shim —
    the hot loop uses ``distributed_delete``).

    ``n_buckets`` defaults to the state's ACTIVE bucket count, falling back
    to the buffer row count only for legacy states that never set one —
    the same active-vs-buffer discipline as the single-node path
    (``core/filter.py``: the table lives in a preallocated pow2 buffer, so
    hashing mod ``table.shape[0]`` is wrong whenever the active count is
    smaller; deletes would probe the wrong buckets and silently miss).
    Returns (new_state, deleted bool[N]).
    """
    table = state.tables[shard]
    if n_buckets is None:
        n_buckets = (state.n_buckets if state.n_buckets is not None
                     else table.shape[0])
    st = jfilter.FilterState(table, jnp.zeros((), jnp.int32),
                             jnp.asarray(n_buckets, jnp.int32))
    st, ok = FilterOps(fp_bits=fp_bits, backend=backend).delete(st, hi, lo)
    return state._replace(tables=state.tables.at[shard].set(st.table)), ok


@functools.partial(jax.jit, static_argnames=("fp_bits", "backend"))
def replicated_lookup(tables: jax.Array, hi: jax.Array, lo: jax.Array, *,
                      fp_bits: int, backend: str = "auto") -> jax.Array:
    """Probe every shard (broadcast query — 'is this key anywhere?')."""
    hit = jax.vmap(lambda t: _local_probe(t, hi, lo, fp_bits, backend))(tables)
    return jnp.any(hit, axis=0)
