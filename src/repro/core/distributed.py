"""Distributed OCF — the paper's distributed-database story on a JAX mesh.

Filter shards live along a mesh axis (one shard per `data`-axis slice, the
same placement a Cassandra node ring would have).  A batched membership query
is routed with the MoE dispatch shape:

    owner = H(key) mod n_shards
    one capacity-bounded all_to_all sends each key to its owner shard,
    the owner probes its local table (pure gather/compare),
    a second all_to_all returns the answers.

Burst tolerance shows up here exactly as in the paper: the per-shard routing
capacity is a buffer; ``overflow`` counts keys that exceeded it (answered
conservatively "maybe present") and feeds the EOF congestion signal, the same
way switch-queue marking drives the resize controller.

Everything inside ``shard_map`` is shape-static and jit-safe; the controller
(resize) stays on the host and swaps shard tables between steps.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import filter as jfilter
from repro.core import hashing
from repro.core.filter_ops import FilterOps

try:                                  # jax >= 0.6 exports it at top level
    _shard_map = jax.shard_map
except AttributeError:                # 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map


def _shard_map_for(backend: str, fn, *, mesh, in_specs, out_specs):
    """shard_map wrapper that disables the replication check for kernels.

    shard_map's replication checker has no rule for ``pallas_call`` (the
    reason the Pallas shard probe used to be impossible — ROADMAP item);
    with fully explicit out_specs the check is advisory here, so it is
    dropped exactly when the FilterOps dispatch may lower a kernel.  The
    kwarg was renamed ``check_rep`` -> ``check_vma`` across jax versions.
    """
    if backend == "jnp":
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)
    try:
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    except TypeError:                 # newer jax: check_vma
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)


class ShardedFilterState(NamedTuple):
    """Stacked per-shard tables: uint32[n_shards, n_buckets, bucket_size]."""
    tables: jax.Array


def make_sharded_state(n_shards: int, n_buckets: int, bucket_size: int = 4
                       ) -> ShardedFilterState:
    return ShardedFilterState(
        tables=jnp.zeros((n_shards, n_buckets, bucket_size), dtype=jnp.uint32))


def _local_probe(table, hi, lo, fp_bits: int, backend: str = "auto"):
    """Per-shard membership probe, routed through the FilterOps data plane
    (same backend dispatch as the single-node OCF hot path)."""
    return FilterOps(fp_bits=fp_bits, backend=backend).probe_table(
        table, hi, lo)


def distributed_lookup(mesh: Mesh, axis: str, state: ShardedFilterState,
                       hi: jax.Array, lo: jax.Array, *, fp_bits: int,
                       capacity_factor: float = 2.0, backend: str = "auto"):
    """Batched membership across filter shards.

    ``hi``/``lo``: uint32[n_shards * per_shard] keys, sharded over ``axis``.
    Returns (hits bool[N], overflow int32[] per-shard overflow count).
    Overflowed keys answer True ("maybe") — conservative for dedup/caching,
    and the overflow count is the congestion signal for the EOF policy.

    ``backend`` selects the local-probe data plane ("jnp" | "pallas" |
    "auto") inside ``shard_map`` — the same FilterOps dispatch as the
    single-node hot path.  "auto" resolves per-host: the fused probe kernel
    on TPU meshes whose shard tables fit the VMEM budget, jnp elsewhere
    (CPU hosts trace the jnp path unless "pallas" is forced, which runs the
    kernel in interpret mode — how the parity tests pin it).
    """
    n_shards = mesh.shape[axis]
    per_shard = hi.shape[0] // n_shards
    cap = int(per_shard * capacity_factor / n_shards + 1)  # slots per (src,dst)

    def shard_fn(tables, hi, lo):
        # tables: [1, n_buckets, b] local shard; hi/lo: [per_shard]
        table = tables[0]
        my = jax.lax.axis_index(axis)
        owner = hashing.owner_shard(hi, lo, n_shards).astype(jnp.int32)
        # Build send buffers: [n_shards, cap] keys routed to each owner.
        order = jnp.argsort(owner, stable=True)
        s_owner, s_hi, s_lo = owner[order], hi[order], lo[order]
        idx = jnp.arange(per_shard)
        run_start = jnp.where(
            jnp.concatenate([jnp.array([True]), s_owner[1:] != s_owner[:-1]]),
            idx, 0)
        run_start = jax.lax.associative_scan(jnp.maximum, run_start)
        rank = idx - run_start
        fits = rank < cap
        overflow = jnp.sum(~fits, dtype=jnp.int32)
        dst = jnp.where(fits, s_owner, n_shards)
        buf_hi = jnp.zeros((n_shards, cap), jnp.uint32).at[dst, rank].set(
            s_hi, mode="drop")
        buf_lo = jnp.zeros((n_shards, cap), jnp.uint32).at[dst, rank].set(
            s_lo, mode="drop")
        valid = jnp.zeros((n_shards, cap), jnp.bool_).at[dst, rank].set(
            fits, mode="drop")
        # Exchange: after all_to_all, row s holds what shard s sent me.
        r_hi = jax.lax.all_to_all(buf_hi, axis, 0, 0, tiled=False)
        r_lo = jax.lax.all_to_all(buf_lo, axis, 0, 0, tiled=False)
        r_valid = jax.lax.all_to_all(valid, axis, 0, 0, tiled=False)
        hit = _local_probe(table, r_hi.reshape(-1), r_lo.reshape(-1),
                           fp_bits, backend).reshape(n_shards, cap)
        hit = jnp.where(r_valid, hit, False)
        # Route answers back.
        back = jax.lax.all_to_all(hit, axis, 0, 0, tiled=False)  # [n_shards, cap]
        # Scatter answers to original key order.
        ans_sorted = jnp.where(fits, back[dst.clip(0, n_shards - 1), rank], True)
        ans = jnp.zeros((per_shard,), jnp.bool_).at[order].set(ans_sorted)
        del my
        return ans, overflow[None]

    fn = _shard_map_for(
        backend, shard_fn, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis)))
    return fn(state.tables, hi, lo)


def local_shard_insert_host(state: ShardedFilterState, shard: int, table
                            ) -> ShardedFilterState:
    """Host-side table swap after a per-shard rebuild/insert."""
    return ShardedFilterState(tables=state.tables.at[shard].set(table))


def local_shard_delete_host(state: ShardedFilterState, shard: int,
                            hi: jax.Array, lo: jax.Array, *, fp_bits: int,
                            backend: str = "auto", n_buckets=None
                            ) -> tuple[ShardedFilterState, jax.Array]:
    """Verified delete on one shard, through the FilterOps data plane.

    The shard-ring analogue of tombstoning a key on its owner node: the
    controller (which already routed the key with ``owner_shard`` and
    verified it against the shard's keystore) deletes from the owner's local
    table and swaps it back in.  ``backend="pallas"`` runs the fused delete
    kernel on the shard table — the same dispatch as the single-node path.
    Returns (new_state, deleted bool[N]).
    """
    table = state.tables[shard]
    if n_buckets is None:
        n_buckets = table.shape[0]
    st = jfilter.FilterState(table, jnp.zeros((), jnp.int32),
                             jnp.asarray(n_buckets, jnp.int32))
    st, ok = FilterOps(fp_bits=fp_bits, backend=backend).delete(st, hi, lo)
    return ShardedFilterState(
        tables=state.tables.at[shard].set(st.table)), ok


@functools.partial(jax.jit, static_argnames=("fp_bits", "backend"))
def replicated_lookup(tables: jax.Array, hi: jax.Array, lo: jax.Array, *,
                      fp_bits: int, backend: str = "auto") -> jax.Array:
    """Probe every shard (broadcast query — 'is this key anywhere?')."""
    hit = jax.vmap(lambda t: _local_probe(t, hi, lo, fp_bits, backend))(tables)
    return jnp.any(hit, axis=0)
