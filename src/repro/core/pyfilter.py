"""Pure-Python/numpy reference cuckoo filter — the semantic oracle.

This is the closest thing in the codebase to the paper's original CPU
implementation: per-key operations with explicit eviction chains.  Every
JAX/Pallas fast path is tested against it bit-for-bit (same hash functions,
same table layout, same eviction order), so "oracle agreement" means the
vectorized paths implement *exactly* this structure.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import hashing


@dataclasses.dataclass
class PyCuckooFilter:
    """Standard cuckoo filter with partial-key hashing (Fan et al. 2014).

    Table: ``uint32[n_buckets, bucket_size]``, 0 == EMPTY.
    Alternate bucket uses the additive-complement involution so n_buckets can
    be arbitrary (required by OCF's fractional resizing; DESIGN.md §1).
    """

    n_buckets: int
    bucket_size: int = 4
    fp_bits: int = 16
    max_displacements: int = 500

    def __post_init__(self):
        assert 1 <= self.fp_bits <= 32
        self.table = np.zeros((self.n_buckets, self.bucket_size), dtype=np.uint32)
        self.count = 0

    # -- derived -------------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.n_buckets * self.bucket_size

    @property
    def occupancy(self) -> float:
        return self.count / self.capacity

    def _fp_i1(self, key: int) -> tuple[int, int]:
        hi, lo = hashing.key_to_u32_pair_np(np.uint64(key))
        fp = int(hashing.fingerprint_np(hi, lo, self.fp_bits))
        i1 = int(hashing.index_hash_np(hi, lo, self.n_buckets))
        return fp, i1

    def _alt(self, i: int, fp: int) -> int:
        return int(hashing.alt_index_np(np.uint32(i), np.uint32(fp), self.n_buckets))

    # -- core ops ------------------------------------------------------

    def lookup(self, key: int) -> bool:
        fp, i1 = self._fp_i1(key)
        i2 = self._alt(i1, fp)
        return bool(np.any(self.table[i1] == fp) or np.any(self.table[i2] == fp))

    def insert(self, key: int) -> bool:
        """Insert; returns False when the filter is full (chain exhausted).

        Deterministic eviction (kick slot = step mod bucket_size, chain starts
        at i2) so the JAX ``lax.scan`` path reproduces this table exactly.
        Transactional: a failed insert rolls the chain back, leaving the
        table unchanged — no resident key is ever orphaned by a failure
        (the paper observed false negatives near saturation; rollback is the
        safeguard that lets OCF resize *then* retry losslessly).
        """
        fp, i1 = self._fp_i1(key)
        i2 = self._alt(i1, fp)
        for i in (i1, i2):
            slot = np.where(self.table[i] == 0)[0]
            if slot.size:
                self.table[i, slot[0]] = fp
                self.count += 1
                return True
        # Eviction chain with rollback history.
        i, cur = i2, np.uint32(fp)
        hist: list[tuple[int, int]] = []
        for step in range(self.max_displacements):
            j = step % self.bucket_size
            cur, self.table[i, j] = self.table[i, j], cur
            hist.append((i, j))
            i = self._alt(i, int(cur))
            slot = np.where(self.table[i] == 0)[0]
            if slot.size:
                self.table[i, slot[0]] = cur
                self.count += 1
                return True
        for (bi, bj) in reversed(hist):
            cur, self.table[bi, bj] = self.table[bi, bj], cur
        assert cur == fp  # rollback returned the original fingerprint
        return False

    def delete(self, key: int) -> bool:
        fp, i1 = self._fp_i1(key)
        for i in (i1, self._alt(i1, fp)):
            slot = np.where(self.table[i] == fp)[0]
            if slot.size:
                self.table[i, slot[0]] = 0
                self.count -= 1
                return True
        return False

    # -- bulk wrappers (oracle for the JAX bulk ops) --------------------

    def bulk_lookup(self, keys) -> np.ndarray:
        return np.array([self.lookup(int(k)) for k in np.asarray(keys)], dtype=bool)

    def bulk_insert(self, keys) -> np.ndarray:
        return np.array([self.insert(int(k)) for k in np.asarray(keys)], dtype=bool)

    def bulk_delete(self, keys) -> np.ndarray:
        return np.array([self.delete(int(k)) for k in np.asarray(keys)], dtype=bool)
