"""OCF — the Optimized Cuckoo Filter (paper §II).

Host-side control plane + backend-dispatched data plane:

  * data plane: every lookup/insert/delete/rebuild goes through
    ``repro.core.filter_ops.FilterOps`` — one dispatch layer over the
    pure-jnp bulk ops and the fused Pallas kernels (probe, insert with
    bounded device-side eviction rounds, first-match-slot delete), selected
    by ``OcfConfig.backend`` ("jnp" | "pallas" | "auto").  The table is a
    device-resident **dynamic active capacity inside a preallocated pow2
    buffer** — resizes change no shapes, so the jit/kernel cache stays warm
    across the whole EOF schedule; device calls are fixed-``CHUNK`` batches
    with validity masks (one compile per buffer size, ever).
  * control plane: PRE or EOF resize policy; on a resize decision (or an
    insert failure = filter full) the table is **rebuilt from the backing
    keystore** at the new capacity.  The keystore also makes deletes safe:
    only keys it contains reach the filter (the paper's fix for
    blind-delete corruption).  The keystore itself is a vectorized numpy
    multiset (``core.keystore.VectorKeystore``) — no per-key Python loops
    anywhere on the batch path.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import numpy as np

from repro.core.chunking import (collect_chunk_results, key_chunks,
                                 pow2_at_least)
from repro.core.filter_ops import Backend, FilterOps, evict_rounds_for_load
from repro.core.scheduling import dedupe_keys
# Leaf-module import (NOT repro.kernels.ops): core/__init__ runs during the
# kernel package's own init when an entry point imports kernels first, and
# ops would be partially initialized here.  kernels/stash.py only needs
# core.hashing, so it is cycle-safe.
from repro.kernels.stash import make_stash, stash_occupancy
from repro.core.keystore import VectorKeystore
from repro.core.policy import EofPolicy, PrePolicy, ResizeDecision
from repro.core import filter as jfilter

SNAP_BUCKETS = 256


@dataclasses.dataclass
class OcfConfig:
    """Paper §II-B parameters (+ the data-plane backend switch)."""

    capacity: int = 1 << 16          # item slots; paper: 2× expected items
    bucket_size: int = 4             # paper-recommended
    fp_bits: int = 16
    max_displacements: int = 500
    mode: Literal["PRE", "EOF"] = "EOF"
    backend: Backend = "auto"        # filter data plane: jnp | pallas | auto
    # Pallas insert kernel's eviction budget.  None (default) derives it
    # from the configured operating load: evict_rounds_for_load(o_max) —
    # 32 at the default o_max=0.85, 64 at 0.9.
    evict_rounds: Optional[int] = None
    # Overflow-stash slots (0 = no stash, the classic grow-on-failure OCF).
    # With a stash, eviction-storm inserts park in the stash instead of
    # triggering an emergency grow+rebuild; the stash is re-derived empty on
    # every rebuild, which also reclaims entries whose key was deleted.
    stash_slots: int = 0
    # Conflict-aware wave scheduling of insert batches (core/scheduling.py)
    # on the pallas data plane — fewer intra-batch rank races and eviction
    # rounds; membership semantics unchanged.
    schedule: bool = True
    # Host-side lookup dedup (probe one lane per distinct key in a batch).
    # Off by default — an all-unique batch pays the np.unique sort for
    # nothing; dedup-heavy consumers opt in.  Same knob and rationale as
    # GenerationConfig.dedupe_lookups.
    dedupe_lookups: bool = False
    # Buffer donation: the OCF owns its pow2 buffer and never reuses a
    # pre-op table, so mutating ops update it in place (zero-copy) instead
    # of copying the buffer every batch.
    donate: bool = True
    o_max: float = 0.85              # Max Occupancy
    o_min: float = 0.25              # Min Occupancy
    k_min: float = 0.35              # K markers (EOF)
    k_max: float = 0.75
    gain: float = 1.0 / 16.0         # Estimation Gain g (EOF)
    c_min: int = 1024
    c_max: int = 1 << 30

    def make_policy(self):
        if self.mode == "PRE":
            return PrePolicy(o_max=self.o_max, o_min=self.o_min,
                             c_min=self.c_min, c_max=self.c_max)
        return EofPolicy(o_max=self.o_max, o_min=self.o_min, k_min=self.k_min,
                         k_max=self.k_max, gain=self.gain, c_min=self.c_min,
                         c_max=self.c_max)

    def make_filter_ops(self) -> FilterOps:
        rounds = (self.evict_rounds if self.evict_rounds is not None
                  else evict_rounds_for_load(self.o_max))
        return FilterOps(fp_bits=self.fp_bits,
                         max_disp=self.max_displacements,
                         backend=self.backend,
                         evict_rounds=rounds,
                         schedule=self.schedule,
                         donate=self.donate)


@dataclasses.dataclass
class OcfStats:
    inserts: int = 0
    deletes: int = 0
    lookups: int = 0
    resizes: int = 0
    grows: int = 0
    shrinks: int = 0
    rebuild_keys: int = 0
    failed_inserts: int = 0       # chain exhausted -> emergency grow
    stash_spills: int = 0         # chain exhausted -> parked in the stash
    blind_deletes_blocked: int = 0
    buffer_reallocs: int = 0      # pow2 buffer growth (recompile events)


class OCF:
    """Optimized Cuckoo Filter with a backing keystore (memtable analogue)."""

    def __init__(self, config: OcfConfig | None = None):
        self.config = config or OcfConfig()
        self.policy = self.config.make_policy()
        self.ops = self.config.make_filter_ops()
        self.keystore = VectorKeystore()
        active = self._snap_buckets(self.config.capacity)
        buf = pow2_at_least(active)
        self.state = jfilter.make_state(active, self.config.bucket_size,
                                        buffer_buckets=buf)
        self.stash = (make_stash(self.config.stash_slots)
                      if self.config.stash_slots else None)
        self.stats = OcfStats()
        self.capacity_history: list[int] = [self.capacity]

    # ------------------------------------------------------------ props --

    def _snap_buckets(self, capacity_slots: int) -> int:
        b = max(1, -(-capacity_slots // self.config.bucket_size))
        return -(-b // SNAP_BUCKETS) * SNAP_BUCKETS

    @property
    def capacity(self) -> int:
        return int(self.state.n_buckets) * self.config.bucket_size

    @property
    def buffer_capacity(self) -> int:
        return self.state.table.shape[0] * self.config.bucket_size

    @property
    def count(self) -> int:
        return int(self.state.count)

    @property
    def occupancy(self) -> float:
        return self.count / self.capacity

    def __len__(self) -> int:
        return self.keystore.total

    # ---------------------------------------------------------- chunking --

    _chunks = staticmethod(key_chunks)   # shared contract: core/chunking.py

    # ------------------------------------------------------------- ops ---

    def lookup(self, keys) -> np.ndarray:
        keys = np.asarray(keys, dtype=np.uint64)
        self.stats.lookups += keys.size
        # Dedup pre-pass (core/scheduling.py, opt-in): probes are
        # idempotent, so a batch with in-batch repeats only pays one device
        # lane per distinct key; answers broadcast back through the
        # inverse index.
        if self.config.dedupe_lookups:
            probe_keys, inverse = dedupe_keys(keys)
        else:
            probe_keys, inverse = keys, None
        hits, ns = [], []
        for hi, lo, _valid, n in self._chunks(probe_keys, with_valid=False):
            if self.stash is not None:
                hit = self.ops.lookup_with_stash(self.state, self.stash,
                                                 hi, lo)
            else:
                hit = self.ops.lookup(self.state, hi, lo)
            hits.append(hit)
            ns.append(n)
        out = collect_chunk_results(hits, ns)
        return out[inverse] if inverse is not None else out

    def insert(self, keys) -> np.ndarray:
        """Insert a batch; returns ok mask (all True unless c_max exhausted)."""
        keys = np.asarray(keys, dtype=np.uint64)
        self.stats.inserts += keys.size
        self._maybe_resize(extra=keys.size, ops=keys.size)
        self.keystore.add(keys)
        # Queue every chunk on device first; the ok masks are stacked on
        # device and pulled back in ONE host transfer after the whole batch
        # (the seed synced per chunk, serializing on device->host latency).
        # The stash-spill stat follows the same discipline: occupancy stays
        # a device scalar until everything is queued.
        spilled_before = (stash_occupancy(self.stash)
                          if self.stash is not None else None)
        oks, ns = [], []
        for hi, lo, valid, n in self._chunks(keys):
            if self.stash is not None:
                state, stash, ok = self.ops.insert_spill(
                    self.state, self.stash, hi, lo, valid=valid)
                self.stash = stash
            else:
                state, ok = self.ops.insert(self.state, hi, lo, valid=valid)
            self.state = state
            oks.append(ok)
            ns.append(n)
        failed = int((~collect_chunk_results(oks, ns)).sum()) if oks else 0
        if self.stash is not None:
            self.stats.stash_spills += int(
                stash_occupancy(self.stash) - spilled_before)
        if failed:
            # Table AND (when configured) stash exhausted: emergency grow +
            # rebuild; the keystore already holds the whole batch, so the
            # rebuild IS the retry (never double-insert).
            self.stats.failed_inserts += failed
            self._resize(ResizeDecision(
                new_capacity=min(self.capacity * 2, self.config.c_max),
                reason="grow"))
        return np.ones(keys.size, dtype=bool)

    def delete(self, keys) -> np.ndarray:
        """Verified delete (paper §IV): only keystore-present keys reach the
        filter, so foreign fingerprints are never removed.  The presence
        check is one vectorized keystore op, not a per-key loop.

        With a stash configured, a key whose fingerprint sits in the stash
        (not the table) is removed from the keystore but its stash entry
        lingers as a false positive until the next rebuild re-derives the
        stash — the standard filter trade (false positives allowed, false
        negatives never)."""
        keys = np.asarray(keys, dtype=np.uint64)
        self.stats.deletes += keys.size
        present = self.keystore.remove(keys)
        self.stats.blind_deletes_blocked += int((~present).sum())
        victims = keys[present]
        if victims.size:
            for hi, lo, valid, _n in self._chunks(victims):
                state, _ok = self.ops.delete(self.state, hi, lo, valid=valid)
                self.state = state
        self._maybe_resize(ops=keys.size)
        return present

    def contains_key_exact(self, key: int) -> bool:
        return self.keystore.contains(int(key))

    def contains_keys_exact(self, keys) -> np.ndarray:
        """Vectorized ground truth: residency mask bool[B] in one keystore
        pass (``measure_false_positives`` probes millions of keys — the
        scalar form would loop Python per key)."""
        return self.keystore.contains_batch(keys)

    # ---------------------------------------------------------- control --

    def _maybe_resize(self, extra: int = 0, ops: int = 1) -> None:
        decision = self.policy.observe(items=self.count + extra,
                                       capacity=self.capacity, ops=ops)
        if decision is not None:
            self._resize(decision)

    def _rebuild_into(self, active_buckets: int, buffer_buckets: int) -> bool:
        """Rebuild from the keystore; the stash (when configured) restarts
        empty — rebuilding re-homes previously stashed fingerprints into the
        (larger) table and garbage-collects entries whose key was deleted
        while stashed."""
        keys = self.keystore.materialize()
        state = jfilter.make_state(active_buckets, self.config.bucket_size,
                                   buffer_buckets=buffer_buckets)
        stash = (make_stash(self.config.stash_slots)
                 if self.stash is not None else None)
        ok_all = True
        for hi, lo, valid, n in self._chunks(keys):
            if stash is not None:
                state, stash, ok = self.ops.insert_spill(state, stash, hi,
                                                         lo, valid=valid)
            else:
                state, ok = self.ops.insert(state, hi, lo, valid=valid)
            ok_all = ok_all and bool(np.asarray(ok)[:n].all())
        if ok_all:
            self.state = state
            self.stash = stash
            self.stats.rebuild_keys += keys.size
        return ok_all

    def _resize(self, decision: ResizeDecision) -> None:
        new_active = self._snap_buckets(decision.new_capacity)
        if new_active == int(self.state.n_buckets):
            return
        buf = self.state.table.shape[0]
        # Reallocate the buffer only when the active size outgrows it or
        # drops below a quarter of it (reclaim memory); pow2 keeps the jit
        # cache to O(log range) entries.
        if new_active > buf or new_active * 4 < buf:
            buf = pow2_at_least(new_active)
            self.stats.buffer_reallocs += 1
        while not self._rebuild_into(new_active, max(buf, pow2_at_least(
                new_active))):
            # Shrink too tight even after clamping: grow until it fits.
            new_active *= 2
            buf = pow2_at_least(new_active)
        self.stats.resizes += 1
        if decision.reason == "grow":
            self.stats.grows += 1
        else:
            self.stats.shrinks += 1
        self.capacity_history.append(self.capacity)
