"""OCF — the Optimized Cuckoo Filter (paper §II).

Host-side control plane + JAX data plane:

  * data plane: jitted bulk lookup/insert/delete over a device-resident
    table with a **dynamic active capacity inside a preallocated pow2
    buffer** (repro.core.filter) — resizes change no shapes, so the jit
    cache stays warm across the whole EOF schedule; device calls are
    fixed-``CHUNK`` batches with validity masks (one compile per buffer
    size, ever).
  * control plane: PRE or EOF resize policy; on a resize decision (or an
    insert failure = filter full) the table is **rebuilt from the backing
    keystore** at the new capacity.  The keystore also makes deletes safe:
    only keys it contains reach the filter (the paper's fix for
    blind-delete corruption).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import numpy as np
import jax.numpy as jnp

from repro.core import filter as jfilter
from repro.core import hashing
from repro.core.policy import EofPolicy, PrePolicy, ResizeDecision

SNAP_BUCKETS = 256
CHUNK = 4096


@dataclasses.dataclass
class OcfConfig:
    """Paper §II-B parameters."""

    capacity: int = 1 << 16          # item slots; paper: 2× expected items
    bucket_size: int = 4             # paper-recommended
    fp_bits: int = 16
    max_displacements: int = 500
    mode: Literal["PRE", "EOF"] = "EOF"
    o_max: float = 0.85              # Max Occupancy
    o_min: float = 0.25              # Min Occupancy
    k_min: float = 0.35              # K markers (EOF)
    k_max: float = 0.75
    gain: float = 1.0 / 16.0         # Estimation Gain g (EOF)
    c_min: int = 1024
    c_max: int = 1 << 30

    def make_policy(self):
        if self.mode == "PRE":
            return PrePolicy(o_max=self.o_max, o_min=self.o_min,
                             c_min=self.c_min, c_max=self.c_max)
        return EofPolicy(o_max=self.o_max, o_min=self.o_min, k_min=self.k_min,
                         k_max=self.k_max, gain=self.gain, c_min=self.c_min,
                         c_max=self.c_max)


@dataclasses.dataclass
class OcfStats:
    inserts: int = 0
    deletes: int = 0
    lookups: int = 0
    resizes: int = 0
    grows: int = 0
    shrinks: int = 0
    rebuild_keys: int = 0
    failed_inserts: int = 0       # chain exhausted -> emergency grow
    blind_deletes_blocked: int = 0
    buffer_reallocs: int = 0      # pow2 buffer growth (recompile events)


def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


class OCF:
    """Optimized Cuckoo Filter with a backing keystore (memtable analogue)."""

    def __init__(self, config: OcfConfig | None = None):
        self.config = config or OcfConfig()
        self.policy = self.config.make_policy()
        self._keys: dict[int, int] = {}  # key -> multiplicity
        active = self._snap_buckets(self.config.capacity)
        buf = _pow2_at_least(active)
        self.state = jfilter.make_state(active, self.config.bucket_size,
                                        buffer_buckets=buf)
        self.stats = OcfStats()
        self.capacity_history: list[int] = [self.capacity]

    # ------------------------------------------------------------ props --

    def _snap_buckets(self, capacity_slots: int) -> int:
        b = max(1, -(-capacity_slots // self.config.bucket_size))
        return -(-b // SNAP_BUCKETS) * SNAP_BUCKETS

    @property
    def capacity(self) -> int:
        return int(self.state.n_buckets) * self.config.bucket_size

    @property
    def buffer_capacity(self) -> int:
        return self.state.table.shape[0] * self.config.bucket_size

    @property
    def count(self) -> int:
        return int(self.state.count)

    @property
    def occupancy(self) -> float:
        return self.count / self.capacity

    def __len__(self) -> int:
        return sum(self._keys.values())

    # ---------------------------------------------------------- chunking --

    @staticmethod
    def _chunks(keys: np.ndarray):
        """Yield (hi, lo, valid, n_real) fixed-size CHUNK batches."""
        for i in range(0, keys.size, CHUNK):
            part = keys[i:i + CHUNK]
            n = part.size
            if n < CHUNK:
                part = np.pad(part, (0, CHUNK - n))
            hi, lo = hashing.key_to_u32_pair_np(part)
            valid = np.zeros(CHUNK, bool)
            valid[:n] = True
            yield jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(valid), n

    # ------------------------------------------------------------- ops ---

    def lookup(self, keys) -> np.ndarray:
        keys = np.asarray(keys, dtype=np.uint64)
        self.stats.lookups += keys.size
        out = np.zeros(keys.size, bool)
        off = 0
        for hi, lo, _valid, n in self._chunks(keys):
            hits = jfilter.bulk_lookup(self.state, hi, lo,
                                       fp_bits=self.config.fp_bits)
            out[off:off + n] = np.asarray(hits)[:n]
            off += n
        return out

    def insert(self, keys) -> np.ndarray:
        """Insert a batch; returns ok mask (all True unless c_max exhausted)."""
        keys = np.asarray(keys, dtype=np.uint64)
        self.stats.inserts += keys.size
        self._maybe_resize(extra=keys.size, ops=keys.size)
        for k in keys.tolist():
            self._keys[k] = self._keys.get(k, 0) + 1
        all_ok = True
        for hi, lo, valid, n in self._chunks(keys):
            state, ok = jfilter.bulk_insert_hybrid(
                self.state, hi, lo, fp_bits=self.config.fp_bits,
                max_disp=self.config.max_displacements, valid=valid)
            self.state = state
            if not bool(np.asarray(ok)[:n].all()):
                all_ok = False
                self.stats.failed_inserts += int(
                    (~np.asarray(ok)[:n]).sum())
        if not all_ok:
            # Emergency grow + rebuild; the keystore already holds the whole
            # batch, so the rebuild IS the retry (never double-insert).
            self._resize(ResizeDecision(
                new_capacity=min(self.capacity * 2, self.config.c_max),
                reason="grow"))
        return np.ones(keys.size, dtype=bool)

    def delete(self, keys) -> np.ndarray:
        """Verified delete (paper §IV): only keystore-present keys reach the
        filter, so foreign fingerprints are never removed."""
        keys = np.asarray(keys, dtype=np.uint64)
        self.stats.deletes += keys.size
        present = np.array([self._keys.get(int(k), 0) > 0 for k in keys])
        self.stats.blind_deletes_blocked += int((~present).sum())
        victims = keys[present]
        if victims.size:
            for k in victims.tolist():
                self._keys[k] -= 1
                if self._keys[k] <= 0:
                    del self._keys[k]
            for hi, lo, valid, n in self._chunks(victims):
                state, _ok = jfilter.bulk_delete(
                    self.state, hi, lo, fp_bits=self.config.fp_bits,
                    valid=valid)
                self.state = state
        self._maybe_resize(ops=keys.size)
        return present

    def contains_key_exact(self, key: int) -> bool:
        return self._keys.get(int(key), 0) > 0

    # ---------------------------------------------------------- control --

    def _maybe_resize(self, extra: int = 0, ops: int = 1) -> None:
        decision = self.policy.observe(items=self.count + extra,
                                       capacity=self.capacity, ops=ops)
        if decision is not None:
            self._resize(decision)

    def _rebuild_into(self, active_buckets: int, buffer_buckets: int) -> bool:
        keys = np.fromiter(
            (k for k, m in self._keys.items() for _ in range(m)),
            dtype=np.uint64, count=sum(self._keys.values()))
        state = jfilter.make_state(active_buckets, self.config.bucket_size,
                                   buffer_buckets=buffer_buckets)
        ok_all = True
        for hi, lo, valid, n in self._chunks(keys):
            state, ok = jfilter.bulk_insert_hybrid(
                state, hi, lo, fp_bits=self.config.fp_bits,
                max_disp=self.config.max_displacements, valid=valid)
            ok_all = ok_all and bool(np.asarray(ok)[:n].all())
        if ok_all:
            self.state = state
            self.stats.rebuild_keys += keys.size
        return ok_all

    def _resize(self, decision: ResizeDecision) -> None:
        new_active = self._snap_buckets(decision.new_capacity)
        if new_active == int(self.state.n_buckets):
            return
        buf = self.state.table.shape[0]
        # Reallocate the buffer only when the active size outgrows it or
        # drops below a quarter of it (reclaim memory); pow2 keeps the jit
        # cache to O(log range) entries.
        if new_active > buf or new_active * 4 < buf:
            buf = _pow2_at_least(new_active)
            self.stats.buffer_reallocs += 1
        while not self._rebuild_into(new_active, max(buf, _pow2_at_least(
                new_active))):
            # Shrink too tight even after clamping: grow until it fits.
            new_active *= 2
            buf = _pow2_at_least(new_active)
        self.stats.resizes += 1
        if decision.reason == "grow":
            self.stats.grows += 1
        else:
            self.stats.shrinks += 1
        self.capacity_history.append(self.capacity)
