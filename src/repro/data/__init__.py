from repro.data.pipeline import DedupPipeline, SyntheticDocs, content_hash
