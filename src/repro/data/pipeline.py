"""Synthetic token data pipeline with OCF dedup (paper integration #1).

A deterministic document stream (mixture of fresh docs and re-emitted
duplicates, with bursty duplicate storms) flows through an OCF keyed on
content hashes.  Duplicates are dropped before batching; aged-out shards are
*deleted* from the filter, shrinking it via the EOF controller — the exact
insert/delete churn the paper targets.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.core.hashing import murmur3_mix_np, splitmix32_np
from repro.core.ocf import OCF, OcfConfig


def content_hash(doc: np.ndarray) -> np.uint64:
    """Order-sensitive uint64 hash of a token document."""
    toks = np.asarray(doc, dtype=np.uint32)
    with np.errstate(over="ignore"):  # uint32 wraparound is the hash mix
        pos = splitmix32_np(np.arange(toks.size, dtype=np.uint32))
        lo = murmur3_mix_np(np.bitwise_xor.reduce(murmur3_mix_np(toks ^ pos)))
        hi = splitmix32_np(lo + np.uint32(toks.size))
    return (np.uint64(hi) << np.uint64(32)) | np.uint64(lo)


@dataclasses.dataclass
class PipelineStats:
    docs_seen: int = 0
    docs_deduped: int = 0
    batches: int = 0
    shards_retired: int = 0


class SyntheticDocs:
    """Deterministic doc stream; ``dup_rate`` of docs are repeats, emitted in
    bursts of ``burst`` to stress the filter the way the paper's workload
    does."""

    def __init__(self, vocab: int, doc_len: int = 128, seed: int = 0,
                 dup_rate: float = 0.3, burst: int = 64):
        self.vocab, self.doc_len = vocab, doc_len
        self.rng = np.random.RandomState(seed)
        self.dup_rate, self.burst = dup_rate, burst
        self._history: list[np.ndarray] = []

    def __iter__(self) -> Iterator[np.ndarray]:
        while True:
            if (self._history and self.rng.rand() < self.dup_rate):
                for _ in range(self.rng.randint(1, self.burst)):
                    yield self._history[self.rng.randint(len(self._history))]
            doc = self.rng.randint(0, self.vocab, self.doc_len).astype(np.int32)
            if len(self._history) < 4096:
                self._history.append(doc)
            yield doc


class DedupPipeline:
    """Doc stream -> OCF dedup -> packed (tokens, targets) batches."""

    def __init__(self, source: Iterator[np.ndarray], batch: int, seq: int,
                 ocf_config: Optional[OcfConfig] = None,
                 shard_docs: int = 4096):
        self.source = iter(source)
        self.batch, self.seq = batch, seq
        self.ocf = OCF(ocf_config or OcfConfig(capacity=8192, mode="EOF"))
        self.stats = PipelineStats()
        self.shard_docs = shard_docs
        self._shard_keys: list[list[int]] = [[]]

    def _next_doc(self) -> np.ndarray:
        while True:
            doc = next(self.source)
            self.stats.docs_seen += 1
            key = content_hash(doc)
            if bool(self.ocf.lookup(np.array([key]))[0]):
                self.stats.docs_deduped += 1
                continue
            self.ocf.insert(np.array([key], dtype=np.uint64))
            self._shard_keys[-1].append(int(key))
            if len(self._shard_keys[-1]) >= self.shard_docs:
                self._shard_keys.append([])
                if len(self._shard_keys) > 4:
                    self.retire_oldest_shard()
            return doc

    def retire_oldest_shard(self) -> int:
        """Age out a data shard: verified-delete its keys from the filter."""
        if not self._shard_keys or not self._shard_keys[0]:
            return 0
        keys = np.array(self._shard_keys.pop(0), dtype=np.uint64)
        self.ocf.delete(keys)
        self.stats.shards_retired += 1
        return keys.size

    def __iter__(self):
        buf = np.zeros(0, dtype=np.int32)
        need = self.batch * (self.seq + 1)
        while True:
            while buf.size < need:
                buf = np.concatenate([buf, self._next_doc()])
            flat = buf[:need].reshape(self.batch, self.seq + 1)
            buf = buf[need:]
            self.stats.batches += 1
            yield {"tokens": flat[:, :-1].copy(),
                   "targets": flat[:, 1:].copy()}
