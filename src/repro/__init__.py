"""repro: OCF (Optimized Cuckoo Filter) inside a multi-pod JAX LM framework."""
__version__ = "1.0.0"
