from repro.train.step import cross_entropy, make_train_step
