"""Train-step factory: loss + backward + AdamW under pjit.

Handles remat (activation checkpointing), gradient accumulation
(``microbatches > 1`` scans over batch splits) and optional bf16 cross-pod
gradient compression (the pod axis all-reduce is the cross-DCN collective —
halving its bytes is the §Perf lever for collective-bound multi-pod cells).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import ParallelConfig
from repro.models.transformer import Transformer
from repro.optim.adamw import AdamW


def cross_entropy(logits, targets, mask=None):
    """logits [B,S,V] f32; targets [B,S] int32. Mean over valid tokens."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    mask = mask.astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _compress_pod_grads(grads, parallel: ParallelConfig):
    """bf16 round-trip before the cross-pod all-reduce.

    Params are replicated over ``pod``; XLA inserts the cross-pod grad
    all-reduce right after this cast, so the collective moves bf16 (half the
    bytes).  The f32 restore happens after the sum.
    """
    return jax.tree.map(
        lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)


def quantize_int8(g):
    """Per-tensor symmetric int8 quantization -> (q, scale)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def _compress_pod_grads_int8(grads, parallel: ParallelConfig):
    """int8 round-trip: 4× fewer cross-pod bytes than f32, 2× vs bf16.

    Error is bounded by scale/2 per element (symmetric rounding); with
    per-tensor scales and gradient clipping at 1.0 the induced noise is
    well under optimizer epsilon for the tensors that matter.  The
    quantize/AR/dequantize pattern matches 1-bit/8-bit Adam deployments.
    """
    def one(g):
        q, s = quantize_int8(g)
        return dequantize_int8(q, s)

    return jax.tree.map(one, grads)


def make_train_step(model: Transformer, tx: AdamW,
                    parallel: ParallelConfig):
    cfg = model.cfg

    def loss_fn(params, batch):
        kwargs = {}
        if cfg.prefix_embed_len:
            kwargs["prefix_embeds"] = batch["prefix_embeds"]
        if cfg.cross_attn_memory_len:
            kwargs["memory"] = batch["memory"]
        out = model.apply(params, batch["tokens"], remat=parallel.remat,
                          parallel=parallel, **kwargs)
        loss = cross_entropy(out.logits, batch["targets"],
                             batch.get("mask"))
        return loss + out.aux_loss, (loss, out.aux_loss)

    def train_step(params, opt_state, batch):
        if parallel.microbatches > 1:
            mb = parallel.microbatches

            def split(x):
                b = x.shape[0]
                return x.reshape(mb, b // mb, *x.shape[1:])

            mbatch = jax.tree.map(split, batch)

            def acc_step(carry, mb_batch):
                g_acc, l_acc, a_acc = carry
                (tot, (loss, aux)), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb_batch)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + loss, a_acc + aux), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, aux), _ = jax.lax.scan(
                acc_step, (zeros, jnp.zeros(()), jnp.zeros(())), mbatch)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss, aux = loss / mb, aux / mb
        else:
            (tot, (loss, aux)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        if parallel.compress_grads and parallel.pod_axis:
            if getattr(parallel, "compress_int8", False):
                grads = _compress_pod_grads_int8(grads, parallel)
            else:
                grads = _compress_pod_grads(grads, parallel)

        params, opt_state, gnorm = tx.update(grads, opt_state, params)
        metrics = {"loss": loss, "aux_loss": aux, "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step
