"""Trace spans: Chrome trace-event JSON, loadable in Perfetto.

``TraceRecorder.span("harvest", kind="insert")`` wraps any region in a
complete-event (``ph: "X"``) with microsecond timestamps; ``instant``
drops a point marker.  ``save(path)`` writes the standard
``{"traceEvents": [...]}`` envelope — open it at https://ui.perfetto.dev
or ``chrome://tracing``.

When ``jax_profiler=True`` each span also enters a
``jax.profiler.TraceAnnotation`` so the same names show up inside an XLA
profile; the import is guarded so the recorder works wherever JSON does.

A recorder is cheap but not free (two clock reads and a dict per span),
so the serving stack only creates spans when a recorder is passed in —
``tracer=None`` keeps the hot path untouched.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional

try:  # optional: annotate XLA profiles when jax.profiler is importable
    from jax.profiler import TraceAnnotation as _JaxAnnotation
except Exception:  # pragma: no cover - jax always present in this repo
    _JaxAnnotation = None


class TraceRecorder:
    """Collects Chrome trace events; one recorder per run/scenario."""

    def __init__(self, *, process_name: str = "repro",
                 jax_profiler: bool = False,
                 clock=time.perf_counter) -> None:
        self._events: List[dict] = []
        self._clock = clock
        self._t0 = clock()
        self._pid = os.getpid()
        self._jax = bool(jax_profiler) and _JaxAnnotation is not None
        self._events.append({
            "name": "process_name", "ph": "M", "pid": self._pid, "tid": 0,
            "args": {"name": process_name}})

    def _us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    @contextmanager
    def span(self, name: str, **args: Any) -> Iterator[None]:
        tid = threading.get_ident() % (1 << 31)
        t0 = self._us()
        if self._jax:
            with _JaxAnnotation(name):
                yield
        else:
            yield
        self._events.append({
            "name": name, "ph": "X", "ts": t0, "dur": self._us() - t0,
            "pid": self._pid, "tid": tid,
            "args": {k: _jsonable(v) for k, v in args.items()}})

    def instant(self, name: str, **args: Any) -> None:
        self._events.append({
            "name": name, "ph": "i", "s": "t", "ts": self._us(),
            "pid": self._pid, "tid": threading.get_ident() % (1 << 31),
            "args": {k: _jsonable(v) for k, v in args.items()}})

    def counter(self, name: str, **values: float) -> None:
        """Emit a counter event — renders as a stacked area in Perfetto."""
        self._events.append({
            "name": name, "ph": "C", "ts": self._us(), "pid": self._pid,
            "tid": 0,
            "args": {k: float(v) for k, v in values.items()}})

    @property
    def events(self) -> List[dict]:
        return list(self._events)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"traceEvents": self._events,
                       "displayTimeUnit": "ms"}, f)


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    try:
        return float(v)  # numpy / jax scalars
    except Exception:
        return str(v)


__all__ = ["TraceRecorder"]
