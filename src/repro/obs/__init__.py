"""Observability: host metrics registry + trace spans.

Device counter planes live beside the kernels in
``repro.kernels.telemetry``; this package is the host half — the
label-carrying metrics registry (JSONL / Prometheus export) and the
Chrome-trace span recorder.  Everything here is optional-by-default:
components accept ``metrics=None`` / ``tracer=None`` and do no
observability work unless handed one.
"""
from repro.obs.recovery import RecoveryMetrics
from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                RingBuffer)
from repro.obs.trace import TraceRecorder

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "RecoveryMetrics", "RingBuffer", "TraceRecorder"]
