"""Host-side metrics registry: counters / gauges / histograms with labels.

One process-wide (or per-harness) ``MetricsRegistry`` owns every metric;
components take an optional ``metrics=None`` argument and do *zero* work
when it is absent — the default-off path allocates nothing and transfers
nothing off-device.  When enabled, per-wave records land in a fixed-
capacity ring buffer written by the single harvest thread (appends under
the GIL are atomic; there is no lock, and readers snapshot by index so a
concurrent scrape never blocks the wave path).

Export formats:

* ``snapshot()``      — plain dict, one entry per (metric, labelset);
* ``to_jsonl(path)``  — one JSON object per line, ready for artifact
  upload / offline diffing;
* ``prometheus_text()`` — text exposition format (counters as
  ``_total``, histograms as cumulative ``_bucket{le=...}`` + ``_sum`` +
  ``_count``), scrapeable by anything that speaks Prometheus.

Label sets are small and explicit (``kind="insert"``), normalised to a
sorted tuple so ``{a=1,b=2}`` and ``{b=2,a=1}`` are the same series.
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _labelkey(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _labelstr(key: LabelKey) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class Counter:
    """Monotonically increasing count, one series per label set."""

    kind = "counter"

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self._series: Dict[LabelKey, float] = {}

    def inc(self, value: float = 1.0, **labels: Any) -> None:
        key = _labelkey(labels)
        self._series[key] = self._series.get(key, 0.0) + float(value)

    def value(self, **labels: Any) -> float:
        return self._series.get(_labelkey(labels), 0.0)

    def series(self) -> Dict[LabelKey, float]:
        return dict(self._series)


class Gauge:
    """Last-set value; ``set_max`` keeps a high-water mark instead."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self._series: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels: Any) -> None:
        self._series[_labelkey(labels)] = float(value)

    def set_max(self, value: float, **labels: Any) -> None:
        key = _labelkey(labels)
        cur = self._series.get(key)
        if cur is None or value > cur:
            self._series[key] = float(value)

    def value(self, **labels: Any) -> float:
        return self._series.get(_labelkey(labels), 0.0)

    def series(self) -> Dict[LabelKey, float]:
        return dict(self._series)


@dataclass
class _HistSeries:
    counts: List[float]
    total: float = 0.0
    n: float = 0.0


class Histogram:
    """Fixed-bucket histogram; buckets are inclusive upper edges.

    ``observe`` records one sample; ``observe_counts`` folds a whole
    per-bucket count vector in one call — that is how a device-computed
    kick-depth histogram (already binned on the accelerator) merges into
    the host registry without being unbinned.
    """

    kind = "histogram"

    def __init__(self, name: str, buckets: Sequence[float],
                 help: str = "") -> None:
        self.name = name
        self.help = help
        self.buckets = tuple(float(b) for b in buckets)
        self._series: Dict[LabelKey, _HistSeries] = {}

    def _get(self, labels: Dict[str, Any]) -> _HistSeries:
        key = _labelkey(labels)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = _HistSeries([0.0] * (len(self.buckets)
                                                         + 1))
        return s

    def observe(self, value: float, **labels: Any) -> None:
        s = self._get(labels)
        value = float(value)
        for i, edge in enumerate(self.buckets):
            if value <= edge:
                s.counts[i] += 1.0
                break
        else:
            s.counts[-1] += 1.0
        s.total += value
        s.n += 1.0

    def observe_counts(self, counts: Sequence[float],
                       **labels: Any) -> None:
        if len(counts) != len(self.buckets) + 1:
            raise ValueError(
                f"{self.name}: expected {len(self.buckets) + 1} bucket "
                f"counts, got {len(counts)}")
        s = self._get(labels)
        for i, c in enumerate(counts):
            s.counts[i] += float(c)
        # Bucket midpoint proxy for the sum: device planes only ship
        # counts, so the _sum series is approximate there (documented).
        edges = self.buckets + (self.buckets[-1],)
        s.total += sum(float(c) * edges[i] for i, c in enumerate(counts))
        s.n += sum(float(c) for c in counts)

    def series(self) -> Dict[LabelKey, _HistSeries]:
        return dict(self._series)


class RingBuffer:
    """Fixed-capacity per-wave record ring, single writer, lock-free.

    The harvest thread is the only writer; ``append`` is one list store
    plus one integer bump (each atomic under the GIL), so the wave path
    never takes a lock.  Readers copy out by index — a torn read can at
    worst see a record twice across two snapshots, never a half-written
    record.
    """

    def __init__(self, capacity: int = 1024) -> None:
        self.capacity = int(capacity)
        self._buf: List[Optional[dict]] = [None] * self.capacity
        self._n = 0  # total appends ever

    def append(self, record: dict) -> None:
        self._buf[self._n % self.capacity] = record
        self._n += 1

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    def records(self) -> List[dict]:
        n = self._n
        if n <= self.capacity:
            out = self._buf[:n]
        else:
            i = n % self.capacity
            out = self._buf[i:] + self._buf[:i]
        return [r for r in out if r is not None]


class MetricsRegistry:
    """Namespace of metrics + the per-wave ring buffer."""

    def __init__(self, *, ring_capacity: int = 1024) -> None:
        self._metrics: Dict[str, Any] = {}
        self._lock = threading.Lock()  # registration only, never the hot path
        self.ring = RingBuffer(ring_capacity)

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(name, lambda: Counter(name, help), Counter)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(name, lambda: Gauge(name, help), Gauge)

    def histogram(self, name: str, buckets: Sequence[float],
                  help: str = "") -> Histogram:
        h = self._register(name, lambda: Histogram(name, buckets, help),
                           Histogram)
        if h.buckets != tuple(float(b) for b in buckets):
            raise ValueError(f"{name}: histogram re-registered with "
                             f"different buckets")
        return h

    def _register(self, name, factory, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = factory()
            elif not isinstance(m, cls):
                raise TypeError(f"{name}: already registered as "
                                f"{type(m).__name__}")
            return m

    def record_wave(self, record: dict) -> None:
        self.ring.append(record)

    # -- export ----------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Histogram):
                for key, s in sorted(m.series().items()):
                    out[name + _labelstr(key)] = {
                        "buckets": list(m.buckets), "counts": list(s.counts),
                        "sum": s.total, "count": s.n}
            else:
                for key, v in sorted(m.series().items()):
                    out[name + _labelstr(key)] = v
        return out

    def to_jsonl(self, path: str) -> None:
        ts = time.time()
        with open(path, "w") as f:
            for name, m in sorted(self._metrics.items()):
                if isinstance(m, Histogram):
                    for key, s in sorted(m.series().items()):
                        f.write(json.dumps({
                            "ts": ts, "metric": name, "type": m.kind,
                            "labels": dict(key),
                            "buckets": list(m.buckets),
                            "counts": list(s.counts),
                            "sum": s.total, "count": s.n}) + "\n")
                else:
                    for key, v in sorted(m.series().items()):
                        f.write(json.dumps({
                            "ts": ts, "metric": name, "type": m.kind,
                            "labels": dict(key), "value": v}) + "\n")
            for rec in self.ring.records():
                f.write(json.dumps({"ts": ts, "type": "wave",
                                    "record": rec}) + "\n")

    def prometheus_text(self) -> str:
        lines: List[str] = []
        for name, m in sorted(self._metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                for key, s in sorted(m.series().items()):
                    cum = 0.0
                    for i, edge in enumerate(m.buckets):
                        cum += s.counts[i]
                        lk = key + (("le", repr(edge)),)
                        lines.append(f"{name}_bucket{_labelstr(lk)} {cum}")
                    cum += s.counts[-1]
                    lk = key + (("le", "+Inf"),)
                    lines.append(f"{name}_bucket{_labelstr(lk)} {cum}")
                    lines.append(f"{name}_sum{_labelstr(key)} {s.total}")
                    lines.append(f"{name}_count{_labelstr(key)} {s.n}")
            else:
                suffix = "_total" if isinstance(m, Counter) else ""
                for key, v in sorted(m.series().items()):
                    lines.append(f"{name}{suffix}{_labelstr(key)} {v}")
        return "\n".join(lines) + "\n"


__all__ = ["Counter", "Gauge", "Histogram", "RingBuffer",
           "MetricsRegistry"]
