"""Recovery observability: one facade over registry + tracer for the
fault/elastic control plane.

The elastic controller, fault injector, and recovery path all want the same
small vocabulary — keys migrated, rounds streamed, time-to-recover,
degraded-window answers, deferred backlog — and the bench gate wants those
names STABLE (it greps the exported JSONL for ``elastic_*`` rows).  This
module is that vocabulary: every producer calls one semantic method, and
the method fans out to the right counter/gauge/span so no producer
hand-rolls metric names.

Metric schema (all through one ``MetricsRegistry``):

  counters
    ``elastic_keys_migrated{direction}``      fingerprints shipped
    ``elastic_migration_rounds{direction}``   all_to_all rounds
    ``elastic_migration_failed{direction}``   lanes lost to full receivers
    ``elastic_backlog_drained_lanes``         parked writes replayed
    ``degraded_lookup_answers``               conservative "maybe" answers
    ``shard_faults{kind}``                    injected kill/corrupt/delay
  gauges
    ``elastic_migration_seconds{direction}``  migration wall time
    ``elastic_time_to_recover_s{event}``      hold -> recovered, per event
    ``elastic_deferred_backlog``              lanes still parked

Spans (``elastic_split`` / ``elastic_merge`` / ``recover_shard`` /
``pump_resubmit``) ride the same ``TraceRecorder`` the serving batcher
uses, so a migration shows up on the one timeline next to the waves it
displaced.  Like every obs consumer in the repo: ``metrics=None`` /
``tracer=None`` makes every method a no-op.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional


@dataclasses.dataclass
class RecoveryMetrics:
    """Recovery-event recorder over an optional registry + tracer."""

    metrics: Optional[object] = None    # repro.obs.MetricsRegistry
    tracer: Optional[object] = None     # repro.obs.TraceRecorder

    def span(self, name: str, **args):
        if self.tracer is None:
            return contextlib.nullcontext()
        return self.tracer.span(name, **args)

    def fault(self, kind: str, shard: int) -> None:
        """An injected (or detected) shard fault: kill/corrupt/delay."""
        if self.metrics is not None:
            self.metrics.counter("shard_faults").inc(kind=kind)
        if self.tracer is not None:
            self.tracer.instant(f"fault_{kind}", shard=shard)

    def degraded(self, n: int) -> None:
        """``n`` lookups answered conservatively during a degraded window."""
        if n and self.metrics is not None:
            self.metrics.counter("degraded_lookup_answers").inc(n)

    def migration(self, direction: str, *, keys: int, rounds: int,
                  failed: int, seconds: float) -> None:
        """One completed split/merge — the MigrationReport, as metrics."""
        if self.metrics is None:
            return
        m = self.metrics
        m.counter("elastic_keys_migrated").inc(keys, direction=direction)
        m.counter("elastic_migration_rounds").inc(rounds,
                                                  direction=direction)
        m.counter("elastic_migration_failed").inc(failed,
                                                  direction=direction)
        m.gauge("elastic_migration_seconds").set(seconds,
                                                 direction=direction)

    def recovered(self, event: str, seconds: float) -> None:
        """Time-to-recover for one event (elastic_split, shard_restore...)."""
        if self.metrics is not None:
            self.metrics.gauge("elastic_time_to_recover_s").set(
                seconds, event=event)
        if self.tracer is not None:
            self.tracer.instant("recovered", event=event, seconds=seconds)

    def backlog(self, pending: int) -> None:
        """Deferred-write backlog still parked (0 == fully drained)."""
        if self.metrics is not None:
            self.metrics.gauge("elastic_deferred_backlog").set(pending)

    def drained(self, lanes: int) -> None:
        """Parked lanes replayed through the pump after a cutover."""
        if lanes and self.metrics is not None:
            self.metrics.counter("elastic_backlog_drained_lanes").inc(lanes)
