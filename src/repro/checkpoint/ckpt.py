"""Atomic, restart-safe checkpointing for params/opt/filter state.

Layout:  <dir>/step_<n>.tmp/  -> fsync'd .npy per leaf + manifest.json
         atomically renamed to <dir>/step_<n>/ (crash mid-write leaves only
         a .tmp that restore ignores).  An optional background thread makes
         saves asynchronous (training never blocks on disk).  The OCF state
         (table + keystore) checkpoints alongside the model so a restarted
         node resumes with its membership filter intact — the paper's
         "avoid complete rebuild of in-memory structures on flush" goal,
         applied to restarts.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.core.ocf import OCF


def _flatten(tree) -> dict[str, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(k): v for k, v in flat}, treedef


def save(ckpt_dir: str, step: int, tree, *, ocf: Optional[OCF] = None,
         extra: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = _flatten(tree)
    names = {}
    for i, (k, v) in enumerate(sorted(flat.items())):
        fn = f"leaf_{i:05d}.npy"
        arr = np.asarray(v)
        dtype_name = str(arr.dtype)
        if arr.dtype.kind == "V" or dtype_name == "bfloat16":
            # ml_dtypes (bfloat16, fp8) do not survive .npy — store the raw
            # bits as uint16/uint8 and record the logical dtype.
            import ml_dtypes  # noqa: F401 — registered via jax
            width = arr.dtype.itemsize
            arr = arr.view(np.uint16 if width == 2 else np.uint8)
        np.save(os.path.join(tmp, fn), arr)
        names[k] = {"file": fn, "dtype": dtype_name}
    if ocf is not None:
        np.save(os.path.join(tmp, "ocf_table.npy"), np.asarray(ocf.state.table))
        np.save(os.path.join(tmp, "ocf_keys.npy"), ocf.keystore.materialize())
    manifest = {"step": step, "leaves": names, "extra": extra or {},
                "has_ocf": ocf is not None}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, *,
            shardings=None) -> tuple[Any, dict]:
    """Restore into the structure of ``like_tree``; optional resharding via
    ``shardings`` (a matching tree of NamedSharding) for elastic restarts."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    # leaves must be rebuilt in TREE order (tree_unflatten's contract), while
    # the manifest is keyed by path string — look each one up by key.
    flat_pairs, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = []
    for k, _v in flat_pairs:
        key = jax.tree_util.keystr(k)
        rec = manifest["leaves"].get(key)
        if rec is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        fn, dtype_name = rec["file"], rec["dtype"]
        arr = np.load(os.path.join(path, fn))
        if str(arr.dtype) != dtype_name:
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest


def save_sharded(ckpt_dir: str, step: int, state) -> str:
    """Checkpoint a ``ShardedFilterState`` (durable fault-recovery snapshot).

    Rides the generic leaf writer — tables (and stashes, when present) land
    as .npy, the static ``n_buckets`` in the manifest extra — so the
    atomic-rename/fsync crash discipline applies unchanged.  The sharded
    stacks are gathered to host first (``np.asarray``), which is the point:
    the snapshot must outlive the mesh it was taken on (a restore may land
    on a replacement shard, or a differently-sized mesh after an elastic
    resize).
    """
    tree = {"tables": np.asarray(state.tables)}
    if state.stashes is not None:
        tree["stashes"] = np.asarray(state.stashes)
    extra = {"sharded_filter": {"n_buckets": state.n_buckets,
                                "has_stashes": state.stashes is not None}}
    return save(ckpt_dir, step, tree, extra=extra)


def restore_sharded(ckpt_dir: str, step: Optional[int] = None):
    """Restore a ``ShardedFilterState`` saved by ``save_sharded``.

    ``step=None`` restores the latest durable snapshot.  Returns host-backed
    (uncommitted) arrays, so the caller can drop the state onto whatever
    mesh survives the fault.
    """
    from repro.core.distributed import ShardedFilterState
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    meta = manifest["extra"]["sharded_filter"]
    like = {"tables": 0}
    if meta["has_stashes"]:
        like["stashes"] = 0
    tree, _ = restore(ckpt_dir, step, like)
    return ShardedFilterState(
        tables=np.asarray(tree["tables"]),
        stashes=(np.asarray(tree["stashes"]) if meta["has_stashes"]
                 else None),
        n_buckets=meta["n_buckets"])


def restore_ocf(ckpt_dir: str, step: int, ocf: OCF) -> OCF:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    keys = np.load(os.path.join(path, "ocf_keys.npy"))
    ocf.keystore.clear()
    if keys.size:
        ocf.insert(keys)
    return ocf


class AsyncCheckpointer:
    """Fire-and-forget saves on a worker thread; join() before exit."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree, **kw):
        self.join()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async

        def work():
            save(self.ckpt_dir, step, host_tree, **kw)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def join(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
