"""Deterministic closed-loop workload generators for the SLO harness.

The paper's title promises burst tolerance, low latency, and high
throughput; measuring any of the three needs *scenarios*, not one random
batch.  Each generator here turns a single ``np.random.Generator`` into a
deterministic stream of ``OpBatch`` waves — the unit of work the serving
submit path (``serving.scheduler.FilterOpBatcher``) dispatches to the
device.  Determinism is a hard requirement twice over: the bench gate
compares percentile rows across commits (same seed => same key stream =>
comparable tails), and the async double-buffered submit path is parity-
tested bit-for-bit against the synchronous one (same stream in, same
results out).

Every generator takes the rng as its first argument and derives *all*
randomness from it — no module-level state, no ``np.random.*`` globals —
so ``scenario_stream(name, seed)`` is byte-reproducible
(``tests/test_slo.py::test_scenario_streams_are_deterministic``).

Scenario catalog (docs/ARCHITECTURE.md has the prose version):

  * ``uniform``      — uniform key mix, ~50% hit-rate lookups + fresh
                       inserts; the baseline tail.
  * ``zipfian``      — rank-zipf lookups over a shuffled universe; hot
                       keys repeat within a wave, so the dedup pre-pass
                       (``core.scheduling.dedupe_keys``) carries the load.
  * ``adversarial``  — a fixed non-member pool replayed round after round
                       with ``feedback=True``: the harness reports every
                       hit back through ``report_false_positive`` (the
                       Adaptive Cuckoo Filters closed loop).
  * ``burst_train``  — insert bursts separated by lookup gaps, each burst
                       cleared by delete waves: the hysteresis-admission
                       story, and the arm the sync-vs-async bench row runs.
  * ``ttl_churn``    — TTL-aged churn against the generational ring:
                       every wave advances the logical clock, inserts are
                       fresh, lookups chase a sliding recency window.
  * ``delete_heavy`` — one delete wave per insert wave at steady state;
                       the delete kernel's tail, not just its throughput.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["OpBatch", "SCENARIOS", "scenario_stream"]

# Key 0 is reserved for padding lanes (``FilterOpBatcher`` pads waves to a
# fixed shape with key 0 + valid=False); generators never emit it.
_KEY_LOW, _KEY_HIGH = 1, np.uint64(2**63)


@dataclasses.dataclass(frozen=True)
class OpBatch:
    """One wave of homogeneous filter ops.

    ``kind``     — "lookup" | "insert" | "delete" | "report".
    ``keys``     — uint64[N], N <= the batcher's wave_slots.
    ``burst``    — wave belongs to a burst train (tagged in the recorder so
                   in-burst and gap tails can be split).
    ``advance``  — logical-clock delta applied BEFORE the wave (TTL
                   scenarios; 0.0 everywhere else).
    ``feedback`` — lookup wave whose hits the harness must report back as
                   confirmed false positives (closed-loop adversarial mix).
    """
    kind: str
    keys: np.ndarray
    burst: bool = False
    advance: float = 0.0
    feedback: bool = False


def _fresh(rng: np.random.Generator, n: int) -> np.ndarray:
    return rng.integers(_KEY_LOW, _KEY_HIGH, size=n, dtype=np.uint64)


def _mix(rng: np.random.Generator, pools: list[np.ndarray],
         counts: list[int]) -> np.ndarray:
    """Concatenate ``counts[i]`` draws (with replacement) from each pool,
    shuffled together — a lookup wave with a controlled hit/miss blend."""
    parts = [rng.choice(p, size=c, replace=True) for p, c in zip(pools,
                                                                 counts)]
    keys = np.concatenate(parts)
    rng.shuffle(keys)
    return keys


# ------------------------------------------------------------ scenarios --


def uniform(rng: np.random.Generator, *, wave_slots: int = 512,
            waves: int = 48, write_frac: float = 0.25) -> list[OpBatch]:
    """Uniform mix: prefill a member set, then lookups (~50% hits) with a
    ``write_frac`` fraction of fresh-key insert waves."""
    members = _fresh(rng, 4 * wave_slots)
    stream = [OpBatch("insert", members[i:i + wave_slots])
              for i in range(0, members.size, wave_slots)]
    for _ in range(waves):
        if rng.random() < write_frac:
            stream.append(OpBatch("insert", _fresh(rng, wave_slots)))
        else:
            half = wave_slots // 2
            stream.append(OpBatch("lookup", _mix(
                rng, [members, _fresh(rng, half)], [wave_slots - half,
                                                    half])))
    return stream


def zipfian(rng: np.random.Generator, *, wave_slots: int = 512,
            waves: int = 48, a: float = 1.2,
            write_frac: float = 0.2) -> list[OpBatch]:
    """Rank-zipf lookups over a shuffled member universe: in-wave repeats
    of hot keys are the norm, which is exactly what the lookup dedup
    pre-pass collapses."""
    universe = _fresh(rng, 8 * wave_slots)
    stream = [OpBatch("insert", universe[i:i + wave_slots])
              for i in range(0, universe.size, wave_slots)]
    for _ in range(waves):
        if rng.random() < write_frac:
            stream.append(OpBatch("insert", _fresh(rng, wave_slots)))
        else:
            ranks = (rng.zipf(a, size=wave_slots) - 1) % universe.size
            stream.append(OpBatch("lookup", universe[ranks]))
    return stream


def adversarial(rng: np.random.Generator, *, wave_slots: int = 512,
                rounds: int = 4, pool_waves: int = 2) -> list[OpBatch]:
    """Adaptive-filter stressor: one fixed non-member pool replayed every
    round with ``feedback=True`` — each round's surviving false positives
    are reported back, so by construction the FP set should shrink round
    over round (PR 7's adversarial bench, now with latency attached)."""
    members = _fresh(rng, 4 * wave_slots)
    pool = _fresh(rng, pool_waves * wave_slots)
    stream = [OpBatch("insert", members[i:i + wave_slots])
              for i in range(0, members.size, wave_slots)]
    for _ in range(rounds):
        for i in range(0, pool.size, wave_slots):
            stream.append(OpBatch("lookup", pool[i:i + wave_slots],
                                  feedback=True))
    return stream


def burst_train(rng: np.random.Generator, *, wave_slots: int = 512,
                bursts: int = 6, burst_waves: int = 4,
                gap_waves: int = 6) -> list[OpBatch]:
    """Insert bursts separated by lookup gaps, each burst deleted at the
    end of its gap — occupancy breathes up and down, the admission
    controller's hysteresis band gets crossed in both directions, and the
    sync-vs-async submit comparison runs on exactly this stream."""
    base = _fresh(rng, 2 * wave_slots)
    stream = [OpBatch("insert", base[i:i + wave_slots])
              for i in range(0, base.size, wave_slots)]
    for _ in range(bursts):
        burst_keys = []
        for _ in range(burst_waves):
            k = _fresh(rng, wave_slots)
            burst_keys.append(k)
            stream.append(OpBatch("insert", k, burst=True))
        transient = np.concatenate(burst_keys)
        half = wave_slots // 2
        for _ in range(gap_waves):
            stream.append(OpBatch("lookup", _mix(
                rng, [base, transient], [half, wave_slots - half])))
        for k in burst_keys:
            stream.append(OpBatch("delete", k))
    return stream


def ttl_churn(rng: np.random.Generator, *, wave_slots: int = 512,
              waves: int = 36, dt: float = 1.0,
              window: int = 4) -> list[OpBatch]:
    """Generational-ring churn: every wave advances the logical clock by
    ``dt``; inserts are always-fresh keys, lookups chase the last
    ``window`` insert waves (older keys age out of the ring and miss)."""
    recent: list[np.ndarray] = []
    stream: list[OpBatch] = []
    for w in range(waves):
        if w % 2 == 0:
            k = _fresh(rng, wave_slots)
            recent.append(k)
            recent[:] = recent[-window:]
            stream.append(OpBatch("insert", k, advance=dt))
        else:
            pool = np.concatenate(recent)
            stream.append(OpBatch(
                "lookup", rng.choice(pool, size=wave_slots, replace=True),
                advance=dt))
    return stream


def delete_heavy(rng: np.random.Generator, *, wave_slots: int = 512,
                 waves: int = 36) -> list[OpBatch]:
    """Steady-state churn with one delete wave per insert wave: the
    generator tracks residency host-side (pure python, still
    deterministic), so every delete wave targets keys that are actually
    resident."""
    resident = [_fresh(rng, wave_slots) for _ in range(4)]
    stream = [OpBatch("insert", k) for k in resident]
    for w in range(waves):
        r = w % 3
        if r == 0:
            k = _fresh(rng, wave_slots)
            resident.append(k)
            stream.append(OpBatch("insert", k))
        elif r == 1:
            victim = resident.pop(int(rng.integers(len(resident))))
            stream.append(OpBatch("delete", victim))
        else:
            pool = np.concatenate(resident)
            half = wave_slots // 2
            stream.append(OpBatch("lookup", _mix(
                rng, [pool, _fresh(rng, half)], [wave_slots - half, half])))
    return stream


SCENARIOS = {
    "uniform": uniform,
    "zipfian": zipfian,
    "adversarial": adversarial,
    "burst_train": burst_train,
    "ttl_churn": ttl_churn,
    "delete_heavy": delete_heavy,
}


def scenario_stream(name: str, seed: int = 0, **kwargs) -> list[OpBatch]:
    """Materialize scenario ``name`` from one seeded ``np.random.Generator``.

    The ONLY rng entry point for the SLO suite: the bench CLI's ``--seed``
    flag lands here, and everything downstream (stream, filter state,
    percentiles given a fixed backend) is a pure function of it.
    """
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r} "
                       f"(have {sorted(SCENARIOS)})")
    return SCENARIOS[name](np.random.default_rng(seed), **kwargs)
