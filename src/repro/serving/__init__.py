from repro.serving.engine import (generate, greedy_sample, make_decode_step,
                                  make_prefill_step)
from repro.serving.kvcache import PrefixCacheIndex, block_hashes
from repro.serving.scheduler import (ContinuousBatcher, DeferredWritePump,
                                     FilterOpBatcher, OpWave, Request)
from repro.serving.slo import LatencyRecorder, SloHarness, SloReport
from repro.serving.workloads import OpBatch, SCENARIOS, scenario_stream
