from repro.serving.engine import (generate, greedy_sample, make_decode_step,
                                  make_prefill_step)
from repro.serving.kvcache import PrefixCacheIndex, block_hashes
