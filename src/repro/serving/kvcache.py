"""Paged-prefix KV-cache index backed by the OCF (paper integration #2).

Token streams are chunked into fixed-size blocks; each block's rolling
content hash is a key in an OCF.  The index answers "is this prefix block
cached somewhere in the cluster?" in O(1) filter probes *before* any page
table is consulted, supports true deletes on eviction (the cuckoo advantage
over bloom — Cassandra's filters cannot do this), and burst arrivals drive
the EOF resize controller instead of forcing a flush/rebuild.

With ``backend="pallas"`` the whole index lifecycle is device-kernel-fused
through ``FilterOps``: probes hit the fused lookup kernel, admissions the
insert kernel (eviction residue resolved on-device), and LRU/sequence
evictions the first-match-slot delete kernel — the serving path never waits
on a sequential ``lax.scan``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.hashing import murmur3_mix_np, splitmix32_np
from repro.core.ocf import OCF, OcfConfig
from repro.streaming.generations import GenerationConfig, GenerationalFilter


def block_hashes(tokens: np.ndarray, block: int = 64) -> np.ndarray:
    """Rolling prefix hashes, one uint64 key per complete block.

    Hash of block i commits to ALL tokens in blocks 0..i (prefix semantics:
    a block is reusable only if the entire prefix matches).
    """
    tokens = np.asarray(tokens, dtype=np.uint32)
    n = tokens.size // block
    keys = np.zeros(n, dtype=np.uint64)
    h_hi = np.uint32(0x9E3779B9)
    h_lo = np.uint32(0x85EBCA6B)
    with np.errstate(over="ignore"):  # uint32 wraparound is the hash mix
        for i in range(n):
            blk = tokens[i * block:(i + 1) * block]
            for off in range(0, block, 4):  # mix 4 tokens per round
                h_lo = murmur3_mix_np(h_lo ^ splitmix32_np(
                    np.bitwise_xor.reduce(blk[off:off + 4])))
                h_hi = splitmix32_np(h_hi + h_lo)
            keys[i] = (np.uint64(h_hi) << np.uint64(32)) | np.uint64(h_lo)
    return keys


@dataclasses.dataclass
class PrefixStats:
    queries: int = 0
    block_hits: int = 0
    block_misses: int = 0
    admitted: int = 0
    evicted: int = 0


class PrefixCacheIndex:
    """OCF-backed membership index over cached KV prefix blocks.

    ``backend`` (optional) overrides the filter data-plane backend of the
    underlying OCF ("jnp" | "pallas" | "auto") without callers having to
    build an ``OcfConfig`` — the serving layer inherits the same
    ``FilterOps`` dispatch as every other consumer.
    """

    def __init__(self, config: Optional[OcfConfig] = None, block: int = 64,
                 max_blocks: int = 1 << 16, backend: Optional[str] = None):
        self.block = block
        self.max_blocks = max_blocks
        config = config or OcfConfig(capacity=4096, mode="EOF")
        if backend is not None:
            config = dataclasses.replace(config, backend=backend)
        self.ocf = OCF(config)
        self.stats = PrefixStats()
        self._lru: list[int] = []   # admitted block keys, oldest first

    def match_prefix(self, tokens: np.ndarray) -> int:
        """Longest cached prefix in *tokens*, in complete blocks."""
        keys = block_hashes(tokens, self.block)
        self.stats.queries += 1
        if keys.size == 0:
            return 0
        hits = self.ocf.lookup(keys)
        n = 0
        while n < len(hits) and hits[n]:
            n += 1
        self.stats.block_hits += n
        self.stats.block_misses += len(hits) - n
        return n

    def admit(self, tokens: np.ndarray) -> int:
        """Insert all blocks of a finished prefill; evict LRU on pressure."""
        keys = block_hashes(tokens, self.block)
        if keys.size == 0:
            return 0
        new = keys[~self.ocf.lookup(keys)]
        if new.size:
            self.ocf.insert(new)
            self._lru.extend(int(k) for k in new)
            self.stats.admitted += new.size
        while len(self._lru) > self.max_blocks:
            victim = self._lru.pop(0)
            self.ocf.delete(np.array([victim], dtype=np.uint64))
            self.stats.evicted += 1
        return int(new.size)

    def evict(self, tokens: np.ndarray) -> int:
        """Verified delete of a sequence's blocks (paper's safe-delete)."""
        keys = block_hashes(tokens, self.block)
        ok = self.ocf.delete(keys)
        lru_set = set(int(k) for k in keys[ok])
        self._lru = [k for k in self._lru if k not in lru_set]
        self.stats.evicted += int(ok.sum())
        return int(ok.sum())

    @property
    def hit_rate(self) -> float:
        tot = self.stats.block_hits + self.stats.block_misses
        return self.stats.block_hits / tot if tot else 0.0


class GenerationalPrefixIndex:
    """Prefix-cache index over TTL-aged filter generations (streaming).

    Same duck API as ``PrefixCacheIndex`` (``match_prefix`` / ``admit`` /
    ``evict`` / ``hit_rate``) but backed by ``repro.streaming``'s
    ``GenerationalFilter`` instead of a single OCF: admitted prefix blocks
    land in the active generation, lookups probe every live generation plus
    the overflow stashes in one fused device call, and **freshness replaces
    the LRU delete loop** — stale blocks age out when their generation's
    TTL expires or the ring rotates past them, an O(1) retirement instead
    of per-key deletes.  ``evict`` is therefore a no-op (sequence eviction
    is generation retirement), and the page-table layer must treat the
    index as advisory — exactly the filter contract (false positives
    possible, false negatives never, within the freshness window).
    """

    def __init__(self, block: int = 64, *,
                 config: Optional[GenerationConfig] = None,
                 backend: Optional[str] = None, ttl: Optional[float] = None,
                 generations: int = 4, capacity: int = 4096,
                 now: Optional[float] = None):
        """``now`` is the stream epoch — pass it (and every later ``now``)
        when driving TTLs on a logical clock; omit all of them for wall
        time (one clock domain, like ``GenerationalFilter``)."""
        self.block = block
        if config is None:
            config = GenerationConfig(
                generations=generations, capacity=capacity, ttl=ttl,
                backend=backend if backend is not None else "auto")
        self.filt = GenerationalFilter(config, now=now)
        self.stats = PrefixStats()

    def match_prefix(self, tokens: np.ndarray,
                     now: Optional[float] = None) -> int:
        """Longest cached prefix in *tokens*, in complete blocks.

        Matched blocks resident only in an *aging* generation are promoted
        (re-inserted into the active one) — the multi-level promote-on-read
        step, without which a continuously-hot prefix would still age out
        after K rotations and force a periodic full recompute.
        """
        keys = block_hashes(tokens, self.block)
        self.stats.queries += 1
        if keys.size == 0:
            return 0
        hits = self.filt.lookup(keys, now=now)
        n = 0
        while n < len(hits) and hits[n]:
            n += 1
        if n:
            hot = keys[:n]
            in_active = self.filt.lookup_active(hot, now=now)
            if not in_active.all():
                self.filt.insert(hot[~in_active], now=now)
        self.stats.block_hits += n
        self.stats.block_misses += len(hits) - n
        return n

    def admit(self, tokens: np.ndarray, now: Optional[float] = None) -> int:
        """Insert all blocks of a finished prefill into the active gen."""
        keys = block_hashes(tokens, self.block)
        if keys.size == 0:
            return 0
        new = keys[~self.filt.lookup(keys, now=now)]
        if new.size:
            self.filt.insert(new, now=now)
            self.stats.admitted += new.size
        return int(new.size)

    def evict(self, tokens: np.ndarray) -> int:
        """No-op: generational aging (TTL/rotation) replaces per-key
        eviction — see the class docstring."""
        del tokens
        return 0

    @property
    def hit_rate(self) -> float:
        tot = self.stats.block_hits + self.stats.block_misses
        return self.stats.block_hits / tot if tot else 0.0
