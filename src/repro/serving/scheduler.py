"""Continuous-batching request scheduler with OCF admission control.

The serving-side embodiment of the paper's burst story: requests arrive in
bursts; the scheduler packs them into a fixed decode batch (slots), uses the
OCF prefix index to skip recomputing shared prefixes, and its admission
queue depth is a live congestion signal — the same quantity the EOF
controller integrates.  Host-side control plane; the device work is the
jitted prefill/decode steps from ``engine.py``.

Semantics follow vLLM-style continuous batching, reduced to what a dry-run
framework needs: slot lifecycle (admit → prefill → decode* → finish/evict),
prefix reuse accounting, and backpressure statistics.

Backpressure has two layers since the streaming subsystem landed:
queue depth (always on), and — when an ``AdmissionController``
(``repro.streaming.admission``) is attached — the filter-side congestion
signal (overflow-stash fill + generation fill).  A tripped controller
defers new requests into a side queue that drains once the signal drops
below the hysteresis low-water mark, so a membership-layer burst sheds
load *before* it turns into decode-slot starvation.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import greedy_sample, make_decode_step, \
    make_prefill_step
from repro.serving.kvcache import PrefixCacheIndex


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    prefix_hit_blocks: int = 0


@dataclasses.dataclass
class SchedStats:
    admitted: int = 0
    finished: int = 0
    decode_steps: int = 0
    prefills: int = 0
    peak_queue: int = 0
    prefix_blocks_reused: int = 0
    wasted_slot_steps: int = 0    # decode steps with idle slots (burst gaps)
    deferred: int = 0             # requests parked by admission control


class ContinuousBatcher:
    """Fixed-slot continuous batcher over a per-slot KV cache.

    One cache per slot keeps the dry-run simple (a paged allocator would
    share pages across slots; the OCF index is the membership layer either
    way).  ``step()`` runs one scheduler tick: fill free slots from the
    queue (prefill), then one fused decode step over the occupied slots.
    """

    def __init__(self, model, params, *, slots: int = 4, cache_len: int = 512,
                 block: int = 32, dtype=jnp.float32,
                 sample_fn: Optional[Callable] = None, index=None,
                 admission=None):
        """``index``: any PrefixCacheIndex-duck (e.g. the streaming
        ``GenerationalPrefixIndex``); defaults to the OCF-backed one.
        ``admission``: optional ``streaming.AdmissionController`` — when its
        congestion signal trips, ``submit`` parks requests in ``deferred``
        until the signal recedes."""
        self.model = model
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.index = index if index is not None else PrefixCacheIndex(
            block=block)
        self.admission = admission
        self.queue: deque[Request] = deque()
        self.deferred: deque[Request] = deque()
        self.active: dict[int, Request] = {}
        self.pos = np.zeros(slots, dtype=np.int64)
        self.caches = [None] * slots
        self.stats = SchedStats()
        self._prefill = jax.jit(make_prefill_step(model))
        self._decode = jax.jit(make_decode_step(model))
        self._dtype = dtype
        self._sample = sample_fn or greedy_sample
        self._last_tok = [None] * slots

    # ------------------------------------------------------------ intake --

    def submit(self, req: Request) -> bool:
        """Queue a request; returns False when admission control deferred
        it (it stays in ``deferred`` and re-enters on a later tick)."""
        if self.admission is not None and not self.admission.admit():
            self.deferred.append(req)
            self.stats.deferred += 1
            return False
        self.queue.append(req)
        self.stats.admitted += 1
        self.stats.peak_queue = max(self.stats.peak_queue, len(self.queue))
        return True

    def _drain_deferred(self):
        """Re-admit parked requests while the congestion signal allows.

        Uses the controller's side-effect-free ``peek`` so per-tick polling
        does not inflate its per-request counters.  If the batcher is fully
        starved (everything deferred, nothing queued or decoding), the
        congestion signal can never recede on its own — nothing mutates the
        filter — so age it: reclaim TTL-expired generations, else rotate
        (the same early-rotate policy the filter applies under insert
        pressure); the next tick re-checks.
        """
        # One peek gates the whole drain: nothing in this loop mutates the
        # filter, so the congestion signal (a device read) cannot change
        # between iterations — don't pay one transfer per request.
        if self.deferred and self.admission.peek():
            while self.deferred:
                self.queue.append(self.deferred.popleft())
                self.stats.admitted += 1
                self.stats.peak_queue = max(self.stats.peak_queue,
                                            len(self.queue))
        if self.deferred and not self.queue and not self.active:
            filt = self.admission.filt
            if not filt.advance():
                filt.rotate()

    @property
    def congestion(self) -> float:
        """Queue pressure (+ filter congestion when admission is wired):
        the EOF-style signal, in [0, inf)."""
        q = (len(self.queue) + len(self.deferred)) / max(1, self.slots)
        if self.admission is not None:
            q += self.admission.signal()
        return q

    # ------------------------------------------------------------- tick ---

    def _admit_one(self, slot: int, req: Request):
        hit = self.index.match_prefix(req.prompt)
        req.prefix_hit_blocks = hit
        self.stats.prefix_blocks_reused += hit
        cache = self.model.init_cache(1, self.cache_len, dtype=self._dtype)
        logits, cache = self._prefill(self.params, cache,
                                      jnp.asarray(req.prompt)[None, :])
        self.caches[slot] = cache
        self.pos[slot] = req.prompt.size
        self._last_tok[slot] = self._sample(logits)
        req.out.append(int(self._last_tok[slot][0, 0]))
        self.active[slot] = req
        self.stats.prefills += 1

    def step(self) -> int:
        """One scheduler tick; returns number of live requests decoded."""
        if self.admission is not None and self.deferred:
            self._drain_deferred()
        for slot in range(self.slots):
            if slot not in self.active and self.queue:
                self._admit_one(slot, self.queue.popleft())
        live = 0
        for slot, req in list(self.active.items()):
            logits, cache = self._decode(self.params, self.caches[slot],
                                         self._last_tok[slot],
                                         jnp.int32(int(self.pos[slot])))
            self.caches[slot] = cache
            self.pos[slot] += 1
            tok = self._sample(logits)
            self._last_tok[slot] = tok
            req.out.append(int(tok[0, 0]))
            live += 1
            if len(req.out) >= req.max_new:
                self.index.admit(req.prompt)     # publish prefix blocks
                del self.active[slot]
                self.caches[slot] = None
                self.stats.finished += 1
        self.stats.decode_steps += 1
        self.stats.wasted_slot_steps += self.slots - live
        return live

    def run_until_drained(self, max_ticks: int = 10_000) -> SchedStats:
        ticks = 0
        while ((self.queue or self.active or self.deferred)
               and ticks < max_ticks):
            self.step()
            ticks += 1
        return self.stats


# ------------------------------------------------ deferred write pump ----
#
# The routed distributed writes (``core.distributed.distributed_insert``)
# return a **deferred batch**: lanes that exceeded their owner shard's
# all_to_all capacity and were never attempted.  PR 6 left resubmission to
# the caller; the pump below closes the loop with the SAME hysteresis
# controller the request path uses — deferred keys are a write-side
# admission queue, and resubmitting them while the shards are congested
# just re-defers them (or worse, lands them in saturated stashes).


class ShardedFilterFills:
    """``GenerationalFilter.fills()``-shaped duck over a ShardedFilterState.

    ``AdmissionController`` reads congestion as (generation fill, stash
    fill); for a sharded state the analogous device scalars are aggregate
    table occupancy and aggregate stash occupancy.  Takes a zero-arg getter
    (not a state) because the pump replaces its state every write — the
    controller must always read the CURRENT one.
    """

    def __init__(self, get_state: Callable):
        self._get = get_state

    def fills(self) -> tuple[float, float]:
        state = self._get()
        fill = float(jnp.mean(state.tables != 0))
        stash_fill = (float(jnp.mean(state.stashes[:, 0, :] != 0))
                      if state.stashes is not None else 0.0)
        return fill, stash_fill


@dataclasses.dataclass
class PumpStats:
    submitted: int = 0      # lanes offered via submit()
    inserted: int = 0       # lanes resident after their (re)attempt
    deferred: int = 0       # lane-deferrals observed (a lane can repeat)
    resubmitted: int = 0    # lanes re-offered by pump()
    held_ticks: int = 0     # pump ticks the hysteresis gate held the queue
    failed: int = 0         # genuine insert failures (chain + stash full)


class DeferredWritePump:
    """Hysteresis-controlled resubmission of routed-write deferred batches.

    Wraps ``distributed_insert`` on a fixed (mesh, axis, sharded state):
    ``submit`` runs the routed insert and parks the returned deferred batch
    host-side; ``pump`` re-offers parked keys only while the admission
    controller's congestion signal allows (trip at ``high_water``, resume
    at ``low_water`` — the identical hysteresis the request scheduler
    applies to decode admission, pointed at the write path).  Parked
    batches are padded to the sharded batch shape with ``valid=False``
    lanes, so resubmission never fabricates sentinel inserts.
    """

    def __init__(self, mesh, axis: str, state, *, fp_bits: int,
                 admission=None, capacity_factor: float = 2.0,
                 backend: str = "auto", donate: bool = True):
        from repro.core.distributed import distributed_insert
        from repro.streaming.admission import AdmissionController
        self.mesh, self.axis = mesh, axis
        self.state = state
        self.fp_bits = fp_bits
        self.capacity_factor = capacity_factor
        self.backend = backend
        self.donate = donate
        self._insert = distributed_insert
        self.admission = admission or AdmissionController(
            filt=ShardedFilterFills(lambda: self.state))
        self.n_shards = mesh.shape[axis]
        self._pend_hi = np.empty((0,), np.uint32)
        self._pend_lo = np.empty((0,), np.uint32)
        self.stats = PumpStats()

    @property
    def pending(self) -> int:
        return int(self._pend_hi.size)

    def _attempt(self, hi: np.ndarray, lo: np.ndarray):
        """One routed insert over a host batch, padded to the shard shape."""
        pad = (-hi.size) % self.n_shards
        valid = np.ones(hi.size + pad, bool)
        if pad:
            hi = np.concatenate([hi, np.zeros(pad, np.uint32)])
            lo = np.concatenate([lo, np.zeros(pad, np.uint32)])
            valid[-pad:] = False
        self.state, ok, deferred, _ov = self._insert(
            self.mesh, self.axis, self.state, jnp.asarray(hi),
            jnp.asarray(lo), fp_bits=self.fp_bits,
            capacity_factor=self.capacity_factor, backend=self.backend,
            donate=self.donate, valid=jnp.asarray(valid))
        ok, deferred = np.asarray(ok), np.asarray(deferred)
        self._pend_hi = np.concatenate([self._pend_hi, hi[deferred]])
        self._pend_lo = np.concatenate([self._pend_lo, lo[deferred]])
        self.stats.inserted += int(ok.sum())
        self.stats.deferred += int(deferred.sum())
        self.stats.failed += int((valid & ~ok & ~deferred).sum())
        return ok, deferred

    def submit(self, hi, lo):
        """Routed insert of a fresh batch -> (ok[N], deferred[N]).

        Deferred lanes are parked for ``pump``; the batch must divide the
        shard count (the ``distributed_insert`` contract for fresh traffic).
        """
        hi = np.asarray(hi, np.uint32)
        lo = np.asarray(lo, np.uint32)
        self.stats.submitted += int(hi.size)
        return self._attempt(hi, lo)

    def pump(self) -> int:
        """One resubmission tick -> lanes re-attempted (0 while held).

        Gated by the side-effect-free ``peek`` so polling does not inflate
        the controller's per-request counters; a tripped gate holds the
        parked batch untouched (``held_ticks``) until the congestion signal
        recedes past low_water.
        """
        if not self.pending:
            return 0
        if not self.admission.peek():
            self.stats.held_ticks += 1
            return 0
        hi, lo = self._pend_hi, self._pend_lo
        self._pend_hi = np.empty((0,), np.uint32)
        self._pend_lo = np.empty((0,), np.uint32)
        self.stats.resubmitted += int(hi.size)
        self._attempt(hi, lo)
        return int(hi.size)

    def run_until_drained(self, *, max_ticks: int = 100,
                          on_held=None) -> PumpStats:
        """Pump until nothing is parked (or ``max_ticks``).

        ``on_held``: optional callback invoked on each held tick — the hook
        where a control plane relieves congestion (rotate a generation,
        grow the shards, age the stash); without one a tripped gate over a
        static filter would hold forever, so the loop stops early when
        holding makes no progress and nothing external intervenes.
        """
        for _ in range(max_ticks):
            if not self.pending:
                break
            if self.pump() == 0 and on_held is None:
                break
            if on_held is not None and self.admission.tripped:
                on_held(self)
        return self.stats
