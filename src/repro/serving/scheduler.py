"""Continuous-batching request scheduler with OCF admission control.

The serving-side embodiment of the paper's burst story: requests arrive in
bursts; the scheduler packs them into a fixed decode batch (slots), uses the
OCF prefix index to skip recomputing shared prefixes, and its admission
queue depth is a live congestion signal — the same quantity the EOF
controller integrates.  Host-side control plane; the device work is the
jitted prefill/decode steps from ``engine.py``.

Semantics follow vLLM-style continuous batching, reduced to what a dry-run
framework needs: slot lifecycle (admit → prefill → decode* → finish/evict),
prefix reuse accounting, and backpressure statistics.

Backpressure has two layers since the streaming subsystem landed:
queue depth (always on), and — when an ``AdmissionController``
(``repro.streaming.admission``) is attached — the filter-side congestion
signal (overflow-stash fill + generation fill).  A tripped controller
defers new requests into a side queue that drains once the signal drops
below the hysteresis low-water mark, so a membership-layer burst sheds
load *before* it turns into decode-slot starvation.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import time
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import filter as jfilter
from repro.core import hashing
from repro.core.scheduling import dedupe_keys
from repro.kernels import ops as kops
from repro.kernels.telemetry import KICK_EDGES
from repro.serving.engine import greedy_sample, make_decode_step, \
    make_prefill_step
from repro.serving.kvcache import PrefixCacheIndex


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    prefix_hit_blocks: int = 0


@dataclasses.dataclass
class SchedStats:
    admitted: int = 0
    finished: int = 0
    decode_steps: int = 0
    prefills: int = 0
    peak_queue: int = 0
    prefix_blocks_reused: int = 0
    wasted_slot_steps: int = 0    # decode steps with idle slots (burst gaps)
    deferred: int = 0             # requests parked by admission control
    shed_requests: int = 0        # requests dropped by backpressure policy


class ContinuousBatcher:
    """Fixed-slot continuous batcher over a per-slot KV cache.

    One cache per slot keeps the dry-run simple (a paged allocator would
    share pages across slots; the OCF index is the membership layer either
    way).  ``step()`` runs one scheduler tick: fill free slots from the
    queue (prefill), then one fused decode step over the occupied slots.
    """

    def __init__(self, model, params, *, slots: int = 4, cache_len: int = 512,
                 block: int = 32, dtype=jnp.float32,
                 sample_fn: Optional[Callable] = None, index=None,
                 admission=None, backpressure=None):
        """``index``: any PrefixCacheIndex-duck (e.g. the streaming
        ``GenerationalPrefixIndex``); defaults to the OCF-backed one.
        ``admission``: optional ``streaming.AdmissionController`` — when its
        congestion signal trips, ``submit`` parks requests in ``deferred``
        until the signal recedes.  ``backpressure``: optional
        ``engine.BackpressureController`` — a registry-fed admit/defer/shed
        policy consulted BEFORE the filter-side gate; ``shed`` drops the
        request outright (counted in ``stats.shed_requests``)."""
        self.model = model
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.index = index if index is not None else PrefixCacheIndex(
            block=block)
        self.admission = admission
        self.backpressure = backpressure
        self.queue: deque[Request] = deque()
        self.deferred: deque[Request] = deque()
        self.active: dict[int, Request] = {}
        self.pos = np.zeros(slots, dtype=np.int64)
        self.caches = [None] * slots
        self.stats = SchedStats()
        self._prefill = jax.jit(make_prefill_step(model))
        self._decode = jax.jit(make_decode_step(model))
        self._dtype = dtype
        self._sample = sample_fn or greedy_sample
        self._last_tok = [None] * slots

    # ------------------------------------------------------------ intake --

    def submit(self, req: Request) -> bool:
        """Queue a request; returns False when admission control deferred
        it (it stays in ``deferred`` and re-enters on a later tick) or the
        backpressure policy shed it (dropped — the caller must retry)."""
        if self.backpressure is not None:
            decision = self.backpressure.decide()
            if decision == "shed":
                self.stats.shed_requests += 1
                return False
            if decision == "defer":
                self.deferred.append(req)
                self.stats.deferred += 1
                return False
        if self.admission is not None and not self.admission.admit():
            self.deferred.append(req)
            self.stats.deferred += 1
            return False
        self.queue.append(req)
        self.stats.admitted += 1
        self.stats.peak_queue = max(self.stats.peak_queue, len(self.queue))
        return True

    def _drain_deferred(self):
        """Re-admit parked requests while the congestion signal allows.

        Uses the controller's side-effect-free ``peek`` so per-tick polling
        does not inflate its per-request counters.  If the batcher is fully
        starved (everything deferred, nothing queued or decoding), the
        congestion signal can never recede on its own — nothing mutates the
        filter — so age it: reclaim TTL-expired generations, else rotate
        (the same early-rotate policy the filter applies under insert
        pressure); the next tick re-checks.
        """
        # One peek gates the whole drain: nothing in this loop mutates the
        # filter, so the congestion signal (a device read) cannot change
        # between iterations — don't pay one transfer per request.
        if self.deferred and self.admission.peek():
            while self.deferred:
                self.queue.append(self.deferred.popleft())
                self.stats.admitted += 1
                self.stats.peak_queue = max(self.stats.peak_queue,
                                            len(self.queue))
        if self.deferred and not self.queue and not self.active:
            filt = self.admission.filt
            if not filt.advance():
                filt.rotate()

    @property
    def congestion(self) -> float:
        """Queue pressure (+ filter congestion when admission is wired):
        the EOF-style signal, in [0, inf)."""
        q = (len(self.queue) + len(self.deferred)) / max(1, self.slots)
        if self.admission is not None:
            q += self.admission.signal()
        return q

    # ------------------------------------------------------------- tick ---

    def _admit_one(self, slot: int, req: Request):
        hit = self.index.match_prefix(req.prompt)
        req.prefix_hit_blocks = hit
        self.stats.prefix_blocks_reused += hit
        cache = self.model.init_cache(1, self.cache_len, dtype=self._dtype)
        logits, cache = self._prefill(self.params, cache,
                                      jnp.asarray(req.prompt)[None, :])
        self.caches[slot] = cache
        self.pos[slot] = req.prompt.size
        self._last_tok[slot] = self._sample(logits)
        req.out.append(int(self._last_tok[slot][0, 0]))
        self.active[slot] = req
        self.stats.prefills += 1

    def step(self) -> int:
        """One scheduler tick; returns number of live requests decoded."""
        if self.admission is not None and self.deferred:
            self._drain_deferred()
        elif (self.deferred and self.backpressure is not None
                and self.backpressure.decide() == "admit"):
            while self.deferred:
                self.queue.append(self.deferred.popleft())
                self.stats.admitted += 1
                self.stats.peak_queue = max(self.stats.peak_queue,
                                            len(self.queue))
        for slot in range(self.slots):
            if slot not in self.active and self.queue:
                self._admit_one(slot, self.queue.popleft())
        # Dispatch phase: every occupied slot's decode + sample is *queued*
        # on the device with no host sync (jax async dispatch); the per-tick
        # harvest below materializes all sampled tokens in ONE stacked
        # transfer instead of one ``int(tok[0, 0])`` sync per slot — the
        # same dispatch/harvest split the membership submit path
        # (``FilterOpBatcher``) runs at wave granularity.
        live = 0
        ticked: list[tuple[int, Request]] = []
        toks = []
        for slot, req in list(self.active.items()):
            logits, cache = self._decode(self.params, self.caches[slot],
                                         self._last_tok[slot],
                                         jnp.int32(int(self.pos[slot])))
            self.caches[slot] = cache
            self.pos[slot] += 1
            tok = self._sample(logits)
            self._last_tok[slot] = tok
            ticked.append((slot, req))
            toks.append(tok)
            live += 1
        if ticked:
            vals = np.asarray(jnp.concatenate([t[:, 0] for t in toks]))
            for (slot, req), val in zip(ticked, vals):
                req.out.append(int(val))
                if len(req.out) >= req.max_new:
                    self.index.admit(req.prompt)  # publish prefix blocks
                    del self.active[slot]
                    self.caches[slot] = None
                    self.stats.finished += 1
        self.stats.decode_steps += 1
        self.stats.wasted_slot_steps += self.slots - live
        return live

    def run_until_drained(self, max_ticks: int = 10_000) -> SchedStats:
        ticks = 0
        while ((self.queue or self.active or self.deferred)
               and ticks < max_ticks):
            self.step()
            ticks += 1
        return self.stats


# ------------------------------------------------ deferred write pump ----
#
# The routed distributed writes (``core.distributed.distributed_insert``)
# return a **deferred batch**: lanes that exceeded their owner shard's
# all_to_all capacity and were never attempted.  PR 6 left resubmission to
# the caller; the pump below closes the loop with the SAME hysteresis
# controller the request path uses — deferred keys are a write-side
# admission queue, and resubmitting them while the shards are congested
# just re-defers them (or worse, lands them in saturated stashes).


class ShardedFilterFills:
    """``GenerationalFilter.fills()``-shaped duck over a ShardedFilterState.

    ``AdmissionController`` reads congestion as (generation fill, stash
    fill); for a sharded state the analogous device scalars are aggregate
    table occupancy and aggregate stash occupancy.  Takes a zero-arg getter
    (not a state) because the pump replaces its state every write — the
    controller must always read the CURRENT one.
    """

    def __init__(self, get_state: Callable):
        self._get = get_state

    def fills(self) -> tuple[float, float]:
        state = self._get()
        fill = float(jnp.mean(state.tables != 0))
        stash_fill = (float(jnp.mean(state.stashes[:, 0, :] != 0))
                      if state.stashes is not None else 0.0)
        return fill, stash_fill


@dataclasses.dataclass
class PumpStats:
    submitted: int = 0      # lanes offered via submit()
    inserted: int = 0       # lanes resident after their (re)attempt
    deferred: int = 0       # lane-deferrals observed (a lane can repeat)
    resubmitted: int = 0    # lanes re-offered by pump()
    held_ticks: int = 0     # pump ticks the hysteresis gate held the queue
    failed: int = 0         # genuine insert failures (chain + stash full)


class DeferredWritePump:
    """Hysteresis-controlled resubmission of routed-write deferred batches.

    Wraps ``distributed_insert`` on a fixed (mesh, axis, sharded state):
    ``submit`` runs the routed insert and parks the returned deferred batch
    host-side; ``pump`` re-offers parked keys only while the admission
    controller's congestion signal allows (trip at ``high_water``, resume
    at ``low_water`` — the identical hysteresis the request scheduler
    applies to decode admission, pointed at the write path).  Parked
    batches are padded to the sharded batch shape with ``valid=False``
    lanes, so resubmission never fabricates sentinel inserts.
    """

    def __init__(self, mesh, axis: str, state, *, fp_bits: int,
                 admission=None, capacity_factor: float = 2.0,
                 backend: str = "auto", donate: bool = True, metrics=None,
                 tracer=None, route: str = "key"):
        from repro.core.distributed import distributed_insert
        from repro.streaming.admission import AdmissionController
        self.mesh, self.axis = mesh, axis
        self.state = state
        self.fp_bits = fp_bits
        self.capacity_factor = capacity_factor
        self.backend = backend
        self.donate = donate
        self.metrics = metrics
        self.tracer = tracer
        self.route = route
        self._insert = distributed_insert
        self.admission = admission or AdmissionController(
            filt=ShardedFilterFills(lambda: self.state), metrics=metrics)
        self.n_shards = mesh.shape[axis]
        self._pend_hi = np.empty((0,), np.uint32)
        self._pend_lo = np.empty((0,), np.uint32)
        self.stats = PumpStats()
        self.held = False

    @property
    def pending(self) -> int:
        return int(self._pend_hi.size)

    def _span(self, name: str, **args):
        if self.tracer is None:
            return contextlib.nullcontext()
        return self.tracer.span(name, **args)

    # ------------------------------------------ elastic cutover hooks --

    def hold(self):
        """Park ALL traffic (fresh submits included) until ``release``.

        The elastic controller brackets a migration with hold/release: a
        routed insert issued mid-migration would race the all_to_all
        streams (and target the wrong mesh after cutover), so during the
        window every offered lane goes straight to the pending queue.
        """
        self.held = True

    def release(self):
        self.held = False

    def retarget(self, mesh, axis: str, state):
        """Point the pump at a new (mesh, axis, state) — the cutover step.

        The parked backlog survives verbatim (host-side uint32 arrays carry
        no mesh commitment) and drains through the new mesh's routed path
        on the next ``pump``.
        """
        self.mesh, self.axis = mesh, axis
        self.state = state
        self.n_shards = mesh.shape[axis]

    def _attempt(self, hi: np.ndarray, lo: np.ndarray):
        """One routed insert over a host batch, padded to the shard shape."""
        pad = (-hi.size) % self.n_shards
        valid = np.ones(hi.size + pad, bool)
        if pad:
            hi = np.concatenate([hi, np.zeros(pad, np.uint32)])
            lo = np.concatenate([lo, np.zeros(pad, np.uint32)])
            valid[-pad:] = False
        self.state, ok, deferred, ov = self._insert(
            self.mesh, self.axis, self.state, jnp.asarray(hi),
            jnp.asarray(lo), fp_bits=self.fp_bits,
            capacity_factor=self.capacity_factor, backend=self.backend,
            donate=self.donate, valid=jnp.asarray(valid),
            route=self.route)
        ok, deferred = np.asarray(ok), np.asarray(deferred)
        self._pend_hi = np.concatenate([self._pend_hi, hi[deferred]])
        self._pend_lo = np.concatenate([self._pend_lo, lo[deferred]])
        self.stats.inserted += int(ok.sum())
        self.stats.deferred += int(deferred.sum())
        self.stats.failed += int((valid & ~ok & ~deferred).sum())
        if self.metrics is not None:
            # ok/deferred already forced a sync; ov rides the same fence.
            m = self.metrics
            m.counter("routing_inserted_lanes").inc(int(ok.sum()))
            m.counter("routing_deferred_lanes").inc(int(deferred.sum()))
            m.counter("routing_overflow_lanes").inc(
                int(np.asarray(ov).sum()))
        return ok, deferred

    def submit(self, hi, lo):
        """Routed insert of a fresh batch -> (ok[N], deferred[N]).

        Deferred lanes are parked for ``pump``; the batch must divide the
        shard count (the ``distributed_insert`` contract for fresh traffic).
        While ``held`` (elastic migration window) the batch parks whole —
        nothing inserted, everything deferred — and replays after cutover.
        """
        hi = np.asarray(hi, np.uint32)
        lo = np.asarray(lo, np.uint32)
        self.stats.submitted += int(hi.size)
        if self.held:
            self._pend_hi = np.concatenate([self._pend_hi, hi])
            self._pend_lo = np.concatenate([self._pend_lo, lo])
            self.stats.deferred += int(hi.size)
            return (np.zeros(hi.size, bool), np.ones(hi.size, bool))
        return self._attempt(hi, lo)

    def pump(self) -> int:
        """One resubmission tick -> lanes re-attempted (0 while held).

        Gated by the side-effect-free ``peek`` so polling does not inflate
        the controller's per-request counters; a tripped gate holds the
        parked batch untouched (``held_ticks``) until the congestion signal
        recedes past low_water.
        """
        if not self.pending:
            return 0
        if self.held or not self.admission.peek():
            self.stats.held_ticks += 1
            if self.metrics is not None:
                self.metrics.counter("pump_held_ticks").inc()
            return 0
        hi, lo = self._pend_hi, self._pend_lo
        self._pend_hi = np.empty((0,), np.uint32)
        self._pend_lo = np.empty((0,), np.uint32)
        self.stats.resubmitted += int(hi.size)
        if self.metrics is not None:
            self.metrics.counter("pump_resubmitted_lanes").inc(int(hi.size))
        with self._span("pump_resubmit", lanes=int(hi.size)):
            self._attempt(hi, lo)
        return int(hi.size)

    def run_until_drained(self, *, max_ticks: int = 100,
                          on_held=None) -> PumpStats:
        """Pump until nothing is parked (or ``max_ticks``).

        ``on_held``: optional callback invoked on each held tick — the hook
        where a control plane relieves congestion (rotate a generation,
        grow the shards, age the stash); without one a tripped gate over a
        static filter would hold forever, so the loop stops early when
        holding makes no progress and nothing external intervenes.
        """
        for _ in range(max_ticks):
            if not self.pending:
                break
            if self.pump() == 0 and on_held is None:
                break
            if on_held is not None and self.admission.tripped:
                on_held(self)
        return self.stats


# --------------------------------------- membership-op submit path ------
#
# The latency side of the serving story.  ``ContinuousBatcher`` schedules
# decode slots; the filter traffic it fronts (prefix-index probes, the SLO
# harness's scenario replay) arrives as *waves* of homogeneous membership
# ops.  The batcher below is the wave-granular submit path: one wave is
# prepared host-side (pad to a fixed shape, hash split, optional lookup
# dedup), dispatched to the device through ``FilterOps``, and harvested —
# ``jax.block_until_ready`` ONLY at harvest.  In double-buffered mode the
# harvest of wave k happens *after* wave k+1 has been prepared and
# dispatched, so host prep overlaps device execution and the scheduler,
# not host sync, sets the latency floor.  Both modes issue the identical
# device-call sequence in the identical order, so their results (and the
# filter state they leave behind) are bit-for-bit equal — the oracle
# parity tests in tests/test_slo.py pin this.


# Wave latency histogram edges (µs) for the metrics registry — spans the
# sync-path microbench floor through admission-parked closed-loop tails.
LATENCY_BUCKETS_US = (50.0, 100.0, 200.0, 500.0, 1_000.0, 2_000.0,
                      5_000.0, 10_000.0, 25_000.0, 50_000.0, 100_000.0)


@dataclasses.dataclass
class OpWave:
    """One submitted wave and its timing: the recorder's unit of sample.

    ``latency_us`` spans offered -> results-materialized, so a wave parked
    by admission control carries its queueing delay (closed-loop latency,
    not bare kernel time)."""
    kind: str
    n: int
    submit_s: float
    done_s: float = 0.0
    deferred_ticks: int = 0       # submit ticks spent parked by admission
    results: Optional[np.ndarray] = None
    # harvest internals: device refs + result slicing metadata
    _device: tuple = dataclasses.field(default=(), repr=False)
    _n_probe: int = 0
    _inverse: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False)

    @property
    def latency_us(self) -> float:
        return (self.done_s - self.submit_s) * 1e6


@dataclasses.dataclass
class BatcherStats:
    waves: int = 0                # waves offered via submit()
    ops: int = 0                  # real (non-padding) lanes offered
    harvests: int = 0
    deferred_waves: int = 0       # insert waves parked by admission
    held_ticks: int = 0           # drain attempts the gate held the queue
    shed_ops: int = 0             # lanes still parked when drain gave up
    deduped_lanes: int = 0        # lookup lanes collapsed by dedup


class FilterOpBatcher:
    """Double-buffered wave submit path over a ``FilterOps`` data plane.

    Works over either state family:

      * ``core.filter.FilterState`` (+ optional overflow stash) — lookup /
        insert / delete through the static-filter entry points;
      * ``adaptive.state.AdaptiveState`` (detected by its ``sels`` plane)
        — the selector-aware entry points, plus the ``report`` kind
        feeding confirmed false positives back.

    Waves are padded to ``wave_slots`` (key 0, ``valid=False``) so every
    (kind, state-family) pair compiles exactly once.  ``submit`` returns
    the ``OpWave`` immediately; ``wave.results`` is populated at harvest —
    the next submit (double-buffered) or before submit returns (sync).
    Call ``flush()`` to force the in-flight wave out (the closed-loop
    feedback point: adversarial report waves need the previous lookup's
    results).

    Admission coupling: with an ``AdmissionController`` attached (or an
    ``AdmissionConfig``, from which one is built over this batcher's own
    ``fills()`` duck), insert waves are gated by the hysteresis signal —
    tripped inserts park in a deferred queue that retries on later submits
    / ``drain()``.  Deletes and lookups bypass the gate (deletes *relieve*
    congestion; probes don't add occupancy).  ``fills()`` reports the
    occupancy snapshot taken at the last harvest — polling it costs no
    device sync, so the controller can gate every wave without stalling
    the pipeline.

    ``double_buffer="auto"`` (the default) resolves per host: overlap
    only pays when device work and host prep run on different silicon, so
    it picks the async path on real accelerators and on multi-core CPU
    hosts (XLA's compute pool and the numpy prep genuinely interleave),
    and the sync path on a single-core CPU host — there the "device" IS
    the host core, every pipelined wave just queues behind the previous
    one, and per-wave latency doubles for zero wall-clock gain.  Both
    paths issue the identical device-call sequence in the identical
    order, so the choice is bit-for-bit invisible to results.
    """

    def __init__(self, ops, state, *, stash: Optional[jax.Array] = None,
                 wave_slots: int = 512, double_buffer="auto",
                 dedupe_lookups: bool = True, admission=None,
                 clock: Callable[[], float] = time.perf_counter,
                 telemetry: bool = False, metrics=None, tracer=None):
        """Observability kwargs (all default-off; the off path issues the
        identical device-call sequence as a batcher built without them):

        ``telemetry``: dispatch through the ``FilterOps`` ``*_tm`` twins so
        every wave also returns a device-computed ``FilterTelemetry``
        (kick-depth histogram, probe hit-depth, spill/rollback counters);
        the counters ride ``wave._device`` and materialize in the SAME
        single ``block_until_ready`` as the results.  ``metrics``: a
        ``repro.obs.MetricsRegistry`` receiving wave timings + counters
        (auto-created when ``telemetry`` is on and none is given).
        ``tracer``: a ``repro.obs.TraceRecorder``; dispatch and harvest
        get Chrome-trace spans."""
        self.ops = ops
        self.state = state
        self.stash = stash
        self.telemetry = bool(telemetry)
        if self.telemetry and metrics is None:
            from repro.obs import MetricsRegistry
            metrics = MetricsRegistry()
        self.metrics = metrics
        self.tracer = tracer
        self.wave_slots = int(wave_slots)
        if double_buffer == "auto":
            double_buffer = (jax.default_backend() != "cpu"
                             or (os.cpu_count() or 1) > 1)
        self.double_buffer = bool(double_buffer)
        self.dedupe_lookups = bool(dedupe_lookups)
        self._clock = clock
        self._adaptive = hasattr(state, "sels")
        self.capacity = int(state.n_buckets) * state.table.shape[1]
        self.stash_slots = 0 if stash is None else int(stash.shape[1])
        self._fill_snapshot = (
            float(jax.device_get(state.count)) / max(1, self.capacity), 0.0)
        if admission is not None and not hasattr(admission, "admit"):
            from repro.streaming.admission import AdmissionController
            admission = AdmissionController(filt=self, config=admission,
                                            metrics=self.metrics)
        self.admission = admission
        self._inflight: Optional[OpWave] = None
        self._deferred: deque[tuple[OpWave, np.ndarray]] = deque()
        self.stats = BatcherStats()

    # ----------------------------------------------------------- intake --

    def submit(self, kind: str, keys) -> OpWave:
        """Offer one wave -> its ``OpWave`` (results pending until harvest).

        Parked insert waves are retried (FIFO) before the new wave, so
        admission never reorders writes relative to each other."""
        keys = np.ascontiguousarray(np.asarray(keys, np.uint64))
        wave = OpWave(kind=kind, n=int(keys.size), submit_s=self._clock())
        self.stats.waves += 1
        self.stats.ops += wave.n
        self._retry_deferred()
        if (kind == "insert" and self.admission is not None
                and not self.admission.admit()):
            self._deferred.append((wave, keys))
            self.stats.deferred_waves += 1
            if self.metrics is not None:
                self.metrics.counter("filter_deferred_waves").inc()
            return wave
        self._launch(wave, keys)
        return wave

    def flush(self) -> None:
        """Materialize the in-flight wave (one ``block_until_ready``)."""
        if self._inflight is not None:
            self._harvest(self._inflight)

    def drain(self, *, max_ticks: int = 100, on_held=None) -> int:
        """Retry parked waves until none remain (or ``max_ticks``), then
        flush -> number of ops still parked (shed).

        ``on_held``: callback invoked when the gate holds with nothing
        in flight to relieve it — the hook where a control plane ages or
        deletes; without one the loop stops once holding makes no
        progress, and the remainder counts as shed load."""
        for _ in range(max_ticks):
            if not self._deferred:
                break
            before = len(self._deferred)
            self._retry_deferred()
            if len(self._deferred) == before:
                self.stats.held_ticks += 1
                if self.metrics is not None:
                    self.metrics.counter("filter_held_ticks").inc()
                if on_held is None:
                    break
                on_held(self)
        self.flush()
        shed = sum(keys.size for _, keys in self._deferred)
        self.stats.shed_ops += shed
        if shed and self.metrics is not None:
            self.metrics.counter("filter_shed_ops").inc(shed)
        return shed

    def fills(self) -> tuple[float, float]:
        """(table fill, stash fill) at the LAST harvest — the
        ``GenerationalFilter.fills()`` duck, sync-free by construction."""
        return self._fill_snapshot

    # --------------------------------------------------------- pipeline --

    def _retry_deferred(self) -> None:
        while self._deferred:
            if self.admission is not None and not self.admission.peek():
                for parked, _ in self._deferred:
                    parked.deferred_ticks += 1
                break
            wave, keys = self._deferred.popleft()
            self._launch(wave, keys)

    def _span(self, name: str, **args):
        """Trace span (or no-op) — host-side only, never a device sync."""
        if self.tracer is None:
            return contextlib.nullcontext()
        return self.tracer.span(name, **args)

    def _launch(self, wave: OpWave, keys: np.ndarray) -> None:
        prev = self._inflight
        with self._span("wave_dispatch", kind=wave.kind, n=wave.n):
            if self.telemetry:
                self._dispatch_tm(wave, keys)
            else:
                self._dispatch(wave, keys)  # overlaps prev's device exec
        self._inflight = wave
        if prev is not None:
            self._harvest(prev)
        if not self.double_buffer:
            self._harvest(wave)

    def _prepare(self, wave: OpWave, keys: np.ndarray):
        """Host-side wave prep: dedup (lookups), pad, hash split, upload."""
        if wave.kind == "lookup" and self.dedupe_lookups:
            keys, wave._inverse = dedupe_keys(keys)
            if wave._inverse is not None:
                self.stats.deduped_lanes += wave.n - keys.size
        n = keys.size
        assert n <= self.wave_slots, (n, self.wave_slots)
        wave._n_probe = n
        padded = np.zeros(self.wave_slots, np.uint64)
        padded[:n] = keys
        hi, lo = hashing.key_to_u32_pair_np(padded)
        valid = np.zeros(self.wave_slots, bool)
        valid[:n] = True
        return jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(valid)

    def _dispatch(self, wave: OpWave, keys: np.ndarray) -> None:
        """Queue the wave's device work; grab (results, count, occupancy)
        refs for the harvest.  No host sync on this path."""
        hi, lo, valid = self._prepare(wave, keys)
        ops, state, stash = self.ops, self.state, self.stash
        if wave.kind == "lookup":
            if self._adaptive:
                res = ops.lookup_adaptive(state, hi, lo, stash=stash)
            elif stash is not None:
                res = ops.lookup_with_stash(state, stash, hi, lo)
            else:
                res = ops.lookup(state, hi, lo)
        elif wave.kind == "insert":
            if self._adaptive and stash is not None:
                self.state, self.stash, res = ops.insert_adaptive(
                    state, hi, lo, valid=valid, stash=stash)
            elif self._adaptive:
                self.state, res = ops.insert_adaptive(state, hi, lo,
                                                      valid=valid)
            elif stash is not None:
                self.state, self.stash, res = ops.insert_spill(
                    state, stash, hi, lo, valid=valid)
            else:
                self.state, res = ops.insert(state, hi, lo, valid=valid)
        elif wave.kind == "delete":
            if self._adaptive:
                out = ops.delete_adaptive(state, hi, lo, valid=valid,
                                          stash=stash)
                if stash is not None:
                    self.state, self.stash, res = out
                else:
                    self.state, res = out
            elif stash is not None:
                table, new_stash, res = ops.delete_table(
                    state.table, hi, lo, n_buckets=state.n_buckets,
                    valid=valid, stash=stash)
                # ok counts table AND stash clears; count tracks the table
                stash_cleared = (kops.stash_occupancy(stash)
                                 - kops.stash_occupancy(new_stash))
                count = (state.count - jnp.sum(res, dtype=jnp.int32)
                         + stash_cleared)
                self.state = jfilter.FilterState(table, count,
                                                 state.n_buckets)
                self.stash = new_stash
            else:
                self.state, res = ops.delete(state, hi, lo, valid=valid)
        elif wave.kind == "report":
            if not self._adaptive:
                raise ValueError("'report' waves need an AdaptiveState")
            self.state, adapted, _resident = ops.report_false_positive(
                state, hi, lo, valid=valid)
            res = adapted
        else:
            raise ValueError(f"unknown wave kind {wave.kind!r}")
        occ = (kops.stash_occupancy(self.stash)
               if self.stash is not None else jnp.int32(0))
        wave._device = (res, self.state.count, occ)

    def _dispatch_tm(self, wave: OpWave, keys: np.ndarray) -> None:
        """Telemetry twin of ``_dispatch``: the same wave semantics through
        the ``FilterOps`` ``*_tm`` entry points.  The per-wave
        ``FilterTelemetry`` rides ``wave._device`` so the harvest
        materializes counters and results in the SAME single
        ``block_until_ready`` — telemetry adds no extra sync points."""
        hi, lo, valid = self._prepare(wave, keys)
        ops, state, stash = self.ops, self.state, self.stash
        if wave.kind == "lookup":
            if self._adaptive:
                res, tm = ops.lookup_adaptive_tm(state, hi, lo, stash=stash)
            elif stash is not None:
                res, tm = ops.lookup_with_stash_tm(state, stash, hi, lo)
            else:
                res, tm = ops.lookup_tm(state, hi, lo)
        elif wave.kind == "insert":
            if self._adaptive and stash is not None:
                self.state, self.stash, res, tm = ops.insert_adaptive_tm(
                    state, hi, lo, valid=valid, stash=stash)
            elif self._adaptive:
                self.state, res, tm = ops.insert_adaptive_tm(
                    state, hi, lo, valid=valid)
            elif stash is not None:
                self.state, self.stash, res, tm = ops.insert_spill_tm(
                    state, stash, hi, lo, valid=valid)
            else:
                self.state, res, tm = ops.insert_tm(state, hi, lo,
                                                    valid=valid)
        elif wave.kind == "delete":
            if self._adaptive:
                out = ops.delete_adaptive_tm(state, hi, lo, valid=valid,
                                             stash=stash)
                if stash is not None:
                    self.state, self.stash, res, tm = out
                else:
                    self.state, res, tm = out
            elif stash is not None:
                table, new_stash, res, tm = kops.filter_delete_tm(
                    state.table, hi, lo, fp_bits=ops.fp_bits,
                    n_buckets=state.n_buckets, valid=valid, stash=stash)
                # same count convention as the telemetry-off arm: ok counts
                # table AND stash clears; count tracks the table
                stash_cleared = (kops.stash_occupancy(stash)
                                 - kops.stash_occupancy(new_stash))
                count = (state.count - jnp.sum(res, dtype=jnp.int32)
                         + stash_cleared)
                self.state = jfilter.FilterState(table, count,
                                                 state.n_buckets)
                self.stash = new_stash
            else:
                self.state, res, tm = ops.delete_tm(state, hi, lo,
                                                    valid=valid)
        elif wave.kind == "report":
            if not self._adaptive:
                raise ValueError("'report' waves need an AdaptiveState")
            self.state, adapted, _resident, tm = \
                ops.report_false_positive_tm(state, hi, lo, valid=valid)
            res = adapted
        else:
            raise ValueError(f"unknown wave kind {wave.kind!r}")
        occ = (kops.stash_occupancy(self.stash)
               if self.stash is not None else jnp.int32(0))
        wave._device = (res, self.state.count, occ, tm)

    def _harvest(self, wave: OpWave) -> None:
        """The ONLY sync point: materialize one wave's device refs."""
        with self._span("wave_harvest", kind=wave.kind, n=wave.n):
            dev = jax.block_until_ready(wave._device)
        if len(dev) == 4:
            res, count, occ, tm = dev
        else:
            (res, count, occ), tm = dev, None
        out = np.asarray(res)[:wave._n_probe]
        wave.results = out[wave._inverse] if wave._inverse is not None \
            else out
        wave._device = ()
        wave.done_s = self._clock()
        self._fill_snapshot = (
            float(count) / max(1, self.capacity),
            float(occ) / self.stash_slots if self.stash_slots else 0.0)
        self.stats.harvests += 1
        if wave is self._inflight:
            self._inflight = None
        if self.metrics is not None:
            self._record_wave(wave, tm)

    # ------------------------------------------------------ observability --

    def _record_wave(self, wave: OpWave, tm) -> None:
        """Fold one harvested wave into the metrics registry.  ``tm`` is a
        ``FilterTelemetry`` (already materialized) or None when the batcher
        runs host metrics without device counter planes."""
        m = self.metrics
        m.counter("filter_waves").inc(kind=wave.kind)
        m.counter("filter_wave_ops").inc(wave.n, kind=wave.kind)
        m.histogram("filter_wave_latency_us",
                    buckets=LATENCY_BUCKETS_US).observe(wave.latency_us,
                                                        kind=wave.kind)
        m.record_wave({"kind": wave.kind, "n": wave.n,
                       "latency_us": wave.latency_us,
                       "deferred_ticks": wave.deferred_ticks})
        if tm is None:
            return
        # One bulk device->host pull for the whole counter plane — the
        # per-field int()/asarray conversions each pay a jax->numpy hop,
        # which at wave rate was the biggest slice of telemetry overhead.
        tm = type(tm)(*jax.device_get(tuple(tm)))
        if wave.kind == "insert":
            m.histogram("filter_kick_depth",
                        buckets=KICK_EDGES).observe_counts(
                [int(c) for c in tm.kick_hist])
        for depth, cnt in zip(("b1", "b2", "stash", "miss"),
                              tm.probe_depth):
            if cnt:
                m.counter("filter_probe_depth").inc(int(cnt), depth=depth)
        for name, val in (("filter_stash_spills", tm.stash_spills),
                          ("filter_rollback_lanes", tm.rollback_lanes),
                          ("filter_selector_bumps", tm.selector_bumps),
                          ("filter_overflow_lanes", tm.overflow_lanes),
                          ("filter_table_deletes", tm.table_deletes),
                          ("filter_stash_deletes", tm.stash_deletes)):
            v = int(val)
            if v:
                m.counter(name).inc(v)
        m.gauge("filter_stash_fill_hw").set_max(int(tm.stash_fill_hw))
