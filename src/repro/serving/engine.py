"""Serving engine: prefill + decode step factories and a batched driver.

The OCF prefix-cache index (kvcache.py) sits on the admission path: before a
prefill, the engine asks the filter which prefix blocks are already cached;
hits skip recompute (here: skip re-prefill of the shared prefix), misses are
inserted after prefill, and evictions *delete* from the filter — exercising
the full insert/lookup/delete OCF cycle at serving rates.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import Transformer


def make_prefill_step(model: Transformer, parallel=None):
    """(params, cache, tokens[B,S]) -> (logits[B,S,V], cache)."""

    def prefill(params, cache, tokens, *, memory=None, prefix_embeds=None):
        out = model.apply(params, tokens, cache=cache, cache_pos=0,
                          memory=memory, prefix_embeds=prefix_embeds,
                          parallel=parallel)
        return out.logits, out.cache

    return prefill


def make_decode_step(model: Transformer, parallel=None):
    """(params, cache, token[B,1], pos) -> (logits[B,1,V], cache)."""

    def decode(params, cache, token, pos, *, memory=None):
        out = model.apply(params, token, memory=memory, cache=cache,
                          cache_pos=pos, parallel=parallel)
        return out.logits, out.cache

    return decode


def greedy_sample(logits):
    return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)


@dataclasses.dataclass
class GenerationResult:
    tokens: Any
    steps: int


def generate(model: Transformer, params, prompt, max_new: int, *,
             memory=None, cache_len: Optional[int] = None,
             dtype=jnp.float32) -> GenerationResult:
    """Simple batched greedy generation driver (prefill + decode loop)."""
    b, s = prompt.shape
    cache_len = cache_len or (s + max_new)
    cache = model.init_cache(b, cache_len, dtype=dtype)
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))
    logits, cache = prefill(params, cache, prompt, memory=memory)
    tok = greedy_sample(logits)
    toks = [tok]
    pos = s
    for _ in range(max_new - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(pos),
                               memory=memory)
        tok = greedy_sample(logits)
        toks.append(tok)
        pos += 1
    return GenerationResult(tokens=jnp.concatenate(toks, axis=1),
                            steps=max_new)
