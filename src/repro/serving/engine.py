"""Serving engine: prefill + decode step factories and a batched driver.

The OCF prefix-cache index (kvcache.py) sits on the admission path: before a
prefill, the engine asks the filter which prefix blocks are already cached;
hits skip recompute (here: skip re-prefill of the shared prefix), misses are
inserted after prefill, and evictions *delete* from the filter — exercising
the full insert/lookup/delete OCF cycle at serving rates.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import Transformer


def make_prefill_step(model: Transformer, parallel=None):
    """(params, cache, tokens[B,S]) -> (logits[B,S,V], cache)."""

    def prefill(params, cache, tokens, *, memory=None, prefix_embeds=None):
        out = model.apply(params, tokens, cache=cache, cache_pos=0,
                          memory=memory, prefix_embeds=prefix_embeds,
                          parallel=parallel)
        return out.logits, out.cache

    return prefill


def make_decode_step(model: Transformer, parallel=None):
    """(params, cache, token[B,1], pos) -> (logits[B,1,V], cache)."""

    def decode(params, cache, token, pos, *, memory=None):
        out = model.apply(params, token, memory=memory, cache=cache,
                          cache_pos=pos, parallel=parallel)
        return out.logits, out.cache

    return decode


def greedy_sample(logits):
    return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)


# ------------------------------------------------- backpressure policy --
#
# The SLO follow-on: the admission arm's congestion evidence — shed load,
# deferred insert waves, and the hysteresis gate's signal — now lives in
# the metrics registry (``repro.obs``), so the serving engine can make its
# backpressure decision from the SAME numbers the report surfaces, instead
# of re-deriving them from scheduler internals.


@dataclasses.dataclass(frozen=True)
class BackpressureConfig:
    """Thresholds over the registry's admission metrics.

    ``defer_signal`` / ``resume_signal`` form the hysteresis band over the
    ``admission_signal`` gauge (the same congestion scalar the filter-side
    ``AdmissionController`` trips on); fresh ``filter_shed_ops`` escalate
    straight to shedding — load the filter already gave up on must not be
    re-offered as decode work.
    """

    defer_signal: float = 0.85
    resume_signal: float = 0.60


class BackpressureController:
    """Three-state admit/defer/shed decision over a metrics registry.

    Reads (never writes) the congestion metrics the filter stack publishes:

    * ``admission_signal`` / ``admission_peak_signal`` gauges — live and
      worst-case congestion from the hysteresis gate;
    * ``filter_deferred_waves`` counter — insert waves the gate parked;
    * ``filter_shed_ops`` counter — lanes still parked when a drain gave
      up (genuine shed load).

    ``decide()`` is the engine-side transition function:

      admit --(deferred waves grow OR signal >= defer_signal)--> defer
      any   --(fresh shed ops)-----------------------------------> shed
      defer/shed --(signal <= resume_signal, no new evidence)----> admit

    Counter *deltas* (not absolutes) drive the transitions, so a
    controller attached mid-run does not re-punish historical congestion.
    """

    def __init__(self, metrics, config: Optional[BackpressureConfig] = None):
        self.metrics = metrics
        self.config = config or BackpressureConfig()
        self.state = "admit"
        self._last = {"filter_shed_ops": self._read("filter_shed_ops"),
                      "filter_deferred_waves":
                          self._read("filter_deferred_waves")}

    def _read(self, name: str) -> float:
        return float(self.metrics.counter(name).value())

    def _delta(self, name: str) -> float:
        cur = self._read(name)
        d = cur - self._last[name]
        self._last[name] = cur
        return d

    @property
    def peak_signal(self) -> float:
        return float(self.metrics.gauge("admission_peak_signal").value())

    def decide(self) -> str:
        """One backpressure decision -> 'admit' | 'defer' | 'shed'."""
        cfg = self.config
        sig = float(self.metrics.gauge("admission_signal").value())
        shed = self._delta("filter_shed_ops")
        deferred = self._delta("filter_deferred_waves")
        if shed > 0:
            self.state = "shed"
        elif self.state == "shed":
            if sig <= cfg.resume_signal and deferred == 0:
                self.state = "admit"
        elif deferred > 0 or sig >= cfg.defer_signal:
            self.state = "defer"
        elif self.state == "defer" and sig <= cfg.resume_signal:
            self.state = "admit"
        self.metrics.counter("backpressure_decisions").inc(
            decision=self.state)
        return self.state


@dataclasses.dataclass
class GenerationResult:
    tokens: Any
    steps: int


def generate(model: Transformer, params, prompt, max_new: int, *,
             memory=None, cache_len: Optional[int] = None,
             dtype=jnp.float32) -> GenerationResult:
    """Simple batched greedy generation driver (prefill + decode loop)."""
    b, s = prompt.shape
    cache_len = cache_len or (s + max_new)
    cache = model.init_cache(b, cache_len, dtype=dtype)
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))
    logits, cache = prefill(params, cache, prompt, memory=memory)
    tok = greedy_sample(logits)
    toks = [tok]
    pos = s
    for _ in range(max_new - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(pos),
                               memory=memory)
        tok = greedy_sample(logits)
        toks.append(tok)
        pos += 1
    return GenerationResult(tokens=jnp.concatenate(toks, axis=1),
                            steps=max_new)
