"""Latency-SLO harness — closed-loop scenario replay with tail percentiles.

Every number in ``BENCH_filter.json`` used to be a throughput row; this
module adds the latency axis the paper's title promises.  A scenario
stream (``serving.workloads``) is replayed closed-loop against a live
filter stack through the wave-granular submit path
(``serving.scheduler.FilterOpBatcher``); every wave's offered -> results-
materialized span lands in a ``LatencyRecorder``, and the per-scenario
``SloReport`` folds the samples into p50/p99/p99.9 (+ keys/s alongside,
so tails are never read without their throughput context).

The recorder follows the structured-metrics shape of gpu-recipes'
``training_metrics`` loggers: raw per-sample records kept (kind, µs, op
count, tags), summaries derived — never the other way around — so a
report can be re-sliced (per-kind, in-burst vs gap, admitted vs deferred)
without re-running the scenario.

Determinism & comparability: given one ``--seed`` and one backend, the
stream, the filter state trajectory, and the device-call sequence are all
pure functions of the seed (``workloads.scenario_stream``), so percentile
rows are comparable across commits and the bench gate
(``scripts/bench_gate.py``) can fail verify on tail regressions.

Compile discipline: p99.9 over ~50 waves is garbage if wave 0 carries a
jit compile, so ``run_scenario`` warms every (kind, shape) pair the
stream will touch on a THROWAWAY same-shape stack first (the jit cache is
keyed on shapes + the shared ``FilterOps``, not on array identity), then
starts the clock.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Iterable, Optional

import numpy as np

from repro.adaptive.state import make_adaptive_state
from repro.core import filter as jfilter
from repro.core.filter_ops import FilterOps
from repro.kernels import ops as kops
from repro.serving.scheduler import FilterOpBatcher, OpWave
from repro.serving.workloads import OpBatch, scenario_stream

__all__ = ["LatencyRecorder", "SloHarness", "SloReport", "run_scenario",
           "run_scenario_telemetry", "bench_scenarios", "BENCH_SCENARIOS",
           "PERCENTILES"]

PERCENTILES = (("p50", 50.0), ("p99", 99.0), ("p999", 99.9))

# Scenarios whose percentile rows the bench emits (and the gate requires).
BENCH_SCENARIOS = ("uniform", "zipfian", "adversarial", "burst_train",
                   "ttl_churn", "delete_heavy")


@dataclasses.dataclass(frozen=True)
class WaveSample:
    """One wave's latency record — the raw unit the summaries derive from."""
    kind: str
    us: float        # offered -> materialized, microseconds
    ops: int         # real lanes in the wave (percentiles are op-weighted)
    burst: bool = False
    deferred: bool = False   # spent >=1 submit tick parked by admission


class LatencyRecorder:
    """Append-only per-wave samples + derived percentile summaries.

    Percentiles are **op-weighted**: a 512-key wave contributes 512
    identical per-op samples, so "p99 of ops" means what an SLO means —
    the latency the 99th-percentile *operation* saw, not the 99th-
    percentile wave.
    """

    def __init__(self):
        self.samples: list[WaveSample] = []

    def observe(self, kind: str, us: float, *, ops: int = 1,
                burst: bool = False, deferred: bool = False) -> None:
        self.samples.append(WaveSample(kind, float(us), int(ops),
                                       burst, deferred))

    def observe_wave(self, wave: OpWave, *, burst: bool = False) -> None:
        self.observe(wave.kind, wave.latency_us, ops=wave.n, burst=burst,
                     deferred=wave.deferred_ticks > 0)

    def _select(self, kinds=None, burst=None, exclude_deferred=False):
        out = self.samples
        if kinds is not None:
            out = [s for s in out if s.kind in kinds]
        if burst is not None:
            out = [s for s in out if s.burst == burst]
        if exclude_deferred:
            out = [s for s in out if not s.deferred]
        return out

    def ops(self, **sel) -> int:
        return sum(s.ops for s in self._select(**sel))

    def percentiles(self, **sel) -> dict[str, float]:
        """Op-weighted {p50, p99, p999} in µs over the selected samples."""
        chosen = self._select(**sel)
        if not chosen:
            return {name: 0.0 for name, _ in PERCENTILES}
        us = np.repeat([s.us for s in chosen], [s.ops for s in chosen])
        return {name: float(np.percentile(us, q))
                for name, q in PERCENTILES}

    def kinds(self) -> list[str]:
        return sorted({s.kind for s in self.samples})


@dataclasses.dataclass
class SloReport:
    """One scenario's summary — ``rows()`` is the BENCH_filter.json shape."""
    scenario: str
    ops: int
    waves: int
    wall_s: float
    keys_per_s: float
    percentiles_us: dict[str, float]
    per_kind: dict[str, dict[str, float]]
    shed_ops: int = 0
    deferred_waves: int = 0
    held_ticks: int = 0
    extras: dict = dataclasses.field(default_factory=dict)
    # raw samples, for re-slicing (NOT part of rows())
    recorder: Optional[LatencyRecorder] = None

    def rows(self, prefix: Optional[str] = None) -> dict[str, float]:
        """Flat bench rows: ``slo_<scenario>_{p50,p99,p999}_us`` +
        ``slo_<scenario>_keys_per_s`` (+ extras verbatim)."""
        p = prefix or f"slo_{self.scenario}"
        out = {f"{p}_{name}_us": round(v, 1)
               for name, v in self.percentiles_us.items()}
        out[f"{p}_keys_per_s"] = int(self.keys_per_s)
        for k, v in self.extras.items():
            out[f"{p}_{k}"] = v
        return out


class SloHarness:
    """Closed-loop scenario driver over a submit path or generation ring.

    ``tracer``: optional ``repro.obs.TraceRecorder`` — each replay gets a
    scenario-level span (wave/harvest spans come from the batcher's own
    tracer; wire the same recorder into both for one coherent timeline).
    """

    def __init__(self, *, clock: Callable[[], float] = time.perf_counter,
                 tracer=None):
        self._clock = clock
        self.tracer = tracer

    def _span(self, name: str, **args):
        if self.tracer is None:
            return contextlib.nullcontext()
        return self.tracer.span(name, **args)

    # ------------------------------------------------------ wave stacks --

    def run(self, batcher: FilterOpBatcher, stream: Iterable[OpBatch], *,
            scenario: str = "scenario", on_held=None) -> SloReport:
        """Replay ``stream`` through ``batcher``; every wave's latency is
        recorded at harvest.  ``feedback`` lookup waves close the adaptive
        loop: the harness flushes, gathers the hits, and submits them back
        as a ``report`` wave (its latency is a sample like any other —
        feedback is part of the serving path, not free).

        Burst waves are timed from the **burst's arrival**, not from each
        wave's own submit call: a run of consecutive ``burst=True``
        batches models one client dumping the whole train at once, so
        every wave in the run shares the run's start timestamp.  Without
        this the synchronous arm coordinate-omits its queueing delay (it
        cannot even *submit* wave k+1 until wave k completes, so
        per-submit stamps hide the wait the client actually experiences),
        while the async arm exposes its device queue — the classic way to
        make the slower path look faster."""
        rec = LatencyRecorder()
        seen: list[tuple[OpWave, bool]] = []
        reported = 0
        burst_t0 = None
        t0 = self._clock()
        with self._span("scenario", scenario=scenario):
            for batch in stream:
                if not batch.burst:
                    burst_t0 = None
                elif burst_t0 is None:
                    burst_t0 = self._clock()  # the whole train arrives now
                wave = batcher.submit(batch.kind, batch.keys)
                if burst_t0 is not None:
                    wave.submit_s = burst_t0
                seen.append((wave, batch.burst))
                if batch.feedback:
                    batcher.flush()
                    hits = batch.keys[wave.results]
                    if hits.size:
                        seen.append((batcher.submit("report", hits), False))
                        reported += int(hits.size)
            batcher.drain(on_held=on_held)
        wall = self._clock() - t0
        for wave, burst in seen:
            if wave.done_s:        # shed waves never materialized
                rec.observe_wave(wave, burst=burst)
        return self._report(scenario, rec, wall, batcher=batcher,
                            extras={"reported_fps": reported}
                            if reported else {})

    # -------------------------------------------------- generation ring --

    def run_generational(self, filt, stream: Iterable[OpBatch], *,
                         scenario: str = "ttl_churn") -> SloReport:
        """Replay a TTL stream against a ``GenerationalFilter``.

        The ring's chunked host loop materializes its own results, so the
        timing here is synchronous per wave — the comparison point the
        double-buffered submit path is measured against."""
        rec = LatencyRecorder()
        now = 0.0
        t0 = self._clock()
        for batch in stream:
            now += batch.advance
            t1 = self._clock()
            if batch.kind == "insert":
                filt.insert(batch.keys, now=now)
            elif batch.kind == "lookup":
                filt.lookup(batch.keys, now=now)
            else:
                raise ValueError(
                    f"generation ring stream got {batch.kind!r}")
            rec.observe(batch.kind, (self._clock() - t1) * 1e6,
                        ops=batch.keys.size, burst=batch.burst)
        wall = self._clock() - t0
        extras = {"rotations": filt.stats.rotations,
                  "expirations": filt.stats.expirations}
        return self._report(scenario, rec, wall, extras=extras)

    # ---------------------------------------------------------- report --

    def _report(self, scenario: str, rec: LatencyRecorder, wall_s: float,
                *, batcher: Optional[FilterOpBatcher] = None,
                extras: Optional[dict] = None) -> SloReport:
        ops = rec.ops()
        report = SloReport(
            scenario=scenario, ops=ops, waves=len(rec.samples),
            wall_s=wall_s, keys_per_s=ops / wall_s if wall_s > 0 else 0.0,
            percentiles_us=rec.percentiles(),
            per_kind={k: rec.percentiles(kinds=(k,))
                      for k in rec.kinds()},
            extras=dict(extras or {}))
        if batcher is not None:
            report.shed_ops = batcher.stats.shed_ops
            report.deferred_waves = batcher.stats.deferred_waves
            report.held_ticks = batcher.stats.held_ticks
            if batcher.admission is not None:
                report.extras["peak_signal"] = round(
                    batcher.admission.peak_signal, 3)
        report.recorder = rec
        return report


# ----------------------------------------------------- scenario stacks --
#
# One sizing per scenario, chosen so the steady-state load stays in the
# regime the scenario is about (moderate for the latency mixes, breathing
# across the hysteresis band for the admission arm).  All stacks run
# backend="pallas": off-TPU that resolves to the XLA grid emulation of the
# kernel bodies, which PR 5 made the leading CPU throughput config — the
# SLO numbers measure the serving path, not a strawman backend.

_STATIC_STACKS = {
    "uniform": dict(n_buckets=4096),
    "zipfian": dict(n_buckets=4096),
    "burst_train": dict(n_buckets=2048, stash_slots=64),
    "delete_heavy": dict(n_buckets=2048),
}
_ADAPTIVE_STACKS = {
    # fp_bits=8 so the fixed adversarial pool actually yields false
    # positives to report (the latency of the feedback loop is the point).
    "adversarial": dict(n_buckets=2048, fp_bits=8),
}
_BUCKET_SIZE = 4


def make_batcher(scenario: str, *, backend: str = "pallas",
                 wave_slots: int = 512, double_buffer="auto",
                 admission=None, n_buckets: Optional[int] = None,
                 stash_slots: Optional[int] = None, telemetry: bool = False,
                 metrics=None, tracer=None) -> FilterOpBatcher:
    """Fresh scenario-sized stack -> its ``FilterOpBatcher``."""
    if scenario in _ADAPTIVE_STACKS:
        cfg = dict(_ADAPTIVE_STACKS[scenario])
        nb = n_buckets or cfg["n_buckets"]
        ops = FilterOps(fp_bits=cfg.get("fp_bits", 16), backend=backend,
                        schedule=True)
        state = make_adaptive_state(nb, _BUCKET_SIZE)
    else:
        cfg = dict(_STATIC_STACKS.get(scenario, {"n_buckets": 4096}))
        nb = n_buckets or cfg["n_buckets"]
        ops = FilterOps(fp_bits=cfg.get("fp_bits", 16), backend=backend,
                        schedule=True)
        state = jfilter.make_state(nb, _BUCKET_SIZE)
    slots = stash_slots if stash_slots is not None \
        else cfg.get("stash_slots", 128)
    stash = kops.make_stash(slots) if slots else None
    return FilterOpBatcher(ops, state, stash=stash, wave_slots=wave_slots,
                           double_buffer=double_buffer, admission=admission,
                           telemetry=telemetry, metrics=metrics,
                           tracer=tracer)


def _warm_batcher(proto: FilterOpBatcher, kinds: Iterable[str]) -> None:
    """Compile every (kind, shape) the stream will touch on a throwaway
    same-shape stack (shared jit cache), leaving ``proto`` untouched."""
    if hasattr(proto.state, "sels"):
        state = make_adaptive_state(int(proto.state.n_buckets),
                                    proto.state.table.shape[1])
    else:
        state = jfilter.make_state(int(proto.state.n_buckets),
                                   proto.state.table.shape[1])
    stash = (kops.make_stash(proto.stash.shape[1])
             if proto.stash is not None else None)
    clone = FilterOpBatcher(proto.ops, state, stash=stash,
                            wave_slots=proto.wave_slots,
                            double_buffer=proto.double_buffer,
                            dedupe_lookups=proto.dedupe_lookups,
                            telemetry=proto.telemetry)
    keys = np.arange(1, proto.wave_slots + 1, dtype=np.uint64)
    for kind in ("insert", "lookup", "delete", "report"):
        if kind in kinds:
            clone.submit(kind, keys)
    clone.drain()


def _warm_generational(config) -> None:
    """Compile the ring's insert/probe closures at every live generation
    count TTL churn will visit (each count is its own multiprobe shape)."""
    from repro.streaming.generations import GenerationalFilter
    gf = GenerationalFilter(config=config, now=0.0)
    for i in range(config.generations):
        keys = np.arange(1, 513, dtype=np.uint64) + np.uint64(i << 20)
        gf.insert(keys, now=0.0)
        gf.lookup(keys, now=0.0)
        gf.rotate(now=0.0)
    gf.lookup(np.arange(1, 513, dtype=np.uint64), now=0.0)


def run_scenario(name: str, *, seed: int = 0, backend: str = "pallas",
                 double_buffer="auto", admission=None,
                 warmup: bool = True, wave_slots: int = 512,
                 stream_kwargs: Optional[dict] = None,
                 harness: Optional[SloHarness] = None,
                 telemetry: bool = False, metrics=None, tracer=None,
                 stack_kwargs: Optional[dict] = None) -> SloReport:
    """Run one scenario end to end -> its ``SloReport``.

    Everything downstream of (``name``, ``seed``, ``backend``,
    ``double_buffer``) is deterministic; the sync/async parity test and
    the committed bench rows both lean on that.  ``telemetry`` routes the
    waves through the device counter planes (answers unchanged — the twin
    jits are parity-pinned); ``metrics``/``tracer`` receive the counters
    and spans.
    """
    stream = scenario_stream(name, seed,
                             wave_slots=wave_slots,
                             **(stream_kwargs or {}))
    harness = harness or SloHarness(tracer=tracer)
    if name == "ttl_churn":
        from repro.streaming.generations import (GenerationalFilter,
                                                 GenerationConfig)
        cfg = GenerationConfig(generations=4, capacity=4096, fp_bits=16,
                               ttl=3.0, backend=backend)
        if warmup:
            _warm_generational(cfg)
        # now=0.0 pins the ring to the stream's logical clock domain —
        # the epoch the waves' ``advance`` deltas accumulate from.
        return harness.run_generational(
            GenerationalFilter(config=cfg, now=0.0, metrics=metrics),
            stream, scenario=name)
    batcher = make_batcher(name, backend=backend, wave_slots=wave_slots,
                           double_buffer=double_buffer, admission=admission,
                           telemetry=telemetry, metrics=metrics,
                           tracer=tracer, **(stack_kwargs or {}))
    if warmup:
        kinds = {b.kind for b in stream}
        if any(b.feedback for b in stream):
            kinds.add("report")
        _warm_batcher(batcher, kinds)
    return harness.run(batcher, stream, scenario=name)


def run_scenario_telemetry(name: str, out_dir: str = ".", *, seed: int = 0,
                           backend: str = "pallas", double_buffer="auto",
                           admission=None) -> tuple[SloReport, dict]:
    """The harness's ``--telemetry`` mode: one scenario with counter
    planes + spans on, exported to files.

    Returns ``(report, paths)`` where ``paths`` names the two artifacts:

    * ``slo_<name>_metrics.jsonl``   — full registry snapshot (kick-depth
      histogram, stash high-water, probe depths, admission transitions,
      wave timings + ring records), one JSON object per line;
    * ``slo_<name>_trace.json``      — Chrome trace-event JSON; load in
      ``ui.perfetto.dev`` (or chrome://tracing) to see dispatch/harvest
      overlap per wave.
    """
    import os

    from repro.obs import MetricsRegistry, TraceRecorder
    metrics = MetricsRegistry()
    tracer = TraceRecorder(process_name=f"slo:{name}")
    stack_kwargs = None
    if name == "burst_train" and admission is None:
        # Default the burst replay to the bench's tuned admission arm
        # (small stack + hysteresis band the bursts actually cross), so
        # the exported snapshot carries trip/readmit transitions alongside
        # the kernel counters — the scenario the telemetry mode exists to
        # make visible.
        from repro.streaming.admission import AdmissionConfig
        admission = AdmissionConfig(high_water=0.18, low_water=0.12)
        stack_kwargs = dict(n_buckets=1024, stash_slots=32)
        double_buffer = True
    report = run_scenario(name, seed=seed, backend=backend,
                          double_buffer=double_buffer, admission=admission,
                          telemetry=(name != "ttl_churn"), metrics=metrics,
                          tracer=tracer, stack_kwargs=stack_kwargs)
    os.makedirs(out_dir, exist_ok=True)
    paths = {
        "metrics": os.path.join(out_dir, f"slo_{name}_metrics.jsonl"),
        "trace": os.path.join(out_dir, f"slo_{name}_trace.json"),
    }
    metrics.to_jsonl(paths["metrics"])
    tracer.save(paths["trace"])
    report.extras["telemetry_files"] = paths
    return report, paths


def bench_scenarios(seed: int = 0, scenarios=BENCH_SCENARIOS, *,
                    backend: str = "pallas") -> dict[str, float]:
    """The scenario x percentile matrix ``BENCH_filter.json`` carries.

    The per-scenario rows use the DEFAULT submit path
    (``double_buffer="auto"`` — async where the host can actually
    overlap, sync on a single-core CPU container; recorded in
    ``slo_submit_double_buffered``).  Extra arms beyond those rows:

      * ``slo_burst_train_sync_*`` / ``slo_burst_train_async_*`` — the
        burst train replayed through BOTH explicit submit paths (same
        seed, fresh stacks).  The gate checks the default-path rows
        against the sync arm same-run: on hardware that can overlap the
        default is the async path and must not lose to sync; on a
        single-core host both sides are the sync path and the check pins
        run-to-run stability.  The async arm is always recorded so the
        pipelining cost/benefit is visible on any host;
      * ``slo_burst_admission_*`` — the burst train against a small stack
        with a tuned hysteresis gate: admitted-op tail + shed count, i.e.
        what admission control buys the p99 and what it costs in load;
      * ``slo_seed`` — the seed the whole matrix derives from.
    """
    rows: dict[str, float] = {"slo_seed": seed}
    for name in scenarios:
        rows.update(run_scenario(name, seed=seed, backend=backend).rows())
    # explicit sync/async arms of the burst train (the double-buffer
    # comparison pair)
    for arm, flag in (("sync", False), ("async", True)):
        rep = run_scenario("burst_train", seed=seed, backend=backend,
                           double_buffer=flag)
        for name, v in rep.percentiles_us.items():
            rows[f"slo_burst_train_{arm}_{name}_us"] = round(v, 1)
    rows["slo_submit_double_buffered"] = int(
        make_batcher("burst_train", backend=backend).double_buffer)
    # admission arm: small stack + tuned hysteresis band so the bursts
    # actually cross it (high load -> defer, post-delete -> re-admit).
    # Pinned to the double-buffered path: its fills() snapshot lags one
    # harvested wave, and the band is set against the *snapshot*
    # trajectory (fill 0.25 base -> ~0.5 seen at the burst tail), not the
    # instantaneous one — explicit so the committed defer/shed counters
    # don't depend on the host's auto resolution.
    from repro.streaming.admission import AdmissionConfig
    adm = make_batcher("burst_train", backend=backend,
                       n_buckets=1024, stash_slots=32, double_buffer=True,
                       admission=AdmissionConfig(high_water=0.18,
                                                 low_water=0.12))
    stream = scenario_stream("burst_train", seed)
    _warm_batcher(adm, {"insert", "lookup", "delete"})
    rep = SloHarness().run(adm, stream, scenario="burst_admission")
    admitted = rep.recorder.percentiles(exclude_deferred=True)
    rows["slo_burst_admission_p99_us"] = round(admitted["p99"], 1)
    rows["slo_burst_admission_shed_ops"] = rep.shed_ops
    rows["slo_burst_admission_deferred_waves"] = rep.deferred_waves
    rows["slo_burst_admission_peak_signal"] = rep.extras.get(
        "peak_signal", 0.0)
    return rows
