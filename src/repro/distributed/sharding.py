"""Logical-axis → mesh-axis sharding rules (FSDP × TP × EP × pod-DP).

Model code annotates every parameter dim with a *logical* name
(repro.models.layers docstring).  This module turns those into
``PartitionSpec``s for a concrete mesh:

  expert → model   (expert parallelism: dispatch all-to-all on the TP axis)
  vocab/heads/kv/mlp/rnn/lora → model   (Megatron TP)
  embed → data     (FSDP: params sharded over the DP axis, all-gathered
                    per layer by XLA — the standard ZeRO-3 lowering)
  mem   → data
  layers → never sharded (scan axis)

Each mesh axis is used at most once per tensor (priority order below); any
dim that does not divide evenly falls back to replication — this is what
makes one rule set serve ten heterogeneous architectures.

``pod`` axis: pure data parallelism by default (params replicated across
pods, gradients all-reduced — compressible, see optim), or FSDP over
(pod, data) with ``pod_fsdp=True`` (beyond-paper memory optimization).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PRIORITY = ["expert", "vocab", "heads", "kv", "mlp", "rnn", "lora", "embed",
            "mem"]
AXIS_FOR = {
    "expert": "model", "vocab": "model", "heads": "model", "kv": "model",
    "mlp": "model", "rnn": "model", "lora": "model",
    "embed": "data", "mem": "data",
}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    data_axis: str = "data"
    model_axis: str = "model"
    pod_axis: Optional[str] = None      # set for the multi-pod mesh
    pod_fsdp: bool = False              # shard params over (pod, data)
    compress_grads: bool = False        # bf16 cross-pod gradient all-reduce
    compress_int8: bool = False         # int8 instead of bf16 (4x vs f32)
    remat: str = "none"                 # none | full | dots
    microbatches: int = 1
    seq_shard: bool = False             # sequence-sharded activations (SP)
    layout: str = "tp_fsdp"             # tp_fsdp | fsdp_only | tp_only
    ep_axis: str = "model"              # model | data  (expert placement)
    # mesh axis sizes, filled by the launcher — lets jitted code apply
    # sharding constraints without querying (possibly absent) mesh context
    axis_sizes: Optional[tuple] = None  # (("data",16),("model",16),...)

    def size_of(self, axis: str) -> int:
        if not self.axis_sizes:
            return 0
        return dict(self.axis_sizes).get(axis, 0)

    def batch_axes(self):
        axes = ((self.pod_axis, self.data_axis) if self.pod_axis
                else (self.data_axis,))
        if self.layout == "fsdp_only":
            # no TP: the model axis carries extra data parallelism
            axes = (*axes, self.model_axis)
        return axes


def _mesh_axis(logical: str, parallel: ParallelConfig):
    a = AXIS_FOR.get(logical)
    if logical == "expert" and parallel.ep_axis == "data":
        # EP over the data axis: expert weights never all-gathered (FSDP);
        # the dispatch einsum becomes the MoE all-to-all instead.
        return parallel.data_axis
    if parallel.layout == "fsdp_only" and a == "model":
        return None           # weights replicated across the model axis
    if parallel.layout == "tp_only" and a == "data":
        return None           # no FSDP: weights whole per TP rank
    if a == "data":
        if parallel.pod_fsdp and parallel.pod_axis:
            return (parallel.pod_axis, parallel.data_axis)
        return parallel.data_axis
    if a == "model":
        return parallel.model_axis
    return None


def spec_to_pspec(spec: tuple, shape: tuple, mesh: Mesh,
                  parallel: ParallelConfig) -> P:
    """One tensor's logical spec -> PartitionSpec with divisibility checks."""
    used: set = set()
    out = []
    # decide assignment in priority order, then emit in dim order
    assign: dict[int, Any] = {}
    order = sorted(range(len(spec)),
                   key=lambda i: PRIORITY.index(spec[i])
                   if spec[i] in PRIORITY else len(PRIORITY))
    for i in order:
        name = spec[i]
        if name is None or name == "layers":
            continue
        ax = _mesh_axis(name, parallel)
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        if any(a in used for a in axes):
            continue
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if shape[i] % size != 0:
            # fall back to the last axis alone if that divides
            if (len(axes) > 1 and shape[i] % mesh.shape[axes[-1]] == 0
                    and axes[-1] not in used):
                axes = (axes[-1],)
            else:
                continue
        for a in axes:
            used.add(a)
        assign[i] = axes if len(axes) > 1 else axes[0]
    for i in range(len(spec)):
        out.append(assign.get(i))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def make_shardings(mesh: Mesh, specs_tree, shapes_tree,
                   parallel: ParallelConfig):
    """Parallel trees of logical specs + shapes -> NamedSharding tree."""
    def one(spec, shaped):
        return NamedSharding(mesh, spec_to_pspec(tuple(spec), shaped.shape,
                                                 mesh, parallel))
    return jax.tree.map(one, specs_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        all(isinstance(e, (str, type(None))) for e in x))


def batch_pspec(batch_size: int, ndim: int, mesh: Mesh,
                parallel: ParallelConfig, *, seq_dim: int | None = None) -> P:
    """Sharding for a [B, ...] input: batch over (pod,)data when divisible,
    optional sequence sharding over model for long-context activations."""
    axes = parallel.batch_axes()
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    first = None
    if batch_size % size == 0:
        first = axes if len(axes) > 1 else axes[0]
    elif batch_size % mesh.shape[parallel.data_axis] == 0:
        first = parallel.data_axis
    spec = [first] + [None] * (ndim - 1)
    if parallel.seq_shard and seq_dim is not None and first is not None:
        spec[seq_dim] = parallel.model_axis
    return P(*spec)


def constrain_batch_activations(x, parallel: Optional[ParallelConfig], *,
                                batch_size: Optional[int] = None):
    """Pin [B, S, D] activations to batch-over-(pod,)data (+ optional SP).

    GSPMD occasionally resolves ambiguous layouts by replicating the batch
    and sharding D — then re-gathers multi-GB activations every layer (the
    recurrentgemma pathology, EXPERIMENTS.md §Perf iter 2).  An explicit
    constraint at every block boundary removes the ambiguity.  No-op when
    ``parallel`` is None (single-device tests) or the batch doesn't divide.
    """
    if parallel is None or not parallel.axis_sizes:
        return x
    b = batch_size if batch_size is not None else x.shape[0]
    axes = parallel.batch_axes()
    prod = 1
    for a in axes:
        prod *= max(1, parallel.size_of(a))
    if prod <= 1 or b % prod != 0:
        return x
    spec = [axes if len(axes) > 1 else axes[0]] + [None] * (x.ndim - 1)
    if parallel.seq_shard and x.ndim >= 3:
        spec[1] = parallel.model_axis
    import jax
    return jax.lax.with_sharding_constraint(x, P(*spec))


def shard_filter_state(mesh: Mesh, axis: str, state):
    """Place a ``ShardedFilterState``'s arrays shard-per-device on ``mesh``.

    The filter data plane's counterpart of ``make_shardings``: every array
    field whose leading dim is the shard count (tables uint32[S, B, b],
    stashes uint32[S, 2, slots]) gets ``P(axis)``; non-array fields (static
    geometry like ``n_buckets``) pass through.  Works on any NamedTuple via
    ``_replace``-free tree mapping, so this module needs no import of
    ``core.distributed`` (which imports nothing from here either — the
    placement helper is deliberately the only coupling point, and it is
    one-directional).
    """
    n_shards = mesh.shape[axis]
    sharding = NamedSharding(mesh, P(axis))

    def place(x):
        if isinstance(x, jax.Array) and x.ndim >= 1 and x.shape[0] == n_shards:
            return jax.device_put(x, sharding)
        return x

    return jax.tree.map(place, state)


def cache_pspec(shape: tuple, mesh: Mesh, parallel: ParallelConfig) -> P:
    """KV/state caches: batch over data + context-parallel seq over model.

    Sharding the *sequence* dim of KV caches over the TP axis makes decode
    attention context-parallel: each rank scores its slice of history and
    the softmax combines with O(B·H) partial-max/sum all-reduces — versus
    head-dim sharding, whose contraction all-reduces full [B,H,1,S] logits
    every step (§Perf dsv2/iter4).  States without a seq dim (SSM, RG-LRU)
    fall back to feature-dim sharding.
    """
    ndim = len(shape)
    spec: list = [None] * ndim
    # leading dim is the stacked-periods axis; dim 1 is batch
    if ndim >= 2 and shape[1] % mesh.shape[parallel.data_axis] == 0:
        spec[1] = parallel.data_axis
    m = mesh.shape[parallel.model_axis]
    if ndim >= 4 and shape[-2] % m == 0 and shape[-2] >= 16 * m:
        spec[-2] = parallel.model_axis      # seq dim (KV / MLA-latent cache)
    elif shape[-1] % m == 0 and shape[-1] >= 16:
        spec[-1] = parallel.model_axis      # feature dim (SSM/RG-LRU states)
    return P(*spec)
