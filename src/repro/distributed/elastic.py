"""Elastic resharding: zero-downtime shard split/merge + mesh rebuild.

Two layers live here:

**Mesh elasticity** (``largest_mesh`` / ``reshard_state``): when a pod drops
out, pick the largest grid the survivors support and re-derive shardings
from the logical specs — unchanged from the original module, now
feature-detecting ``jax.sharding.AxisType`` (absent on the 0.4.x line the
repo compat-shims elsewhere).

**Filter elasticity** (``split_state`` / ``merge_state`` / the round
machinery): grow or shrink a live ``ShardedFilterState`` between pow2 shard
counts with NO keystore round-trip and NO rebuild.  This leans on the
partial-key cuckoo identity (Fan et al., via Eppstein's *Simplification and
Analysis*): a resident slot stores (bucket, fingerprint), and since the
candidate pair satisfies ``i + alt(i, fp) ≡ H(fp) (mod n_buckets)``, the
invariant ``min(bucket, alt(bucket, fp))`` + fingerprint identifies the
key's bucket *pair* from either end.  ``hashing.owner_shard_pair`` hashes
exactly that pair identity, so ownership under ANY shard count is
re-derivable from what the table already stores — the property key-hash
routing can never have (the key is gone).  States that want to reshard must
therefore be written with ``route="pair"`` (``core.distributed``).

Because the pair hash is independent of the shard count, owners nest across
pow2 counts: ``owner(2n) mod n == owner(n)``.  A 2x split moves a strict
subset of each shard's entries to its image shard (``s -> s + n``); a merge
folds ``s + n`` back onto ``s``.  Splits therefore never overfill (each
destination bucket receives at most one source bucket's slots); merges can
contend, so received entries run the real pair insert — place / alternate /
bounded eviction chain (kicks preserve the pair invariant) / stash spill.

Migration is the same capacity-bounded ``all_to_all`` idiom as
``distributed_insert``: each round, every shard extracts its foreign-owned
lanes (table slots + stash entries), ranks them with ``conflict_waves``
against the destination, ships ``(fingerprint, bucket)`` pairs — 8 bytes a
key, no keys — clears ONLY the lanes that fit this round at the source, and
pair-inserts what it received.  A host loop streams rounds until no foreign
lanes remain; entries never exist in zero or two places, so a lookup racing
the migration on either mesh misses only keys mid-flight in the current
round — the window the serving layer covers by parking writes in
``DeferredWritePump`` and replaying them after cutover
(``ElasticController``).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import filter as jfilter
from repro.core import hashing
from repro.core.distributed import ShardedFilterState, _shard_map_unchecked
from repro.core.scheduling import conflict_waves
from repro.distributed.sharding import ParallelConfig, make_shardings
from repro.kernels import stash as kstash


# ------------------------------------------------------- mesh elasticity --


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types=`` kwarg for ``jax.make_mesh``, or {} where unsupported.

    ``jax.sharding.AxisType`` only exists on newer jax; the 0.4.x line this
    repo still runs on has neither the enum nor the kwarg, and passing it
    raises ``AttributeError`` before ``make_mesh`` even sees the call.  The
    default axis type there is Auto anyway, so omitting it is equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def largest_mesh(devices: Optional[Sequence] = None, *, model_parallel: int,
                 axis_names=("data", "model")) -> Mesh:
    """Largest (data, model) mesh on the surviving devices.

    Keeps TP fixed (weights must still fit) and gives every remaining
    multiple of ``model_parallel`` devices to data parallelism.
    """
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    data = n // model_parallel
    if data < 1:
        raise RuntimeError(
            f"{n} devices cannot host model_parallel={model_parallel}")
    use = devices[: data * model_parallel]
    return jax.make_mesh((data, model_parallel), axis_names, devices=use,
                         **_axis_type_kwargs(2))


def filter_mesh(n_shards: int, axis_name: str = "data",
                devices: Optional[Sequence] = None) -> Mesh:
    """1-D filter mesh over the first ``n_shards`` devices.

    The elastic controller builds the pre- and post-cutover meshes with
    this so a 2->4 split and its 4->2 inverse agree on device order.
    """
    devices = list(devices if devices is not None else jax.devices())
    if len(devices) < n_shards:
        raise RuntimeError(
            f"{len(devices)} devices cannot host {n_shards} filter shards")
    return Mesh(np.array(devices[:n_shards]), (axis_name,))


def reshard_state(state_tree, specs_tree, new_mesh: Mesh,
                  parallel: ParallelConfig):
    """Re-derive shardings from logical specs on the new mesh and move."""
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state_tree)
    shardings = make_shardings(new_mesh, specs_tree, shapes, parallel)
    return jax.tree.map(jax.device_put, state_tree, shardings)


# ---------------------------------------------------- filter elasticity --


def insert_pairs(table, stash, bucket, fp, valid, *, n_buckets,
                 max_disp: int = 64):
    """Insert migrated (bucket, fingerprint) pairs into one shard's slice.

    The receive side of a migration round: lanes carry a *pair identity*
    (any bucket of the pair — the involution recovers the other), not a
    key, so this runs ``i1 = bucket mod n_buckets``, ``i2 = alt(i1, fp)``
    straight into the sequential insert core the single-node scan path uses
    (place / alternate / bounded eviction with lossless rollback), spilling
    exhausted chains to the shard stash exactly like the routed write path.
    Returns ``(table, stash, ok bool[N])``; invalid lanes never touch
    either structure.
    """
    bucket_size = table.shape[1]
    n = jnp.asarray(n_buckets, jnp.uint32)
    b1 = bucket.astype(jnp.uint32) % n
    b2 = hashing.alt_index_dyn(b1, fp.astype(jnp.uint32), n)

    def step(carry, x):
        table, stash = carry
        f, i1, i2, v = x

        def attempt(_):
            t, ok = jfilter._insert_one(table, f, i1, i2, n_buckets,
                                        max_disp=max_disp,
                                        bucket_size=bucket_size)

            def spill(_):
                s, fits = kstash.stash_spill(
                    stash, f[None], i2[None], jnp.ones((1,), bool))
                return (t, s), fits[0]

            return jax.lax.cond(ok, lambda _: ((t, stash), ok), spill,
                                operand=None)

        return jax.lax.cond(v, attempt,
                            lambda _: ((table, stash), jnp.bool_(False)),
                            operand=None)

    (table, stash), ok = jax.lax.scan(step, (table, stash),
                                      (fp, b1, b2, valid))
    return table, stash, ok


@functools.lru_cache(maxsize=None)
def _migrate_round_fn(mesh: Mesh, axis: str, target_shards: int, cap: int,
                      n_buckets: int, max_disp: int):
    """Build (and cache) one jitted migration round over ``mesh``.

    Each shard: enumerate its lanes (every table slot with its row index,
    every stash entry with its stored bucket — the SAME pair identity),
    compute the pair owner under ``target_shards``, extract foreign lanes,
    rank them per destination with ``conflict_waves``, clear at the source
    ONLY the lanes that fit this round's ``cap`` (streaming — unmoved lanes
    survive for the next round), all_to_all the (fp, bucket) buffers, and
    pair-insert the received lanes.  Returns per-shard
    ``(tables, stashes, moved, remaining, failed)`` where ``remaining``
    counts foreign lanes still resident (the host loop's stop condition)
    and ``failed`` counts received lanes that neither placed nor spilled —
    real capacity loss the caller must surface.
    """
    n_mesh = mesh.shape[axis]

    def shard_fn(tables, stashes):
        table, stash = tables[0], stashes[0]
        me = jax.lax.axis_index(axis).astype(jnp.int32)
        buf, bucket_size = table.shape
        n_table = buf * bucket_size

        t_fp = table.reshape(-1)
        t_bkt = jnp.repeat(
            jnp.arange(buf, dtype=jnp.uint32), bucket_size)
        lane_fp = jnp.concatenate([t_fp, stash[0]])
        lane_bkt = jnp.concatenate([t_bkt, stash[1]])
        occupied = lane_fp != 0
        owner = hashing.owner_shard_pair(
            lane_bkt, lane_fp, n_buckets, target_shards).astype(jnp.int32)
        foreign = occupied & (owner != me)

        rank = conflict_waves(owner, foreign)
        fits = (rank < cap) & foreign
        dst = jnp.where(fits, owner, n_mesh)

        # Clear shipped lanes at the source BEFORE inserting received ones,
        # so a shard that both sends and receives reuses the freed slots.
        new_table = jnp.where(fits[:n_table], jnp.uint32(0),
                              t_fp).reshape(buf, bucket_size)
        s_clear = fits[n_table:]
        new_stash = jnp.stack([jnp.where(s_clear, jnp.uint32(0), stash[0]),
                               jnp.where(s_clear, jnp.uint32(0), stash[1])])

        buf_fp = jnp.zeros((n_mesh, cap), jnp.uint32).at[dst, rank].set(
            lane_fp, mode="drop")
        buf_bkt = jnp.zeros((n_mesh, cap), jnp.uint32).at[dst, rank].set(
            lane_bkt, mode="drop")
        buf_valid = jnp.zeros((n_mesh, cap), jnp.bool_).at[dst, rank].set(
            fits, mode="drop")
        r_fp = jax.lax.all_to_all(buf_fp, axis, 0, 0, tiled=False)
        r_bkt = jax.lax.all_to_all(buf_bkt, axis, 0, 0, tiled=False)
        r_valid = jax.lax.all_to_all(buf_valid, axis, 0, 0, tiled=False)

        new_table, new_stash, ok = insert_pairs(
            new_table, new_stash, r_bkt.reshape(-1), r_fp.reshape(-1),
            r_valid.reshape(-1), n_buckets=n_buckets, max_disp=max_disp)

        moved = jnp.sum(fits, dtype=jnp.int32)
        remaining = jnp.sum(foreign & ~fits, dtype=jnp.int32)
        failed = jnp.sum(r_valid.reshape(-1) & ~ok, dtype=jnp.int32)
        return (new_table[None], new_stash[None], moved[None],
                remaining[None], failed[None])

    mapped = _shard_map_unchecked(
        shard_fn, mesh=mesh, in_specs=(P(axis), P(axis)),
        out_specs=(P(axis),) * 5)
    return jax.jit(mapped)


@dataclasses.dataclass
class MigrationReport:
    """What one split/merge did — the recovery-metrics payload."""
    direction: str          # "split" | "merge"
    old_shards: int
    new_shards: int
    keys_moved: int         # fingerprints shipped shard-to-shard
    rounds: int             # all_to_all rounds until drained
    failed: int             # received lanes lost to full destinations
    seconds: float = 0.0    # migration wall time (filled by split/merge)


def migrate_state(mesh: Mesh, axis: str, state: ShardedFilterState, *,
                  target_shards: int, cap: Optional[int] = None,
                  max_disp: int = 64, max_rounds: int = 64):
    """Stream every mis-owned lane to its pair owner under ``target_shards``.

    The shared engine under ``split_state``/``merge_state``: runs jitted
    migration rounds on ``mesh`` until no shard holds a foreign lane.
    ``cap`` bounds fingerprints per (src, dst) pair per round — the default
    moves everything a shard can hold in one round; tests shrink it to
    exercise multi-round streaming.  Requires per-shard stashes (receivers
    spill contended chains exactly like the routed write path; silently
    dropping them would lose keys).

    Returns ``(new_state, moved, rounds, failed)``.
    """
    assert state.stashes is not None, \
        "elastic migration requires per-shard stashes (spill target)"
    n_buckets = (state.n_buckets if state.n_buckets is not None
                 else state.tables.shape[1])
    bucket_size = state.tables.shape[2]
    stash_slots = state.stashes.shape[2]
    if cap is None:
        cap = n_buckets * bucket_size + stash_slots
    fn = _migrate_round_fn(mesh, axis, target_shards, cap, n_buckets,
                           max_disp)
    tables, stashes = state.tables, state.stashes
    moved_total = rounds = failed_total = 0
    while True:
        tables, stashes, moved, remaining, failed = fn(tables, stashes)
        rounds += 1
        moved_total += int(jnp.sum(moved))
        failed_total += int(jnp.sum(failed))
        if int(jnp.sum(remaining)) == 0:
            break
        if rounds >= max_rounds:
            raise RuntimeError(
                f"migration did not drain in {max_rounds} rounds "
                f"({int(jnp.sum(remaining))} lanes still foreign)")
    new_state = state._replace(tables=tables, stashes=stashes)
    return new_state, moved_total, rounds, failed_total


def split_state(new_mesh: Mesh, axis: str, state: ShardedFilterState, *,
                cap: Optional[int] = None, max_disp: int = 64,
                max_rounds: int = 64
                ) -> tuple[ShardedFilterState, MigrationReport]:
    """Grow a pair-routed state 2x: n shards -> 2n, live, rebuild-free.

    Seeds the new mesh hierarchically — shard ``s < n`` keeps the old shard
    ``s``'s slice, shards ``n..2n-1`` start empty — then migrates on the NEW
    mesh.  The pow2 owner hierarchy (``owner(2n) mod n == owner(n)``) means
    every foreign lane on shard ``s`` is bound for exactly ``s + n``, and a
    destination bucket receives at most one source bucket's slots: splits
    cannot overfill and every received lane places without eviction.
    """
    n_old = state.tables.shape[0]
    n_new = new_mesh.shape[axis]
    assert n_new == 2 * n_old, (n_old, n_new)
    assert n_new & (n_new - 1) == 0, "shard counts must stay pow2"
    t0 = time.perf_counter()
    pad_t = jnp.zeros((n_new - n_old,) + state.tables.shape[1:], jnp.uint32)
    pad_s = jnp.zeros((n_new - n_old,) + state.stashes.shape[1:], jnp.uint32)
    place = jax.sharding.NamedSharding(new_mesh, P(axis))
    seeded = state._replace(
        tables=jax.device_put(jnp.concatenate([state.tables, pad_t]), place),
        stashes=jax.device_put(jnp.concatenate([state.stashes, pad_s]),
                               place))
    new_state, moved, rounds, failed = migrate_state(
        new_mesh, axis, seeded, target_shards=n_new, cap=cap,
        max_disp=max_disp, max_rounds=max_rounds)
    jax.block_until_ready(new_state.tables)
    return new_state, MigrationReport(
        "split", n_old, n_new, moved, rounds, failed,
        time.perf_counter() - t0)


def merge_state(old_mesh: Mesh, axis: str, state: ShardedFilterState, *,
                cap: Optional[int] = None, max_disp: int = 64,
                max_rounds: int = 64
                ) -> tuple[ShardedFilterState, MigrationReport]:
    """Shrink a pair-routed state 2x: n shards -> n/2, live, rebuild-free.

    Migrates on the OLD mesh with the halved owner function — the top half's
    entries all fold onto their image shard ``s - n/2`` — then slices the
    drained top half off.  Receivers are genuinely contended here (two
    shards' entries interleave into one), which is why received lanes run
    the full pair insert with eviction chains and stash spill.
    """
    n_old = state.tables.shape[0]
    assert n_old == old_mesh.shape[axis] and n_old % 2 == 0
    k = n_old // 2
    t0 = time.perf_counter()
    new_state, moved, rounds, failed = migrate_state(
        old_mesh, axis, state, target_shards=k, cap=cap, max_disp=max_disp,
        max_rounds=max_rounds)
    top_tables = int(jnp.sum(new_state.tables[k:] != 0))
    top_stash = int(jnp.sum(new_state.stashes[k:, 0, :] != 0))
    assert top_tables == 0 and top_stash == 0, \
        f"merge left {top_tables}+{top_stash} lanes on drained shards"
    # Host round-trip the sliced halves: the result is uncommitted, so the
    # caller's k-shard mesh (unknown here) can place it without a device
    # conflict — control-plane cost, once per merge.
    merged = new_state._replace(
        tables=jnp.asarray(np.asarray(new_state.tables[:k])),
        stashes=jnp.asarray(np.asarray(new_state.stashes[:k])))
    jax.block_until_ready(merged.tables)
    return merged, MigrationReport(
        "merge", n_old, k, moved, rounds, failed, time.perf_counter() - t0)


# --------------------------------------------------- serving control plane


@dataclasses.dataclass
class ElasticController:
    """Zero-downtime split/merge over a live ``DeferredWritePump``.

    The cutover protocol: (1) hold the pump — fresh submits park instead of
    racing the migration — and freeze write admission; (2) run the
    migration (split on the new mesh / merge on the old); (3) retarget the
    pump at the new (mesh, state) and release; (4) drain the parked backlog
    through the normal resubmission path.  Time-to-recover is hold ->
    backlog-drained, the recovery metric the bench gate enforces.

    ``recovery`` is an ``obs.recovery.RecoveryMetrics`` (optional — without
    one the controller is metrics-silent, matching the repo-wide contract).
    """

    pump: object                               # serving DeferredWritePump
    axis: str = "data"
    recovery: Optional[object] = None
    cap: Optional[int] = None
    max_disp: int = 64
    max_rounds: int = 64
    drain_ticks: int = 100
    clock: Callable[[], float] = time.perf_counter

    def split(self, new_mesh: Mesh) -> MigrationReport:
        return self._resize("split", new_mesh)

    def merge(self, new_mesh: Mesh) -> MigrationReport:
        return self._resize("merge", new_mesh)

    def _resize(self, direction: str, new_mesh: Mesh) -> MigrationReport:
        pump, rec = self.pump, self.recovery
        t0 = self.clock()
        pump.hold()
        admission = getattr(pump, "admission", None)
        if admission is not None and hasattr(admission, "freeze"):
            admission.freeze()
        try:
            with (rec.span(f"elastic_{direction}",
                           new_shards=new_mesh.shape[self.axis])
                  if rec else _NULL_CTX):
                if direction == "split":
                    new_state, report = split_state(
                        new_mesh, self.axis, pump.state, cap=self.cap,
                        max_disp=self.max_disp, max_rounds=self.max_rounds)
                else:
                    # merge migrates on the OLD mesh, then lands on the new.
                    new_state, report = merge_state(
                        pump.mesh, self.axis, pump.state, cap=self.cap,
                        max_disp=self.max_disp, max_rounds=self.max_rounds)
                pump.retarget(new_mesh, self.axis, new_state)
        finally:
            if admission is not None and hasattr(admission, "thaw"):
                admission.thaw()
            pump.release()
        backlog = pump.pending
        pump.run_until_drained(max_ticks=self.drain_ticks)
        seconds = self.clock() - t0
        if rec is not None:
            rec.migration(direction, keys=report.keys_moved,
                          rounds=report.rounds, failed=report.failed,
                          seconds=report.seconds)
            rec.backlog(pump.pending)
            rec.drained(backlog - pump.pending)
            rec.recovered(f"elastic_{direction}", seconds)
        return report


class _Null:
    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_CTX = _Null()
