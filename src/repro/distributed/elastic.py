"""Elastic scaling: rebuild the mesh from the live device set and reshard.

When a pod (or slice) drops out, training continues on the surviving
devices: pick the largest (data × model) grid the survivors support, rebuild
shardings from the *logical* specs (sharding.py), and device_put the
checkpointed state onto the new mesh.  Because every tensor's layout is
derived from logical names rather than hard-coded axes, resharding is a
pure re-evaluation of the rules — no per-arch code.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import ParallelConfig, make_shardings


def largest_mesh(devices: Optional[Sequence] = None, *, model_parallel: int,
                 axis_names=("data", "model")) -> Mesh:
    """Largest (data, model) mesh on the surviving devices.

    Keeps TP fixed (weights must still fit) and gives every remaining
    multiple of ``model_parallel`` devices to data parallelism.
    """
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    data = n // model_parallel
    if data < 1:
        raise RuntimeError(
            f"{n} devices cannot host model_parallel={model_parallel}")
    use = devices[: data * model_parallel]
    return jax.make_mesh((data, model_parallel), axis_names, devices=use,
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def reshard_state(state_tree, specs_tree, new_mesh: Mesh,
                  parallel: ParallelConfig):
    """Re-derive shardings from logical specs on the new mesh and move."""
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state_tree)
    shardings = make_shardings(new_mesh, specs_tree, shapes, parallel)
    return jax.tree.map(jax.device_put, state_tree, shardings)
