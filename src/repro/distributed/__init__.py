from repro.distributed.sharding import (ParallelConfig, batch_pspec,
                                        cache_pspec, make_shardings,
                                        spec_to_pspec)
