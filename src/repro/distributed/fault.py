"""Fault tolerance: injection, degraded serving, and recovery (host control
plane).

On a real multi-pod fleet these hooks wire into the cluster scheduler; in
this repo they are fully functional against simulated failures and drive
the same code paths a production run would:

  * ``FaultInjector`` kills / corrupts / delays a filter shard inside a
    test — the chaos half of the story;
  * ``degraded_lookup`` keeps answering while a shard is down, degrading
    to the cuckoo filter's one safe direction: a key owned by a lost shard
    answers "maybe present" (a conservative positive), NEVER a false
    negative — the same contract routing overflow already has, extended to
    whole-shard loss;
  * ``recover_shard`` re-populates the lost shard from the last durable
    ``checkpoint.ckpt.save_sharded`` snapshot and closes the degraded
    window;
  * ``retry_routed_write`` / ``run_with_restarts`` bound the retry story
    (monotone backoff, exhaustion re-raises);
  * ``StragglerWatchdog`` flags slow steps and feeds the registry gauges
    the elastic controller reads.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
import time
from typing import Callable, Optional

import numpy as np

log = logging.getLogger("repro.fault")


@dataclasses.dataclass
class StragglerWatchdog:
    """Flags steps slower than ``factor`` × trailing-median step time.

    At 1000+ nodes the main throughput killer is one slow host; the watchdog
    feeds the elastic controller (drop/replace the host) or, for data
    stragglers, triggers OCF-level mitigation (shrink that node's filter
    capacity so rebuild bursts shorten — the paper's premature-flush story).

    With a ``metrics`` registry attached, every observation updates
    ``straggler_last_ratio`` / ``straggler_median_s`` gauges and each flag
    increments ``straggler_flagged`` — so a dashboard sees the slow host,
    not just the log line.
    """

    factor: float = 3.0
    history: int = 64
    _times: list = dataclasses.field(default_factory=list)
    flagged: int = 0
    metrics: Optional[object] = None    # repro.obs.MetricsRegistry

    def observe(self, step_seconds: float) -> bool:
        times = sorted(self._times[-self.history:])
        median = times[len(times) // 2] if times else None
        self._times.append(step_seconds)
        if median is not None and self.metrics is not None:
            self.metrics.gauge("straggler_median_s").set(median)
            self.metrics.gauge("straggler_last_ratio").set(
                step_seconds / median if median > 0 else 0.0)
        if median is not None and step_seconds > self.factor * median:
            self.flagged += 1
            if self.metrics is not None:
                self.metrics.counter("straggler_flagged").inc()
            log.warning("straggler: step %.3fs vs median %.3fs",
                        step_seconds, median)
            return True
        return False


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 5
    backoff_s: float = 0.1


def run_with_restarts(make_state: Callable[[Optional[int]], tuple],
                      run_from: Callable, policy: RestartPolicy,
                      *, latest_step_fn: Callable[[], Optional[int]]):
    """Generic restart loop.

    ``make_state(step|None)`` builds/restores training state;
    ``run_from(state)`` runs until completion or raises.  On failure we
    restore from the latest durable checkpoint and continue.  Returns the
    final result of ``run_from``.
    """
    restarts = 0
    while True:
        step = latest_step_fn()
        state = make_state(step)
        try:
            return run_from(state)
        except Exception as e:  # noqa: BLE001 — any node failure
            restarts += 1
            if restarts > policy.max_restarts:
                raise
            log.warning("step failed (%s); restart %d/%d from ckpt %s",
                        e, restarts, policy.max_restarts, latest_step_fn())
            time.sleep(policy.backoff_s * restarts)


def retry_routed_write(attempt: Callable[[], object], policy: RestartPolicy,
                       *, sleep: Callable[[float], None] = time.sleep):
    """Bounded retry-with-backoff around one routed write attempt.

    ``attempt`` is a zero-arg closure over (mesh, state, batch) — typically
    ``lambda: pump.submit(hi, lo)``.  Transient faults (an injected shard
    failure, a collective timeout) retry with monotone backoff
    ``backoff_s * failures``; after ``max_restarts`` failures the last
    exception re-raises — routed writes must never retry forever, the
    deferred-pump queue is the correct parking lot for longer outages.
    """
    failures = 0
    while True:
        try:
            return attempt()
        except Exception:  # noqa: BLE001 — injected faults are plain raises
            failures += 1
            if failures > policy.max_restarts:
                raise
            sleep(policy.backoff_s * failures)


# ----------------------------------------------------- fault injection --


class InjectedFault(RuntimeError):
    """Raised by injector-wrapped callables to simulate a node failure."""


class FaultInjector:
    """Kill / corrupt / delay filter shards inside tests.

    Tracks which shards are *lost* (killed or corrupted and not yet
    healed); ``degraded_lookup`` consults that set to answer the lost
    shards' keys conservatively.  All mutations are host-side on purpose —
    a real failure destroys device state, and modeling it as "the rows are
    garbage/zero and the control plane knows" is exactly what the recovery
    path must handle.
    """

    def __init__(self, recovery=None):
        self.lost: set[int] = set()
        self.recovery = recovery    # optional obs.recovery.RecoveryMetrics

    def _mark(self, kind: str, shard: int):
        self.lost.add(int(shard))
        if self.recovery is not None:
            self.recovery.fault(kind, int(shard))

    def kill(self, state, shard: int):
        """Zero one shard's table+stash rows (node gone, memory gone)."""
        tables = np.asarray(state.tables).copy()
        tables[shard] = 0
        stashes = None
        if state.stashes is not None:
            stashes = np.asarray(state.stashes).copy()
            stashes[shard] = 0
        self._mark("kill", shard)
        return state._replace(tables=tables, stashes=stashes)

    def corrupt(self, state, shard: int, seed: int = 0):
        """Scramble one shard's rows (bit flips — worse than death: the
        shard still answers, wrongly, until the control plane notices)."""
        rng = np.random.default_rng(seed)
        tables = np.asarray(state.tables).copy()
        tables[shard] = rng.integers(0, 2**32, tables[shard].shape,
                                     dtype=np.uint32)
        stashes = None
        if state.stashes is not None:
            stashes = np.asarray(state.stashes).copy()
        self._mark("corrupt", shard)
        return state._replace(tables=tables, stashes=stashes)

    def delay(self, fn: Callable, seconds: float) -> Callable:
        """Wrap ``fn`` with a fixed sleep — the straggler injector."""
        @functools.wraps(fn)
        def slow(*a, **kw):
            time.sleep(seconds)
            return fn(*a, **kw)
        return slow

    def failing(self, fn: Callable, times: int) -> Callable:
        """Wrap ``fn`` to raise ``InjectedFault`` on its first ``times``
        calls, then pass through — the retry-loop test double."""
        remaining = [times]

        @functools.wraps(fn)
        def flaky(*a, **kw):
            if remaining[0] > 0:
                remaining[0] -= 1
                raise InjectedFault(
                    f"injected failure ({remaining[0]} more)")
            return fn(*a, **kw)
        return flaky

    def heal(self, shard: int):
        self.lost.discard(int(shard))


# ------------------------------------------------- degraded-mode serving --


def degraded_lookup(mesh, axis: str, state, hi, lo, *, fp_bits: int,
                    injector: FaultInjector, route: str = "key",
                    capacity_factor: float = 2.0, backend: str = "auto",
                    recovery=None):
    """``distributed_lookup`` that survives lost shards.

    Runs the normal routed probe, then overrides every lane whose OWNER
    shard is in the injector's lost set to True — "maybe present".  That
    is the only safe degradation a membership filter has: the lost shard's
    keys cannot be disproven, so claiming absence would be a false
    negative (the one error class the filter contract forbids), while a
    conservative positive merely costs the caller a backing-store read.
    Surviving shards' answers are untouched — bit-identical to the
    healthy path.

    Returns ``(hits, overflow, degraded bool[N])`` where ``degraded``
    marks the conservative answers; ``recovery.degraded`` counts them so
    the degraded window is visible in the exported metrics.
    """
    from repro.core import hashing
    from repro.core.distributed import distributed_lookup
    hits, overflow = distributed_lookup(
        mesh, axis, state, hi, lo, fp_bits=fp_bits,
        capacity_factor=capacity_factor, backend=backend, route=route)
    n_shards = mesh.shape[axis]
    hi_np = np.asarray(hi, np.uint32)
    lo_np = np.asarray(lo, np.uint32)
    if route == "pair":
        nb = (state.n_buckets if state.n_buckets is not None
              else state.tables.shape[1])
        owner = hashing.owner_shard_key_pair_np(hi_np, lo_np, nb, fp_bits,
                                                n_shards)
    else:
        owner = hashing.owner_shard_np(hi_np, lo_np, n_shards)
    degraded = np.isin(owner, np.fromiter(injector.lost, np.uint32,
                                          len(injector.lost)))
    out = np.asarray(hits) | degraded
    if recovery is not None:
        recovery.degraded(int(degraded.sum()))
    return out, overflow, degraded


def recover_shard(state, shard: int, *, ckpt_dir: str,
                  step: Optional[int] = None,
                  injector: Optional[FaultInjector] = None, recovery=None):
    """Re-populate one lost shard from the last durable snapshot.

    Restores ``save_sharded``'s host-backed copy, grafts the lost shard's
    table+stash rows into the live state (surviving shards keep their
    CURRENT rows — writes since the snapshot must not roll back), heals
    the injector, and reports time-to-recover.  Keys the lost shard
    accepted after the snapshot are gone — their degraded window ends with
    a re-insert from the keystore/WAL upstream, which is out of filter
    scope; everything up to the snapshot answers exactly again.
    """
    from repro.checkpoint.ckpt import restore_sharded
    t0 = time.perf_counter()
    ctx = (recovery.span("recover_shard", shard=int(shard))
           if recovery is not None else _NULL)
    with ctx:
        snap = restore_sharded(ckpt_dir, step)
        tables = np.asarray(state.tables).copy()
        tables[shard] = np.asarray(snap.tables)[shard]
        stashes = None
        if state.stashes is not None:
            stashes = np.asarray(state.stashes).copy()
            if snap.stashes is not None:
                stashes[shard] = np.asarray(snap.stashes)[shard]
            else:
                stashes[shard] = 0
        if injector is not None:
            injector.heal(shard)
    new_state = state._replace(tables=tables, stashes=stashes)
    if recovery is not None:
        recovery.recovered("shard_restore", time.perf_counter() - t0)
    return new_state


class _NullCtx:
    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL = _NullCtx()
