"""Fault tolerance & straggler mitigation (host-side control plane).

On a real multi-pod fleet these hooks wire into the cluster scheduler; in
this repo they are fully functional against simulated failures (tests inject
exceptions / slow steps) and drive the same code paths a production run
would: checkpoint-restart, straggler detection, and bounded retry.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

log = logging.getLogger("repro.fault")


@dataclasses.dataclass
class StragglerWatchdog:
    """Flags steps slower than ``factor`` × trailing-median step time.

    At 1000+ nodes the main throughput killer is one slow host; the watchdog
    feeds the elastic controller (drop/replace the host) or, for data
    stragglers, triggers OCF-level mitigation (shrink that node's filter
    capacity so rebuild bursts shorten — the paper's premature-flush story).
    """

    factor: float = 3.0
    history: int = 64
    _times: list = dataclasses.field(default_factory=list)
    flagged: int = 0

    def observe(self, step_seconds: float) -> bool:
        times = sorted(self._times[-self.history:])
        median = times[len(times) // 2] if times else None
        self._times.append(step_seconds)
        if median is not None and step_seconds > self.factor * median:
            self.flagged += 1
            log.warning("straggler: step %.3fs vs median %.3fs",
                        step_seconds, median)
            return True
        return False


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 5
    backoff_s: float = 0.1


def run_with_restarts(make_state: Callable[[Optional[int]], tuple],
                      run_from: Callable, policy: RestartPolicy,
                      *, latest_step_fn: Callable[[], Optional[int]]):
    """Generic restart loop.

    ``make_state(step|None)`` builds/restores training state;
    ``run_from(state)`` runs until completion or raises.  On failure we
    restore from the latest durable checkpoint and continue.  Returns the
    final result of ``run_from``.
    """
    restarts = 0
    while True:
        step = latest_step_fn()
        state = make_state(step)
        try:
            return run_from(state)
        except Exception as e:  # noqa: BLE001 — any node failure
            restarts += 1
            if restarts > policy.max_restarts:
                raise
            log.warning("step failed (%s); restart %d/%d from ckpt %s",
                        e, restarts, policy.max_restarts, latest_step_fn())
            time.sleep(policy.backoff_s * restarts)
