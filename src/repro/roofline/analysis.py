"""Roofline-term derivation from a compiled dry-run artifact.

Hardware model (TPU v5e, per chip):
  peak bf16 compute  197e12 FLOP/s
  HBM bandwidth      819e9  B/s
  ICI link bandwidth 50e9   B/s

Terms (EXPERIMENTS.md §Roofline):
  T_compute    = total_HLO_FLOPs    / (chips × peak)
  T_memory     = total_HLO_bytes    / (chips × hbm_bw)
  T_collective = wire_bytes_per_dev / link_bw          (per-chip wire bytes)

``cost_analysis()`` on a GSPMD-partitioned executable reports the
*per-partition* module, so totals are (per-device value × chips); the
collective term uses per-device wire bytes directly.  Wire bytes model the
actual ring traffic: all-gather ≈ out bytes, all-reduce ≈ 2× in bytes,
reduce-scatter ≈ in bytes, all-to-all / collective-permute ≈ in bytes
(raw operand bytes are also recorded).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
# the pod axis crosses DCN, not ICI — collectives with replica groups that
# span pods are charged at DCN bandwidth
DCN_BW = 25e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# op line: %name = <out-type> op-name(<operands>)
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[\w\[\],{}\s]*?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: int = 0        # modeled ring-traffic bytes per device
    operand_bytes: int = 0     # raw input-operand bytes
    by_op: dict = dataclasses.field(default_factory=dict)
    count: int = 0
    flops: float = 0.0         # loop-weighted dot FLOPs (per device)
    hbm_bytes: float = 0.0     # loop-weighted op-output bytes (per device)


_COMP_HDR = re.compile(r"^(ENTRY\s+)?(%?[\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=([%\w\.\-]+),\s*body=([%\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str):
    """-> (comps: name -> list[str] lines, entry_name)."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur: Optional[str] = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR.match(line.strip()) if "{" in line else None
        if m and ("->" in line):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps, entry


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIT_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def _line_collective(line: str):
    m = _OP_RE.search(line)
    if m is None or "-done(" in line:
        return None
    out_type, op = m.group(1), m.group(2)
    out_b = _shape_bytes(out_type)
    # operand types are usually elided in optimized HLO; derive wire bytes
    # from the (always present) output type + the op's ring semantics.
    if op == "all-gather":
        wire = out_b                      # receive (N-1)/N of the output
        in_b = out_b // max(1, _group_size(line))
    elif op == "all-reduce":
        wire = 2 * out_b                  # reduce-scatter + all-gather ring
        in_b = out_b
    elif op == "reduce-scatter":
        g = _group_size(line)
        wire = out_b * max(1, g - 1)      # input ~= out*g, moves (g-1)/g
        in_b = out_b * g
    else:  # all-to-all, collective-permute: out == in, moves ~all of it
        wire = out_b
        in_b = out_b
    return op, wire, in_b


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*([^=]+?)\s+"
                     r"([\w\-]+)\(")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_RHS_CONTRACT_RE = re.compile(r"rhs_contracting_dims=\{([0-9,]*)\}")
_FIRST_ARG_RE = re.compile(r"\(\s*(%[\w\.\-]+)")
_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "iota"}


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return dims


def _comp_local_stats(lines):
    """(flops, bytes, symtab) for one computation, loops excluded."""
    sym: dict[str, str] = {}
    flops = 0.0
    byts = 0.0
    for ln in lines:
        dm = _DEF_RE.match(ln)
        if dm is None:
            continue
        name, out_type, op = dm.group(1), dm.group(2), dm.group(3)
        sym[name] = out_type
        # dynamic-update-slice writes only its update in place; counting the
        # full aliased buffer would charge a scan's stacked-ys buffer once
        # per iteration (94x47GiB of phantom traffic on qwen3).  The update
        # tensor's producer is already counted, so charge DUS zero.
        is_dus = (op == "dynamic-update-slice"
                  or name.startswith("%dynamic-update-slice"))
        if op not in _SKIP_BYTES_OPS and op != "while" and not is_dus:
            byts += _shape_bytes(out_type)
        if op == "dot":
            out_dims = _shape_dims(out_type) or []
            cm = _CONTRACT_RE.search(ln)
            # dm.end() sits just past "dot(" — the lhs name follows directly
            am = re.match(r"\s*(%[\w\.\-]+)", ln[dm.end():])
            k = 1
            if cm and am and am.group(1) in sym:
                lhs_dims = _shape_dims(sym[am.group(1)]) or []
                for ci in (int(c) for c in cm.group(1).split(",") if c):
                    if ci < len(lhs_dims):
                        k *= lhs_dims[ci]
            n_out = 1
            for d in out_dims:
                n_out *= d
            flops += 2.0 * n_out * k
    return flops, byts, sym


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Dynamic-execution-weighted collective bytes.

    Splits the module into computations, multiplies while-loop bodies by the
    loop trip count (max s32 constant in the loop condition — the pattern
    XLA emits for lax.scan), and accumulates from the entry computation.
    """
    comps, entry = _split_computations(hlo_text)

    def trip_count(cond_name: str) -> int:
        lines = comps.get(cond_name.lstrip("%"), comps.get(cond_name, []))
        best = 1
        for ln in lines:
            for c in _CONST_RE.findall(ln):
                best = max(best, int(c))
        return best

    memo: dict[str, CollectiveStats] = {}

    def walk(name: str, depth=0) -> CollectiveStats:
        key = name.lstrip("%")
        if key in memo:
            return memo[key]
        st = CollectiveStats()
        memo[key] = st  # break cycles defensively
        lines = comps.get(key, comps.get(name, []))
        st.flops, st.hbm_bytes, _sym = _comp_local_stats(lines)
        for ln in lines:
            c = _line_collective(ln)
            if c is not None:
                op, wire, in_b = c
                st.wire_bytes += wire
                st.operand_bytes += in_b
                d = st.by_op.setdefault(op, {"count": 0, "wire_bytes": 0})
                d["count"] += 1
                d["wire_bytes"] += wire
                st.count += 1
            wm = _WHILE_RE.search(ln)
            if wm is not None and depth < 8:
                n = trip_count(wm.group(1))
                sub = walk(wm.group(2), depth + 1)
                st.wire_bytes += n * sub.wire_bytes
                st.operand_bytes += n * sub.operand_bytes
                st.count += n * sub.count
                st.flops += n * sub.flops
                st.hbm_bytes += n * sub.hbm_bytes
                for op, d in sub.by_op.items():
                    o = st.by_op.setdefault(op, {"count": 0, "wire_bytes": 0})
                    o["count"] += n * d["count"]
                    o["wire_bytes"] += n * d["wire_bytes"]
            cm = re.search(r"conditional\(.*branch_computations=\{([^}]*)\}",
                           ln)
            if cm is not None and depth < 8:
                for br in cm.group(1).split(","):
                    sub = walk(br.strip(), depth + 1)
                    st.wire_bytes += sub.wire_bytes
                    st.operand_bytes += sub.operand_bytes
                    st.flops += sub.flops
                    st.hbm_bytes += sub.hbm_bytes
        memo[key] = st
        return st

    if entry is None:
        # fallback: flat scan (no loop weighting)
        st = CollectiveStats()
        for ln in hlo_text.splitlines():
            c = _line_collective(ln)
            if c:
                op, wire, in_b = c
                st.wire_bytes += wire
                st.operand_bytes += in_b
                st.count += 1
                d = st.by_op.setdefault(op, {"count": 0, "wire_bytes": 0})
                d["count"] += 1
                d["wire_bytes"] += wire
        return st
    return walk(entry)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_dev: float
    bytes_per_dev: float
    wire_bytes_per_dev: float
    model_flops: float            # 6·N_active·D tokens-based
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0
    peak_flops: float = PEAK_FLOPS
    collectives: Optional[dict] = None
    memory_stats: Optional[dict] = None

    def finalize(self) -> "Roofline":
        total_flops = self.flops_per_dev * self.chips
        total_bytes = self.bytes_per_dev * self.chips
        self.t_compute = total_flops / (self.chips * PEAK_FLOPS)
        self.t_memory = total_bytes / (self.chips * HBM_BW)
        self.t_collective = self.wire_bytes_per_dev / ICI_BW
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        self.useful_ratio = (self.model_flops / total_flops
                             if total_flops else 0.0)
        return self

    @property
    def step_time_est(self) -> float:
        """No-overlap upper bound; with perfect overlap it's the max term."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline-limited step time."""
        t = self.step_time_est
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["step_time_est"] = self.step_time_est
        d["mfu"] = self.mfu
        return d


def model_flops_for(cfg, shape_name: str, n_active: int) -> float:
    """6·N·D for train (fwd+bwd), 2·N·D for inference, per step."""
    from repro.configs.registry import SHAPES
    seq, gbatch, mode = SHAPES[shape_name]
    if mode == "train":
        tokens = seq * gbatch
        return 6.0 * n_active * tokens
    if mode == "prefill":
        tokens = seq * gbatch
        return 2.0 * n_active * tokens
    tokens = gbatch  # decode: one token per sequence
    return 2.0 * n_active * tokens
