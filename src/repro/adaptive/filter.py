"""AdaptiveFilter — host control plane over the four-plane adaptive state.

The device data plane (``core.filter_ops.FilterOps``'s ``*_adaptive`` entry
points over ``AdaptiveState``) speaks (hi, lo) uint32 pairs and jax arrays;
this wrapper speaks uint64 key batches and owns the state + overflow stash,
the way ``streaming.generations.GenerationalFilter`` wraps the generation
ring.  The one genuinely new verb is ``report_false_positives``: the
feedback edge that makes the filter *learn* — a confirmed false positive
(the caller checked ground truth and the key is NOT a member) repairs every
colliding slot by bumping its 2-bit selector and rewriting the stored
fingerprint from the mirrored resident key.  Entries never move, so
repairs can never manufacture a false negative; repeat offenders that the
selector family cannot separate are the reputation tier's job
(``adaptive.reputation``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.adaptive.state import AdaptiveState, make_adaptive_state
from repro.core.filter_ops import Backend, FilterOps
from repro.kernels import ops as kops


def split_keys(keys) -> tuple[jnp.ndarray, jnp.ndarray]:
    """uint64 key batch -> (hi, lo) uint32 device pair."""
    k = np.asarray(keys, dtype=np.uint64)
    hi = (k >> np.uint64(32)).astype(np.uint32)
    lo = (k & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return jnp.asarray(hi), jnp.asarray(lo)


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Sizing + dispatch knobs for one adaptive filter."""

    n_buckets: int
    bucket_size: int = 4
    fp_bits: int = 16
    stash_slots: int = kops.DEFAULT_STASH_SLOTS
    backend: Backend = "auto"
    donate: bool = True

    def __post_init__(self):
        assert self.n_buckets > 0 and self.bucket_size in (1, 2, 4, 8, 16)

    def make_filter_ops(self) -> FilterOps:
        return FilterOps(fp_bits=self.fp_bits, backend=self.backend,
                         donate=self.donate)


class AdaptiveFilter:
    """Uint64-key facade over the adaptive data plane.

    Duck-compatible with ``GenerationalFilter`` where the admission layer
    cares (``fills()``), so ``streaming.admission.AdmissionController`` can
    gate report floods against THIS filter's congestion signal unchanged.
    """

    def __init__(self, config: AdaptiveConfig,
                 ops: Optional[FilterOps] = None):
        self.config = config
        self.ops = ops or config.make_filter_ops()
        self.state: AdaptiveState = make_adaptive_state(
            config.n_buckets, config.bucket_size)
        self.stash = kops.make_stash(config.stash_slots)
        self.reports = 0
        self.adapted = 0

    # -- occupancy ------------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.config.n_buckets * self.config.bucket_size

    def fills(self) -> tuple[float, float]:
        """(table fill, stash fill) — one host transfer each."""
        fill = float(self.state.count) / self.capacity
        stash_fill = (float(kops.stash_occupancy(self.stash))
                      / self.config.stash_slots)
        return fill, stash_fill

    # -- data-plane verbs ----------------------------------------------

    def insert(self, keys) -> np.ndarray:
        hi, lo = split_keys(keys)
        self.state, self.stash, ok = self.ops.insert_adaptive(
            self.state, hi, lo, stash=self.stash)
        return np.asarray(ok)

    def lookup(self, keys) -> np.ndarray:
        hi, lo = split_keys(keys)
        return np.asarray(self.ops.lookup_adaptive(self.state, hi, lo,
                                                   stash=self.stash))

    def delete(self, keys) -> np.ndarray:
        hi, lo = split_keys(keys)
        self.state, self.stash, ok = self.ops.delete_adaptive(
            self.state, hi, lo, stash=self.stash)
        return np.asarray(ok)

    def report_false_positives(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """Feed confirmed false positives back -> (adapted[N], resident[N]).

        Callers MUST have verified the keys against ground truth: a report
        whose key IS a member is refused slot-by-slot (``resident`` lanes),
        never repaired into a false negative.  ``adapted`` lanes had at
        least one colliding slot rewritten; a reported key that matches
        only the stash adapts nothing (no selector there) and returns
        False on both — the reputation tier promotes those.
        """
        hi, lo = split_keys(keys)
        self.state, adapted, resident = self.ops.report_false_positive(
            self.state, hi, lo)
        adapted = np.asarray(adapted)
        self.reports += int(adapted.shape[0])
        self.adapted += int(adapted.sum())
        return adapted, np.asarray(resident)
