"""Device state of the adaptive (false-positive-learning) filter.

Four planes beside each other, all in one preallocated pow2 buffer (the
``core.filter.FilterState`` discipline — OCF-style resizes change no array
shapes):

  * ``table`` — the fingerprint plane, identical layout to the static
    filter: ``uint32[buffer_buckets, bucket_size]``, 0 == EMPTY.  A slot
    stores ``fingerprint_sel(resident, sel[slot])`` — the SELECTED family
    member, not necessarily the selector-0 fingerprint.
  * ``sels`` — the packed per-slot hash-selector plane
    (``kernels.selector``): ``uint32[buffer_buckets, 1]``, 2 bits per slot.
    All-zero == every slot on the static fingerprint, which makes a fresh
    adaptive filter bit-identical to a fresh static one.
  * ``khi`` / ``klo`` — mirror key planes (the adaptive-cuckoo-filter
    "remote representation"): the resident's uint32 key pair, needed to
    rehash a slot on repair and to re-derive selector-0 geometry when an
    eviction chain kicks it.

Memory: +9 bytes/slot over the static filter's 4 (8 for the mirrored key,
0.25 packed selector) — the price of repairability; the reputation tier
(``adaptive.reputation``) is deliberately NOT part of this state, it is a
tiny host-side exact structure.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.selector import make_key_planes, make_sel_plane


class AdaptiveState(NamedTuple):
    table: jax.Array      # uint32[buffer_buckets, bucket_size]; 0 == EMPTY
    sels: jax.Array       # uint32[buffer_buckets, 1] packed 2-bit selectors
    khi: jax.Array        # uint32[buffer_buckets, bucket_size] mirror key hi
    klo: jax.Array        # uint32[buffer_buckets, bucket_size] mirror key lo
    count: jax.Array      # int32[] live fingerprints (table-resident)
    n_buckets: jax.Array  # int32[] ACTIVE bucket count (<= buffer_buckets)


def make_adaptive_state(n_buckets: int, bucket_size: int = 4,
                        buffer_buckets: Optional[int] = None
                        ) -> AdaptiveState:
    buf = buffer_buckets or n_buckets
    assert buf >= n_buckets
    khi, klo = make_key_planes(buf, bucket_size)
    return AdaptiveState(
        table=jnp.zeros((buf, bucket_size), dtype=jnp.uint32),
        sels=make_sel_plane(buf),
        khi=khi, klo=klo,
        count=jnp.zeros((), dtype=jnp.int32),
        n_buckets=jnp.asarray(n_buckets, jnp.int32))
