"""Adaptive filtering: false-positive feedback, per-slot hash selectors,
and reputation-weighted admission.

Three tiers, cheapest first:

  1. ``AdaptiveState`` + the selector-aware kernels — per-slot 2-bit hash
     selectors let a confirmed false positive be *repaired in place* (the
     colliding slot's fingerprint is rewritten under the next member of a
     4-hash family; the entry never moves, so no false negative is ever
     introduced).
  2. ``ReputationManager`` — repeat offenders the selector family cannot
     separate are promoted to a tiny exact-negative side table.
  3. ``AdmissionController`` hysteresis (shared with the streaming
     scheduler) gates cold report floods off the device path.
"""
from repro.adaptive.filter import (AdaptiveConfig, AdaptiveFilter,
                                   split_keys)
from repro.adaptive.reputation import (AdaptiveMembership, ReputationConfig,
                                       ReputationManager)
from repro.adaptive.state import AdaptiveState, make_adaptive_state

__all__ = ["AdaptiveConfig", "AdaptiveFilter", "AdaptiveMembership",
           "AdaptiveState", "ReputationConfig", "ReputationManager",
           "make_adaptive_state", "split_keys"]
