"""Reputation-weighted admission for false-positive feedback.

The selector family repairs a colliding slot with success probability
1 - 2^-fp_bits per bump — but two populations escape it:

  * **persistent offenders** — keys whose reports keep landing (stash-
    resident collisions have no selector to bump; a slot that has cycled
    all four family members can re-collide).  Counting reports per key and
    promoting repeat offenders into a tiny EXACT side table turns them
    into guaranteed negatives forever — O(promoted) host memory for the
    heavy tail of the false-positive distribution.
  * **cold floods** — an adversary spraying *novel* "false positive"
    reports (each key reported once, never seen again).  Every report
    costs a sequential device adaptation pass, and a flood of fabricated
    ones could thrash selectors on slots that mostly answer honest
    queries.  Reports are therefore admission-controlled with the SAME
    hysteresis controller the streaming scheduler uses
    (``streaming.admission.AdmissionController`` — the filter's own
    congestion signal): while tripped, only keys with prior reputation
    (seen before) reach the device; cold first-time reports are counted
    host-side and deferred, so a flood degrades to a cheap hash-map
    increment.

``AdaptiveMembership`` composes the three tiers — adaptive filter,
reputation counts, exact side table — into one lookup/insert/report facade
(the shape ``examples/adaptive_abuse_detection.py`` drives).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.adaptive.filter import AdaptiveConfig, AdaptiveFilter
from repro.streaming.admission import AdmissionConfig, AdmissionController


@dataclasses.dataclass(frozen=True)
class ReputationConfig:
    promote_after: int = 2    # reports on the same key before promotion
    side_table_max: int = 4096  # exact-negative capacity (host memory)


class ReputationManager:
    """Per-key false-positive report counts + the exact-negative side table.

    The side table is a promoted set of uint64 keys known (by caller-
    verified ground truth) to be non-members that the probabilistic tiers
    keep answering True for.  Membership checks are vectorized via
    ``np.isin`` against a sorted array snapshot, rebuilt lazily on
    promotion — promotions are rare control-plane events, lookups are the
    hot path.
    """

    def __init__(self, config: ReputationConfig | None = None):
        self.config = config or ReputationConfig()
        self.counts: dict[int, int] = {}
        self._promoted: set[int] = set()
        self._sorted: np.ndarray = np.empty((0,), dtype=np.uint64)
        self._dirty = False

    @property
    def promoted(self) -> int:
        return len(self._promoted)

    def seen(self, keys) -> np.ndarray:
        """Which keys have ANY prior reputation (>= 1 past report)?"""
        return np.array([int(k) in self.counts or int(k) in self._promoted
                         for k in np.asarray(keys, dtype=np.uint64)],
                        dtype=bool)

    def observe(self, keys) -> np.ndarray:
        """Count one report per key -> promoted-now bool[N].

        A key reaching ``promote_after`` total reports moves from the
        count map to the exact side table (and stops being counted).
        Promotion saturates at ``side_table_max`` — beyond it the heavy
        tail keeps adapting probabilistically instead of growing host
        memory without bound.
        """
        out = np.zeros(len(np.asarray(keys)), dtype=bool)
        for j, k in enumerate(np.asarray(keys, dtype=np.uint64)):
            k = int(k)
            if k in self._promoted:
                continue
            c = self.counts.get(k, 0) + 1
            if (c >= self.config.promote_after
                    and len(self._promoted) < self.config.side_table_max):
                self._promoted.add(k)
                self.counts.pop(k, None)
                self._dirty = True
                out[j] = True
            else:
                self.counts[k] = c
        return out

    def denied(self, keys) -> np.ndarray:
        """Exact side-table membership -> bool[N] (True == known negative)."""
        if self._dirty:
            self._sorted = np.fromiter(self._promoted, dtype=np.uint64,
                                       count=len(self._promoted))
            self._sorted.sort()
            self._dirty = False
        if self._sorted.size == 0:
            return np.zeros(len(np.asarray(keys)), dtype=bool)
        return np.isin(np.asarray(keys, dtype=np.uint64), self._sorted)


class AdaptiveMembership:
    """Three-tier learned membership: adaptive filter -> side table.

    ``lookup`` answers filter-hit AND NOT known-negative; ``report`` feeds
    verified false positives through the reputation-weighted admission
    path.  Guarantees: zero false negatives (both subtractive tiers only
    remove caller-verified non-members), and every *confirmed* report
    eventually stops hitting — immediately when a selector bump lands,
    after ``promote_after`` reports via the exact tier otherwise.
    """

    def __init__(self, config: AdaptiveConfig,
                 reputation: ReputationConfig | None = None,
                 admission: AdmissionConfig | None = None,
                 filt: Optional[AdaptiveFilter] = None):
        self.filt = filt or AdaptiveFilter(config)
        self.reputation = ReputationManager(reputation)
        # The controller reads THIS filter's congestion via the
        # GenerationalFilter-shaped fills() duck.
        self.admission = AdmissionController(
            filt=self.filt, config=admission or AdmissionConfig())
        self.deferred_reports = 0

    def insert(self, keys) -> np.ndarray:
        return self.filt.insert(keys)

    def delete(self, keys) -> np.ndarray:
        return self.filt.delete(keys)

    def lookup(self, keys) -> np.ndarray:
        hit = self.filt.lookup(keys)
        denied = self.reputation.denied(keys)
        return hit & ~denied

    def report(self, keys) -> np.ndarray:
        """Verified-false-positive feedback -> device-adapted bool[N].

        Hysteresis gate: while the filter's congestion signal is tripped,
        only keys with prior reputation reach the device adaptation pass;
        cold first-time reports are deferred (counted, so a repeat DOES
        carry reputation next time).  All admitted reports also feed the
        reputation counts, promoting repeat offenders to the exact tier.
        """
        keys = np.asarray(keys, dtype=np.uint64)
        if keys.size == 0:
            return np.zeros((0,), dtype=bool)
        if self.admission.peek():
            device = np.ones(keys.shape, dtype=bool)
        else:
            device = self.reputation.seen(keys)
            self.deferred_reports += int((~device).sum())
        self.reputation.observe(keys)
        adapted = np.zeros(keys.shape, dtype=bool)
        if device.any():
            adapted[device], _ = self.filt.report_false_positives(
                keys[device])
        return adapted
