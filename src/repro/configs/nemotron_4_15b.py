"""nemotron-4-15b — dense GQA with squared-ReLU MLP. [arXiv:2402.16819; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    activation="squared_relu",
    pattern=("global",),
    rope_theta=10000.0,
    tie_embeddings=False,
    max_seq_len=4096,
)

SMOKE_CONFIG = ModelConfig(
    name="nemotron-4-15b-smoke",
    family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, activation="squared_relu", pattern=("global",),
    tie_embeddings=False, max_seq_len=128,
)
