"""llava-next-mistral-7b — Mistral-7B backbone + anyres vision stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
Backbone only (assignment): the CLIP tower is a stub; ``input_specs`` feeds
precomputed anyres patch embeddings (5 tiles × 576 patches = 2880 tokens).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    activation="silu_glu",
    pattern=("global",),
    rope_theta=1e6,
    prefix_embed_len=2880,       # anyres: 5 tiles x 24x24 patches
    max_seq_len=32768,
)

SMOKE_CONFIG = ModelConfig(
    name="llava-next-mistral-7b-smoke",
    family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, activation="silu_glu", pattern=("global",),
    prefix_embed_len=8, max_seq_len=128,
)
