"""gemma3-1b — 5:1 local:global sliding-window attention, 262k vocab.

[hf:google/gemma-3-1b-pt; unverified]
26 layers = 4 scanned (5 local + 1 global) periods + a 2-layer local tail
(config.tail — keeps the traced HLO at 8 blocks, not 26).  Single rope theta
(1M) is used for both local and global layers — a documented simplification.
"""
from repro.models.config import ModelConfig

_PATTERN = ("local",) * 5 + ("global",)

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    activation="gelu_glu",
    pattern=_PATTERN,
    window=512,
    rope_theta=1e6,
    use_qk_norm=True,
    use_post_norm=True,
    embed_scale=True,
    max_seq_len=131072,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma3-1b-smoke",
    family="dense",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=256, activation="gelu_glu",
    pattern=("local",) * 5 + ("global",), window=16,
    use_qk_norm=True, use_post_norm=True, embed_scale=True, max_seq_len=128,
)
