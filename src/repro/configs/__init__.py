from repro.configs.registry import (ARCHS, LONG_CONTEXT_ARCHS, SHAPES, cells,
                                    canonical, get_config, get_smoke_config)
