"""mamba2-1.3b — attention-free SSD (state-space duality). [arXiv:2405.21060; unverified]

Vocab padded 50280 -> 50432 (x16 TP divisibility; DESIGN.md §5).  Constant-
size recurrent state => runs the long_500k shape.
"""
from repro.models.config import ModelConfig, SsmConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,                         # mamba2 blocks have no MLP
    vocab_size=50432,               # padded from 50280
    pattern=("ssm",),
    ssm=SsmConfig(d_state=128, head_dim=64, n_groups=1, d_conv=4, expand=2,
                  chunk=256),
    tie_embeddings=True,
    max_seq_len=1048576,
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-1.3b-smoke",
    family="ssm",
    n_layers=2, d_model=64, n_heads=1, n_kv_heads=1, head_dim=16, d_ff=0,
    vocab_size=256, pattern=("ssm",),
    ssm=SsmConfig(d_state=16, head_dim=16, n_groups=1, d_conv=4, expand=2,
                  chunk=32),
    max_seq_len=256,
)
