"""deepseek-v2-lite-16b — MLA (kv_lora 512) + MoE 64 routed top-6, 2 shared.

[arXiv:2405.04434; hf]  Layer 0 is a dense MLP (first_k_dense=1); the MLA
cache stores the 576-wide latent per token instead of full K/V.
"""
from repro.models.config import MlaConfig, ModelConfig, MoeConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,                     # dense prefix layer width
    vocab_size=102400,
    activation="silu_glu",
    pattern=("global",),
    rope_theta=10000.0,
    moe=MoeConfig(n_experts=64, top_k=6, expert_d_ff=1408,
                  n_shared_experts=2, shared_d_ff=1408,
                  norm_topk=True, first_k_dense=1),
    mla=MlaConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                  v_head_dim=128),
    max_seq_len=32768,
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b-smoke",
    family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, activation="silu_glu", pattern=("global",),
    moe=MoeConfig(n_experts=8, top_k=2, expert_d_ff=32, n_shared_experts=1,
                  shared_d_ff=32, norm_topk=True, capacity_factor=8.0, first_k_dense=1),
    mla=MlaConfig(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                  v_head_dim=16),
    max_seq_len=128,
)
