"""recurrentgemma-2b — Griffin: RG-LRU + local attention, 2:1. [arXiv:2402.19427; hf]

26 layers = 8 scanned (rglru, rglru, local) periods + a 2-layer rglru tail
(config.tail).  Bounded state (RG-LRU h + 2048-window KV) => runs long_500k.
"""
from repro.models.config import ModelConfig, RglruConfig

_PATTERN = ("rglru", "rglru", "local")

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    activation="gelu_glu",
    pattern=_PATTERN,
    window=2048,
    rope_theta=10000.0,
    embed_scale=True,
    rglru=RglruConfig(d_rnn=2560, d_conv=4),
    max_seq_len=1048576,
)

SMOKE_CONFIG = ModelConfig(
    name="recurrentgemma-2b-smoke",
    family="hybrid",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128,
    vocab_size=256, activation="gelu_glu",
    pattern=("rglru", "rglru", "local"), window=16, embed_scale=True,
    rglru=RglruConfig(d_rnn=64, d_conv=4), max_seq_len=256,
)
