"""mistral-nemo-12b — dense GQA, 128k context. [hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,                # explicit (not d_model / n_heads)
    d_ff=14336,
    vocab_size=131072,
    activation="silu_glu",
    pattern=("global",),
    rope_theta=1e6,
    max_seq_len=131072,
)

SMOKE_CONFIG = ModelConfig(
    name="mistral-nemo-12b-smoke",
    family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, activation="silu_glu", pattern=("global",),
    max_seq_len=128,
)
