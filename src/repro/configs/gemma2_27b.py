"""gemma2-27b — alternating local/global attention + logit softcaps.

[arXiv:2408.00118; hf]  attn softcap 50, final softcap 30, query scale
1/sqrt(d_model/n_heads) = 144^-0.5 (not head_dim^-0.5).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    activation="gelu_glu",
    pattern=("local", "global"),
    window=4096,
    rope_theta=10000.0,
    use_post_norm=True,
    embed_scale=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    attn_scale=(4608 / 32) ** -0.5,
    max_seq_len=8192,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma2-27b-smoke",
    family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, activation="gelu_glu",
    pattern=("local", "global"), window=16, use_post_norm=True,
    embed_scale=True, attn_logit_softcap=50.0, final_logit_softcap=30.0,
    attn_scale=16.0 ** -0.5, max_seq_len=128,
)
