"""musicgen-large — decoder-only over EnCodec tokens + T5 cross-attention.

[arXiv:2306.05284; hf]  Backbone only: EnCodec frame embeddings and the T5
text memory are stubs from ``input_specs`` (DESIGN.md §5).  Positional
encoding adapted to rope (original uses sinusoidal) — documented deviation.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    activation="gelu",
    pattern=("global",),
    rope_theta=10000.0,
    tie_embeddings=False,
    cross_attn_memory_len=256,      # T5 text-conditioning stub
    cross_attn_memory_dim=2048,
    max_seq_len=32768,
)

SMOKE_CONFIG = ModelConfig(
    name="musicgen-large-smoke",
    family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab_size=128, activation="gelu", pattern=("global",),
    tie_embeddings=False, cross_attn_memory_len=16, cross_attn_memory_dim=64,
    max_seq_len=128,
)
