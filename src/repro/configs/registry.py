"""Config registry: ``get_config("<arch-id>")`` + per-shape input specs."""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCHS = [
    "llava_next_mistral_7b",
    "mistral_nemo_12b",
    "gemma3_1b",
    "nemotron_4_15b",
    "gemma2_27b",
    "deepseek_v2_lite_16b",
    "qwen3_moe_235b_a22b",
    "mamba2_1p3b",
    "recurrentgemma_2b",
    "musicgen_large",
]

_ALIASES = {
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "gemma3-1b": "gemma3_1b",
    "nemotron-4-15b": "nemotron_4_15b",
    "gemma2-27b": "gemma2_27b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "mamba2-1.3b": "mamba2_1p3b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "musicgen-large": "musicgen_large",
}

# (seq_len, global_batch, mode)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# long_500k runs only for sub-quadratic archs (DESIGN.md §5)
LONG_CONTEXT_ARCHS = {"mamba2_1p3b", "recurrentgemma_2b"}


def canonical(arch: str) -> str:
    return _ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.SMOKE_CONFIG


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; skipped long_500k cells flagged."""
    out = []
    for a in ARCHS:
        for s in SHAPES:
            runnable = s != "long_500k" or a in LONG_CONTEXT_ARCHS
            if runnable or include_skipped:
                out.append((a, s, runnable))
    return out
