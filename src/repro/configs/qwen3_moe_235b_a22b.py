"""qwen3-moe-235b-a22b — 128 experts top-8, qk-norm. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.models.config import ModelConfig, MoeConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,                      # (all layers MoE; kept for bookkeeping)
    vocab_size=151936,
    activation="silu_glu",
    pattern=("global",),
    rope_theta=1e6,
    use_qk_norm=True,
    tie_embeddings=False,
    moe=MoeConfig(n_experts=128, top_k=8, expert_d_ff=1536,
                  n_shared_experts=0, norm_topk=True, first_k_dense=0),
    max_seq_len=32768,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b-smoke",
    family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=32, vocab_size=256, activation="silu_glu", pattern=("global",),
    use_qk_norm=True, tie_embeddings=False,
    moe=MoeConfig(n_experts=8, top_k=2, expert_d_ff=32, norm_topk=True, capacity_factor=8.0),
    max_seq_len=128,
)
