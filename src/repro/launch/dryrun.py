import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be run as its own process (the device-count flag binds at first jax
init).  For each cell this:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. lowers the step function against ShapeDtypeStruct inputs with the
     sharding rules from repro.distributed.sharding,
  3. compiles (proving the distribution config is coherent: no sharding
     mismatches, no unsupported collectives, memory accounted),
  4. records memory_analysis / cost_analysis / HLO collective bytes and the
     derived roofline terms to artifacts/dryrun/<cell>.json.

Usage:
  python -m repro.launch.dryrun --arch mistral-nemo-12b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both] [--jobs N]
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp


def _build(arch: str, shape_name: str, mesh_kind: str, knobs):
    """Build (fn, arg_shapes, in_shardings, cfg, parallel) for one cell."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.registry import SHAPES, get_config
    from repro.distributed.sharding import (ParallelConfig, batch_pspec,
                                            cache_pspec, make_shardings)
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import abstract_cache, abstract_init, batch_specs
    from repro.models.transformer import Transformer
    from repro.optim.adamw import AdamW, cosine_schedule
    from repro.serving.engine import make_decode_step, make_prefill_step
    from repro.train.step import make_train_step

    cfg = get_config(arch)
    seq, gbatch, mode = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    axis_sizes = tuple((n, int(mesh.shape[n])) for n in mesh.axis_names)
    parallel = ParallelConfig(
        axis_sizes=axis_sizes,
        pod_axis="pod" if mesh_kind == "multi" else None,
        pod_fsdp=knobs.get("pod_fsdp", False),
        compress_grads=knobs.get("compress_grads", False),
        remat=knobs.get("remat", "dots"),
        microbatches=knobs.get("microbatches", 1),
        seq_shard=knobs.get("seq_shard", False),
        layout=knobs.get("layout", "tp_fsdp"),
        ep_axis=knobs.get("ep_axis", "model"),
    )
    model = Transformer(cfg)
    p_shapes, p_specs = abstract_init(model)
    p_shard = make_shardings(mesh, p_specs, p_shapes, parallel)
    b_specs = batch_specs(cfg, shape_name)
    rep = NamedSharding(mesh, P())

    def batch_shardings():
        out = {}
        for k, v in b_specs.items():
            if v.ndim == 0:
                out[k] = rep
            else:
                out[k] = NamedSharding(
                    mesh, batch_pspec(v.shape[0], v.ndim, mesh, parallel,
                                      seq_dim=1 if v.ndim >= 2 else None))
        return out

    if mode == "train":
        tx = AdamW(lr=cosine_schedule(3e-4, 100, 10000))
        step = make_train_step(model, tx, parallel)
        o_shapes = jax.eval_shape(tx.init, p_shapes)
        o_shard = jax.tree.map(
            lambda s: (p_shard if hasattr(s, "shape") else None), o_shapes)
        # AdamWState(step, m, v): m/v shard like params, step replicated
        from repro.optim.adamw import AdamWState
        o_shard = AdamWState(step=rep, m=p_shard, v=p_shard)
        args = (p_shapes, o_shapes, b_specs)
        in_sh = (p_shard, o_shard, batch_shardings())
        return step, args, in_sh, cfg, parallel, mesh

    # Serving runs bf16 weights (no f32 masters at inference) — halves every
    # FSDP weight-gather on the decode/prefill paths (§Perf dsv2/iter2).
    p_shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if s.dtype == jnp.float32 else s, p_shapes)
    # long-context decode: local-attention slots use window-sized ring
    # buffers (the 500k KV never exists for windowed layers)
    cache_shapes = abstract_cache(model, gbatch, seq, dtype=jnp.bfloat16,
                                  window_bound=(shape_name == "long_500k"))
    c_shard = jax.tree.map(
        lambda s: NamedSharding(mesh, cache_pspec(s.shape, mesh, parallel)),
        cache_shapes)
    bs = batch_shardings()
    if mode == "prefill":
        step = make_prefill_step(model, parallel)
        kwargs_keys = [k for k in ("memory", "prefix_embeds") if k in b_specs]

        def fn(params, cache, tokens, *extra):
            kw = dict(zip(kwargs_keys, extra))
            return step(params, cache, tokens, **kw)

        extra_shapes = tuple(b_specs[k] for k in kwargs_keys)
        extra_sh = tuple(bs[k] for k in kwargs_keys)
        args = (p_shapes, cache_shapes, b_specs["tokens"], *extra_shapes)
        in_sh = (p_shard, c_shard, bs["tokens"], *extra_sh)
        return fn, args, in_sh, cfg, parallel, mesh

    step = make_decode_step(model, parallel)
    kwargs_keys = [k for k in ("memory",) if k in b_specs]

    def fn(params, cache, token, pos, *extra):
        kw = dict(zip(kwargs_keys, extra))
        return step(params, cache, token, pos, **kw)

    extra_shapes = tuple(b_specs[k] for k in kwargs_keys)
    extra_sh = tuple(bs[k] for k in kwargs_keys)
    args = (p_shapes, cache_shapes, b_specs["token"], b_specs["pos"],
            *extra_shapes)
    in_sh = (p_shard, c_shard, bs["token"], rep, *extra_sh)
    return fn, args, in_sh, cfg, parallel, mesh


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             knobs=None, tag: str = "") -> dict:
    from repro.roofline.analysis import (Roofline, model_flops_for,
                                         parse_collectives)

    knobs = knobs or {}
    t0 = time.time()
    fn, args, in_sh, cfg, parallel, mesh = _build(arch, shape_name, mesh_kind,
                                                  knobs)
    chips = mesh.devices.size
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
        }
    except Exception:  # noqa: BLE001 — backend may not support it
        mem = {}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    n_active = cfg.active_param_count()
    # Loop-weighted terms from the HLO walker (XLA's cost_analysis counts
    # while bodies ONCE — useless for scanned-layer models; see
    # roofline/analysis.py).  cost_analysis values kept as *_unweighted.
    rl = Roofline(
        arch=arch, shape=shape_name, mesh=mesh_kind, chips=chips,
        flops_per_dev=float(coll.flops), bytes_per_dev=float(coll.hbm_bytes),
        wire_bytes_per_dev=float(coll.wire_bytes),
        model_flops=model_flops_for(cfg, shape_name, n_active),
        collectives={"by_op": coll.by_op, "count": coll.count,
                     "operand_bytes": coll.operand_bytes},
        memory_stats=mem,
    ).finalize()
    rec = rl.to_dict()
    rec.update({
        "params_total": cfg.param_count(),
        "params_active": n_active,
        "flops_per_dev_unweighted": flops,
        "bytes_per_dev_unweighted": byts,
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "knobs": knobs,
        "hlo_bytes": len(hlo),
    })
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = f"{arch}__{shape_name}__{mesh_kind}{tag}.json"
        with open(os.path.join(out_dir, name), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--remat", default="dots",
                    choices=["none", "full", "dots"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--pod-fsdp", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--layout", default="tp_fsdp",
                    choices=["tp_fsdp", "fsdp_only", "tp_only"])
    ap.add_argument("--ep-axis", default="model", choices=["model", "data"])
    args = ap.parse_args()
    knobs = {"remat": args.remat, "microbatches": args.microbatches,
             "pod_fsdp": args.pod_fsdp, "compress_grads": args.compress_grads,
             "seq_shard": args.seq_shard, "layout": args.layout,
             "ep_axis": args.ep_axis}

    from repro.configs.registry import canonical, cells

    if args.all:
        todo = [(a, s) for a, s, ok in cells() if ok]
    else:
        todo = [(canonical(args.arch), args.shape)]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = 0
    for arch, shape in todo:
        for mk in meshes:
            try:
                rec = run_cell(arch, shape, mk, args.out, knobs, args.tag)
                print(f"OK   {arch:26s} {shape:12s} {mk:6s} "
                      f"Tc={rec['t_compute']:.4f}s Tm={rec['t_memory']:.4f}s "
                      f"Tx={rec['t_collective']:.4f}s "
                      f"bn={rec['bottleneck']:10s} mfu={rec['mfu']:.3f} "
                      f"compile={rec['t_compile_s']}s", flush=True)
            except Exception as e:  # noqa: BLE001
                failures += 1
                print(f"FAIL {arch} {shape} {mk}: {e}", flush=True)
                traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
