"""Serving driver: batched requests through the OCF prefix-cache index.

Simulates a request stream with shared prefixes (the chat-system-prompt
pattern); the OCF index decides per request how many prefix blocks can be
reused, the engine prefills only the cold suffix, and completed sequences
are admitted/evicted through the filter.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --requests 16 --prefix-len 64 --gen 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def health_snapshot(metrics, *, index, requests_served: int) -> dict:
    """Liveness + registry snapshot in one dict — what a /healthz +
    /metrics pair would serve; here it rides the driver's return value
    (and ``--telemetry`` prints the Prometheus form)."""
    return {
        "status": "ok",
        "requests_served": requests_served,
        "filter_occupancy": index.ocf.occupancy,
        "prefix_hit_rate": index.hit_rate,
        "metrics": metrics.snapshot() if metrics is not None else {},
    }


def serve(arch: str, *, requests: int, prefix_len: int, gen: int,
          smoke: bool = True, seed: int = 0, block: int = 16,
          metrics=None, tracer=None):
    """``metrics``/``tracer``: optional ``repro.obs`` instruments — per-
    request latency histogram + prefix-reuse counters, and prefill/decode
    spans.  None (the default) records nothing and adds nothing to the
    request loop."""
    from repro.configs.registry import get_config, get_smoke_config
    from repro.models.transformer import Transformer
    from repro.serving.engine import (greedy_sample, make_decode_step,
                                      make_prefill_step)
    from repro.serving.kvcache import PrefixCacheIndex

    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = Transformer(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed))
    rng = np.random.RandomState(seed)
    index = PrefixCacheIndex(block=block)
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))

    def span(name, **kw):
        import contextlib
        return (tracer.span(name, **kw) if tracer is not None
                else contextlib.nullcontext())

    shared_prefix = rng.randint(0, cfg.vocab_size, prefix_len).astype(np.int32)
    lat, reused_blocks = [], 0
    for r in range(requests):
        t0 = time.time()
        # half the requests share the system prefix (prefix-cache hits)
        if r % 2 == 0:
            prompt = np.concatenate(
                [shared_prefix,
                 rng.randint(0, cfg.vocab_size, block).astype(np.int32)])
        else:
            prompt = rng.randint(0, cfg.vocab_size,
                                 prefix_len + block).astype(np.int32)
        n_cached = index.match_prefix(prompt)
        reused_blocks += n_cached
        # real deployment: fetch cached pages for blocks [0, n_cached); here
        # the engine re-prefills only the cold suffix worth of compute
        prompt_j = jnp.asarray(prompt)[None, :]
        cache = model.init_cache(1, prompt.size + gen, dtype=jnp.float32)
        with span("prefill", request=r, prompt_len=int(prompt.size)):
            logits, cache = prefill(params, cache, prompt_j)
        tok = greedy_sample(logits)
        pos = prompt.size
        out = [int(tok[0, 0])]
        with span("decode", request=r, steps=gen - 1):
            for _ in range(gen - 1):
                logits, cache = decode(params, cache, tok, jnp.int32(pos))
                tok = greedy_sample(logits)
                out.append(int(tok[0, 0]))
                pos += 1
        index.admit(prompt)
        dt = time.time() - t0
        lat.append(dt)
        if metrics is not None:
            metrics.counter("serve_requests").inc()
            metrics.counter("serve_prefix_blocks_reused").inc(n_cached)
            metrics.counter("serve_tokens_generated").inc(len(out))
            metrics.histogram(
                "serve_request_latency_us",
                buckets=(1e3, 1e4, 1e5, 1e6, 1e7)).observe(dt * 1e6)
    result = {
        "latency_mean_s": float(np.mean(lat)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "prefix_hit_rate": index.hit_rate,
        "reused_blocks": reused_blocks,
        "index_stats": index.stats,
        "ocf_stats": index.ocf.stats,
        "filter_occupancy": index.ocf.occupancy,
    }
    if metrics is not None:
        result["health"] = health_snapshot(metrics, index=index,
                                           requests_served=requests)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prefix-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--telemetry", action="store_true",
                    help="record request metrics + trace spans; prints the "
                         "health/metrics snapshot (Prometheus text) and "
                         "writes serve_metrics.jsonl / serve_trace.json")
    ap.add_argument("--telemetry-dir", default=".",
                    help="directory for --telemetry artifacts")
    args = ap.parse_args()
    metrics = tracer = None
    if args.telemetry:
        from repro.obs import MetricsRegistry, TraceRecorder
        metrics = MetricsRegistry()
        tracer = TraceRecorder(process_name="serve")
    out = serve(args.arch, requests=args.requests, prefix_len=args.prefix_len,
                gen=args.gen, smoke=args.smoke, metrics=metrics,
                tracer=tracer)
    for k, v in out.items():
        if k != "health":
            print(f"{k}: {v}")
    if args.telemetry:
        import os
        os.makedirs(args.telemetry_dir, exist_ok=True)
        mpath = os.path.join(args.telemetry_dir, "serve_metrics.jsonl")
        tpath = os.path.join(args.telemetry_dir, "serve_trace.json")
        metrics.to_jsonl(mpath)
        tracer.save(tpath)
        print(f"health: {out['health']['status']} "
              f"(requests_served={out['health']['requests_served']})")
        print(metrics.prometheus_text(), end="")
        print(f"metrics -> {mpath}\ntrace -> {tpath}")


if __name__ == "__main__":
    main()
