"""Serving driver: batched requests through the OCF prefix-cache index.

Simulates a request stream with shared prefixes (the chat-system-prompt
pattern); the OCF index decides per request how many prefix blocks can be
reused, the engine prefills only the cold suffix, and completed sequences
are admitted/evicted through the filter.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --requests 16 --prefix-len 64 --gen 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve(arch: str, *, requests: int, prefix_len: int, gen: int,
          smoke: bool = True, seed: int = 0, block: int = 16):
    from repro.configs.registry import get_config, get_smoke_config
    from repro.models.transformer import Transformer
    from repro.serving.engine import (greedy_sample, make_decode_step,
                                      make_prefill_step)
    from repro.serving.kvcache import PrefixCacheIndex

    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = Transformer(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed))
    rng = np.random.RandomState(seed)
    index = PrefixCacheIndex(block=block)
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))

    shared_prefix = rng.randint(0, cfg.vocab_size, prefix_len).astype(np.int32)
    lat, reused_blocks = [], 0
    for r in range(requests):
        t0 = time.time()
        # half the requests share the system prefix (prefix-cache hits)
        if r % 2 == 0:
            prompt = np.concatenate(
                [shared_prefix,
                 rng.randint(0, cfg.vocab_size, block).astype(np.int32)])
        else:
            prompt = rng.randint(0, cfg.vocab_size,
                                 prefix_len + block).astype(np.int32)
        n_cached = index.match_prefix(prompt)
        reused_blocks += n_cached
        # real deployment: fetch cached pages for blocks [0, n_cached); here
        # the engine re-prefills only the cold suffix worth of compute
        prompt_j = jnp.asarray(prompt)[None, :]
        cache = model.init_cache(1, prompt.size + gen, dtype=jnp.float32)
        logits, cache = prefill(params, cache, prompt_j)
        tok = greedy_sample(logits)
        pos = prompt.size
        out = [int(tok[0, 0])]
        for _ in range(gen - 1):
            logits, cache = decode(params, cache, tok, jnp.int32(pos))
            tok = greedy_sample(logits)
            out.append(int(tok[0, 0]))
            pos += 1
        index.admit(prompt)
        lat.append(time.time() - t0)
    return {
        "latency_mean_s": float(np.mean(lat)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "prefix_hit_rate": index.hit_rate,
        "reused_blocks": reused_blocks,
        "index_stats": index.stats,
        "ocf_stats": index.ocf.stats,
        "filter_occupancy": index.ocf.occupancy,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prefix-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    out = serve(args.arch, requests=args.requests, prefix_len=args.prefix_len,
                gen=args.gen, smoke=args.smoke)
    for k, v in out.items():
        print(f"{k}: {v}")


if __name__ == "__main__":
    main()
