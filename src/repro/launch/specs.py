"""Abstract input/state specs for lowering (no device allocation).

``input_specs(cfg, shape_name)`` returns ShapeDtypeStruct stand-ins for every
input of the step function selected by the shape's mode:
  train_*   -> train_step(params, opt_state, batch)
  prefill_* -> prefill_step(params, cache, tokens)
  decode_*  -> decode_step(params, cache, token[B,1], pos)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.registry import SHAPES
from repro.models.config import ModelConfig
from repro.models.transformer import Transformer

SDS = jax.ShapeDtypeStruct


def abstract_init(model: Transformer, seed: int = 0):
    """(param shapes, logical specs) without allocating anything."""
    side = {}

    def f(k):
        p, s = model.init(k)
        side["specs"] = s
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(seed))
    return shapes, side["specs"]


def abstract_cache(model: Transformer, batch: int, max_len: int,
                   dtype=jnp.bfloat16, window_bound: bool = False):
    return jax.eval_shape(
        lambda: model.init_cache(batch, max_len, dtype=dtype,
                                 window_bound=window_bound))


def batch_specs(cfg: ModelConfig, shape_name: str) -> dict[str, Any]:
    seq, gbatch, mode = SHAPES[shape_name]
    out: dict[str, Any] = {}
    if mode == "train":
        text = seq - cfg.prefix_embed_len
        out["tokens"] = SDS((gbatch, text), jnp.int32)
        out["targets"] = SDS((gbatch, text), jnp.int32)
        if cfg.prefix_embed_len:
            out["prefix_embeds"] = SDS(
                (gbatch, cfg.prefix_embed_len, cfg.d_model), jnp.bfloat16)
        if cfg.cross_attn_memory_len:
            out["memory"] = SDS(
                (gbatch, cfg.cross_attn_memory_len, cfg.cross_attn_memory_dim),
                jnp.bfloat16)
    elif mode == "prefill":
        text = seq - cfg.prefix_embed_len
        out["tokens"] = SDS((gbatch, text), jnp.int32)
        if cfg.prefix_embed_len:
            out["prefix_embeds"] = SDS(
                (gbatch, cfg.prefix_embed_len, cfg.d_model), jnp.bfloat16)
        if cfg.cross_attn_memory_len:
            out["memory"] = SDS(
                (gbatch, cfg.cross_attn_memory_len, cfg.cross_attn_memory_dim),
                jnp.bfloat16)
    else:  # decode: one new token against a seq-long cache
        out["token"] = SDS((gbatch, 1), jnp.int32)
        out["pos"] = SDS((), jnp.int32)
        if cfg.cross_attn_memory_len:
            out["memory"] = SDS(
                (gbatch, cfg.cross_attn_memory_len, cfg.cross_attn_memory_dim),
                jnp.bfloat16)
    return out
