"""End-to-end training driver.

Wires every substrate together: config registry -> model -> sharded init ->
OCF-dedup data pipeline -> pjit train_step -> checkpoint/restart loop with
straggler watchdog.  Works identically on the CPU smoke mesh (tests,
examples/quickstart.py) and the production mesh (via dryrun for compile-only
validation).

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --smoke \
      --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import logging
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

log = logging.getLogger("repro.train")


def build_state(arch: str, *, smoke: bool, mesh, parallel, seed: int = 0):
    from repro.configs.registry import get_config, get_smoke_config
    from repro.distributed.sharding import make_shardings
    from repro.launch.specs import abstract_init
    from repro.models.transformer import Transformer
    from repro.optim.adamw import AdamW, cosine_schedule

    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = Transformer(cfg)
    shapes, specs = abstract_init(model)
    shardings = make_shardings(mesh, specs, shapes, parallel)
    with mesh:
        params = jax.jit(
            lambda k: model.init(k)[0],
            out_shardings=shardings)(jax.random.PRNGKey(seed))
    tx = AdamW(lr=cosine_schedule(3e-4, 20, 10000))
    opt_state = jax.jit(tx.init)(params)
    return cfg, model, tx, params, opt_state, shardings, specs


def train(arch: str, *, steps: int, batch: int, seq: int, smoke: bool = True,
          ckpt_dir: str | None = None, ckpt_every: int = 10,
          resume: bool = True, data_seed: int = 0, mesh=None, parallel=None,
          inject_failure_at: int | None = None):
    from repro.checkpoint import ckpt as ckpt_mod
    from repro.data.pipeline import DedupPipeline, SyntheticDocs
    from repro.distributed.fault import StragglerWatchdog
    from repro.distributed.sharding import ParallelConfig
    from repro.train.step import make_train_step

    if mesh is None:
        dev = jax.devices()[0]
        mesh = jax.make_mesh((1, 1), ("data", "model"), devices=[dev],
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    parallel = parallel or ParallelConfig()
    cfg, model, tx, params, opt_state, shardings, specs = build_state(
        arch, smoke=smoke, mesh=mesh, parallel=parallel)

    start_step = 0
    if ckpt_dir and resume:
        last = ckpt_mod.latest_step(ckpt_dir)
        if last is not None:
            params, _ = ckpt_mod.restore(ckpt_dir, last, params)
            opt_state, _ = ckpt_mod.restore(ckpt_dir + "/opt", last, opt_state)
            start_step = last
            log.info("resumed from step %d", last)

    pipe = DedupPipeline(
        SyntheticDocs(cfg.vocab_size, doc_len=seq + 1, seed=data_seed),
        batch=batch, seq=seq)
    data = iter(pipe)

    step_fn = jax.jit(make_train_step(model, tx, parallel))
    watchdog = StragglerWatchdog()
    history = []
    for step in range(start_step, steps):
        t0 = time.time()
        raw = next(data)
        batch_d = {k: jnp.asarray(v) for k, v in raw.items()}
        if cfg.prefix_embed_len:
            batch_d["prefix_embeds"] = jnp.zeros(
                (batch, cfg.prefix_embed_len, cfg.d_model), jnp.bfloat16)
        if cfg.cross_attn_memory_len:
            batch_d["memory"] = jnp.zeros(
                (batch, cfg.cross_attn_memory_len, cfg.cross_attn_memory_dim),
                jnp.bfloat16)
        if inject_failure_at is not None and step == inject_failure_at:
            raise RuntimeError(f"injected node failure at step {step}")
        params, opt_state, metrics = step_fn(params, opt_state, batch_d)
        dt = time.time() - t0
        watchdog.observe(dt)
        history.append({k: float(v) for k, v in metrics.items()})
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ckpt_mod.save(ckpt_dir, step + 1, params, ocf=pipe.ocf)
            ckpt_mod.save(ckpt_dir + "/opt", step + 1, opt_state)
    return {
        "params": params, "opt_state": opt_state, "history": history,
        "pipeline_stats": pipe.stats, "dedup_ocf_stats": pipe.ocf.stats,
        "straggler_flags": watchdog.flagged, "model": model, "cfg": cfg,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    out = train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
                smoke=args.smoke, ckpt_dir=args.ckpt_dir)
    losses = [h["loss"] for h in out["history"]]
    print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f}")
    print(f"dedup: {out['pipeline_stats']}")


if __name__ == "__main__":
    main()
