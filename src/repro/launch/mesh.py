"""Production meshes.

Functions, never module-level constants — importing this module must not
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).

Topology (TPU v5e): one pod = 16×16 = 256 chips, ``data`` × ``model``;
multi-pod = 2 pods = 512 chips with a leading ``pod`` axis (DCN-connected).
"""
from __future__ import annotations

import jax


def _axis_kwargs(n_axes: int) -> dict:
    """``axis_types=`` for make_mesh, or {} on jax lines without AxisType
    (0.4.x — where Auto is the only behavior anyway).  Same compat shim as
    ``distributed.elastic._axis_type_kwargs``; duplicated here because this
    module must stay import-light (no repro.distributed dependency)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} — run via "
            f"launch/dryrun.py (it sets xla_force_host_platform_device_count)")
    return jax.make_mesh(shape, axes, devices=devs[:need],
                         **_axis_kwargs(len(shape)))


def make_test_mesh(data: int = 2, model: int = 2, pod: int | None = None):
    """Small mesh for unit tests (8 host devices)."""
    shape = (pod, data, model) if pod else (data, model)
    axes = ("pod", "data", "model") if pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:need],
                         **_axis_kwargs(len(shape)))
