"""Admission control — stash + generation fill as a congestion signal.

The paper's burst story is a control loop: congestion must be *measured*
where it first appears and fed back to whatever admits work.  In the
streaming subsystem congestion appears in exactly two places, both cheap
device scalars:

  * **stash fill** — the overflow stash absorbs eviction-chain exhaustion,
    so its occupancy is a direct reading of how hard the active table is
    thrashing (it starts rising near the o_max operating point, well before
    inserts fail);
  * **generation fill** — the active table's occupancy, the same quantity
    the OCF's EOF policy integrates.

``congestion_signal`` folds the two into one [0, ~1] scalar;
``AdmissionController`` adds hysteresis (trip at ``high_water``, re-admit
below ``low_water``) so a burst sheds load without flapping; and
``observe_eof`` feeds the same signal to an ``EofPolicy`` by inflating its
marked-operation count — under congestion the EOF monitoring window closes
faster, which is precisely "resize ahead of the traffic".  The serving
scheduler (``serving/scheduler.py``) consumes the controller directly: its
admission queue defers requests while the controller is tripped.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.policy import EofPolicy, PrePolicy, ResizeDecision
from repro.streaming.generations import GenerationalFilter


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    stash_weight: float = 0.6     # stash fill is the earlier indicator
    fill_weight: float = 0.4
    high_water: float = 0.85      # trip: stop admitting
    low_water: float = 0.60       # reset: admit again (hysteresis band)


def congestion_signal(stash_fill: float, gen_fill: float,
                      config: AdmissionConfig | None = None) -> float:
    """Weighted congestion scalar in [0, ~1] from the two device readings."""
    cfg = config or AdmissionConfig()
    return cfg.stash_weight * stash_fill + cfg.fill_weight * gen_fill


@dataclasses.dataclass
class AdmissionController:
    """Hysteresis gate over a congestion signal read from ``filt.fills()``.

    ``filt`` is any fills-duck — something with
    ``fills() -> (fill, stash_fill)`` in [0, 1] each.  Shipping ducks:
    ``GenerationalFilter`` (live device read), ``serving.scheduler.
    ShardedFilterFills`` (sharded aggregate), and ``serving.scheduler.
    FilterOpBatcher`` (last-harvest snapshot — sync-free, so the SLO
    harness can gate every wave without stalling the submit pipeline).

    ``last_signal`` / ``peak_signal`` record the most recent and worst
    congestion reading — the SLO report surfaces them so a burst scenario
    can show how close the gate came to (or how long it sat past) the
    high-water mark.
    """

    filt: GenerationalFilter   # or any fills() duck, see docstring
    config: AdmissionConfig = dataclasses.field(
        default_factory=AdmissionConfig)
    tripped: bool = False
    admitted: int = 0
    deferred: int = 0
    last_signal: float = 0.0
    peak_signal: float = 0.0
    # Optional repro.obs.MetricsRegistry: trip/readmit transitions become
    # counters, the congestion reading a pair of gauges.  None = free.
    metrics: Optional[object] = None
    # Hard gate for control-plane windows (elastic cutover): while frozen,
    # peek()/admit() answer False without reading the signal — the filter
    # state is mid-migration and fills() may straddle two meshes.
    frozen: bool = False

    def signal(self) -> float:
        """Current congestion in [0, ~1] (one stacked device read)."""
        fill, stash_fill = self.filt.fills()
        s = congestion_signal(stash_fill, fill, self.config)
        self.last_signal = s
        self.peak_signal = max(self.peak_signal, s)
        if self.metrics is not None:
            self.metrics.gauge("admission_signal").set(s)
            self.metrics.gauge("admission_peak_signal").set_max(s)
        return s

    def peek(self) -> bool:
        """Would a request be admitted right now?  Updates the hysteresis
        state but NOT the admitted/deferred counters — the side-effect-free
        form pollers (the scheduler's deferred-queue drain) must use, so
        the counters keep meaning *per-request decisions*."""
        if self.frozen:
            return False
        s = self.signal()
        if self.tripped:
            if s <= self.config.low_water:
                self.tripped = False
                if self.metrics is not None:
                    self.metrics.counter("admission_readmits").inc()
        elif s >= self.config.high_water:
            self.tripped = True
            if self.metrics is not None:
                self.metrics.counter("admission_trips").inc()
        return not self.tripped

    def freeze(self):
        """Deny all admissions until ``thaw`` — no signal read, no
        hysteresis transition.  The elastic controller brackets a migration
        window with freeze/thaw so nothing races the shard cutover."""
        self.frozen = True

    def thaw(self):
        self.frozen = False

    def admit(self) -> bool:
        """One per-request admission decision, with hysteresis + counters."""
        if self.peek():
            self.admitted += 1
            return True
        self.deferred += 1
        return False

    def observe_eof(self, policy: EofPolicy | PrePolicy, *, items: int,
                    capacity: int, ops: int = 1
                    ) -> Optional[ResizeDecision]:
        """Feed an OCF resize policy congestion-weighted marked ops.

        The EOF controller measures offered load by counting marked
        operations inside its monitoring window; scaling the count by
        ``1 + signal`` makes a congested stream close the window sooner, so
        the resize lands *ahead* of the burst (the paper's Alg. 1 intent,
        driven by the stash instead of switch-queue marks).
        """
        weighted = max(1, int(round(ops * (1.0 + self.signal()))))
        return policy.observe(items=items, capacity=capacity, ops=weighted)
