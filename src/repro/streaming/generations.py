"""TTL-aged filter generations — the streaming membership data plane.

The OCF answers growth with resize+rebuild, which is the right call for a
database index but the wrong one for an unbounded stream: the keystore grows
forever and every rebuild replays it.  Streaming membership (dedup windows,
recent-flow tables, prefix caches with freshness) wants the *multi-level
aging* design of "Don't Thrash: How to Cache Your Hash on Flash": keep K
rotating filter **generations**, insert into the newest, probe all live
ones, and expire by **retiring a whole generation** — an O(1) state drop
instead of per-key deletes.

Layered on the PR-1/PR-3 data plane:

  * every generation is a standard ``FilterState`` + overflow stash pair
    driven through ``FilterOps`` (``insert_spill`` / ``lookup_with_stash``),
    so pallas/jnp dispatch, bounded eviction rounds, and stash spill all
    apply per generation;
  * all generations share one **preallocated buffer pool** (K pow2 tables
    allocated up front and recycled on retirement), so rotation changes no
    array shapes and the jit/kernel cache stays warm for the lifetime of
    the stream;
  * lookups probe every live generation in one jitted device call (the
    FilterOps instance is a static jit argument, so each live-generation
    count compiles once per chunk shape);
  * TTL expiry is **lazy**: an expired generation stops answering lookups
    immediately (it is filtered out of the probe set by timestamp) and its
    buffer is reclaimed on the next rotation/advance — no cleanup thread.

A full-and-stashed insert failure rotates early and retries once in the
fresh generation — the streaming analogue of the OCF's emergency grow,
with bounded (capacity-sized) state instead of a rebuild.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import filter as jfilter
from repro.core.chunking import (collect_chunk_results, key_chunks,
                                 pow2_at_least)
from repro.core.filter_ops import Backend, FilterOps, evict_rounds_for_load
from repro.core.scheduling import dedupe_keys
from repro.kernels.stash import DEFAULT_STASH_SLOTS, stash_occupancy
from repro.streaming.stash import OverflowStash


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    """Shape and policy of the generation ring."""

    generations: int = 4             # K live generations (the probe fan-out)
    capacity: int = 1 << 14          # item slots per generation
    bucket_size: int = 4
    fp_bits: int = 16
    stash_slots: int = DEFAULT_STASH_SLOTS
    backend: Backend = "auto"
    evict_rounds: Optional[int] = None   # None -> derived from o_max
    o_max: float = 0.85              # rotate when the active table fills past
    stash_high: float = 0.5          # ... or the active stash fills past
    ttl: Optional[float] = None      # seconds a generation stays live
    # Conflict-aware wave scheduling of insert batches + zero-copy buffer
    # donation (the ring owns its pool buffers and never reuses a pre-op
    # table) — see core/scheduling.py and FilterOps.
    schedule: bool = True
    donate: bool = True
    # Host-side lookup dedup (probe one lane per distinct key in a batch).
    # Off by default: all-unique batches would pay the np.unique sort for
    # nothing; dedup-window streams — where repeats ARE the workload —
    # should turn it on.
    dedupe_lookups: bool = False

    def __post_init__(self):
        # Unlike OcfConfig (where stash_slots=0 means "classic OCF, grow on
        # failure"), a generation has no grow path — the stash IS its burst
        # absorber — so a stash-less generation ring is a config error.
        if self.stash_slots < 1:
            raise ValueError(
                "GenerationConfig.stash_slots must be >= 1: generations "
                "absorb eviction storms in the stash (they rotate instead "
                "of growing); use OcfConfig(stash_slots=0) for a stash-"
                "less filter")
        if self.generations < 1:
            raise ValueError("GenerationConfig.generations must be >= 1")

    @property
    def n_buckets(self) -> int:
        return max(1, -(-self.capacity // self.bucket_size))

    def make_filter_ops(self) -> FilterOps:
        rounds = (self.evict_rounds if self.evict_rounds is not None
                  else evict_rounds_for_load(self.o_max))
        return FilterOps(fp_bits=self.fp_bits, backend=self.backend,
                         evict_rounds=rounds, schedule=self.schedule,
                         donate=self.donate)


@dataclasses.dataclass
class GenStats:
    inserts: int = 0
    lookups: int = 0
    rotations: int = 0
    expirations: int = 0             # generations retired by TTL
    spills: int = 0                  # fingerprints parked in stashes
    rotate_retries: int = 0          # inserts that needed the early-rotate


@dataclasses.dataclass
class _Generation:
    state: jfilter.FilterState
    stash: OverflowStash
    born: float
    expires: Optional[float]         # None = no TTL

    def live(self, now: float) -> bool:
        return self.expires is None or now < self.expires


class _BufferPool:
    """K preallocated pow2 table buffers, recycled across generations.

    Retirement hands a zeroed same-shape buffer back, so every generation
    the ring ever creates reuses one of the K original shapes — rotation is
    a jit-cache hit, never a recompile or a foreign allocation.
    """

    def __init__(self, k: int, buffer_buckets: int, bucket_size: int):
        self.shape = (buffer_buckets, bucket_size)
        self._free = [jnp.zeros(self.shape, jnp.uint32) for _ in range(k)]

    def acquire(self) -> jax.Array:
        assert self._free, "buffer pool exhausted (more gens than K?)"
        return self._free.pop()

    def release(self, table: jax.Array) -> None:
        self._free.append(jnp.zeros_like(table))


@functools.partial(jax.jit, static_argnames=("ops",))
def _multi_probe(ops: FilterOps, states, stashes, hi, lo):
    """OR of table+stash membership across the live generations.

    One jitted call per (live-count, chunk-shape) pair — the python loop
    unrolls at trace time, so on device this is a single fused program, not
    K round-trips.
    """
    hit = jnp.zeros(hi.shape, jnp.bool_)
    for state, stash in zip(states, stashes):
        hit = hit | ops.lookup_with_stash(state, stash, hi, lo)
    return hit


class GenerationalFilter:
    """K rotating TTL-aged filter generations with per-generation stashes.

    All ``now`` parameters share ONE clock domain: pass nothing anywhere and
    the wall clock (``time.monotonic``) drives TTLs, or pass your own
    logical timestamps everywhere (tests, replay, event-time streams).  The
    constructor takes the stream's epoch for the same reason — the first
    generation's TTL starts there.
    """

    def __init__(self, config: GenerationConfig | None = None,
                 now: Optional[float] = None, metrics=None):
        """``metrics``: optional ``repro.obs.MetricsRegistry`` — rotation /
        TTL-expiry events become counters; None costs nothing."""
        self.config = config or GenerationConfig()
        self.metrics = metrics
        self.ops = self.config.make_filter_ops()
        buf = pow2_at_least(self.config.n_buckets)
        self.pool = _BufferPool(self.config.generations, buf,
                                self.config.bucket_size)
        self.gens: list[_Generation] = []
        self.stats = GenStats()
        self._last_now: Optional[float] = None
        # identity key -> (prober, source-array refs) for the fused
        # fan-out — see _fanout_prober.
        self._prober_cache: dict = {}
        self._spawn(self._now(now))

    # --------------------------------------------------------- plumbing --

    def _now(self, now: Optional[float]) -> float:
        """Resolve a timestamp, remembering the caller's clock domain.

        Callers on a logical clock pass ``now`` everywhere; the last value
        seen becomes the default for argument-less reads (``len``,
        ``live_generations``), so mixed-domain confusion can't make an
        expired generation look live.  Callers who never pass ``now`` get
        the wall clock throughout.
        """
        if now is not None:
            self._last_now = now
            return now
        return time.monotonic() if self._last_now is None else self._last_now

    def _spawn(self, now: float) -> None:
        cfg = self.config
        state = jfilter.FilterState(
            self.pool.acquire(), jnp.zeros((), jnp.int32),
            jnp.asarray(cfg.n_buckets, jnp.int32))
        ttl = None if cfg.ttl is None else now + cfg.ttl
        self.gens.append(_Generation(state, OverflowStash(cfg.stash_slots),
                                     born=now, expires=ttl))

    def _retire(self, gen: _Generation, *, expired: bool) -> None:
        self.pool.release(gen.state.table)
        if expired:
            self.stats.expirations += 1
            if self.metrics is not None:
                self.metrics.counter("generation_expirations").inc()

    @property
    def active(self) -> _Generation:
        return self.gens[-1]

    def _live(self, now: float) -> list[_Generation]:
        return [g for g in self.gens if g.live(now)]

    _chunks = staticmethod(key_chunks)   # shared contract: core/chunking.py

    # ------------------------------------------------------------- fill --

    @property
    def fill(self) -> float:
        """Active generation's table occupancy (rotation + admission input)."""
        return int(self.active.state.count) / self.config.capacity

    @property
    def stash_fill(self) -> float:
        """Active generation's stash occupancy in [0, 1]."""
        return self.active.stash.fill

    def fills(self) -> tuple[float, float]:
        """(table fill, stash fill) of the active generation in ONE device
        transfer — what the admission controller polls on the scheduler
        intake path (the separate ``fill``/``stash_fill`` properties each
        pay their own sync)."""
        count, occ = self._control_read()
        return count / self.config.capacity, occ / self.config.stash_slots

    @property
    def live_generations(self) -> int:
        return len(self._live(self._now(None)))

    def __len__(self) -> int:
        """Table-resident fingerprints across all generations (approx.)."""
        return sum(int(g.state.count) + g.stash.occupancy for g in self.gens)

    # ---------------------------------------------------------- control --

    def advance(self, now: Optional[float] = None) -> int:
        """Reclaim expired generations' buffers; returns how many retired.

        Lookups already ignore expired generations (lazy expiry) — this
        just returns their buffers to the pool.  The active generation is
        replaced with a fresh one if it expired.
        """
        now = self._now(now)
        dead = [g for g in self.gens if not g.live(now)]
        for g in dead:
            self.gens.remove(g)
            self._retire(g, expired=True)
        if not self.gens:
            self._spawn(now)
        return len(dead)

    def rotate(self, now: Optional[float] = None) -> None:
        """Seal the active generation and open a fresh one (O(1) aging)."""
        now = self._now(now)
        self.advance(now)
        if len(self.gens) >= self.config.generations:
            oldest = self.gens.pop(0)
            self._retire(oldest, expired=False)
        self._spawn(now)
        self.stats.rotations += 1
        if self.metrics is not None:
            self.metrics.counter("generation_rotations").inc()

    def _control_read(self) -> tuple[int, int]:
        """Active generation's (table count, stash occupancy) in ONE
        device->host transfer — the only per-chunk sync the insert path
        pays (the OCF learned the same lesson: per-chunk round-trips
        serialize the whole stream on transfer latency)."""
        gen = self.active
        pair = np.asarray(jnp.stack([
            gen.state.count, stash_occupancy(gen.stash.array)]))
        return int(pair[0]), int(pair[1])

    # ------------------------------------------------------------- ops ---

    def insert(self, keys, now: Optional[float] = None) -> np.ndarray:
        """Insert a batch into the active generation -> ok bool[N].

        Overflow order: table → bounded eviction rounds → stash → early
        rotation + one retry in the fresh generation.  ``ok`` is False only
        when even the retry fails (a chunk larger than a whole generation's
        capacity — a sizing error, not a burst).

        Device discipline: every chunk's ok mask is queued on device and
        pulled back in one stacked transfer after the whole batch; the
        rotation decision costs one combined scalar read per chunk
        (``_control_read``), which doubles as the spill accounting.
        """
        now = self._now(now)
        keys = np.asarray(keys, dtype=np.uint64)
        self.stats.inserts += keys.size
        self.advance(now)
        out = np.ones(keys.size, dtype=bool)
        cfg = self.config
        count, occ = self._control_read()
        oks, ns = [], []
        for hi, lo, valid, n in self._chunks(keys):
            if (count / cfg.capacity >= cfg.o_max
                    or occ / cfg.stash_slots >= cfg.stash_high):
                self.rotate(now)
                count = occ = 0
            prev_occ = occ
            oks.append(self._insert_chunk(hi, lo, valid))
            ns.append(n)
            count, occ = self._control_read()
            self.stats.spills += occ - prev_occ
        idx = (np.flatnonzero(~collect_chunk_results(oks, ns)) if oks
               else np.zeros((0,), np.intp))   # one transfer, all chunks
        if idx.size:
            # Even the stash overflowed: rotate early and retry ONCE in the
            # fresh generation (the streaming analogue of emergency grow).
            self.stats.rotate_retries += idx.size
            self.rotate(now)
            off = 0
            for hi, lo, valid, n in self._chunks(keys[idx]):
                ok = np.asarray(self._insert_chunk(hi, lo, valid))[:n]
                out[idx[off:off + n]] = ok
                off += n
            _count, occ = self._control_read()
            self.stats.spills += occ               # fresh gen started at 0
        return out

    def _insert_chunk(self, hi, lo, valid) -> jax.Array:
        """One device insert into the active generation -> ok (on device)."""
        gen = self.active
        state, stash_arr, ok = self.ops.insert_spill(
            gen.state, gen.stash.array, hi, lo, valid=valid)
        gen.state = state
        gen.stash.array = stash_arr
        return ok

    def _fanout_prober(self, states, stashes):
        """Cached fused fan-out closure over the live generations' tables.

        Stacking K tables + stashes into the fused kernel's [K, ...] inputs
        is an O(K · table_bytes) device copy; the generation set only
        changes on insert/rotate/advance, while a serving workload may
        probe many batches in between.  The cache keys on the live arrays'
        identities (strong refs to the keyed arrays ride along so an id
        can't be recycled while the key is alive) and rebuilds lazily on
        any state change — including donation, which always rebinds
        ``gen.state`` to a fresh array.
        """
        key = tuple((id(s.table), id(a)) for s, a in zip(states, stashes))
        hit = self._prober_cache.get(key)
        if hit is not None:
            return hit[0]
        tables = jnp.stack([s.table for s in states])
        stash_stack = jnp.stack(stashes)
        prober = self.ops.fanout_prober(tables, stash_stack,
                                        n_buckets=states[0].n_buckets)
        if len(self._prober_cache) >= 4:
            # A dict (not one slot) because the serving path alternates
            # lookup() [all live gens] with lookup_active() [active only]
            # per request — one slot would thrash and re-stack every call.
            self._prober_cache.pop(next(iter(self._prober_cache)))
        self._prober_cache[key] = (prober, [s.table for s in states],
                                   list(stashes))
        return prober

    def lookup(self, keys, now: Optional[float] = None) -> np.ndarray:
        """Membership across every live generation -> bool[N]."""
        return self._lookup(keys, now, active_only=False)

    def lookup_active(self, keys, now: Optional[float] = None) -> np.ndarray:
        """Membership in the ACTIVE generation only -> bool[N].

        The promote-on-read primitive of a multi-level design: a key that
        hits overall but misses here lives in an aging generation, and a
        caller that wants it to survive rotation re-inserts it (see
        ``serving.kvcache.GenerationalPrefixIndex.match_prefix``).
        """
        return self._lookup(keys, now, active_only=True)

    def _lookup(self, keys, now: Optional[float], *, active_only: bool
                ) -> np.ndarray:
        now = self._now(now)
        keys = np.asarray(keys, dtype=np.uint64)
        self.stats.lookups += keys.size
        live = self._live(now)
        if active_only:
            live = [g for g in live if g is self.gens[-1]]
        if not live:
            return np.zeros(keys.size, bool)
        if self.config.dedupe_lookups:
            uniq, inverse = dedupe_keys(keys)
        else:
            uniq, inverse = keys, None
        states = tuple(g.state for g in live)
        stashes = tuple(g.stash.array for g in live)
        # pallas: ONE fused kernel per chunk, its grid spanning every live
        # generation (keys hashed once).  jnp: the unrolled per-generation
        # probe loop.  Either way every chunk's hits queue on device and
        # come back in one stacked transfer.
        fused = (self.ops.resolve_bytes(
            states[0].table.size * 4,
            stash_slots=self.config.stash_slots) == "pallas")
        if fused:
            prober = self._fanout_prober(states, stashes)
        hits, ns = [], []
        for hi, lo, _valid, n in self._chunks(uniq, with_valid=False):
            if fused:
                hit = prober(hi, lo)
            else:
                hit = _multi_probe(self.ops, states, stashes, hi, lo)
            hits.append(hit)
            ns.append(n)
        out = collect_chunk_results(hits, ns)
        return out[inverse] if inverse is not None else out
