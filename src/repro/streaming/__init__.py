"""Streaming membership subsystem: TTL-aged generations + overflow stash.

Public surface:

  * ``GenerationalFilter`` / ``GenerationConfig`` — K rotating filter
    generations over a preallocated buffer pool, lazy TTL expiry, stash-
    backed inserts (``generations.py``);
  * ``OverflowStash`` — the host-facing stash wrapper (``stash.py``; device
    math in ``repro.kernels.stash``);
  * ``AdmissionController`` / ``AdmissionConfig`` / ``congestion_signal`` —
    stash+fill backpressure for the serving scheduler and the EOF resize
    policy (``admission.py``);
  * ``PyStashFilter`` — the sequential stash-extended oracle the kernels
    are parity-tested against (``oracle.py``).
"""
from repro.streaming.admission import (AdmissionConfig, AdmissionController,
                                       congestion_signal)
from repro.streaming.generations import (GenerationConfig,
                                         GenerationalFilter, GenStats)
from repro.streaming.oracle import PyStashFilter
from repro.streaming.stash import OverflowStash

__all__ = ["AdmissionConfig", "AdmissionController", "congestion_signal",
           "GenerationConfig", "GenerationalFilter", "GenStats",
           "OverflowStash", "PyStashFilter"]
