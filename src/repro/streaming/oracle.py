"""Sequential oracle for the stash-extended filter — `pyfilter` + a stash.

``PyStashFilter`` extends the semantic oracle (``core.pyfilter``) with the
overflow stash the streaming subsystem adds to the device data plane.  Its
eviction schedule replicates the *kernel's* chain discipline (probe-then-
kick rounds, dirty-slot exclusion, spill-on-exhaustion) rather than the
classic ``max_displacements`` chain, so that for single-lane residues — one
contended key per batch — the Pallas insert kernel reproduces this oracle
**bit for bit**: same table, same stash entries, same order.  Multi-lane
batches are order-racy by construction on any parallel schedule; there the
parity contract is membership + conservation, not table identity (exactly
the contract the PR-3 eviction tests already use).

The stash is modeled as a fixed array of *slots* (not a compacting list),
mirroring the kernels' uint32[2, slots] layout: a spill takes the first
empty slot in slot order, a delete zeroes its slot in place, and later
spills refill holes first — so the slot-for-slot comparison against the
device stash stays exact through interleaved insert/delete streams (the
distributed write-path tests drive exactly that).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import hashing
from repro.core.pyfilter import PyCuckooFilter


@dataclasses.dataclass
class PyStashFilter(PyCuckooFilter):
    """Cuckoo filter + overflow stash, kernel-faithful eviction rounds.

    ``evict_rounds`` plays the kernel's role (bounded rounds, not bounded
    kicks: a round whose bucket is fully dirty burns the round without
    kicking, exactly like a lane losing its rank race).  Stash slots hold
    ``(fp, bucket)`` pairs; by the alternate-index involution the stored
    bucket identifies the fingerprint's candidate pair regardless of which
    end of it the chain held at exhaustion.
    """

    evict_rounds: int = 32
    stash_slots: int = 128

    def __post_init__(self):
        super().__post_init__()
        # Fixed slot array (None == empty) — kernel layout, not a list.
        self._slots: list[tuple[int, int] | None] = [None] * self.stash_slots
        self.spills = 0

    @property
    def stash(self) -> list[tuple[int, int]]:
        """Live (fp, bucket) entries in slot order (holes skipped)."""
        return [e for e in self._slots if e is not None]

    # -- core ops ------------------------------------------------------

    def lookup(self, key: int) -> bool:
        fp, i1 = self._fp_i1(key)
        i2 = self._alt(i1, fp)
        if super().lookup(key):
            return True
        return any(sf == fp and sb in (i1, i2) for sf, sb in self.stash)

    def insert(self, key: int) -> bool:
        """Insert; spills to the stash when the round budget exhausts.

        Chain schedule == kernel ``_evict_rounds`` for a single lane:
        per round, (A) place the carried fingerprint in the first empty
        slot of the current bucket, else (B) kick the first non-dirty slot
        rotating from ``steps % bucket_size``, chase the victim to its
        alternate bucket.  On exhaustion the carried fingerprint parks in
        the first empty stash slot (kicks stay committed); only a full
        stash rolls back.
        """
        fp, i1 = self._fp_i1(key)
        i2 = self._alt(i1, fp)
        for i in (i1, i2):
            slot = np.where(self.table[i] == 0)[0]
            if slot.size:
                self.table[i, slot[0]] = fp
                self.count += 1
                return True
        bucket, carried, steps = i2, np.uint32(fp), 0
        dirty: set[tuple[int, int]] = set()
        hist: list[tuple[int, int, np.uint32]] = []
        for _round in range(self.evict_rounds):
            empty = np.where(self.table[bucket] == 0)[0]
            if empty.size:                        # phase A: place carried
                self.table[bucket, empty[0]] = carried
                self.count += 1
                return True
            slot = None
            for j in range(self.bucket_size):     # first non-dirty slot,
                cand = (steps + j) % self.bucket_size   # rotating
                if (bucket, cand) not in dirty:
                    slot = cand
                    break
            if slot is None:                      # fully-dirty bucket:
                continue                          # burn the round, no kick
            victim = self.table[bucket, slot]
            self.table[bucket, slot] = carried
            dirty.add((bucket, slot))
            hist.append((bucket, slot, carried))
            carried = victim
            bucket = self._alt(bucket, int(carried))
            steps += 1
        for k, entry in enumerate(self._slots):   # spill: first empty slot,
            if entry is None:                     # kicks stay committed
                self._slots[k] = (int(carried), int(bucket))
                self.spills += 1
                return True
        for (bi, bj, w) in reversed(hist):        # stash full too: rollback
            # newest-first restore, identical to the kernel's rb_body:
            # put the carried victim back, pick up what the kick wrote.
            self.table[bi, bj] = carried
            carried = w
        assert carried == fp                      # chain unwound losslessly
        return False

    def delete(self, key: int) -> bool:
        """Verified delete: table copies first, then the stash.

        Mirrors the device order (``ops.filter_delete`` with a stash): the
        fused kernel clears a resident copy when one exists; only a lane
        that misses the table clears its stash slot — zeroed in place, so
        slot positions of the survivors are untouched (bit-for-bit vs the
        device stash).
        """
        if super().delete(key):
            return True
        fp, i1 = self._fp_i1(key)
        i2 = self._alt(i1, fp)
        for k, entry in enumerate(self._slots):
            if entry is not None and entry[0] == fp and entry[1] in (i1, i2):
                self._slots[k] = None
                return True
        return False

    def stash_array(self) -> np.ndarray:
        """The stash as the kernels' uint32[2, slots] layout (tests)."""
        out = np.zeros((2, self.stash_slots), dtype=np.uint32)
        for k, entry in enumerate(self._slots):
            if entry is not None:
                out[0, k] = entry[0]
                out[1, k] = entry[1]
        return out


@dataclasses.dataclass
class PyAdaptiveFilter(PyStashFilter):
    """Sequential oracle for the ADAPTIVE filter — four planes, selectors.

    Extends the stash oracle with the adaptive state's companion planes
    (``adaptive.state.AdaptiveState``): per-slot 2-bit selectors ``sel``
    and mirror key planes ``khi``/``klo``.  The kernel-faithful contracts:

      * bucket geometry is ALWAYS the selector-0 fingerprint's (i1 from the
        key, i2 from fp0) — adaptation changes what a slot stores, never
        where the entry lives;
      * a slot stores ``fingerprint_sel(resident, sel[slot])`` and answers
        lookups/deletes under ITS selector;
      * placements and kicks write selector-0 entries with the key
        mirrored (movement resets adaptation — the standard adaptive-
        cuckoo trade); eviction chains chase the VICTIM's fp0 re-derived
        from its mirror key; rollback restores original plane contents
        verbatim (slot exclusivity via the dirty set makes that identical
        to the carried newest-first unwind);
      * the stash holds selector-0 fingerprints (no selector to bump —
        stash collisions are the reputation tier's problem);
      * ``report_false_positive`` bumps every colliding non-resident slot
        in the candidate pair (i2 pass skipped on involution fixed points)
        and rewrites it from the mirror key.
    """

    def __post_init__(self):
        super().__post_init__()
        shape = (self.n_buckets, self.bucket_size)
        self.sel = np.zeros(shape, dtype=np.uint32)
        self.khi = np.zeros(shape, dtype=np.uint32)
        self.klo = np.zeros(shape, dtype=np.uint32)
        self.adapted = 0

    # -- helpers -------------------------------------------------------

    def _pair(self, key: int) -> tuple[np.uint32, np.uint32]:
        return hashing.key_to_u32_pair_np(np.uint64(key))

    def _fp_sel(self, hi: np.uint32, lo: np.uint32, sel) -> np.ndarray:
        return hashing.fingerprint_sel_np(hi, lo, np.uint32(sel),
                                          self.fp_bits)

    def _write(self, b: int, s: int, fp, sel, hi, lo) -> None:
        self.table[b, s] = fp
        self.sel[b, s] = sel
        self.khi[b, s] = hi
        self.klo[b, s] = lo

    # -- core ops ------------------------------------------------------

    def lookup(self, key: int) -> bool:
        hi, lo = self._pair(key)
        fp, i1 = self._fp_i1(key)
        i2 = self._alt(i1, fp)
        for b in (i1, i2):
            exp = self._fp_sel(hi, lo, self.sel[b])
            if np.any((self.table[b] != 0) & (self.table[b] == exp)):
                return True
        return any(sf == fp and sb in (i1, i2) for sf, sb in self.stash)

    def insert(self, key: int) -> bool:
        """Insert carrying the KEY through the chain (kernel schedule).

        Identical round discipline to ``PyStashFilter.insert``; the carried
        quantity is the key pair so every write mirrors it, kicks re-derive
        the victim's selector-0 geometry from ITS mirror key, and rollback
        restores each kicked slot's original four-plane contents.
        """
        hi, lo = self._pair(key)
        fp, i1 = self._fp_i1(key)
        i2 = self._alt(i1, fp)
        for i in (i1, i2):
            slot = np.where(self.table[i] == 0)[0]
            if slot.size:
                self._write(i, slot[0], fp, 0, hi, lo)
                self.count += 1
                return True
        bucket, chi, clo, steps = i2, hi, lo, 0
        dirty: set[tuple[int, int]] = set()
        hist: list[tuple[int, int, tuple]] = []
        for _round in range(self.evict_rounds):
            cfp = int(hashing.fingerprint_np(chi, clo, self.fp_bits))
            empty = np.where(self.table[bucket] == 0)[0]
            if empty.size:                        # phase A: place carried
                self._write(bucket, empty[0], cfp, 0, chi, clo)
                self.count += 1
                return True
            slot = None
            for j in range(self.bucket_size):     # first non-dirty slot,
                cand = (steps + j) % self.bucket_size   # rotating
                if (bucket, cand) not in dirty:
                    slot = cand
                    break
            if slot is None:                      # fully-dirty bucket:
                continue                          # burn the round, no kick
            orig = (self.table[bucket, slot], self.sel[bucket, slot],
                    self.khi[bucket, slot], self.klo[bucket, slot])
            hist.append((bucket, slot, orig))
            self._write(bucket, slot, cfp, 0, chi, clo)
            dirty.add((bucket, slot))
            chi, clo = orig[2], orig[3]           # victim's mirror key
            vfp0 = int(hashing.fingerprint_np(chi, clo, self.fp_bits))
            bucket = self._alt(bucket, vfp0)      # chase fp0 geometry
            steps += 1
        cfp = int(hashing.fingerprint_np(chi, clo, self.fp_bits))
        for k, entry in enumerate(self._slots):   # spill carried fp0
            if entry is None:
                self._slots[k] = (cfp, int(bucket))
                self.spills += 1
                return True
        for (bi, bj, orig) in reversed(hist):     # stash full: restore
            self._write(bi, bj, *orig)            # originals verbatim
        return False

    def delete(self, key: int) -> bool:
        """Verified delete under slot selectors; table first, then stash."""
        hi, lo = self._pair(key)
        fp, i1 = self._fp_i1(key)
        i2 = self._alt(i1, fp)
        for b in (i1, i2):
            exp = self._fp_sel(hi, lo, self.sel[b])
            slot = np.where((self.table[b] != 0) & (self.table[b] == exp))[0]
            if slot.size:
                self._write(b, slot[0], 0, 0, 0, 0)
                self.count -= 1
                return True
        for k, entry in enumerate(self._slots):
            if entry is not None and entry[0] == fp and entry[1] in (i1, i2):
                self._slots[k] = None
                return True
        return False

    def report_false_positive(self, key: int) -> tuple[bool, bool]:
        """One confirmed-FP repair -> (adapted, resident).

        Bumps every colliding slot in the candidate pair whose mirror key
        differs from the reported key; a slot actually holding the key is
        flagged resident and never repaired.
        """
        hi, lo = self._pair(key)
        fp, i1 = self._fp_i1(key)
        i2 = self._alt(i1, fp)
        adapted = resident = False
        buckets = (i1,) if i2 == i1 else (i1, i2)
        for b in buckets:
            for s in range(self.bucket_size):
                row = self.table[b, s]
                if row == 0:
                    continue
                same = self.khi[b, s] == hi and self.klo[b, s] == lo
                exp = self._fp_sel(hi, lo, self.sel[b, s])
                if same:
                    resident = True
                elif row == exp:
                    nsel = (int(self.sel[b, s]) + 1) & 3
                    nfp = self._fp_sel(self.khi[b, s], self.klo[b, s], nsel)
                    self._write(b, s, nfp, nsel,
                                self.khi[b, s], self.klo[b, s])
                    adapted = True
        self.adapted += bool(adapted)
        return adapted, resident

    # -- plane exports (tests) -----------------------------------------

    def sel_plane_array(self) -> np.ndarray:
        """The selector plane as the kernels' packed uint32[n, 1] layout."""
        shifts = (np.arange(self.bucket_size, dtype=np.uint32)
                  * np.uint32(2))
        packed = np.sum((self.sel & np.uint32(3)) << shifts, axis=-1,
                        dtype=np.uint64).astype(np.uint32)
        return packed[:, None]

    def key_planes(self) -> tuple[np.ndarray, np.ndarray]:
        return self.khi.copy(), self.klo.copy()
