"""Sequential oracle for the stash-extended filter — `pyfilter` + a stash.

``PyStashFilter`` extends the semantic oracle (``core.pyfilter``) with the
overflow stash the streaming subsystem adds to the device data plane.  Its
eviction schedule replicates the *kernel's* chain discipline (probe-then-
kick rounds, dirty-slot exclusion, spill-on-exhaustion) rather than the
classic ``max_displacements`` chain, so that for single-lane residues — one
contended key per batch — the Pallas insert kernel reproduces this oracle
**bit for bit**: same table, same stash entries, same order.  Multi-lane
batches are order-racy by construction on any parallel schedule; there the
parity contract is membership + conservation, not table identity (exactly
the contract the PR-3 eviction tests already use).

The stash is modeled as a fixed array of *slots* (not a compacting list),
mirroring the kernels' uint32[2, slots] layout: a spill takes the first
empty slot in slot order, a delete zeroes its slot in place, and later
spills refill holes first — so the slot-for-slot comparison against the
device stash stays exact through interleaved insert/delete streams (the
distributed write-path tests drive exactly that).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.pyfilter import PyCuckooFilter


@dataclasses.dataclass
class PyStashFilter(PyCuckooFilter):
    """Cuckoo filter + overflow stash, kernel-faithful eviction rounds.

    ``evict_rounds`` plays the kernel's role (bounded rounds, not bounded
    kicks: a round whose bucket is fully dirty burns the round without
    kicking, exactly like a lane losing its rank race).  Stash slots hold
    ``(fp, bucket)`` pairs; by the alternate-index involution the stored
    bucket identifies the fingerprint's candidate pair regardless of which
    end of it the chain held at exhaustion.
    """

    evict_rounds: int = 32
    stash_slots: int = 128

    def __post_init__(self):
        super().__post_init__()
        # Fixed slot array (None == empty) — kernel layout, not a list.
        self._slots: list[tuple[int, int] | None] = [None] * self.stash_slots
        self.spills = 0

    @property
    def stash(self) -> list[tuple[int, int]]:
        """Live (fp, bucket) entries in slot order (holes skipped)."""
        return [e for e in self._slots if e is not None]

    # -- core ops ------------------------------------------------------

    def lookup(self, key: int) -> bool:
        fp, i1 = self._fp_i1(key)
        i2 = self._alt(i1, fp)
        if super().lookup(key):
            return True
        return any(sf == fp and sb in (i1, i2) for sf, sb in self.stash)

    def insert(self, key: int) -> bool:
        """Insert; spills to the stash when the round budget exhausts.

        Chain schedule == kernel ``_evict_rounds`` for a single lane:
        per round, (A) place the carried fingerprint in the first empty
        slot of the current bucket, else (B) kick the first non-dirty slot
        rotating from ``steps % bucket_size``, chase the victim to its
        alternate bucket.  On exhaustion the carried fingerprint parks in
        the first empty stash slot (kicks stay committed); only a full
        stash rolls back.
        """
        fp, i1 = self._fp_i1(key)
        i2 = self._alt(i1, fp)
        for i in (i1, i2):
            slot = np.where(self.table[i] == 0)[0]
            if slot.size:
                self.table[i, slot[0]] = fp
                self.count += 1
                return True
        bucket, carried, steps = i2, np.uint32(fp), 0
        dirty: set[tuple[int, int]] = set()
        hist: list[tuple[int, int, np.uint32]] = []
        for _round in range(self.evict_rounds):
            empty = np.where(self.table[bucket] == 0)[0]
            if empty.size:                        # phase A: place carried
                self.table[bucket, empty[0]] = carried
                self.count += 1
                return True
            slot = None
            for j in range(self.bucket_size):     # first non-dirty slot,
                cand = (steps + j) % self.bucket_size   # rotating
                if (bucket, cand) not in dirty:
                    slot = cand
                    break
            if slot is None:                      # fully-dirty bucket:
                continue                          # burn the round, no kick
            victim = self.table[bucket, slot]
            self.table[bucket, slot] = carried
            dirty.add((bucket, slot))
            hist.append((bucket, slot, carried))
            carried = victim
            bucket = self._alt(bucket, int(carried))
            steps += 1
        for k, entry in enumerate(self._slots):   # spill: first empty slot,
            if entry is None:                     # kicks stay committed
                self._slots[k] = (int(carried), int(bucket))
                self.spills += 1
                return True
        for (bi, bj, w) in reversed(hist):        # stash full too: rollback
            # newest-first restore, identical to the kernel's rb_body:
            # put the carried victim back, pick up what the kick wrote.
            self.table[bi, bj] = carried
            carried = w
        assert carried == fp                      # chain unwound losslessly
        return False

    def delete(self, key: int) -> bool:
        """Verified delete: table copies first, then the stash.

        Mirrors the device order (``ops.filter_delete`` with a stash): the
        fused kernel clears a resident copy when one exists; only a lane
        that misses the table clears its stash slot — zeroed in place, so
        slot positions of the survivors are untouched (bit-for-bit vs the
        device stash).
        """
        if super().delete(key):
            return True
        fp, i1 = self._fp_i1(key)
        i2 = self._alt(i1, fp)
        for k, entry in enumerate(self._slots):
            if entry is not None and entry[0] == fp and entry[1] in (i1, i2):
                self._slots[k] = None
                return True
        return False

    def stash_array(self) -> np.ndarray:
        """The stash as the kernels' uint32[2, slots] layout (tests)."""
        out = np.zeros((2, self.stash_slots), dtype=np.uint32)
        for k, entry in enumerate(self._slots):
            if entry is not None:
                out[0, k] = entry[0]
                out[1, k] = entry[1]
        return out
