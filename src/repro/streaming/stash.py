"""Host-facing wrapper around the device-resident overflow stash.

The device math (layout, fused match, rank-resolved spill) lives in
``repro.kernels.stash`` — one jnp definition shared by the Pallas kernels,
the jnp dispatch arm, and the tests.  This module is the *policy* view the
streaming subsystem holds: occupancy/fill accounting for the admission
signal, and reset-on-retirement for generation rotation.
"""
from __future__ import annotations

import dataclasses

import jax

import jax.numpy as jnp

from repro.kernels.stash import (DEFAULT_STASH_SLOTS, make_stash,
                                 stash_occupancy)


@dataclasses.dataclass
class OverflowStash:
    """A fixed-size overflow stash bound to one filter generation.

    ``array`` is the uint32[2, slots] device buffer the kernels alias
    in→out; rebinding it after each ``FilterOps.insert_spill`` call is the
    only mutation.  ``fill`` (occupancy / slots) is the first half of the
    admission congestion signal (``streaming.admission``).
    """

    slots: int = DEFAULT_STASH_SLOTS
    array: jax.Array = None  # type: ignore[assignment]

    def __post_init__(self):
        if self.array is None:
            self.array = make_stash(self.slots)

    @property
    def occupancy(self) -> int:
        return int(stash_occupancy(self.array))

    @property
    def fill(self) -> float:
        return self.occupancy / self.slots


def make_sharded_stashes(n_shards: int, slots: int = DEFAULT_STASH_SLOTS
                         ) -> jax.Array:
    """Per-shard stash stack: uint32[n_shards, 2, slots] of zeros.

    The distributed write path (``core/distributed.py``) carries one stash
    per shard inside ``ShardedFilterState`` so a shard's eviction-chain
    overflows park on the shard that owns them — sharded with the tables,
    mutated inside the same ``shard_map`` body, never copied to the host.
    """
    assert n_shards > 0 and slots > 0
    return jnp.zeros((n_shards, 2, slots), dtype=jnp.uint32)


def sharded_stash_fill(stashes: jax.Array) -> jax.Array:
    """Per-shard fill fraction -> float32[n_shards].

    The distributed half of the admission congestion signal: the max over
    shards is what a streaming control plane compares against the same
    thresholds ``streaming.admission`` applies to a single generation's
    ``OverflowStash.fill``.
    """
    occ = jnp.sum(stashes[:, 0, :] != 0, axis=-1)
    return occ.astype(jnp.float32) / jnp.float32(stashes.shape[-1])
