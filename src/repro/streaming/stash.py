"""Host-facing wrapper around the device-resident overflow stash.

The device math (layout, fused match, rank-resolved spill) lives in
``repro.kernels.stash`` — one jnp definition shared by the Pallas kernels,
the jnp dispatch arm, and the tests.  This module is the *policy* view the
streaming subsystem holds: occupancy/fill accounting for the admission
signal, and reset-on-retirement for generation rotation.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.kernels.stash import (DEFAULT_STASH_SLOTS, make_stash,
                                 stash_occupancy)


@dataclasses.dataclass
class OverflowStash:
    """A fixed-size overflow stash bound to one filter generation.

    ``array`` is the uint32[2, slots] device buffer the kernels alias
    in→out; rebinding it after each ``FilterOps.insert_spill`` call is the
    only mutation.  ``fill`` (occupancy / slots) is the first half of the
    admission congestion signal (``streaming.admission``).
    """

    slots: int = DEFAULT_STASH_SLOTS
    array: jax.Array = None  # type: ignore[assignment]

    def __post_init__(self):
        if self.array is None:
            self.array = make_stash(self.slots)

    @property
    def occupancy(self) -> int:
        return int(stash_occupancy(self.array))

    @property
    def fill(self) -> float:
        return self.occupancy / self.slots
