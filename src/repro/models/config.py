"""Model configuration covering every assigned architecture family.

One ``ModelConfig`` describes dense / MoE / MLA / SSM / hybrid / vlm / audio
decoder stacks.  Layer heterogeneity (local vs global attention, recurrent vs
attention blocks, dense-then-MoE) is expressed as a repeating ``pattern`` of
block kinds; the transformer scans over pattern *periods* with stacked
weights, so a 94-layer model still traces a single period.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal, Optional, Sequence

BlockKind = Literal["global", "local", "rglru"]


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    n_experts: int
    top_k: int
    expert_d_ff: int
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    norm_topk: bool = True
    first_k_dense: int = 0          # leading dense layers (deepseek-v2)
    router_aux_weight: float = 0.001
    group_size: int = 512           # routing-group tokens (bounds dispatch)


@dataclasses.dataclass(frozen=True)
class MlaConfig:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SsmConfig:
    d_state: int = 128
    head_dim: int = 64
    n_groups: int = 1
    d_conv: int = 4
    expand: int = 2
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class RglruConfig:
    d_rnn: int = 2560               # lru width
    d_conv: int = 4
    block_width_mult: int = 3       # gated-mlp expansion in recurrent block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    activation: Literal["silu_glu", "gelu_glu", "squared_relu", "gelu"] = "silu_glu"
    pattern: Sequence[BlockKind] = ("global",)  # repeats to n_layers
    window: int = 4096                      # for "local" blocks
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6
    use_qk_norm: bool = False
    use_post_norm: bool = False             # gemma sandwich norms
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    embed_scale: bool = False               # gemma: embeddings * sqrt(d)
    tie_embeddings: bool = True
    attn_scale: Optional[float] = None      # override 1/sqrt(head_dim)
    moe: Optional[MoeConfig] = None
    mla: Optional[MlaConfig] = None
    ssm: Optional[SsmConfig] = None
    rglru: Optional[RglruConfig] = None
    # modality stubs (DESIGN.md §5): precomputed frontend embeddings
    prefix_embed_len: int = 0               # vlm: image patch embeddings
    cross_attn_memory_len: int = 0          # audio: text-encoder memory
    cross_attn_memory_dim: int = 0
    cross_attn_every: int = 1               # cross-attn in every k'th layer
    max_seq_len: int = 131072
    dtype: str = "bfloat16"

    # ------------------------------------------------------------ helpers --

    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def period(self) -> int:
        return len(self.pattern)

    def layer_kinds(self) -> list[BlockKind]:
        p = list(self.pattern)
        reps = -(-self.n_layers // len(p))
        return (p * reps)[: self.n_layers]

    @property
    def n_periods(self) -> int:
        body = self.n_layers - (self.moe.first_k_dense if self.moe else 0)
        return body // self.period

    @property
    def tail(self) -> tuple:
        """Leftover layers when n_layers isn't a period multiple: the first
        ``body % period`` pattern entries run once after the scanned periods
        (gemma3: 26 = 4×(5L+1G) + 2L; recurrentgemma: 26 = 8×(R,R,A) + R,R)."""
        body = self.n_layers - (self.moe.first_k_dense if self.moe else 0)
        return tuple(self.pattern[: body % self.period])

    def validate(self) -> None:
        assert self.n_heads % self.n_kv_heads == 0
        if self.family == "moe":
            assert self.moe is not None
        if self.family == "ssm":
            assert self.ssm is not None and all(k == "rglru" for k in []) or True
        _ = self.n_periods  # divisibility check

    # -------------------------------------------------------- param count --

    def param_count(self) -> int:
        """Exact parameter count (used for 6·N·D roofline bookkeeping)."""
        return _count_params(self, active_only=False)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        return _count_params(self, active_only=True)


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    glu = cfg.activation in ("silu_glu", "gelu_glu")
    return cfg.d_model * d_ff * (3 if glu else 2)


def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.head_dim_
    if cfg.mla is not None:
        m = cfg.mla
        q = cfg.d_model * cfg.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
        dkv = cfg.d_model * (m.kv_lora_rank + m.qk_rope_dim)
        up = m.kv_lora_rank * cfg.n_heads * (m.qk_nope_dim + m.v_head_dim)
        o = cfg.n_heads * m.v_head_dim * cfg.d_model
        return q + dkv + up + o
    qkv = cfg.d_model * hd * (cfg.n_heads + 2 * cfg.n_kv_heads)
    return qkv + cfg.n_heads * hd * cfg.d_model


def _ssm_params(cfg: ModelConfig) -> int:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_h = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    in_p = cfg.d_model * (2 * d_in + 2 * s.n_groups * s.d_state + n_h)
    conv = s.d_conv * conv_dim + conv_dim
    extra = n_h * 2 + d_in  # A_log, D, norm
    out_p = d_in * cfg.d_model
    return in_p + conv + extra + out_p


def _rglru_params(cfg: ModelConfig) -> int:
    r = cfg.rglru
    d, dr = cfg.d_model, r.d_rnn
    proj = d * dr * 2 + dr * d                    # two in-branches + out
    conv = r.d_conv * dr + dr
    gates = 2 * dr * dr + 2 * dr + dr             # Wx, Wa + biases + Λ
    return proj + conv + gates


def _block_params(cfg: ModelConfig, kind: BlockKind, layer_idx: int) -> int:
    d = cfg.d_model
    norms = d * (4 if cfg.use_post_norm else 2)
    if kind == "rglru":
        mixer = _rglru_params(cfg)
    elif cfg.family == "ssm":
        mixer = _ssm_params(cfg)
        return mixer + d  # single pre-norm, no mlp in mamba2 blocks
    else:
        mixer = _attn_params(cfg)
        if cfg.use_qk_norm:
            mixer += 2 * cfg.head_dim_
    if cfg.moe is not None and layer_idx >= cfg.moe.first_k_dense:
        m = cfg.moe
        mlp = (m.n_experts * _mlp_params(cfg, m.expert_d_ff)
               + d * m.n_experts
               + (m.n_shared_experts * _mlp_params(
                   cfg, m.shared_d_ff or m.expert_d_ff)))
    else:
        mlp = _mlp_params(cfg, cfg.d_ff)
    cross = 0
    if cfg.cross_attn_memory_len and layer_idx % cfg.cross_attn_every == 0:
        hd = cfg.head_dim_
        cross = (d * cfg.n_heads * hd + 2 * cfg.cross_attn_memory_dim *
                 cfg.n_kv_heads * hd + cfg.n_heads * hd * d + d)
    return mixer + mlp + norms + cross


def _count_params(cfg: ModelConfig, active_only: bool) -> int:
    total = cfg.vocab_size * cfg.d_model  # embed
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * cfg.d_model
    total += cfg.d_model  # final norm
    if cfg.prefix_embed_len:
        total += 0  # frontend is a stub
    for i, kind in enumerate(cfg.layer_kinds()):
        p = _block_params(cfg, kind, i)
        if (active_only and cfg.moe is not None
                and i >= cfg.moe.first_k_dense and kind != "rglru"
                and cfg.family == "moe"):
            m = cfg.moe
            full_experts = m.n_experts * _mlp_params(cfg, m.expert_d_ff)
            active_experts = m.top_k * _mlp_params(cfg, m.expert_d_ff)
            p = p - full_experts + active_experts
        total += p
    return total
