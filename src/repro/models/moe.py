"""Mixture-of-Experts with group-limited, capacity-based routing.

Tokens are routed in fixed-size *groups* (``group_size``, default 512): the
one-hot dispatch/combine tensors are [G, S_g, E, C] with the per-group
capacity ``C = S_g·top_k·cf/E`` — bounded regardless of global batch (the
naive global formulation materializes T×E×C_global, which at
1M tokens × 128 experts is terabytes; groups keep it at megabytes and the
position cumsum inside a group never crosses devices).

Dispatch/combine/expert-compute are all einsums over stacked expert weights
(leading ``expert`` logical axis → the TP mesh axis), so GSPMD lowers the
group→expert exchange to an all-to-all on the EP axis and the compiled
FLOPs reflect active-expert compute only.

``exact=True`` (decode): one group, capacity = n_tokens — no token drops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_mlp, dense_init, init_mlp


def init_moe(key, cfg: ModelConfig, *, stacked=(), stack_spec=()):
    m = cfg.moe
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["router"], s["router"] = dense_init(
        ks[0], (*stacked, cfg.d_model, m.n_experts),
        (*stack_spec, "embed", None))
    p["experts"], s["experts"] = init_mlp(
        ks[1], cfg, m.expert_d_ff, stacked=(*stacked, m.n_experts),
        stack_spec=(*stack_spec, "expert"))
    if m.n_shared_experts:
        p["shared"], s["shared"] = init_mlp(
            ks[2], cfg, (m.shared_d_ff or m.expert_d_ff) * m.n_shared_experts,
            stacked=stacked, stack_spec=stack_spec)
    return p, s


def _expert_ffn(p, cfg: ModelConfig, xe, parallel=None):
    """xe: [G, E, C, D] -> [G, E, C, D] through stacked expert weights.

    Expert weights are resident (expert->model, embed->data)-sharded; the
    use-site constraint keeps only the expert dim sharded so the contraction
    all-gathers the layer's expert weights (ZeRO-3 prefetch) instead of
    all-reducing [G,E,C,F] activations over the data axis (§Perf qwen3).
    """
    from repro.models.layers import use_site_tp
    w_in = use_site_tp(p["w_in"].astype(xe.dtype), (0,), parallel)
    w_out = use_site_tp(p["w_out"].astype(xe.dtype), (0,), parallel)
    h = jnp.einsum("gecd,edf->gecf", xe, w_in)
    if cfg.activation == "silu_glu":
        w_g = use_site_tp(p["w_gate"].astype(xe.dtype), (0,), parallel)
        g = jnp.einsum("gecd,edf->gecf", xe, w_g)
        h = jax.nn.silu(g) * h
    elif cfg.activation == "gelu_glu":
        w_g = use_site_tp(p["w_gate"].astype(xe.dtype), (0,), parallel)
        g = jnp.einsum("gecd,edf->gecf", xe, w_g)
        h = jax.nn.gelu(g, approximate=True) * h
    elif cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("gecf,efd->gecd", h, w_out)


def _constrain(t, spec, parallel):
    """Pin a MoE intermediate's layout (no-op without launcher axis sizes)."""
    if parallel is None or not getattr(parallel, "axis_sizes", None):
        return t
    from jax.sharding import PartitionSpec as P
    ok = all(s is None or (parallel.size_of(s) > 0
                           and t.shape[i] % parallel.size_of(s) == 0)
             for i, s in enumerate(spec))
    if not ok:
        return t
    return jax.lax.with_sharding_constraint(t, P(*spec))


def apply_moe(p, cfg: ModelConfig, x, *, exact: bool = False, parallel=None):
    """x: [B, S, E] -> (y, aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    n_tok = b * s
    gs = getattr(m, "group_size", 512)
    if exact or n_tok <= gs:
        G, gs_eff = 1, n_tok
    else:
        assert n_tok % gs == 0, f"{n_tok} tokens not divisible by group {gs}"
        G, gs_eff = n_tok // gs, gs
    xt = x.reshape(G, gs_eff, d)

    logits = (xt @ p["router"].astype(x.dtype)).astype(jnp.float32)  # [G,T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)            # [G,T,k]
    if m.norm_topk:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    if exact:
        capacity = gs_eff
    else:
        capacity = max(1, int(gs_eff * m.top_k * m.capacity_factor
                              / m.n_experts))

    onehot = jax.nn.one_hot(expert_idx, m.n_experts,
                            dtype=jnp.int32)                  # [G,T,k,E]
    flat = onehot.reshape(G, gs_eff * m.top_k, m.n_experts)
    pos = jnp.cumsum(flat, axis=1) * flat - 1                 # in-group rank
    pos = pos.reshape(G, gs_eff, m.top_k, m.n_experts)
    pos_tk = jnp.take_along_axis(pos, expert_idx[..., None], axis=3)[..., 0]
    keep = (pos_tk >= 0) & (pos_tk < capacity)                # [G,T,k]
    # one-hots in the compute dtype: these [G,T,E,C] tensors dominate the
    # MoE memory term at f32 (§Perf qwen3/iter3) — bf16 halves the traffic
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos_tk, capacity),
                            capacity, dtype=x.dtype)          # [G,T,k,C]
    sel = onehot.astype(x.dtype) * keep[..., None].astype(x.dtype)
    disp = jnp.einsum("gtke,gtkc->gtec", sel, pos_oh)         # [G,T,E,C]
    comb = jnp.einsum("gtke,gtkc->gtec",
                      sel * gate_vals[..., None].astype(x.dtype), pos_oh)

    da = parallel.data_axis if parallel else None
    ma = parallel.model_axis if parallel else None
    disp = _constrain(disp, (da, None, ma, None), parallel)
    comb = _constrain(comb, (da, None, ma, None), parallel)
    xe = jnp.einsum("gtec,gtd->gecd", disp, xt)
    xe = _constrain(xe, (da, ma, None, None), parallel)
    ye = _expert_ffn(p["experts"], cfg, xe, parallel)
    ye = _constrain(ye, (da, ma, None, None), parallel)
    y = jnp.einsum("gtec,gecd->gtd", comb, ye)

    if m.n_shared_experts:
        y = y + apply_mlp(p["shared"], cfg, xt, parallel)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], m.n_experts),
                  axis=(0, 1))
    aux = m.n_experts * jnp.sum(me * ce) * m.router_aux_weight
    return y.reshape(b, s, d), aux
