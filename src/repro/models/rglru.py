"""RecurrentGemma / Griffin recurrent block: causal conv + RG-LRU.

RG-LRU (arXiv:2402.19427):
    r_t = sigmoid(W_a x_t + b_a)             recurrence gate
    i_t = sigmoid(W_x x_t + b_x)             input gate
    a_t = a ** (c * r_t),  a = sigmoid(Λ)    (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Train/prefill: the diagonal linear recurrence is evaluated with
``jax.lax.associative_scan`` (log-depth, TPU-friendly) instead of a
sequential loop.  Decode: one-step update on a constant-size state [B, D_rnn]
(why recurrentgemma runs the ``long_500k`` shape).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init

_C = 8.0


class RglruCache(NamedTuple):
    conv: jax.Array   # [B, d_conv-1, d_rnn]
    h: jax.Array      # [B, d_rnn]


def init_rglru(key, cfg: ModelConfig, *, stacked=(), stack_spec=()):
    r = cfg.rglru
    d, dr = cfg.d_model, r.d_rnn
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["w_in_rnn"], s["w_in_rnn"] = dense_init(
        ks[0], (*stacked, d, dr), (*stack_spec, "embed", "rnn"))
    p["w_in_gate"], s["w_in_gate"] = dense_init(
        ks[1], (*stacked, d, dr), (*stack_spec, "embed", "rnn"))
    p["conv_w"], s["conv_w"] = dense_init(
        ks[2], (*stacked, r.d_conv, dr), (*stack_spec, None, "rnn"))
    p["conv_b"], s["conv_b"] = jnp.zeros((*stacked, dr)), (*stack_spec, "rnn")
    p["w_a"], s["w_a"] = dense_init(ks[3], (*stacked, dr, dr),
                                    (*stack_spec, "rnn", "rnn"))
    p["b_a"], s["b_a"] = jnp.zeros((*stacked, dr)), (*stack_spec, "rnn")
    p["w_x"], s["w_x"] = dense_init(ks[4], (*stacked, dr, dr),
                                    (*stack_spec, "rnn", "rnn"))
    p["b_x"], s["b_x"] = jnp.zeros((*stacked, dr)), (*stack_spec, "rnn")
    # Λ init so the effective decay a = sigmoid(Λ)^c lies in [0.9, 0.999]
    y = jnp.linspace(0.9, 0.999, dr) ** (1.0 / _C)
    lam = jnp.log(y / (1.0 - y))
    p["lam"], s["lam"] = (jnp.broadcast_to(lam, (*stacked, dr)).copy(),
                          (*stack_spec, "rnn"))
    p["w_out"], s["w_out"] = dense_init(
        ks[5], (*stacked, dr, d), (*stack_spec, "rnn", "embed"))
    return p, s


def _conv(x, w, b, prev: Optional[jax.Array]):
    k = w.shape[0]
    if prev is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = prev.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
              for i in range(k))
    new_prev = xp[:, -(k - 1):, :] if k > 1 else None
    return out + b.astype(x.dtype), new_prev


def _rglru_scan(x, a, *, h0: Optional[jax.Array] = None):
    """h_t = a_t h_{t-1} + b_t via associative scan.  x=b_t: [B,S,D]."""
    if h0 is not None:
        # fold initial state into the first step: b_0' = a_0 h0 + b_0
        x = x.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, x), axis=1)
    return h


def apply_rglru(p, cfg: ModelConfig, x, *, cache: Optional[RglruCache] = None,
                parallel=None):
    """Griffin recurrent block. x: [B,S,E] -> (y, new_cache)."""
    from repro.models.layers import use_site_tp
    b, s, _ = x.shape
    w_ig = use_site_tp(p["w_in_gate"].astype(x.dtype), (-1,), parallel)
    w_ir = use_site_tp(p["w_in_rnn"].astype(x.dtype), (-1,), parallel)
    gate = jax.nn.gelu(x @ w_ig, approximate=True)
    u = x @ w_ir
    u, new_conv = _conv(u, p["conv_w"], p["conv_b"],
                        cache.conv if cache is not None else None)
    # Gate matmuls: contraction over the full dr — gather u once (bf16,
    # dr-replicated via the constraint below) and run both gate matmuls
    # column-parallel (w_a/w_x constrained TP-only) so the only collective
    # is one small activation gather, not two full-width f32 all-reduces
    # (§Perf rg iterations).  Sigmoids still run in f32.
    from repro.models.layers import use_site_tp as _ust
    w_a = _ust(p["w_a"].astype(u.dtype), (-1,), parallel)
    w_x = _ust(p["w_x"].astype(u.dtype), (-1,), parallel)
    r = jax.nn.sigmoid((u @ w_a).astype(jnp.float32)
                       + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid((u @ w_x).astype(jnp.float32)
                       + p["b_x"].astype(jnp.float32))
    uf = u.astype(jnp.float32)
    log_a_base = jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))
    log_a = _C * r * log_a_base                    # [B,S,D]
    a = jnp.exp(log_a)
    gated = i * uf
    scaled = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    if cache is None:
        h = _rglru_scan(scaled, a)
        new_cache = None
    elif s == 1:
        h = a * cache.h.astype(jnp.float32)[:, None] + scaled  # decode step
        new_cache = RglruCache(conv=new_conv.astype(cache.conv.dtype),
                               h=h[:, -1].astype(cache.h.dtype))
    else:  # prefill: scan with the cached initial state, emit the final one
        h = _rglru_scan(scaled, a, h0=cache.h.astype(jnp.float32))
        new_cache = RglruCache(conv=new_conv.astype(cache.conv.dtype),
                               h=h[:, -1].astype(cache.h.dtype))
    w_out = use_site_tp(p["w_out"].astype(x.dtype), (-2,), parallel)
    y = (h.astype(x.dtype) * gate) @ w_out
    return y, new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32
                     ) -> RglruCache:
    r = cfg.rglru
    return RglruCache(conv=jnp.zeros((batch, r.d_conv - 1, r.d_rnn), dtype),
                      h=jnp.zeros((batch, r.d_rnn), dtype))
