"""Mamba-2 mixer via SSD (state-space duality, arXiv:2405.21060).

Train/prefill: chunked dual form — intra-chunk attention-like matmuls (MXU)
plus an inter-chunk linear recurrence over chunk summaries (lax.scan of
length S/chunk).  Decode: exact single-step recurrence on a constant-size
state [B, H, P, N] + rolling conv window — which is why mamba2 is a
``long_500k`` architecture: the "KV cache" never grows.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rmsnorm


class SsmCache(NamedTuple):
    conv: jax.Array   # [B, d_conv-1, conv_dim]  rolling conv input window
    state: jax.Array  # [B, H, P, N]             SSM state


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, n_heads, conv_dim


def init_ssm(key, cfg: ModelConfig, *, stacked=(), stack_spec=()):
    s, d_in, n_heads, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    p, sp = {}, {}
    d_proj = 2 * d_in + 2 * s.n_groups * s.d_state + n_heads
    p["in_proj"], sp["in_proj"] = dense_init(
        ks[0], (*stacked, cfg.d_model, d_proj), (*stack_spec, "embed", "mlp"))
    p["conv_w"], sp["conv_w"] = dense_init(
        ks[1], (*stacked, s.d_conv, conv_dim), (*stack_spec, None, "mlp"))
    p["conv_b"], sp["conv_b"] = (jnp.zeros((*stacked, conv_dim)),
                                 (*stack_spec, "mlp"))
    p["A_log"], sp["A_log"] = (
        jnp.log(jnp.broadcast_to(
            jnp.linspace(1.0, 16.0, n_heads), (*stacked, n_heads)).copy()),
        (*stack_spec, None))
    p["D"], sp["D"] = jnp.ones((*stacked, n_heads)), (*stack_spec, None)
    p["dt_bias"], sp["dt_bias"] = (
        jnp.log(jnp.expm1(jnp.broadcast_to(
            jnp.exp(jnp.linspace(jnp.log(1e-3), jnp.log(1e-1), n_heads)),
            (*stacked, n_heads)).copy())),
        (*stack_spec, None))
    p["norm"], sp["norm"] = jnp.ones((*stacked, d_in)), (*stack_spec, "mlp")
    p["out_proj"], sp["out_proj"] = dense_init(
        ks[2], (*stacked, d_in, cfg.d_model), (*stack_spec, "mlp", "embed"))
    return p, sp


def _split_proj(cfg, zxbcdt):
    s, d_in, n_heads, conv_dim = _dims(cfg)
    gn = s.n_groups * s.d_state
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + conv_dim]
    dt = zxbcdt[..., d_in + conv_dim:]
    return z, xbc, dt


def _causal_conv(xbc, w, b, *, prev: Optional[jax.Array] = None):
    """Depthwise causal conv1d. xbc: [B, S, C]; w: [K, C]."""
    k = w.shape[0]
    if prev is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = prev.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)  # [B, S+K-1, C]
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i].astype(xbc.dtype)
              for i in range(k))
    new_prev = xp[:, -(k - 1):, :] if k > 1 else None
    return jax.nn.silu(out + b.astype(xbc.dtype)), new_prev


def _segsum(x):
    """Lower-tri cumulative segment sums: out[..., i, j] = sum_{j<k<=i} x[k]."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, *, chunk: int,
                init_state: Optional[jax.Array] = None):
    """SSD dual form. x:[b,s,h,p] dt:[b,s,h] A:[h] B,C:[b,s,g,n].

    Returns (y [b,s,h,p], final_state [b,h,p,n]).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0
    nc = s // chunk
    rep = h // g
    xb = x.reshape(b, nc, chunk, h, p)
    dtb = dt.reshape(b, nc, chunk, h)
    Bb = jnp.repeat(B.reshape(b, nc, chunk, g, n), rep, axis=3)
    Cb = jnp.repeat(C.reshape(b, nc, chunk, g, n), rep, axis=3)

    dA = dtb * A[None, None, None, :]              # [b,nc,l,h]
    dA_cs = jnp.cumsum(dA, axis=2)
    # intra-chunk (diagonal blocks): attention-like matmul with decay mask
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # [b,nc,h,l,l]
    scores = jnp.einsum("bclhn,bcshn->bchls", Cb, Bb)  # [b,nc,h,l,l]
    xdt = xb * dtb[..., None]                      # [b,nc,l,h,p]
    y_diag = jnp.einsum("bchls,bcshp->bclhp", scores * L, xdt)

    # chunk summaries -> inter-chunk scan
    decay_last = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)        # [b,nc,l,h]
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", Bb, decay_last * dtb, xb)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                # [b,nc,h]

    def scan_fn(carry, inp):
        st, dec = inp            # st:[b,h,p,n], dec:[b,h]
        new = carry * dec[:, :, None, None] + st
        return new, carry        # emit state *entering* the chunk

    init = (jnp.zeros((b, h, p, n), x.dtype) if init_state is None
            else init_state.astype(x.dtype))
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # [b,nc,h,p,n]

    state_decay = jnp.exp(dA_cs)                             # [b,nc,l,h]
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", Cb, prev_states, state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def apply_ssm(p, cfg: ModelConfig, x, *, cache: Optional[SsmCache] = None,
              parallel=None):
    """x: [B, S, E] -> (y, new_cache).  cache!=None => S must be 1 (decode)."""
    from repro.models.layers import use_site_tp
    s_cfg, d_in, n_heads, conv_dim = _dims(cfg)
    bsz, seq, _ = x.shape
    w_inp = use_site_tp(p["in_proj"].astype(x.dtype), (-1,), parallel)
    zxbcdt = x @ w_inp
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # [B,S,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # [H]

    if cache is None or seq > 1:
        # train (cache=None) or prefill (cache written with the final state)
        prev = cache.conv if cache is not None else None
        xbc_c, new_prev = _causal_conv(xbc, p["conv_w"], p["conv_b"], prev=prev)
        gn = s_cfg.n_groups * s_cfg.d_state
        xs = xbc_c[..., :d_in].reshape(bsz, seq, n_heads, s_cfg.head_dim)
        B = xbc_c[..., d_in:d_in + gn].reshape(bsz, seq, s_cfg.n_groups,
                                               s_cfg.d_state)
        C = xbc_c[..., d_in + gn:].reshape(bsz, seq, s_cfg.n_groups,
                                           s_cfg.d_state)
        y, final = ssd_chunked(
            xs.astype(jnp.float32), dt, A, B.astype(jnp.float32),
            C.astype(jnp.float32),
            chunk=min(s_cfg.chunk, seq),
            init_state=cache.state if cache is not None else None)
        new_cache = None if cache is None else SsmCache(
            conv=new_prev.astype(cache.conv.dtype),
            state=final.astype(cache.state.dtype))
    else:
        prev = cache.conv
        xbc_c, new_prev = _causal_conv(xbc, p["conv_w"], p["conv_b"], prev=prev)
        gn = s_cfg.n_groups * s_cfg.d_state
        xs = xbc_c[..., :d_in].reshape(bsz, seq, n_heads, s_cfg.head_dim)
        B = xbc_c[..., d_in:d_in + gn].reshape(bsz, seq, s_cfg.n_groups,
                                               s_cfg.d_state)
        C = xbc_c[..., d_in + gn:].reshape(bsz, seq, s_cfg.n_groups,
                                           s_cfg.d_state)
        rep = n_heads // s_cfg.n_groups
        Br = jnp.repeat(B, rep, axis=2)[:, 0]   # [B,H,N]
        Cr = jnp.repeat(C, rep, axis=2)[:, 0]
        dt1 = dt[:, 0]                           # [B,H]
        dA = jnp.exp(dt1 * A[None, :])           # [B,H]
        xs1 = xs[:, 0].astype(jnp.float32)       # [B,H,P]
        st = (cache.state.astype(jnp.float32) * dA[..., None, None]
              + jnp.einsum("bhp,bhn->bhpn", xs1 * dt1[..., None],
                           Br.astype(jnp.float32)))
        y = jnp.einsum("bhpn,bhn->bhp", st, Cr.astype(jnp.float32))[:, None]
        new_cache = SsmCache(conv=new_prev.astype(cache.conv.dtype),
                             state=st.astype(cache.state.dtype))
        y = y.reshape(bsz, seq, n_heads, s_cfg.head_dim)

    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, seq, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.rms_eps)
    w_outp = use_site_tp(p["out_proj"].astype(x.dtype), (-2,), parallel)
    return y @ w_outp, new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SsmCache:
    s, d_in, n_heads, conv_dim = _dims(cfg)
    return SsmCache(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        state=jnp.zeros((batch, n_heads, s.head_dim, s.d_state), dtype))
