"""Decoder-stack assembly over heterogeneous layer patterns.

The stack = optional dense-prefix layers (DeepSeek first_k_dense) followed by
``n_periods`` repetitions of ``cfg.pattern``.  Weights for each pattern slot
are stacked on a leading ``layers`` axis and the forward pass is a
``lax.scan`` over periods — one period is traced regardless of depth (a
94-layer qwen3 compiles the same HLO size as a 4-layer smoke model).

Caches mirror the weight layout: per pattern slot, a cache pytree stacked
over periods.  ``apply`` (train), ``prefill`` and ``decode_step`` share the
same block code, differing only in cache handling.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import KVCache
from repro.models.config import ModelConfig
from repro.models.layers import (apply_mlp, embed, init_embedding, init_mlp,
                                 init_rmsnorm, rmsnorm, unembed)


@dataclasses.dataclass
class ModelOutput:
    logits: jax.Array
    aux_loss: jax.Array
    cache: Any = None


def _block_uses_moe(cfg: ModelConfig, in_prefix: bool) -> bool:
    return cfg.moe is not None and not in_prefix


# ----------------------------------------------------------------- init ----


def _init_block(key, cfg: ModelConfig, kind: str, *, stacked, stack_spec,
                in_prefix: bool = False):
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["norm1"], s["norm1"] = (jnp.ones((*stacked, cfg.d_model)),
                              (*stack_spec, "embed"))
    if kind == "ssm":
        p["mixer"], s["mixer"] = ssm_mod.init_ssm(
            ks[0], cfg, stacked=stacked, stack_spec=stack_spec)
        return p, s  # mamba2 block = norm + mixer only
    if kind == "rglru":
        p["mixer"], s["mixer"] = rglru_mod.init_rglru(
            ks[0], cfg, stacked=stacked, stack_spec=stack_spec)
    elif cfg.mla is not None:
        p["mixer"], s["mixer"] = attn_mod.init_mla(
            ks[0], cfg, stacked=stacked, stack_spec=stack_spec)
    else:
        p["mixer"], s["mixer"] = attn_mod.init_attention(
            ks[0], cfg, stacked=stacked, stack_spec=stack_spec)
    if cfg.use_post_norm:
        p["norm1b"], s["norm1b"] = (jnp.ones((*stacked, cfg.d_model)),
                                    (*stack_spec, "embed"))
    p["norm2"], s["norm2"] = (jnp.ones((*stacked, cfg.d_model)),
                              (*stack_spec, "embed"))
    if _block_uses_moe(cfg, in_prefix) and kind != "rglru":
        p["mlp"], s["mlp"] = moe_mod.init_moe(
            ks[1], cfg, stacked=stacked, stack_spec=stack_spec)
    else:
        p["mlp"], s["mlp"] = init_mlp(
            ks[1], cfg, cfg.d_ff, stacked=stacked, stack_spec=stack_spec)
    if cfg.use_post_norm:
        p["norm2b"], s["norm2b"] = (jnp.ones((*stacked, cfg.d_model)),
                                    (*stack_spec, "embed"))
    if cfg.cross_attn_memory_len and kind in ("global", "local"):
        p["xattn"], s["xattn"] = attn_mod.init_cross_attention(
            ks[2], cfg, stacked=stacked, stack_spec=stack_spec)
        p["norm_x"], s["norm_x"] = (jnp.ones((*stacked, cfg.d_model)),
                                    (*stack_spec, "embed"))
    return p, s


class Transformer:
    def __init__(self, cfg: ModelConfig):
        cfg.validate()
        self.cfg = cfg
        self.prefix_k = cfg.moe.first_k_dense if cfg.moe else 0

    # ------------------------------------------------------------ init ---

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 4 + len(cfg.pattern))
        p, s = {}, {}
        p["embed"], s["embed"] = init_embedding(ks[0], cfg)
        if not cfg.tie_embeddings:
            p["lm_head"], s["lm_head"] = init_embedding(ks[1], cfg)
        p["final_norm"], s["final_norm"] = (jnp.ones((cfg.d_model,)),
                                            ("embed",))
        if self.prefix_k:
            p["prefix"], s["prefix"] = _init_block(
                ks[2], cfg, cfg.pattern[0], stacked=(self.prefix_k,),
                stack_spec=("layers",), in_prefix=True)
        blocks_p, blocks_s = [], []
        for j, kind in enumerate(cfg.pattern):
            bp, bs = _init_block(ks[3 + j], cfg, kind,
                                 stacked=(cfg.n_periods,),
                                 stack_spec=("layers",))
            blocks_p.append(bp)
            blocks_s.append(bs)
        p["blocks"], s["blocks"] = tuple(blocks_p), tuple(blocks_s)
        if cfg.tail:
            tks = jax.random.split(ks[-1], len(cfg.tail))
            tail_p, tail_s = [], []
            for j, kind in enumerate(cfg.tail):
                bp, bs = _init_block(tks[j], cfg, kind, stacked=(1,),
                                     stack_spec=("layers",))
                tail_p.append(bp)
                tail_s.append(bs)
            p["tail"], s["tail"] = tuple(tail_p), tuple(tail_s)
        return p, s

    # ------------------------------------------------------------ block --

    def _apply_block(self, p, kind, x, *, positions, memory, cache=None,
                     cache_pos=None, parallel=None):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        h = rmsnorm(x, p["norm1"], cfg.rms_eps)
        new_cache = None
        # Use-site weight gathering pays off when activations >> weights
        # (train/prefill); at decode the activation all-reduce is cheaper
        # than re-gathering weights every step (§Perf dsv2 iter5 refuted it
        # for decode) — so disable it there.
        if cache is not None and x.shape[1] == 1:
            parallel = None if parallel is None else dataclasses.replace(
                parallel, axis_sizes=None)
        if kind == "ssm":
            out, new_cache = ssm_mod.apply_ssm(p["mixer"], cfg, h, cache=cache,
                                               parallel=parallel)
            return x + out, new_cache, aux
        if kind == "rglru":
            out, new_cache = rglru_mod.apply_rglru(p["mixer"], cfg, h,
                                                   cache=cache,
                                                   parallel=parallel)
        elif cfg.mla is not None:
            out, new_cache = attn_mod.apply_mla(p["mixer"], cfg, h,
                                                positions=positions,
                                                cache=cache,
                                                cache_pos=cache_pos,
                                                parallel=parallel)
        else:
            window = cfg.window if kind == "local" else None
            out, new_cache = attn_mod.apply_attention(
                p["mixer"], cfg, h, positions=positions, window=window,
                cache=cache, cache_pos=cache_pos, parallel=parallel)
        if cfg.use_post_norm:
            out = rmsnorm(out, p["norm1b"], cfg.rms_eps)
        x = x + out
        if "xattn" in p and memory is not None:
            hx = rmsnorm(x, p["norm_x"], cfg.rms_eps)
            x = x + attn_mod.apply_cross_attention(p["xattn"], cfg, hx, memory)
        h = rmsnorm(x, p["norm2"], cfg.rms_eps)
        if "router" in p["mlp"]:
            exact = cache is not None and x.shape[1] == 1  # decode: no drops
            out, aux = moe_mod.apply_moe(p["mlp"], cfg, h, exact=exact,
                                         parallel=parallel)
        else:
            out = apply_mlp(p["mlp"], cfg, h, parallel)
        if cfg.use_post_norm:
            out = rmsnorm(out, p["norm2b"], cfg.rms_eps)
        return x + out, new_cache, aux

    # ------------------------------------------------------------ apply --

    def apply(self, params, tokens, *, prefix_embeds=None, memory=None,
              cache=None, cache_pos=None, remat: str = "none",
              parallel=None):
        """tokens: [B, S] -> ModelOutput.

        ``prefix_embeds`` [B, P, E] (vlm stub) are prepended to the token
        embeddings.  With ``cache`` this is prefill/decode; logits cover the
        token positions only.
        """
        cfg = self.cfg
        x = embed(params["embed"], tokens, cfg)
        n_prefix_tok = 0
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
            n_prefix_tok = prefix_embeds.shape[1]
        b, s, _ = x.shape
        start = cache_pos if cache_pos is not None else 0
        positions = start + jnp.arange(s)[None, :].repeat(b, 0)

        aux_total = jnp.zeros((), jnp.float32)

        def make_block_fn(kind):
            def run(lp, x, lc):
                from repro.distributed.sharding import \
                    constrain_batch_activations
                x = constrain_batch_activations(x, parallel)
                return self._apply_block(lp, kind, x, positions=positions,
                                         memory=memory, cache=lc,
                                         cache_pos=cache_pos,
                                         parallel=parallel)
            if remat == "full":
                return jax.checkpoint(
                    run, policy=jax.checkpoint_policies.nothing_saveable)
            if remat == "dots":
                return jax.checkpoint(
                    run,
                    policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            return run

        new_prefix_cache = None
        if self.prefix_k:
            prefix_fn = make_block_fn(cfg.pattern[0])
            pcache = cache["prefix"] if cache is not None else None

            def prefix_step(carry, xs):
                x, aux = carry
                lp, lc = xs
                x, nc, a = prefix_fn(lp, x, lc)
                return (x, aux + a), nc

            (x, aux_total), new_prefix_cache = jax.lax.scan(
                prefix_step, (x, aux_total), (params["prefix"], pcache))

        period = cfg.pattern
        caches = cache["blocks"] if cache is not None else [None] * len(period)
        new_caches = []
        for j, kind in enumerate(period):
            block_fn = make_block_fn(kind)

            def period_step(carry, xs, _fn=block_fn):
                x, aux = carry
                lp, lc = xs
                x, nc, a = _fn(lp, x, lc)
                return (x, aux + a), nc

            (x, aux_total), nc = jax.lax.scan(
                period_step, (x, aux_total), (params["blocks"][j], caches[j]))
            new_caches.append(nc)

        new_tail = []
        if cfg.tail:
            tcaches = (cache["tail"] if cache is not None
                       else [None] * len(cfg.tail))
            for j, kind in enumerate(cfg.tail):
                block_fn = make_block_fn(kind)

                def tail_step(carry, xs, _fn=block_fn):
                    x, aux = carry
                    lp, lc = xs
                    x, nc, a = _fn(lp, x, lc)
                    return (x, aux + a), nc

                (x, aux_total), nc = jax.lax.scan(
                    tail_step, (x, aux_total),
                    (params["tail"][j], tcaches[j]))
                new_tail.append(nc)

        x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
        head = params.get("lm_head", params["embed"])
        logits = unembed(head, x[:, n_prefix_tok:], cfg)
        out_cache = None
        if cache is not None:
            out_cache = dict(cache)
            out_cache["blocks"] = new_caches
            if cfg.tail:
                out_cache["tail"] = new_tail
            if self.prefix_k:
                out_cache["prefix"] = new_prefix_cache
        return ModelOutput(logits=logits, aux_loss=aux_total, cache=out_cache)

    # ------------------------------------------------------------ cache --

    def _slot_cache(self, kind, n, batch, max_len, dtype, *, window_bound):
        cfg = self.cfg
        hkv, hd = cfg.n_kv_heads, cfg.head_dim_
        if kind == "ssm":
            c = ssm_mod.init_ssm_cache(cfg, batch, dtype)
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n, *a.shape)).copy(), c)
        if kind == "rglru":
            c = rglru_mod.init_rglru_cache(cfg, batch, dtype)
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n, *a.shape)).copy(), c)
        if cfg.mla is not None:
            m = cfg.mla
            width = m.kv_lora_rank + m.qk_rope_dim
            return KVCache(k=jnp.zeros((n, batch, 1, max_len, width), dtype),
                           v=jnp.zeros((n, 1, 1, 1, 1), dtype))
        klen = max_len
        if window_bound and kind == "local":
            klen = min(max_len, cfg.window)
        return KVCache(k=jnp.zeros((n, batch, hkv, klen, hd), dtype),
                       v=jnp.zeros((n, batch, hkv, klen, hd), dtype))

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   window_bound: bool = False):
        """Cache pytree: per pattern slot, stacked over periods.

        ``window_bound=True`` allocates local-attention slots at window size
        (ring-buffer decode — the long_500k memory optimization)."""
        cfg = self.cfg
        n = cfg.n_periods
        out = {"blocks": [
            self._slot_cache(kind, n, batch, max_len, dtype,
                             window_bound=window_bound)
            for kind in cfg.pattern]}
        if cfg.tail:
            out["tail"] = [
                self._slot_cache(kind, 1, batch, max_len, dtype,
                                 window_bound=window_bound)
                for kind in cfg.tail]
        if self.prefix_k:
            out["prefix"] = self._slot_cache(
                cfg.pattern[0], self.prefix_k, batch, max_len, dtype,
                window_bound=window_bound)
        return out

    def decode_step(self, params, cache, tokens, pos, *, memory=None):
        """tokens: [B, 1]; pos: scalar int32 — one decode step."""
        return self.apply(params, tokens, memory=memory, cache=cache,
                          cache_pos=pos)
