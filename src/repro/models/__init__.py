from repro.models.config import (MlaConfig, ModelConfig, MoeConfig,
                                 RglruConfig, SsmConfig)
from repro.models.transformer import Transformer
