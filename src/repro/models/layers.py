"""Shared layer primitives + the (params, specs) convention.

Every ``init_*`` returns two parallel pytrees: ``params`` (arrays) and
``specs`` (tuples of *logical* axis names per array dim).  The sharding layer
(``repro.distributed.sharding``) maps logical names to mesh axes, so model
code never mentions "data"/"model" directly.

Logical axes used across the stack:
  embed   — d_model                (FSDP axis)
  heads   — flattened q-head dim   (TP axis)
  kv      — flattened kv-head dim  (TP axis)
  mlp     — d_ff                   (TP axis)
  vocab   — vocabulary             (TP axis)
  expert  — MoE experts            (EP axis)
  layers  — stacked scan layers    (never sharded)
  rnn/state/conv/mem/lora — family-specific, replicated or TP as configured
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

# ------------------------------------------------------------------ init ---


def use_site_tp(w, tp_dims: tuple, parallel):
    """Constrain a weight at its use site to TP-only sharding.

    Resident weights are FSDP-sharded (an axis over ``data``); contracting
    against them in that layout makes GSPMD partial-sum the *activations*
    over the data axis — gigabytes of all-reduce per layer (§Perf
    qwen3/rg iterations).  Re-constraining the weight to keep only its TP
    dims sharded forces the cheap choice: an all-gather of the (small)
    weight, exactly ZeRO-3's per-layer prefetch.  No-op without a mesh.
    """
    if parallel is None or not getattr(parallel, "axis_sizes", None):
        return w
    m = parallel.size_of(parallel.model_axis)
    if m <= 1:
        return w
    spec = [None] * w.ndim
    for d in tp_dims:
        if w.shape[d] % m == 0:
            spec[d] = parallel.model_axis
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(w, P(*spec))


def dense_init(key, shape, specs, in_axis=-2, dtype=jnp.float32):
    """Truncated-normal fan-in init; returns (param, spec)."""
    fan_in = shape[in_axis] if shape else 1
    std = 1.0 / math.sqrt(max(1, fan_in))
    p = std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)
    return p, specs


def zeros_init(shape, specs, dtype=jnp.float32):
    return jnp.zeros(shape, dtype), specs


# ----------------------------------------------------------------- norms ---


def init_rmsnorm(d: int, spec_axis: str = "embed"):
    return jnp.ones((d,), jnp.float32), (spec_axis,)


def rmsnorm(x, w, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ rope ---


def rope(x, positions, theta: float = 10000.0):
    """Rotary embedding. x: [..., S, D] with D even; positions: [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- mlp ---


def init_mlp(key, cfg: ModelConfig, d_ff: int, *, stacked: tuple[int, ...] = (),
             stack_spec: tuple[str, ...] = ()):
    """GLU / plain MLP params. ``stacked``: leading dims (layers, experts…)."""
    d = cfg.d_model
    glu = cfg.activation in ("silu_glu", "gelu_glu")
    ks = jax.random.split(key, 3)
    params, specs = {}, {}
    params["w_in"], specs["w_in"] = dense_init(
        ks[0], (*stacked, d, d_ff), (*stack_spec, "embed", "mlp"))
    if glu:
        params["w_gate"], specs["w_gate"] = dense_init(
            ks[1], (*stacked, d, d_ff), (*stack_spec, "embed", "mlp"))
    params["w_out"], specs["w_out"] = dense_init(
        ks[2], (*stacked, d_ff, d), (*stack_spec, "mlp", "embed"), in_axis=-2)
    return params, specs


def apply_mlp(p, cfg: ModelConfig, x, parallel=None):
    w_in = use_site_tp(p["w_in"].astype(x.dtype), (-1,), parallel)
    h = x @ w_in
    if cfg.activation == "silu_glu":
        w_g = use_site_tp(p["w_gate"].astype(x.dtype), (-1,), parallel)
        h = jax.nn.silu(x @ w_g) * h
    elif cfg.activation == "gelu_glu":
        w_g = use_site_tp(p["w_gate"].astype(x.dtype), (-1,), parallel)
        h = jax.nn.gelu(x @ w_g, approximate=True) * h
    elif cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h, approximate=True)
    w_out = use_site_tp(p["w_out"].astype(x.dtype), (-2,), parallel)
    return h @ w_out


# ------------------------------------------------------------- embedding ---


def init_embedding(key, cfg: ModelConfig):
    p, s = dense_init(key, (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                      in_axis=-1)
    return p, s


def embed(table, tokens, cfg: ModelConfig):
    x = table.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(table_or_head, x, cfg: ModelConfig):
    logits = x @ table_or_head.astype(x.dtype).T if table_or_head.shape[0] == cfg.vocab_size \
        else x @ table_or_head.astype(x.dtype)
    logits = logits.astype(jnp.float32)
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits
