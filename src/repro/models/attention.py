"""Attention mixers: GQA/MQA (+ local window, softcap, qk-norm), MLA
(DeepSeek-V2 latent attention) and cross-attention (MusicGen memory).

All apply-functions are cache-polymorphic:
  * ``cache=None``       — full-sequence training/prefill, causal flash path.
  * ``cache=(k, v), pos``— decode: append this step's kv at ``pos`` and
                            attend over the valid prefix.
KV caches are plain arrays [B, Hkv, S_max, D]; MLA caches the 576-wide
latent instead (kv_lora + rope dims) — the paper-grade memory win of MLA.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, init_rmsnorm, rmsnorm, rope


class KVCache(NamedTuple):
    k: jax.Array  # [B, Hkv, S, D]  (MLA: [B, S, lora+rope], Hkv folded)
    v: jax.Array  # [B, Hkv, S, D]  (MLA: unused -> zeros[0])


# =============================================================== GQA ======


def init_attention(key, cfg: ModelConfig, *, stacked=(), stack_spec=()):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["wq"], s["wq"] = dense_init(ks[0], (*stacked, d, hq * hd),
                                  (*stack_spec, "embed", "heads"))
    p["wk"], s["wk"] = dense_init(ks[1], (*stacked, d, hkv * hd),
                                  (*stack_spec, "embed", "kv"))
    p["wv"], s["wv"] = dense_init(ks[2], (*stacked, d, hkv * hd),
                                  (*stack_spec, "embed", "kv"))
    p["wo"], s["wo"] = dense_init(ks[3], (*stacked, hq * hd, d),
                                  (*stack_spec, "heads", "embed"))
    if cfg.use_qk_norm:
        p["q_norm"], s["q_norm"] = jnp.ones((*stacked, hd)), (*stack_spec, None)
        p["k_norm"], s["k_norm"] = jnp.ones((*stacked, hd)), (*stack_spec, None)
    return p, s


def apply_attention(p, cfg: ModelConfig, x, *, positions, window=None,
                    cache: Optional[KVCache] = None, cache_pos=None,
                    parallel=None):
    """x: [B, S, E] -> ([B, S, E], new_cache)."""
    from repro.models.layers import use_site_tp
    b, sq, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    wq = use_site_tp(p["wq"].astype(x.dtype), (-1,), parallel)
    wk = use_site_tp(p["wk"].astype(x.dtype), (-1,), parallel)
    wv = use_site_tp(p["wv"].astype(x.dtype), (-1,), parallel)
    q = (x @ wq).reshape(b, sq, hq, hd)
    k = (x @ wk).reshape(b, sq, hkv, hd)
    v = (x @ wv).reshape(b, sq, hkv, hd)
    if cfg.use_qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.rms_eps)
        k = rmsnorm(k, p["k_norm"], cfg.rms_eps)
    q = rope(q.transpose(0, 2, 1, 3), positions[:, None, :], cfg.rope_theta)
    k = rope(k.transpose(0, 2, 1, 3), positions[:, None, :], cfg.rope_theta)
    v = v.transpose(0, 2, 1, 3)

    new_cache = None
    if cache is not None:
        klen = cache.k.shape[2]
        ring = window is not None and klen == window
        if ring:
            # windowed ring buffer (window_bound cache): wrap writes, key
            # slot i holds absolute position newest - ((newest - i) mod klen)
            idx = (cache_pos + jnp.arange(sq)) % klen
            ck = cache.k.at[:, :, idx].set(k.astype(cache.k.dtype))
            cv = cache.v.at[:, :, idx].set(v.astype(cache.v.dtype))
            newest = cache_pos + sq - 1
            slot = jnp.arange(klen)
            key_pos = newest - ((newest - slot) % klen)
            out = ops.attention(q, ck, cv, causal=True, window=window,
                                logit_softcap=cfg.attn_logit_softcap,
                                scale=cfg.attn_scale, qpos_start=cache_pos,
                                key_positions=key_pos)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k.astype(cache.k.dtype), cache_pos, axis=2)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v.astype(cache.v.dtype), cache_pos, axis=2)
            out = ops.attention(q, ck, cv, causal=True, window=window,
                                logit_softcap=cfg.attn_logit_softcap,
                                scale=cfg.attn_scale, qpos_start=cache_pos,
                                valid_len=cache_pos + sq)
        new_cache = KVCache(ck, cv)
    else:
        out = ops.attention(q, k, v, causal=True, window=window,
                            logit_softcap=cfg.attn_logit_softcap,
                            scale=cfg.attn_scale)
    out = out.transpose(0, 2, 1, 3).reshape(b, sq, hq * hd)
    wo = use_site_tp(p["wo"].astype(x.dtype), (-2,), parallel)
    return out @ wo, new_cache


# =============================================================== MLA ======


def init_mla(key, cfg: ModelConfig, *, stacked=(), stack_spec=()):
    m = cfg.mla
    d, hq = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 5)
    p, s = {}, {}
    p["wq"], s["wq"] = dense_init(
        ks[0], (*stacked, d, hq * (m.qk_nope_dim + m.qk_rope_dim)),
        (*stack_spec, "embed", "heads"))
    p["w_dkv"], s["w_dkv"] = dense_init(
        ks[1], (*stacked, d, m.kv_lora_rank + m.qk_rope_dim),
        (*stack_spec, "embed", "lora"))
    p["kv_norm"], s["kv_norm"] = (jnp.ones((*stacked, m.kv_lora_rank)),
                                  (*stack_spec, "lora"))
    p["w_uk"], s["w_uk"] = dense_init(
        ks[2], (*stacked, m.kv_lora_rank, hq * m.qk_nope_dim),
        (*stack_spec, "lora", "heads"))
    p["w_uv"], s["w_uv"] = dense_init(
        ks[3], (*stacked, m.kv_lora_rank, hq * m.v_head_dim),
        (*stack_spec, "lora", "heads"))
    p["wo"], s["wo"] = dense_init(
        ks[4], (*stacked, hq * m.v_head_dim, d), (*stack_spec, "heads", "embed"))
    return p, s


def apply_mla(p, cfg: ModelConfig, x, *, positions,
              cache: Optional[KVCache] = None, cache_pos=None, parallel=None):
    """DeepSeek-V2 multi-head latent attention.

    Cache holds only the compressed latent [B, S, kv_lora + rope] — the
    per-token cache is 576 entries instead of 2·Hkv·D.
    """
    from repro.models.layers import use_site_tp
    m = cfg.mla
    b, sq, _ = x.shape
    hq = cfg.n_heads
    wq_u = use_site_tp(p["wq"].astype(x.dtype), (-1,), parallel)
    q = (x @ wq_u).reshape(b, sq, hq, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = rope(q_rope.transpose(0, 2, 1, 3), positions[:, None, :],
                  cfg.rope_theta)
    w_dkv = use_site_tp(p["w_dkv"].astype(x.dtype), (), parallel)
    latent = x @ w_dkv  # [B, S, lora+rope]
    c_kv = rmsnorm(latent[..., :m.kv_lora_rank], p["kv_norm"], cfg.rms_eps)
    k_rope = rope(latent[..., None, m.kv_lora_rank:].transpose(0, 2, 1, 3),
                  positions[:, None, :], cfg.rope_theta)  # [B, 1, S, rope]
    packed = jnp.concatenate([c_kv, k_rope[:, 0]], axis=-1)  # [B,S,lora+rope]

    if cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache.k, packed[:, None].astype(cache.k.dtype), cache_pos, axis=2)
        new_cache = KVCache(ck, cache.v)
        hist = ck[:, 0]                     # [B, S_max, lora+rope]
        c_kv_all = hist[..., :m.kv_lora_rank]
        k_rope_all = hist[..., None, m.kv_lora_rank:].transpose(0, 2, 1, 3)
        skv = hist.shape[1]
        valid = jnp.arange(skv) < cache_pos + sq
    else:
        new_cache = None
        c_kv_all, k_rope_all = c_kv, k_rope
        skv = sq
        valid = jnp.ones((sq,), bool)

    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    q_nope = q_nope.transpose(0, 2, 1, 3)

    if cache is not None and sq <= 8:
        # ABSORBED decode path (DeepSeek-V2's own serving trick): fold w_uk
        # into the query and w_uv into the output so attention runs directly
        # against the 576-wide latent cache — k_nope/v for all S_kv
        # positions are never materialized (S_kv × H × 256 per layer saved;
        # §Perf dsv2/iter3).
        w_uk = use_site_tp(p["w_uk"].astype(x.dtype), (-1,), parallel).reshape(
            m.kv_lora_rank, hq, m.qk_nope_dim)
        q_lat = jnp.einsum("bhqd,lhd->bhql", q_nope, w_uk)   # [B,H,sq,lora]
        q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)    # [B,H,sq,lora+r]
        k_cat = hist[None].transpose(1, 0, 2, 3)             # [B,1,S,lora+r]
        out_lat = ops.attention(
            q_cat, k_cat, c_kv_all[:, None], causal=True, window=None,
            logit_softcap=None, scale=scale, qpos_start=cache_pos,
            valid_len=cache_pos + sq)                        # [B,H,sq,lora]
        w_uv = use_site_tp(p["w_uv"].astype(x.dtype), (-1,), parallel).reshape(
            m.kv_lora_rank, hq, m.v_head_dim)
        out = jnp.einsum("bhql,lhd->bhqd", out_lat, w_uv)
    else:
        w_uk_f = use_site_tp(p["w_uk"].astype(x.dtype), (-1,), parallel)
        w_uv_f = use_site_tp(p["w_uv"].astype(x.dtype), (-1,), parallel)
        k_nope = (c_kv_all @ w_uk_f).reshape(
            b, skv, hq, m.qk_nope_dim).transpose(0, 2, 1, 3)
        vv = (c_kv_all @ w_uv_f).reshape(
            b, skv, hq, m.v_head_dim).transpose(0, 2, 1, 3)
        # concat nope+rope halves -> one blockwise attention (no SxS tensor)
        q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_rope_b = jnp.broadcast_to(
            k_rope_all, (b, hq, skv, m.qk_rope_dim)).astype(x.dtype)
        k_cat = jnp.concatenate([k_nope, k_rope_b], axis=-1)
        out = ops.attention(
            q_cat, k_cat, vv, causal=True, window=None, logit_softcap=None,
            scale=scale,
            qpos_start=cache_pos if cache is not None else None,
            valid_len=(cache_pos + sq) if cache is not None else None)
    out = out.transpose(0, 2, 1, 3).reshape(b, sq, -1)
    wo = use_site_tp(p["wo"].astype(x.dtype), (-2,), parallel)
    return out @ wo, new_cache


# ========================================================= cross-attn ====


def init_cross_attention(key, cfg: ModelConfig, *, stacked=(), stack_spec=()):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    md = cfg.cross_attn_memory_dim or d
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["wq"], s["wq"] = dense_init(ks[0], (*stacked, d, hq * hd),
                                  (*stack_spec, "embed", "heads"))
    p["wk"], s["wk"] = dense_init(ks[1], (*stacked, md, hkv * hd),
                                  (*stack_spec, "mem", "kv"))
    p["wv"], s["wv"] = dense_init(ks[2], (*stacked, md, hkv * hd),
                                  (*stack_spec, "mem", "kv"))
    p["wo"], s["wo"] = dense_init(ks[3], (*stacked, hq * hd, d),
                                  (*stack_spec, "heads", "embed"))
    return p, s


def apply_cross_attention(p, cfg: ModelConfig, x, memory):
    """x: [B, S, E]; memory: [B, M, md] (precomputed frontend stub)."""
    b, sq, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, sq, hq, hd).transpose(0, 2, 1, 3)
    k = (memory @ p["wk"].astype(x.dtype)).reshape(
        b, -1, hkv, hd).transpose(0, 2, 1, 3)
    v = (memory @ p["wv"].astype(x.dtype)).reshape(
        b, -1, hkv, hd).transpose(0, 2, 1, 3)
    out = ops.attention(q, k, v, causal=False, window=None,
                        logit_softcap=None, scale=None)
    out = out.transpose(0, 2, 1, 3).reshape(b, sq, hq * hd)
    return out @ p["wo"].astype(x.dtype)
