"""Quickstart: the OCF in 60 seconds.

Creates an EOF-mode Optimized Cuckoo Filter, pushes a bursty insert/delete
workload through it, and prints the capacity trajectory — the paper's core
behaviour (grow under burst, shrink under churn, never lose a key, block
blind deletes) in one script.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import OCF, OcfConfig
from repro.core.metrics import (measure_false_negatives,
                                measure_false_positives)

rng = np.random.RandomState(0)
keys = rng.randint(0, 2 ** 63, size=40_000, dtype=np.int64).astype(np.uint64)

ocf = OCF(OcfConfig(capacity=4096, mode="EOF"))
print(f"start: capacity={ocf.capacity} occupancy={ocf.occupancy:.3f}")

# 1. bursty inserts — the filter resizes ahead of the traffic
for i in range(0, keys.size, 4096):
    ocf.insert(keys[i:i + 4096])
print(f"after 40k burst inserts: capacity={ocf.capacity} "
      f"occupancy={ocf.occupancy:.3f} resizes={ocf.stats.resizes} "
      f"(grow={ocf.stats.grows})")

# 2. correctness: zero false negatives, bounded false positives
probes = rng.randint(0, 2 ** 63, size=40_000, dtype=np.int64).astype(np.uint64)
print(f"false negatives: {measure_false_negatives(ocf, keys)} (must be 0)")
print(f"false positives on 40k absent probes: "
      f"{measure_false_positives(ocf, probes)}")

# 3. blind deletes are verified against the keystore (paper §IV)
foreign = rng.randint(0, 2 ** 63, size=1000, dtype=np.int64).astype(np.uint64)
ocf.delete(foreign)
print(f"blind deletes blocked: {ocf.stats.blind_deletes_blocked}")
assert ocf.lookup(keys).all(), "no resident key was corrupted"

# 4. delete churn — EOF shrinks the filter back down
for i in range(0, 36_000, 2048):
    ocf.delete(keys[i:i + 2048])
print(f"after churn: capacity={ocf.capacity} occupancy={ocf.occupancy:.3f} "
      f"shrinks={ocf.stats.shrinks}")
print(f"capacity history: {ocf.capacity_history}")
