"""End-to-end driver: train a (reduced) assigned architecture for a few
hundred steps with the OCF-dedup data pipeline, checkpointing and the
straggler watchdog — the trainer's full production path on one CPU device.

    PYTHONPATH=src python examples/train_lm_with_dedup.py \
        --arch gemma2-27b --steps 200
"""
import argparse

import numpy as np

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    out = train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
                smoke=True, ckpt_dir=args.ckpt_dir, ckpt_every=50)
    losses = [h["loss"] for h in out["history"]]
    n = len(losses)
    print(f"steps: {n}")
    for i in range(0, n, max(1, n // 10)):
        print(f"  step {i:4d}  loss {losses[i]:.4f}")
    print(f"final loss: {losses[-1]:.4f} (start {losses[0]:.4f})")
    assert np.mean(losses[-20:]) < np.mean(losses[:20]), "should be learning"
    ps = out["pipeline_stats"]
    print(f"data pipeline: {ps.docs_seen} docs seen, "
          f"{ps.docs_deduped} dropped by the OCF ({ps.docs_deduped/max(1,ps.docs_seen):.1%})")
    print(f"filter: {out['dedup_ocf_stats']}")
    print(f"straggler flags: {out['straggler_flags']}")


if __name__ == "__main__":
    main()
