"""Adaptive filtering end-to-end — an abuse-detection denylist that LEARNS.

A membership filter sits in front of an expensive ground-truth check (a
database of flagged accounts, a signature scanner): a filter hit pays the
slow path, a miss is served instantly.  Every false positive therefore
costs a wasted ground-truth lookup — and a classic cuckoo filter keeps
paying for the SAME colliding keys forever, which an adversary who finds
one can exploit by replaying it (a degradation-of-service attack on the
slow path).

The adaptive filter closes the loop.  When the slow path refutes a hit,
the confirmed false positive is fed back via ``report``: the colliding
slot's 2-bit hash selector is bumped and its fingerprint rewritten from
the mirrored resident key — the entry never moves, so denylisted accounts
can never be lost (zero false negatives), but the replayed query stops
hitting.  Keys the selector family cannot separate are promoted to a tiny
exact side table after ``promote_after`` reports, and cold report floods
are admission-controlled by the filter's own congestion signal.

    PYTHONPATH=src python examples/adaptive_abuse_detection.py
"""
import numpy as np

from repro.adaptive import AdaptiveConfig, AdaptiveMembership

rng = np.random.RandomState(7)

N_FLAGGED = 6_000          # denylisted account ids (the filter's members)
N_TRAFFIC = 40_000         # benign lookups per round
ROUNDS = 4

flagged = np.unique(rng.randint(0, 2 ** 63, size=N_FLAGGED, dtype=np.int64)
                    .astype(np.uint64))
truth = set(int(k) for k in flagged)

m = AdaptiveMembership(AdaptiveConfig(n_buckets=4096, bucket_size=4,
                                      fp_bits=12, backend="auto"))
ok = m.insert(flagged)
assert ok.all(), "denylist must fit"

# One benign population queried every round — the replay pattern that hurts
# a static filter most: its false positives are DETERMINISTIC, so the same
# colliding ids pay the slow path round after round.
benign = np.unique(rng.randint(0, 2 ** 63, size=N_TRAFFIC, dtype=np.int64)
                   .astype(np.uint64))
benign = benign[~np.isin(benign, flagged)]

total_slow = 0
for r in range(ROUNDS):
    hits = m.lookup(benign)
    fps = benign[hits]                 # every benign hit = wasted slow path
    total_slow += fps.size
    for k in fps:                      # ground truth refutes them...
        assert int(k) not in truth
    adapted = m.report(fps)            # ...and the filter LEARNS
    print(f"round {r}: false positives={fps.size:4d} "
          f"(fp rate {fps.size / benign.size:.2e})  "
          f"adapted={int(adapted.sum()):4d}  "
          f"promoted={m.reputation.promoted:3d}")

# The members are all still caught — adaptation cannot lose a flagged id.
assert m.lookup(flagged).all(), "false negative on a denylisted account!"

final_fp = int(m.lookup(benign).sum())
print(f"\nslow-path lookups wasted across {ROUNDS} rounds: {total_slow}")
print(f"steady-state false positives on the replayed population: "
      f"{final_fp} (static filter would repeat round 0 forever)")
print(f"reputation tier: {m.reputation.promoted} ids promoted to the exact "
      f"side table, {m.deferred_reports} cold reports deferred")
assert final_fp == 0, "replayed population should be fully repaired"
print("zero false negatives, replayed false positives fully repaired")
