"""Distributed membership service: OCF shards on a JAX mesh (paper §I-B).

The paper's Cassandra-cluster scenario: keys are owned by shards; batched
inserts, lookups, and verified deletes are all routed shard-to-shard with
one capacity-bounded all_to_all and run by the owner's local data plane —
writes resolve their eviction chains and stash spills on-device inside
shard_map (PR 6), no host round-trips.  Run on 8 virtual devices:

    PYTHONPATH=src python examples/distributed_membership.py
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed as dist
from repro.core import hashing

N_SHARDS, N_BUCKETS = 8, 4096

try:
    mesh = jax.make_mesh((N_SHARDS,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
except AttributeError:  # jax 0.4.x: no AxisType; Auto is the default
    mesh = jax.make_mesh((N_SHARDS,), ("data",))
rng = np.random.RandomState(0)
keys = rng.randint(0, 2 ** 63, size=32768, dtype=np.int64).astype(np.uint64)
hi, lo = hashing.key_to_u32_pair_np(keys)

# Routed insert: every key rides the all_to_all to its owner shard, which
# runs the conflict-aware scheduled insert on its table slice on-device —
# the host never partitions keys or swaps tables (that was the pre-PR-6
# idiom; see ARCHITECTURE.md "Distributed write path").
owner = np.asarray(hashing.owner_shard_np(hi, lo, N_SHARDS))
state = dist.make_sharded_state(N_SHARDS, N_BUCKETS, 4)
state, ok, deferred, iov = dist.distributed_insert(
    mesh, "data", state, jnp.asarray(hi), jnp.asarray(lo), fp_bits=16)
assert bool(np.asarray(ok).all()) and not bool(np.asarray(deferred).any())
print(f"{N_SHARDS} shards, {keys.size} keys routed+inserted on-device, "
      f"owner histogram: {np.bincount(owner, minlength=N_SHARDS)}")
print(f"aggregate load: {float(dist.sharded_occupancy(state)):.3f}")

# Distributed lookup: one all_to_all out, local probe, one all_to_all back.
hits, overflow = dist.distributed_lookup(
    mesh, "data", state, jnp.asarray(hi), jnp.asarray(lo), fp_bits=16)
print(f"present keys found: {int(np.asarray(hits).sum())}/{keys.size}")
print(f"per-shard routing overflow: {np.asarray(overflow)}")

absent = rng.randint(0, 2 ** 63, size=32768, dtype=np.int64).astype(np.uint64)
ahi, alo = hashing.key_to_u32_pair_np(absent)
ahits, _ = dist.distributed_lookup(mesh, "data", state, jnp.asarray(ahi),
                                   jnp.asarray(alo), fp_bits=16)
print(f"false positives on {absent.size} absent keys: "
      f"{int(np.asarray(ahits).sum())}")

# Congestion: shrink routing capacity -> overflow counters fire (the EOF
# signal) while answers stay conservative.
thits, tov = dist.distributed_lookup(mesh, "data", state, jnp.asarray(hi),
                                     jnp.asarray(lo), fp_bits=16,
                                     capacity_factor=0.5)
print(f"tight capacity: found={int(np.asarray(thits).sum())}/{keys.size} "
      f"overflow={np.asarray(tov)} (burst signal -> EOF controller)")

# Routed verified delete: half the keys churn out, owner shards clear them
# (table first, then any stash-parked copies) in the same dispatch shape.
half = keys.size // 2
state, dok, _, _ = dist.distributed_delete(
    mesh, "data", state, jnp.asarray(hi[:half]), jnp.asarray(lo[:half]),
    fp_bits=16)
rhits, _ = dist.distributed_lookup(mesh, "data", state, jnp.asarray(hi),
                                   jnp.asarray(lo), fp_bits=16)
print(f"deleted {int(np.asarray(dok).sum())}/{half}; survivors found: "
      f"{int(np.asarray(rhits)[half:].sum())}/{keys.size - half}, "
      f"load now {float(dist.sharded_occupancy(state)):.3f}")

# Deferred-batch resubmission (PR 7): a skewed burst under tight routing
# capacity overflows some owners' all_to_all rows — those lanes come back
# as a DEFERRED batch, never attempted.  The pump parks them and re-offers
# under the admission controller's hysteresis, so resubmission waits out
# shard congestion instead of hammering saturated owners.
from repro.serving.scheduler import DeferredWritePump

burst = rng.randint(0, 2 ** 63, size=8192, dtype=np.int64).astype(np.uint64)
bhi, blo = hashing.key_to_u32_pair_np(burst)
# Skew: half the burst targets two hot owners (replayed hot-key pattern).
hot = np.asarray(hashing.owner_shard_np(bhi, blo, N_SHARDS)) < 2
skewed = np.concatenate([burst[hot], burst[hot], burst[~hot]])[:8192]
shi, slo = hashing.key_to_u32_pair_np(skewed)

pump = DeferredWritePump(mesh, "data",
                         dist.make_sharded_state(N_SHARDS, N_BUCKETS, 4),
                         fp_bits=16, capacity_factor=0.5)
ok, deferred = pump.submit(shi, slo)
print(f"\nburst of {skewed.size} under tight capacity: "
      f"{int(ok.sum())} landed, {int(deferred.sum())} deferred")
pump.run_until_drained(max_ticks=64)
print(f"pump drained: inserted={pump.stats.inserted} "
      f"resubmitted={pump.stats.resubmitted} held_ticks="
      f"{pump.stats.held_ticks} pending={pump.pending} "
      f"(signal={pump.admission.signal():.2f})")
phits, _ = dist.distributed_lookup(mesh, "data", pump.state,
                                   jnp.asarray(shi), jnp.asarray(slo),
                                   fp_bits=16)
assert bool(np.asarray(phits).all()), "a deferred key never landed"
print("every burst key resident after hysteresis-gated resubmission")
