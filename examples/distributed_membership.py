"""Distributed membership service: OCF shards on a JAX mesh (paper §I-B).

The paper's Cassandra-cluster scenario: keys are owned by shards; a batched
membership query is routed shard-to-shard with one capacity-bounded
all_to_all and answered by local VMEM probes.  Run on 8 virtual devices:

    PYTHONPATH=src python examples/distributed_membership.py
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed as dist
from repro.core import filter as jf
from repro.core import hashing

N_SHARDS, N_BUCKETS = 8, 4096

try:
    mesh = jax.make_mesh((N_SHARDS,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
except AttributeError:  # jax 0.4.x: no AxisType; Auto is the default
    mesh = jax.make_mesh((N_SHARDS,), ("data",))
rng = np.random.RandomState(0)
keys = rng.randint(0, 2 ** 63, size=32768, dtype=np.int64).astype(np.uint64)
hi, lo = hashing.key_to_u32_pair_np(keys)

# Build each shard's filter from the keys it owns (host-side control plane).
owner = np.asarray(hashing.owner_shard_np(hi, lo, N_SHARDS))
tables = np.zeros((N_SHARDS, N_BUCKETS, 4), np.uint32)
for s in range(N_SHARDS):
    m = owner == s
    fs = jf.make_state(N_BUCKETS, 4)
    fs, ok = jf.bulk_insert_hybrid(fs, jnp.asarray(hi[m]), jnp.asarray(lo[m]),
                                   fp_bits=16)
    assert bool(np.asarray(ok).all())
    tables[s] = np.asarray(fs.table)
state = dist.ShardedFilterState(tables=jnp.asarray(tables))
print(f"{N_SHARDS} shards, {keys.size} keys, "
      f"owner histogram: {np.bincount(owner, minlength=N_SHARDS)}")

# Distributed lookup: one all_to_all out, local probe, one all_to_all back.
hits, overflow = dist.distributed_lookup(
    mesh, "data", state, jnp.asarray(hi), jnp.asarray(lo), fp_bits=16)
print(f"present keys found: {int(np.asarray(hits).sum())}/{keys.size}")
print(f"per-shard routing overflow: {np.asarray(overflow)}")

absent = rng.randint(0, 2 ** 63, size=32768, dtype=np.int64).astype(np.uint64)
ahi, alo = hashing.key_to_u32_pair_np(absent)
ahits, _ = dist.distributed_lookup(mesh, "data", state, jnp.asarray(ahi),
                                   jnp.asarray(alo), fp_bits=16)
print(f"false positives on {absent.size} absent keys: "
      f"{int(np.asarray(ahits).sum())}")

# Congestion: shrink routing capacity -> overflow counters fire (the EOF
# signal) while answers stay conservative.
thits, tov = dist.distributed_lookup(mesh, "data", state, jnp.asarray(hi),
                                     jnp.asarray(lo), fp_bits=16,
                                     capacity_factor=0.5)
print(f"tight capacity: found={int(np.asarray(thits).sum())}/{keys.size} "
      f"overflow={np.asarray(tov)} (burst signal -> EOF controller)")
