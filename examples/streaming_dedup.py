"""Streaming dedup over a TTL window — the streaming subsystem end-to-end.

Simulates a bursty event stream with heavy short-range duplication (the
workload a log/metrics dedup stage or a recent-flow table sees) and pushes
it through a ``GenerationalFilter``: duplicates inside the TTL window are
dropped, eviction storms at high fill spill to the device-resident overflow
stash instead of failing, whole generations age out in O(1), and the
admission controller's congestion signal rises and falls with the burst.

    PYTHONPATH=src python examples/streaming_dedup.py
"""
import numpy as np

from repro.streaming import (AdmissionConfig, AdmissionController,
                             GenerationConfig, GenerationalFilter)

rng = np.random.RandomState(0)

WINDOW = 60.0          # seconds of "recent" an event stays deduplicated
TICKS = 24             # simulated seconds of stream
BASE, BURST = 1500, 6000   # events/tick, quiet vs burst

gf = GenerationalFilter(GenerationConfig(
    generations=4, capacity=1 << 13, stash_slots=128,
    ttl=WINDOW, backend="auto"), now=0.0)
ctl = AdmissionController(gf, AdmissionConfig(high_water=0.7, low_water=0.3))

unique = dropped = 0
for t in range(TICKS):
    n = BURST if 8 <= t < 12 else BASE          # a 4-second burst mid-stream
    # ~40% of each tick repeats recent ids (the dedup target)
    fresh = rng.randint(0, 2 ** 63, size=int(n * 0.6),
                        dtype=np.int64).astype(np.uint64)
    repeats = (rng.choice(fresh, size=n - fresh.size, replace=True)
               if t == 0 else
               rng.choice(seen_pool, size=n - fresh.size, replace=True))
    events = np.concatenate([fresh, repeats])
    seen_pool = fresh if t == 0 else np.concatenate([seen_pool, fresh])[-20_000:]

    new = ~gf.lookup(events, now=float(t))      # probe all live generations
    gf.insert(events[new], now=float(t))        # burst overflow -> stash
    unique += int(new.sum())
    dropped += int((~new).sum())
    if t in (0, 7, 9, 11, 13, TICKS - 1):
        print(f"t={t:2d}  events={n:5d}  dedup_dropped={int((~new).sum()):5d}"
              f"  fill={gf.fill:.2f}  stash_fill={gf.stash_fill:.2f}"
              f"  signal={ctl.signal():.2f}  admit={ctl.admit()}")

print(f"\nstream: {unique} unique, {dropped} duplicates dropped "
      f"({dropped / (unique + dropped):.1%} of traffic)")
print(f"generations: rotations={gf.stats.rotations} "
      f"expirations={gf.stats.expirations} live={gf.live_generations}")
print(f"stash: spills={gf.stats.spills} (burst overflow absorbed on-device)")
print(f"admission: admitted={ctl.admitted} deferred={ctl.deferred}")

# TTL: an hour later the whole window has aged out — O(1) per generation,
# no per-key deletes, and the buffers go back to the pool.
assert not gf.lookup(seen_pool[:1000], now=3600.0).any()
retired = gf.advance(now=3600.0)
print(f"after TTL: {retired} generations retired, "
      f"window empty, pool recycled")
