"""Serving scenario: batched requests through the OCF prefix-cache index.

Simulates the chat pattern (many requests share a system prefix).  The OCF
answers "which prefix blocks are already cached?" before any prefill; hits
skip recompute, evictions *delete* from the filter (the cuckoo advantage),
and the admission burst drives EOF resizing instead of a flush.

    PYTHONPATH=src python examples/serve_with_prefix_cache.py \
        --arch mistral-nemo-12b --requests 24
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-nemo-12b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prefix-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args()

    out = serve(args.arch, requests=args.requests,
                prefix_len=args.prefix_len, gen=args.gen, smoke=True)
    print(f"requests: {args.requests}")
    print(f"mean latency: {out['latency_mean_s']*1e3:.1f} ms   "
          f"p99: {out['latency_p99_s']*1e3:.1f} ms")
    print(f"prefix-cache hit rate: {out['prefix_hit_rate']:.1%} "
          f"({out['reused_blocks']} blocks reused)")
    print(f"index: {out['index_stats']}")
    print(f"filter: occupancy={out['filter_occupancy']:.3f} "
          f"{out['ocf_stats']}")


if __name__ == "__main__":
    main()
