#!/usr/bin/env bash
# Tier-1 verification: the fast core test subset plus a smoke run of the
# filter data-plane benchmark.  This is the check every PR must keep green
# (see ROADMAP.md "Tier-1 verify" and README.md "Verifying").
#
#   bash scripts/verify.sh            # from the repo root
#
# The benchmark smoke writes BENCH_filter.json at the repo root — per-backend
# lookup/insert/insert-residue/delete keys-per-second plus the SLO scenario
# latency matrix (the perf trajectory tracked across PRs).
#
# SKIP_TIER1=1 skips the pytest step — for CI, which runs tier-1 as its own
# budgeted step (5-minute timeout) and then calls this script for the bench
# smoke + gates without paying for the suite twice.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tracked-artifact guard =="
# PR 3 untracked 38 stray .pyc files; fail fast if any creep back in.
if git ls-files | grep -E '(\.pyc$|(^|/)__pycache__(/|$))'; then
  echo "ERROR: compiled Python artifacts are tracked (see list above);"
  echo "       git rm --cached them — .gitignore already covers the paths."
  exit 1
fi

if [[ "${SKIP_TIER1:-0}" == "1" ]]; then
  echo "== tier-1 test suite == (skipped: SKIP_TIER1=1)"
else
  echo "== tier-1 test suite =="
  python -m pytest -m tier1 -x -q
fi

echo "== filter_bench smoke =="
python benchmarks/filter_bench.py

echo "== bench-regression gate =="
# Fails if any *_keys_per_s row in the fresh BENCH_filter.json dropped >20%
# below the committed baseline, any slo_*_p99_us row rose >25%, or the
# telemetry wave-path overhead exceeded 5% (BENCH_GATE_THRESHOLD /
# BENCH_GATE_SLO_THRESHOLD / BENCH_GATE_TELEMETRY_PCT override).
python scripts/bench_gate.py

echo "== telemetry smoke =="
# Replay the burst_train scenario with counter planes + spans on and check
# both exported artifacts are well-formed: a non-empty metrics JSONL and a
# perfetto-loadable Chrome trace with at least one complete span.
# TELEMETRY_DIR keeps the artifacts (CI uploads them); default is a temp
# dir cleaned on exit.
if [[ -n "${TELEMETRY_DIR:-}" ]]; then
  TDIR="$TELEMETRY_DIR"
  mkdir -p "$TDIR"
else
  TDIR="$(mktemp -d)"
  trap 'rm -rf "$TDIR"' EXIT
fi
python benchmarks/serving_bench.py --scenario burst_train \
  --telemetry --telemetry-dir "$TDIR" > /dev/null
python - "$TDIR" <<'EOF'
import json, sys, os
tdir = sys.argv[1]
metrics = os.path.join(tdir, "slo_burst_train_metrics.jsonl")
trace = os.path.join(tdir, "slo_burst_train_trace.json")
lines = [json.loads(l) for l in open(metrics) if l.strip()]
assert lines, "telemetry metrics JSONL is empty"
with open(trace) as f:
    tr = json.load(f)
events = tr["traceEvents"]
assert any(e.get("ph") == "X" for e in events), "trace has no complete spans"
print(f"telemetry smoke OK ({len(lines)} metric records, "
      f"{len(events)} trace events)")
EOF

echo "== fault-injection smoke =="
# Kill one shard of a live 2-shard mesh mid-stream: degraded lookups must
# stay free of false negatives (conservative positives only), checkpoint-
# restart must close the window, and the recovery metrics must export as
# JSONL into $TDIR (CI uploads it with the telemetry snapshot).
python - "$TDIR" <<'EOF'
import os, sys, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np, jax.numpy as jnp
from repro.checkpoint import ckpt
from repro.core import distributed as dist, hashing
from repro.distributed import elastic, fault
from repro.obs import MetricsRegistry, RecoveryMetrics

tdir = sys.argv[1]
NB, FP, CF = 64, 16, 8.0
mesh = elastic.filter_mesh(2)
state = dist.make_sharded_state(2, NB, 4, stash_slots=32)
rng = np.random.RandomState(0)
raw = rng.randint(0, 2**63, size=256, dtype=np.int64).astype(np.uint64)
hi, lo = hashing.key_to_u32_pair_np(raw)
state, ok, _, _ = dist.distributed_insert(
    mesh, "data", state, jnp.asarray(hi), jnp.asarray(lo), fp_bits=FP,
    backend="jnp", capacity_factor=CF)
keep = np.asarray(ok)
hi, lo = hi[keep], lo[keep]
if hi.size % 2:
    hi, lo = hi[:-1], lo[:-1]
reg = MetricsRegistry()
rec = RecoveryMetrics(metrics=reg)
inj = fault.FaultInjector(recovery=rec)
with tempfile.TemporaryDirectory() as d:
    ckpt.save_sharded(d, 1, state)
    dead = inj.kill(state, 0)       # mid-stream shard loss
    hits, _, deg = fault.degraded_lookup(
        mesh, "data", dead, jnp.asarray(hi), jnp.asarray(lo), fp_bits=FP,
        injector=inj, backend="jnp", capacity_factor=CF, recovery=rec)
    assert hits.all(), "false negative under injected shard loss"
    assert deg.sum() > 0, "smoke must exercise the lost shard"
    healed = fault.recover_shard(dead, 0, ckpt_dir=d, injector=inj,
                                 recovery=rec)
rh, _ = dist.distributed_lookup(
    mesh, "data",
    healed._replace(tables=jnp.asarray(healed.tables),
                    stashes=jnp.asarray(healed.stashes)),
    jnp.asarray(hi), jnp.asarray(lo), fp_bits=FP, backend="jnp",
    capacity_factor=CF)
assert bool(np.asarray(rh).all()), "checkpoint-restart left keys missing"
out = os.path.join(tdir, "recovery_metrics.jsonl")
reg.to_jsonl(out)
n = sum(1 for line in open(out) if line.strip())
assert n > 0, "recovery metrics JSONL is empty"
print(f"fault smoke OK ({int(deg.sum())} degraded answers, zero false "
      f"negatives, recovered; {n} recovery metric records)")
EOF

echo "verify OK"
