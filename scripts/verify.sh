#!/usr/bin/env bash
# Tier-1 verification: the fast core test subset plus a smoke run of the
# filter data-plane benchmark.  This is the check every PR must keep green
# (see ROADMAP.md "Tier-1 verify" and README.md "Verifying").
#
#   bash scripts/verify.sh            # from the repo root
#
# The benchmark smoke writes BENCH_filter.json at the repo root — per-backend
# lookup/insert/insert-residue/delete keys-per-second (the perf trajectory
# tracked across PRs).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tracked-artifact guard =="
# PR 3 untracked 38 stray .pyc files; fail fast if any creep back in.
if git ls-files | grep -E '(\.pyc$|(^|/)__pycache__(/|$))'; then
  echo "ERROR: compiled Python artifacts are tracked (see list above);"
  echo "       git rm --cached them — .gitignore already covers the paths."
  exit 1
fi

echo "== tier-1 test suite =="
python -m pytest -m tier1 -x -q

echo "== filter_bench smoke =="
python benchmarks/filter_bench.py

echo "== bench-regression gate =="
# Fails if any *_keys_per_s row in the fresh BENCH_filter.json dropped >20%
# below the committed baseline (BENCH_GATE_THRESHOLD overrides).
python scripts/bench_gate.py

echo "verify OK"
