#!/usr/bin/env python
"""Bench-regression gate: fail when a fresh ``BENCH_filter.json`` shows any
``*_keys_per_s`` row more than ``THRESHOLD`` below the committed one.

Run by ``scripts/verify.sh`` right after the filter_bench smoke (which
rewrites ``BENCH_filter.json`` at the repo root); compares against the
version committed at HEAD via ``git show``, so the gate always measures
against the trajectory the repo actually promises.  A PR that slows a hot
path >20% must either fix the regression or consciously commit the slower
numbers (changing the baseline in the same commit clears the gate).

Since ISSUE 8 the gate also covers **tail latency**: every committed
``slo_*_p99_us`` row may rise at most ``BENCH_GATE_SLO_THRESHOLD``
(fraction, default 0.25) over its baseline, the scenario matrix must be
present (p50/p99/p999 for the core scenarios), and the double-buffered
burst tail must not fall behind the synchronous arm measured in the same
run.  Latency percentiles are single-pass samples (no min-of-trials — a
percentile of minima isn't a percentile), so they are noisier than the
keys/s rows; CI sets the SLO threshold generously and the local default
stays tight.

Since ISSUE 9 the gate also holds the **telemetry contract**: the fresh
``telemetry_overhead_pct`` row (telemetry-on vs -off arms of the same
serving-wave stream, same run) must stay at or below
``BENCH_GATE_TELEMETRY_PCT`` percent (default 5).

Exit codes: 0 pass / 1 regression / 0 with a notice when there is no
committed baseline (first run) or no git.  ``BENCH_GATE_THRESHOLD``
overrides the drop threshold (fraction, default 0.20) — the CPU container
rows are minima over interleaved trials, but a loaded machine can still
dip; raise the threshold there rather than deleting the gate.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
THRESHOLD = float(os.environ.get("BENCH_GATE_THRESHOLD", "0.20"))
SLO_THRESHOLD = float(os.environ.get("BENCH_GATE_SLO_THRESHOLD", "0.25"))
TELEMETRY_PCT = float(os.environ.get("BENCH_GATE_TELEMETRY_PCT", "5.0"))

# Scenarios whose percentile rows must exist in every fresh bench run
# (ISSUE 8 acceptance: the matrix can't silently shrink).
SLO_REQUIRED = ("uniform", "zipfian", "burst_train", "delete_heavy")


def main() -> int:
    fresh_path = os.path.join(REPO, "BENCH_filter.json")
    with open(fresh_path) as f:
        fresh = json.load(f)
    try:
        committed = json.loads(subprocess.check_output(
            ["git", "-C", REPO, "show", "HEAD:BENCH_filter.json"],
            text=True, stderr=subprocess.DEVNULL))
    except (subprocess.CalledProcessError, FileNotFoundError):
        print("bench gate: no committed BENCH_filter.json baseline; skipping")
        return 0
    bad = []
    for key, base in sorted(committed.items()):
        if not key.endswith("_keys_per_s") or not isinstance(base, (int,
                                                                    float)):
            continue
        cur = fresh.get(key)
        if cur is None:
            bad.append(f"  {key}: row disappeared (baseline {base})")
            continue
        if base > 0 and cur < base * (1.0 - THRESHOLD):
            bad.append(f"  {key}: {cur} vs baseline {base} "
                       f"({cur / base - 1.0:+.0%}, limit -{THRESHOLD:.0%})")
    # PR-6 acceptance: the routed distributed insert must beat the host-loop
    # baseline measured IN THE SAME RUN (not vs the committed file — both
    # arms see identical machine weather, so this comparison is noise-free
    # in a way the cross-run threshold can't be).
    routed = fresh.get("distributed_insert_pallas_keys_per_s")
    hostloop = fresh.get("distributed_insert_hostloop_keys_per_s")
    if routed is not None and hostloop is not None and routed <= hostloop:
        bad.append(f"  distributed_insert: routed {routed} keys/s does not "
                   f"beat the host-loop baseline {hostloop} keys/s")
    # PR-7 acceptance: false-positive-rate gates, same-run like the routed/
    # hostloop pair (rates at a fixed seed are deterministic, so these are
    # exact, not thresholds-with-noise).
    #   * ceiling: every fp_rate_* row must stay below 4x the partial-key
    #     expectation 2b/2^f (b=4 slots, two buckets, fp_rate_fp_bits) —
    #     a hash-quality tripwire, generous enough for binomial wobble;
    #   * ratio: after feedback the adaptive filter's FPR on the replayed
    #     adversarial mix must be >= 10x below the static filter's.
    fpb = fresh.get("fp_rate_fp_bits")
    if fpb is not None:
        ceiling = 4.0 * (2 * 4) / (1 << int(fpb))
        for key in ("fp_rate_static_uniform", "fp_rate_adaptive_uniform",
                    "fp_rate_static_adversarial",
                    "fp_rate_adaptive_adversarial"):
            rate = fresh.get(key)
            if rate is None:
                bad.append(f"  {key}: row missing from fresh bench")
            elif rate > ceiling:
                bad.append(f"  {key}: {rate:.2e} above ceiling "
                           f"{ceiling:.2e} (fp_bits={fpb})")
        stat = fresh.get("fp_rate_static_adversarial")
        adap = fresh.get("fp_rate_adaptive_adversarial")
        if stat is not None and adap is not None and adap * 10.0 > stat:
            bad.append(f"  fp_rate adversarial: adaptive {adap:.2e} not "
                       f">=10x below static {stat:.2e} after feedback")
    # ISSUE-8 acceptance: tail-latency gates.
    #   * regression: committed slo_*_p99_us rows may rise at most
    #     SLO_THRESHOLD (latency regresses UP — the mirror of keys/s);
    #   * presence + sanity: the core scenarios' percentile rows must
    #     exist and be ordered p50 <= p99 <= p999;
    #   * double-buffer win: the async burst tail must not fall behind
    #     the sync arm measured in the SAME run (same machine weather).
    for key, base in sorted(committed.items()):
        if not key.endswith("_p99_us") or not isinstance(base, (int, float)):
            continue
        if "_async_" in key:
            # observability-only arm: on single-core hosts the pipelined
            # path pays pure queueing, so its absolute value is volatile;
            # the default-vs-sync same-run check below is the contract.
            continue
        cur = fresh.get(key)
        if cur is None:
            bad.append(f"  {key}: row disappeared (baseline {base})")
        elif base > 0 and cur > base * (1.0 + SLO_THRESHOLD):
            bad.append(f"  {key}: {cur} us vs baseline {base} us "
                       f"({cur / base - 1.0:+.0%}, limit "
                       f"+{SLO_THRESHOLD:.0%})")
    for scen in SLO_REQUIRED:
        p = {q: fresh.get(f"slo_{scen}_{q}_us") for q in ("p50", "p99",
                                                          "p999")}
        if any(v is None for v in p.values()):
            bad.append(f"  slo_{scen}: percentile rows missing from "
                       f"fresh bench ({p})")
        elif not (p["p50"] <= p["p99"] <= p["p999"]):
            bad.append(f"  slo_{scen}: percentiles not monotone ({p})")
    dflt_p99 = fresh.get("slo_burst_train_p99_us")
    sync_p99 = fresh.get("slo_burst_train_sync_p99_us")
    if (dflt_p99 is not None and sync_p99 is not None and sync_p99 > 0
            and dflt_p99 > sync_p99 * (1.0 + SLO_THRESHOLD)):
        bad.append(f"  burst_train: default submit path p99 {dflt_p99} us "
                   f"fell behind the sync arm {sync_p99} us (same-run, "
                   f"limit +{SLO_THRESHOLD:.0%})")
    # ISSUE-10 acceptance: elastic resharding recovery rows.  These are
    # structural/correctness gates, not noise-tolerant thresholds: a
    # migration that loses a key or strands a parked write is broken at any
    # speed.  The elastic_*_keys_per_s rows additionally ride the generic
    # regression threshold above.
    for key in ("elastic_split_keys_per_s", "elastic_merge_keys_per_s",
                "elastic_time_to_recover_s", "elastic_shard_restore_s",
                "elastic_deferred_backlog_after",
                "elastic_migration_failed"):
        if fresh.get(key) is None:
            bad.append(f"  {key}: recovery row missing from fresh bench")
    for key in ("elastic_split_false_negatives",
                "elastic_merge_false_negatives",
                "elastic_degraded_false_negatives",
                "elastic_recover_false_negatives",
                "elastic_migration_failed",
                "elastic_deferred_backlog_after"):
        v = fresh.get(key)
        if v is not None and v != 0:
            bad.append(f"  {key}: {v} != 0 — elastic migration/recovery "
                       f"must be lossless and fully drained")
    ttr = fresh.get("elastic_time_to_recover_s")
    if ttr is not None and not 0.0 < ttr < 600.0:
        bad.append(f"  elastic_time_to_recover_s: {ttr} not in (0, 600)s "
                   f"— must be reported and sane")
    # ISSUE-9 acceptance: telemetry must stay near-free on the wave path.
    # ``telemetry_overhead_pct`` compares the telemetry-on and -off arms of
    # the SAME mixed wave stream measured in the same run (fresh batcher per
    # arm, arms alternated per trial), so like the routed/hostloop pair the
    # comparison is weather-free; the raw per-twin rows stay informational
    # because the CPU emulation re-materializes gather chains the fused TPU
    # probe would not (see benchmarks/filter_bench.py::telemetry_rows).
    tel = fresh.get("telemetry_overhead_pct")
    if tel is None:
        bad.append("  telemetry_overhead_pct: row missing from fresh bench")
    elif tel > TELEMETRY_PCT:
        bad.append(f"  telemetry_overhead_pct: {tel}% wave-path overhead "
                   f"above the {TELEMETRY_PCT}% ceiling "
                   f"(BENCH_GATE_TELEMETRY_PCT overrides)")
    if bad:
        print(f"bench gate FAILED ({len(bad)} row(s) regressed "
              f">{THRESHOLD:.0%}):")
        print("\n".join(bad))
        print("fix the regression, or commit the new BENCH_filter.json as "
              "the intended baseline; on a host slower than the one that "
              "produced the baseline, set BENCH_GATE_THRESHOLD higher.")
        return 1
    n = sum(1 for k in committed if k.endswith("_keys_per_s"))
    n_slo = sum(1 for k in committed if k.endswith("_p99_us"))
    print(f"bench gate OK ({n} keys/s rows within -{THRESHOLD:.0%}, "
          f"{n_slo} p99 rows within +{SLO_THRESHOLD:.0%} of baseline, "
          f"telemetry wave overhead {fresh.get('telemetry_overhead_pct')}% "
          f"<= {TELEMETRY_PCT}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
