"""SLO harness tests — submit-path parity, determinism, admission coupling.

The contracts pinned here:

  * the async double-buffered submit path is **bit-for-bit** the sync
    path (same per-wave results, same final device state) — scheduling
    may overlap work, never change answers;
  * single-lane waves through ``FilterOpBatcher`` reproduce the
    sequential oracles (``PyStashFilter`` / ``PyAdaptiveFilter``) op for
    op AND state for state — the batcher adds pipelining, not semantics;
  * scenario streams are byte-reproducible from one seed (the bench
    gate's comparability requirement, satellite of ISSUE 8);
  * admission coupling under a burst train: the hysteresis gate defers
    inserts at high water, re-admits below low water, and sheds what a
    sustained overload never lets back in;
  * the latency recorder's op-weighted percentiles are the numbers the
    bench rows claim they are.
"""
import numpy as np
import pytest

from repro.core import filter as jfilter
from repro.core.filter_ops import FilterOps
from repro.adaptive.state import make_adaptive_state
from repro.kernels import ops as kops
from repro.serving.scheduler import FilterOpBatcher
from repro.serving.slo import (LatencyRecorder, SloHarness, make_batcher,
                               run_scenario)
from repro.serving.workloads import SCENARIOS, scenario_stream
from repro.streaming.admission import AdmissionConfig
from repro.streaming.oracle import PyAdaptiveFilter, PyStashFilter

pytestmark = [pytest.mark.tier1, pytest.mark.slo]

WS = 64  # small waves keep tier-1 fast; shapes are what compile, not sizes

SMALL = {
    "uniform": dict(waves=8),
    "zipfian": dict(waves=8),
    "adversarial": dict(rounds=2),
    "burst_train": dict(bursts=2, burst_waves=2, gap_waves=2),
    "ttl_churn": dict(waves=8),
    "delete_heavy": dict(waves=9),
}


def _replay(name, *, double_buffer, seed=7):
    """Run a small scenario through a fresh stack -> (batcher, results)."""
    batcher = make_batcher(name, double_buffer=double_buffer, wave_slots=WS)
    waves = []
    for batch in scenario_stream(name, seed, wave_slots=WS, **SMALL[name]):
        wave = batcher.submit(batch.kind, batch.keys)
        waves.append(wave)
        if batch.feedback:
            batcher.flush()
            hits = batch.keys[wave.results]
            if hits.size:
                waves.append(batcher.submit("report", hits))
    batcher.drain()
    return batcher, [w.results for w in waves]


# ------------------------------------------------- async/sync parity ----


@pytest.mark.parametrize("scenario", ["uniform", "burst_train",
                                      "delete_heavy", "adversarial"])
def test_double_buffered_path_is_bit_for_bit(scenario):
    """Double-buffering overlaps host prep with device execution but must
    issue the identical device-call sequence: every wave's results and the
    final filter state match the synchronous path exactly."""
    ba, ra = _replay(scenario, double_buffer=True)
    bs, rs = _replay(scenario, double_buffer=False)
    assert len(ra) == len(rs)
    for x, y in zip(ra, rs):
        assert np.array_equal(x, y)
    assert np.array_equal(np.asarray(ba.state.table),
                          np.asarray(bs.state.table))
    assert int(ba.state.count) == int(bs.state.count)
    if ba.stash is not None:
        assert np.array_equal(np.asarray(ba.stash), np.asarray(bs.stash))
    if hasattr(ba.state, "sels"):
        assert np.array_equal(np.asarray(ba.state.sels),
                              np.asarray(bs.state.sels))


# ------------------------------------------------- oracle parity --------


def _ops_stream(rng, n_ops, pool):
    """A deterministic single-key op mix over a small key pool."""
    ops = []
    inserted = []
    for _ in range(n_ops):
        r = rng.random()
        key = int(pool[rng.integers(pool.size)])
        if r < 0.5 or not inserted:
            ops.append(("insert", key))
            inserted.append(key)
        elif r < 0.7:
            ops.append(("delete", inserted.pop(
                int(rng.integers(len(inserted))))))
        else:
            ops.append(("lookup", key))
    return ops


def test_single_lane_parity_vs_stash_oracle():
    """Single-lane waves == the sequential kernel-faithful oracle, op for
    op and state for state, through spills and deletes."""
    NB, BS, FPB, ER, SS = 16, 4, 12, 8, 8
    rng = np.random.default_rng(11)
    pool = rng.integers(1, 2**63, 160, dtype=np.uint64)
    oracle = PyStashFilter(n_buckets=NB, bucket_size=BS, fp_bits=FPB,
                           evict_rounds=ER, stash_slots=SS)
    batcher = FilterOpBatcher(
        FilterOps(fp_bits=FPB, backend="pallas", evict_rounds=ER),
        jfilter.make_state(NB, BS), stash=kops.make_stash(SS),
        wave_slots=1, double_buffer=True)
    for kind, key in _ops_stream(rng, 120, pool):
        wave = batcher.submit(kind, np.asarray([key], np.uint64))
        expect = getattr(oracle, kind)(key)
        batcher.flush()
        assert bool(wave.results[0]) == expect, (kind, key)
    assert np.array_equal(np.asarray(batcher.state.table), oracle.table)
    assert np.array_equal(np.asarray(batcher.stash), oracle.stash_array())
    assert int(batcher.state.count) == oracle.count


def test_single_lane_parity_vs_adaptive_oracle():
    """Same contract over the adaptive planes, with the report verb in the
    mix: adapted flags, selector plane, and mirror planes all match."""
    NB, BS, FPB, ER, SS = 32, 4, 8, 8, 8
    rng = np.random.default_rng(13)
    members = rng.integers(1, 2**63, 96, dtype=np.uint64)
    probes = rng.integers(1, 2**63, 64, dtype=np.uint64)
    oracle = PyAdaptiveFilter(n_buckets=NB, bucket_size=BS, fp_bits=FPB,
                              evict_rounds=ER, stash_slots=SS)
    batcher = FilterOpBatcher(
        FilterOps(fp_bits=FPB, backend="pallas", evict_rounds=ER),
        make_adaptive_state(NB, BS), stash=kops.make_stash(SS),
        wave_slots=1, double_buffer=True)

    def step(kind, key):
        wave = batcher.submit(kind, np.asarray([key], np.uint64))
        if kind == "report":
            expect = oracle.report_false_positive(int(key))[0]
        else:
            expect = getattr(oracle, kind)(int(key))
        batcher.flush()
        assert bool(wave.results[0]) == expect, (kind, key)

    for key in members:
        step("insert", key)
    for key in probes:        # report every probe that false-positives
        wave = batcher.submit("lookup", np.asarray([key], np.uint64))
        batcher.flush()
        assert bool(wave.results[0]) == oracle.lookup(int(key))
        if wave.results[0]:
            step("report", key)
    for key in members[::3]:
        step("delete", key)
    assert np.array_equal(np.asarray(batcher.state.table), oracle.table)
    assert np.array_equal(np.asarray(batcher.state.sels),
                          oracle.sel_plane_array())
    khi, klo = oracle.key_planes()
    assert np.array_equal(np.asarray(batcher.state.khi), khi)
    assert np.array_equal(np.asarray(batcher.state.klo), klo)


# ------------------------------------------------- determinism ----------


def test_scenario_streams_are_deterministic():
    """One seed => one byte-identical stream, for every scenario (the
    bench-row comparability contract); a different seed must differ."""
    for name in SCENARIOS:
        a = scenario_stream(name, 123, wave_slots=WS, **SMALL[name])
        b = scenario_stream(name, 123, wave_slots=WS, **SMALL[name])
        assert len(a) == len(b)
        for x, y in zip(a, b):
            assert x.kind == y.kind
            assert (x.burst, x.advance, x.feedback) == \
                   (y.burst, y.advance, y.feedback)
            assert np.array_equal(x.keys, y.keys)
        c = scenario_stream(name, 124, wave_slots=WS, **SMALL[name])
        assert any(not np.array_equal(x.keys, y.keys)
                   for x, y in zip(a, c))


def test_serving_bench_streams_are_seed_reproducible():
    """The bench CLI's --seed flag threads one np.random.Generator into
    every generator: two builds at one seed are identical key streams."""
    import sys
    sys.path.insert(0, "benchmarks")
    try:
        import serving_bench
    finally:
        sys.path.pop(0)
    a = serving_bench.make_streams(seed=42, wave_slots=WS)
    b = serving_bench.make_streams(seed=42, wave_slots=WS)
    assert sorted(a) == sorted(b)
    for name in a:
        for x, y in zip(a[name], b[name]):
            assert x.kind == y.kind and np.array_equal(x.keys, y.keys)


# ------------------------------------------------- admission coupling ---


def test_admission_defers_readmits_and_sheds_under_burst():
    """Hysteresis both ways: the burst pushes the fills snapshot past high
    water (insert waves park), deletes pull it below low water (parked
    waves re-launch), and a sustained overload leaves shed ops behind."""
    # double_buffer pinned: the band is tuned against the async path's
    # lagged fills() snapshot, so the hysteresis trajectory must not
    # depend on the host's "auto" resolution
    batcher = make_batcher(
        "burst_train", wave_slots=WS, n_buckets=128, stash_slots=8,
        double_buffer=True,
        admission=AdmissionConfig(high_water=0.18, low_water=0.12))
    stream = scenario_stream("burst_train", 0, wave_slots=WS,
                             bursts=3, burst_waves=4, gap_waves=3)
    report = SloHarness().run(batcher, stream, scenario="burst_admission")
    assert report.deferred_waves > 0          # gate tripped at high water
    readmitted = [s for s in report.recorder.samples if s.deferred]
    assert readmitted                          # ...and re-admitted later
    assert batcher.admission.peak_signal >= 0.18
    # deferred waves carry their queueing delay: their tail cannot beat
    # the admitted-only tail
    admitted = report.recorder.percentiles(exclude_deferred=True)
    assert report.percentiles_us["p99"] >= admitted["p99"]
    lo, hi = batcher.fills()
    assert 0.0 <= lo <= 1.0 and 0.0 <= hi <= 1.0


def test_lookups_and_deletes_bypass_admission():
    """Only inserts are gated: probes add no occupancy and deletes relieve
    it, so a tripped gate must not defer either."""
    state = jfilter.make_state(16, 4)
    batcher = FilterOpBatcher(
        FilterOps(fp_bits=12, backend="pallas", evict_rounds=8),
        state, stash=kops.make_stash(8), wave_slots=WS,
        double_buffer=True,
        admission=AdmissionConfig(high_water=0.0, low_water=-1.0))
    keys = np.arange(1, WS + 1, dtype=np.uint64)
    w_ins = batcher.submit("insert", keys)
    w_look = batcher.submit("lookup", keys)
    w_del = batcher.submit("delete", keys)
    batcher.drain()
    assert w_ins.results is None               # parked forever (shed)
    assert w_look.results is not None and not w_look.results.any()
    assert w_del.results is not None
    assert batcher.stats.shed_ops == WS


def test_double_buffer_auto_resolves_per_host(monkeypatch):
    """``double_buffer="auto"`` picks the async path only where overlap can
    pay: real accelerators always, CPU hosts only with more than one core
    (on a single core the pipelined wave just queues behind the previous
    one).  Explicit flags are never overridden."""
    from repro.serving import scheduler as sched

    def mk(**kw):
        return FilterOpBatcher(FilterOps(fp_bits=12, backend="pallas"),
                               jfilter.make_state(16, 4), wave_slots=4,
                               **kw)

    monkeypatch.setattr(sched.jax, "default_backend", lambda: "cpu")
    monkeypatch.setattr(sched.os, "cpu_count", lambda: 8)
    assert mk().double_buffer
    monkeypatch.setattr(sched.os, "cpu_count", lambda: 1)
    assert not mk().double_buffer
    assert mk(double_buffer=True).double_buffer
    monkeypatch.setattr(sched.jax, "default_backend", lambda: "tpu")
    assert mk().double_buffer
    assert not mk(double_buffer=False).double_buffer


# ------------------------------------------------- recorder & reports ---


def test_recorder_percentiles_are_op_weighted():
    rec = LatencyRecorder()
    rec.observe("lookup", 100.0, ops=990)
    rec.observe("lookup", 1000.0, ops=10, deferred=True)
    p = rec.percentiles()
    assert p["p50"] == 100.0
    assert p["p999"] == 1000.0                # the slow wave IS the tail
    assert rec.percentiles(exclude_deferred=True)["p999"] == 100.0
    assert rec.ops() == 1000
    assert rec.percentiles(kinds=("insert",)) == {
        "p50": 0.0, "p99": 0.0, "p999": 0.0}


def test_report_rows_shape_and_monotonicity():
    """rows() carries the gate-facing names and p50 <= p99 <= p999."""
    rep = run_scenario("uniform", seed=3, wave_slots=WS, warmup=True,
                       stream_kwargs=SMALL["uniform"])
    rows = rep.rows()
    for suffix in ("p50_us", "p99_us", "p999_us", "keys_per_s"):
        assert f"slo_uniform_{suffix}" in rows
    assert rows["slo_uniform_p50_us"] <= rows["slo_uniform_p99_us"] \
        <= rows["slo_uniform_p999_us"]
    assert rows["slo_uniform_keys_per_s"] > 0
    assert rep.ops == sum(s.ops for s in rep.recorder.samples)


def test_ttl_churn_expires_generations():
    rep = run_scenario("ttl_churn", seed=5, wave_slots=WS, warmup=False,
                       stream_kwargs=SMALL["ttl_churn"])
    assert rep.extras["expirations"] > 0       # the ring actually aged
    assert rep.ops == WS * SMALL["ttl_churn"]["waves"]
    assert rep.percentiles_us["p99"] > 0
