"""Elastic resharding: pair routing, live split/merge, cutover protocol.

The routing layer first: ``owner_shard_pair`` must be (a) identical across
the np/jnp twins, (b) derivable from EITHER bucket of a candidate pair (the
involution invariance migration leans on — a resident slot knows only the
bucket it sits in), and (c) hierarchical across pow2 shard counts
(``owner(2n) mod n == owner(n)``), which is what makes a 2x split a strict
one-way scatter.

Then the migration itself, in a forced-4-device subprocess: a live 2->4
split and 4->2 merge over a ``DeferredWritePump`` with a concurrent write
stream parked mid-cutover — zero false negatives on everything previously
acknowledged, per-shard content parity against ``PyStashFilter`` oracles
rebuilt at the new shard count (multisets of (pair-id, fingerprint) — the
placement-schedule-free form of bit-parity), the parked backlog fully
drained, and the recovery metrics + ``pump_resubmit``/``elastic_*`` spans
exported.  Mesh tests run in subprocesses so the forced host-device count
doesn't leak (same pattern as test_distributed_write.py).
"""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import hashing
from repro.distributed import elastic

pytestmark = pytest.mark.tier1

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
        "JAX_PLATFORMS": "cpu"}


def _run(script):
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600, env=_ENV)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# ------------------------------------------------------ pair routing ----


def test_owner_pair_np_jnp_parity():
    import jax.numpy as jnp
    rng = np.random.RandomState(3)
    nb, fp_bits = 128, 16
    hi = rng.randint(0, 2**32, 512).astype(np.uint32)
    lo = rng.randint(0, 2**32, 512).astype(np.uint32)
    for n_shards in (2, 4, 8):
        o_np = hashing.owner_shard_key_pair_np(hi, lo, nb, fp_bits, n_shards)
        o_j = np.asarray(hashing.owner_shard_key_pair(
            jnp.asarray(hi), jnp.asarray(lo), nb, fp_bits, n_shards))
        assert np.array_equal(o_np, o_j)
        assert o_np.max() < n_shards


def test_owner_pair_bucket_invariance():
    """The owner must be computable from EITHER bucket of the pair — a
    migrating slot only knows the bucket it happens to sit in."""
    rng = np.random.RandomState(4)
    nb = 64
    b = rng.randint(0, nb, 1024).astype(np.uint32)
    fp = rng.randint(1, 2**16, 1024).astype(np.uint32)
    alt = hashing.alt_index_np(b, fp, nb)
    for n_shards in (2, 4):
        o1 = hashing.owner_shard_pair_np(b, fp, nb, n_shards)
        o2 = hashing.owner_shard_pair_np(alt, fp, nb, n_shards)
        assert np.array_equal(o1, o2)


def test_owner_pair_pow2_hierarchy():
    """owner(2n) mod n == owner(n): a split moves shard s's entries only to
    {s, s+n}, a merge folds s+n onto s — the elastic invariant."""
    rng = np.random.RandomState(5)
    nb, fp_bits = 256, 16
    hi = rng.randint(0, 2**32, 2048).astype(np.uint32)
    lo = rng.randint(0, 2**32, 2048).astype(np.uint32)
    for n in (1, 2, 4, 8):
        o_n = hashing.owner_shard_key_pair_np(hi, lo, nb, fp_bits, n)
        o_2n = hashing.owner_shard_key_pair_np(hi, lo, nb, fp_bits, 2 * n)
        assert np.array_equal(o_2n % n, o_n)
    # and the pair hash actually spreads load across shards
    o4 = hashing.owner_shard_key_pair_np(hi, lo, nb, fp_bits, 4)
    counts = np.bincount(o4, minlength=4)
    assert (counts > 0.5 * len(hi) / 4).all(), counts


def test_largest_mesh_compat():
    """Satellite regression: largest_mesh must work on jax lines WITHOUT
    jax.sharding.AxisType (0.4.x) as well as with it — the axis_types
    kwarg is feature-detected, not assumed."""
    import jax
    mesh = elastic.largest_mesh(model_parallel=1)
    assert mesh.shape["model"] == 1
    assert mesh.shape["data"] == len(jax.devices())
    # the helper itself: {} exactly when the enum is absent
    kw = elastic._axis_type_kwargs(2)
    if getattr(jax.sharding, "AxisType", None) is None:
        assert kw == {}
    else:
        assert len(kw["axis_types"]) == 2


# ----------------------------------------------- live split/merge -------


SPLIT_MERGE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import distributed as dist, hashing
    from repro.distributed import elastic
    from repro.obs import MetricsRegistry, TraceRecorder, RecoveryMetrics
    from repro.serving.scheduler import DeferredWritePump
    from repro.streaming.oracle import PyStashFilter

    NB, BS, FP, SS = 32, 4, 16, 32
    CF = 8.0

    def pair_multiset(table, stash, nb):
        # {(pair-id, fp)} with multiplicity: the placement-free content
        # identity (pair-id = min(bucket, alt(bucket, fp))).
        out = []
        t = np.asarray(table)
        for b in range(t.shape[0]):
            for fp in t[b][t[b] != 0]:
                alt = int(hashing.alt_index_np(np.uint32(b), np.uint32(fp),
                                               nb))
                out.append((min(b, alt), int(fp)))
        s = np.asarray(stash)
        for fp, bkt in zip(s[0][s[0] != 0], s[1][s[0] != 0]):
            alt = int(hashing.alt_index_np(np.uint32(bkt), np.uint32(fp),
                                           nb))
            out.append((min(int(bkt), alt), int(fp)))
        return sorted(out)

    def oracle_multisets(keys, n_shards):
        hi, lo = hashing.key_to_u32_pair_np(keys)
        owner = hashing.owner_shard_key_pair_np(hi, lo, NB, FP, n_shards)
        oracles = [PyStashFilter(n_buckets=NB, bucket_size=BS, fp_bits=FP,
                                 stash_slots=SS) for _ in range(n_shards)]
        for k, o in zip(keys, owner):
            assert oracles[o].insert(int(k)), "oracle overfull"
        out = []
        for o in oracles:
            ms = pair_multiset(o.table, np.zeros((2, 1)), NB)
            for fp, bkt in o.stash:
                alt = int(hashing.alt_index_np(np.uint32(bkt),
                                               np.uint32(fp), NB))
                ms.append((min(int(bkt), alt), int(fp)))
            out.append(sorted(ms))
        return out

    rng = np.random.RandomState(11)
    raw = rng.randint(0, 2**63, size=96, dtype=np.int64).astype(np.uint64)
    hi, lo = hashing.key_to_u32_pair_np(raw)

    m2 = elastic.filter_mesh(2)
    m4 = elastic.filter_mesh(4)
    reg, tr = MetricsRegistry(), TraceRecorder()
    rec = RecoveryMetrics(metrics=reg, tracer=tr)
    pump = DeferredWritePump(
        m2, "data", dist.make_sharded_state(2, NB, BS, stash_slots=SS),
        fp_bits=FP, backend="jnp", donate=False, metrics=reg, tracer=tr,
        route="pair", capacity_factor=CF)
    ok, _ = pump.submit(hi, lo)
    pump.run_until_drained()
    assert pump.pending == 0 and pump.stats.failed == 0

    # -- concurrent stream arrives mid-cutover: must park, then drain --
    raw2 = rng.randint(0, 2**63, size=32, dtype=np.int64).astype(np.uint64)
    h2, l2 = hashing.key_to_u32_pair_np(raw2)
    ctrl = elastic.ElasticController(pump, axis="data", recovery=rec)
    pump.hold()
    ok2, def2 = pump.submit(h2, l2)
    parked_during_window = (not ok2.any()) and bool(def2.all())
    pend_mid = pump.pending
    rep_split = ctrl.split(m4)

    all_keys = np.concatenate([raw, raw2])
    ahi, alo = hashing.key_to_u32_pair_np(all_keys)
    hits4, _ = dist.distributed_lookup(
        m4, "data", pump.state, jnp.asarray(ahi), jnp.asarray(alo),
        fp_bits=FP, backend="jnp", route="pair", capacity_factor=CF)
    split_fns = int((~np.asarray(hits4)).sum())

    dev_ms4 = [pair_multiset(pump.state.tables[s], pump.state.stashes[s],
                             NB) for s in range(4)]
    parity4 = dev_ms4 == oracle_multisets(all_keys, 4)

    # -- merge back 4 -> 2 --
    rep_merge = ctrl.merge(m2)
    hits2, _ = dist.distributed_lookup(
        m2, "data", pump.state, jnp.asarray(ahi), jnp.asarray(alo),
        fp_bits=FP, backend="jnp", route="pair", capacity_factor=CF)
    merge_fns = int((~np.asarray(hits2)).sum())
    dev_ms2 = [pair_multiset(pump.state.tables[s], pump.state.stashes[s],
                             NB) for s in range(2)]
    parity2 = dev_ms2 == oracle_multisets(all_keys, 2)

    # -- small-cap streaming: the same split must take multiple rounds --
    seed = dist.make_sharded_state(2, NB, BS, stash_slots=SS)
    seed, sok, sdef, _ = dist.distributed_insert(
        m2, "data", seed, jnp.asarray(hi), jnp.asarray(lo), fp_bits=FP,
        backend="jnp", route="pair", capacity_factor=CF)
    small, rep_small = elastic.split_state(m4, "data", seed, cap=4)
    hits_s, _ = dist.distributed_lookup(
        m4, "data", small, jnp.asarray(hi), jnp.asarray(lo), fp_bits=FP,
        backend="jnp", route="pair", capacity_factor=CF)
    small_fns = int((~np.asarray(hits_s)[np.asarray(sok)]).sum())

    snap = reg.snapshot()
    span_names = [e["name"] for e in tr.events]
    print(json.dumps({
        "parked_during_window": bool(parked_during_window),
        "pend_mid": int(pend_mid),
        "pend_after": int(pump.pending),
        "split_fns": split_fns, "merge_fns": merge_fns,
        "split_moved": rep_split.keys_moved,
        "merge_moved": rep_merge.keys_moved,
        "split_failed": rep_split.failed, "merge_failed": rep_merge.failed,
        "parity4": bool(parity4), "parity2": bool(parity2),
        "small_rounds": rep_small.rounds, "small_fns": small_fns,
        "metrics": {k: v for k, v in snap.items()
                    if k.startswith(("elastic_",))},
        "has_resubmit_span": "pump_resubmit" in span_names,
        "has_split_span": "elastic_split" in span_names,
        "has_merge_span": "elastic_merge" in span_names,
    }))
""")


def test_live_split_merge_subprocess():
    """2->4 split and 4->2 merge, live, with a parked concurrent stream:
    zero false negatives, oracle content parity, backlog drained."""
    res = _run(SPLIT_MERGE_SCRIPT)
    assert res["parked_during_window"], "held pump must park fresh submits"
    assert res["pend_mid"] == 32
    assert res["pend_after"] == 0, "backlog must drain after cutover"
    assert res["split_fns"] == 0, "split lost keys (false negatives)"
    assert res["merge_fns"] == 0, "merge lost keys (false negatives)"
    assert res["split_moved"] > 0 and res["merge_moved"] > 0
    assert res["split_failed"] == 0 and res["merge_failed"] == 0
    assert res["parity4"], "post-split content != 4-shard oracle rebuild"
    assert res["parity2"], "post-merge content != 2-shard oracle rebuild"
    assert res["small_rounds"] > 1, "tiny cap must stream multiple rounds"
    assert res["small_fns"] == 0
    m = res["metrics"]
    assert m['elastic_keys_migrated{direction="split"}'] > 0
    assert m['elastic_keys_migrated{direction="merge"}'] > 0
    assert m["elastic_deferred_backlog"] == 0
    assert m['elastic_time_to_recover_s{event="elastic_split"}'] > 0
    assert m['elastic_time_to_recover_s{event="elastic_merge"}'] > 0
    assert m["elastic_backlog_drained_lanes"] >= 32
    assert res["has_resubmit_span"], "pump resubmits must emit spans"
    assert res["has_split_span"] and res["has_merge_span"]
