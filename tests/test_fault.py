"""Fault tolerance: watchdog, restarts, injection, degraded serving.

Host-side pieces in-process (the watchdog's exact flag boundary, the
restart loop's exhaustion/backoff contract, the bounded write retry); the
shard-loss story in a forced-2-device subprocess: checkpoint round-trip is
bit-for-bit, a killed shard degrades lookups to conservative positives with
ZERO false negatives, checkpoint-restart closes the window, and the
recovery metrics land in the registry export — the degraded-answer
semantics ARCHITECTURE.md documents, pinned.
"""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.distributed import fault
from repro.obs import MetricsRegistry

pytestmark = pytest.mark.tier1

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
        "JAX_PLATFORMS": "cpu"}


def _run(script):
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600, env=_ENV)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# ------------------------------------------------------- watchdog -------


def test_watchdog_exact_flag_boundary():
    """The flag fires strictly ABOVE factor x median — at the boundary a
    step is merely slow, not a straggler."""
    wd = fault.StragglerWatchdog(factor=3.0)
    for _ in range(5):
        assert not wd.observe(1.0)
    assert not wd.observe(3.0), "exactly factor x median must NOT flag"
    assert wd.observe(3.0001), "strictly above must flag"
    assert wd.flagged == 1


def test_watchdog_feeds_registry():
    reg = MetricsRegistry()
    wd = fault.StragglerWatchdog(factor=3.0, metrics=reg)
    for _ in range(4):
        wd.observe(1.0)
    wd.observe(9.0)
    snap = reg.snapshot()
    assert snap["straggler_flagged"] == 1
    assert snap["straggler_median_s"] == 1.0
    assert snap["straggler_last_ratio"] == pytest.approx(9.0)


def test_watchdog_empty_history_never_flags():
    wd = fault.StragglerWatchdog(factor=3.0)
    assert not wd.observe(1e9), "first observation has no median to exceed"


# -------------------------------------------------- restart loops -------


def test_run_with_restarts_restores_and_succeeds(monkeypatch):
    sleeps = []
    monkeypatch.setattr(fault.time, "sleep", sleeps.append)
    ckpt_steps = [None, 3, 7]           # what latest_step_fn sees each try
    built, fails = [], [2]

    def make_state(step):
        built.append(step)
        return step

    def run_from(state):
        if fails[0] > 0:
            fails[0] -= 1
            raise RuntimeError("node died")
        return ("done", state)

    out = fault.run_with_restarts(
        make_state, run_from,
        fault.RestartPolicy(max_restarts=5, backoff_s=0.1),
        latest_step_fn=lambda: ckpt_steps[len(built)]
        if len(built) < len(ckpt_steps) else 7)
    assert out == ("done", 7), "must resume from the LATEST checkpoint"
    assert built == [None, 3, 7], "each restart re-reads latest_step_fn"
    assert sleeps == pytest.approx([0.1, 0.2]), "backoff must be monotone"


def test_run_with_restarts_exhaustion_reraises(monkeypatch):
    sleeps = []
    monkeypatch.setattr(fault.time, "sleep", sleeps.append)
    calls = [0]

    def run_from(state):
        calls[0] += 1
        raise ValueError("permanently broken")

    with pytest.raises(ValueError, match="permanently broken"):
        fault.run_with_restarts(
            lambda step: step, run_from,
            fault.RestartPolicy(max_restarts=2, backoff_s=0.5),
            latest_step_fn=lambda: None)
    assert calls[0] == 3, "initial try + max_restarts retries"
    assert sleeps == pytest.approx([0.5, 1.0]), \
        "monotone backoff, none after the re-raise"


def test_retry_routed_write_bounded():
    inj = fault.FaultInjector()
    flaky = inj.failing(lambda: "written", times=2)
    sleeps = []
    out = fault.retry_routed_write(
        flaky, fault.RestartPolicy(max_restarts=5, backoff_s=0.05),
        sleep=sleeps.append)
    assert out == "written"
    assert sleeps == pytest.approx([0.05, 0.1]), "monotone backoff"

    hopeless = inj.failing(lambda: "never", times=99)
    with pytest.raises(fault.InjectedFault):
        fault.retry_routed_write(
            hopeless, fault.RestartPolicy(max_restarts=2, backoff_s=0.01),
            sleep=sleeps.append)


def test_injector_delay_passthrough():
    inj = fault.FaultInjector()
    slow = inj.delay(lambda x: x * 2, seconds=0.0)
    assert slow(21) == 42


# ------------------------------------- shard loss, degraded, recover ----


SHARD_LOSS_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.checkpoint import ckpt
    from repro.core import distributed as dist, hashing
    from repro.distributed import elastic, fault
    from repro.obs import MetricsRegistry, TraceRecorder, RecoveryMetrics

    NB, BS, FP, SS = 32, 4, 16, 16
    CF = 8.0
    mesh = elastic.filter_mesh(2)
    state = dist.make_sharded_state(2, NB, BS, stash_slots=SS)
    rng = np.random.RandomState(13)
    raw = rng.randint(0, 2**63, size=128, dtype=np.int64).astype(np.uint64)
    hi, lo = hashing.key_to_u32_pair_np(raw)
    state, ok, deferred, _ = dist.distributed_insert(
        mesh, "data", state, jnp.asarray(hi), jnp.asarray(lo), fp_bits=FP,
        backend="jnp", capacity_factor=CF)
    keep = np.asarray(ok)
    hi, lo = hi[keep], lo[keep]
    if hi.size % 2:
        hi, lo = hi[:-1], lo[:-1]

    # -- checkpoint round-trip: bit-for-bit --
    d = tempfile.mkdtemp()
    ckpt.save_sharded(d, 5, state)
    snap = ckpt.restore_sharded(d)
    rt_tables = bool(np.array_equal(np.asarray(snap.tables),
                                    np.asarray(state.tables)))
    rt_stashes = bool(np.array_equal(np.asarray(snap.stashes),
                                     np.asarray(state.stashes)))
    rt_nb = snap.n_buckets == state.n_buckets
    rt_latest = ckpt.latest_step(d) == 5

    # -- kill shard 0, serve degraded --
    reg, tr = MetricsRegistry(), TraceRecorder()
    rec = RecoveryMetrics(metrics=reg, tracer=tr)
    inj = fault.FaultInjector(recovery=rec)
    dead = inj.kill(state, 0)
    owner = hashing.owner_shard_np(hi, lo, 2)
    hits, ovf, deg = fault.degraded_lookup(
        mesh, "data", dead, jnp.asarray(hi), jnp.asarray(lo), fp_bits=FP,
        injector=inj, backend="jnp", capacity_factor=CF, recovery=rec)
    zero_fns = bool(np.asarray(hits).all())
    deg_matches_owner = bool(np.array_equal(deg, owner == 0))

    # conservative positives: NEVER-inserted keys owned by the lost shard
    # answer True; surviving-shard strangers still mostly answer False.
    fresh = rng.randint(0, 2**63, size=256, dtype=np.int64).astype(np.uint64)
    fhi, flo = hashing.key_to_u32_pair_np(fresh)
    fown = hashing.owner_shard_np(fhi, flo, 2)
    fhits, _, fdeg = fault.degraded_lookup(
        mesh, "data", dead, jnp.asarray(fhi), jnp.asarray(flo), fp_bits=FP,
        injector=inj, backend="jnp", capacity_factor=CF, recovery=rec)
    lost_conservative = bool(fhits[fown == 0].all())
    survivor_fpr = float(fhits[fown == 1].mean())

    # -- recover from the snapshot, verify the window closes --
    healed = fault.recover_shard(dead, 0, ckpt_dir=d, injector=inj,
                                 recovery=rec)
    injector_healed = not inj.lost
    hits2, _ = dist.distributed_lookup(
        mesh, "data",
        healed._replace(tables=jnp.asarray(healed.tables),
                        stashes=jnp.asarray(healed.stashes)),
        jnp.asarray(hi), jnp.asarray(lo), fp_bits=FP, backend="jnp",
        capacity_factor=CF)
    recovered_all = bool(np.asarray(hits2).all())

    out = os.path.join(d, "recovery_metrics.jsonl")
    reg.to_jsonl(out)
    snapm = reg.snapshot()
    print(json.dumps({
        "rt_tables": rt_tables, "rt_stashes": rt_stashes,
        "rt_nb": bool(rt_nb), "rt_latest": bool(rt_latest),
        "zero_fns": zero_fns, "deg_matches_owner": deg_matches_owner,
        "n_degraded": int(np.asarray(deg).sum()),
        "n_fresh_degraded": int(np.asarray(fdeg).sum()),
        "lost_conservative": lost_conservative,
        "survivor_fpr": survivor_fpr,
        "injector_healed": bool(injector_healed),
        "recovered_all": recovered_all,
        "faults_kill": snapm.get('shard_faults{kind="kill"}', 0),
        "degraded_total": snapm.get("degraded_lookup_answers", 0),
        "ttr_present": 'elastic_time_to_recover_s{event="shard_restore"}'
                       in snapm,
        "jsonl_lines": sum(1 for _ in open(out)),
        "has_recover_span": "recover_shard" in
                            [e["name"] for e in tr.events],
    }))
""")


def test_shard_loss_degraded_recover_subprocess():
    """Kill one of two shards: zero false negatives, conservative positives
    for the lost shard only, checkpoint-restart recovers, metrics export."""
    res = _run(SHARD_LOSS_SCRIPT)
    assert res["rt_tables"] and res["rt_stashes"] and res["rt_nb"], \
        "checkpoint round-trip must be bit-for-bit"
    assert res["rt_latest"]
    assert res["zero_fns"], "shard loss caused a false negative"
    assert res["deg_matches_owner"], \
        "degraded mask must be exactly the lost shard's keys"
    assert res["n_degraded"] > 0, "workload must exercise the lost shard"
    assert res["lost_conservative"], \
        "never-inserted keys on the lost shard must answer maybe-present"
    assert res["survivor_fpr"] < 0.5, \
        "surviving shard must keep real (non-degraded) answers"
    assert res["injector_healed"] and res["recovered_all"], \
        "checkpoint-restart must fully close the degraded window"
    assert res["faults_kill"] == 1
    assert res["degraded_total"] == res["n_degraded"] + \
        res["n_fresh_degraded"], "every conservative answer must be counted"
    assert res["ttr_present"], "time-to-recover gauge must be exported"
    assert res["jsonl_lines"] > 0, "recovery metrics JSONL must be written"
    assert res["has_recover_span"]
