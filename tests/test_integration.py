"""End-to-end integration: train driver (with OCF dedup), serve driver (with
OCF prefix cache), checkpoint-restart, fault injection, elastic resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import train
from repro.launch.serve import serve


def test_train_loop_loss_decreases(tmp_path):
    out = train("gemma2_27b", steps=12, batch=4, seq=64, smoke=True,
                ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=5)
    losses = [h["loss"] for h in out["history"]]
    assert all(np.isfinite(losses))
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), "loss must decrease"
    assert out["pipeline_stats"].docs_deduped > 0, "OCF dedup active"


def test_checkpoint_restart_resumes(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    with pytest.raises(RuntimeError, match="injected"):
        train("mistral_nemo_12b", steps=10, batch=2, seq=32, smoke=True,
              ckpt_dir=ckpt, ckpt_every=2, inject_failure_at=7)
    from repro.checkpoint.ckpt import latest_step
    assert latest_step(ckpt) == 6
    out = train("mistral_nemo_12b", steps=10, batch=2, seq=32, smoke=True,
                ckpt_dir=ckpt, ckpt_every=2, resume=True)
    assert len(out["history"]) == 4, "resumed from step 6, ran 6..10"


def test_run_with_restarts_helper(tmp_path):
    from repro.checkpoint.ckpt import latest_step
    from repro.distributed.fault import RestartPolicy, run_with_restarts
    ckpt = str(tmp_path / "ckpt")
    attempts = []

    def make_state(step):
        return step

    def run_from(state):
        attempts.append(state)
        if len(attempts) < 3:
            return train("gemma3_1b", steps=6, batch=2, seq=32, smoke=True,
                         ckpt_dir=ckpt, ckpt_every=2,
                         inject_failure_at=3 + len(attempts))
        return train("gemma3_1b", steps=6, batch=2, seq=32, smoke=True,
                     ckpt_dir=ckpt, ckpt_every=2)

    out = run_with_restarts(make_state, run_from, RestartPolicy(max_restarts=5),
                            latest_step_fn=lambda: latest_step(ckpt))
    assert out is not None
    assert len(attempts) == 3


def test_serve_driver_prefix_cache_hits():
    out = serve("gemma3_1b", requests=8, prefix_len=64, gen=4, smoke=True,
                block=16)
    assert out["prefix_hit_rate"] > 0, "shared prefixes must hit the index"
    assert out["ocf_stats"].inserts > 0
    assert out["filter_occupancy"] <= 0.96


def test_elastic_reshard_roundtrip():
    from repro.distributed.elastic import largest_mesh, reshard_state
    from repro.distributed.sharding import ParallelConfig
    from repro.models import Transformer
    from repro.configs import get_smoke_config

    cfg = get_smoke_config("mistral_nemo_12b")
    model = Transformer(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    mesh = largest_mesh(jax.devices()[:1], model_parallel=1)
    moved = reshard_state(params, specs, mesh, ParallelConfig())
    same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), params, moved)
    assert all(jax.tree.leaves(same))


def test_async_checkpointer_roundtrip(tmp_path):
    from repro.checkpoint.ckpt import AsyncCheckpointer, restore
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3))}}
    ac = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        ac.save(s, jax.tree.map(lambda x: x * s, tree))
    ac.join()
    got, manifest = restore(str(tmp_path), 3, tree)
    np.testing.assert_allclose(np.asarray(got["a"]), np.arange(10.0) * 3)
    assert not os.path.exists(str(tmp_path) + "/step_00000001"), "gc keeps 2"


def test_data_pipeline_dedup_and_retirement():
    from repro.data.pipeline import DedupPipeline, SyntheticDocs
    pipe = DedupPipeline(SyntheticDocs(1000, doc_len=64, seed=1,
                                       dup_rate=0.5),
                         batch=4, seq=63, shard_docs=20)
    it = iter(pipe)
    for _ in range(30):
        b = next(it)
        assert b["tokens"].shape == (4, 63)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])
    assert pipe.stats.docs_deduped > 0
    assert pipe.stats.shards_retired > 0, "aged shards deleted from filter"
    assert pipe.ocf.stats.deletes > 0
