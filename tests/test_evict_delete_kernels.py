"""PR-3 kernel validation: device-side eviction rounds and the fused delete.

Covers the acceptance criteria for closing the Pallas data-plane gaps:
  * eviction-round parity vs the lax.scan path at >= 0.9 load factor;
  * lossless rollback under a near-full-table eviction storm (a failed
    insert NEVER orphans a resident fingerprint — the paper's
    false-negative-at-saturation safeguard, on device);
  * delete parity vs the jnp scan path AND the pyfilter oracle, bit for
    bit, including duplicate keys beyond the resident multiplicity;
  * empty-batch guards on both new kernels;
  * the FilterOps pallas backend never touching the scan fallback.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PyCuckooFilter, hashing
from repro.core import filter as jf
from repro.core.filter_ops import FilterOps, evict_rounds_for_load
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.delete import delete_bulk
from repro.kernels.insert import insert_bulk
from repro.kernels.probe import probe

from conftest import random_keys

pytestmark = pytest.mark.tier1


def _pair(keys):
    hi, lo = hashing.key_to_u32_pair_np(keys)
    return jnp.asarray(hi), jnp.asarray(lo)


def _probe_all(table, hi, lo, n_buckets=None):
    n = hi.shape[0]
    pad = (-n) % 256
    hit = probe(jnp.asarray(table), jnp.pad(hi, (0, pad)),
                jnp.pad(lo, (0, pad)), fp_bits=16, n_buckets=n_buckets,
                block=256, interpret=True)
    return np.asarray(hit)[:n]


# ------------------------------------------------ eviction-round inserts --


def test_evict_rounds_parity_vs_scan_high_load(rng):
    """>= 0.9 load from empty: the kernel's bounded eviction rounds place
    the same key set the sequential scan does, and every placed key is
    findable on both backends' tables.  The 64-round budget this load needs
    comes from the config curve, not an ad-hoc override."""
    n_buckets, n = 256, 920                 # 920 / 1024 slots = 0.9
    rounds = evict_rounds_for_load(0.9)
    assert rounds == 64
    keys = random_keys(rng, n)
    hi, lo = _pair(keys)
    st = jf.make_state(n_buckets, 4)
    st_j, ok_j = jf.bulk_insert_hybrid(st, hi, lo, fp_bits=16)
    t_p, ok_p = insert_bulk(st.table, hi, lo, fp_bits=16, block=n,
                            evict_rounds=rounds, interpret=True)
    assert np.asarray(ok_j).all(), "scan path must drain this workload"
    np.testing.assert_array_equal(np.asarray(ok_p), np.asarray(ok_j))
    # fingerprint conservation: exactly one slot per placed key, and every
    # placed key answers True through the probe kernel on both tables.
    assert int((np.asarray(t_p) != 0).sum()) == n
    assert _probe_all(t_p, hi, lo).all()
    assert _probe_all(st_j.table, hi, lo).all()


def test_evict_rounds_multi_block_high_load(rng):
    """Multi-block grids accumulate through the aliased table at high load;
    placements from earlier blocks are visible (and evictable) later."""
    keys = random_keys(rng, 4096)
    hi, lo = _pair(keys)
    st = jf.make_state(1152, 4)             # 4096 / 4608 slots = 0.89
    t_p, ok_p = insert_bulk(st.table, hi, lo, fp_bits=16, block=1024,
                            evict_rounds=32, interpret=True)
    ok = np.asarray(ok_p)
    assert int((np.asarray(t_p) != 0).sum()) == int(ok.sum())
    assert _probe_all(t_p, hi, lo)[ok].all()
    # the scan path places everything here; the bounded kernel must come
    # within a hair of it (chains it gives up on report False, not corrupt)
    _, ok_j = jf.bulk_insert_hybrid(st, hi, lo, fp_bits=16)
    assert ok.sum() >= int(np.asarray(ok_j).sum()) - 8


def test_eviction_storm_rollback_never_corrupts_residents(rng):
    """Near-full table + oversized burst: chains exhaust the round budget,
    roll back, and report False — no resident fingerprint is lost or
    duplicated (count conservation, bit for bit)."""
    base = random_keys(rng, 240)            # 240 / 256 slots = 0.94
    bhi, blo = _pair(base)
    st = jf.make_state(64, 4)
    st, ok_base = jf.bulk_insert(st, bhi, blo, fp_bits=16)
    placed_base = np.asarray(ok_base)
    extra = random_keys(rng, 64)
    ehi, elo = _pair(extra)
    t, ok = insert_bulk(st.table, ehi, elo, fp_bits=16, block=64,
                        evict_rounds=8, interpret=True)
    ok = np.asarray(ok)
    assert not ok.all(), "storm must overflow the round budget"
    assert _probe_all(t, bhi, blo)[placed_base].all(), \
        "rollback lost a resident fingerprint"
    assert _probe_all(t, ehi, elo)[ok].all()
    assert int((np.asarray(t) != 0).sum()) == int(placed_base.sum() + ok.sum())


def test_filter_ops_pallas_insert_no_scan_fallback(rng, monkeypatch):
    """FilterOps(backend='pallas').insert resolves the residue on-device:
    jfilter.bulk_insert must never be called (acceptance criterion)."""
    from repro.core import filter_ops as fops_mod

    def boom(*a, **kw):
        raise AssertionError("pallas insert fell back to jfilter.bulk_insert")

    monkeypatch.setattr(fops_mod.jfilter, "bulk_insert", boom)
    keys = random_keys(rng, 1800)           # 1800 / 2048 slots = 0.88
    hi, lo = _pair(keys)
    fops = FilterOps(fp_bits=16, backend="pallas")
    st, ok = fops.insert(jf.make_state(512, 4), hi, lo)
    assert np.asarray(ok).all()
    assert int(st.count) == 1800
    assert np.asarray(fops.lookup(st, hi, lo)).all()


def test_evict_rounds_respect_active_region(rng):
    """Eviction chains stay inside the ACTIVE bucket range of a larger
    pow2 buffer (the SMEM scalar governs every round, not just round 0)."""
    keys = random_keys(rng, 1120)           # 1120 / 1200 active slots = 0.93
    hi, lo = _pair(keys)
    st = jf.make_state(300, 4, buffer_buckets=512)
    t, ok = insert_bulk(st.table, hi, lo, fp_bits=16, n_buckets=st.n_buckets,
                        block=1120, evict_rounds=32, interpret=True)
    assert not np.asarray(t)[300:].any(), "fp escaped the active region"
    assert _probe_all(t, hi, lo, n_buckets=st.n_buckets)[np.asarray(ok)].all()


# --------------------------------------------------------------- deletes --


def test_delete_kernel_parity_scan_and_oracle(rng):
    """Random deletes (hits, misses, foreign keys): kernel vs scan vs
    pyfilter, table bit-for-bit."""
    keys = random_keys(rng, 1500)
    hi, lo = _pair(keys)
    st = jf.make_state(512, 4)
    st, _ = jf.bulk_insert(st, hi, lo, fp_bits=16)
    oracle = PyCuckooFilter(n_buckets=512, bucket_size=4, fp_bits=16)
    oracle.bulk_insert(keys)
    dels = np.concatenate([keys[400:900], random_keys(rng, 300)])
    dhi, dlo = _pair(dels)
    st_j, ok_j = jf.bulk_delete(st, dhi, dlo, fp_bits=16)
    ok_o = oracle.bulk_delete(dels)
    t_p, ok_p = delete_bulk(st.table, dhi, dlo, fp_bits=16, block=800,
                            interpret=True)
    np.testing.assert_array_equal(np.asarray(ok_j), ok_o)
    np.testing.assert_array_equal(np.asarray(ok_p), ok_o)
    np.testing.assert_array_equal(np.asarray(t_p), oracle.table)
    np.testing.assert_array_equal(np.asarray(t_p), np.asarray(st_j.table))


def test_delete_duplicate_keys_parity(rng):
    """The k-th duplicate of a key clears the k-th resident copy; deletes
    beyond the multiplicity report False — matching the sequential scan and
    the oracle bit-for-bit even when duplicates share one kernel block."""
    uniq = random_keys(rng, 600)
    dups = uniq[:80]
    ins = np.concatenate([uniq, dups])      # dups resident twice
    ihi, ilo = _pair(ins)
    st = jf.make_state(512, 4)
    st, _ = jf.bulk_insert(st, ihi, ilo, fp_bits=16)
    oracle = PyCuckooFilter(n_buckets=512, bucket_size=4, fp_bits=16)
    oracle.bulk_insert(ins)
    # delete each dup three times (one more than resident), in one block
    dels = np.concatenate([dups, uniq[300:400], dups, dups])
    dhi, dlo = _pair(dels)
    st_j, ok_j = jf.bulk_delete(st, dhi, dlo, fp_bits=16)
    ok_o = oracle.bulk_delete(dels)
    t_p, ok_p = delete_bulk(st.table, dhi, dlo, fp_bits=16,
                            block=dels.size, interpret=True)
    np.testing.assert_array_equal(np.asarray(ok_j), ok_o)
    np.testing.assert_array_equal(np.asarray(ok_p), ok_o)
    np.testing.assert_array_equal(np.asarray(t_p), oracle.table)
    # third round of dup deletes must have failed (multiplicity exhausted)
    assert not np.asarray(ok_p)[-dups.size:].any()


def test_delete_buffered_active_region(rng):
    """Delete with active < buffer reads the same SMEM-scalar state."""
    keys = random_keys(rng, 800)
    hi, lo = _pair(keys)
    st = jf.make_state(300, 4, buffer_buckets=512)
    st, ok = jf.bulk_insert(st, hi, lo, fp_bits=16)
    st_j, ok_j = jf.bulk_delete(st, hi, lo, fp_bits=16)
    t_p, ok_p = delete_bulk(st.table, hi, lo, fp_bits=16,
                            n_buckets=st.n_buckets, block=800, interpret=True)
    np.testing.assert_array_equal(np.asarray(ok_p), np.asarray(ok_j))
    np.testing.assert_array_equal(np.asarray(t_p), np.asarray(st_j.table))


def test_filter_ops_delete_dispatch_and_count(rng, monkeypatch):
    """FilterOps(backend='pallas').delete dispatches to the delete kernel
    (not the scan) and keeps the live count in sync."""
    calls = {"delete": 0}
    real = kops.delete_bulk

    def spy(*a, **kw):
        calls["delete"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(kops, "delete_bulk", spy)
    keys = random_keys(rng, 1000)
    hi, lo = _pair(keys)
    fops = FilterOps(fp_bits=16, backend="pallas")
    st, ok = fops.insert(jf.make_state(512, 4), hi, lo)
    st2, okd = fops.delete(st, hi[:400], lo[:400])
    assert calls["delete"] == 1
    assert np.asarray(okd).all()
    assert int(st2.count) == int(st.count) - 400
    st_j, okd_j = FilterOps(fp_bits=16, backend="jnp").delete(
        st, hi[:400], lo[:400])
    np.testing.assert_array_equal(np.asarray(okd), np.asarray(okd_j))
    np.testing.assert_array_equal(np.asarray(st2.table),
                                  np.asarray(st_j.table))


# ---------------------------------------------------------------- guards --


def test_empty_batch_guards_new_kernels(rng):
    """Zero-length batches return empty results through every entry point
    of both new kernels — no ZeroDivisionError in block-size math."""
    st = jf.make_state(256, 4)
    e = jnp.zeros((0,), jnp.uint32)
    t, ok = kops.filter_insert(st.table, e, e, fp_bits=16, evict_rounds=16,
                               use_pallas="always")
    assert np.asarray(ok).shape == (0,) and not np.asarray(t).any()
    t, ok = kops.filter_delete(st.table, e, e, fp_bits=16,
                               use_pallas="always")
    assert np.asarray(ok).shape == (0,) and not np.asarray(t).any()
    for backend in ("jnp", "pallas"):
        fops = FilterOps(fp_bits=16, backend=backend)
        st2, ok = fops.delete(st, e, e)
        assert np.asarray(ok).shape == (0,) and int(st2.count) == 0


def test_delete_ref_fallback_matches_kernel(rng):
    """ops.filter_delete's non-kernel arm (the scan oracle) agrees with the
    kernel arm on a random workload — 'auto' dispatch can't change answers."""
    keys = random_keys(rng, 1200)
    hi, lo = _pair(keys)
    st = jf.make_state(512, 4)
    st, _ = jf.bulk_insert(st, hi, lo, fp_bits=16)
    dels = np.concatenate([keys[:500], random_keys(rng, 200)])
    dhi, dlo = _pair(dels)
    t_k, ok_k = kops.filter_delete(st.table, dhi, dlo, fp_bits=16,
                                   use_pallas="always")
    t_r, ok_r = kops.filter_delete(st.table, dhi, dlo, fp_bits=16,
                                   use_pallas="never")
    np.testing.assert_array_equal(np.asarray(ok_k), np.asarray(ok_r))
    np.testing.assert_array_equal(np.asarray(t_k), np.asarray(t_r))


def test_insert_ref_fallback_completes_residue(rng):
    """ops.filter_insert with evict_rounds>0 on the non-kernel arm finishes
    the whole insert too (optimistic round + scan residue)."""
    keys = random_keys(rng, 1800)           # 0.88 load
    hi, lo = _pair(keys)
    st = jf.make_state(512, 4)
    t_r, ok_r = kops.filter_insert(st.table, hi, lo, fp_bits=16,
                                   evict_rounds=32, use_pallas="never")
    assert np.asarray(ok_r).all()
    t_k, ok_k = kops.filter_insert(st.table, hi, lo, fp_bits=16,
                                   evict_rounds=32, use_pallas="always")
    assert np.asarray(ok_k).all()
    assert _probe_all(t_k, hi, lo).all() and _probe_all(t_r, hi, lo).all()


# ----------------------------------------------- consumers of the kernels --


def test_distributed_shard_delete_roundtrip(rng):
    """local_shard_delete_host deletes through FilterOps on the owner shard
    only, on both backends."""
    from repro.core import distributed as dist
    keys = random_keys(rng, 1024)
    hi, lo = _pair(keys)
    st = jf.make_state(512, 4)
    st, _ = jf.bulk_insert(st, hi, lo, fp_bits=16)
    for backend in ("jnp", "pallas"):
        sh = dist.ShardedFilterState(
            tables=jnp.stack([st.table, st.table]))
        sh2, ok = dist.local_shard_delete_host(sh, 0, hi[:200], lo[:200],
                                               fp_bits=16, backend=backend)
        assert np.asarray(ok).all()
        # shard 1 untouched, shard 0 lost exactly 200 fingerprints
        np.testing.assert_array_equal(np.asarray(sh2.tables[1]),
                                      np.asarray(st.table))
        assert int((np.asarray(sh2.tables[0]) != 0).sum()) == \
            int((np.asarray(st.table) != 0).sum()) - 200


def test_kvcache_evict_reaches_delete_kernel(rng, monkeypatch):
    """PrefixCacheIndex(backend='pallas') eviction path runs the fused
    delete kernel end-to-end (serving-layer thread-through)."""
    from repro.serving.kvcache import PrefixCacheIndex
    calls = {"delete": 0}
    real = kops.delete_bulk

    def spy(*a, **kw):
        calls["delete"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(kops, "delete_bulk", spy)
    idx = PrefixCacheIndex(backend="pallas", block=32)
    tokens = rng.randint(0, 1000, size=256).astype(np.uint32)
    idx.admit(tokens)
    assert idx.match_prefix(tokens) == 256 // 32
    assert idx.evict(tokens) == 256 // 32
    assert calls["delete"] > 0, "evict did not reach the delete kernel"
    assert idx.match_prefix(tokens) == 0
