"""JAX bulk filter vs the oracle — table-exact for the sequential path."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PyCuckooFilter, hashing
from repro.core import filter as jf

from conftest import random_keys

pytestmark = pytest.mark.tier1


def _pair(keys):
    hi, lo = hashing.key_to_u32_pair_np(keys)
    return jnp.asarray(hi), jnp.asarray(lo)


@pytest.mark.parametrize("n_buckets,n_keys,fp_bits", [
    (256, 500, 16), (1024, 2000, 16), (1000, 1500, 12), (333, 600, 8),
])
def test_bulk_insert_matches_oracle_exactly(rng, n_buckets, n_keys, fp_bits):
    keys = random_keys(rng, n_keys)
    oracle = PyCuckooFilter(n_buckets=n_buckets, bucket_size=4,
                            fp_bits=fp_bits)
    ok_o = oracle.bulk_insert(keys)
    st = jf.make_state(n_buckets, 4)
    hi, lo = _pair(keys)
    st, ok_j = jf.bulk_insert(st, hi, lo, fp_bits=fp_bits)
    np.testing.assert_array_equal(ok_o, np.asarray(ok_j))
    np.testing.assert_array_equal(oracle.table, np.asarray(st.table))
    assert int(st.count) == oracle.count


def test_bulk_delete_matches_oracle(rng):
    keys = random_keys(rng, 1200)
    oracle = PyCuckooFilter(n_buckets=512, bucket_size=4, fp_bits=16)
    oracle.bulk_insert(keys)
    st = jf.make_state(512, 4)
    hi, lo = _pair(keys)
    st, _ = jf.bulk_insert(st, hi, lo, fp_bits=16)
    del_keys = keys[::3]
    ok_o = oracle.bulk_delete(del_keys)
    dhi, dlo = _pair(del_keys)
    st, ok_j = jf.bulk_delete(st, dhi, dlo, fp_bits=16)
    np.testing.assert_array_equal(ok_o, np.asarray(ok_j))
    np.testing.assert_array_equal(oracle.table, np.asarray(st.table))


def test_lookup_matches_oracle(rng):
    keys = random_keys(rng, 1000)
    probes = np.concatenate([keys[:500], random_keys(rng, 1000)])
    oracle = PyCuckooFilter(n_buckets=512, bucket_size=4, fp_bits=16)
    oracle.bulk_insert(keys)
    st = jf.make_state(512, 4)
    hi, lo = _pair(keys)
    st, _ = jf.bulk_insert(st, hi, lo, fp_bits=16)
    phi, plo = _pair(probes)
    got = np.asarray(jf.bulk_lookup(st, phi, plo, fp_bits=16))
    np.testing.assert_array_equal(oracle.bulk_lookup(probes), got)


def test_parallel_insert_membership_equivalent(rng):
    """Hybrid insert may lay the table out differently but answers the same
    membership queries (order-independence of cuckoo semantics)."""
    keys = random_keys(rng, 3000)
    hi, lo = _pair(keys)
    st_seq = jf.make_state(2048, 4)
    st_seq, ok_seq = jf.bulk_insert(st_seq, hi, lo, fp_bits=16)
    st_par, ok_par = jf.rebuild(hi, lo, 2048, 4, fp_bits=16)
    assert bool(np.asarray(ok_seq).all()) and bool(np.asarray(ok_par).all())
    assert int(st_seq.count) == int(st_par.count)
    probes = np.concatenate([keys, random_keys(rng, 3000)])
    phi, plo = _pair(probes)
    a = np.asarray(jf.bulk_lookup(st_seq, phi, plo, fp_bits=16))
    b = np.asarray(jf.bulk_lookup(st_par, phi, plo, fp_bits=16))
    # all inserted keys found in both
    assert a[:3000].all() and b[:3000].all()


def test_parallel_insert_no_slot_collisions(rng):
    keys = random_keys(rng, 4000)
    hi, lo = _pair(keys)
    st, placed = jf.parallel_insert_once(jf.make_state(2048, 4), hi, lo,
                                         fp_bits=16)
    # count matches placed: no fingerprint overwrote another
    assert int(st.count) == int(np.asarray(placed).sum())
    nonzero = int((np.asarray(st.table) != 0).sum())
    assert nonzero == int(st.count)
