"""Hypothesis property tests on the system's core invariants.

``hypothesis`` is an optional dev dependency: when absent the module skips
cleanly instead of killing collection for the whole suite.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import OCF, OcfConfig, PyCuckooFilter, hashing

keys_strategy = st.lists(st.integers(min_value=0, max_value=2 ** 64 - 1),
                         min_size=1, max_size=300, unique=True)


@settings(max_examples=30, deadline=None)
@given(keys=keys_strategy)
def test_no_false_negatives_after_any_insert_set(keys):
    f = PyCuckooFilter(n_buckets=512, bucket_size=4, fp_bits=16)
    arr = np.array(keys, dtype=np.uint64)
    ok = f.bulk_insert(arr)
    assert f.bulk_lookup(arr[ok]).all()


@settings(max_examples=30, deadline=None)
@given(keys=keys_strategy, n_del=st.integers(0, 300))
def test_delete_subset_invariant(keys, n_del):
    """After deleting any subset, the remainder is still found."""
    f = PyCuckooFilter(n_buckets=512, bucket_size=4, fp_bits=16)
    arr = np.array(keys, dtype=np.uint64)
    ok = f.bulk_insert(arr)
    ins = arr[ok]
    n_del = min(n_del, ins.size)
    f.bulk_delete(ins[:n_del])
    assert f.bulk_lookup(ins[n_del:]).all()


@settings(max_examples=20, deadline=None)
@given(keys=keys_strategy)
def test_count_is_exact(keys):
    f = PyCuckooFilter(n_buckets=1024, bucket_size=4, fp_bits=16)
    arr = np.array(keys, dtype=np.uint64)
    ok = f.bulk_insert(arr)
    assert f.count == int(ok.sum())
    del_ok = f.bulk_delete(arr)
    # every inserted key deletes exactly once (duplicates impossible: unique)
    assert f.count == int(ok.sum()) - int(del_ok.sum())


@settings(max_examples=20, deadline=None)
@given(keys=keys_strategy,
       n_buckets=st.sampled_from([64, 100, 257, 1024]))
def test_alt_index_involution_property(keys, n_buckets):
    arr = np.array(keys, dtype=np.uint64)
    hi, lo = hashing.key_to_u32_pair_np(arr)
    fp = hashing.fingerprint_np(hi, lo, 16)
    i1 = hashing.index_hash_np(hi, lo, n_buckets)
    i2 = hashing.alt_index_np(i1, fp, n_buckets)
    back = hashing.alt_index_np(i2, fp, n_buckets)
    np.testing.assert_array_equal(i1, back)


@settings(max_examples=10, deadline=None)
@given(keys=st.lists(st.integers(0, 2 ** 64 - 1), min_size=50, max_size=200,
                     unique=True),
       mode=st.sampled_from(["PRE", "EOF"]))
def test_ocf_occupancy_always_safe(keys, mode):
    """System invariant: the controller never lets occupancy exceed O_SAFE."""
    ocf = OCF(OcfConfig(capacity=1024, mode=mode, c_min=1024))
    arr = np.array(keys, dtype=np.uint64)
    for i in range(0, arr.size, 37):
        ocf.insert(arr[i:i + 37])
        assert ocf.occupancy <= 0.96
    for i in range(0, arr.size, 53):
        ocf.delete(arr[i:i + 53])
        assert ocf.occupancy <= 0.96
    assert ocf.count == 0 or ocf.lookup(
        arr[ocf.count and 0:1]) is not None
