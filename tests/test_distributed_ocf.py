"""Distributed OCF: shard_map all_to_all routing on an 8-device test mesh.

Runs in a subprocess so the 8 host devices don't leak into other tests."""
import json
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import distributed as dist, hashing
    from repro.core import filter as jf

    try:
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    except AttributeError:  # jax 0.4.x: no AxisType; Auto is the default
        mesh = jax.make_mesh((8,), ("data",))
    n_shards, n_buckets = 8, 512
    rng = np.random.RandomState(1)
    keys = rng.randint(0, 2**63, size=4096, dtype=np.int64).astype(np.uint64)
    hi, lo = hashing.key_to_u32_pair_np(keys)
    owner = np.asarray(hashing.owner_shard_np(hi, lo, n_shards))
    tables = np.zeros((n_shards, n_buckets, 4), np.uint32)
    for s in range(n_shards):
        m = owner == s
        fs = jf.make_state(n_buckets, 4)
        fs, ok = jf.bulk_insert(fs, jnp.asarray(hi[m]), jnp.asarray(lo[m]),
                                fp_bits=16)
        assert bool(np.asarray(ok).all())
        tables[s] = np.asarray(fs.table)
    st = dist.ShardedFilterState(tables=jnp.asarray(tables))
    hits, overflow = dist.distributed_lookup(
        mesh, "data", st, jnp.asarray(hi), jnp.asarray(lo), fp_bits=16)
    absent = rng.randint(0, 2**63, size=4096, dtype=np.int64).astype(np.uint64)
    ahi, alo = hashing.key_to_u32_pair_np(absent)
    ahits, _ = dist.distributed_lookup(
        mesh, "data", st, jnp.asarray(ahi), jnp.asarray(alo), fp_bits=16)
    # tiny capacity -> overflow counters fire (burst signal), answers stay
    # conservative (True)
    thits, toverflow = dist.distributed_lookup(
        mesh, "data", st, jnp.asarray(hi), jnp.asarray(lo), fp_bits=16,
        capacity_factor=0.25)
    rep = dist.replicated_lookup(st.tables, jnp.asarray(hi), jnp.asarray(lo),
                                 fp_bits=16)
    print(json.dumps({
        "present_found": int(np.asarray(hits).sum()),
        "n": int(keys.size),
        "absent_hits": int(np.asarray(ahits).sum()),
        "overflow_total": int(np.asarray(overflow).sum()),
        "tight_found": int(np.asarray(thits).sum()),
        "tight_overflow": int(np.asarray(toverflow).sum()),
        "replicated_found": int(np.asarray(rep).sum()),
    }))
""")


def test_distributed_lookup_subprocess():
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["present_found"] == res["n"], "no false negatives"
    assert res["absent_hits"] < 20, "fp rate sane"
    assert res["overflow_total"] == 0
    assert res["tight_found"] == res["n"], "overflow answers conservative"
    assert res["tight_overflow"] > 0, "congestion signal fires under burst"
    assert res["replicated_found"] == res["n"]
