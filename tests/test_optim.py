"""Optimizer + schedule unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import AdamW, cosine_schedule


def test_adamw_converges_quadratic():
    tx = AdamW(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = tx.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = tx.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clip_caps_norm():
    tx = AdamW(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    state = tx.init(params)
    _, _, gn = tx.update({"w": jnp.full(4, 100.0)}, state, params)
    assert float(gn) == 200.0  # raw norm reported
    # after clip, m == grads * scale => |m| <= clip * (1-b1)
    _, state2, _ = tx.update({"w": jnp.full(4, 100.0)}, state, params)
    assert float(jnp.linalg.norm(state2.m["w"])) <= 1.0 * 0.1 + 1e-6


def test_weight_decay_decoupled():
    tx = AdamW(lr=0.1, weight_decay=0.5, grad_clip=0.0)
    params = {"w": jnp.array([1.0])}
    state = tx.init(params)
    p2, _, _ = tx.update({"w": jnp.array([0.0])}, state, params)
    assert float(p2["w"][0]) < 1.0  # decays with zero gradient


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.asarray(100))) < float(lr(jnp.asarray(50)))
    assert float(lr(jnp.asarray(1000))) >= 1e-4 * 0.99  # floor


def test_state_dtype_f32_for_bf16_params():
    tx = AdamW(lr=1e-3)
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    st = tx.init(params)
    assert st.m["w"].dtype == jnp.float32
    p2, st2, _ = tx.update({"w": jnp.ones(4, jnp.bfloat16)}, st, params)
    assert p2["w"].dtype == jnp.bfloat16
