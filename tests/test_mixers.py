"""SSM (SSD) and RG-LRU mixers against naive sequential recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import ssm as ssm_mod
from repro.models import rglru as rglru_mod

KEY = jax.random.PRNGKey(0)


def naive_ssd(x, dt, A, B, C):
    """Sequential h_{t} = h_{t-1} * exp(dt_t A) + dt_t B_t x_t ; y = C_t h."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    rep = h // B.shape[2]
    Br = np.repeat(np.asarray(B), rep, axis=2)
    Cr = np.repeat(np.asarray(C), rep, axis=2)
    xn, dtn, An = map(np.asarray, (x, dt, A))
    hst = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        decay = np.exp(dtn[:, t] * An[None, :])          # [b,h]
        hst = hst * decay[..., None, None] + np.einsum(
            "bhp,bhn->bhpn", xn[:, t] * dtn[:, t][..., None], Br[:, t])
        ys[:, t] = np.einsum("bhpn,bhn->bhp", hst, Cr[:, t])
    return ys, hst


@pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (64, 64)])
def test_ssd_chunked_matches_naive(s, chunk):
    rng = np.random.RandomState(0)
    b, h, p, g, n = 2, 4, 8, 1, 16
    x = jnp.asarray(rng.randn(b, s, h, p), jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(b, s, h)) * 0.1, jnp.float32)
    A = jnp.asarray(-np.abs(rng.randn(h)), jnp.float32)
    B = jnp.asarray(rng.randn(b, s, g, n), jnp.float32)
    C = jnp.asarray(rng.randn(b, s, g, n), jnp.float32)
    y, final = ssm_mod.ssd_chunked(x, dt, A, B, C, chunk=chunk)
    y_ref, h_ref = naive_ssd(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(final), h_ref, atol=1e-3, rtol=1e-3)


def test_ssm_decode_matches_prefill():
    cfg = get_smoke_config("mamba2_1p3b")
    p, _ = ssm_mod.init_ssm(KEY, cfg)
    B, S = 2, 16
    x = 0.1 * jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)
    full, _ = ssm_mod.apply_ssm(p, cfg, x)
    cache = ssm_mod.init_ssm_cache(cfg, B)
    ys = []
    for t in range(S):
        y, cache = ssm_mod.apply_ssm(p, cfg, x[:, t:t + 1], cache=cache)
        ys.append(y)
    step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               atol=2e-3, rtol=2e-3)


def test_ssm_prefill_state_handoff():
    cfg = get_smoke_config("mamba2_1p3b")
    p, _ = ssm_mod.init_ssm(KEY, cfg)
    B, S = 1, 64
    x = 0.1 * jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)
    full, _ = ssm_mod.apply_ssm(p, cfg, x)
    cache = ssm_mod.init_ssm_cache(cfg, B)
    y1, cache = ssm_mod.apply_ssm(p, cfg, x[:, :32], cache=cache)
    y2, cache = ssm_mod.apply_ssm(p, cfg, x[:, 32:], cache=cache)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(full), atol=2e-3, rtol=2e-3)


def test_rglru_scan_matches_sequential():
    cfg = get_smoke_config("recurrentgemma_2b")
    p, _ = rglru_mod.init_rglru(KEY, cfg)
    B, S = 2, 24
    x = 0.1 * jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)
    full, _ = rglru_mod.apply_rglru(p, cfg, x)
    cache = rglru_mod.init_rglru_cache(cfg, B)
    ys = []
    for t in range(S):
        y, cache = rglru_mod.apply_rglru(p, cfg, x[:, t:t + 1], cache=cache)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(full), atol=1e-4, rtol=1e-4)


def test_moe_exact_routing_no_drops():
    from repro.models import moe as moe_mod
    cfg = get_smoke_config("qwen3_moe_235b_a22b")
    p, _ = moe_mod.init_moe(KEY, cfg)
    x = 0.1 * jax.random.normal(KEY, (4, 1, cfg.d_model), jnp.float32)
    y, aux = moe_mod.apply_moe(p, cfg, x, exact=True)
    assert y.shape == x.shape
    # exact routing: output must differ from shared-only (tokens routed)
    assert float(jnp.max(jnp.abs(y))) > 0
