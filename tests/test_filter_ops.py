"""FilterOps backend dispatch: cross-backend parity, kernel routing, the
ops.py precedence regression, and the vectorized keystore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import OCF, OcfConfig, PyCuckooFilter, hashing
from repro.core import filter as jf
from repro.core.filter_ops import FilterOps
from repro.core.keystore import VectorKeystore
from repro.kernels import ops as kops

from conftest import random_keys

pytestmark = pytest.mark.tier1


def _pair(keys):
    hi, lo = hashing.key_to_u32_pair_np(keys)
    return jnp.asarray(hi), jnp.asarray(lo)


# ------------------------------------------------------- backend parity ---


def test_lookup_parity_jnp_pallas_pyfilter(rng):
    """Same table, same probes: jnp, pallas (interpret), and the pyfilter
    oracle must agree bit-for-bit — including the false-positive bits."""
    keys = random_keys(rng, 1500)
    probes = np.concatenate([keys, random_keys(rng, 20000)])
    oracle = PyCuckooFilter(n_buckets=1024, bucket_size=4, fp_bits=16)
    oracle.bulk_insert(keys)
    st = jf.make_state(1024, 4)
    hi, lo = _pair(keys)
    st, _ = jf.bulk_insert(st, hi, lo, fp_bits=16)  # table-exact vs oracle
    phi, plo = _pair(probes)
    want = oracle.bulk_lookup(probes)
    got_jnp = np.asarray(FilterOps(fp_bits=16, backend="jnp").lookup(
        st, phi, plo))
    got_pl = np.asarray(FilterOps(fp_bits=16, backend="pallas").lookup(
        st, phi, plo))
    np.testing.assert_array_equal(want, got_jnp)
    np.testing.assert_array_equal(want, got_pl)


def test_lookup_parity_buffered_state(rng):
    """Active capacity < pow2 buffer: both backends read the same dynamic
    state (the pallas kernel takes the active count as an SMEM scalar)."""
    keys = random_keys(rng, 900)
    hi, lo = _pair(keys)
    st = jf.make_state(300, 4, buffer_buckets=512)
    st, ok = jf.bulk_insert(st, hi, lo, fp_bits=16)
    probes = np.concatenate([keys, random_keys(rng, 5000)])
    phi, plo = _pair(probes)
    a = np.asarray(FilterOps(fp_bits=16, backend="jnp").lookup(st, phi, plo))
    b = np.asarray(FilterOps(fp_bits=16, backend="pallas").lookup(st, phi, plo))
    np.testing.assert_array_equal(a, b)
    assert a[:900][np.asarray(ok)].all()


def test_insert_parity_single_block(rng):
    """For a single kernel block the pallas optimistic round reproduces the
    jnp round table-for-table, so the full hybrid insert is identical."""
    keys = random_keys(rng, 1000)
    hi, lo = _pair(keys)
    st_j, ok_j = FilterOps(fp_bits=16, backend="jnp").insert(
        jf.make_state(512, 4), hi, lo)
    st_p, ok_p = FilterOps(fp_bits=16, backend="pallas").insert(
        jf.make_state(512, 4), hi, lo)
    np.testing.assert_array_equal(np.asarray(st_j.table),
                                  np.asarray(st_p.table))
    np.testing.assert_array_equal(np.asarray(ok_j), np.asarray(ok_p))
    assert int(st_j.count) == int(st_p.count)
    # and membership agrees with the oracle for every key both inserted
    oracle = PyCuckooFilter(n_buckets=512, bucket_size=4, fp_bits=16)
    ok_o = oracle.bulk_insert(keys)
    both = np.asarray(ok_j) & ok_o
    hits_p = np.asarray(FilterOps(fp_bits=16, backend="pallas").lookup(
        st_p, hi, lo))
    assert hits_p[both].all() and oracle.bulk_lookup(keys)[both].all()


def test_insert_parity_multi_chunk_membership(rng):
    """Across kernel blocks layouts may differ (blocks see earlier blocks'
    placements) but membership answers for inserted keys never do."""
    keys = random_keys(rng, 5000)
    hi, lo = _pair(keys)
    st_j, ok_j = FilterOps(fp_bits=16, backend="jnp").insert(
        jf.make_state(4096, 4), hi, lo)
    st_p, ok_p = FilterOps(fp_bits=16, backend="pallas").insert(
        jf.make_state(4096, 4), hi, lo)
    assert np.asarray(ok_j).all() and np.asarray(ok_p).all()
    assert int(st_j.count) == int(st_p.count) == 5000
    for ops_, st in ((FilterOps(fp_bits=16, backend="jnp"), st_j),
                     (FilterOps(fp_bits=16, backend="pallas"), st_p)):
        assert np.asarray(ops_.lookup(st, hi, lo)).all()


def test_probe_table_backend_parity(rng):
    """Raw-table probe (the distributed shard path) agrees across backends."""
    keys = random_keys(rng, 2000)
    hi, lo = _pair(keys)
    st = jf.make_state(1024, 4)
    st, _ = jf.bulk_insert(st, hi, lo, fp_bits=16)
    probes = np.concatenate([keys, random_keys(rng, 4000)])
    phi, plo = _pair(probes)
    a = np.asarray(FilterOps(fp_bits=16, backend="jnp").probe_table(
        st.table, phi, plo))
    b = np.asarray(FilterOps(fp_bits=16, backend="pallas").probe_table(
        st.table, phi, plo))
    np.testing.assert_array_equal(a, b)
    assert a[:2000].all()


# ------------------------------------------------------ kernel routing ----


def test_ocf_pallas_backend_dispatches_through_kernels(rng, monkeypatch):
    """OCF(backend='pallas') must reach the Pallas kernels for the probe,
    the full insert (incl. eviction rounds), and the delete — with NO
    lax.scan fallback anywhere on the path (acceptance criterion)."""
    calls = {"probe": 0, "insert": 0, "delete": 0, "scan_fallback": 0}
    real_probe, real_insert = kops.probe, kops.insert_bulk
    real_delete = kops.delete_bulk

    real_probe_emulated = kops.probe_emulated

    def probe_spy(*a, **kw):
        calls["probe"] += 1
        return real_probe(*a, **kw)

    def probe_emulated_spy(*a, **kw):
        # the off-TPU fast path FilterOps.lookup takes (same kernel body,
        # XLA-compiled — see kernels/probe.py::probe_emulated)
        calls["probe"] += 1
        return real_probe_emulated(*a, **kw)

    def insert_spy(*a, **kw):
        calls["insert"] += 1
        return real_insert(*a, **kw)

    def delete_spy(*a, **kw):
        calls["delete"] += 1
        return real_delete(*a, **kw)

    def scan_spy(*a, **kw):
        calls["scan_fallback"] += 1
        raise AssertionError("pallas backend fell back to the scan path")

    monkeypatch.setattr(kops, "probe", probe_spy)
    monkeypatch.setattr(kops, "probe_emulated", probe_emulated_spy)
    monkeypatch.setattr(kops, "insert_bulk", insert_spy)
    monkeypatch.setattr(kops, "delete_bulk", delete_spy)
    from repro.core import filter_ops as fops_mod
    monkeypatch.setattr(fops_mod.jfilter, "bulk_insert", scan_spy)
    monkeypatch.setattr(fops_mod.jfilter, "bulk_delete", scan_spy)
    ocf = OCF(OcfConfig(capacity=4096, backend="pallas"))
    keys = random_keys(rng, 1000)
    ocf.insert(keys)
    assert calls["insert"] > 0, "insert did not go through the Pallas kernel"
    hits = ocf.lookup(keys)
    assert calls["probe"] > 0, "lookup did not go through the Pallas kernel"
    assert hits.all()
    ocf.delete(keys[:300])
    assert calls["delete"] > 0, "delete did not go through the Pallas kernel"
    assert calls["scan_fallback"] == 0
    assert ocf.lookup(keys[300:]).all(), "delete disturbed a resident key"
    monkeypatch.undo()  # un-patch the scan path before the jnp comparison
    # same answers as the jnp backend end-to-end
    ocf_j = OCF(OcfConfig(capacity=4096, backend="jnp"))
    ocf_j.insert(keys)
    ocf_j.delete(keys[:300])
    assert ocf_j.lookup(keys[300:]).all()
    assert ocf.count == ocf_j.count


def test_use_pallas_always_never_demoted(rng, monkeypatch):
    """Regression for the seed precedence bug: a VMEM estimate above budget
    silently demoted use_pallas='always' to the ref path."""
    calls = {"probe": 0}
    real_probe = kops.probe

    def probe_spy(*a, **kw):
        calls["probe"] += 1
        return real_probe(*a, **kw)

    monkeypatch.setattr(kops, "probe", probe_spy)
    # 1M buckets x 4 slots x 4 bytes = 16 MB > the 12 MB kernel budget
    table = jnp.zeros((1 << 20, 4), jnp.uint32)
    assert table.size * 4 > kops.VMEM_TABLE_BUDGET
    keys = random_keys(rng, 256)
    hi, lo = _pair(keys)
    kops.filter_lookup(table, hi, lo, fp_bits=16, use_pallas="auto")
    assert calls["probe"] == 0, "'auto' must respect the VMEM budget"
    kops.filter_lookup(table, hi, lo, fp_bits=16, use_pallas="never")
    assert calls["probe"] == 0
    kops.filter_lookup(table, hi, lo, fp_bits=16, use_pallas="always")
    assert calls["probe"] == 1, "'always' must never fall back to ref"


def test_bulk_insert_hybrid_is_fully_jittable(rng):
    """Regression: the seed pulled bool(jnp.any(residue)) to the host, which
    raises TracerBoolConversionError under an outer jit."""
    keys = random_keys(rng, 512)
    hi, lo = _pair(keys)

    @jax.jit
    def run(state, hi, lo):
        return jf.bulk_insert_hybrid(state, hi, lo, fp_bits=16)

    st, ok = run(jf.make_state(512, 4), hi, lo)
    assert np.asarray(ok).all()
    assert int(st.count) == 512


# ------------------------------------------------- vectorized keystore ----


def test_keystore_matches_dict_reference(rng):
    """Batch add/remove against the seed's dict-loop semantics, with
    duplicate keys inside and across batches."""
    ks = VectorKeystore()
    ref: dict[int, int] = {}
    for _ in range(20):
        batch = rng.randint(0, 50, size=rng.randint(1, 40)).astype(np.uint64)
        if rng.rand() < 0.5:
            ks.add(batch)
            for k in batch.tolist():
                ref[k] = ref.get(k, 0) + 1
        else:
            got = ks.remove(batch)
            want = np.zeros(batch.size, bool)
            for i, k in enumerate(batch.tolist()):
                if ref.get(k, 0) > 0:
                    ref[k] -= 1
                    if ref[k] == 0:
                        del ref[k]
                    want[i] = True
            np.testing.assert_array_equal(got, want)
        assert ks.total == sum(ref.values())
        assert ks.unique == len(ref)
    want_all = np.sort(np.fromiter(
        (k for k, m in ref.items() for _ in range(m)), dtype=np.uint64,
        count=sum(ref.values())))
    np.testing.assert_array_equal(np.sort(ks.materialize()), want_all)


def test_keystore_remove_per_occurrence_order(rng):
    ks = VectorKeystore()
    ks.add(np.array([7, 7], dtype=np.uint64))
    got = ks.remove(np.array([7, 7, 7], dtype=np.uint64))
    np.testing.assert_array_equal(got, [True, True, False])
    assert ks.total == 0 and ks.unique == 0


def test_ocf_duplicate_delete_semantics(rng):
    """Multiplicity survives the vectorization: the k-th delete of a key
    succeeds only while the keystore holds k copies."""
    ocf = OCF(OcfConfig(capacity=4096))
    k = random_keys(rng, 1)
    ocf.insert(np.concatenate([k, k]))
    assert len(ocf) == 2
    present = ocf.delete(np.concatenate([k, k, k]))
    np.testing.assert_array_equal(present, [True, True, False])
    assert ocf.stats.blind_deletes_blocked == 1
    assert not ocf.contains_key_exact(int(k[0]))


def test_filter_ops_rebuild_roundtrip(rng):
    keys = random_keys(rng, 3000)
    hi, lo = _pair(keys)
    fops = FilterOps(fp_bits=16, backend="jnp")
    st, ok = fops.rebuild(hi, lo, 2048, 4, buffer_buckets=4096)
    assert np.asarray(ok).all()
    assert np.asarray(fops.lookup(st, hi, lo)).all()


def test_serving_backend_threads_through(rng):
    from repro.serving.kvcache import PrefixCacheIndex
    idx = PrefixCacheIndex(backend="jnp")
    assert idx.ocf.config.backend == "jnp"
    assert idx.ocf.ops == FilterOps(fp_bits=16, max_disp=500, backend="jnp",
                                    schedule=True, donate=True)
    cfg = OcfConfig(capacity=4096, backend="auto")
    idx2 = PrefixCacheIndex(config=cfg, backend="pallas")
    assert idx2.ocf.config.backend == "pallas"
    tokens = rng.randint(0, 1000, size=256).astype(np.uint32)
    idx.admit(tokens)
    assert idx.match_prefix(tokens) == 256 // idx.block


def test_empty_batch_backend_parity(rng):
    """Zero-length batches return empty results on BOTH backends (the
    pallas path used to ZeroDivisionError in the block-size computation)."""
    st = jf.make_state(512, 4)
    e = jnp.zeros((0,), jnp.uint32)
    for backend in ("jnp", "pallas"):
        fops = FilterOps(fp_bits=16, backend=backend)
        assert np.asarray(fops.lookup(st, e, e)).shape == (0,)
        st2, ok = fops.insert(st, e, e)
        assert np.asarray(ok).shape == (0,) and int(st2.count) == 0
        assert np.asarray(fops.probe_table(st.table, e, e)).shape == (0,)


def test_distributed_replicated_backend_param(rng):
    from repro.core import distributed as dist
    keys = random_keys(rng, 1024)
    hi, lo = _pair(keys)
    st = jf.make_state(512, 4)
    st, _ = jf.bulk_insert(st, hi, lo, fp_bits=16)
    tables = jnp.stack([st.table, jnp.zeros_like(st.table)])
    hits = dist.replicated_lookup(tables, hi, lo, fp_bits=16, backend="jnp")
    assert np.asarray(hits).all()
