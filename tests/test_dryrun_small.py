"""Small-mesh dry-run integration: lower+compile the production code path on
8 host devices (2×2×2 pod/data/model), one arch per family, both step kinds.

The full 512-device sweep is artifacts/dryrun (deliverable e); this test
keeps the machinery honest in CI time.
"""
import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.registry import get_smoke_config
    from repro.distributed.sharding import (ParallelConfig, batch_pspec,
                                            cache_pspec, make_shardings)
    from repro.launch.specs import abstract_cache, abstract_init
    from repro.models.transformer import Transformer
    from repro.optim.adamw import AdamW, AdamWState
    from repro.serving.engine import make_decode_step
    from repro.train.step import make_train_step
    from repro.roofline.analysis import parse_collectives

    arch = %r
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    parallel = ParallelConfig(pod_axis="pod", remat="dots",
                              compress_grads=True)
    cfg = get_smoke_config(arch)
    model = Transformer(cfg)
    shapes, specs = abstract_init(model)
    shard = make_shardings(mesh, specs, shapes, parallel)
    tx = AdamW(lr=1e-3)
    o_shapes = jax.eval_shape(tx.init, shapes)
    rep = NamedSharding(mesh, P())
    o_shard = AdamWState(step=rep, m=shard, v=shard)
    B, S = 8, 32
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    bs = {k: NamedSharding(mesh, batch_pspec(B, 2, mesh, parallel))
          for k in batch}
    if cfg.prefix_embed_len:
        batch["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.prefix_embed_len, cfg.d_model), jnp.bfloat16)
        bs["prefix_embeds"] = NamedSharding(
            mesh, batch_pspec(B, 3, mesh, parallel))
    if cfg.cross_attn_memory_len:
        batch["memory"] = jax.ShapeDtypeStruct(
            (B, cfg.cross_attn_memory_len, cfg.cross_attn_memory_dim),
            jnp.bfloat16)
        bs["memory"] = NamedSharding(mesh, batch_pspec(B, 3, mesh, parallel))
    step = make_train_step(model, tx, parallel)
    with mesh:
        lowered = jax.jit(step, in_shardings=(shard, o_shard, bs)).lower(
            shapes, o_shapes, batch)
        compiled = lowered.compile()
    coll = parse_collectives(compiled.as_text())
    cost = compiled.cost_analysis() or {}

    # decode step
    cache_shapes = abstract_cache(model, B, 64, dtype=jnp.bfloat16)
    c_shard = jax.tree.map(
        lambda s: NamedSharding(mesh, cache_pspec(s.shape, mesh, parallel)),
        cache_shapes)
    dec = make_decode_step(model)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    dargs = [shapes, cache_shapes, tok, pos]
    dsh = [shard, c_shard, NamedSharding(mesh, batch_pspec(B, 2, mesh,
                                                            parallel)), rep]
    if cfg.cross_attn_memory_len:
        def dec2(p, c, t, q, mem):
            return dec(p, c, t, q, memory=mem)
        dargs.append(batch["memory"]); dsh.append(bs["memory"])
        dfn = dec2
    else:
        dfn = dec
    with mesh:
        dc = jax.jit(dfn, in_shardings=tuple(dsh)).lower(*dargs).compile()
    print(json.dumps({
        "train_collectives": coll.count,
        "train_flops": cost.get("flops", 0.0),
        "decode_ok": True,
    }))
""")

FAMILIES = ["mistral_nemo_12b", "qwen3_moe_235b_a22b", "mamba2_1p3b",
            "recurrentgemma_2b", "deepseek_v2_lite_16b", "musicgen_large",
            "llava_next_mistral_7b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_small_mesh_dryrun(arch):
    r = subprocess.run([sys.executable, "-c", SCRIPT % arch],
                       capture_output=True, text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stderr[-4000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["decode_ok"]
    assert out["train_collectives"] > 0, "sharded training must communicate"
