"""OCF prefix-cache index tests (paper integration in the serving path)."""
import numpy as np
import pytest

from repro.serving.kvcache import PrefixCacheIndex, block_hashes

pytestmark = pytest.mark.tier1


def test_block_hashes_prefix_sensitivity(rng):
    t1 = rng.randint(0, 1000, 256).astype(np.int32)
    t2 = t1.copy()
    t2[3] = (t2[3] + 1) % 1000  # perturb inside block 0
    k1, k2 = block_hashes(t1, 64), block_hashes(t2, 64)
    assert k1.shape == (4,)
    assert (k1 != k2).all(), "rolling hash: all downstream blocks change"
    t3 = t1.copy()
    t3[200] += 1  # perturb inside block 3 only
    k3 = block_hashes(t3, 64)
    assert (k1[:3] == k3[:3]).all() and k1[3] != k3[3]


def test_match_admit_evict_cycle(rng):
    idx = PrefixCacheIndex(block=32)
    prompt = rng.randint(0, 1000, 256).astype(np.int32)
    assert idx.match_prefix(prompt) == 0
    idx.admit(prompt)
    assert idx.match_prefix(prompt) == 8
    # extension shares the prefix
    longer = np.concatenate([prompt, rng.randint(0, 1000, 64).astype(np.int32)])
    assert idx.match_prefix(longer) == 8
    idx.evict(prompt)
    assert idx.match_prefix(prompt) == 0


def test_lru_eviction_deletes_from_filter(rng):
    idx = PrefixCacheIndex(block=32, max_blocks=8)
    for _ in range(6):
        idx.admit(rng.randint(0, 1000, 128).astype(np.int32))
    assert idx.stats.evicted > 0
    assert len(idx._lru) <= 8
    assert idx.ocf.stats.deletes > 0


def test_burst_admission_resizes_filter(rng):
    from repro.core.ocf import OcfConfig
    idx = PrefixCacheIndex(OcfConfig(capacity=1024, mode="EOF"), block=16,
                           max_blocks=1 << 20)
    for _ in range(40):  # burst of distinct prompts
        idx.admit(rng.randint(0, 10000, 512).astype(np.int32))
    assert idx.ocf.stats.resizes >= 1, "EOF must grow under admission burst"
    assert idx.ocf.occupancy <= 0.96
