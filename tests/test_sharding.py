"""Sharding-rule unit tests + a small-mesh pjit lowering check."""
import json
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (ParallelConfig, batch_pspec,
                                        cache_pspec, spec_to_pspec)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1],
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def test_mlp_weight_spec(mesh):
    pc = ParallelConfig()
    got = spec_to_pspec(("layers", "embed", "mlp"), (4, 64, 128), mesh, pc)
    assert got == P(None, "data", "model")


def test_expert_weight_spec_priority(mesh):
    pc = ParallelConfig()
    # expert takes the model axis; mlp falls back to replication
    got = spec_to_pspec(("layers", "expert", "embed", "mlp"),
                        (4, 8, 64, 128), mesh, pc)
    assert got == P(None, "model", "data")  # trailing Nones trimmed


def test_non_divisible_falls_back_to_replication(mesh):
    pc = ParallelConfig()
    got = spec_to_pspec(("embed", "heads"), (63, 33), mesh, pc)
    # 1x1 mesh: everything divides; use a fake mesh via shape math instead
    assert got == P("data", "model")


def test_batch_pspec_small_batch(mesh):
    pc = ParallelConfig()
    assert batch_pspec(16, 2, mesh, pc) == P("data", None)
    # batch=1 cannot shard over data>1 — replicate (long_500k case) —
    # with a 1x1 mesh everything divides, so emulate via ndim/seq rules
    assert batch_pspec(1, 2, mesh, pc)[0] in ("data", None)


def test_cache_pspec_context_parallel(mesh):
    pc = ParallelConfig()
    # KV cache [n, B, Hkv, S, D]: batch over data, SEQ over model (context-
    # parallel decode; EXPERIMENTS.md §Perf dsv2/iter4)
    got = cache_pspec((4, 8, 2, 128, 64), mesh, pc)
    assert got[1] == "data" and got[-2] == "model" and got[-1] is None
    # stateful caches without a long seq dim fall back to feature sharding
    got2 = cache_pspec((4, 8, 64), mesh, pc)
    assert got2[-1] == "model"


MULTIAXIS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import (ParallelConfig, batch_pspec,
                                            spec_to_pspec)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    pc = ParallelConfig(pod_axis="pod")
    out = {}
    out["w"] = str(spec_to_pspec(("embed", "mlp"), (64, 128), mesh, pc))
    out["w_nodiv"] = str(spec_to_pspec(("embed", "mlp"), (63, 128), mesh, pc))
    out["batch"] = str(batch_pspec(16, 2, mesh, pc))
    out["batch1"] = str(batch_pspec(1, 2, mesh, pc))
    pcf = ParallelConfig(pod_axis="pod", pod_fsdp=True)
    out["w_podfsdp"] = str(spec_to_pspec(("embed", "mlp"), (64, 128), mesh,
                                         pcf))
    print(json.dumps(out))
""")


def test_multiaxis_rules_subprocess():
    r = subprocess.run([sys.executable, "-c", MULTIAXIS], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["w"] == "PartitionSpec('data', 'model')"
    assert out["w_nodiv"] == "PartitionSpec(None, 'model')"
    assert out["batch"] == "PartitionSpec(('pod', 'data'), None)"
    assert out["batch1"] == "PartitionSpec(None, None)"
    assert out["w_podfsdp"] == "PartitionSpec(('pod', 'data'), 'model')"
