"""Conflict-aware batch scheduling + zero-copy kernel pipeline (ISSUE 5).

Covers the acceptance criteria of the scheduling/pipeline PR:
  * the wave pre-pass (`core/scheduling.py`) emits conflict-free waves,
    keeps same-bucket lanes in their original relative order, and splits
    in-batch duplicate keys across waves;
  * scheduled dispatch is invisible to results: membership + conservation
    parity vs unscheduled dispatch on contended batches, and single-lane
    residue chains stay **bit-for-bit** identical to the sequential
    stash oracle (`PyStashFilter`) through the whole scheduled FilterOps
    path — including spill and stash-full rollback;
  * the XLA grid emulation (`emulate=True`) is bit-for-bit the Pallas
    interpreter for insert/probe/delete and the fused multi-generation
    probe;
  * lookup dedup answers exactly like the raw batch (duplicates included);
  * buffer donation consumes the caller's table (zero-copy contract) and
    produces the same results as the undonated call;
  * empty batches are safe through every scheduled/deduped entry point.

The seeded tier-1 tests and the hypothesis property tests (bottom of the
file; skipped when hypothesis isn't installed — it's an optional dep, so
they are deliberately NOT tier1) share the same invariant checkers:
``_check_dispatch_invariants`` and ``_check_dedupe_roundtrip``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import filter as jf
from repro.core import hashing
from repro.core.filter_ops import FilterOps
from repro.core.ocf import OCF, OcfConfig
from repro.core.scheduling import (conflict_waves, dedupe_keys,
                                   dispatch_order, wave_count)
from repro.kernels import ops as kops
from repro.kernels.delete import delete_bulk
from repro.kernels.insert import insert_bulk
from repro.kernels.probe import probe, probe_multi
from repro.kernels.stash import make_stash, stash_occupancy
from repro.streaming import PyStashFilter

from conftest import random_keys

tier1 = pytest.mark.tier1


def _pair(keys):
    hi, lo = hashing.key_to_u32_pair_np(np.asarray(keys, dtype=np.uint64))
    return jnp.asarray(hi), jnp.asarray(lo)


# ------------------------------------------------------- wave pre-pass ----


def _check_dispatch_invariants(keys, valid, n_buckets):
    """The full dispatch_order/conflict_waves contract on one batch:
    perm is a permutation inverted by inv, invalid lanes park at the end,
    dispatch is wave-major with at most one lane per bucket per wave, and
    same-bucket lanes keep their original relative order."""
    n = keys.size
    hi, lo = _pair(keys)
    valid = jnp.asarray(np.asarray(valid, dtype=bool))
    i1 = np.asarray(hashing.index_hash_dyn(hi, lo, n_buckets), dtype=np.int64)
    perm, inv = dispatch_order(hi, lo, valid, n_buckets=n_buckets)
    perm, inv = np.asarray(perm), np.asarray(inv)
    v = np.asarray(valid)
    # a permutation, and inv really inverts it
    assert sorted(perm.tolist()) == list(range(n))
    np.testing.assert_array_equal(perm[inv], np.arange(n))
    # invalid lanes are all parked at the end
    n_valid = int(v.sum())
    assert not v[perm[n_valid:]].any() and v[perm[:n_valid]].all()
    # waves: walk the dispatch order; a bucket repeating within one wave
    # would mean the wave is not conflict-free
    waves = np.asarray(conflict_waves(jnp.asarray(i1), valid))
    if n_valid:
        w_sorted = waves[perm[:n_valid]]
        b_sorted = i1[perm[:n_valid]]
        assert (np.diff(w_sorted) >= 0).all(), "dispatch must be wave-major"
        for w in range(int(w_sorted.max()) + 1):
            bw = b_sorted[w_sorted == w]
            assert len(np.unique(bw)) == len(bw), f"wave {w} has a conflict"
    # same-bucket lanes keep original relative order (the property that
    # makes scheduling invisible to rank-based placement)
    pos = np.empty(n, dtype=np.int64)
    pos[perm] = np.arange(n)
    for b in np.unique(i1[v]):
        lanes = np.flatnonzero(v & (i1 == b))
        assert (np.diff(pos[lanes]) > 0).all()
    # k valid copies of one key (same bucket, same fp) land in k distinct
    # waves — the repeats the lookup dedup pre-pass collapses
    ku = np.asarray(keys)
    for k in np.unique(ku[v]):
        dup_waves = waves[v & (ku == k)]
        assert len(np.unique(dup_waves)) == dup_waves.size
    assert int(wave_count(jnp.asarray(i1), valid)) == (
        int(waves[v].max()) + 1 if n_valid else 0)


def _check_dedupe_roundtrip(keys):
    """dedupe_keys contract: probe_keys[inverse] reconstructs the batch
    exactly; inverse is None iff the batch had no repeats."""
    keys = np.asarray(keys, dtype=np.uint64)
    uniq, inverse = dedupe_keys(keys)
    if inverse is None:
        assert np.unique(keys).size == keys.size
        np.testing.assert_array_equal(uniq, keys)
    else:
        assert uniq.size < keys.size
        assert np.unique(uniq).size == uniq.size
        np.testing.assert_array_equal(uniq[inverse], keys)


@tier1
def test_waves_are_conflict_free_and_order_preserving(rng):
    """Each wave holds at most one lane per bucket; same-bucket lanes keep
    their original relative order; invalid lanes sort last."""
    n, n_buckets = 1024, 64                    # dense conflicts
    keys = random_keys(rng, n)
    _check_dispatch_invariants(keys, rng.rand(n) < 0.9, n_buckets)


@tier1
def test_duplicate_keys_split_across_waves(rng):
    """In-batch repeats of one key (same bucket, same fp) are the repeats
    the scheduler deduplicates: k copies land in k distinct waves."""
    key = random_keys(rng, 1)
    keys = np.repeat(key, 5)
    hi, lo = _pair(keys)
    valid = jnp.ones((5,), bool)
    i1 = hashing.index_hash_dyn(hi, lo, 64)
    waves = np.asarray(conflict_waves(i1, valid))
    np.testing.assert_array_equal(np.sort(waves), np.arange(5))
    assert int(wave_count(i1, valid)) == 5
    # all-distinct buckets -> a single wave
    spread = random_keys(rng, 32)
    shi, slo = _pair(spread)
    si1 = hashing.index_hash_dyn(shi, slo, 1 << 20)
    assert int(wave_count(si1, jnp.ones((32,), bool))) == 1


# --------------------------------------------- scheduled-dispatch parity --


@tier1
def test_scheduled_vs_unscheduled_membership_and_conservation(rng):
    """A contended spill batch lands the same keys with the same totals
    whether or not the wave pre-pass reorders the dispatch (duplicates in
    the batch included)."""
    keys = random_keys(rng, 896)
    keys = np.concatenate([keys, keys[:128]])    # in-batch duplicates
    hi, lo = _pair(keys)                         # 1024 keys, block multiple
    table = jnp.zeros((288, 4), jnp.uint32)      # 1024 / 1152 slots = 0.89
    outs = {}
    for sched in (False, True):
        t, stash, ok = insert_bulk(table, hi, lo, fp_bits=16,
                                   evict_rounds=64, stash=make_stash(256),
                                   block=128, emulate=True, schedule=sched)
        outs[sched] = (np.asarray(t), np.asarray(stash), np.asarray(ok))
    for sched, (t, stash, ok) in outs.items():
        assert ok.all(), f"stash must absorb the storm (schedule={sched})"
    # conservation: same number of resident + stashed fingerprints
    assert ((outs[False][0] != 0).sum() + (outs[False][1][0] != 0).sum()
            == (outs[True][0] != 0).sum() + (outs[True][1][0] != 0).sum()
            == keys.size)
    # membership parity probe-for-probe (including false positives)
    probes = np.concatenate([keys, random_keys(rng, 4000)])
    phi, plo = _pair(probes)
    h0 = kops.filter_lookup(jnp.asarray(outs[False][0]), phi, plo,
                            fp_bits=16, stash=jnp.asarray(outs[False][1]),
                            use_pallas="always")
    h1 = kops.filter_lookup(jnp.asarray(outs[True][0]), phi, plo,
                            fp_bits=16, stash=jnp.asarray(outs[True][1]),
                            use_pallas="always")
    np.testing.assert_array_equal(np.asarray(h0)[:keys.size],
                                  np.asarray(h1)[:keys.size])
    assert np.asarray(h0)[:keys.size].all()


@tier1
def test_scheduled_single_lane_residues_bit_for_bit_oracle(rng):
    """One key per batch through the FULL scheduled pipeline (FilterOps
    insert_spill: wave pre-pass + emulated kernel + spill + rollback) ==
    the sequential stash oracle, table and stash bit-for-bit."""
    n_buckets, bs, rounds, slots = 64, 4, 8, 16
    oracle = PyStashFilter(n_buckets=n_buckets, bucket_size=bs, fp_bits=16,
                           evict_rounds=rounds, stash_slots=slots)
    fops = FilterOps(fp_bits=16, backend="pallas", evict_rounds=rounds,
                     schedule=True)
    state = jf.make_state(n_buckets, bs)
    stash = make_stash(slots)
    keys = random_keys(rng, 300)
    ok_k, ok_o = [], []
    for k in keys:
        hi, lo = _pair(np.array([k], dtype=np.uint64))
        state, stash, ok = fops.insert_spill(state, stash, hi, lo)
        ok_k.append(bool(np.asarray(ok)[0]))
        ok_o.append(oracle.insert(int(k)))
    np.testing.assert_array_equal(np.array(ok_k), np.array(ok_o))
    np.testing.assert_array_equal(np.asarray(state.table), oracle.table)
    np.testing.assert_array_equal(np.asarray(stash), oracle.stash_array())
    assert not all(ok_k), "stash-full rollback must have been exercised"
    assert int(state.count) == int((np.asarray(state.table) != 0).sum())


# ------------------------------------------------- emulation bit-parity ---


@tier1
def test_emulation_bit_for_bit_vs_interpreter(rng):
    """The XLA grid emulation IS the kernel: insert (multi-block, stash),
    probe (stash), delete, and the fused multi-generation probe all match
    the Pallas interpreter bit-for-bit."""
    keys = random_keys(rng, 1024)
    hi, lo = _pair(keys)
    table = jnp.zeros((128, 4), jnp.uint32)      # heavy contention
    kw = dict(fp_bits=16, evict_rounds=16, block=256)
    ti, si, oki = insert_bulk(table, hi, lo, **kw, stash=make_stash(64),
                              interpret=True)
    te, se, oke = insert_bulk(table, hi, lo, **kw, stash=make_stash(64),
                              emulate=True)
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(te))
    np.testing.assert_array_equal(np.asarray(si), np.asarray(se))
    np.testing.assert_array_equal(np.asarray(oki), np.asarray(oke))
    assert int(stash_occupancy(se)) > 0, "workload must exercise the stash"
    hi2, lo2 = _pair(np.concatenate([keys, random_keys(rng, 1024)]))
    p_i = probe(ti, hi2, lo2, fp_bits=16, stash=si, block=256,
                interpret=True)
    p_e = probe(te, hi2, lo2, fp_bits=16, stash=se, block=256, emulate=True)
    np.testing.assert_array_equal(np.asarray(p_i), np.asarray(p_e))
    d_i = delete_bulk(ti, hi, lo, fp_bits=16, block=256, interpret=True)
    d_e = delete_bulk(te, hi, lo, fp_bits=16, block=256, emulate=True)
    np.testing.assert_array_equal(np.asarray(d_i[0]), np.asarray(d_e[0]))
    np.testing.assert_array_equal(np.asarray(d_i[1]), np.asarray(d_e[1]))
    tables = jnp.stack([ti, jnp.asarray(d_i[0])])
    stashes = jnp.stack([si, make_stash(64)])
    m_i = probe_multi(tables, hi2, lo2, fp_bits=16, stashes=stashes,
                      block=256, interpret=True)
    m_e = probe_multi(tables, hi2, lo2, fp_bits=16, stashes=stashes,
                      block=256, emulate=True)
    np.testing.assert_array_equal(np.asarray(m_i), np.asarray(m_e))


# ------------------------------------------------------------- dedup ------


@tier1
def test_lookup_dedup_answers_match_raw_batch(rng):
    """OCF.lookup's dedup pre-pass: a batch with heavy repeats answers
    exactly like the same batch probed lane-for-lane."""
    base = random_keys(rng, 500)
    ocf = OCF(OcfConfig(capacity=4096, backend="pallas",
                        dedupe_lookups=True))
    ocf.insert(base)
    probes = rng.choice(np.concatenate([base, random_keys(rng, 500)]),
                        size=6000, replace=True)
    got = ocf.lookup(probes)
    uniq, inverse = dedupe_keys(probes)
    assert uniq.size < probes.size, "workload must actually dedupe"
    want = ocf.lookup(uniq)[inverse]             # uniq batch: no dedup gain
    np.testing.assert_array_equal(got, want)
    member = np.isin(probes, base)
    assert got[member].all(), "no false negatives through the dedup path"


# ---------------------------------------------------------- donation ------


@tier1
def test_donation_consumes_input_and_matches_undonated(rng):
    """donate=True: same results, and the caller's table buffer is consumed
    (the zero-copy contract — reusing a donated buffer must fail loudly)."""
    keys = random_keys(rng, 2000)
    hi, lo = _pair(keys)
    st_keep = jf.make_state(1024, 4)
    fops = FilterOps(fp_bits=16, backend="pallas")
    fops_d = FilterOps(fp_bits=16, backend="pallas", donate=True)
    out_keep, ok_keep = fops.insert(st_keep, hi, lo)
    st_don = jf.make_state(1024, 4)
    donated_table = st_don.table
    out_don, ok_don = fops_d.insert(st_don, hi, lo)
    np.testing.assert_array_equal(np.asarray(out_keep.table),
                                  np.asarray(out_don.table))
    np.testing.assert_array_equal(np.asarray(ok_keep), np.asarray(ok_don))
    assert donated_table.is_deleted(), "donated input must be consumed"
    assert not st_keep.table.is_deleted()
    # the end-to-end owners (OCF / generation ring) stay healthy
    ocf = OCF(OcfConfig(capacity=4096, backend="pallas"))  # donate=True
    ocf.insert(keys)
    assert ocf.lookup(keys).all()
    ocf.delete(keys[:500])
    assert ocf.lookup(keys[500:]).all()


# ------------------------------------------------------------- guards -----


@tier1
def test_empty_batches_through_scheduled_pipeline(rng):
    e = jnp.zeros((0,), jnp.uint32)
    fops = FilterOps(fp_bits=16, backend="pallas", schedule=True,
                     donate=True)
    st = jf.make_state(64, 4)
    st2, ok = fops.insert(st, e, e)
    assert np.asarray(ok).shape == (0,) and int(st2.count) == 0
    st3, stash, ok2 = fops.insert_spill(st, make_stash(16), e, e)
    assert np.asarray(ok2).shape == (0,)
    empty = np.zeros((0,), np.uint64)
    uniq, inverse = dedupe_keys(empty)
    assert uniq.size == 0 and inverse is None
    assert int(wave_count(jnp.zeros((0,), jnp.int32),
                          jnp.zeros((0,), bool))) == 0
    ocf = OCF(OcfConfig(capacity=1024, backend="pallas"))
    assert ocf.lookup(empty).shape == (0,)
    perm, inv = dispatch_order(e, e, jnp.zeros((0,), bool), n_buckets=64)
    assert np.asarray(perm).shape == (0,) and np.asarray(inv).shape == (0,)


# ------------------------------------------- hypothesis property tests ----
# Optional-dep section: hypothesis is NOT a tier-1 dependency, so these
# tests carry no tier1 mark and skip cleanly when the package is missing.
# They drive the exact same invariant checkers as the seeded tests above,
# but over adversarially-shrunk batches (empty, all-invalid, heavy
# duplicates, tiny bucket counts).

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not _HAVE_HYPOTHESIS, reason="hypothesis not installed (optional dep)")

if _HAVE_HYPOTHESIS:
    _key_lists = hst.lists(hst.integers(min_value=1, max_value=2 ** 63 - 1),
                           max_size=96)
    # a tiny alphabet makes in-batch duplicates and bucket collisions the
    # common case rather than the corner case
    _dup_lists = hst.lists(hst.integers(min_value=1, max_value=12),
                           max_size=64)
    _n_buckets = hst.sampled_from([4, 16, 64, 1024])

    @needs_hypothesis
    @settings(max_examples=60, deadline=None)
    @given(keys=_key_lists, n_buckets=_n_buckets, data=hst.data())
    def test_property_dispatch_order_and_waves(keys, n_buckets, data):
        keys = np.asarray(keys, dtype=np.uint64)
        valid = np.asarray(
            data.draw(hst.lists(hst.booleans(), min_size=keys.size,
                                max_size=keys.size)), dtype=bool)
        _check_dispatch_invariants(keys, valid, n_buckets)

    @needs_hypothesis
    @settings(max_examples=60, deadline=None)
    @given(keys=_dup_lists, n_buckets=hst.sampled_from([4, 16]))
    def test_property_duplicates_always_split(keys, n_buckets):
        keys = np.asarray(keys, dtype=np.uint64)
        _check_dispatch_invariants(keys, np.ones(keys.size, bool), n_buckets)

    @needs_hypothesis
    @settings(max_examples=80, deadline=None)
    @given(keys=hst.one_of(_key_lists, _dup_lists))
    def test_property_dedupe_roundtrip(keys):
        _check_dedupe_roundtrip(np.asarray(keys, dtype=np.uint64))
else:
    @needs_hypothesis
    def test_property_dispatch_order_and_waves():
        raise AssertionError("unreachable without hypothesis")

    @needs_hypothesis
    def test_property_duplicates_always_split():
        raise AssertionError("unreachable without hypothesis")

    @needs_hypothesis
    def test_property_dedupe_roundtrip():
        raise AssertionError("unreachable without hypothesis")
