"""OCF end-to-end: resize-on-burst, verified deletes, no false negatives."""
import numpy as np
import pytest

from repro.core import OCF, OcfConfig
from repro.core.metrics import (measure_false_negatives,
                                measure_false_positives, theoretical_fp_rate)

from conftest import random_keys

pytestmark = pytest.mark.tier1


@pytest.mark.parametrize("mode", ["PRE", "EOF"])
def test_burst_insert_grows_and_keeps_all_keys(rng, mode):
    ocf = OCF(OcfConfig(capacity=2048, mode=mode))
    keys = random_keys(rng, 10000)
    for i in range(0, keys.size, 1000):
        ok = ocf.insert(keys[i:i + 1000])
        assert ok.all()
    assert ocf.count == keys.size
    assert ocf.lookup(keys).all(), "no false negatives ever"
    assert ocf.stats.resizes >= 1
    assert ocf.occupancy <= 0.96


@pytest.mark.parametrize("mode", ["PRE", "EOF"])
def test_delete_churn_shrinks(rng, mode):
    ocf = OCF(OcfConfig(capacity=2048, mode=mode, c_min=1024))
    keys = random_keys(rng, 8000)
    for i in range(0, keys.size, 1000):
        ocf.insert(keys[i:i + 1000])
    cap_peak = ocf.capacity
    for i in range(0, 7500, 500):
        ocf.delete(keys[i:i + 500])
    assert ocf.capacity < cap_peak, f"{mode} must shrink after delete churn"
    survivors = keys[7500:]
    assert ocf.lookup(survivors).all()


def test_blind_delete_blocked(rng):
    """Paper §IV: deleting a never-inserted key must not corrupt others."""
    ocf = OCF(OcfConfig(capacity=4096))
    keys = random_keys(rng, 1000)
    ocf.insert(keys)
    foreign = random_keys(rng, 1000)
    present = ocf.delete(foreign)
    # (collisions between random 64-bit draws are ~impossible)
    assert not present.any()
    assert ocf.stats.blind_deletes_blocked == 1000
    assert ocf.lookup(keys).all(), "no resident key lost to a blind delete"


def test_false_positive_rate_and_zero_false_negatives(rng):
    ocf = OCF(OcfConfig(capacity=8192, mode="EOF"))
    keys = random_keys(rng, 4000)
    ocf.insert(keys)
    assert measure_false_negatives(ocf, keys) == 0
    probes = random_keys(rng, 50000)
    fps = measure_false_positives(ocf, probes)
    bound = theoretical_fp_rate(4, 16, 1.0) * probes.size * 20 + 5
    assert fps <= bound


def test_emergency_grow_on_full(rng):
    ocf = OCF(OcfConfig(capacity=1024, mode="PRE", o_max=0.999, o_min=0.0))
    # o_max ~1.0 disables predictive resize; filter must self-heal on fail
    keys = random_keys(rng, 5000)
    ok = ocf.insert(keys)
    assert ok.all()
    assert ocf.lookup(keys).all()
    assert ocf.capacity >= 5000


def test_capacity_history_tracks_traffic(rng):
    ocf = OCF(OcfConfig(capacity=2048, mode="EOF"))
    keys = random_keys(rng, 6000)
    ocf.insert(keys[:3000])
    ocf.insert(keys[3000:])
    for i in range(0, 5000, 500):
        ocf.delete(keys[i:i + 500])
    assert len(ocf.capacity_history) == ocf.stats.resizes + 1
