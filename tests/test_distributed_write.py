"""Distributed write path: routed insert/delete on a 2-shard test mesh.

Three contracts, mirroring the single-node parity ladder:

* **Bit-for-bit** (single-lane): batches carrying exactly one key per owner
  shard make every shard's kernel call a single-lane residue, so the PR-4
  contract — Pallas insert/delete/stash == ``PyStashFilter`` oracle, table
  AND stash, entry for entry — must extend through the all_to_all routing
  unchanged.  This is the strongest possible statement that routing is
  semantics-free.

* **Membership + conservation** (contended): multi-lane batches are
  order-racy by design, so the batched test asserts the weaker invariants
  that survive any schedule — every acknowledged key answers lookups, every
  acknowledgment corresponds to exactly one live entry (table or stash),
  and verified deletes drain the state to empty.

* **Deferred routing overflow**: keys exceeding the all_to_all capacity are
  never attempted, never lost — returned as a deferred batch whose
  resubmission drains to full membership, while the per-shard overflow
  counters feed the EOF signal.

Mesh tests run in subprocesses so the forced host-device count doesn't leak
into other tests (same pattern as test_distributed_ocf.py).
"""
import json
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributed as dist
from repro.core import filter as jf
from repro.core import hashing

from conftest import random_keys

pytestmark = pytest.mark.tier1

# JAX_PLATFORMS pinned: without it, backend discovery in the bare-env
# subprocess can stall for minutes on hosts whose accelerator plugins
# time out rather than fail fast.
_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
        "JAX_PLATFORMS": "cpu"}


def _run(script):
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600, env=_ENV)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


ORACLE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import distributed as dist, hashing
    from repro.streaming.oracle import PyStashFilter

    mesh = jax.make_mesh((2,), ("data",))
    NB, BS, FP, ER, SS = 16, 4, 16, 8, 8
    state = dist.make_sharded_state(2, NB, BS, stash_slots=SS)
    oracle = [PyStashFilter(n_buckets=NB, bucket_size=BS, fp_bits=FP,
                            evict_rounds=ER, stash_slots=SS)
              for _ in range(2)]

    # One key per owner shard per step -> every shard-local kernel call is a
    # single valid lane: the bit-for-bit contract applies end to end.
    rng = np.random.RandomState(7)
    raw = rng.randint(0, 2**63, size=4096, dtype=np.int64).astype(np.uint64)
    rhi, rlo = hashing.key_to_u32_pair_np(raw)
    owner = np.asarray(hashing.owner_shard_np(rhi, rlo, 2))
    by_owner = [raw[owner == s] for s in range(2)]
    steps = 72          # 72 keys/shard into 64 slots: evictions + spills
    pairs = [(int(by_owner[0][t]), int(by_owner[1][t])) for t in range(steps)]

    ok_dev, ok_orc = [], []
    for k0, k1 in pairs:
        ks = np.array([k0, k1], dtype=np.uint64)
        hi, lo = hashing.key_to_u32_pair_np(ks)
        state, ok, deferred, _ = dist.distributed_insert(
            mesh, "data", state, jnp.asarray(hi), jnp.asarray(lo),
            fp_bits=FP, backend="pallas", evict_rounds=ER)
        assert not bool(np.asarray(deferred).any())
        ok_dev.append(np.asarray(ok).tolist())
        ok_orc.append([oracle[0].insert(k0), oracle[1].insert(k1)])

    tables_eq = all(
        np.array_equal(np.asarray(state.tables[s]), oracle[s].table)
        for s in range(2))
    stash_eq = all(
        np.array_equal(np.asarray(state.stashes[s]), oracle[s].stash_array())
        for s in range(2))
    spilled = sum(o.spills for o in oracle)

    # Delete the acknowledged half of the stream (even steps), still one
    # lane per shard: stash-parked keys must clear exactly like residents.
    dok_dev, dok_orc = [], []
    for t in range(0, steps, 2):
        k0, k1 = pairs[t]
        if not (ok_orc[t][0] and ok_orc[t][1]):
            continue
        ks = np.array([k0, k1], dtype=np.uint64)
        hi, lo = hashing.key_to_u32_pair_np(ks)
        state, dok, _, _ = dist.distributed_delete(
            mesh, "data", state, jnp.asarray(hi), jnp.asarray(lo),
            fp_bits=FP, backend="pallas")
        dok_dev.append(np.asarray(dok).tolist())
        dok_orc.append([oracle[0].delete(k0), oracle[1].delete(k1)])

    tables_eq2 = all(
        np.array_equal(np.asarray(state.tables[s]), oracle[s].table)
        for s in range(2))
    stash_eq2 = all(
        np.array_equal(np.asarray(state.stashes[s]), oracle[s].stash_array())
        for s in range(2))

    print(json.dumps({
        "ok_match": ok_dev == ok_orc,
        "tables_eq": bool(tables_eq), "stash_eq": bool(stash_eq),
        "spilled": int(spilled),
        "dok_match": dok_dev == dok_orc,
        "tables_eq_after_delete": bool(tables_eq2),
        "stash_eq_after_delete": bool(stash_eq2),
        "n_deletes": len(dok_dev),
    }))
""")


def test_distributed_write_oracle_subprocess():
    """Routed insert/delete == per-shard PyStashFilter, bit for bit."""
    res = _run(ORACLE_SCRIPT)
    assert res["ok_match"], "per-step ack parity"
    assert res["tables_eq"], "shard tables bit-for-bit after inserts"
    assert res["stash_eq"], "shard stashes bit-for-bit after inserts"
    assert res["spilled"] > 0, "workload must actually exercise the stash"
    assert res["n_deletes"] > 0
    assert res["dok_match"], "per-step delete-ack parity"
    assert res["tables_eq_after_delete"]
    assert res["stash_eq_after_delete"], "stash deletes clear in place"


CONTENDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import distributed as dist, hashing

    mesh = jax.make_mesh((2,), ("data",))
    NB, BS, FP = 256, 4, 16            # 2048 slots total
    N = 1800                           # -> 0.879 load when fully placed
    rng = np.random.RandomState(11)
    keys = np.unique(
        rng.randint(1, 2**63, size=2 * N, dtype=np.int64))[:N].astype(
        np.uint64)
    hi, lo = hashing.key_to_u32_pair_np(keys)
    hi, lo = jnp.asarray(hi), jnp.asarray(lo)

    state = dist.make_sharded_state(2, NB, BS, stash_slots=128)
    # max_disp=8: short chains exhaust at this load, forcing stash spills
    # (the jnp arm's chain knob; the kernel arm's is evict_rounds)
    state, ok, deferred, ovf = dist.distributed_insert(
        mesh, "data", state, hi, lo, fp_bits=FP, backend="jnp",
        evict_rounds=64, max_disp=8)
    ok = np.asarray(ok)
    load = float(dist.sharded_occupancy(state))
    hits, _ = dist.distributed_lookup(mesh, "data", state, hi, lo,
                                      fp_bits=FP, backend="jnp")
    live = (int(np.asarray(state.tables != 0).sum())
            + int(np.asarray(state.stashes[:, 0, :] != 0).sum()))
    in_stash = int(np.asarray(state.stashes[:, 0, :] != 0).sum())

    # verified delete of every acknowledged key drains the state to empty
    state2, dok, ddef, _ = dist.distributed_delete(
        mesh, "data", state, hi, lo, fp_bits=FP, backend="jnp")
    residue = (int(np.asarray(state2.tables != 0).sum())
               + int(np.asarray(state2.stashes[:, 0, :] != 0).sum()))

    print(json.dumps({
        "n": int(keys.size),
        "ok": int(ok.sum()),
        "deferred": int(np.asarray(deferred).sum()),
        "load": load,
        "acked_found": int((np.asarray(hits) & ok).sum()),
        "live": live, "in_stash": in_stash,
        "dok": int(np.asarray(dok).sum()),
        "ddeferred": int(np.asarray(ddef).sum()),
        "residue": residue,
    }))
""")


def test_distributed_contended_subprocess():
    """Contended batch at >=0.85 load resolves on-device: membership +
    conservation, then verified deletes drain to empty."""
    res = _run(CONTENDED_SCRIPT)
    assert res["deferred"] == 0, "default capacity absorbs the batch"
    assert res["ok"] == res["n"], "chains + stash place the whole batch"
    assert res["load"] >= 0.85, "the acceptance load is actually reached"
    assert res["acked_found"] == res["ok"], "no false negatives"
    assert res["live"] == res["ok"], "one live entry per acknowledged key"
    assert res["in_stash"] > 0, "contention actually spilled"
    assert res["ddeferred"] == 0
    assert res["dok"] == res["ok"], "every acknowledged key deletes"
    assert res["residue"] == 0, "conservation: deletes drain the state"


OVERFLOW_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import distributed as dist, hashing

    mesh = jax.make_mesh((2,), ("data",))
    NB, BS, FP = 128, 4, 16
    rng = np.random.RandomState(3)
    keys = rng.randint(1, 2**63, size=256, dtype=np.int64).astype(np.uint64)
    hi0, lo0 = hashing.key_to_u32_pair_np(keys)

    state = dist.make_sharded_state(2, NB, BS, stash_slots=32)
    hi, lo = jnp.asarray(hi0), jnp.asarray(lo0)
    state, ok, dfr, ovf = dist.distributed_insert(
        mesh, "data", state, hi, lo, fp_bits=FP, backend="jnp",
        capacity_factor=0.25)
    first_deferred = int(np.asarray(dfr).sum())
    first_ovf = int(np.asarray(ovf).sum())
    both = bool(np.any(np.asarray(ok) & np.asarray(dfr)))

    rounds = 0
    d = np.asarray(dfr)
    while d.any() and rounds < 200:
        idx = np.where(d)[0]
        if len(idx) % 2:
            idx = np.concatenate([idx, idx[:1]])
        hi, lo = hi[idx], lo[idx]
        state, ok, d, _ = dist.distributed_insert(
            mesh, "data", state, hi, lo, fp_bits=FP, backend="jnp",
            capacity_factor=0.25)
        d = np.asarray(d)
        rounds += 1

    hits, _ = dist.distributed_lookup(
        mesh, "data", state, jnp.asarray(hi0), jnp.asarray(lo0), fp_bits=FP,
        backend="jnp")
    print(json.dumps({
        "first_deferred": first_deferred,
        "first_ovf": first_ovf,
        "ok_and_deferred": both,
        "drained": not bool(d.any()),
        "rounds": rounds,
        "all_present": bool(np.asarray(hits).all()),
    }))
""")


def test_distributed_overflow_deferred_subprocess():
    """Routing overflow defers (never loses) keys; resubmission converges."""
    res = _run(OVERFLOW_SCRIPT)
    assert res["first_deferred"] > 0, "tiny capacity must actually overflow"
    assert res["first_deferred"] == res["first_ovf"], (
        "per-shard counters == deferred mask")
    assert not res["ok_and_deferred"], "deferred lanes are never acked"
    assert res["drained"], "resubmission makes progress every round"
    assert res["all_present"], "no key is ever dropped by routing overflow"


def test_local_shard_delete_host_explicit_n_buckets(rng):
    """Compat-shim regression: ``n_buckets`` must follow the active count,
    not the pow2 buffer rows (the single-node discipline, core/filter.py).

    Active count 48 in a 64-row buffer: hashing mod 64 probes the wrong
    buckets, so the pre-fix default silently missed most deletes."""
    keys = random_keys(rng, 120)
    hi, lo = hashing.key_to_u32_pair_np(keys)
    hi, lo = jnp.asarray(hi), jnp.asarray(lo)
    st = jf.make_state(48, 4, buffer_buckets=64)
    st, ok = jf.bulk_insert(st, hi, lo, fp_bits=16)
    assert bool(np.asarray(ok).all())

    # state carrying its active count: the default must pick it up
    sh = dist.ShardedFilterState(tables=st.table[None], n_buckets=48)
    sh2, dok = dist.local_shard_delete_host(sh, 0, hi, lo, fp_bits=16,
                                            backend="jnp")
    assert bool(np.asarray(dok).all())
    assert int(np.asarray(sh2.tables).sum()) == 0

    # legacy state (no n_buckets): explicit argument works ...
    legacy = dist.ShardedFilterState(tables=st.table[None])
    leg2, lok = dist.local_shard_delete_host(legacy, 0, hi, lo, fp_bits=16,
                                             backend="jnp", n_buckets=48)
    assert bool(np.asarray(lok).all())
    # ... while the buffer-rows fallback (the old default) probes wrong
    # buckets and misses — the behavior the fix removes for carried states.
    _, bad = dist.local_shard_delete_host(legacy, 0, hi, lo, fp_bits=16,
                                          backend="jnp")
    assert not bool(np.asarray(bad).all())


def test_sharded_state_compat(rng):
    """Old construction patterns keep working: bare tables, _replace-based
    host swap, and the lookup path over a stash-less state."""
    tables = jnp.zeros((2, 32, 4), jnp.uint32)
    st = dist.ShardedFilterState(tables=tables)
    assert st.stashes is None and st.n_buckets is None
    st2 = dist.local_shard_insert_host(st, 1, jnp.ones((32, 4), jnp.uint32))
    assert int(np.asarray(st2.tables[1]).sum()) == 32 * 4
    assert st2.stashes is None

    full = dist.make_sharded_state(2, 32, 4, stash_slots=16,
                                   buffer_buckets=64)
    assert full.tables.shape == (2, 64, 4)
    assert full.stashes.shape == (2, 2, 16)
    assert full.n_buckets == 32
    swapped = dist.local_shard_insert_host(full, 0,
                                           jnp.zeros((64, 4), jnp.uint32))
    assert swapped.stashes is not None and swapped.n_buckets == 32


PUMP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import distributed as dist, hashing
    from repro.serving.scheduler import DeferredWritePump

    mesh = jax.make_mesh((2,), ("data",))
    NB, BS, FP = 256, 4, 16
    rng = np.random.RandomState(7)
    keys = np.unique(rng.randint(1, 2**63, size=1024, dtype=np.int64)
                     ).astype(np.uint64)
    hi, lo = hashing.key_to_u32_pair_np(keys)

    # --- valid-mask semantics: poisoned invalid lanes must be inert ----
    state = dist.make_sharded_state(2, NB, BS, stash_slots=64)
    n = 64
    vhi = jnp.concatenate([jnp.asarray(hi[:n]), jnp.zeros((n,), jnp.uint32)])
    vlo = jnp.concatenate([jnp.asarray(lo[:n]), jnp.zeros((n,), jnp.uint32)])
    valid = jnp.concatenate([jnp.ones((n,), bool), jnp.zeros((n,), bool)])
    state, ok, dfr, _ = dist.distributed_insert(
        mesh, "data", state, vhi, vlo, fp_bits=FP, valid=valid)
    ok, dfr = np.asarray(ok), np.asarray(dfr)
    zhit, _ = dist.distributed_lookup(
        mesh, "data", state, jnp.zeros((2,), jnp.uint32),
        jnp.zeros((2,), jnp.uint32), fp_bits=FP)
    mask_ok = bool(ok[:n].all() and not ok[n:].any() and not dfr.any())
    live = int(np.asarray(state.tables != 0).sum())

    # --- pump: skewed burst under tight capacity defers, then drains ---
    owner = np.asarray(hashing.owner_shard_np(hi, lo, 2))
    hot = keys[owner == 0]
    skew = np.concatenate([hot, hot, keys[owner == 1]])[:512]
    shi, slo = hashing.key_to_u32_pair_np(skew)
    pump = DeferredWritePump(mesh, "data",
                             dist.make_sharded_state(2, NB, BS,
                                                     stash_slots=64),
                             fp_bits=FP, capacity_factor=0.25)
    sok, sdfr = pump.submit(shi, slo)
    first_deferred = int(sdfr.sum())

    # hold the gate shut for 3 ticks, then open: held_ticks must count
    class Gate:
        def __init__(self, closed): self.closed, self.tripped = closed, True
        def peek(self):
            self.closed -= 1
            self.tripped = self.closed >= 0
            return not self.tripped
    pump.admission = Gate(3)
    pump.run_until_drained(max_ticks=64,
                           on_held=lambda p: None)   # keep ticking
    phits, _ = dist.distributed_lookup(
        mesh, "data", pump.state, jnp.asarray(shi), jnp.asarray(slo),
        fp_bits=FP)
    pzero, _ = dist.distributed_lookup(
        mesh, "data", pump.state, jnp.zeros((2,), jnp.uint32),
        jnp.zeros((2,), jnp.uint32), fp_bits=FP)

    print(json.dumps({
        "mask_ok": mask_ok,
        "zero_hit": bool(np.asarray(zhit).any()),
        "live": live, "n": n,
        "first_deferred": first_deferred,
        "held_ticks": pump.stats.held_ticks,
        "pending": pump.pending,
        "inserted": pump.stats.inserted,
        "submitted": pump.stats.submitted,
        "all_present": bool(np.asarray(phits).all()),
        "pad_hit": bool(np.asarray(pzero).any()),
    }))
""")


def test_deferred_write_pump_subprocess():
    """PR-7 satellite: the hysteresis-gated pump re-lands every deferred
    lane, valid-mask padding stays inert, and a closed admission gate is
    counted as held ticks instead of hammering the mesh."""
    res = _run(PUMP_SCRIPT)
    # lane-mask contract: invalid lanes are never acked, deferred, or
    # written — the all-zero poison key must not become resident
    assert res["mask_ok"], "valid mask acks exactly the valid lanes"
    assert not res["zero_hit"], "invalid poison lanes must never land"
    assert res["live"] == res["n"], "one live entry per valid lane"
    # pump contract
    assert res["first_deferred"] > 0, "tight capacity must defer"
    assert res["held_ticks"] == 3, "closed gate ticks are counted, not spun"
    assert res["pending"] == 0, "pump drains once the gate opens"
    assert res["inserted"] == res["submitted"]
    assert res["all_present"], "every deferred key eventually lands"
    assert not res["pad_hit"], "resubmission padding lanes must stay inert"
