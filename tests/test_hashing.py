"""Hash-primitive tests: numpy/jax agreement, involution, distribution."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashing

from conftest import random_keys

pytestmark = pytest.mark.tier1


def test_numpy_jax_agreement(rng):
    keys = random_keys(rng, 4096)
    hi, lo = hashing.key_to_u32_pair_np(keys)
    for fp_bits in (8, 12, 16, 24, 32):
        fnp = hashing.fingerprint_np(hi, lo, fp_bits)
        fj = np.asarray(hashing.fingerprint(jnp.asarray(hi), jnp.asarray(lo),
                                            fp_bits))
        np.testing.assert_array_equal(fnp, fj)
    for n in (7, 256, 1000, 1 << 20):
        inp = hashing.index_hash_np(hi, lo, n)
        ij = np.asarray(hashing.index_hash(jnp.asarray(hi), jnp.asarray(lo), n))
        np.testing.assert_array_equal(inp, ij)


@pytest.mark.parametrize("n_buckets", [2, 7, 256, 1000, 4096, 999983])
def test_alt_index_involution(rng, n_buckets):
    """alt(alt(i)) == i for ANY bucket count (the non-pow2 requirement)."""
    keys = random_keys(rng, 2048)
    hi, lo = hashing.key_to_u32_pair_np(keys)
    fp = hashing.fingerprint_np(hi, lo, 16)
    i1 = hashing.index_hash_np(hi, lo, n_buckets)
    i2 = hashing.alt_index_np(i1, fp, n_buckets)
    i1_back = hashing.alt_index_np(i2, fp, n_buckets)
    np.testing.assert_array_equal(i1 % n_buckets, i1_back)
    assert (i2 < n_buckets).all()


def test_fingerprint_never_zero(rng):
    keys = random_keys(rng, 1 << 16)
    hi, lo = hashing.key_to_u32_pair_np(keys)
    for fp_bits in (4, 8, 16):
        fp = hashing.fingerprint_np(hi, lo, fp_bits)
        assert (fp != 0).all()
        assert (fp < (1 << fp_bits)).all()


def test_index_distribution_uniform(rng):
    keys = random_keys(rng, 1 << 16)
    hi, lo = hashing.key_to_u32_pair_np(keys)
    idx = hashing.index_hash_np(hi, lo, 64)
    counts = np.bincount(idx, minlength=64)
    # chi-square-ish bound: each bucket within 25% of the mean
    mean = keys.size / 64
    assert (np.abs(counts - mean) < 0.25 * mean).all()


def test_owner_shard_matches_jax(rng):
    keys = random_keys(rng, 1024)
    hi, lo = hashing.key_to_u32_pair_np(keys)
    a = hashing.owner_shard_np(hi, lo, 16)
    b = np.asarray(hashing.owner_shard(jnp.asarray(hi), jnp.asarray(lo), 16))
    np.testing.assert_array_equal(a, b)
