"""Continuous-batching scheduler + gradient-compression tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Transformer
from repro.serving.scheduler import ContinuousBatcher, Request

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(get_smoke_config("gemma3_1b"), dtype="float32")
    model = Transformer(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_burst_drains_and_reuses_prefixes(small_model):
    cfg, model, params = small_model
    rng = np.random.RandomState(0)
    b = ContinuousBatcher(model, params, slots=3, cache_len=128, block=16)
    shared = rng.randint(0, cfg.vocab_size, 48).astype(np.int32)
    # a burst of 7 requests, 4 sharing a prefix
    for i in range(7):
        if i % 2 == 0:
            p = np.concatenate([shared,
                                rng.randint(0, cfg.vocab_size,
                                            16).astype(np.int32)])
        else:
            p = rng.randint(0, cfg.vocab_size, 64).astype(np.int32)
        b.submit(Request(rid=i, prompt=p, max_new=4))
    assert b.congestion > 1.0, "burst exceeds slot capacity (backpressure)"
    stats = b.run_until_drained()
    assert stats.finished == 7
    assert stats.prefills == 7
    assert stats.prefix_blocks_reused > 0, "shared prefixes must hit the OCF"
    assert stats.decode_steps > 0
    assert not b.queue and not b.active


def test_scheduler_output_matches_unbatched(small_model):
    """A request decoded through the scheduler == plain greedy generation."""
    from repro.serving.engine import generate
    cfg, model, params = small_model
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab_size, 32).astype(np.int32)
    b = ContinuousBatcher(model, params, slots=2, cache_len=64, block=16)
    req = Request(rid=0, prompt=prompt, max_new=6)
    b.submit(req)
    b.run_until_drained()
    ref = generate(model, params, jnp.asarray(prompt)[None, :], 6,
                   cache_len=64)
    np.testing.assert_array_equal(np.array(req.out),
                                  np.asarray(ref.tokens)[0])


def test_int8_gradient_compression_bounded_error():
    from repro.train.step import dequantize_int8, quantize_int8
    rng = np.random.RandomState(0)
    for scale in (1e-4, 1.0, 37.0):
        g = jnp.asarray(rng.randn(256, 64) * scale, jnp.float32)
        q, s = quantize_int8(g)
        back = dequantize_int8(q, s)
        assert q.dtype == jnp.int8
        err = float(jnp.max(jnp.abs(back - g)))
        assert err <= float(s) / 2 + 1e-9, "symmetric rounding bound"


def test_compress_grads_int8_in_train_step():
    from repro.distributed.sharding import ParallelConfig
    from repro.optim.adamw import AdamW
    from repro.train.step import make_train_step
    cfg = dataclasses.replace(get_smoke_config("mistral_nemo_12b"),
                              dtype="float32")
    model = Transformer(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    tx = AdamW(lr=1e-3)
    opt = tx.init(params)
    pc = ParallelConfig(pod_axis="pod", compress_grads=True,
                        compress_int8=True)
    step = make_train_step(model, tx, pc)
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size),
             "targets": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                           cfg.vocab_size)}
    p2, _, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
