"""Admission hysteresis boundary contracts (PR 7 satellite).

``test_streaming.py`` exercises the controller end-to-end against a real
``GenerationalFilter``; these tests pin the *exact* boundary semantics with
a stub whose ``fills()`` is programmable, because the reputation tier and
the deferred-write pump both key off the precise trip/reset points:

  * trip happens exactly AT ``high_water`` (``>=``, not ``>``);
  * re-admission happens exactly AT ``low_water`` (``<=``, not ``<``);
  * inside the hysteresis band the previous state holds in both directions;
  * ``observe_eof`` inflates marked ops by exactly
    ``max(1, round(ops * (1 + signal)))``.
"""
import dataclasses

import pytest

from repro.core.policy import EofPolicy
from repro.streaming import AdmissionConfig, AdmissionController

pytestmark = pytest.mark.tier1


@dataclasses.dataclass
class _StubFilter:
    """Duck-typed stand-in: anything with ``fills() -> (fill, stash_fill)``.

    Drives the whole congestion signal through ``fill`` (fill_weight=1) so
    each test names the signal value directly.
    """

    fill: float = 0.0

    def fills(self):
        return self.fill, 0.0


_CFG = AdmissionConfig(stash_weight=0.0, fill_weight=1.0,
                       high_water=0.85, low_water=0.60)


def _controller(fill=0.0):
    return AdmissionController(_StubFilter(fill), _CFG)


def test_trips_exactly_at_high_water():
    ctl = _controller()
    eps = 1e-9
    ctl.filt.fill = _CFG.high_water - eps
    assert ctl.peek(), "just under high_water must still admit"
    assert not ctl.tripped
    ctl.filt.fill = _CFG.high_water
    assert not ctl.peek(), "signal == high_water must trip (>= boundary)"
    assert ctl.tripped


def test_readmits_exactly_at_low_water():
    ctl = _controller(fill=1.0)
    assert not ctl.peek()                   # trip first
    eps = 1e-9
    ctl.filt.fill = _CFG.low_water + eps
    assert not ctl.peek(), "just above low_water must stay tripped"
    ctl.filt.fill = _CFG.low_water
    assert ctl.peek(), "signal == low_water must re-admit (<= boundary)"
    assert not ctl.tripped


def test_hysteresis_band_holds_previous_state():
    mid = (_CFG.low_water + _CFG.high_water) / 2.0
    # Approaching from below: band value admits (never tripped).
    ctl = _controller(fill=mid)
    assert ctl.peek()
    # Approaching from above: same band value stays tripped.
    ctl = _controller(fill=1.0)
    assert not ctl.peek()
    ctl.filt.fill = mid
    assert not ctl.peek(), "band is sticky: tripped state holds"


def test_peek_leaves_counters_untouched_admit_counts():
    ctl = _controller(fill=0.0)
    for _ in range(3):
        ctl.peek()
    assert (ctl.admitted, ctl.deferred) == (0, 0)
    assert ctl.admit() and ctl.admitted == 1
    ctl.filt.fill = 1.0
    assert not ctl.admit()
    assert ctl.deferred == 1


def test_observe_eof_inflates_marked_ops_exactly():
    # Window armed outside the markers, occupancy outside [o_min, o_max]
    # band never reached, so every observe just accumulates t_cur.
    for signal, ops, want in ((0.0, 7, 7), (0.5, 7, 10), (1.0, 7, 14),
                              (0.8, 1, 2), (0.0, 1, 1)):
        ctl = _controller(fill=signal)
        pol = EofPolicy(c_min=64)
        pol.observe(items=90, capacity=100, ops=1)   # arm the window
        before = pol.t_cur
        ctl.observe_eof(pol, items=90, capacity=100, ops=ops)
        inflated = pol.t_cur - before
        assert inflated == want, (
            f"signal={signal} ops={ops}: got {inflated}, want "
            f"max(1, round(ops * (1 + signal))) = {want}")
