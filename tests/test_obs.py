"""Observability tests — counter-plane parity, registry, spans, policy.

The contracts pinned here:

  * **dispatch identity**: a batcher with telemetry OFF issues exactly
    the pre-telemetry device-call sequence (never a ``*_tm`` entry
    point), and its results and final filter state are bit-for-bit those
    of a batcher built without any observability kwargs at all —
    attaching a registry or tracer must not change the device work;
  * **telemetry parity**: turning the counter planes ON changes the
    counters, never the answers — results, tables, stashes and counts
    stay bit-identical to the off path, while the registry fills with a
    kick-depth histogram whose mass equals the insert lanes offered;
  * **trip -> shed -> readmit**: the registry-fed ``BackpressureController``
    walks the admit/defer/shed state machine off the same metrics the
    admission gate publishes, with hysteresis on the way back down;
  * **vectorized ground truth**: ``measure_false_positives`` /
    ``measure_false_negatives`` through the batch keystore pass agree
    with the per-key scalar loop they replaced;
  * merge associativity of the device telemetry fold (hypothesis,
    optional dep — not tier-1).
"""
import json

import numpy as np
import pytest

import repro.kernels.ops as kops_mod
from repro.core import filter as jfilter
from repro.core.filter_ops import FilterOps
from repro.core.keystore import VectorKeystore
from repro.core.metrics import (measure_false_negatives,
                                measure_false_positives)
from repro.core.ocf import OCF, OcfConfig
from repro.kernels import ops as kops
from repro.obs import MetricsRegistry, TraceRecorder
from repro.serving.engine import BackpressureConfig, BackpressureController
from repro.serving.scheduler import FilterOpBatcher
from repro.streaming.admission import AdmissionConfig, AdmissionController

pytestmark = pytest.mark.obs

WS = 64

# every device entry point the batcher can reach, off and on
_SPIED = ("probe_dispatch", "filter_insert", "filter_delete",
          "adaptive_lookup", "adaptive_insert", "adaptive_delete",
          "adaptive_report", "probe_dispatch_tm", "filter_insert_tm",
          "filter_delete_tm", "adaptive_lookup_tm", "adaptive_insert_tm",
          "adaptive_delete_tm", "adaptive_report_tm")


def _spy_kops(monkeypatch):
    """Record the name of every kops entry point the batcher dispatches."""
    calls = []

    def wrap(name):
        orig = getattr(kops_mod, name)

        def wrapped(*a, **k):
            calls.append(name)
            return orig(*a, **k)

        return wrapped

    for name in _SPIED:
        monkeypatch.setattr(kops_mod, name, wrap(name))
    return calls


def _mk_batcher(**obs_kwargs):
    ops = FilterOps(backend="pallas", evict_rounds=16)
    state = jfilter.make_state(256, buffer_buckets=256)
    stash = kops.make_stash(16)
    return FilterOpBatcher(ops, state, stash=stash, wave_slots=WS,
                           double_buffer=True, **obs_kwargs)


def _replay(batcher, rng):
    results = []
    for i in range(6):
        kind = ("insert", "lookup", "delete")[i % 3]
        keys = rng.randint(1, 2 ** 62, size=WS, dtype=np.int64)
        wave = batcher.submit(kind, keys.astype(np.uint64))
        results.append(wave)
    batcher.flush()
    return [w.results for w in results]


@pytest.mark.tier1
def test_telemetry_off_dispatch_identical(monkeypatch):
    """Attaching metrics/tracer with telemetry OFF must not change the
    device-call sequence or any bit of the results/state."""
    import jax.numpy as jnp

    calls = _spy_kops(monkeypatch)
    plain = _mk_batcher()
    res_plain = _replay(plain, np.random.RandomState(3))
    seq_plain = list(calls)

    calls.clear()
    observed = _mk_batcher(metrics=MetricsRegistry(), tracer=TraceRecorder())
    res_obs = _replay(observed, np.random.RandomState(3))
    seq_obs = list(calls)

    assert seq_obs == seq_plain
    assert not any(name.endswith("_tm") for name in seq_obs)
    for a, b in zip(res_plain, res_obs):
        np.testing.assert_array_equal(a, b)
    assert jnp.array_equal(plain.state.table, observed.state.table)
    assert jnp.array_equal(plain.stash, observed.stash)
    assert int(plain.state.count) == int(observed.state.count)


@pytest.mark.tier1
def test_telemetry_on_counters_change_answers_dont(monkeypatch):
    import jax.numpy as jnp

    calls = _spy_kops(monkeypatch)
    plain = _mk_batcher()
    res_plain = _replay(plain, np.random.RandomState(5))

    calls.clear()
    m = MetricsRegistry()
    on = _mk_batcher(telemetry=True, metrics=m)
    res_on = _replay(on, np.random.RandomState(5))

    # the telemetry arm dispatches ONLY through the twin entry points
    assert calls and all(n.endswith("_tm") for n in calls)
    for a, b in zip(res_plain, res_on):
        np.testing.assert_array_equal(a, b)
    assert jnp.array_equal(plain.state.table, on.state.table)
    assert jnp.array_equal(plain.stash, on.stash)
    assert int(plain.state.count) == int(on.state.count)

    snap = m.snapshot()
    kick = snap["filter_kick_depth"]
    assert sum(kick["counts"]) == 2 * WS  # every insert lane binned once
    assert 'filter_waves{kind="insert"}' in snap
    assert any(k.startswith("filter_probe_depth") for k in snap)
    assert "filter_stash_fill_hw" in snap
    assert len(m.ring) == 6


@pytest.mark.tier1
def test_adaptive_telemetry_parity():
    import jax.numpy as jnp

    from repro.adaptive.state import make_adaptive_state

    def mk(**kw):
        return FilterOpBatcher(FilterOps(backend="pallas", evict_rounds=16),
                               make_adaptive_state(256),
                               stash=kops.make_stash(8), wave_slots=WS,
                               double_buffer=True, **kw)

    rng = np.random.RandomState(11)
    keys = rng.randint(1, 2 ** 62, size=WS, dtype=np.int64).astype(np.uint64)
    m = MetricsRegistry()
    on, off = mk(telemetry=True, metrics=m), mk()
    for b in (on, off):
        b.submit("insert", keys)
        b.submit("lookup", keys)
        b.submit("report", keys[:16])
        b.submit("delete", keys[:32])
        b.flush()
    assert jnp.array_equal(on.state.table, off.state.table)
    assert jnp.array_equal(on.state.sels, off.state.sels)
    assert jnp.array_equal(on.stash, off.stash)
    assert int(on.state.count) == int(off.state.count)
    snap = m.snapshot()
    # every inserted key was present: lookups must all land at some depth
    depth = sum(v for k, v in snap.items()
                if k.startswith("filter_probe_depth"))
    assert depth == WS
    assert snap.get("filter_table_deletes", 0) + snap.get(
        "filter_stash_deletes", 0) >= 1


@pytest.mark.tier1
def test_backpressure_trip_shed_readmit_sequence():
    """The engine's admit -> defer -> shed -> admit walk over registry
    metrics, exactly as the admission arm publishes them."""
    m = MetricsRegistry()
    bp = BackpressureController(m, BackpressureConfig(defer_signal=0.8,
                                                      resume_signal=0.5))
    sig = m.gauge("admission_signal")

    sig.set(0.1)
    assert bp.decide() == "admit"
    # congestion crosses the defer threshold (the gate trips)
    sig.set(0.9)
    m.counter("admission_trips").inc()
    m.counter("filter_deferred_waves").inc()
    assert bp.decide() == "defer"
    # inside the hysteresis band: still deferring, no flap
    sig.set(0.7)
    assert bp.decide() == "defer"
    # a drain gave up -> genuine shed load escalates
    m.counter("filter_shed_ops").inc(128)
    assert bp.decide() == "shed"
    # signal recedes below resume with no new evidence -> readmit
    sig.set(0.4)
    m.counter("admission_readmits").inc()
    assert bp.decide() == "admit"
    # decisions were themselves recorded
    snap = m.snapshot()
    assert snap['backpressure_decisions{decision="shed"}'] == 1
    assert snap['backpressure_decisions{decision="admit"}'] == 2


@pytest.mark.tier1
def test_backpressure_from_live_admission_metrics():
    """End to end: a burst through an admission-gated batcher publishes
    trips/deferred/shed into the registry, and a BackpressureController
    reading that registry sheds."""
    m = MetricsRegistry()
    ops = FilterOps(backend="pallas", evict_rounds=16)
    state = jfilter.make_state(64, buffer_buckets=64)
    batcher = FilterOpBatcher(
        ops, state, stash=kops.make_stash(8), wave_slots=WS,
        double_buffer=True, metrics=m,
        admission=AdmissionConfig(high_water=0.3, low_water=0.1))
    bp = BackpressureController(m)
    assert bp.decide() == "admit"
    rng = np.random.RandomState(2)
    for _ in range(12):  # overload a tiny table: 12 x 64 lanes into 256 slots
        batcher.submit("insert",
                       rng.randint(1, 2 ** 62, size=WS,
                                   dtype=np.int64).astype(np.uint64))
    batcher.drain()
    snap = m.snapshot()
    assert snap.get("filter_deferred_waves", 0) >= 1
    assert snap.get("filter_shed_ops", 0) >= 1
    assert snap.get("admission_trips", 0) >= 1
    assert bp.decide() == "shed"


@pytest.mark.tier1
def test_admission_controller_transition_counters():
    class Fills:
        def __init__(self):
            self.v = (0.0, 0.0)

        def fills(self):
            return self.v

    m = MetricsRegistry()
    f = Fills()
    ctl = AdmissionController(filt=f, config=AdmissionConfig(
        high_water=0.5, low_water=0.2), metrics=m)
    assert ctl.peek()
    f.v = (1.0, 1.0)
    assert not ctl.peek()          # trip
    assert not ctl.peek()          # still tripped: no double count
    f.v = (0.0, 0.0)
    assert ctl.peek()              # readmit
    assert m.counter("admission_trips").value() == 1
    assert m.counter("admission_readmits").value() == 1
    assert m.gauge("admission_peak_signal").value() == 1.0


@pytest.mark.tier1
def test_measure_fp_fn_match_scalar_loop(rng):
    ocf = OCF(OcfConfig(capacity=1 << 10, fp_bits=8))
    inserted = rng.randint(1, 2 ** 62, size=600,
                           dtype=np.int64).astype(np.uint64)
    ocf.insert(inserted)
    probes = rng.randint(1, 2 ** 62, size=2000,
                         dtype=np.int64).astype(np.uint64)
    mixed = np.concatenate([probes, inserted[:100]])

    # the scalar ground-truth loop the vectorized path replaced
    absent = np.array([not ocf.contains_key_exact(int(k)) for k in mixed])
    hits = ocf.lookup(mixed)
    assert measure_false_positives(ocf, mixed) == int(np.sum(hits & absent))
    assert measure_false_negatives(ocf, inserted) == 0
    present = ocf.contains_keys_exact(mixed)
    np.testing.assert_array_equal(present, ~absent)


@pytest.mark.tier1
def test_keystore_contains_batch_duplicates_and_empty():
    ks = VectorKeystore()
    assert ks.contains_batch(np.array([1, 2], np.uint64)).tolist() == \
        [False, False]
    ks.add(np.array([5, 5, 9], np.uint64))
    got = ks.contains_batch(np.array([9, 5, 7, 5, 0], np.uint64))
    assert got.tolist() == [True, True, False, True, False]
    ks.remove(np.array([5, 5], np.uint64))
    assert ks.contains_batch(np.array([5], np.uint64)).tolist() == [False]


# ---------------------------------------------------------- registry ----


@pytest.mark.tier1
def test_registry_counter_gauge_histogram():
    m = MetricsRegistry()
    m.counter("c").inc(2, kind="a")
    m.counter("c").inc(kind="b")
    assert m.counter("c").value(kind="a") == 2
    m.gauge("g").set(3.0)
    m.gauge("g").set_max(1.0)
    assert m.gauge("g").value() == 3.0
    h = m.histogram("h", buckets=(1, 2, 4))
    h.observe(0.5)
    h.observe(3)
    h.observe(100)
    h.observe_counts([1, 0, 0, 0])
    s = h.series()[()]
    assert s.counts == [2.0, 0.0, 1.0, 1.0]
    with pytest.raises(ValueError):
        m.histogram("h", buckets=(1, 2, 8))
    with pytest.raises(TypeError):
        m.gauge("c")
    with pytest.raises(ValueError):
        h.observe_counts([1, 2])


@pytest.mark.tier1
def test_registry_exports(tmp_path):
    m = MetricsRegistry(ring_capacity=4)
    m.counter("filter_waves").inc(3, kind="insert")
    m.histogram("lat", buckets=(10, 100)).observe(42)
    for i in range(6):
        m.record_wave({"i": i})
    # ring wrapped: only the last 4 records, in order
    assert [r["i"] for r in m.ring.records()] == [2, 3, 4, 5]

    path = tmp_path / "m.jsonl"
    m.to_jsonl(str(path))
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert any(ln.get("metric") == "filter_waves" for ln in lines)
    assert sum(1 for ln in lines if ln["type"] == "wave") == 4

    text = m.prometheus_text()
    assert 'filter_waves_total{kind="insert"} 3.0' in text
    assert 'lat_bucket{le="+Inf"} 1.0' in text
    assert "# TYPE lat histogram" in text


@pytest.mark.tier1
def test_trace_recorder_perfetto_shape(tmp_path):
    t = [0.0]

    def clock():
        t[0] += 0.001
        return t[0]

    tr = TraceRecorder(process_name="test", clock=clock)
    with tr.span("outer", kind="insert"):
        with tr.span("inner"):
            pass
    tr.instant("mark")
    tr.counter("fill", table=0.5)
    path = tmp_path / "trace.json"
    tr.save(str(path))
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    names = [e["name"] for e in events]
    assert "outer" in names and "inner" in names and "mark" in names
    spans = [e for e in events if e.get("ph") == "X"]
    assert all(e["dur"] > 0 for e in spans)
    inner = next(e for e in events if e["name"] == "inner")
    outer = next(e for e in events if e["name"] == "outer")
    assert outer["ts"] <= inner["ts"]
    assert outer["ts"] + outer["dur"] >= inner["ts"] + inner["dur"]
    assert outer["args"]["kind"] == "insert"


# ------------------------------------------------- merge properties -----
#
# NOT tier-1: hypothesis is an optional dev dependency.


def test_telemetry_merge_associative_commutative():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    from repro.kernels.telemetry import (FilterTelemetry, empty_telemetry,
                                         merge)
    import jax.numpy as jnp

    def mk(vals):
        u32 = lambda x: jnp.asarray(x, jnp.uint32)  # noqa: E731
        return FilterTelemetry(
            kick_hist=u32(vals[:8]), probe_depth=u32(vals[8:12]),
            stash_spills=u32(vals[12]), stash_fill_hw=u32(vals[13]),
            rollback_lanes=u32(vals[14]), selector_bumps=u32(vals[15]),
            overflow_lanes=u32(vals[16]), table_deletes=u32(vals[17]),
            stash_deletes=u32(vals[18]))

    vec = st.lists(st.integers(min_value=0, max_value=2 ** 20),
                   min_size=19, max_size=19)

    @settings(max_examples=50, deadline=None)
    @given(vec, vec, vec)
    def check(a, b, c):
        ta, tb, tc = mk(a), mk(b), mk(c)
        left = merge(merge(ta, tb), tc)
        right = merge(ta, merge(tb, tc))
        for x, y in zip(left, right):
            assert jnp.array_equal(x, y)
        ab, ba = merge(ta, tb), merge(tb, ta)
        for x, y in zip(ab, ba):
            assert jnp.array_equal(x, y)
        ea = merge(empty_telemetry(), ta)
        for x, y in zip(ea, ta):
            assert jnp.array_equal(x, y)

    check()
